"""Deterministic, shardable synthetic token pipeline.

Every (step, shard) pair maps to a unique counter-based PRNG stream, so:
  * restarts resume byte-identically (checkpoint stores only the step),
  * elastic re-sharding (different dp size) re-partitions the same global
    batch deterministically,
  * no host I/O — generation is jittable and runs on-device, double-buffered
    by the driver (prefetch overlap).

A real deployment swaps `synthetic_batch` for a tokenized corpus reader with
the same (step -> global batch) contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    input_mode: str = "tokens"  # "tokens" | "embeds"
    d_model: int = 0  # for embeds mode
    seed: int = 0


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, Array]:
    """Global batch for `step` — markov-ish stream so the LM loss decreases."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S = cfg.global_batch, cfg.seq_len
    if cfg.input_mode == "tokens":
        k1, k2 = jax.random.split(key)
        # structured stream: ramps + noise -> learnable bigram structure
        starts = jax.random.randint(k1, (B, 1), 0, cfg.vocab)
        ramps = (starts + jnp.arange(S + 1)[None, :]) % cfg.vocab
        noise = jax.random.bernoulli(k2, 0.1, (B, S + 1))
        rand = jax.random.randint(k2, (B, S + 1), 0, cfg.vocab)
        seq = jnp.where(noise, rand, ramps)
        return {"inputs": seq[:, :S], "targets": seq[:, 1:]}
    k1, k2 = jax.random.split(key)
    embeds = jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32)
    targets = jax.random.randint(k2, (B, S), 0, cfg.vocab)
    return {"inputs": embeds, "targets": targets}


class Prefetcher:
    """One-step-ahead prefetch: generation of batch t+1 overlaps step t.

    On TPU this hides host->device transfer; here it documents the overlap
    structure (compute/comm overlap requirement) and keeps the driver honest.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0, sharding=None):
        self.cfg = cfg
        self.step = start_step
        self.sharding = sharding
        self._next = None
        self._gen = jax.jit(
            lambda s: synthetic_batch(cfg, s), out_shardings=sharding
        ) if sharding is not None else jax.jit(lambda s: synthetic_batch(cfg, s))
        self._prefetch()

    def _prefetch(self):
        self._next = self._gen(self.step)

    def __next__(self) -> Dict[str, Array]:
        batch = self._next
        self.step += 1
        self._prefetch()  # dispatch is async; overlaps consumer compute
        return batch

    def __iter__(self) -> Iterator[Dict[str, Array]]:
        return self
