from .pipeline import DataConfig, Prefetcher, synthetic_batch  # noqa: F401
