"""Version-compat shims for JAX sharding APIs.

The repo targets the newest jax sharding surface (``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.shard_map`` with ``check_vma``), but must also run on
older installs (0.4.x) where those names live elsewhere or don't exist:

  * ``AxisType``      — stub enum when ``jax.sharding.AxisType`` is missing
                        (old meshes have no axis types; the stub lets call
                        sites pass ``axis_types=(AxisType.Auto,) * n``
                        unconditionally).
  * ``make_mesh``     — builds a Mesh from a device ndarray *or* a shape
                        tuple, dropping ``axis_types`` when unsupported.
  * ``set_mesh``      — context manager: ``jax.set_mesh`` on new jax, the
                        legacy ``with mesh:`` resource-env manager otherwise.
  * ``shard_map``     — ``jax.shard_map`` / ``jax.experimental.shard_map``,
                        translating ``check_vma`` <-> ``check_rep``.

Every file that touches these APIs imports them from here, never from jax
directly — that is what keeps tier-1 collection working across jax versions.
"""
from __future__ import annotations

import inspect

import jax

# --------------------------------------------------------------------- AxisType
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPE = True
except ImportError:  # jax < 0.5: meshes have no axis types
    import enum

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False


# --------------------------------------------------------------------- make_mesh
def _mesh_accepts_axis_types() -> bool:
    try:
        return "axis_types" in inspect.signature(jax.sharding.Mesh.__init__).parameters
    except (ValueError, TypeError):
        # old Mesh has a (*args, **kwargs) __init__ wrapper; probe the class
        return HAS_AXIS_TYPE


_MESH_AXIS_TYPES = _mesh_accepts_axis_types()


def make_mesh(devices_or_shape, axis_names, axis_types=None) -> jax.sharding.Mesh:
    """Mesh from a device ndarray or a shape tuple; drops unsupported kwargs."""
    if isinstance(devices_or_shape, tuple) and all(
        isinstance(d, int) for d in devices_or_shape
    ):
        if hasattr(jax, "make_mesh"):
            if axis_types is not None and HAS_AXIS_TYPE:
                try:
                    return jax.make_mesh(
                        devices_or_shape, axis_names, axis_types=axis_types
                    )
                except TypeError:
                    pass
            return jax.make_mesh(devices_or_shape, axis_names)
        # jax < 0.4.35: no jax.make_mesh — build the device grid ourselves
        from jax.experimental import mesh_utils

        devices_or_shape = mesh_utils.create_device_mesh(devices_or_shape)
    if axis_types is not None and _MESH_AXIS_TYPES and HAS_AXIS_TYPE:
        try:
            return jax.sharding.Mesh(devices_or_shape, axis_names, axis_types=axis_types)
        except TypeError:
            pass
    return jax.sharding.Mesh(devices_or_shape, axis_names)


# --------------------------------------------------------------------- set_mesh
def set_mesh(mesh: jax.sharding.Mesh):
    """``with set_mesh(mesh): ...`` — ambient mesh on any jax version."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # legacy: Mesh is itself a context manager entering the resource env
    return mesh


# --------------------------------------------------------------------- axis_size
def axis_size(axis_name) -> int:
    """``lax.axis_size`` (new jax) or a psum-of-ones fallback (old jax).

    Must be called under a collective context (shard_map body). The fallback
    is a replicated constant so XLA folds it — no real collective is issued.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# --------------------------------------------------------------------- shard_map
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = set(inspect.signature(_shard_map_impl).parameters)
_CHECK_KW = (
    "check_vma" if "check_vma" in _SM_PARAMS
    else ("check_rep" if "check_rep" in _SM_PARAMS else None)
)


def shard_map(f, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map with the replication-check kwarg spelled per-version."""
    kwargs = {}
    if check_vma is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
