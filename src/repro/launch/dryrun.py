import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we AOT-compile the real step function (train/prefill/decode —
the same builders launch/train.py executes) against ShapeDtypeStruct inputs
(zero allocation), then record:
  * memory_analysis()  — per-device argument/output/temp bytes (fits HBM?)
  * cost_analysis()    — per-device FLOPs + bytes accessed
  * collective traffic — parsed from the optimized HLO (hlo_analysis)
  * roofline terms     — compute/memory/collective seconds (v5e constants)

Results append to a JSON file so the sweep is resumable (each cell is
expensive to compile on one host core).

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results.json]
  python -m repro.launch.dryrun --spgemm            # the paper's workloads
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, WORKLOADS, get_config, input_specs
from ..models import transformer as tfm
from ..optim import adamw
from ..train.step import (
    TrainConfig,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    shardings_for,
)
from . import hlo_analysis
from .mesh import make_production_mesh

RESULTS_DEFAULT = "dryrun_results.json"


def _sds_like(shapes_tree, shardings_tree, force_dtype=None):
    """ShapeDtypeStructs carrying shardings (AOT inputs; no allocation).
    force_dtype: serving lowers against bf16 weights (training keeps f32
    master weights; the checkpoint converter casts offline)."""
    def one(s, sh):
        dt = force_dtype if (force_dtype and jnp.issubdtype(s.dtype, jnp.floating)) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
    return jax.tree.map(one, shapes_tree, shardings_tree)


def _analyze(lowered, compiled, mesh) -> Dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    world = mesh.devices.size
    # loop-aware module costs: XLA's cost_analysis counts while (scan) bodies
    # once; analyze_module multiplies by parsed trip counts (hlo_analysis).
    mod = hlo_analysis.analyze_module(compiled.as_text(), world)
    roof = hlo_analysis.Roofline(
        flops=mod.flops,
        hbm_bytes=mod.bytes,
        wire_bytes=mod.total_wire_bytes,
        compute_s=mod.flops / hlo_analysis.PEAK_FLOPS,
        memory_s=mod.bytes / hlo_analysis.HBM_BW,
        collective_s=mod.total_wire_bytes / hlo_analysis.ICI_BW,
    )
    return {
        "devices": int(world),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_estimate_bytes": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "cost": {
            # loop-corrected per-device numbers (used for the roofline)
            "flops_per_device": float(mod.flops),
            "bytes_per_device": float(mod.bytes),
            # raw XLA numbers (while bodies counted once) for reference
            "xla_flops_body_once": float(cost.get("flops", 0.0)),
            "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
            "loop_trips": {k: int(v) for k, v in mod.loop_trips.items()},
        },
        "collectives": {
            "counts": {k: float(v) for k, v in mod.coll_counts.items()},
            "wire_bytes": {k: float(v) for k, v in mod.coll_wire.items()},
            "total_wire_bytes": float(mod.total_wire_bytes),
        },
        "roofline": {
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "bound_s": roof.bound_s,
        },
    }


def run_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                zero1: bool = True, extra_tag: str = "",
                strategy: str = "tp", pad_heads: int = 0,
                act_shard: Optional[str] = None,
                master_opt: bool = False,
                moe_capacity: float = 0.0) -> Dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if pad_heads:
        cfg = _dc.replace(cfg, pad_heads_to=pad_heads)
    if act_shard:
        cfg = _dc.replace(cfg, act_sharding=act_shard)
    if moe_capacity and cfg.moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               capacity_factor=moe_capacity))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    if shape.kind == "train":
        tc = TrainConfig(
            optimizer=adamw.AdamWConfig(zero1=zero1, master_in_opt=master_opt),
            strategy=strategy,
        )
        p_sh, o_sh, b_sh, _, params_shapes = shardings_for(
            cfg, mesh, tc, shape.global_batch
        )
        step_jit, _, _ = build_train_step(cfg, mesh, tc, shape.global_batch)
        if master_opt:  # model weights bf16; f32 master in opt state
            params_shapes = jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct(
                    sd.shape,
                    jnp.bfloat16 if jnp.issubdtype(sd.dtype, jnp.floating)
                    else sd.dtype,
                ),
                params_shapes,
            )
        opt_shapes = jax.eval_shape(
            lambda: adamw.init_opt_state(params_shapes, master_in_opt=master_opt)
        )
        batch_shapes = input_specs(cfg, shape)
        lowered = step_jit.lower(
            _sds_like(params_shapes, p_sh),
            _sds_like(opt_shapes, o_sh),
            _sds_like(batch_shapes, b_sh),
        )
        # MODEL_FLOPS = 6·N_active·D per step
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.active_param_count() * tokens
    elif shape.kind == "prefill":
        step_jit, sh = build_prefill_step(cfg, mesh, s_max=shape.seq_len,
                                          batch=shape.global_batch)
        tp = mesh.shape.get("model", 1)
        pspecs = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            tfm.param_specs(cfg, tp),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
        params_shapes = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        batch_shapes = input_specs(cfg, shape)
        i_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), batch_shapes
        )["inputs"]
        lowered = step_jit.lower(
            _sds_like(params_shapes, pspecs, force_dtype=jnp.bfloat16), i_sds
        )
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.active_param_count() * tokens
    else:  # decode
        step_jit, sh = build_decode_step(cfg, mesh, batch=shape.global_batch,
                                         s_max=shape.seq_len)
        tp = mesh.shape.get("model", 1)
        pspecs = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            tfm.param_specs(cfg, tp),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
        params_shapes = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        cache_shapes = jax.eval_shape(
            lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        batch_shapes = input_specs(cfg, shape)
        i_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), batch_shapes
        )["inputs"]
        lowered = step_jit.lower(
            _sds_like(params_shapes, pspecs, force_dtype=jnp.bfloat16),
            _sds_like(cache_shapes, sh["cache"]),
            i_sds,
            jax.ShapeDtypeStruct((), jnp.int32),
        )
        tokens = shape.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens

    compiled = lowered.compile()
    result = _analyze(lowered, compiled, mesh)
    result.update(
        arch=arch,
        shape=shape_name,
        mesh="multi" if multi_pod else "single",
        kind=shape.kind,
        tag=extra_tag,
        compile_s=round(time.time() - t0, 1),
        model_flops_total=float(model_flops),
    )
    hlo_total = result["cost"]["flops_per_device"] * result["devices"]
    result["useful_flops_fraction"] = (
        float(model_flops) / hlo_total if hlo_total else 0.0
    )
    print(compiled.memory_analysis())
    print({k: v for k, v in result["cost"].items()})
    return result


def run_spgemm_cell(name: str, multi_pod: bool) -> Dict:
    """Lower one batched-SUMMA3D step of the paper's workload on the
    production mesh (grid = data×model×pod per DESIGN.md §5)."""
    from ..core.distsparse import DistSparse
    from ..core.grid import grid_from_mesh
    from ..core.summa3d import BatchCaps

    wl = WORKLOADS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    grid = grid_from_mesh(mesh, row_axis="data", col_axis="model",
                          layer_axis="pod" if multi_pod else None)
    pr, pc, l = grid.pr, grid.pc, grid.l
    n = wl.n
    t0 = time.time()

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=jax.sharding.NamedSharding(
                grid.mesh, jax.sharding.PartitionSpec(*grid.axis_names)
            )
        )

    cap = wl.cap_per_tile
    tm_a, tn_a = n // pr, n // pc // l
    tm_b, tn_b = n // pr // l, n // pc // wl.num_batches
    a_sds = DistSparse(
        rows=sds((pr, pc, l, cap), jnp.int32),
        cols=sds((pr, pc, l, cap), jnp.int32),
        vals=sds((pr, pc, l, cap), jnp.float32),
        nnz=sds((pr, pc, l), jnp.int32),
        shape=(n, n), tile_shape=(tm_a, tn_a), grid_shape=(pr, pc, l), kind="A",
    )
    bcap = max(cap // wl.num_batches * 2, 64)
    b_sds = DistSparse(
        rows=sds((pr, pc, l, bcap), jnp.int32),
        cols=sds((pr, pc, l, bcap), jnp.int32),
        vals=sds((pr, pc, l, bcap), jnp.float32),
        nnz=sds((pr, pc, l), jnp.int32),
        shape=(n, n // wl.num_batches), tile_shape=(tm_b, tn_b),
        grid_shape=(pr, pc, l), kind="B",
    )
    caps = BatchCaps(flops_cap=wl.flops_cap, d_cap=wl.d_cap,
                     piece_cap=wl.piece_cap, c_cap=wl.c_cap)
    from ..core import semiring as sr
    from ..core.summa3d import summa3d_sparse_step

    lowered = jax.jit(
        summa3d_sparse_step, static_argnames=("grid", "caps", "semiring")
    ).lower(a_sds, b_sds, grid=grid, caps=caps, semiring=sr.get(wl.semiring))
    compiled = lowered.compile()
    result = _analyze(lowered, compiled, mesh)
    # algorithmic flops for the batch: ~ nnz(A)/p rows × avg B per col...
    total_nnz_a = wl.avg_nnz_per_row * n
    flops_batch = 2 * total_nnz_a * wl.avg_nnz_per_row / wl.num_batches
    result.update(
        arch=name, shape=f"b{wl.num_batches}",
        mesh="multi" if multi_pod else "single",
        kind="spgemm", tag="", compile_s=round(time.time() - t0, 1),
        model_flops_total=float(flops_batch),
    )
    print(compiled.memory_analysis())
    return result


def append_result(out_path: str, result: Dict) -> None:
    data = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data = [
        r for r in data
        if not (r["arch"] == result["arch"] and r["shape"] == result["shape"]
                and r["mesh"] == result["mesh"] and r.get("tag", "") == result.get("tag", ""))
    ]
    data.append(result)
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1)


def cell_applicable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        return cfg.supports_long_context
    return True


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--spgemm", action="store_true")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--strategy", default="tp", choices=["tp", "dp"])
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--act-shard", default=None, choices=[None, "seq"])
    ap.add_argument("--master-opt", action="store_true")
    ap.add_argument("--moe-capacity", type=float, default=0.0)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.spgemm:
        for name in WORKLOADS:
            for mp in meshes:
                cells.append(("spgemm", name, None, mp))
    elif args.all:
        for arch in ARCHS:
            for shape_name in SHAPES:
                for mp in meshes:
                    cells.append(("lm", arch, shape_name, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            cells.append(("lm", args.arch, args.shape, mp))

    failures = 0
    for kind, arch, shape_name, mp in cells:
        mesh_name = "multi" if mp else "single"
        if kind == "lm" and not cell_applicable(arch, shape_name):
            append_result(args.out, {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "kind": "skip", "tag": args.tag,
                "skip_reason": "full-attention arch at 512k decode context "
                               "(sub-quadratic state required; DESIGN.md §4)",
            })
            print(f"SKIP {arch} {shape_name} {mesh_name}")
            continue
        try:
            print(f"=== {arch} {shape_name or ''} {mesh_name} ===", flush=True)
            if kind == "spgemm":
                res = run_spgemm_cell(arch, mp)
            else:
                res = run_lm_cell(arch, shape_name, mp,
                                  zero1=not args.no_zero1, extra_tag=args.tag,
                                  strategy=args.strategy,
                                  pad_heads=args.pad_heads,
                                  act_shard=args.act_shard,
                                  master_opt=args.master_opt,
                                  moe_capacity=args.moe_capacity)
            append_result(args.out, res)
            r = res["roofline"]
            print(f"  -> dominant={r['dominant']} compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
                  f"compile={res['compile_s']}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            append_result(args.out, {
                "arch": arch, "shape": shape_name or "", "mesh": mesh_name,
                "kind": "error", "tag": args.tag,
                "error": traceback.format_exc()[-2000:],
            })
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
