"""Post-compile HLO analysis: collective-traffic extraction + roofline terms.

``cost_analysis()`` gives per-device FLOPs and bytes (verified per-device
after SPMD partitioning) but no collective volumes, so we parse the
optimized HLO text and apply the standard ring-algorithm wire models:

  all-gather       (g-1)/g × result_bytes     per device
  reduce-scatter   (g-1)   × result_bytes     (result is the scattered piece)
  all-reduce       2(g-1)/g × buffer_bytes    (ring AR = RS + AG)
  all-to-all       (g-1)/g × result_bytes
  collective-permute  result_bytes

Group size g is parsed from replica_groups (explicit list or iota form).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e hardware constants (per chip) — assignment-specified
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# explicit groups: replica_groups={{0,1,2},{3,4,5}}
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([\d,]+)\}")
# iota groups: replica_groups=[32,16]<=[...]  -> 32 groups of 16
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]  # raw buffer sizes per op kind
    wire_bytes: Dict[str, float]  # ring-model bytes on the wire per device

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, world: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    result_bytes: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        for op in _COLLECTIVES:
            # match '<op>(' or '<op>-start(' as the op invocation
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                # result type: between '=' and the op token
                lhs, rhs = stripped.split("=", 1)
                op_pos = rhs.find(op)
                rbytes = _shape_bytes(rhs[:op_pos])
                g = _group_size(stripped, world)
                if g <= 1:
                    continue
                if op == "all-gather":
                    w = rbytes * (g - 1) / g
                elif op == "reduce-scatter":
                    w = rbytes * (g - 1)
                elif op == "all-reduce":
                    w = rbytes * 2 * (g - 1) / g
                elif op == "all-to-all":
                    w = rbytes * (g - 1) / g
                else:  # collective-permute
                    w = rbytes
                counts[op] = counts.get(op, 0) + 1
                result_bytes[op] = result_bytes.get(op, 0) + rbytes
                wire[op] = wire.get(op, 0.0) + w
                break
    return CollectiveStats(counts=counts, result_bytes=result_bytes, wire_bytes=wire)


# ---------------------------------------------------------------------------
# Loop-aware module analysis.
#
# XLA's cost_analysis() counts a while-loop body ONCE regardless of trip
# count, so a lax.scan over 48 layers under-reports flops/bytes/collectives
# by ~48x. We rebuild the costs from the optimized HLO text:
#   * split the module into computations,
#   * per computation: dot flops (2·prod(result)·K from the contracting
#     dims), materialized bytes (result sizes of non-fusion-body
#     instructions ×2 for read+write), and collective wire bytes,
#   * walk the call graph from ENTRY, multiplying while-body costs by the
#     trip count parsed from the loop condition's comparison constant.
# Validated against hand-counted matmul loops in tests/test_hlo_analysis.py.
# ---------------------------------------------------------------------------
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_CALLS = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w\.\-]+)")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_S32 = re.compile(r"constant\((\d+)\)")


def _first_shape(segment: str):
    m = _SHAPE_RE.search(segment)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return None
    shape = [int(d) for d in dims.split(",") if d]
    return dt, shape


@dataclasses.dataclass
class _CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)  # (name, kind)
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond)
    max_const: int = 1  # largest s32 constant (trip-count heuristic for conds)


def _parse_computations(hlo_text: str):
    comps = {}
    symbols = {}  # instruction name -> (dtype, shape); module-global
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if not raw.startswith(" ") and s.endswith("{"):
            hdr = _COMP_HDR.match(s)
            if hdr:
                cur = hdr.group(1)
                comps[cur] = _CompCost()
                if s.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None or s == "}":
            continue
        comps[cur].max_const = max(
            comps[cur].max_const,
            max((int(v) for v in _CONST_S32.findall(s)), default=1),
        )
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        # symbol table: every instruction defines its result type on the lhs
        name_m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)", lhs.strip())
        res_shape = _first_shape(rhs.split("(")[0])  # type precedes the op
        if name_m and res_shape:
            symbols[name_m.group(1)] = res_shape
        # --- dot flops (operand shapes via the symbol table; older jax HLO
        # prints operand types inline — `dot(f32[64,128]{1,0} %x, ...)` — so
        # accept an optional type token before each operand name and prefer
        # the inline shape when present)
        if re.search(r"\bdot\(", rhs):
            op_m = re.search(
                r"\bdot\("
                r"(?:(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%?([\w\.\-]+)"
                r"(?:,\s*(?:(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%?([\w\.\-]+))?",
                rhs,
            )
            cd = _DOT_CDIMS.search(rhs)
            lhs_shape = rhs_shape = None
            if op_m:
                lhs_shape = (
                    _first_shape(op_m.group(1)) if op_m.group(1)
                    else symbols.get(op_m.group(2))
                )
                rhs_shape = (
                    _first_shape(op_m.group(3)) if op_m.group(3)
                    else symbols.get(op_m.group(4)) if op_m.group(4) else None
                )
            if res_shape and lhs_shape and cd:
                k = 1
                for d in cd.group(1).split(","):
                    if d:
                        k *= lhs_shape[1][int(d)]
                nres = 1
                for d in res_shape[1]:
                    nres *= d
                comps[cur].flops += 2.0 * nres * k
                # dot operand+result traffic (the HBM roofline driver on TPU)
                for shp in (lhs_shape, rhs_shape, res_shape):
                    if shp:
                        n = 1
                        for d in shp[1]:
                            n *= d
                        comps[cur].bytes += n * _DTYPE_BYTES[shp[0]]
        # --- collectives
        for op in _COLLECTIVES:
            if f" {op}(" in s or f" {op}-start(" in s:
                op_pos = rhs.find(op)
                rbytes = _shape_bytes(rhs[:op_pos])
                g = _group_size(s, 0) or 0
                comps[cur].coll_counts[op] = comps[cur].coll_counts.get(op, 0) + 1
                comps[cur].coll_wire.setdefault(op, []).append((rbytes, g))
                break
        # --- bytes: HBM traffic model. Counting every instruction result
        # massively over-states TPU traffic (XLA fuses elementwise chains;
        # the CPU pipeline text wraps each op in its own fusion), so we count
        # the flows that must touch HBM: dot operands/results (above),
        # collective results, cache updates (dynamic-update-slice), gathers
        # (embedding lookups), and scatter/reduce outputs.
        if any(tok in rhs for tok in ("dynamic-update-slice(", " gather(",
                                      " scatter(", " reduce(")):
            if res_shape:
                n = 1
                for d in res_shape[1]:
                    n *= d
                comps[cur].bytes += 2.0 * n * _DTYPE_BYTES[res_shape[0]]
        # --- call edges
        if "while(" in rhs:
            m = re.search(r"body=%?([\w\.\-]+)", rhs)
            c = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if m and c:
                comps[cur].whiles.append((m.group(1), c.group(1)))
        else:
            for callee in _CALLS.findall(rhs):
                comps[cur].calls.append(callee)
    return comps, entry


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes: float
    coll_wire: Dict[str, float]
    coll_counts: Dict[str, float]
    loop_trips: Dict[str, int]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.coll_wire.values())


def analyze_module(hlo_text: str, world: int) -> ModuleCost:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        return ModuleCost(0, 0, {}, {}, {})
    wire_total: Dict[str, float] = {}
    count_total: Dict[str, float] = {}
    flops_total = 0.0
    bytes_total = 0.0
    trips_seen: Dict[str, int] = {}
    seen_stack = []

    def visit(name: str, mult: float):
        nonlocal flops_total, bytes_total
        if name not in comps or name in seen_stack:
            return
        seen_stack.append(name)
        c = comps[name]
        flops_total += mult * c.flops
        bytes_total += mult * c.bytes
        for op, items in c.coll_wire.items():
            for rbytes, g in items:
                gg = g if g and g > 1 else world
                if gg <= 1:
                    continue
                if op == "all-gather":
                    w = rbytes * (gg - 1) / gg
                elif op == "reduce-scatter":
                    w = rbytes * (gg - 1)
                elif op == "all-reduce":
                    w = rbytes * 2 * (gg - 1) / gg
                elif op == "all-to-all":
                    w = rbytes * (gg - 1) / gg
                else:
                    w = rbytes
                wire_total[op] = wire_total.get(op, 0.0) + mult * w
                count_total[op] = count_total.get(op, 0.0) + mult
        for callee in c.calls:
            visit(callee, mult)
        for body, cond in c.whiles:
            trips = comps[cond].max_const if cond in comps else 1
            trips = max(trips, 1)
            trips_seen[body] = trips
            visit(body, mult * trips)
        seen_stack.pop()

    visit(entry, 1.0)
    return ModuleCost(
        flops=flops_total,
        bytes=bytes_total,
        coll_wire=wire_total,
        coll_counts=count_total,
        loop_trips=trips_seen,
    )


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    wire_bytes: float  # per-device collective bytes (ring model)
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(cost: dict, coll: CollectiveStats, ici_links: int = 1) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = coll.total_wire_bytes
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=wire,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=wire / (ICI_BW * ici_links),
    )
