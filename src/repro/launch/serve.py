"""SpGEMM serving launcher: plan-cached multiply-as-a-service on a grid.

  python -m repro.launch.serve --requests 16 --repeat-frac 0.5

Generates a mixed repeat/novel request stream, runs it through the
``SpgemmEngine`` admission queue + plan cache, and reports per-request
latency percentiles, throughput, and the plan-cache hit rate. A real
SIGTERM is translated into ``PreemptionError`` at the loop boundary
(``runtime.resilient.install_preemption_handler``), so an orchestrator's
stop signal drains as a clean preemption instead of a hard kill.
"""
from __future__ import annotations

import argparse


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n", type=int, default=128, help="matrix dimension")
    ap.add_argument("--deg", type=float, default=4.0,
                    help="average nonzeros per row")
    ap.add_argument("--repeat-frac", type=float, default=0.5,
                    help="fraction of requests repeating one signature")
    ap.add_argument("--memory", type=int, default=1 << 26,
                    help="per-process admission budget (bytes)")
    ap.add_argument("--pr", type=int, default=1)
    ap.add_argument("--layers", type=int, default=1)
    args = ap.parse_args()

    import numpy as np

    from ..core.gen import erdos_renyi
    from ..core.grid import make_grid
    from ..runtime.resilient import (
        PreemptionError,
        install_preemption_handler,
    )
    from ..serve import MultiplyRequest, ServeConfig, SpgemmEngine

    install_preemption_handler()
    grid = make_grid(args.pr, args.pr, args.layers)
    eng = SpgemmEngine(grid, ServeConfig(per_process_memory=args.memory))

    a0 = erdos_renyi(args.n, args.deg, seed=7)
    b0 = erdos_renyi(args.n, args.deg, seed=8)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        if rng.random() < args.repeat_frac:
            eng.submit(MultiplyRequest(rid=rid, a=a0, b=b0))
        else:
            eng.submit(MultiplyRequest(
                rid=rid,
                a=erdos_renyi(args.n, args.deg, seed=100 + 2 * rid),
                b=erdos_renyi(args.n, args.deg, seed=101 + 2 * rid),
            ))
    try:
        results = eng.run_to_completion()
    except PreemptionError as e:
        print(f"preempted: {e} — served {len(eng.done)} of {args.requests}")
        return 0
    ok = [r for r in results if r.status == "ok"]
    lat = sorted(r.latency_ms for r in ok)
    p = lambda q: lat[min(int(q * len(lat)), len(lat) - 1)] if lat else 0.0  # noqa: E731
    print(
        f"served {len(ok)}/{args.requests} "
        f"(refused {eng.stats['refused']}, deferred {eng.stats['deferred']}) "
        f"p50 {p(0.5):.1f}ms p99 {p(0.99):.1f}ms "
        f"plan-cache hit rate {eng.cache_hit_rate():.2f}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
