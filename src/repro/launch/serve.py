"""Production serving launcher: continuous-batching engine on the mesh.

  python -m repro.launch.serve --arch granite-20b --smoke --requests 8
"""
from __future__ import annotations

import argparse


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    args = ap.parse_args()

    import numpy as np
    import jax
    from ..compat import AxisType, make_mesh, set_mesh

    from ..configs import get_config
    from ..models import transformer as tfm
    from ..serve import EngineConfig, Request, ServeEngine

    cfg = get_config(args.arch, smoke=args.smoke)
    ndev = len(jax.devices())
    model = 2 if ndev >= 2 else 1
    mesh = make_mesh((max(ndev // model, 1), model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    with set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, mesh,
                          EngineConfig(max_batch=args.max_batch, s_max=args.s_max))
        rng = np.random.default_rng(0)
        for rid in range(args.requests):
            plen = int(rng.integers(4, args.s_max // 4))
            eng.submit(Request(rid=rid,
                               prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                               max_new_tokens=args.max_new))
        done = eng.run_to_completion()
    print(f"served {len(done)}/{args.requests} requests "
          f"({sum(len(r.out_tokens) for r in done)} tokens generated)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
