"""Production training launcher.

On a real pod this is the per-host entrypoint (jax.distributed.initialize
picks up the TPU topology); on this container it runs the same code path on
host devices. Wires together: config registry → production mesh → sharded
train step → synthetic/real data pipeline → fault-tolerant driver with async
checkpointing.

  python -m repro.launch.train --arch granite-20b --steps 100 \
      --ckpt /tmp/ckpt [--smoke] [--microbatches 2] [--seq 4096 --batch 256]
"""
from __future__ import annotations

import argparse


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-size); full config otherwise")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="auto",
                    help="auto | single | multi | dxm (e.g. 2x2)")
    ap.add_argument("--distributed-init", action="store_true",
                    help="call jax.distributed.initialize() (real pods)")
    args = ap.parse_args()

    if args.distributed_init:
        import jax

        jax.distributed.initialize()

    import jax
    import numpy as np
    from ..compat import AxisType, make_mesh, set_mesh

    from ..configs import get_config
    from ..data import DataConfig, synthetic_batch
    from ..models import transformer as tfm
    from ..optim import adamw
    from ..runtime import RuntimeConfig, run_training
    from ..train import TrainConfig, build_train_step
    from .mesh import make_production_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    ndev = len(jax.devices())
    if args.mesh == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "auto":
        model = 2 if ndev >= 4 else 1
        mesh = make_mesh((ndev // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    else:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    print(f"arch={cfg.arch_id} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    seq = args.seq or (128 if args.smoke else 4096)
    batch = args.batch or (8 if args.smoke else 256)
    tc = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
    )
    step_fn, shardings, _ = build_train_step(cfg, mesh, tc)
    dcfg = DataConfig(seq_len=seq, global_batch=batch, vocab=cfg.vocab,
                      input_mode=cfg.input_mode, d_model=cfg.d_model)

    def make_state():
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": adamw.init_opt_state(params)}

    def wrapped_step(state, batch_):
        with set_mesh(mesh):
            p, o, m = step_fn(state["params"], state["opt"], batch_)
        return {"params": p, "opt": o}, m

    rc = RuntimeConfig(ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every)
    res = run_training(
        steps=args.steps, make_state=make_state, step_fn=wrapped_step,
        batch_fn=lambda s: synthetic_batch(dcfg, s), rc=rc,
    )
    print(f"done: step={res.final_step} loss[last5]={np.mean(res.losses[-5:]):.4f} "
          f"rollbacks={res.rollbacks} restarts={res.restarts} "
          f"stragglers={res.straggler_events}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
