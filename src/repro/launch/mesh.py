"""Production mesh definitions.

IMPORTANT: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax  # noqa: F401 — kept for device queries by callers

from ..compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: ("pod",) "data", "model" — DP over pod+data, TP/EP over model.
    The SpGEMM grid maps data→rows, model→cols, pod→layers
    (core.grid.grid_from_mesh).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for tests/examples (needs XLA host-device flag)."""
    if pod:
        return make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
