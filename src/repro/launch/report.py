"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep JSON.

  python -m repro.launch.report [--results dryrun_results.json]
prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import json

HBM_PER_CHIP = 16e9  # v5e


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.1f}G"
    if b >= 1e6:
        return f"{b / 1e6:.1f}M"
    return f"{b / 1e3:.0f}K"


def roofline_table(rows, mesh: str) -> str:
    out = [
        f"### Roofline — {'single-pod 16×16 (256 chips)' if mesh == 'single' else 'multi-pod 2×16×16 (512 chips)'}",
        "",
        "| arch | shape | dominant | compute_s | memory_s | collective_s | "
        "MODEL/HLO flops | peak GB/dev | fits HBM | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x.get("shape", ""))):
        if r["mesh"] != mesh:
            continue
        if r.get("kind") == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"SKIP: {r.get('skip_reason', '')[:70]} |"
            )
            continue
        if r.get("kind") == "error":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        roof = r["roofline"]
        peak = r["memory"]["peak_estimate_bytes"]
        fits = "yes" if peak <= HBM_PER_CHIP else f"NO ({peak/1e9:.0f}G)"
        useful = r.get("useful_flops_fraction", 0)
        diag = _diagnose(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | **{roof['dominant']}** | "
            f"{roof['compute_s']:.4f} | {roof['memory_s']:.4f} | "
            f"{roof['collective_s']:.4f} | {useful:.2f} | {peak/1e9:.1f} | "
            f"{fits} | {diag} |"
        )
    return "\n".join(out)


def _diagnose(r) -> str:
    roof = r["roofline"]
    dom = roof["dominant"]
    kind = r.get("kind")
    if dom == "collective":
        ag = r["collectives"]["wire_bytes"].get("all-gather", 0)
        ar = r["collectives"]["wire_bytes"].get("all-reduce", 0)
        a2a = r["collectives"]["wire_bytes"].get("all-to-all", 0)
        big = max((("AG", ag), ("AR", ar), ("A2A", a2a)), key=lambda t: t[1])
        return (f"{big[0]} traffic {fmt_bytes(big[1])}/dev — shrink activation "
                f"collectives (resharding / DP / hierarchy)")
    if dom == "memory":
        if kind == "decode":
            return "cache/weight reads dominate — shard cache further or quantize"
        return "activation+weight traffic — remat policy / SP / fusion"
    return "compute-bound — near roofline for this sharding"


def dryrun_summary(rows) -> str:
    n_ok = sum(1 for r in rows if r.get("kind") not in ("skip", "error"))
    n_skip = sum(1 for r in rows if r.get("kind") == "skip")
    n_err = sum(1 for r in rows if r.get("kind") == "error")
    out = [
        f"Cells compiled: **{n_ok}**, documented skips: **{n_skip}**, "
        f"errors: **{n_err}**.",
        "",
        "| arch | shape | mesh | devices | args GB/dev | temps GB/dev | "
        "collective counts (loop-corrected) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x.get("shape", ""), x["mesh"])):
        if r.get("kind") in ("skip", "error"):
            continue
        cc = ", ".join(f"{k}:{int(v)}" for k, v in
                       sorted(r["collectives"]["counts"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['devices']} | "
            f"{r['memory']['argument_bytes']/1e9:.2f} | "
            f"{r['memory']['temp_bytes']/1e9:.2f} | {cc} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.json")
    ap.add_argument("--section", default="all", choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    with open(args.results) as f:
        rows = json.load(f)
    if args.section in ("all", "dryrun"):
        print(dryrun_summary(rows))
        print()
    if args.section in ("all", "roofline"):
        print(roofline_table(rows, "single"))
        print()
        print(roofline_table(rows, "multi"))


if __name__ == "__main__":
    main()
