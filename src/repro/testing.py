"""Property-testing helpers with a graceful ``hypothesis`` fallback.

Test modules import ``given``/``settings``/``strategies`` from here. When the
real ``hypothesis`` package is installed it is re-exported unchanged; when it
is missing (minimal containers) a deterministic random-sampling stand-in runs
each property ``max_examples`` times with values drawn from a seeded
``numpy.random.Generator``. The fallback covers only the strategy surface the
repo uses (``integers``, ``floats``, ``booleans``, ``sampled_from``) — it does
*not* shrink failures, so keep real hypothesis installed where possible.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class strategies:  # type: ignore[no-redef]
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))]
            )

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        """Record max_examples on the (already-@given-wrapped) function."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test once per drawn example (seeded by the test name)."""

        def deco(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = getattr(runner, "_max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the property parameters as fixtures
            runner.__signature__ = inspect.Signature()
            runner.__wrapped__ = None
            del runner.__wrapped__
            return runner

        return deco
