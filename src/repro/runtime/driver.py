"""Fault-tolerant training driver: checkpoint/restart, NaN rollback,
straggler watchdog, elastic re-meshing.

The failure model (scaled to this container, same control flow as a 1000+
node deployment):
  * **step divergence** (NaN/inf loss or grad) → roll back to the last good
    checkpoint, skip the poisoned data batch, continue; bounded retries.
  * **node failure** (simulated via `FailureInjector`) → restart path:
    rebuild mesh (possibly smaller — elastic), restore latest checkpoint
    with the new shardings, resume from the stored step.
  * **stragglers** → per-step wall-time EWMA; a step slower than
    `straggler_factor ×` the EWMA raises a StragglerEvent; the driver logs
    and (if persistent) triggers the elastic path. On real pods the signal
    feeds the scheduler; here it is exercised deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..checkpoint import store

log = logging.getLogger("repro.runtime")


class LookaheadWindow:
    """Bounded in-flight window for pipelined dispatch.

    ``push`` enqueues a dispatched unit of work; once more than ``depth``
    units are in flight the oldest is completed via ``finish`` (which is
    where the host first blocks on device results — overflow flags, batch
    payloads). ``drain`` completes everything still in flight. The batched
    SUMMA3D driver runs its per-batch pipeline through one window; the
    serving engine shares a single window across concurrent requests so
    independent multiplies interleave at batch granularity.
    """

    def __init__(self, depth: int, finish: Callable[..., None]):
        self.depth = depth
        self.finish = finish
        self._inflight: deque = deque()

    @classmethod
    def from_exec(cls, exec_spec, finish: Callable[..., None]
                  ) -> "LookaheadWindow":
        """Window sized by an ``ExecSpec``: ``lookahead`` deep when the
        pipelined schedule is on, depth 0 (synchronous — every push
        completes immediately) when it is off. The one place the exec
        policy turns into schedule mechanics, shared by the batched driver
        and the serving engine."""
        return cls(exec_spec.lookahead if exec_spec.pipelined else 0, finish)

    def push(self, *item) -> None:
        self._inflight.append(item)
        while len(self._inflight) > self.depth:
            self.finish(*self._inflight.popleft())

    def drain(self) -> None:
        while self._inflight:
            self.finish(*self._inflight.popleft())

    def __len__(self) -> int:
        return len(self._inflight)


@dataclasses.dataclass
class RuntimeConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_rollbacks: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    ewma_warmup: int = 3  # steps before straggler detection arms (jit compiles)


class StragglerEvent(Exception):
    pass


class StragglerEwma:
    """Per-step wall-time EWMA with compile-robust warm-up seeding.

    The first steps pay jit compiles, so the EWMA is seeded with the
    *minimum* of the first ``warmup + 1`` observations (a compile never makes
    a step faster) — the warm-up fix from this driver, shared with the
    resilient SpGEMM loop so both watchdogs arm identically. ``observe``
    returns True when the armed watchdog flags the step as a straggler;
    detection never fires during warm-up.
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.2,
                 warmup: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self._warmup_dts: list = []

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self._warmup_dts.append(dt)
            if len(self._warmup_dts) > self.warmup:
                self.ewma = min(self._warmup_dts)
            return False
        slow = dt > self.factor * max(self.ewma, 1e-4)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class FailureInjector:
    """Deterministic fault injection for tests: fail at given steps."""

    def __init__(self, fail_steps=(), straggle_steps=(), straggle_s: float = 0.0):
        self.fail_steps = set(fail_steps)
        self.straggle_steps = set(straggle_steps)
        self.straggle_s = straggle_s

    def maybe_fail(self, step: int):
        if step in self.fail_steps:
            self.fail_steps.discard(step)  # fail once
            raise RuntimeError(f"injected node failure at step {step}")

    def maybe_straggle(self, step: int):
        if step in self.straggle_steps:
            time.sleep(self.straggle_s)


@dataclasses.dataclass
class TrainLoopResult:
    final_step: int
    losses: list
    rollbacks: int
    restarts: int
    straggler_events: int


def run_training(
    *,
    steps: int,
    make_state: Callable[[], Dict[str, Any]],  # fresh (params, opt) pytree dict
    step_fn: Callable,  # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], Any],  # step -> batch
    rc: RuntimeConfig,
    injector: Optional[FailureInjector] = None,
    shardings=None,
) -> TrainLoopResult:
    """The restartable loop. `state` is a dict pytree with a 'step' entry."""
    ckpt = store.AsyncCheckpointer(rc.ckpt_dir, keep=rc.keep)
    injector = injector or FailureInjector()

    def cold_or_warm_start():
        # Drain any in-flight async write BEFORE listing the store:
        # latest_step sweeps stale step_*.tmp dirs, and sweeping an
        # in-progress writer's temp dir out from under it kills the save.
        ckpt.wait()
        last = store.latest_step(rc.ckpt_dir)
        state = make_state()
        if last is not None:
            state = store.restore(rc.ckpt_dir, last, state, shardings)
            log.info("restored checkpoint at step %d", last)
            return state, last
        return state, 0

    state, start = cold_or_warm_start()
    losses: list = []
    rollbacks = restarts = straggler_events = 0
    ewma = StragglerEwma(rc.straggler_factor, rc.ewma_alpha, rc.ewma_warmup)
    step = start
    skip_batches = set()

    while step < steps:
        try:
            injector.maybe_fail(step)
            t0 = time.perf_counter()
            injector.maybe_straggle(step)
            batch_step = step
            while batch_step in skip_batches:
                batch_step += steps  # deterministic replacement stream
            batch = batch_fn(batch_step)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if ewma.observe(dt):
                straggler_events += 1
                log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                            step, dt, ewma.ewma)

            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")

            losses.append(loss)
            step += 1
            if step % rc.ckpt_every == 0 or step == steps:
                ckpt.save(step, state)
        except FloatingPointError as e:
            rollbacks += 1
            if rollbacks > rc.max_rollbacks:
                raise
            log.warning("%s — rolling back", e)
            skip_batches.add(step)  # poisoned batch: skip after restore
            state, step = cold_or_warm_start()
            losses = losses[: step - start]
        except RuntimeError as e:
            restarts += 1
            log.warning("%s — restart path", e)
            state, step = cold_or_warm_start()
            losses = losses[: step - start]
    ckpt.wait()
    return TrainLoopResult(
        final_step=step,
        losses=losses,
        rollbacks=rollbacks,
        restarts=restarts,
        straggler_events=straggler_events,
    )
