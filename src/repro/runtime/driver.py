"""Fault-tolerant training driver: checkpoint/restart, NaN rollback,
straggler watchdog, elastic re-meshing.

The failure model (scaled to this container, same control flow as a 1000+
node deployment):
  * **step divergence** (NaN/inf loss or grad) → roll back to the last good
    checkpoint, skip the poisoned data batch, continue; bounded retries.
  * **node failure** (simulated via `FailureInjector`) → restart path:
    rebuild mesh (possibly smaller — elastic), restore latest checkpoint
    with the new shardings, resume from the stored step.
  * **stragglers** → per-step wall-time EWMA; a step slower than
    `straggler_factor ×` the EWMA raises a StragglerEvent; the driver logs
    and (if persistent) triggers the elastic path. On real pods the signal
    feeds the scheduler; here it is exercised deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..checkpoint import store

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class RuntimeConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_rollbacks: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    ewma_warmup: int = 3  # steps before straggler detection arms (jit compiles)


class StragglerEvent(Exception):
    pass


class FailureInjector:
    """Deterministic fault injection for tests: fail at given steps."""

    def __init__(self, fail_steps=(), straggle_steps=(), straggle_s: float = 0.0):
        self.fail_steps = set(fail_steps)
        self.straggle_steps = set(straggle_steps)
        self.straggle_s = straggle_s

    def maybe_fail(self, step: int):
        if step in self.fail_steps:
            self.fail_steps.discard(step)  # fail once
            raise RuntimeError(f"injected node failure at step {step}")

    def maybe_straggle(self, step: int):
        if step in self.straggle_steps:
            time.sleep(self.straggle_s)


@dataclasses.dataclass
class TrainLoopResult:
    final_step: int
    losses: list
    rollbacks: int
    restarts: int
    straggler_events: int


def run_training(
    *,
    steps: int,
    make_state: Callable[[], Dict[str, Any]],  # fresh (params, opt) pytree dict
    step_fn: Callable,  # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], Any],  # step -> batch
    rc: RuntimeConfig,
    injector: Optional[FailureInjector] = None,
    shardings=None,
) -> TrainLoopResult:
    """The restartable loop. `state` is a dict pytree with a 'step' entry."""
    ckpt = store.AsyncCheckpointer(rc.ckpt_dir, keep=rc.keep)
    injector = injector or FailureInjector()

    def cold_or_warm_start():
        last = store.latest_step(rc.ckpt_dir)
        state = make_state()
        if last is not None:
            ckpt.wait()
            state = store.restore(rc.ckpt_dir, last, state, shardings)
            log.info("restored checkpoint at step %d", last)
            return state, last
        return state, 0

    state, start = cold_or_warm_start()
    losses: list = []
    rollbacks = restarts = straggler_events = 0
    ewma: Optional[float] = None
    warmup_dts: list = []  # early steps pay jit compiles — seed EWMA robustly
    step = start
    skip_batches = set()

    while step < steps:
        try:
            injector.maybe_fail(step)
            t0 = time.perf_counter()
            injector.maybe_straggle(step)
            batch_step = step
            while batch_step in skip_batches:
                batch_step += steps  # deterministic replacement stream
            batch = batch_fn(batch_step)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if ewma is None:
                # warm-up: compiles dominate the first steps; seed with the
                # *minimum* observed (a compile never makes a step faster)
                warmup_dts.append(dt)
                if len(warmup_dts) > rc.ewma_warmup:
                    ewma = min(warmup_dts)
            else:
                if dt > rc.straggler_factor * max(ewma, 1e-4):
                    straggler_events += 1
                    log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                                step, dt, ewma)
                ewma = (1 - rc.ewma_alpha) * ewma + rc.ewma_alpha * dt

            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")

            losses.append(loss)
            step += 1
            if step % rc.ckpt_every == 0 or step == steps:
                ckpt.save(step, state)
        except FloatingPointError as e:
            rollbacks += 1
            if rollbacks > rc.max_rollbacks:
                raise
            log.warning("%s — rolling back", e)
            skip_batches.add(step)  # poisoned batch: skip after restore
            state, step = cold_or_warm_start()
            losses = losses[: step - start]
        except RuntimeError as e:
            restarts += 1
            log.warning("%s — restart path", e)
            state, step = cold_or_warm_start()
            losses = losses[: step - start]
    ckpt.wait()
    return TrainLoopResult(
        final_step=step,
        losses=losses,
        rollbacks=rollbacks,
        restarts=restarts,
        straggler_events=straggler_events,
    )
