"""Fault-tolerant harness for iterated SpGEMM: checkpoint/resume + injection.

The training driver's restartable-loop machinery (`runtime/driver.py`)
retargeted at the real stack — MCL expansion/inflation and APSP iterated
squaring run for hours on real inputs (HipMCL at 262K cores, §V-C), where
preemption, checkpoint corruption, and under-predicted output memory are
routine. `run_iterated` is the generic loop:

  * **checkpoint every N iterations** through `store.AsyncCheckpointer`
    (host snapshot + off-thread write, overlapped with the next multiply;
    stall time and bytes land in the `RunReport`);
  * **cold-or-warm start**: `restore_arrays_latest` walks `steps_available`
    newest-first, *refusing* any corrupt/truncated checkpoint (content-hash
    or unreadable-archive failure) and falling back to the previous step —
    a refused restore is counted, never fatal, and an empty/corrupt store
    degrades to a cold start;
  * **plan-signature meta** rides in the checkpoint manifest (`store.save
    (meta=...)`): the workload's encode/decode callbacks snapshot the pow2/
    floor caps, pinned k-bin signature, hash caps, local path and
    batch-count floor next to the iterate, so the restored loop rebuilds the
    IDENTICAL fused-step static signature — zero extra retraces after a
    resume (asserted via ``summa3d.TRACE_COUNTS`` in the tests);
  * **straggler watchdog**: the driver's warm-up-fixed `StragglerEwma`
    observes per-iteration wall time; events are logged through the
    verbose/logging path and counted in the report.

`SpgemmFailureInjector` grows the deterministic `FailureInjector` to the
SpGEMM failure modes: preemption mid-iteration (at a chosen batch inside
the pipelined lookahead window), checkpoint truncation after a completed
save, overflow storms (forced capacity under-prediction via a slack
override), and per-batch straggler delays.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import re
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from ..checkpoint import store
from ..core.batched import RunReport
from .driver import FailureInjector, StragglerEwma

log = logging.getLogger("repro.runtime.resilient")


class PreemptionError(RuntimeError):
    """Injected (or real) preemption: the loop restores and continues."""


# --- real-preemption translation (SIGTERM → PreemptionError) ---------------
# Schedulers deliver preemption as a signal, not an exception; the handler
# only flips this flag (signal-safe), and `check_preemption` — called at the
# iteration boundary inside `run_iterated` — turns it into the same
# `PreemptionError` the injector raises, so the restore path covers real
# kills identically to injected ones.
_PREEMPTION = {"requested": False}


def _sigterm_handler(signum, frame):
    _PREEMPTION["requested"] = True
    log.warning("signal %d received — requesting preemption", signum)


def install_preemption_handler(signals=None) -> None:
    """Install the SIGTERM→`PreemptionError` translation for this process.

    Launchers call this once before entering a resilient loop; subsequent
    SIGTERMs set a flag that `run_iterated` converts into the restore path
    at the next iteration boundary (a mid-step signal never corrupts an
    in-flight checkpoint write).
    """
    import signal as _signal

    for s in signals if signals is not None else (_signal.SIGTERM,):
        _signal.signal(s, _sigterm_handler)


def preemption_requested() -> bool:
    return _PREEMPTION["requested"]


def clear_preemption() -> None:
    _PREEMPTION["requested"] = False


def check_preemption() -> None:
    """Raise (and clear — one restore per signal, not a restart storm) when
    a translated signal is pending."""
    if _PREEMPTION["requested"]:
        _PREEMPTION["requested"] = False
        raise PreemptionError("preemption signal received (SIGTERM)")


@dataclasses.dataclass
class ResilientConfig:
    """Knobs of the resilient iterated loop (checkpoint cadence + watchdog)."""

    ckpt_dir: str
    ckpt_every: int = 1  # iterations between checkpoints
    keep: int = 3  # keep-N garbage collection
    max_restarts: int = 3  # bounded preemption recoveries
    async_save: bool = True  # off-thread writes (False: synchronous)
    resume: bool = True  # warm-start from latest_step when available
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    ewma_warmup: int = 1  # iterations before the watchdog arms


class SpgemmFailureInjector(FailureInjector):
    """Deterministic SpGEMM fault injection (tests + the durability CI lane).

    All sites fire once (like `FailureInjector.maybe_fail`) so a recovered
    run proceeds past the fault:

      * ``preempt_iters`` — `PreemptionError` at the start of those
        iterations; with ``preempt_batch`` set, the preemption instead fires
        *mid-iteration*, when the workload's consumer reaches that batch
        index (inside the pipelined lookahead window).
      * ``corrupt_steps`` — after the checkpoint for step s is on disk,
        truncate its ``arrays.npz`` (the restore must refuse it and fall
        back to the previous step).
      * ``overflow_iters`` — force capacity under-prediction: the workload
        plans those iterations with ``overflow_slack`` instead of its normal
        slack, driving the §IV-A retry ladder (and, under a tight budget,
        the degradation replans).
      * ``straggle_batches`` — sleep ``batch_straggle_s`` inside the
        consumer at the given (iteration, batch) pairs.
    """

    def __init__(
        self, fail_steps=(), straggle_steps=(), straggle_s: float = 0.0,
        preempt_iters=(), preempt_batch: Optional[int] = None,
        corrupt_steps=(), overflow_iters=(), overflow_slack: float = 0.05,
        straggle_batches=(), batch_straggle_s: float = 0.0,
    ):
        super().__init__(fail_steps, straggle_steps, straggle_s)
        self.preempt_iters = set(preempt_iters)
        self.preempt_batch = preempt_batch
        self.corrupt_steps = set(corrupt_steps)
        self.overflow_iters = set(overflow_iters)
        self.overflow_slack = overflow_slack
        self.straggle_batches = set(straggle_batches)
        self.batch_straggle_s = batch_straggle_s

    def maybe_preempt(self, it: int, batch: Optional[int] = None) -> None:
        """Iteration-start check (``batch=None``) or mid-iteration check
        from the workload's consumer (``batch`` = batch index)."""
        if it not in self.preempt_iters:
            return
        at_batch = self.preempt_batch is not None
        if (batch is None) == at_batch:
            return  # armed for the other site
        if at_batch and batch != self.preempt_batch:
            return
        self.preempt_iters.discard(it)  # fire once
        where = f"batch {batch} of " if batch is not None else ""
        raise PreemptionError(f"injected preemption at {where}iteration {it}")

    def maybe_corrupt(self, ckpt_dir: str, step: int) -> bool:
        """Truncate step's on-disk payload (call after the save landed)."""
        if step not in self.corrupt_steps:
            return False
        self.corrupt_steps.discard(step)
        path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        log.warning("injected corruption: truncated %s", path)
        return True

    def capacity_slack(self, it: int) -> Optional[float]:
        """Slack override for iteration ``it`` (None = no storm)."""
        return self.overflow_slack if it in self.overflow_iters else None

    def maybe_straggle_batch(self, it: int, batch: int) -> None:
        if (it, batch) in self.straggle_batches:
            self.straggle_batches.discard((it, batch))
            time.sleep(self.batch_straggle_s)


_KEYSTR_RE = re.compile(r"^\['(.*)'\]$")


def _plain_key(k: str) -> str:
    """Undo `jax.tree_util.keystr` on a single-level dict key.

    `store.save` flattens state with keystr, so a top-level leaf ``A_rows``
    lands in the archive as ``['A_rows']``; workloads' decode callbacks see
    the plain name again.
    """
    m = _KEYSTR_RE.match(k)
    return m.group(1) if m else k


def restore_arrays_latest(
    ckpt_dir: str,
) -> Tuple[Optional[Dict[str, np.ndarray]], Optional[dict], Optional[int], int]:
    """Newest-valid restore: ``(arrays, meta, step, refused)``.

    Walks complete checkpoints newest-first; a corrupt/truncated/unreadable
    one is REFUSED (logged + counted) and the previous step is tried. With
    no valid checkpoint, returns ``(None, None, None, refused)`` — the
    caller cold-starts.
    """
    refused = 0
    for s in reversed(store.steps_available(ckpt_dir)):
        try:
            arrays = store.restore_arrays(ckpt_dir, s)
            meta = store.load_meta(ckpt_dir, s)
            return {_plain_key(k): v for k, v in arrays.items()}, meta, s, refused
        except IOError as e:
            refused += 1
            log.warning("refusing checkpoint step %d: %s", s, e)
    return None, None, None, refused


@dataclasses.dataclass
class IteratedResult:
    """What `run_iterated` hands back: final state + durability accounting."""

    state: Any
    it: int  # iterations completed
    report: RunReport


def run_iterated(
    *,
    rc: ResilientConfig,
    max_iters: int,
    cold_start: Callable[[], Any],
    step_fn: Callable[[Any, int, "SpgemmFailureInjector"], Tuple[Any, Optional[RunReport], bool]],
    encode: Callable[[Any], Tuple[Dict[str, np.ndarray], dict]],
    decode: Callable[[Dict[str, np.ndarray], dict], Any],
    injector: Optional[SpgemmFailureInjector] = None,
    verbose: bool = False,
) -> IteratedResult:
    """The restartable iterated-SpGEMM loop (MCL, APSP, …).

    Contract with the workload:
      * ``cold_start() -> state`` builds iteration-0 state from the input;
      * ``step_fn(state, it, injector) -> (state', report_i, done)`` runs ONE
        iteration; ``report_i`` (per-iteration `RunReport` or None) is merged
        into the loop's report; ``done`` stops the loop after a checkpoint;
      * ``encode(state) -> (arrays, meta)`` flattens state into checkpoint
        leaves (exact-dtype host arrays) + a JSON-safe meta dict carrying the
        plan signature; ``decode(arrays, meta) -> state`` inverts it,
        re-device_put with the *current* mesh's shardings (elastic restore).

    A `PreemptionError` from ``step_fn`` (injected, or a real SIGTERM
    handler translated by the caller) triggers the restore path: wait out
    the in-flight write, restore the newest VALID checkpoint (refusing
    corrupt ones), and continue from its iteration — bounded by
    ``rc.max_restarts``. Encode/decode round-trip bitwise-identical arrays
    and an identical plan signature, so the trajectory matches the
    uninterrupted run and the resumed fused step hits the jit cache (zero
    retraces).
    """
    injector = injector or SpgemmFailureInjector()
    ckpt = store.AsyncCheckpointer(rc.ckpt_dir, keep=rc.keep)
    report = RunReport()
    ewma = StragglerEwma(rc.straggler_factor, rc.ewma_alpha, rc.ewma_warmup)

    def warm_or_cold(first: bool = False) -> Tuple[Any, int]:
        # rc.resume=False only opts the INITIAL start out of warm-starting
        # (a deliberately fresh run); mid-run preemption recovery always
        # reads the store — that is the point of the checkpoints.
        nonlocal report
        if rc.resume or not first:
            arrays, meta, s, refused = restore_arrays_latest(rc.ckpt_dir)
            report = report.merged(RunReport(refused_restores=refused))
            if arrays is not None:
                log.info("restored checkpoint at iteration %d", s)
                if verbose:
                    print(f"[resilient] resume from iteration {s}")
                return decode(arrays, meta), s
        return cold_start(), 0

    state, it = warm_or_cold(first=True)
    restarts = 0
    done = False
    while it < max_iters and not done:
        try:
            injector.maybe_preempt(it)
            check_preemption()  # real SIGTERM, translated at the boundary
            t0 = time.perf_counter()
            state, rep_i, done = step_fn(state, it, injector)
            dt = time.perf_counter() - t0
            if rep_i is not None:
                report = report.merged(rep_i)
            if ewma.observe(dt):
                report = report.merged(RunReport(straggler_events=1))
                log.warning("straggler: iteration %d took %.3fs (ewma %.3fs)",
                            it, dt, ewma.ewma)
            if verbose:
                ew = f"{ewma.ewma:.3f}" if ewma.ewma is not None else "warmup"
                print(f"[resilient] iter={it} wall={dt:.3f}s ewma={ew}s")
            it += 1
            if it % rc.ckpt_every == 0 or done or it == max_iters:
                arrays, meta = encode(state)
                if rc.async_save:
                    ckpt.save(it, arrays, meta=meta)
                else:
                    ckpt.save_sync(it, arrays, meta=meta)
                if it in injector.corrupt_steps:
                    ckpt.wait()  # the file must be on disk to truncate
                    injector.maybe_corrupt(rc.ckpt_dir, it)
        except PreemptionError as e:
            restarts += 1
            report = report.merged(RunReport(restarts=1))
            if restarts > rc.max_restarts:
                raise
            log.warning("%s — restoring", e)
            ckpt.wait()  # drain the in-flight write before reading the store
            state, it = warm_or_cold()
            done = False
    ckpt.wait()
    report = report.merged(RunReport(
        checkpoint_stalls=ckpt.stalls,
        checkpoint_stall_s=ckpt.stall_s,
        checkpoint_bytes=ckpt.bytes_written,
    ))
    return IteratedResult(state=state, it=it, report=report)
