from .driver import (  # noqa: F401
    FailureInjector,
    RuntimeConfig,
    StragglerEvent,
    StragglerEwma,
    run_training,
)
from .hierarchical import ClusterState, CrossClusterDP  # noqa: F401
from .resilient import (  # noqa: F401
    IteratedResult,
    PreemptionError,
    ResilientConfig,
    SpgemmFailureInjector,
    restore_arrays_latest,
    run_iterated,
)
