from .driver import FailureInjector, RuntimeConfig, StragglerEvent, run_training  # noqa: F401
from .hierarchical import ClusterState, CrossClusterDP  # noqa: F401
