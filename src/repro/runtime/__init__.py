from .driver import (  # noqa: F401
    FailureInjector,
    LookaheadWindow,
    RuntimeConfig,
    StragglerEvent,
    StragglerEwma,
    run_training,
)
from .hierarchical import ClusterState, CrossClusterDP  # noqa: F401
from .resilient import (  # noqa: F401
    IteratedResult,
    PreemptionError,
    ResilientConfig,
    SpgemmFailureInjector,
    check_preemption,
    clear_preemption,
    install_preemption_handler,
    preemption_requested,
    restore_arrays_latest,
    run_iterated,
)
