"""Hierarchical data parallelism with compressed inter-cluster gradients.

At 1000+ nodes the fleet is rarely one flat mesh: pods/clusters have fast
internal ICI but slow links between them (DCN/WAN). This driver runs one
model replica per cluster (each internally sharded however it likes),
exchanges ONLY error-feedback top-k compressed gradients across the slow
boundary, and applies the identical summed update everywhere — replicas stay
bit-identical without ever moving dense gradients between clusters.

The compression machinery is the sparse core reused as a communication
compressor (DESIGN.md §4): top-k gradients ARE a padded-COO vector.

On this container, "clusters" are distinct jit calls on the same devices;
the exchange math and the EF state threading are exactly what a real
deployment ships, with the transport swapped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..optim import adamw, compress

Array = jnp.ndarray


@dataclasses.dataclass
class ClusterState:
    params: Any
    opt: Any
    err: Any  # error-feedback residual (compress.init_error_state)


class CrossClusterDP:
    """num_clusters model replicas; inter-cluster grads are EF-top-k sparse."""

    def __init__(
        self,
        loss_fn: Callable[[Any, Any], Array],  # (params, batch) -> scalar
        opt_cfg: adamw.AdamWConfig,
        comp_cfg: compress.CompressConfig,
        num_clusters: int = 2,
    ):
        self.loss_fn = loss_fn
        self.opt_cfg = opt_cfg
        self.comp_cfg = comp_cfg
        self.num_clusters = num_clusters
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))

        def compress_fn(grads, err):
            (tdef, reps), new_err = compress.compress_tree(grads, err, comp_cfg)
            return reps, new_err

        # rep structure is static given shapes — jit the numeric parts per leaf
        self._compress = compress_fn

        def apply_fn(params, opt, g_sum):
            return adamw.apply_updates(params, g_sum, opt, opt_cfg)

        self._apply = jax.jit(apply_fn)

    def init(self, params) -> List[ClusterState]:
        return [
            ClusterState(
                params=jax.tree.map(jnp.copy, params),
                opt=adamw.init_opt_state(params),
                err=compress.init_error_state(params),
            )
            for _ in range(self.num_clusters)
        ]

    def step(
        self, states: List[ClusterState], batches: List[Any]
    ) -> Tuple[List[ClusterState], Dict[str, float]]:
        """One global step: local grads -> compress -> exchange -> sum ->
        identical update on every cluster."""
        assert len(batches) == self.num_clusters
        losses, compressed, errs = [], [], []
        tdef = None
        for st, batch in zip(states, batches):
            loss, grads = self._grad_fn(st.params, batch)
            losses.append(float(loss))
            (tdef_i, reps), new_err = compress.compress_tree(
                grads, st.err, self.comp_cfg
            )
            tdef = tdef_i
            compressed.append(reps)
            errs.append(new_err)
        # --- the slow-link exchange: only (vals, idx) tuples cross clusters
        wire_bytes = 0
        n_leaves = len(compressed[0])
        summed_leaves = []
        for li in range(n_leaves):
            kinds = {c[li][0] for c in compressed}
            assert len(kinds) == 1
            kind = kinds.pop()
            if kind == "dense":
                total = sum(c[li][1].astype(jnp.float32) for c in compressed)
                wire_bytes += (self.num_clusters - 1) * int(
                    compressed[0][li][1].size
                ) * 4
            else:
                shape = compressed[0][li][1][2]
                total = sum(
                    compress.decompress(c[li][1][0], c[li][1][1], shape)
                    for c in compressed
                )
                k = int(compressed[0][li][1][0].shape[0])
                wire_bytes += (self.num_clusters - 1) * k * 8  # f32 val + i32 idx
            summed_leaves.append(total / self.num_clusters)
        g_sum = jax.tree.unflatten(tdef, summed_leaves)
        new_states = []
        metrics_last = {}
        for st, err in zip(states, errs):
            p, o, m = self._apply(st.params, st.opt, g_sum)
            new_states.append(ClusterState(params=p, opt=o, err=err))
            metrics_last = m
        return new_states, {
            "loss": float(np.mean(losses)),
            "wire_bytes": float(wire_bytes),
            "grad_norm": float(metrics_last.get("grad_norm", 0.0)),
        }
