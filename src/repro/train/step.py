"""Jitted train/serve step builders with full sharding annotations.

``build_train_step(cfg, mesh, opt)`` returns (step_fn, shardings) where
step_fn(params, opt_state, batch) -> (params, opt_state, metrics) is jitted
with explicit in/out shardings — the exact object the multi-pod dry-run
lowers with ShapeDtypeStructs (launch/dryrun.py) and the training driver
executes (launch/train.py).

Sharding summary (DESIGN.md §5):
  batch    P(("pod","data"), None)     — DP over pod+data axes
  params   param_specs(cfg)            — TP over "model"
  opt      ZeRO-1: params' spec + data-axis sharding on the first free axis
  microbatching: optional grad accumulation via lax.scan (static count)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer as tfm
from ..models.common import batch_axes
from ..optim import adamw

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1  # grad accumulation steps per optimizer step
    aux_weight: float = 0.01
    # "tp": DP over (pod, data), TP over "model" (baseline).
    # "dp": pure data parallel — batch sharded over (data, model) [+pod when
    #       divisible], params replicated, optimizer state ZeRO-1 sharded
    #       over ALL those axes. The EXPERIMENTS.md §Perf resharding.
    strategy: str = "tp"


def _dp_axes_for(mesh, train_cfg: TrainConfig, global_batch: int = 0):
    if train_cfg.strategy == "dp":
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        if global_batch:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            while axes and global_batch % size != 0:
                size //= mesh.shape[axes[0]]
                axes = axes[1:]  # drop the pod axis first
        return axes
    return batch_axes(mesh)


def shardings_for(cfg: tfm.ModelConfig, mesh, train_cfg: TrainConfig,
                  global_batch: int = 0):
    """(param, opt, batch) NamedShardings + the spec trees."""
    tp = mesh.shape.get("model", 1) if train_cfg.strategy == "tp" else 1
    pspecs = tfm.param_specs(cfg, tp)
    params_shapes = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0))
    )
    dp = _dp_axes_for(mesh, train_cfg, global_batch)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    ospecs = adamw.opt_state_specs_axes(
        pspecs, params_shapes, dp, dp_size, train_cfg.optimizer
    )
    dspec = dp if len(dp) > 1 else dp[0]
    if cfg.input_mode == "tokens":
        bspecs = {"inputs": P(dspec, None), "targets": P(dspec, None)}
    else:
        bspecs = {"inputs": P(dspec, None, None), "targets": P(dspec, None)}

    def ns(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    return (
        ns(pspecs), ns(ospecs), ns(bspecs),
        {"params": pspecs, "opt": ospecs, "batch": bspecs},
        params_shapes,
    )


def build_train_step(cfg: tfm.ModelConfig, mesh, train_cfg: TrainConfig = TrainConfig(),
                     global_batch: int = 0):
    """Returns (jitted step_fn, dict of NamedShardings, params_shapes)."""
    p_sh, o_sh, b_sh, specs, params_shapes = shardings_for(
        cfg, mesh, train_cfg, global_batch
    )
    # "dp": batch is sharded over "model" too, so the vocab-parallel xent's
    # shard_map specs don't apply — the plain (replicated-vocab) loss is used.
    loss_mesh = mesh if train_cfg.strategy == "tp" else None

    def loss_fn(params, batch):
        return tfm.lm_loss(
            cfg, params, batch["inputs"], batch["targets"], loss_mesh,
            aux_weight=train_cfg.aux_weight,
        )

    # ZeRO gradient flow: constrain grads to the optimizer-state sharding so
    # GSPMD lowers the data-parallel reduction as a reduce-scatter (at the
    # gradient dtype) instead of a full f32 all-reduce; the updated params
    # are then all-gathered back (bf16 when master_in_opt).
    grad_hint = o_sh["mu"] if train_cfg.optimizer.zero1 else None

    def _constrain_grads(grads):
        if grad_hint is None:
            return grads
        # barrier: keeps the f32 upcast in the optimizer from being sunk into
        # the backward loop (which would turn the grad reduction into a
        # per-layer f32 all-reduce)
        grads = jax.lax.optimization_barrier(grads)
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, grad_hint
        )

    def step(params, opt_state, batch):
        if train_cfg.microbatches > 1:
            mb = train_cfg.microbatches
            resh = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
            )

            def acc_body(carry, mbatch):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                return (
                    loss_acc + loss / mb,
                    jax.tree.map(lambda a, g: a + g / mb, grad_acc, grads),
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0), zeros), resh)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = _constrain_grads(grads)
        new_params, new_opt, om = adamw.apply_updates(
            params, grads, opt_state, train_cfg.optimizer
        )
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    step_jit = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return step_jit, {"params": p_sh, "opt": o_sh, "batch": b_sh,
                      "specs": specs}, params_shapes


def _dp_info(mesh):
    dp = batch_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return (dp if len(dp) > 1 else dp[0]), size


def build_decode_step(cfg: tfm.ModelConfig, mesh, batch: int,
                      s_max: int = None):
    """Jitted single-token decode with sharded KV/state cache."""
    tp = mesh.shape.get("model", 1)
    pspecs = tfm.param_specs(cfg, tp)
    cspecs = tfm.cache_specs(cfg, mesh, batch, s_max)
    dspec, dp_size = _dp_info(mesh)

    def ns(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    p_sh, c_sh = ns(pspecs), ns(cspecs)
    tok_rank = 2 if cfg.input_mode == "tokens" else 3
    bax = dspec if batch % dp_size == 0 else None  # batch=1: replicate
    t_sh = NamedSharding(mesh, P(*((bax,) + (None,) * (tok_rank - 1))))

    def step(params, cache, inputs, cache_index):
        return tfm.decode_step(cfg, params, cache, inputs, cache_index, mesh)

    step_jit = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, t_sh, None),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    return step_jit, {"params": p_sh, "cache": c_sh, "specs":
                      {"params": pspecs, "cache": cspecs}}


def build_prefill_step(cfg: tfm.ModelConfig, mesh, s_max: int, batch: int):
    tp = mesh.shape.get("model", 1)
    pspecs = tfm.param_specs(cfg, tp)
    cspecs = tfm.cache_specs(cfg, mesh, batch, s_max)
    dspec, dp_size = _dp_info(mesh)

    def ns(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    p_sh, c_sh = ns(pspecs), ns(cspecs)
    in_rank = 2 if cfg.input_mode == "tokens" else 3
    bax = dspec if batch % dp_size == 0 else None
    i_sh = NamedSharding(mesh, P(*((bax,) + (None,) * (in_rank - 1))))

    def step(params, inputs):
        return tfm.prefill(cfg, params, inputs, s_max, mesh)

    step_jit = jax.jit(
        step, in_shardings=(p_sh, i_sh), out_shardings=(None, c_sh)
    )
    return step_jit, {"params": p_sh, "cache": c_sh}
