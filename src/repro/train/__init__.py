from .step import TrainConfig, build_decode_step, build_prefill_step, build_train_step  # noqa: F401
