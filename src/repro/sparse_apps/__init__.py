"""The paper's SpGEMM applications: Markov clustering (HipMCL), triangle
counting, AA^T sequence-overlap detection (§V-B/C/G)."""
from . import graph_algorithms, mcl  # noqa: F401
