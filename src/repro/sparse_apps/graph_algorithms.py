"""SpGEMM applications from paper §V-B: triangle counting and AA^T overlap.

Triangle counting (app (b)): count(G) = Σ (L·U) ⊙ A / 1, with the masked
plus-pair semiring — reproduces the "AA captures triangle counting" claim.

Overlap detection (app (c), BELLA/PASTIS): C = A·Aᵀ over plus-times where A
is the (sequences × k-mers) indicator matrix; C[i,j] = shared k-mers between
sequences i and j. Batched column formation lets the pair list be consumed
(filtered by min shared k-mers) batch-by-batch without holding all of C.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from ..core import semiring as sr
from ..core.batched import batched_summa3d
from ..core.distsparse import scatter_to_grid
from ..core.grid import Grid
from ..core.sparse import SparseCOO, from_numpy_coo
from .mcl import _sparse_batch_to_global


def triangle_count(a: SparseCOO, grid: Grid,
                   per_process_memory: int = 1 << 26) -> int:
    """Σ_{(i,j) ∈ A, i>j} (L·U)[i,j] — L/U strict lower/upper parts."""
    n = a.shape[0]
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    lo = rows > cols
    hi = rows < cols
    L = from_numpy_coo(rows[lo], cols[lo], np.ones(lo.sum(), np.float32),
                       (n, n), cap=max(int(lo.sum()), 8))
    U = from_numpy_coo(rows[hi], cols[hi], np.ones(hi.sum(), np.float32),
                       (n, n), cap=max(int(hi.sum()), 8))
    mask = set(zip(rows[lo].tolist(), cols[lo].tolist()))  # strict lower of A

    A_d = scatter_to_grid(L, grid, "A")
    B_d = scatter_to_grid(U, grid, "B")
    total = 0

    def consumer(bi, c_batch, col_map):
        nonlocal total
        rr, cc, vv = _sparse_batch_to_global(c_batch, col_map)
        for r, c, v in zip(rr.tolist(), cc.tolist(), vv.tolist()):
            if (r, c) in mask:  # apply the A-mask (element-wise ⊙)
                total += int(round(v))

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse", semiring=sr.PLUS_TIMES,
    )
    return total


def triangle_count_reference(a: SparseCOO) -> int:
    d = (np.asarray(a.to_dense()) != 0).astype(np.int64)
    d = d & d.T
    np.fill_diagonal(d, 0)
    return int(np.trace(d @ d @ d)) // 6


def overlap_pairs(
    a: SparseCOO,  # (nseqs × nkmers) indicator
    grid: Grid,
    min_shared: int = 2,
    per_process_memory: int = 1 << 26,
) -> List[Tuple[int, int, int]]:
    """AA^T batched; emit (i, j, shared) pairs with shared >= min_shared,
    i < j. Each batch is filtered and discarded (memory-constrained use)."""
    at = a.transpose().sort_rowmajor()
    A_d = scatter_to_grid(a, grid, "A")
    B_d = scatter_to_grid(at, grid, "B")
    pairs: List[Tuple[int, int, int]] = []

    def consumer(bi, c_batch, col_map):
        rr, cc, vv = _sparse_batch_to_global(c_batch, col_map)
        for r, c, v in zip(rr.tolist(), cc.tolist(), vv.tolist()):
            if r < c and v >= min_shared:
                pairs.append((int(r), int(c), int(round(v))))

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse",
    )
    return sorted(pairs)


def overlap_pairs_reference(a: SparseCOO, min_shared: int = 2):
    d = np.asarray(a.to_dense())
    c = d @ d.T
    out = []
    n = c.shape[0]
    for i in range(n):
        for j in range(i + 1, n):
            if c[i, j] >= min_shared:
                out.append((i, j, int(round(c[i, j]))))
    return sorted(out)
