"""SpGEMM applications from paper §V-B: triangle counting and AA^T overlap.

Triangle counting (app (b)): count(G) = Σ (L·U) ⊙ L with L/U the strict
lower/upper parts of the adjacency matrix — a *masked* SpGEMM. The mask is
scattered once as a C-layout operand and applied INSIDE the batched multiply
(``batched_summa3d(mask=...)``): the symbolic step budgets only surviving
entries (smaller capacities, fewer batches) and the local multiply filters
partial products against the mask's packed keys before its compress, so
non-triangle products never occupy output capacity, never ride the fiber
all-to-all, and never reach the host. Per-batch sums come back as device
scalars (like MCL's chaos/nnz) — the host sees one number per batch.

Overlap detection (app (c), BELLA/PASTIS): C = A·Aᵀ over plus-times where A
is the (sequences × k-mers) indicator matrix; C[i,j] = shared k-mers between
sequences i and j. The BELLA filter (i < j, shared ≥ min_shared) runs as a
device-side postprocess on each batch — a jitted on-grid compact, one
executable for all batches — so only surviving pairs are ever transferred;
an optional ``candidates`` mask (known candidate pairs, the PASTIS regime)
additionally gates the multiply itself through the masked path.

``triangle_count_host`` / ``overlap_pairs_host`` keep the original
pull-every-batch, filter-in-Python implementations as parity oracles; their
per-entry filters are routed through ``_host_mask_filter`` /
``_host_pair_filter`` so tests can count (and forbid) host-side filtering on
the device paths.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import shard_map
from ..core import distsparse
from ..core import semiring as sr
from ..core.batched import RunReport, batched_summa3d
from ..core.distsparse import DistSparse, dist_spec, scatter_to_grid
from ..core.grid import COL_AX, LAYER_AX, ROW_AX, Grid
from ..core.sparse import SparseCOO, from_numpy_coo
from ..core.specs import ExecSpec, PlanFloors, PlanSpec
from ..core.summa3d import (
    _pmax_grid,
    _psum_grid,
    _squeeze_tile,
    reassemble_operands,
)
from ..core.symbolic import rup8, rup_pow2
from . import mcl as _mcl
from .mcl import _sparse_batch_to_global, _to_host


def _charge_mask_planning_transfer(mask: DistSparse) -> None:
    """Masked planning counts the mask's per-tile column structure ON the
    grid (inside ``batched._symbolic3d_jit``); only the (pr, pc, l, w_l) i32
    count array crosses to the host. Charge those bytes against the transfer
    accounting so the device-vs-host comparisons stay honest."""
    pr, pc, l = mask.grid_shape
    wl = mask.tile_shape[1]
    _mcl._TRANSFER_BYTES[0] += pr * pc * l * wl * 4


def _strict_parts(a: SparseCOO) -> Tuple[SparseCOO, SparseCOO]:
    """Strict lower (L) and upper (U) triangular parts as unit-weight COO."""
    n = a.shape[0]
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    lo = rows > cols
    hi = rows < cols
    L = from_numpy_coo(rows[lo], cols[lo], np.ones(lo.sum(), np.float32),
                       (n, n), cap=max(int(lo.sum()), 8))
    U = from_numpy_coo(rows[hi], cols[hi], np.ones(hi.sum(), np.float32),
                       (n, n), cap=max(int(hi.sum()), 8))
    return L, U


# ---------------------------------------------------------------------------
# Device-side per-batch reductions / filters (the §V-B consumption hooks)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("grid",))
def _batch_value_sum(c: DistSparse, grid: Grid):
    """Σ of one batch's values as a replicated DEVICE scalar (one f32 per
    batch crosses to the host — the masked triangle count's only traffic)."""

    def step(c_t: DistSparse):
        t = _squeeze_tile(c_t)
        return _psum_grid(jnp.sum(jnp.where(t.valid_mask(), t.vals, 0.0)))

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    fn = shard_map(step, mesh=grid.mesh, in_specs=(dist_spec(c, spec3),),
                   out_specs=jax.sharding.PartitionSpec(), check_vma=False)
    return fn(c)


@partial(jax.jit, static_argnames=("grid", "num_batches", "min_shared"))
def _overlap_filter(
    c: DistSparse, batch, grid: Grid, num_batches: int, min_shared: int
):
    """BELLA pair filter ON the grid: keep entries with global row < global
    col and value ≥ ``min_shared``, compacted in place. ``batch`` stays a
    traced scalar (one executable for every batch). Returns the filtered
    batch plus replicated device scalars (surviving count, compact overflow).
    """
    tm, wbl = c.tile_shape
    n_total = c.shape[1] * num_batches
    w = n_total // grid.pc

    def step(c_t: DistSparse, batch_):
        t = _squeeze_tile(c_t)
        i = lax.axis_index(ROW_AX)
        j = lax.axis_index(COL_AX)
        k = lax.axis_index(LAYER_AX)
        g_row = i * tm + t.rows
        g_col = j * w + (k * num_batches + batch_) * wbl + t.cols
        keep = t.valid_mask() & (t.vals >= min_shared) & (g_row < g_col)
        kept, ovf = t.compact(keep, t.cap)
        local = jnp.sum(keep.astype(jnp.int32))
        return (
            kept.rows[None, None, None],
            kept.cols[None, None, None],
            kept.vals[None, None, None],
            kept.nnz[None, None, None],
            _psum_grid(local),
            _pmax_grid(local),
            _pmax_grid(ovf),
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(step, mesh=grid.mesh,
                   in_specs=(dist_spec(c, spec3), spec0),
                   out_specs=(spec3,) * 4 + (spec0,) * 3, check_vma=False)
    rows, cols, vals, nnz, cnt, maxc, ovf = fn(c, jnp.int32(batch))
    filtered = DistSparse(rows=rows, cols=cols, vals=vals, nnz=nnz,
                          shape=c.shape, tile_shape=c.tile_shape,
                          grid_shape=c.grid_shape, kind=c.kind)
    return filtered, cnt, maxc, ovf


def _shrink_batch(d: DistSparse, max_tile_nnz: int) -> DistSparse:
    """Slice a front-compacted batch down to its survivor capacity before the
    device→host pull. ``compact`` front-packs every tile, so dropping the
    tail beyond the max per-tile survivor count is lossless while the pull
    shrinks from O(plan cap) to O(survivors). Pow2-quantized so repeated
    batches reuse the same slice executables."""
    cap = d.rows.shape[-1]
    new_cap = min(cap, rup_pow2(max(int(max_tile_nnz), 8)))
    if new_cap >= cap:
        return d
    return dataclasses.replace(
        d,
        rows=d.rows[..., :new_cap],
        cols=d.cols[..., :new_cap],
        vals=d.vals[..., :new_cap],
    )


# ---------------------------------------------------------------------------
# Triangle counting — masked SpGEMM, device-resident
# ---------------------------------------------------------------------------
def triangle_count(a: SparseCOO, grid: Grid,
                   per_process_memory: int = 1 << 26) -> int:
    """Σ_{(i,j) ∈ A, i>j} (L·U)[i,j] via the masked batched multiply.

    The A-mask (element-wise ⊙) is the strict lower part L, scattered as a
    C-layout operand and applied on-grid inside every batch's fused step;
    each batch contributes ONE device scalar to the total.
    """
    L, U = _strict_parts(a)
    A_d = scatter_to_grid(L, grid, "A")
    B_d = scatter_to_grid(U, grid, "B")
    M_d = scatter_to_grid(L, grid, "C")
    _charge_mask_planning_transfer(M_d)
    totals: List[float] = []

    def postprocess(bi, c_batch):
        return _batch_value_sum(c_batch, grid=grid)

    def consumer(bi, batch_sum, col_map):
        totals.append(float(_to_host(batch_sum)))
        return None

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse", semiring=sr.PLUS_TIMES,
        spec=PlanSpec(mask=M_d), postprocess=postprocess,
    )
    return int(round(sum(totals)))


def _host_mask_filter(rr, cc, vv, mask) -> int:
    """Per-entry host mask filter — the kept §V-B oracle (and the thing the
    device path must never call; tests patch this to count invocations)."""
    total = 0
    for r, c, v in zip(rr.tolist(), cc.tolist(), vv.tolist()):
        if (r, c) in mask:  # apply the A-mask (element-wise ⊙)
            total += int(round(v))
    return total


def triangle_count_host(a: SparseCOO, grid: Grid,
                        per_process_memory: int = 1 << 26) -> int:
    """Host-filter reference: full (unmasked) L·U product, every batch pulled
    to numpy and masked by a Python set lookup — the pre-masked-path
    implementation, kept as the parity oracle and transfer baseline."""
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    L, U = _strict_parts(a)
    mask = set(zip(rows[rows > cols].tolist(), cols[rows > cols].tolist()))

    A_d = scatter_to_grid(L, grid, "A")
    B_d = scatter_to_grid(U, grid, "B")
    total = 0

    def consumer(bi, c_batch, col_map):
        nonlocal total
        rr, cc, vv = _sparse_batch_to_global(c_batch, col_map)
        total += _host_mask_filter(rr, cc, vv, mask)

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse", semiring=sr.PLUS_TIMES,
    )
    return total


def triangle_count_reference(a: SparseCOO) -> int:
    d = (np.asarray(a.to_dense()) != 0).astype(np.int64)
    d = d & d.T
    np.fill_diagonal(d, 0)
    return int(np.trace(d @ d @ d)) // 6


# ---------------------------------------------------------------------------
# Overlap detection — on-grid BELLA filter (+ optional candidate mask)
# ---------------------------------------------------------------------------
def overlap_pairs(
    a: SparseCOO,  # (nseqs × nkmers) indicator
    grid: Grid,
    min_shared: int = 2,
    per_process_memory: int = 1 << 26,
    candidates: Optional[SparseCOO] = None,
) -> List[Tuple[int, int, int]]:
    """AA^T batched; emit (i, j, shared) pairs with shared >= min_shared,
    i < j. Each batch is filtered ON the grid and discarded
    (memory-constrained use): the device postprocess compacts survivors and
    reduces the surviving-pair count to a scalar, so the host only
    reassembles coordinates — it never filters.

    ``candidates`` (an nseqs × nseqs structural mask of known candidate
    pairs, the PASTIS regime) additionally gates the multiply itself via the
    masked path — non-candidate products are dropped before the compress and
    the plan budgets survivors only.
    """
    at = a.transpose().sort_rowmajor()
    A_d = scatter_to_grid(a, grid, "A")
    B_d = scatter_to_grid(at, grid, "B")
    M_d = (
        scatter_to_grid(candidates, grid, "C")
        if candidates is not None else None
    )
    if M_d is not None:
        _charge_mask_planning_transfer(M_d)
    pieces = []
    nseqs = a.shape[0]

    def postprocess(bi, c_batch):
        # the batch width is the column dimension divided by the plan's
        # batch count, so nb is recoverable from the batch itself — no
        # plan probe needed before the driver runs
        num_batches = nseqs // c_batch.shape[1]
        return _overlap_filter(
            c_batch, bi, grid=grid, num_batches=num_batches,
            min_shared=int(min_shared),
        )

    def consumer(bi, payload, col_map):
        filtered, cnt, maxc, ovf = payload
        assert int(_to_host(ovf)) == 0
        # survivor-sized pull: slice the front-compacted batch to the max
        # per-tile survivor count before any array crosses to the host
        shrunk = _shrink_batch(filtered, int(_to_host(maxc)))
        rr, cc, vv = _sparse_batch_to_global(shrunk, col_map)
        assert len(rr) == int(_to_host(cnt)), (len(rr), cnt)
        pieces.append((rr, cc, vv))
        return None

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse", postprocess=postprocess,
        spec=PlanSpec(mask=M_d),
    )
    rows = np.concatenate([p[0] for p in pieces])
    cols = np.concatenate([p[1] for p in pieces])
    vals = np.concatenate([p[2] for p in pieces])
    order = np.lexsort((cols, rows))
    return [
        (int(r), int(c), int(round(v)))
        for r, c, v in zip(rows[order], cols[order], vals[order])
    ]


def _host_pair_filter(rr, cc, vv, min_shared) -> List[Tuple[int, int, int]]:
    """Per-entry host pair filter — the kept §V-B oracle (patched by tests
    to prove the device path never filters on the host)."""
    out = []
    for r, c, v in zip(rr.tolist(), cc.tolist(), vv.tolist()):
        if r < c and v >= min_shared:
            out.append((int(r), int(c), int(round(v))))
    return out


def overlap_pairs_host(
    a: SparseCOO,
    grid: Grid,
    min_shared: int = 2,
    per_process_memory: int = 1 << 26,
) -> List[Tuple[int, int, int]]:
    """Host-filter reference: every full batch pulled to numpy and filtered
    entry-by-entry in Python — the pre-device-filter implementation, kept as
    the parity oracle and transfer baseline."""
    at = a.transpose().sort_rowmajor()
    A_d = scatter_to_grid(a, grid, "A")
    B_d = scatter_to_grid(at, grid, "B")
    pairs: List[Tuple[int, int, int]] = []

    def consumer(bi, c_batch, col_map):
        rr, cc, vv = _sparse_batch_to_global(c_batch, col_map)
        pairs.extend(_host_pair_filter(rr, cc, vv, min_shared))
        return None

    batched_summa3d(
        A_d, B_d, grid, per_process_memory=per_process_memory,
        consumer=consumer, path="sparse",
    )
    return sorted(pairs)


def overlap_pairs_reference(a: SparseCOO, min_shared: int = 2):
    d = np.asarray(a.to_dense())
    c = d @ d.T
    out = []
    n = c.shape[0]
    for i in range(n):
        for j in range(i + 1, n):
            if c[i, j] >= min_shared:
                out.append((i, j, int(round(c[i, j]))))
    return sorted(out)


# ---------------------------------------------------------------------------
# APSP — min-plus iterated squaring (tropical semiring), resilient-ready
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class APSPConfig:
    """All-pairs shortest paths by iterated squaring over MIN_PLUS.

    D ← D ⊗ D doubles the hop horizon each iteration; with an explicit zero
    diagonal the iterate is entrywise non-increasing, D_k covers all paths of
    ≤ 2^k hops, and the fixpoint (exact triplet equality between successive
    iterates) IS the shortest-path matrix — at the fixpoint each entry's min
    over candidates includes D[i,j] + 0 via the diagonal, so equality is
    exact in float, not approximate. Absent entries are an implicit +inf
    (unreachable); only finite distances are ever stored.
    """

    max_iters: Optional[int] = None  # None: ceil(log2(n-1)) + 1
    per_process_memory: int = 1 << 26
    force_num_batches: Optional[int] = None
    lookahead: int = 2
    r_bytes: int = 12
    # 3-way local dispatch; k-binned is plus_times-only and auto-disabled,
    # ESC and the hash accumulator are semiring-generic
    local_path: str = "auto"


@dataclasses.dataclass
class APSPLoopState:
    """Device-resident iterate (A/B operands of the next squaring) +
    plan-signature floors (the checkpointed unit; mirrors `mcl.MCLLoopState`
    minus the k-binned signature, which min_plus never uses)."""

    A: DistSparse
    B: DistSparse
    it: int
    history: List[dict]
    report: RunReport
    floors: PlanFloors = dataclasses.field(default_factory=PlanFloors)
    lp_arg: object = "auto"


def _apsp_triplets(d: SparseCOO):
    k = int(d.nnz)
    return (np.asarray(d.rows[:k]), np.asarray(d.cols[:k]),
            np.asarray(d.vals[:k]))


def apsp_init(a: SparseCOO) -> SparseCOO:
    """D_0: edge weights with an explicit zero diagonal (dedup by MIN —
    a self-loop never beats distance 0)."""
    n = a.shape[0]
    rr, cc, vv = _apsp_triplets(a)
    rows = np.concatenate([rr, np.arange(n, dtype=rr.dtype)])
    cols = np.concatenate([cc, np.arange(n, dtype=cc.dtype)])
    vals = np.concatenate([vv.astype(np.float32), np.zeros(n, np.float32)])
    key = rows.astype(np.int64) * n + cols.astype(np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    best = np.full(len(uniq), np.inf, np.float32)
    np.minimum.at(best, inv, vals)
    return from_numpy_coo(
        (uniq // n).astype(np.int32), (uniq % n).astype(np.int32),
        best, (n, n),
    )


def _apsp_cold_state(a: SparseCOO, grid: Grid) -> APSPLoopState:
    """Iteration-0 state: D_0 scattered ONCE as both operands (the only
    scatters of a whole run — the loop stays on-grid after this)."""
    d0 = apsp_init(a)
    return APSPLoopState(
        A=_mcl._scatter(d0, grid, "A"), B=_mcl._scatter(d0, grid, "B"),
        it=0, history=[], report=RunReport(),
    )


def _apsp_caps(n: int, grid: Grid, cfg: APSPConfig) -> Tuple[int, int, int]:
    """Reassembly capacities for the next iterate's operands. APSP never
    prunes, so the only safe static bound is the dense tile (every (row,
    col) of a tile at most once) — exact, so reassembly overflow is
    impossible; tiny at the studied scales, and the reserved-bytes charge
    keeps the multiply honest about the kept operands."""
    tm = n // grid.pr
    w = n // grid.pc
    wl = w // grid.l
    cap_a = rup8(max(8, tm * wl))
    cap_b = rup8(max(8, wl * w))
    return cap_a, cap_b, cfg.r_bytes * (cap_a + cap_b)


@partial(jax.jit, static_argnames=("grid",))
def _dist_equal_nnz(x: DistSparse, y: DistSparse, grid: Grid):
    """Exact equality of two same-layout DistSparse iterates ON the grid +
    the first argument's total nnz, as two replicated device scalars — the
    APSP fixpoint check without a host gather. Tiles are canonicalized by a
    row-major sort (entries are key-unique), so prefix comparison over the
    smaller static capacity plus nnz equality is exact; at most two
    executables per run (iteration 1 compares the reassembled cap against
    the initial scatter cap, later iterations compare like caps)."""
    kmin = min(x.cap, y.cap)

    def step(x_t: DistSparse, y_t: DistSparse):
        tx = _squeeze_tile(x_t).sort_rowmajor()
        ty = _squeeze_tile(y_t).sort_rowmajor()
        neq = (tx.nnz != ty.nnz).astype(jnp.int32)
        idx = jnp.arange(kmin, dtype=jnp.int32)
        live = idx < jnp.minimum(tx.nnz, ty.nnz)
        mism = live & (
            (tx.rows[:kmin] != ty.rows[:kmin])
            | (tx.cols[:kmin] != ty.cols[:kmin])
            | (tx.vals[:kmin] != ty.vals[:kmin])
        )
        bad = _psum_grid(neq + jnp.sum(mism.astype(jnp.int32)))
        return bad, _psum_grid(tx.nnz)

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(
        step, mesh=grid.mesh,
        in_specs=(dist_spec(x, spec3), dist_spec(y, spec3)),
        out_specs=(spec0, spec0), check_vma=False,
    )
    return fn(x, y)


def _apsp_step(
    state: APSPLoopState, grid: Grid, cfg: APSPConfig, verbose: bool = False,
    injector=None, slack: Optional[float] = None,
) -> Tuple[APSPLoopState, RunReport, bool]:
    """ONE squaring D ← D ⊗ D, device-resident: the batched products
    reassemble into the next iterate's operands on the grid
    (``summa3d.reassemble_operands``, like MCL) and the fixpoint test is an
    on-grid exact comparison — only three scalars cross to the host per
    iteration, and zero ``gather_to_global``/``scatter_to_grid`` calls
    happen inside the loop."""
    it = state.it
    t0 = time.perf_counter()
    n = state.A.shape[0]
    cap_a, cap_b, reserved = _apsp_caps(n, grid, cfg)
    batches: List[DistSparse] = []

    def consumer(bi, c_batch, col_map):
        if injector is not None:
            injector.maybe_straggle_batch(it, bi)
            injector.maybe_preempt(it, batch=bi)
        batches.append(c_batch)
        return None

    res = batched_summa3d(
        state.A, state.B, grid, per_process_memory=cfg.per_process_memory,
        consumer=consumer, path="sparse", semiring=sr.MIN_PLUS,
        spec=PlanSpec(
            local_path=state.lp_arg, r_bytes=cfg.r_bytes,
            reserved_bytes=reserved,
            force_num_batches=cfg.force_num_batches,
            **({"slack": slack} if slack is not None else {}),
        ),
        floors=state.floors.replace(caps_pow2=True),
        exec_spec=ExecSpec(lookahead=cfg.lookahead, binned=False),
    )
    state.floors = state.floors.merged(res.floors())
    state.lp_arg = res.local_path
    a_next, b_next, ovf = reassemble_operands(
        tuple(batches), grid, cap_a, cap_b
    )
    bad, nnz_dev = _dist_equal_nnz(a_next, state.A, grid=grid)
    # ONE host sync per iteration, scalars only (fixpoint + accounting)
    done = int(_to_host(bad)) == 0
    nnz = int(_to_host(nnz_dev))
    overflow = int(_to_host(ovf))
    assert overflow == 0, f"iter {it}: reassembly overflow {overflow}"
    state.A, state.B = a_next, b_next
    dt = time.perf_counter() - t0
    state.history.append({
        "iter": it, "nnz": nnz, "wall_ms": dt * 1e3,
        "retries": res.num_retries, "replans": res.report.replans,
    })
    if verbose:
        print(f"[apsp] iter={it} nnz={nnz} wall={dt*1e3:.1f}ms")
    state.it = it + 1
    state.report = state.report.merged(res.report)
    return state, res.report, done


def _apsp_max_iters(n: int, cfg: APSPConfig) -> int:
    if cfg.max_iters is not None:
        return cfg.max_iters
    return int(np.ceil(np.log2(max(n - 1, 2)))) + 1


def apsp_iterate(
    a: SparseCOO, grid: Grid, cfg: Optional[APSPConfig] = None,
    verbose: bool = False,
) -> Tuple[SparseCOO, List[dict]]:
    """All-pairs shortest paths on the batched multiply; returns the distance
    matrix (absent = unreachable) and per-iteration stats."""
    cfg = cfg or APSPConfig()
    state = _apsp_cold_state(a, grid)
    max_iters = _apsp_max_iters(a.shape[0], cfg)
    while state.it < max_iters:
        state, _, done = _apsp_step(state, grid, cfg, verbose)
        if done:
            break
    final = distsparse.gather_to_global(state.A)
    _mcl._TRANSFER_BYTES[0] += _mcl._dist_bytes(state.A)
    return final, state.history


def apsp_iterate_resilient(
    a: SparseCOO, grid: Grid, cfg: Optional[APSPConfig],
    rc, injector=None, verbose: bool = False,
) -> Tuple[SparseCOO, List[dict], RunReport]:
    """`apsp_iterate` under the durability harness (see
    `runtime.resilient.run_iterated` and `mcl.mcl_iterate_resilient` — same
    contract: checkpoint iterate + plan signature, refuse corrupt restores,
    bitwise trajectory parity after a resume)."""
    from ..runtime.resilient import run_iterated

    cfg = cfg or APSPConfig()
    n = a.shape[0]
    tile_a = (n // grid.pr, n // grid.pc // grid.l)
    tile_b = (n // grid.pr // grid.l, n // grid.pc)

    def encode(state: APSPLoopState):
        arrays: dict = {}
        _mcl._dist_to_arrays(state.A, "A", arrays)
        _mcl._dist_to_arrays(state.B, "B", arrays)
        meta = {
            "workload": "apsp",
            "it": state.it,
            "history": state.history,
            "report": state.report.to_dict(),
            "plan_sig": {
                "floors": state.floors.to_meta(),
                "local_path": state.lp_arg,
            },
        }
        return arrays, meta

    def decode(arrays, meta) -> APSPLoopState:
        sig = meta["plan_sig"]
        return APSPLoopState(
            # bitwise tile restore, re-device_put with the current shardings
            A=_mcl._dist_from_arrays(arrays, "A", grid, (n, n), tile_a, "A"),
            B=_mcl._dist_from_arrays(arrays, "B", grid, (n, n), tile_b, "B"),
            it=int(meta["it"]), history=list(meta["history"]),
            report=RunReport.from_dict(meta["report"]),
            floors=PlanFloors.from_meta(sig["floors"]),
            lp_arg=sig["local_path"],
        )

    def step_fn(state, it, inj):
        return _apsp_step(state, grid, cfg, verbose, injector=inj,
                          slack=inj.capacity_slack(it))

    result = run_iterated(
        rc=rc, max_iters=_apsp_max_iters(n, cfg),
        cold_start=lambda: _apsp_cold_state(a, grid),
        step_fn=step_fn, encode=encode, decode=decode,
        injector=injector, verbose=verbose,
    )
    state = result.state
    final = distsparse.gather_to_global(state.A)
    _mcl._TRANSFER_BYTES[0] += _mcl._dist_bytes(state.A)
    return final, state.history, state.report.merged(dataclasses.replace(
        result.report, retries=0, sel_retries=0, replans=0, ladder_blocked=0,
        degraded_batches=(),
    ))


def apsp_reference(a: SparseCOO) -> np.ndarray:
    """Dense numpy Floyd–Warshall (absent = +inf, zero diagonal)."""
    n = a.shape[0]
    d = np.full((n, n), np.inf, np.float64)
    rr, cc, vv = _apsp_triplets(a)
    np.minimum.at(d, (rr, cc), vv.astype(np.float64))
    np.fill_diagonal(d, np.minimum(np.diag(d), 0.0))
    for k in range(n):
        d = np.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
    return d
