"""HipMCL-style Markov clustering on BatchedSUMMA3D (paper §V-C, Fig. 3).

Each MCL iteration: expansion (A ← A·A, the SpGEMM), inflation (entrywise
power + column normalization), then pruning (threshold + per-column top-k).
The batched multiply lets the expansion run even when nnz(A²) exceeds
memory: each column batch is pruned IMMEDIATELY after it is produced and
only the pruned entries survive — exactly the paper's integration.

``mcl_iterate`` is the DEVICE-RESIDENT pipeline: the per-batch
inflate+normalize+prune runs as a ``batched_summa3d`` postprocess hook (one
jitted SPMD step per batch — column sums/maxima are ``psum``/``pmax``
reductions over the grid, top-k is a distributed threshold bisection on the
sparse path and the ``kernels.col_prune`` Pallas bisection on the dense
path), the pruned batches are reassembled into the next iteration's A/B
operands ON the grid (``summa3d.reassemble_operands`` — a layer all-to-all,
no ``gather_to_global``/``scatter_to_grid`` inside the loop), and chaos is a
distributed per-column max/sumsq reduction read back as one scalar per
batch. The pruned-output capacities feed back into ``plan_batches`` via
``reserved_bytes`` so ``MCLConfig.per_process_memory`` bounds operands +
unmerged batch + kept pruned output together.

``mcl_iterate_host`` is the kept host-loop reference (gathers every batch,
prunes in numpy, re-scatters each iteration) — the parity baseline for tests
and the host-transfer comparison in ``benchmarks.bench_mcl``.

Usage (device-resident loop)::

    from repro.core.grid import make_grid
    from repro.sparse_apps.mcl import MCLConfig, mcl_iterate, clusters_from_matrix

    grid = make_grid(2, 2, 2)            # 8 devices: 2x2 layers x 2
    a = ...  # column-stochastic SparseCOO adjacency (n x n)
    final, history = mcl_iterate(a, grid, MCLConfig(
        inflation=2.0, max_per_col=64, per_process_memory=1 << 26))
    labels = clusters_from_matrix(final.rows[:final.nnz],
                                  final.cols[:final.nnz], a.shape[0])

``history[i]["host_bytes"]`` records the host<->device traffic of iteration
i — a few stat scalars on the device-resident path vs. the full matrix every
batch on the host reference.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import shard_map
from ..core import distsparse
from ..core.batched import RunReport, batched_summa3d
from ..core.distsparse import DistSparse, dist_spec, local_col_reduce
from ..core.grid import COL_AX, LAYER_AX, ROW_AX, Grid
from ..core.sparse import SparseCOO, from_dense_overflow, from_numpy_coo
from ..core.specs import ExecSpec, PlanFloors, PlanSpec
from ..core.summa3d import (
    _pmax_grid,
    _psum_grid,
    _squeeze_tile,
    reassemble_operands,
)
from ..core.symbolic import rup8 as _rup8
from ..kernels.col_prune import THRESH_ITERS, col_topk_bounds_pallas


@dataclasses.dataclass
class MCLConfig:
    inflation: float = 2.0
    prune_threshold: float = 1e-4
    max_per_col: int = 64  # top-k per column (HipMCL "recovery/selection")
    max_iters: int = 20
    converge_tol: float = 1e-3
    per_process_memory: int = 1 << 26
    path: str = "sparse"
    force_num_batches: Optional[int] = None  # None: symbolic-step planning
    lookahead: int = 2  # pipelined driver window
    r_bytes: int = 12  # bytes per stored nonzero (COO: i32+i32+f32)
    binned: object = "auto"  # sparse local multiply: "auto" | True | False
    # 3-way local-multiply dispatch: "auto" | "esc" | "binned" | "hash"
    local_path: str = "auto"


# ---------------------------------------------------------------------------
# Host<->device transfer accounting (benchmark instrumentation)
# ---------------------------------------------------------------------------
_TRANSFER_BYTES = [0]


def reset_transfer_bytes() -> None:
    _TRANSFER_BYTES[0] = 0


def transfer_bytes() -> int:
    """Host<->device bytes moved by MCL code since the last reset."""
    return _TRANSFER_BYTES[0]


def _to_host(x) -> np.ndarray:
    """Device -> host read with byte accounting."""
    a = np.asarray(x)
    _TRANSFER_BYTES[0] += a.nbytes
    return a


def _dist_bytes(d: DistSparse) -> int:
    return d.rows.nbytes + d.cols.nbytes + d.vals.nbytes + d.nnz.nbytes


def _scatter(a: SparseCOO, grid: Grid, kind: str) -> DistSparse:
    """Host -> device scatter with byte accounting (module indirection so
    tests can count/forbid scatter calls inside the iteration loop)."""
    d = distsparse.scatter_to_grid(a, grid, kind)
    _TRANSFER_BYTES[0] += _dist_bytes(d)
    return d


# ---------------------------------------------------------------------------
# Host reference pruning math (kept: parity oracle for the device pipeline)
# ---------------------------------------------------------------------------
def _col_normalize_np(rows, cols, vals, n):
    sums = np.zeros(n, vals.dtype)
    np.add.at(sums, cols, vals)
    sums[sums == 0] = 1.0
    return vals / sums[cols]


def _prune_topk_np(rows, cols, vals, n, thresh, k):
    keep = vals >= thresh
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    # per-column top-k
    order = np.lexsort((-vals, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # rank within column
    first = np.ones(len(cols), bool)
    first[1:] = cols[1:] != cols[:-1]
    idx_of_first = np.maximum.accumulate(np.where(first, np.arange(len(cols)), 0))
    rank = np.arange(len(cols)) - idx_of_first
    keep = rank < k
    return rows[keep], cols[keep], vals[keep]


def _record_iter(history, it, nnz, chaos, res, t0, t0_bytes, verbose):
    """Shared per-iteration epilogue: one history row schema for all three
    loop variants (device sparse / device dense / host reference) so the
    bench and parity consumers can zip them together. The robustness fields
    (retries/replans, from the driver's `RunReport`) ride along so the
    resilient loop's trajectory log carries the degradation story too."""
    history.append({
        "iter": it, "nnz": nnz, "chaos": chaos,
        "batches": res.plan.num_batches, "flops": res.plan.total_flops,
        "retries": res.num_retries, "replans": res.report.replans,
        "host_bytes": transfer_bytes() - t0_bytes,
        "wall_ms": (time.perf_counter() - t0) * 1e3,
    })
    if verbose:
        print(f"[mcl] iter={it} nnz={nnz} chaos={chaos:.5f} "
              f"b={res.plan.num_batches}")


# ---------------------------------------------------------------------------
# Device-side per-batch postprocess (the fused §V-C consumption step)
# ---------------------------------------------------------------------------
@partial(
    jax.jit,
    static_argnames=("grid", "inflation", "thresh", "k", "new_cap"),
)
def _mcl_prune_sparse(
    c: DistSparse, grid: Grid, inflation: float, thresh: float, k: int,
    new_cap: int,
):
    """Inflate + column-normalize + prune one sparse C batch ON the grid.

    One SPMD step per batch (dispatched by the driver's postprocess hook, so
    it overlaps later batches under the pipelined schedule):

      1. inflation: entrywise power (local).
      2. column normalization: column sums are a segment-sum + ``psum`` over
         the grid row axis (a batch column lives in the pr tiles of one
         (grid column, layer) pair — ``distsparse.local_col_reduce``).
      3. top-k: distributed threshold bisection (the sparse masked-select
         realization of ``kernels.col_prune``) — per-column counts are
         ``psum``-reduced each step, so the k-th value is found across all
         row blocks without moving entries; combined with the absolute
         ``thresh`` cut, then one ``compact`` to the pruned capacity.
      4. renormalize survivors; chaos (max per-column max - sumsq) and the
         kept-entry count come back as replicated device scalars.

    Returns ``(pruned DistSparse, stats)`` with stats device-resident:
    ``{"chaos": f32[], "nnz": i32[], "overflow": i32[]}``.
    """
    tm, tn = c.tile_shape

    def step(c_t: DistSparse):
        t = _squeeze_tile(c_t)
        valid = t.valid_mask()
        v = jnp.where(valid, t.vals.astype(jnp.float32), 0.0)
        v = v ** inflation
        # column normalization over the grid row group
        colsum = local_col_reduce(v, t.cols, valid, tn, "sum", (ROW_AX,))
        inv = 1.0 / jnp.where(colsum > 0, colsum, 1.0)
        inv_pad = jnp.concatenate([inv, jnp.ones((1,), jnp.float32)])
        segids = jnp.where(valid, t.cols, tn)
        v = v * inv_pad[segids]
        # distributed per-column top-k threshold (bisection on value)
        colmax = local_col_reduce(v, t.cols, valid, tn, "max", (ROW_AX,))
        hi = colmax + 1e-6
        lo = jnp.zeros_like(hi)

        def body(_, lohi):
            lo_, hi_ = lohi
            mid = 0.5 * (lo_ + hi_)
            mid_pad = jnp.concatenate([mid, jnp.zeros((1,), jnp.float32)])
            over = valid & (v >= mid_pad[segids])
            cnt = local_col_reduce(
                over.astype(jnp.float32), t.cols, valid, tn, "sum", (ROW_AX,)
            )
            take_hi = cnt > k
            return (
                jnp.where(take_hi, mid, lo_),
                jnp.where(take_hi, hi_, mid),
            )

        lo_f, tcol = lax.fori_loop(0, THRESH_ITERS, body, (lo, hi))
        tcol_pad = jnp.concatenate([tcol, jnp.full((1,), jnp.inf, jnp.float32)])
        lo_pad = jnp.concatenate([lo_f, jnp.full((1,), jnp.inf, jnp.float32)])
        # k-boundary ties: a value repeated across the k-th position sits in
        # the final bracket [lo, tcol) — "v >= tcol" alone would drop the
        # WHOLE tied group (annihilating uniform columns, where every entry
        # ties). HipMCL keeps exactly k: take all strictly-greater entries,
        # then fill the remaining slots from the tie band by rank — local
        # rank within the tile plus an all-gathered per-row-block offset, so
        # the quota is allocated consistently across the grid row group.
        greater = valid & (v >= thresh) & (v >= tcol_pad[segids])
        cnt_hi = local_col_reduce(
            greater.astype(jnp.float32), t.cols, valid, tn, "sum", (ROW_AX,)
        ).astype(jnp.int32)
        slots = jnp.maximum(k - cnt_hi, 0)  # (tn,) free slots per column
        tied = (
            valid & (v >= thresh) & (v >= lo_pad[segids])
            & (v < tcol_pad[segids])
        )
        # within-column rank of the tied entries (slot order), O(cap) memory:
        # one stable two-key sort groups tied entries by column, the rank is
        # the position within the column run, scattered back to entry slots —
        # no (cap, tn) scratch in the memory-constrained hot path.
        cap = v.shape[0]
        idx = jnp.arange(cap, dtype=jnp.int32)
        sort_seg = jnp.where(tied, segids, tn)  # non-tied group last
        seg_sorted, perm = lax.sort((sort_seg, idx), num_keys=2)
        pos = jnp.arange(cap, dtype=jnp.int32)
        is_first = jnp.concatenate([
            jnp.ones((1,), bool), seg_sorted[1:] != seg_sorted[:-1]
        ])
        run_start = lax.cummax(jnp.where(is_first, pos, 0))
        rank = jnp.zeros((cap,), jnp.int32).at[perm].set(pos - run_start)
        tied_cnt = jax.ops.segment_sum(
            tied.astype(jnp.int32), segids, num_segments=tn + 1
        )[:tn]
        all_cnt = lax.all_gather(tied_cnt, ROW_AX)  # (pr, tn)
        i_own = lax.axis_index(ROW_AX)
        offset = jnp.sum(
            jnp.where(
                jnp.arange(all_cnt.shape[0], dtype=jnp.int32)[:, None] < i_own,
                all_cnt, 0,
            ),
            axis=0,
        )
        quota = jnp.clip(slots - offset, 0, None)
        quota_pad = jnp.concatenate([quota, jnp.zeros((1,), jnp.int32)])
        keep = greater | (tied & (rank < quota_pad[segids]))
        # renormalize the survivors
        vk = jnp.where(keep, v, 0.0)
        colsum2 = local_col_reduce(vk, t.cols, valid, tn, "sum", (ROW_AX,))
        inv2 = 1.0 / jnp.where(colsum2 > 0, colsum2, 1.0)
        inv2_pad = jnp.concatenate([inv2, jnp.ones((1,), jnp.float32)])
        v2 = vk * inv2_pad[segids]
        # chaos = max over columns of (col max - col sum of squares)
        colmax2 = local_col_reduce(v2, t.cols, keep, tn, "max", (ROW_AX,))
        colsq2 = local_col_reduce(v2 * v2, t.cols, keep, tn, "sum", (ROW_AX,))
        chaos = _pmax_grid(jnp.max(colmax2 - colsq2))
        nnz = _psum_grid(jnp.sum(keep.astype(jnp.int32)))
        pruned, ovf = SparseCOO(t.rows, t.cols, v2, t.nnz, (tm, tn)).compact(
            keep, new_cap
        )
        return (
            pruned.rows[None, None, None],
            pruned.cols[None, None, None],
            pruned.vals[None, None, None],
            pruned.nnz[None, None, None],
            chaos,
            nnz,
            _pmax_grid(ovf),
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(step, mesh=grid.mesh, in_specs=(dist_spec(c, spec3),),
                   out_specs=(spec3,) * 4 + (spec0,) * 3, check_vma=False)
    rows, cols, vals, nnz, chaos, total, ovf = fn(c)
    pruned = DistSparse(rows=rows, cols=cols, vals=vals, nnz=nnz,
                        shape=c.shape, tile_shape=c.tile_shape,
                        grid_shape=c.grid_shape, kind=c.kind)
    return pruned, {"chaos": chaos, "nnz": total, "overflow": ovf}


@partial(jax.jit, static_argnames=("grid", "inflation", "thresh", "k"))
def _mcl_prune_dense(c_tiles, grid: Grid, inflation: float, thresh: float, k: int):
    """Dense-path batch postprocess: inflate + normalize + top-k prune the
    stacked (pr, pc, l, tm, wbl) C tiles on-device. The per-column top-k
    threshold comes from the ``kernels.col_prune`` Pallas bisection on the
    row-gathered column block (the batch column is split across the pr row
    tiles, so the kernel sees the full column). Returns (pruned tiles, stats).
    """
    interpret = jax.default_backend() != "tpu"

    def step(x):
        t = x.reshape(x.shape[-2:]).astype(jnp.float32)  # (tm, wbl)
        tm = t.shape[0]
        t = t ** inflation
        colsum = lax.psum(jnp.sum(t, axis=0), ROW_AX)
        t = t / jnp.where(colsum > 0, colsum, 1.0)[None, :]
        full = lax.all_gather(t, ROW_AX).reshape(-1, t.shape[1])
        lo, thr = col_topk_bounds_pallas(full, k, interpret=interpret)
        # keep all strictly-greater entries, then fill the remaining top-k
        # slots from the [lo, thr) tie band by rank (a value repeated across
        # the k boundary would otherwise be pruned entirely); the full
        # column is gathered here, so the rank fill is local.
        greater = (full >= thr[None, :]) & (full >= thresh)
        tied = (full >= lo[None, :]) & (full < thr[None, :]) & (full >= thresh)
        slots = (k - jnp.sum(greater.astype(jnp.int32), axis=0))
        rank = jnp.cumsum(tied.astype(jnp.int32), axis=0) - tied
        keep_full = greater | (tied & (rank < slots[None, :]))
        i_own = lax.axis_index(ROW_AX)
        keep = lax.dynamic_slice_in_dim(keep_full, i_own * tm, tm, axis=0)
        t = jnp.where(keep, t, 0.0)
        colsum2 = lax.psum(jnp.sum(t, axis=0), ROW_AX)
        t = t / jnp.where(colsum2 > 0, colsum2, 1.0)[None, :]
        colmax = lax.pmax(jnp.max(t, axis=0), ROW_AX)
        colsq = lax.psum(jnp.sum(t * t, axis=0), ROW_AX)
        chaos = _pmax_grid(jnp.max(colmax - colsq))
        nnz = _psum_grid(jnp.sum((t > 0).astype(jnp.int32)))
        return t[None, None, None], chaos, nnz

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(step, mesh=grid.mesh, in_specs=(spec3,),
                   out_specs=(spec3, spec0, spec0), check_vma=False)
    tiles, chaos, nnz = fn(c_tiles)
    return tiles, {"chaos": chaos, "nnz": nnz, "overflow": jnp.int32(0)}


@partial(jax.jit, static_argnames=("grid", "shape", "cap"))
def _dense_to_sparse_batch(tiles, grid: Grid, shape, cap: int):
    """Sparsify one pruned dense batch ON the grid: per-tile
    ``from_dense_overflow`` over the stacked (pr, pc, l, tm, wbl) tiles,
    producing the sparse C-batch layout ``summa3d.reassemble_operands``
    consumes. Returns ``(DistSparse kind "C", pmax-reduced overflow)`` —
    overflow is provably 0 when ``cap >= min(k, tm) * wbl`` (the post-prune
    per-tile hard bound)."""

    def step(x):
        t = x.reshape(x.shape[-2:])
        s, ovf = from_dense_overflow(t, cap)
        return (
            s.rows[None, None, None], s.cols[None, None, None],
            s.vals[None, None, None], s.nnz[None, None, None],
            _pmax_grid(ovf),
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(step, mesh=grid.mesh, in_specs=(spec3,),
                   out_specs=(spec3,) * 4 + (spec0,), check_vma=False)
    rows, cols, vals, nnz, ovf = fn(tiles)
    tm, wbl = tiles.shape[-2:]
    return DistSparse(rows=rows, cols=cols, vals=vals, nnz=nnz,
                      shape=shape, tile_shape=(tm, wbl),
                      grid_shape=(grid.pr, grid.pc, grid.l), kind="C"), ovf


def _extract_dense_batch(tiles: np.ndarray, col_map: np.ndarray):
    """Vectorized host extraction of one dense batch: one ``np.nonzero``
    over the stacked tiles instead of a pr×pc×l Python tile loop."""
    pr, pc, l, tm, wbl = tiles.shape
    i, j, kk, r, c = np.nonzero(tiles)
    return i * tm + r, col_map[j, kk, c], tiles[i, j, kk, r, c]


# ---------------------------------------------------------------------------
# Device-resident MCL loop (explicit-state form: one step = one iteration)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MCLLoopState:
    """Everything one sparse MCL iteration carries to the next — the
    device-resident iterate (A/B operands) PLUS the plan signature: ONE
    ``PlanFloors`` (pow2/floor caps, pinned k-bin caps, hash caps,
    batch-count floor — it replaced four parallel floor attributes) and the
    pinned binned/local-path decisions. The resilient loop checkpoints
    exactly this: the arrays via the content-hashed store, the signature as
    manifest meta — so a restored run replans to the IDENTICAL fused-step
    static signature and hits the jit cache (zero extra retraces after a
    resume)."""

    A: DistSparse
    B: DistSparse
    it: int
    chaos: float
    history: List[dict]
    report: RunReport
    floors: PlanFloors = dataclasses.field(default_factory=PlanFloors)
    binned_arg: object = "auto"
    lp_arg: object = "auto"


def _mcl_caps(n: int, grid: Grid, cfg: MCLConfig) -> Tuple[int, int, int]:
    """Post-prune operand capacities (<= min(k, rows-in-tile) per column)
    and the reserved-bytes charge they place on the multiply budget."""
    tm = n // grid.pr
    w = n // grid.pc
    wl = w // grid.l
    k = cfg.max_per_col
    cap_a = _rup8(max(8, min(k, tm) * wl))
    cap_b = _rup8(max(8, min(k, wl) * w))
    return cap_a, cap_b, cfg.r_bytes * (cap_a + cap_b)


def _mcl_cold_state(a: SparseCOO, grid: Grid, cfg: MCLConfig) -> MCLLoopState:
    """Iteration-0 state: input scattered once, plan signature unpinned."""
    return MCLLoopState(
        A=_scatter(a, grid, "A"), B=_scatter(a, grid, "B"),
        it=0, chaos=float("inf"), history=[], report=RunReport(),
        binned_arg=cfg.binned, lp_arg=cfg.local_path,
    )


def _mcl_sparse_step(
    state: MCLLoopState, grid: Grid, cfg: MCLConfig, verbose: bool = False,
    injector=None, slack: Optional[float] = None,
) -> Tuple[MCLLoopState, RunReport, bool]:
    """ONE device-resident MCL iteration on explicit state.

    Returns ``(state', per-iteration RunReport, converged)``. The plan
    signature floors are pinned after the first iteration exactly as before
    (pow2-quantized + monotone capacities → one fused-step executable, see
    tests/test_mcl_pipeline.py). ``injector`` (resilient runs) hooks the
    consumer — straggler sleeps and mid-iteration preemption fire at batch
    granularity, inside the pipelined lookahead window; ``slack`` overrides
    the planner's capacity slack (overflow-storm injection).
    """
    n = state.A.shape[0]
    tm = n // grid.pr
    k = cfg.max_per_col
    cap_a, cap_b, reserved = _mcl_caps(n, grid, cfg)
    it = state.it
    t0_bytes = transfer_bytes()
    t0 = time.perf_counter()
    batches: List[DistSparse] = []
    stats: List[dict] = []

    def postprocess(bi, c_batch):
        tn = c_batch.tile_shape[1]
        new_cap = _rup8(max(8, min(min(k, tm) * tn, c_batch.cap)))
        return _mcl_prune_sparse(
            c_batch, grid=grid, inflation=cfg.inflation,
            thresh=cfg.prune_threshold, k=k, new_cap=new_cap,
        )

    def consumer(bi, payload, col_map):
        if injector is not None:
            injector.maybe_straggle_batch(it, bi)
            injector.maybe_preempt(it, batch=bi)
        pruned, st = payload
        batches.append(pruned)
        stats.append(st)
        return None

    res = batched_summa3d(
        state.A, state.B, grid,
        per_process_memory=cfg.per_process_memory,
        consumer=consumer, path="sparse",
        postprocess=postprocess,
        spec=PlanSpec(
            local_path=state.lp_arg, r_bytes=cfg.r_bytes,
            reserved_bytes=reserved,
            force_num_batches=cfg.force_num_batches,
            **({"slack": slack} if slack is not None else {}),
        ),
        floors=state.floors.replace(caps_pow2=True),
        exec_spec=ExecSpec(lookahead=cfg.lookahead, binned=state.binned_arg),
    )
    # pin iteration 1's decisions + used capacities (monotone fold) so every
    # later iteration replans onto the same fused-step static signature
    state.floors = state.floors.merged(res.floors())
    state.binned_arg = res.binned
    state.lp_arg = res.local_path
    state.A, state.B, ovf = reassemble_operands(
        tuple(batches), grid, cap_a, cap_b
    )
    # ONE host sync per iteration, scalars only (convergence check)
    chaos = max(float(_to_host(st["chaos"])) for st in stats)
    nnz = sum(int(_to_host(st["nnz"])) for st in stats)
    overflow = int(_to_host(ovf)) + sum(
        int(_to_host(st["overflow"])) for st in stats
    )
    assert overflow == 0, f"iter {it}: pruned-capacity overflow {overflow}"
    _record_iter(state.history, it, nnz, chaos, res, t0, t0_bytes, verbose)
    state.chaos = chaos
    state.it = it + 1
    state.report = state.report.merged(res.report)
    return state, res.report, chaos < cfg.converge_tol


def mcl_iterate(
    a: SparseCOO, grid: Grid, cfg: MCLConfig, verbose: bool = False
) -> Tuple[SparseCOO, List[dict]]:
    """Run MCL until convergence; returns (final matrix, per-iter stats).

    Device-resident: the input is scattered ONCE, every iteration's
    expansion+inflation+normalization+pruning runs on the grid, the pruned
    batches become the next A/B operands via an on-grid reassembly, and only
    per-batch stat scalars (chaos, nnz) cross to the host until the final
    matrix is gathered after convergence. ``cfg.path="dense"`` runs the
    dense-accumulator expansion with the Pallas ``col_prune`` postprocess,
    sparsified on-device and reassembled on-grid exactly like the sparse
    path (scatter once, gather once).

    For long runs, `mcl_iterate_resilient` wraps the same per-iteration step
    in the checkpoint/resume harness (`runtime.resilient.run_iterated`).
    """
    if cfg.path == "dense":
        return _mcl_iterate_dense(a, grid, cfg, verbose)
    state = _mcl_cold_state(a, grid, cfg)
    while state.it < cfg.max_iters:
        state, _, done = _mcl_sparse_step(state, grid, cfg, verbose)
        if done:
            break
    final = distsparse.gather_to_global(state.A)
    _TRANSFER_BYTES[0] += _dist_bytes(state.A)
    return final, state.history


# ---------------------------------------------------------------------------
# Checkpoint codec + resilient loop (durability harness)
# ---------------------------------------------------------------------------
def _dist_to_arrays(d: DistSparse, prefix: str, arrays: dict) -> None:
    arrays[f"{prefix}_rows"] = np.asarray(d.rows)
    arrays[f"{prefix}_cols"] = np.asarray(d.cols)
    arrays[f"{prefix}_vals"] = np.asarray(d.vals)
    arrays[f"{prefix}_nnz"] = np.asarray(d.nnz)


def _dist_from_arrays(
    arrays: dict, prefix: str, grid: Grid, shape, tile_shape, kind: str
) -> DistSparse:
    """Re-device_put checkpointed tiles with the CURRENT grid's shardings
    (elastic restore: the saved mesh layout is irrelevant)."""
    shard = grid.tile_sharding()
    nnz_shard = jax.sharding.NamedSharding(
        grid.mesh, jax.sharding.PartitionSpec(*grid.axis_names)
    )
    return DistSparse(
        rows=jax.device_put(arrays[f"{prefix}_rows"], shard),
        cols=jax.device_put(arrays[f"{prefix}_cols"], shard),
        vals=jax.device_put(arrays[f"{prefix}_vals"], shard),
        nnz=jax.device_put(arrays[f"{prefix}_nnz"], nnz_shard),
        shape=tuple(shape), tile_shape=tuple(tile_shape),
        grid_shape=(grid.pr, grid.pc, grid.l), kind=kind,
    )


def _plan_sig_encode(state: MCLLoopState) -> dict:
    """JSON-safe plan signature: everything `plan_batches` needs to rebuild
    the identical fused-step static signature after a restore — the floors
    round-trip through ``PlanFloors.to_meta`` plus the two pinned driver
    decisions."""
    return {
        "floors": state.floors.to_meta(),
        "binned": state.binned_arg,
        "local_path": state.lp_arg,
    }


def _plan_sig_decode(state: MCLLoopState, sig: dict) -> None:
    state.floors = PlanFloors.from_meta(sig["floors"])
    state.binned_arg = sig["binned"]
    state.lp_arg = sig["local_path"]


def mcl_iterate_resilient(
    a: SparseCOO, grid: Grid, cfg: MCLConfig, rc: "ResilientConfig",
    injector=None, verbose: bool = False,
) -> Tuple[SparseCOO, List[dict], RunReport]:
    """`mcl_iterate` under the durability harness: checkpoint every
    ``rc.ckpt_every`` iterations (device iterate + plan signature), resume
    from ``store.latest_step(rc.ckpt_dir)`` after a preemption (or on
    launch, unless ``rc.resume=False``), refuse corrupt checkpoints, and
    report retries/replans/stalls/stragglers in the returned `RunReport`.

    The encode/decode round-trip is bitwise (i32/f32 host copies) and the
    plan signature restores the exact floors, so a resumed run's trajectory
    — chaos/nnz history AND the final matrix — is identical to the
    uninterrupted run's, with zero extra fused-step retraces (the restored
    operands replan to the same static signature; see tests).
    """
    from ..runtime.resilient import run_iterated

    assert cfg.path == "sparse", "resilient MCL requires the sparse path"
    n = a.shape[0]
    tile_a = (n // grid.pr, n // grid.pc // grid.l)
    tile_b = (n // grid.pr // grid.l, n // grid.pc)

    def encode(state: MCLLoopState):
        arrays: dict = {}
        _dist_to_arrays(state.A, "A", arrays)
        _dist_to_arrays(state.B, "B", arrays)
        meta = {
            "workload": "mcl",
            "it": state.it,
            "chaos": state.chaos,
            "history": state.history,
            "report": state.report.to_dict(),
            "plan_sig": _plan_sig_encode(state),
        }
        return arrays, meta

    def decode(arrays: dict, meta: dict) -> MCLLoopState:
        state = MCLLoopState(
            A=_dist_from_arrays(arrays, "A", grid, (n, n), tile_a, "A"),
            B=_dist_from_arrays(arrays, "B", grid, (n, n), tile_b, "B"),
            it=int(meta["it"]), chaos=float(meta["chaos"]),
            history=list(meta["history"]),
            report=RunReport.from_dict(meta["report"]),
        )
        _plan_sig_decode(state, meta["plan_sig"])
        return state

    def step_fn(state: MCLLoopState, it: int, inj):
        return _mcl_sparse_step(
            state, grid, cfg, verbose, injector=inj,
            slack=inj.capacity_slack(it),
        )

    result = run_iterated(
        rc=rc, max_iters=cfg.max_iters,
        cold_start=lambda: _mcl_cold_state(a, grid, cfg),
        step_fn=step_fn, encode=encode, decode=decode,
        injector=injector, verbose=verbose,
    )
    state = result.state
    final = distsparse.gather_to_global(state.A)
    _TRANSFER_BYTES[0] += _dist_bytes(state.A)
    return final, state.history, state.report.merged(dataclasses.replace(
        result.report, retries=0, sel_retries=0, replans=0, ladder_blocked=0,
        degraded_batches=(),
    ))


def _mcl_iterate_dense(
    a: SparseCOO, grid: Grid, cfg: MCLConfig, verbose: bool = False
) -> Tuple[SparseCOO, List[dict]]:
    """Dense-path loop, device-resident like the sparse path: the input is
    scattered ONCE, each batch is pruned by the Pallas ``col_prune``
    postprocess, sparsified on-device (``from_dense_overflow`` per tile),
    and the sparse batches feed ``summa3d.reassemble_operands`` — no
    ``gather_to_global``/``scatter_to_grid`` inside the iteration loop. The
    final matrix is gathered once after convergence."""
    n = a.shape[0]
    tm = n // grid.pr
    k = cfg.max_per_col
    cap_a, cap_b, reserved = _mcl_caps(n, grid, cfg)
    A = _scatter(a, grid, "A")
    B = _scatter(a, grid, "B")
    history: List[dict] = []
    floors = PlanFloors(caps_pow2=True)
    for it in range(cfg.max_iters):
        t0_bytes = transfer_bytes()
        t0 = time.perf_counter()
        batches: List[DistSparse] = []
        stats: List[dict] = []

        def postprocess(bi, c_tiles):
            tiles, st = _mcl_prune_dense(
                c_tiles, grid=grid, inflation=cfg.inflation,
                thresh=cfg.prune_threshold, k=k,
            )
            wbl = tiles.shape[-1]
            cap = _rup8(max(8, min(k, tm) * wbl))
            sparse, conv_ovf = _dense_to_sparse_batch(
                tiles, grid, (n, n), cap
            )
            return sparse, dict(st, overflow=st["overflow"] + conv_ovf)

        def consumer(bi, payload, col_map):
            sparse, st = payload
            batches.append(sparse)
            stats.append(st)
            return None

        res = batched_summa3d(
            A, B, grid,
            per_process_memory=cfg.per_process_memory,
            consumer=consumer, path="dense", postprocess=postprocess,
            spec=PlanSpec(
                r_bytes=cfg.r_bytes, reserved_bytes=reserved,
                force_num_batches=cfg.force_num_batches,
            ),
            floors=floors,
            exec_spec=ExecSpec(lookahead=cfg.lookahead),
        )
        floors = floors.merged(res.floors())
        A, B, ovf = reassemble_operands(tuple(batches), grid, cap_a, cap_b)
        # ONE host sync per iteration, scalars only (convergence check)
        chaos = max(float(_to_host(st["chaos"])) for st in stats)
        nnz = sum(int(_to_host(st["nnz"])) for st in stats)
        overflow = int(_to_host(ovf)) + sum(
            int(_to_host(st["overflow"])) for st in stats
        )
        assert overflow == 0, f"iter {it}: dense-path overflow {overflow}"
        _record_iter(history, it, nnz, chaos, res, t0, t0_bytes, verbose)
        if chaos < cfg.converge_tol:
            break
    final = distsparse.gather_to_global(A)
    _TRANSFER_BYTES[0] += _dist_bytes(A)
    return final, history


# ---------------------------------------------------------------------------
# Host-loop reference (the kept pre-device implementation)
# ---------------------------------------------------------------------------
def mcl_iterate_host(
    a: SparseCOO, grid: Grid, cfg: MCLConfig, verbose: bool = False
) -> Tuple[SparseCOO, List[dict]]:
    """Host-loop MCL reference: every batch is pulled to numpy, inflation /
    normalization / pruning / chaos all run on the host, and the iterate
    round-trips host<->device each iteration. Kept as the parity oracle and
    the host-transfer baseline for ``benchmarks.bench_mcl``."""
    n = a.shape[0]
    cur = a
    history: List[dict] = []
    for it in range(cfg.max_iters):
        t0_bytes = transfer_bytes()
        t0 = time.perf_counter()
        A = _scatter(cur, grid, "A")
        B = _scatter(cur, grid, "B")
        pieces = []

        def consumer(bi, c_batch, col_map):
            # pull THIS batch to host, prune there, then discard the product
            if cfg.path == "dense":
                pieces.append(_extract_dense_batch(_to_host(c_batch), col_map))
            else:
                pieces.append(_sparse_batch_to_global(c_batch, col_map))
            return None

        res = batched_summa3d(
            A, B, grid,
            per_process_memory=cfg.per_process_memory,
            consumer=consumer, path=cfg.path,
            force_num_batches=cfg.force_num_batches,
        )
        rows = np.concatenate([p[0] for p in pieces])
        cols = np.concatenate([p[1] for p in pieces])
        vals = np.concatenate([p[2] for p in pieces]).astype(np.float64)
        # inflation
        vals = vals ** cfg.inflation
        vals = _col_normalize_np(rows, cols, vals, n)
        rows, cols, vals = _prune_topk_np(
            rows, cols, vals, n, cfg.prune_threshold, cfg.max_per_col
        )
        vals = _col_normalize_np(rows, cols, vals, n).astype(np.float32)
        new = from_numpy_coo(rows, cols, vals, (n, n), cap=max(len(rows), 8))

        # convergence: chaos ~ max col max - col sumsq
        colmax = np.zeros(n, np.float32)
        np.maximum.at(colmax, cols, vals)
        colsq = np.zeros(n, np.float32)
        np.add.at(colsq, cols, vals ** 2)
        chaos = float((colmax - colsq).max())
        _record_iter(history, it, int(len(rows)), chaos, res, t0, t0_bytes,
                     verbose)
        cur = new
        if chaos < cfg.converge_tol:
            break
    return cur, history


def _sparse_batch_to_global(c: DistSparse, col_map: np.ndarray):
    """Host-side reassembly of one sparse C batch into global coordinates
    (vectorized over the tile grid)."""
    pr, pc, l = c.grid_shape
    tm, wbl = c.tile_shape
    R = _to_host(c.rows)
    C = _to_host(c.cols)
    V = _to_host(c.vals)
    N = _to_host(c.nnz)
    cap = R.shape[-1]
    valid = np.arange(cap)[None, None, None, :] < N[..., None]
    i, j, kk, s = np.nonzero(valid)
    return (
        i * tm + R[i, j, kk, s],
        col_map[j, kk, C[i, j, kk, s]],
        V[i, j, kk, s],
    )


def clusters_from_matrix(rows, cols, n: int) -> np.ndarray:
    """Connected components of the converged MCL matrix = cluster labels."""
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for r, c in zip(rows, cols):
        pr_, pc_ = find(r), find(c)
        if pr_ != pc_:
            parent[pr_] = pc_
    return np.array([find(i) for i in range(n)])
