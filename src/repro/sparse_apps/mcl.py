"""HipMCL-style Markov clustering on BatchedSUMMA3D (paper §V-C, Fig. 3).

Each MCL iteration: expansion (A ← A·A, the SpGEMM), inflation (entrywise
power + column normalization), then pruning (threshold + per-column top-k).
The batched multiply lets the expansion run even when nnz(A²) exceeds
memory: each column batch is pruned IMMEDIATELY after it is produced and
only the pruned entries survive — exactly the paper's integration.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import semiring as sr
from ..core.batched import batched_summa3d
from ..core.distsparse import DistSparse, gather_to_global, scatter_to_grid
from ..core.grid import Grid
from ..core.sparse import SparseCOO, from_numpy_coo


@dataclasses.dataclass
class MCLConfig:
    inflation: float = 2.0
    prune_threshold: float = 1e-4
    max_per_col: int = 64  # top-k per column (HipMCL "recovery/selection")
    max_iters: int = 20
    converge_tol: float = 1e-3
    per_process_memory: int = 1 << 26
    path: str = "sparse"


def _col_normalize_np(rows, cols, vals, n):
    sums = np.zeros(n, vals.dtype)
    np.add.at(sums, cols, vals)
    sums[sums == 0] = 1.0
    return vals / sums[cols]


def _prune_topk_np(rows, cols, vals, n, thresh, k):
    keep = vals >= thresh
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    # per-column top-k
    order = np.lexsort((-vals, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # rank within column
    first = np.ones(len(cols), bool)
    first[1:] = cols[1:] != cols[:-1]
    idx_of_first = np.maximum.accumulate(np.where(first, np.arange(len(cols)), 0))
    rank = np.arange(len(cols)) - idx_of_first
    keep = rank < k
    return rows[keep], cols[keep], vals[keep]


def mcl_iterate(
    a: SparseCOO, grid: Grid, cfg: MCLConfig, verbose: bool = False
) -> Tuple[SparseCOO, List[dict]]:
    """Run MCL until convergence; returns (final matrix, per-iter stats).

    The expansion consumes each SpGEMM batch with inflation+prune before the
    next batch is formed (memory-constrained consumption)."""
    n = a.shape[0]
    cur = a
    history = []
    for it in range(cfg.max_iters):
        A = scatter_to_grid(cur, grid, "A")
        B = scatter_to_grid(cur, grid, "B")
        pieces = []

        def consumer(bi, c_batch, col_map):
            # inflate + prune THIS batch, then discard the raw product
            if cfg.path == "dense":
                tiles = np.asarray(c_batch)
                pr, pc, l, tm, wbl = tiles.shape
                for i in range(pr):
                    for j in range(pc):
                        for k_ in range(l):
                            t = tiles[i, j, k_]
                            rr, cc = np.nonzero(t)
                            pieces.append((i * tm + rr, col_map[j, k_][cc], t[rr, cc]))
            else:
                c = gather_to_global(c_batch)
                nnz = int(c.nnz)
                rr = np.asarray(c.rows[:nnz])
                cc_local = np.asarray(c.cols[:nnz])
                vv = np.asarray(c.vals[:nnz])
                # local piece cols -> global via col_map (tile order): the
                # gathered global cols of the batch C are already tile-major;
                # use the DistSparse direct reassembly instead:
                pieces.append(_sparse_batch_to_global(c_batch, col_map))
            return None

        res = batched_summa3d(
            A, B, grid,
            per_process_memory=cfg.per_process_memory,
            consumer=consumer, path=cfg.path,
        )
        rows = np.concatenate([p[0] for p in pieces])
        cols = np.concatenate([p[1] for p in pieces])
        vals = np.concatenate([p[2] for p in pieces]).astype(np.float64)
        # inflation
        vals = vals ** cfg.inflation
        vals = _col_normalize_np(rows, cols, vals, n)
        rows, cols, vals = _prune_topk_np(
            rows, cols, vals, n, cfg.prune_threshold, cfg.max_per_col
        )
        vals = _col_normalize_np(rows, cols, vals, n).astype(np.float32)
        new = from_numpy_coo(rows, cols, vals, (n, n), cap=max(len(rows), 8))

        # convergence: chaos ~ max col max - col sumsq
        colmax = np.zeros(n, np.float32)
        np.maximum.at(colmax, cols, vals)
        colsq = np.zeros(n, np.float32)
        np.add.at(colsq, cols, vals ** 2)
        chaos = float((colmax - colsq).max())
        history.append({
            "iter": it, "nnz": int(len(rows)), "chaos": chaos,
            "batches": res.plan.num_batches, "flops": res.plan.total_flops,
        })
        if verbose:
            print(f"[mcl] iter={it} nnz={len(rows)} chaos={chaos:.5f} "
                  f"b={res.plan.num_batches}")
        cur = new
        if chaos < cfg.converge_tol:
            break
    return cur, history


def _sparse_batch_to_global(c: DistSparse, col_map: np.ndarray):
    pr, pc, l = c.grid_shape
    tm, wbl = c.tile_shape
    R = np.asarray(c.rows)
    C = np.asarray(c.cols)
    V = np.asarray(c.vals)
    N = np.asarray(c.nnz)
    rows_l, cols_l, vals_l = [], [], []
    for i in range(pr):
        for j in range(pc):
            for k in range(l):
                cnt = int(N[i, j, k])
                rows_l.append(i * tm + R[i, j, k, :cnt])
                cols_l.append(col_map[j, k][C[i, j, k, :cnt]])
                vals_l.append(V[i, j, k, :cnt])
    return (
        np.concatenate(rows_l) if rows_l else np.zeros(0, np.int64),
        np.concatenate(cols_l) if cols_l else np.zeros(0, np.int64),
        np.concatenate(vals_l) if vals_l else np.zeros(0, np.float32),
    )


def clusters_from_matrix(rows, cols, n: int) -> np.ndarray:
    """Connected components of the converged MCL matrix = cluster labels."""
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for r, c in zip(rows, cols):
        pr_, pc_ = find(r), find(c)
        if pr_ != pc_:
            parent[pr_] = pc_
    return np.array([find(i) for i in range(n)])
