"""BatchedSUMMA3D (paper Alg. 4) + the distributed symbolic step (Alg. 3).

The driver mirrors the paper's phase structure exactly:

  1. SYMBOLIC3D: one communication-avoiding pass that computes per-process
     flops upper bounds. Instead of broadcasting tiles, it reduces A's
     per-column counts along grid rows (psum) and gathers them along grid
     columns — the paper's observation that the symbolic step has the same
     communicator structure but a far lighter payload (§IV-A, Fig. 8).
  2. Host-side batch planning: b from Alg. 3 line 12 (+ Eq. 2 lower-bound
     check), rounded up for block-cyclic divisibility; static capacities for
     the numeric pass derived from the symbolic per-column vectors. This is
     the paper's symbolic→numeric split — in JAX it also fixes the static
     shapes the compiler needs.
  3. Per-batch SUMMA3D (Alg. 4 line 5-6) with block-cyclic column selection
     (Fig. 1(i)) inside the jitted step — one compile serves all batches
     (batch index is a traced scalar).
  4. The consumer callback sees each C batch and may prune/store/discard it
     (HipMCL-style usage, §V-C) — C is never materialized whole unless asked.

Overflow robustness: if a static capacity is exceeded (sparsity estimate
beaten by correlation structure), the step reports it and the driver retries
that batch with 2× capacity — bounded, logged, and tested.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import semiring as sr
from ..compat import shard_map
from .distsparse import DistSparse
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .summa3d import BatchCaps, _squeeze_tile, summa3d_dense_step, summa3d_sparse_step
from .symbolic import batch_count, batch_count_lower_bound, batching_plan_columns

# cached compiles: one per (grid, caps, semiring, tile-shape) combination —
# the batch index is a traced scalar so all batches share one executable.
_dense_jit = jax.jit(summa3d_dense_step, static_argnames=("grid", "semiring"))
_sparse_jit = jax.jit(
    summa3d_sparse_step,
    static_argnames=("grid", "caps", "semiring", "sorted_merge"),
)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Distributed symbolic step (Alg. 3)
# ---------------------------------------------------------------------------
def symbolic3d(a: DistSparse, b: DistSparse, grid: Grid) -> np.ndarray:
    """Per-(process, local column of B) flops upper bound.

    Returns host array of shape (pr, pc, l, tn_b):
      flops[i,j,k,c] = Σ_{t ∈ B(:, block j, layer k), col(t)=c}
                           nnz(A^(k)(row-block i, k_idx(t)))

    which is exactly the number of partial products process (i,j,k) forms for
    output column c in the numeric step (A gathered over the grid row, B over
    the grid column group). Only count *vectors* travel — the paper's point
    that the symbolic step shares the numeric communicators but moves a far
    lighter payload (§IV-A, Fig. 8).
    """
    _, tn_b = b.tile_shape
    _, wl_a = a.tile_shape

    def step(a_t: DistSparse, b_t: DistSparse):
        a_loc = _squeeze_tile(a_t)
        b_loc = _squeeze_tile(b_t)
        # A col counts restricted to OUR row block, over the per-layer
        # contraction range, ordered by stage (matches _gather_A indexing)
        cc_local = a_loc.col_counts()  # (wl_a,)
        cc_full = lax.all_gather(cc_local, COL_AX).reshape(-1)  # (k_tot,)
        # every row block's count vector (needed because our B entries
        # contribute to every process in our grid column's row group)
        cc_all = lax.all_gather(cc_full, ROW_AX)  # (pr, k_tot)
        k_tot = cc_full.shape[0]
        cc_all_pad = jnp.concatenate(
            [cc_all, jnp.zeros((cc_all.shape[0], 1), jnp.int32)], axis=1
        )
        # B entries in OUR tile: contraction index = i_own*wl + local row
        # (matches _gather_B indexing)
        i_own = lax.axis_index(ROW_AX)
        valid = b_loc.valid_mask()
        k_idx = jnp.where(valid, b_loc.rows + i_own * wl_a, k_tot)
        contrib = cc_all_pad[:, k_idx]  # (pr, capB): per target row block
        contrib = jnp.where(valid[None, :], contrib, 0)
        segids = jnp.where(valid, b_loc.cols, tn_b)
        percol_all = jax.ops.segment_sum(
            contrib.T, segids, num_segments=tn_b + 1
        )[:tn_b].T  # (pr, tn_b): row i = our entries' contribution to block-row i
        # sum over the row group -> each process reads its own row
        percol_all = lax.psum(percol_all, ROW_AX)
        percol = percol_all[i_own]
        return percol[None, None, None]

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    in_specs = (
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=a.shape, tile_shape=a.tile_shape,
                   grid_shape=a.grid_shape, kind=a.kind),
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=b.shape, tile_shape=b.tile_shape,
                   grid_shape=b.grid_shape, kind=b.kind),
    )
    fn = jax.jit(shard_map(
        step, mesh=grid.mesh, in_specs=in_specs, out_specs=spec3,
        check_vma=False,
    ))
    return np.asarray(fn(a, b))  # (pr, pc, l, tn_b)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Host-side plan produced by the symbolic step."""

    num_batches: int
    lower_bound: int  # Eq. (2)
    caps: BatchCaps
    total_flops: int  # Σ multiply ops (global)
    max_unmerged_nnz: int  # max over processes, b=1
    per_batch_flops: np.ndarray  # (num_batches,) global flops per batch


def plan_batches(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    r_bytes: int = 12,
    slack: float = 1.3,
    force_num_batches: Optional[int] = None,
) -> BatchPlan:
    """Run the symbolic step and derive b + static capacities (host math)."""
    percol = symbolic3d(a, b, grid)  # (pr, pc, l, tn_b)
    pr, pc, l, tn_b = percol.shape
    per_process_flops = percol.sum(axis=-1)  # (pr, pc, l)
    max_unmerged = int(per_process_flops.max())
    total_flops = int(per_process_flops.sum())
    max_nnz_a = int(np.asarray(a.nnz).max())
    max_nnz_b = int(np.asarray(b.nnz).max())

    if force_num_batches is not None:
        nb = force_num_batches
    else:
        nb = batch_count(
            max_unmerged, max_nnz_a, max_nnz_b, per_process_memory, r=r_bytes
        )
    nb = batching_plan_columns(tn_b, nb, l)
    wbl = tn_b // (nb * l)  # block width of the block-cyclic split

    # per-(process, batch, piece) flops: fold local columns into
    # (block, within) and map block -> (piece k2 = block // nb, batch = block % nb)
    blocks = percol.reshape(pr, pc, l, nb * l, wbl).sum(axis=-1)  # (pr,pc,l,nb*l)
    piece_of_block = np.arange(nb * l) // nb
    batch_of_block = np.arange(nb * l) % nb
    flops_pbp = np.zeros((pr, pc, l, nb, l), np.int64)  # [..., batch, piece]
    for blk in range(nb * l):
        flops_pbp[:, :, :, batch_of_block[blk], piece_of_block[blk]] += blocks[
            :, :, :, blk
        ]
    per_batch_proc = flops_pbp.sum(axis=-1)  # (pr,pc,l,nb)
    max_batch_flops = int(per_batch_proc.max())
    max_piece_flops = int(flops_pbp.max())
    # merged C piece bound: sum over source layers of that piece's flops
    merged_piece = flops_pbp.sum(axis=2).max()  # max over (pr,pc,batch,piece)

    tm_a = a.tile_shape[0]
    wb = tn_b // nb
    flops_cap = _rup8(max(int(max_batch_flops * slack), 64))
    d_cap = _rup8(min(flops_cap, tm_a * wb))
    piece_cap = _rup8(min(max(int(max_piece_flops * slack), 64), tm_a * (wb // l)))
    c_cap = _rup8(min(max(int(merged_piece * slack), 64), tm_a * (wb // l)))
    caps = BatchCaps(flops_cap=flops_cap, d_cap=d_cap, piece_cap=piece_cap, c_cap=c_cap)

    # Eq. (2) lower bound (global memory form) for reporting/validation
    nnz_a = int(np.asarray(a.nnz).sum())
    nnz_b = int(np.asarray(b.nnz).sum())
    mem_c = r_bytes * int(per_process_flops.sum())
    try:
        lb = batch_count_lower_bound(
            mem_c, per_process_memory * grid.p, nnz_a, nnz_b, r=r_bytes
        )
    except MemoryError:
        lb = -1

    per_batch_flops = per_batch_proc.sum(axis=(0, 1, 2))  # (nb,)
    return BatchPlan(
        num_batches=nb,
        lower_bound=lb,
        caps=caps,
        total_flops=total_flops,
        max_unmerged_nnz=max_unmerged,
        per_batch_flops=per_batch_flops,
    )


def _rup8(x: int) -> int:
    return ((x + 7) // 8) * 8


def batch_column_map(n: int, grid: Grid, num_batches: int, batch: int) -> np.ndarray:
    """Global columns covered by ``batch``, in C-tile order.

    Returns g[j, k, c] of shape (pc, l, wb/l): the global column of local
    column c in C tile (:, j, k) for this batch. Inverse of the block-cyclic
    selection + fiber split.
    """
    pc, l = grid.pc, grid.l
    w = n // pc
    wb = w // num_batches
    wbl = w // (num_batches * l)
    out = np.zeros((pc, l, wb // l), np.int64)
    for j in range(pc):
        for k in range(l):
            for c in range(wb // l):
                # C tile layer k holds fiber piece k = D cols [k*wb/l,(k+1)*wb/l)
                d_col = k * (wb // l) + c
                # D batch cols remap: block t = d_col // wbl (t-th block of the
                # batch), within = d_col % wbl; original local block index =
                # t * num_batches + batch
                t = d_col // wbl
                within = d_col % wbl
                orig_local = (t * num_batches + batch) * wbl + within
                out[j, k, c] = j * w + orig_local
    return out


# ---------------------------------------------------------------------------
# The batched driver (Alg. 4)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchedResult:
    plan: BatchPlan
    num_retries: int
    consumed: list  # consumer outputs per batch


def batched_summa3d(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    consumer: Callable[[int, object, np.ndarray], object],
    path: str = "sparse",
    semiring: sr.Semiring = sr.PLUS_TIMES,
    r_bytes: int = 12,
    slack: float = 1.3,
    max_retries: int = 4,
    force_num_batches: Optional[int] = None,
    sorted_merge: bool = True,
) -> BatchedResult:
    """Multiply A·B in batches; the consumer sees each batch then it's freed.

    consumer(batch_idx, c_batch, global_col_map) -> anything; c_batch is a
    DistSparse (path="sparse") or stacked dense tiles (path="dense").
    ``sorted_merge`` selects the segmented (merge-not-sort) Merge-Fiber in
    the per-batch sparse step.
    """
    plan = plan_batches(
        a, b, grid, per_process_memory, r_bytes=r_bytes, slack=slack,
        force_num_batches=force_num_batches,
    )
    nb = plan.num_batches
    l = grid.l
    tn_b = b.tile_shape[1]
    wb = tn_b // nb
    # batch selection capacity: worst-case per-batch share of B entries
    nnz_host = np.asarray(b.nnz)
    sel_cap = _rup8(max(int(nnz_host.max() * slack / max(nb // 2, 1)), 64))
    sel_cap = min(sel_cap, b.cap)

    consumed = []
    retries = 0
    caps = plan.caps
    for bi in range(nb):
        ok = False
        cur_caps, cur_sel_cap = caps, sel_cap
        for attempt in range(max_retries + 1):
            b_sel, ovf_sel = _select_batch_jit(b, grid, bi, nb, l, cur_sel_cap, wb)
            if int(ovf_sel) > 0:
                cur_sel_cap = min(_rup8(cur_sel_cap * 2), b.cap)
                retries += 1
                continue
            if path == "dense":
                c_batch = _dense_jit(a, b_sel, grid=grid, semiring=semiring)
                ok = True
                break
            c_batch, ovf = _sparse_jit(
                a, b_sel, grid=grid, caps=cur_caps, semiring=semiring,
                sorted_merge=sorted_merge,
            )
            if int(ovf) == 0:
                ok = True
                break
            retries += 1
            cur_caps = BatchCaps(
                flops_cap=cur_caps.flops_cap * 2,
                d_cap=cur_caps.d_cap * 2,
                piece_cap=cur_caps.piece_cap * 2,
                c_cap=cur_caps.c_cap * 2,
            )
        if not ok:
            raise RuntimeError(
                f"batch {bi}: capacity overflow persisted after {max_retries} retries"
            )
        col_map = batch_column_map(b.shape[1], grid, nb, bi)
        consumed.append(consumer(bi, c_batch, col_map))
    return BatchedResult(plan=plan, num_retries=retries, consumed=consumed)


@partial(jax.jit, static_argnames=("grid", "num_batches", "l", "cap", "wb"))
def _select_batch_jit(b: DistSparse, grid: Grid, batch, num_batches: int, l: int,
                      cap: int, wb: int):
    def step(b_t: DistSparse, batch_):
        b_loc = _squeeze_tile(b_t)
        sel, ovf = b_loc.select_cols_blockcyclic(
            batch_, num_batches, l, new_cap=cap
        )
        ovf = lax.pmax(lax.pmax(lax.pmax(ovf, ROW_AX), COL_AX), LAYER_AX)
        return (
            sel.rows[None, None, None],
            sel.cols[None, None, None],
            sel.vals[None, None, None],
            sel.nnz[None, None, None],
            ovf,
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    in_specs = (
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=b.shape, tile_shape=b.tile_shape,
                   grid_shape=b.grid_shape, kind=b.kind),
        spec0,
    )
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=in_specs,
        out_specs=(spec3, spec3, spec3, spec3, spec0),
        check_vma=False,
    )
    rows, cols, vals, nnz, ovf = fn(b, jnp.int32(batch))
    m, n = b.shape
    sel = DistSparse(
        rows=rows, cols=cols, vals=vals, nnz=nnz,
        shape=(m, n // num_batches),
        tile_shape=(b.tile_shape[0], wb),
        grid_shape=b.grid_shape,
        kind="B",
    )
    return sel, ovf
