"""BatchedSUMMA3D (paper Alg. 4) + the distributed symbolic step (Alg. 3).

The driver mirrors the paper's phase structure, pipelined so the host stays
out of the per-batch loop (§IV-A: numeric batches stream through the
communicators once symbolic planning is done):

  1. SYMBOLIC3D: one communication-avoiding pass that computes per-process
     flops upper bounds. Instead of broadcasting tiles, it reduces A's
     per-column counts along grid rows (psum) and gathers them along grid
     columns — the paper's observation that the symbolic step has the same
     communicator structure but a far lighter payload (§IV-A, Fig. 8). The
     same pass also emits B's per-column entry counts (exact per-batch
     selection capacities — no heuristic, no spurious selection retries) and
     the per-k count vectors of the *gathered* operands, from which the
     k-bin plan for the paired local multiply is derived.
  2. Host-side batch planning: b from Alg. 3 line 12 (+ Eq. 2 lower-bound
     check), rounded up for block-cyclic divisibility; static capacities for
     the numeric pass derived from the symbolic per-column vectors; a
     ``KBinPlan`` sizing the k-binned local multiply. This is the paper's
     symbolic→numeric split — in JAX it also fixes the static shapes the
     compiler needs.
  3. Pipelined per-batch schedule: selection + multiply are FUSED into one
     jitted SPMD step (``summa3d.summa3d_fused_step``) whose batch index is
     a traced scalar — one executable for all batches. The driver dispatches
     batch i+1 (and up to ``lookahead`` more) before reading batch i's
     overflow flags, which stay device-resident; under async dispatch the
     next batch's selection and gathers overlap the previous multiply, and
     the consumer's host-side work overlaps device compute.
  4. A device-side ``postprocess`` hook transforms each batch product
     IMMEDIATELY after the fused step, before any host involvement — the
     HipMCL integration (§V-C): MCL fuses inflation + distributed column
     normalization + top-k pruning here, so the raw product never reaches
     the host. The host ``consumer`` then sees the hook's output (or the raw
     batch when no hook is set) and may store/discard it — C is never
     materialized whole unless asked. ``plan_batches(reserved_bytes=...)``
     lets such consumers charge their kept outputs against the per-process
     budget (memory-constrained consumption).

Overflow robustness: if a static capacity is exceeded (sparsity estimate
beaten by correlation structure), the flags come back nonzero and the driver
falls back to the synchronous retry loop for that batch — selection capacity
grows first, then the multiply capacities (2× per attempt) — bounded, logged,
and tested. ``pipelined=False`` keeps the fully synchronous schedule (one
host round-trip per batch), which doubles as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import semiring as sr
from ..compat import shard_map
from .distsparse import DistSparse, dist_spec
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .summa3d import (
    BatchCaps,
    BinnedCaps,
    HashCaps,
    _squeeze_tile,
    summa3d_dense_step,
    summa3d_fused_step,
    summa3d_sparse_step,
)
from .placement import BLOCK_CYCLIC, Placement
from .sparse import hstack_remap
from .specs import ExecSpec, PlanFloors, PlanSpec, resolve_specs
from .symbolic import (
    HASH_LOAD_FACTOR,
    HASH_SLOT_BYTES,
    KBinPlan,
    SymbolicCounts,
    batch_count,
    batch_count_lower_bound,
    estimate_mem_c_bytes,
    plan_k_bins,
    rup8 as _rup8,
    rup_pow2 as _rup_pow2,
)

# auto-dispatch threshold: the hash path pays a per-chunk insert pass, so it
# must buy at least this compression factor (flops per merged survivor)
# before the plan prefers it over ESC/binned.
HASH_CF_THRESHOLD = 2.0

# partial products enumerated per reused chunk buffer of the hash path
HASH_CHUNK_CAP = 4096

# cached compiles: one per (grid, caps, semiring, tile-shape) combination —
# the batch index is a traced scalar so all batches share one executable.
_dense_jit = jax.jit(summa3d_dense_step, static_argnames=("grid", "semiring"))
_sparse_jit = jax.jit(
    summa3d_sparse_step,
    static_argnames=(
        "grid", "caps", "semiring", "sorted_merge", "kbin", "hashc",
    ),
)
_fused_jit = jax.jit(
    summa3d_fused_step,
    static_argnames=(
        "grid", "num_batches", "sel_cap", "caps", "semiring", "sorted_merge",
        "path", "kbin", "hashc", "mask_cap", "mask_complement",
    ),
)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Distributed symbolic step (Alg. 3)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("grid",))
def _symbolic3d_jit(
    a: DistSparse, b: DistSparse, mask: Optional[DistSparse], grid: Grid
):
    """One jitted executable per (grid, operand-structure) — the shard_map is
    built inside the traced function, so re-running the planner hits the jit
    cache instead of rebuilding (and re-lowering) the SPMD program.

    ``mask`` (masked plans) rides the same pass: its exact per-(tile, local
    column) entry counts are computed on-grid and returned as one more count
    vector, so masked planning never round-trips the mask's structure
    through the host (ROADMAP carry-over (d))."""
    _, tn_b = b.tile_shape
    wl_b, _ = b.tile_shape

    def step(a_t: DistSparse, b_t: DistSparse, *rest):
        a_loc = _squeeze_tile(a_t)
        b_loc = _squeeze_tile(b_t)
        # A col counts restricted to OUR row block, over the per-layer
        # contraction range, ordered by stage (matches _gather_A indexing)
        cc_local = a_loc.col_counts()  # (wl_a,)
        cc_full = lax.all_gather(cc_local, COL_AX).reshape(-1)  # (k_tot,)
        # every row block's count vector (needed because our B entries
        # contribute to every process in our grid column's row group)
        cc_all = lax.all_gather(cc_full, ROW_AX)  # (pr, k_tot)
        k_tot = cc_full.shape[0]
        cc_all_pad = jnp.concatenate(
            [cc_all, jnp.zeros((cc_all.shape[0], 1), jnp.int32)], axis=1
        )
        # B entries in OUR tile: contraction index = i_own*wl_b + local row
        # (matches _gather_B indexing — the stride is B's OWN tile row
        # count, which equals A's tile width only on square layer grids)
        i_own = lax.axis_index(ROW_AX)
        valid = b_loc.valid_mask()
        k_idx = jnp.where(valid, b_loc.rows + i_own * wl_b, k_tot)
        contrib = cc_all_pad[:, k_idx]  # (pr, capB): per target row block
        contrib = jnp.where(valid[None, :], contrib, 0)
        segids = jnp.where(valid, b_loc.cols, tn_b)
        percol_all = jax.ops.segment_sum(
            contrib.T, segids, num_segments=tn_b + 1
        )[:tn_b].T  # (pr, tn_b): row i = our entries' contribution to block-row i
        # sum over the row group -> each process reads its own row
        percol_all = lax.psum(percol_all, ROW_AX)
        percol = percol_all[i_own]
        # extras for the numeric pass, free on the same communicators:
        # B per-column entry counts (exact selection capacities) and the
        # per-k counts of the gathered operands (k-bin plan input).
        bcc = b_loc.col_counts()  # (tn_b,)
        rc_local = b_loc.row_counts()  # (wl,)
        rc_full = lax.all_gather(rc_local, ROW_AX).reshape(-1)  # (k_tot,)
        outs = (
            percol[None, None, None],
            bcc[None, None, None],
            cc_full[None, None, None],
            rc_full[None, None, None],
        )
        if rest:
            # exact per-(tile, local column) mask counts, on-grid
            mcc = _squeeze_tile(rest[0]).col_counts()  # (wl,)
            outs = outs + (mcc[None, None, None],)
        return outs

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    in_specs = [dist_spec(d, spec3) for d in (a, b)]
    out_specs = (spec3, spec3, spec3, spec3)
    args = [a, b]
    if mask is not None:
        in_specs.append(dist_spec(mask, spec3))
        out_specs = out_specs + (spec3,)
        args.append(mask)
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=tuple(in_specs),
        out_specs=out_specs,
        check_vma=False,
    )
    return fn(*args)


def _mask_tile_colcounts(mask: DistSparse) -> np.ndarray:
    """Exact per-(tile, local column) mask entry counts — (pr, pc, l, wl).

    Host numpy ORACLE of the on-grid mask counts ``_symbolic3d_jit`` now
    emits (the planner path no longer round-trips the mask's cols/nnz
    arrays); kept for parity testing — mask values never matter, and the
    result is exact, so the mask-selection capacity it sizes cannot
    overflow.
    """
    C = np.asarray(mask.cols)
    N = np.asarray(mask.nnz)
    pr, pc, l, cap = C.shape
    tn = mask.tile_shape[1]
    valid = np.arange(cap)[None, None, None, :] < N[..., None]
    tile = np.arange(pr * pc * l).reshape(pr, pc, l, 1)
    flat = tile * (tn + 1) + np.where(valid, C, tn)
    cnt = np.bincount(flat.ravel(), minlength=pr * pc * l * (tn + 1))
    return cnt.reshape(pr, pc, l, tn + 1)[..., :tn].astype(np.int64)


def symbolic3d_counts(
    a: DistSparse, b: DistSparse, grid: Grid, mask: Optional[DistSparse] = None
) -> SymbolicCounts:
    """Run the distributed symbolic step; see ``SymbolicCounts``.

    ``mask`` (C-layout, same global shape as the product) additionally emits
    the masked output counts the §V-B applications plan with — computed
    inside the same jitted shard_map pass, so only the (pr, pc, l, wl)
    count vectors ever reach the host.
    """
    mask_cc = None
    if mask is not None:
        assert mask.kind in ("A", "C"), mask.kind
        assert mask.shape == (a.shape[0], b.shape[1]), (mask.shape, a.shape, b.shape)
        percol, bcc, cc_full, rc_full, mcc = _symbolic3d_jit(a, b, mask, grid)
        mask_cc = np.asarray(mcc).astype(np.int64)
    else:
        percol, bcc, cc_full, rc_full = _symbolic3d_jit(a, b, None, grid)
    # cc_full is a function of (row block, layer) only; rc_full of
    # (col block, layer) only — slice the redundant grid axes away.
    return SymbolicCounts(
        percol=np.asarray(percol),
        b_colcounts=np.asarray(bcc),
        a_kcounts=np.asarray(cc_full)[:, 0],  # (pr, l, k_tot)
        b_kcounts=np.asarray(rc_full)[0],  # (pc, l, k_tot)
        mask_colcounts=mask_cc,
    )


def symbolic3d(a: DistSparse, b: DistSparse, grid: Grid) -> np.ndarray:
    """Per-(process, local column of B) flops upper bound.

    Returns host array of shape (pr, pc, l, tn_b):
      flops[i,j,k,c] = Σ_{t ∈ B(:, block j, layer k), col(t)=c}
                           nnz(A^(k)(row-block i, k_idx(t)))

    which is exactly the number of partial products process (i,j,k) forms for
    output column c in the numeric step (A gathered over the grid row, B over
    the grid column group). ``symbolic3d_counts`` exposes the fuller payload
    (including the masked output counts when its ``mask`` is given).
    """
    return symbolic3d_counts(a, b, grid).percol


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Host-side plan produced by the symbolic step."""

    num_batches: int
    lower_bound: int  # Eq. (2)
    caps: BatchCaps
    total_flops: int  # Σ multiply ops (global)
    max_unmerged_nnz: int  # max over processes, b=1 (mask-filtered if masked)
    per_batch_flops: np.ndarray  # (num_batches,) global flops per batch
    sel_cap: int = 0  # exact per-batch selection capacity (B entries)
    kbin: Optional[KBinPlan] = None  # k-bin plan for the paired local multiply
    mask_sel_cap: int = 0  # exact per-batch mask-slice capacity (masked only)
    local_path: str = "esc"  # plan-driven local-multiply decision (b=1 view)
    hash_caps: Optional[HashCaps] = None  # static hash caps (local_path="hash")
    compression_est: float = 1.0  # flops per merged survivor (b=1, max proc)

    @property
    def binned_profitable(self) -> bool:
        """Plan-driven switch: does k-binning strictly cut pairing work?

        Requires real bin structure (num_bins > 1): with a single bin the
        capacity-product baseline still shrinks (compaction drops padding),
        but there is no structural reduction to pay the binning pass for.
        """
        return (
            self.kbin is not None
            and self.kbin.num_bins > 1
            and self.kbin.pairings < self.kbin.pairings_unbinned
        )


def plan_batches(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    spec: Optional[PlanSpec] = None,
    floors: Optional[PlanFloors] = None,
    **legacy,
) -> BatchPlan:
    """Run the symbolic step and derive b + static capacities (host math).

    The planning policy lives on ``spec`` (`PlanSpec`) and the cross-plan
    capacity pins on ``floors`` (`PlanFloors`); the old keyword surface
    (``r_bytes=``, ``slack=``, ``caps_floor=``, …) is still accepted for one
    release and mapped onto the specs with a ``DeprecationWarning``. A bare
    call (no spec) keeps the historical ``local_path="esc"`` default; a
    passed spec uses its own default ("auto" — the driver's semantics).

    ``spec.local_path`` drives the 3-way local-multiply decision recorded on
    the plan: "esc" and "binned" keep the classic O(flops)-scratch budget;
    "hash" budgets the hash-accumulator path at O(nnz_out·load_factor)
    resident bytes instead of O(flops) — high compression-factor multiplies
    then need strictly fewer batches at the same ``per_process_memory``;
    "auto" picks "hash" when the estimated compression factor clears
    ``HASH_CF_THRESHOLD`` (the binned-vs-ESC refinement stays with the
    driver, which knows the semiring). ``floors.hash_caps`` floors the
    derived ``HashCaps`` elementwise (iterated-multiply jit-cache
    stability, like ``floors.caps``); ``floors.kbin_caps`` additionally pins
    the k-bin candidate list to its bin count when the spec leaves
    ``kbin_candidates`` unset.

    ``spec.reserved_bytes`` is subtracted from the per-process budget before the
    Alg. 3 batch count: memory the caller has already committed per process
    to the CONSUMED outputs (e.g. the pruned batches a memory-constrained MCL
    iteration keeps on-device for the next iterate, §V-C) — so the budget
    honors what actually lives alongside the unmerged batch results.

    ``mask`` switches on masked planning (§V-B): with a strict mask
    (``mask_complement=False``) the surviving output structure is bounded by
    the mask's exact per-column counts, so the unmerged budget, the batch
    count, and the D/piece/C capacities all shrink to survivors —
    per column c of process (i,j,k):

      unmerged ≤ min(flops[c], mask[c] · nnz(B_gathered(:, c)))  (pre-merge)
      merged D ≤ min(flops[c], mask[c])                          (post-merge)
      merged C ≤ min(Σ_k flops[k][c], mask[c])

    (a complement mask excludes structure, so it cannot tighten counts —
    the plan stays at the unmasked bounds and only the numeric filter runs).
    ``mask_sel_cap`` is sized from the exact mask counts, so the per-batch
    mask-slice selection can never overflow.

    Memory-model semantics: the Alg. 3 budget charges r·nnz of *stored*
    unmerged results — in the paper's hash SpGEMM partial products are
    consumed on the fly, and the masked counts above model exactly the
    stored survivors. Our ESC rendering does materialize an UNMASKED
    ``flops_cap`` expansion scratch per batch (the filter runs between
    expansion and compress), so on memory-bound hardware that transient is
    the masked path's true high-water mark; gating the expansion itself is
    the ROADMAP follow-up that removes it.

    ``floors.caps_pow2`` rounds every derived capacity up to the next power
    of two and ``floors.caps``/``floors.sel_cap`` take an elementwise max
    with a previous plan's capacities — together they keep the fused step's
    static signature stable across the iterations of an iterated multiply
    (MCL), so per-iteration cap drift hits the jit cache instead of
    recompiling.
    """
    spec, floors, _ = resolve_specs(
        spec, floors, None, legacy, default_local_path="esc",
        where="plan_batches", allow_exec=False,
    )
    counts = symbolic3d_counts(a, b, grid, mask=spec.mask)
    inputs = PlanInputs(
        tm_a=a.tile_shape[0],
        max_nnz_a=int(np.asarray(a.nnz).max()),
        max_nnz_b=int(np.asarray(b.nnz).max()),
        nnz_a=int(np.asarray(a.nnz).sum()),
        nnz_b=int(np.asarray(b.nnz).sum()),
        cap_a=a.cap,
        cap_b=b.cap,
        p=grid.p,
        cap_mask=spec.mask.cap if spec.mask is not None else None,
    )
    return plan_from_symbolic(counts, inputs, per_process_memory, spec, floors)


@dataclasses.dataclass(frozen=True)
class PlanInputs:
    """Scalar operand facts ``plan_from_symbolic`` needs besides the count
    vectors — constructible from scattered operands (``plan_batches``) or
    from host COO + a candidate grid shape (``PlanInputs.from_host``, the
    autotuner's no-device oracle path)."""

    tm_a: int  # A/C tile rows (m // pr)
    max_nnz_a: int  # max per-tile nnz of scattered A
    max_nnz_b: int
    nnz_a: int  # global nnz(A)
    nnz_b: int
    cap_a: int  # static per-tile capacity of scattered A
    cap_b: int
    p: int  # process count pr*pc*l
    cap_mask: Optional[int] = None

    @classmethod
    def from_host(cls, a, b, grid_shape: Tuple[int, int, int],
                  mask=None, cap_slack: float = 1.3, min_cap: int = 8,
                  ) -> "PlanInputs":
        """Build the scalar facts for a CANDIDATE grid from host COO —
        per-tile nnz maxima via the layout math (no scatter), static
        capacities via ``scatter_to_grid``'s default sizing rule, so the
        oracle plan matches what a default scatter would produce."""
        from .symbolic import host_tile_counts

        def _cap(counts):
            return max(int(np.ceil(counts.max() * cap_slack)), min_cap)

        ca = host_tile_counts(a, grid_shape, "A")
        cb = host_tile_counts(b, grid_shape, "B")
        pr, pc, l = grid_shape
        return cls(
            tm_a=a.shape[0] // pr,
            max_nnz_a=int(ca.max()),
            max_nnz_b=int(cb.max()),
            nnz_a=int(a.nnz),
            nnz_b=int(b.nnz),
            cap_a=_cap(ca),
            cap_b=_cap(cb),
            p=pr * pc * l,
            cap_mask=(
                _cap(host_tile_counts(mask, grid_shape, "C"))
                if mask is not None else None
            ),
        )


def plan_from_symbolic(
    counts: SymbolicCounts,
    inputs: PlanInputs,
    per_process_memory: int,
    spec: PlanSpec,
    floors: PlanFloors,
) -> BatchPlan:
    """Pure host planning math — ``plan_batches`` minus the device pass.

    Everything downstream of the symbolic counts is numpy over count
    vectors, so the SAME function plans a real multiply (counts from the
    distributed pass) and prices a hypothetical one (counts from
    ``symbolic.host_symbolic_counts`` for a candidate grid the operands were
    never scattered to) — which is what lets ``repro.tune`` enumerate grids
    without touching a device.
    """
    r_bytes, slack = spec.r_bytes, spec.slack
    force_num_batches = spec.force_num_batches
    reserved_bytes = spec.reserved_bytes
    mask_complement = spec.mask_complement
    local_path = spec.local_path
    caps_pow2, caps_floor = floors.caps_pow2, floors.caps
    sel_cap_floor, num_batches_floor = floors.sel_cap, floors.num_batches
    hash_caps_floor = floors.hash_caps
    kbin_candidates = spec.kbin_candidates
    if kbin_candidates is None and floors.kbin_caps is not None:
        # a pinned-bin-count floor implies the candidate pin the old API
        # made every iterated caller thread separately
        kbin_candidates = (floors.kbin_caps.num_bins,)
    if reserved_bytes >= per_process_memory:
        raise MemoryError(
            f"reserved output bytes ({reserved_bytes}) exceed per-process "
            f"memory ({per_process_memory})"
        )
    per_process_memory = per_process_memory - reserved_bytes
    # pluggable tile→batch distribution: every fold below routes through it
    # (BLOCK_CYCLIC delegates to the historical fold_block_cyclic math)
    dist = spec.distribution if spec.distribution is not None else BLOCK_CYCLIC
    percol = counts.percol  # (pr, pc, l, tn_b)
    pr, pc, l, tn_b = percol.shape
    masked = counts.mask_colcounts is not None and not mask_complement
    if masked:
        # mcount[i, j, c]: mask entries of (row block i, col block j) at
        # block-local column c — the (l, wl) mask tiles laid out layer-major
        # cover exactly the w = tn_b local columns of the block.
        mcount = counts.mask_colcounts.reshape(pr, pc, tn_b)
        bcolg = counts.b_colcounts.sum(axis=0, keepdims=True)  # (1,pc,l,tn_b)
        unmerged_percol = np.minimum(percol, mcount[:, :, None, :] * bcolg)
        merged_d_percol = np.minimum(percol, mcount[:, :, None, :])
    else:
        unmerged_percol = percol
        merged_d_percol = percol
    per_process_flops = percol.sum(axis=-1)  # (pr, pc, l)
    max_unmerged = int(unmerged_percol.sum(axis=-1).max())
    total_flops = int(per_process_flops.sum())
    max_nnz_a = inputs.max_nnz_a
    max_nnz_b = inputs.max_nnz_b

    # hash-path resident bound (O(output)): the table holds MERGED
    # survivors, and a D-tile column cannot exceed tm_a distinct rows
    assert local_path in ("auto", "esc", "binned", "hash"), local_path
    tm_a = inputs.tm_a
    max_hash_nnz = int(np.minimum(merged_d_percol, tm_a).sum(axis=-1).max())
    compression_est = max_unmerged / max(max_hash_nnz, 1)
    budget_hash = local_path == "hash" or (
        local_path == "auto" and compression_est >= HASH_CF_THRESHOLD
    )

    if force_num_batches is not None:
        nb = force_num_batches
    else:
        if budget_hash:
            # the stored intermediate is the table, not the expansion:
            # convert its byte footprint back to r-byte units for Alg. 3
            hash_bytes = estimate_mem_c_bytes(
                max_unmerged, compression_est, r_bytes,
                local_path="hash", load_factor=HASH_LOAD_FACTOR,
            )
            budget_nnz = max(-(-hash_bytes // r_bytes), 1)
        else:
            budget_nnz = max_unmerged
        # num_batches is part of the fused step's static signature; the
        # floor (a previous iteration's count — more batches is always
        # valid) keeps iterated multiplies on one executable as nnz drifts.
        nb = max(
            batch_count(
                budget_nnz, max_nnz_a, max_nnz_b, per_process_memory,
                r=r_bytes,
            ),
            num_batches_floor,
        )
    nb = dist.round_batches(tn_b, nb, l)

    # per-(process, batch, piece) flops via the distribution's fold
    flops_pbp = dist.fold(percol, nb, l)  # (pr,pc,l,nb,l)
    per_batch_proc = flops_pbp.sum(axis=-1)  # (pr,pc,l,nb)
    max_batch_flops = int(per_batch_proc.max())
    # D-tile bounds come from the mask-filtered counts (the filter runs
    # before the compress, so survivors alone occupy the static buffers)
    d_pbp = dist.fold(merged_d_percol, nb, l)
    max_batch_d = int(d_pbp.sum(axis=-1).max())
    max_piece_flops = int(d_pbp.max())
    # merged C piece bound: sum over source layers, mask-capped per column
    merged_col = percol.sum(axis=2)  # (pr, pc, tn_b)
    if masked:
        merged_col = np.minimum(merged_col, mcount)
    merged_piece = dist.fold(merged_col, nb, l).max()

    wb = tn_b // nb
    flops_cap = _rup8(max(int(max_batch_flops * slack), 64))
    d_cap = _rup8(
        min(max(int(max_batch_d * slack), 64), flops_cap, tm_a * wb)
    )
    piece_cap = _rup8(min(max(int(max_piece_flops * slack), 64), tm_a * (wb // l)))
    c_cap = _rup8(min(max(int(merged_piece * slack), 64), tm_a * (wb // l)))
    caps = BatchCaps(flops_cap=flops_cap, d_cap=d_cap, piece_cap=piece_cap, c_cap=c_cap)

    # exact per-batch selection capacity: max over (process, batch) of the
    # number of B entries the distribution's selection keeps — from the
    # symbolic B-column counts, so the first batch can never trigger a
    # spurious selection retry on skewed inputs.
    sel_per_batch = dist.fold(counts.b_colcounts, nb, l).sum(axis=-1)
    sel_cap = min(_rup8(max(int(sel_per_batch.max()), 8)), inputs.cap_b)

    # exact per-batch mask-slice capacity: batch bi selects the contiguous
    # local columns [bi·wbl, (bi+1)·wbl) of every mask tile.
    mask_sel_cap = 0
    if counts.mask_colcounts is not None:
        per_batch_mask = dist.fold_batch_slices(counts.mask_colcounts, nb)
        mask_sel_cap = min(
            _rup8(max(int(per_batch_mask.max()), 8)), inputs.cap_mask
        )

    if caps_pow2:
        caps = BatchCaps(*(_rup_pow2(x) for x in dataclasses.astuple(caps)))
        sel_cap = min(_rup_pow2(sel_cap), inputs.cap_b)
        if counts.mask_colcounts is not None:
            mask_sel_cap = min(_rup_pow2(mask_sel_cap), inputs.cap_mask)
    if caps_floor is not None:
        caps = BatchCaps(*(
            max(x, y) for x, y in zip(
                dataclasses.astuple(caps), dataclasses.astuple(caps_floor)
            )
        ))
    sel_cap = max(sel_cap, sel_cap_floor)

    # k-bin plan for the gathered pairing: per-k count vectors bounded
    # element-wise over (block, layer) so the static caps hold on every
    # process; gathered capacities are pc·capA / pr·sel_cap slots.
    # ``kbin_candidates`` pins the bin-count choice (iterated multiplies pin
    # it to the previous iteration's bin count for jit-cache stability).
    kbin_kwargs = (
        {"candidates": tuple(kbin_candidates)} if kbin_candidates else {}
    )
    kbin = plan_k_bins(
        counts.a_kcounts.max(axis=(0, 1)),
        counts.b_kcounts.max(axis=(0, 1)),
        pc * inputs.cap_a,
        pr * sel_cap,
        **kbin_kwargs,
    )

    # Eq. (2) lower bound (global memory form) for reporting/validation
    mem_c = r_bytes * int(per_process_flops.sum())
    try:
        lb = batch_count_lower_bound(
            mem_c, per_process_memory * inputs.p,
            inputs.nnz_a, inputs.nnz_b, r=r_bytes,
        )
    except MemoryError:
        lb = -1

    # plan-driven local-multiply decision + static hash caps. Both derive
    # from the already-quantized/floored capacities, so iterated runs with
    # pow2 caps keep ONE fused-step executable per decided path.
    if budget_hash:
        decided = "hash"
    elif local_path in ("esc", "binned"):
        decided = local_path
    else:  # auto, hash not profitable: structural binned-vs-ESC preference
        decided = (
            "binned"
            if kbin.num_bins > 1 and kbin.pairings < kbin.pairings_unbinned
            else "esc"
        )
    hash_caps = None
    if decided == "hash":
        chunk = min(caps.flops_cap, _rup8(HASH_CHUNK_CAP))
        num_chunks = -(-caps.flops_cap // chunk)
        table = _rup_pow2(max(int(HASH_LOAD_FACTOR * caps.d_cap), 64))
        hash_caps = HashCaps(
            table_cap=table, chunk_cap=chunk, num_chunks=num_chunks
        )
        if hash_caps_floor is not None:
            hash_caps = HashCaps(
                table_cap=max(hash_caps.table_cap, hash_caps_floor.table_cap),
                chunk_cap=max(hash_caps.chunk_cap, hash_caps_floor.chunk_cap),
                num_chunks=max(
                    hash_caps.num_chunks, hash_caps_floor.num_chunks
                ),
                max_probes=max(
                    hash_caps.max_probes, hash_caps_floor.max_probes
                ),
            )

    per_batch_flops = per_batch_proc.sum(axis=(0, 1, 2))  # (nb,)
    return BatchPlan(
        num_batches=nb,
        lower_bound=lb,
        caps=caps,
        total_flops=total_flops,
        max_unmerged_nnz=max_unmerged,
        per_batch_flops=per_batch_flops,
        sel_cap=sel_cap,
        kbin=kbin,
        mask_sel_cap=mask_sel_cap,
        local_path=decided,
        hash_caps=hash_caps,
        compression_est=float(compression_est),
    )


def probe_memory_budget(
    a: DistSparse, b: DistSparse, grid: Grid,
    r_bytes: int = 12, fraction: int = 3, floor: int = 256,
) -> int:
    """A per-process budget that forces the (unmasked) plan to batch:
    inputs plus 1/``fraction`` of the probed unmerged output.

    Shared by the graph bench and the slow-lane R-MAT cases so both assert
    the §V-B masked-vs-unmasked claim against the SAME budget math (the
    symbolic probe is jit-cached — replanning is cheap).
    """
    probe = plan_batches(a, b, grid, per_process_memory=1 << 30,
                         spec=PlanSpec(local_path="esc", r_bytes=r_bytes))
    inputs = r_bytes * (
        int(np.asarray(a.nnz).max()) + int(np.asarray(b.nnz).max())
    )
    return inputs + max(r_bytes * probe.max_unmerged_nnz // fraction, floor)


def batch_column_map(n: int, grid: Grid, num_batches: int, batch: int) -> np.ndarray:
    """Global columns covered by ``batch``, in C-tile order.

    Returns g[j, k, c] of shape (pc, l, wb/l): the global column of local
    column c in C tile (:, j, k) for this batch. Inverse of the block-cyclic
    selection + fiber split (delegates to the distribution object — the
    triple-loop reference lives in the placement contract tests).
    """
    return BLOCK_CYCLIC.batch_column_map(n, grid.pc, grid.l, num_batches, batch)


# ---------------------------------------------------------------------------
# The batched driver (Alg. 4) — pipelined scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunReport:
    """Structured robustness accounting for one driver run or iterated loop.

    ``batched_summa3d`` fills the ladder fields (retries / replans /
    degradations); the resilient iterated loops (`runtime/resilient.py`)
    merge per-iteration reports and add the checkpoint / straggler / restart
    fields. JSON round-trips via `to_dict`/`from_dict` so the report itself
    survives a checkpoint.
    """

    retries: int = 0  # overflow retry dispatches (sync ladder steps)
    sel_retries: int = 0  # selection-capacity retries among those
    replans: int = 0  # batches replanned at finer batching (degradation)
    ladder_blocked: int = 0  # cap doublings refused by the memory ceiling
    degraded_batches: Tuple[Tuple[int, int], ...] = ()  # (batch, split)
    straggler_events: int = 0  # EWMA watchdog firings (iterated loops)
    restarts: int = 0  # preemption restore-and-continue count
    refused_restores: int = 0  # corrupt checkpoints refused at restore
    checkpoint_stalls: int = 0  # saves that blocked on a prior in-flight write
    checkpoint_stall_s: float = 0.0
    checkpoint_bytes: int = 0  # total checkpoint bytes written

    def merged(self, other: "RunReport") -> "RunReport":
        """Field-wise accumulation (counts add, degradations concatenate)."""
        return RunReport(
            retries=self.retries + other.retries,
            sel_retries=self.sel_retries + other.sel_retries,
            replans=self.replans + other.replans,
            ladder_blocked=self.ladder_blocked + other.ladder_blocked,
            degraded_batches=self.degraded_batches + other.degraded_batches,
            straggler_events=self.straggler_events + other.straggler_events,
            restarts=self.restarts + other.restarts,
            refused_restores=self.refused_restores + other.refused_restores,
            checkpoint_stalls=self.checkpoint_stalls + other.checkpoint_stalls,
            checkpoint_stall_s=self.checkpoint_stall_s + other.checkpoint_stall_s,
            checkpoint_bytes=self.checkpoint_bytes + other.checkpoint_bytes,
        )

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["degraded_batches"] = [list(x) for x in self.degraded_batches]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunReport":
        d = dict(d)
        d["degraded_batches"] = tuple(
            tuple(int(v) for v in x) for x in d.get("degraded_batches", ())
        )
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def plan_footprint(
    caps: BatchCaps,
    sel_cap: int,
    hash_caps: Optional[HashCaps],
    *,
    r_bytes: int,
    max_nnz_a: int,
    max_nnz_b: int,
    reserved_bytes: int = 0,
) -> int:
    """Per-process bytes a capacity plan commits to, aligned with Alg. 3's
    budget: ``r`` bytes per stored entry of inputs + selection + the batch's
    stored intermediate (ESC/binned expansion scratch, or the hash table +
    merged survivors). The retry ladder prices cap doublings against this
    model, and the serving engine prices each admitted request with it.
    """
    if hash_caps is not None:
        inter = hash_caps.table_cap * HASH_SLOT_BYTES + r_bytes * caps.d_cap
    else:
        inter = r_bytes * caps.flops_cap
    return r_bytes * (max_nnz_a + max_nnz_b + sel_cap) + inter + reserved_bytes


class _LadderBlocked(Exception):
    """Raised inside the retry ladder when the next cap doubling would blow
    the per-process memory ceiling — caught by the degradation path, which
    replans the batch at finer batching instead of OOMing."""


@partial(jax.jit, static_argnames=("grid",))
def _merge_split_batches(parts: Tuple[DistSparse, ...], grid: Grid) -> DistSparse:
    """Column-concat ``d`` sub-batch products (finer plan ``nb·d``) back into
    ONE batch of the original ``nb``-batch plan.

    Block-cyclic algebra: original batch ``bi`` under plan ``nb`` covers the
    same global columns as batches ``{d·bi, …, d·bi+d−1}`` under plan
    ``nb·d``, and sub-batch ``d·bi+q``'s tile layer holds exactly slice
    ``q`` (width ``wbl/d``) of every original batch block — so the merge is
    an offset column concat + row-major resort. The merged entry set equals
    the undegraded batch's, so consumers see an identical product (only the
    static cap is the sum of the sub caps).
    """
    parts = tuple(parts)
    sub_w = parts[0].tile_shape[1]
    widths = [sub_w] * len(parts)
    cap = sum(p.cap for p in parts)
    tm = parts[0].tile_shape[0]
    wbl = sub_w * len(parts)

    def step(*tiles):
        mats = [_squeeze_tile(t) for t in tiles]
        merged, _ = hstack_remap(mats, widths, cap)  # cap = Σ caps: lossless
        merged = merged.sort_rowmajor()
        return (
            merged.rows[None, None, None], merged.cols[None, None, None],
            merged.vals[None, None, None], merged.nnz[None, None, None],
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    fn = shard_map(
        step, mesh=grid.mesh,
        in_specs=tuple(dist_spec(p, spec3) for p in parts),
        out_specs=(spec3,) * 4,
        check_vma=False,
    )
    rows, cols, vals, nnz = fn(*parts)
    c0 = parts[0]
    return DistSparse(
        rows=rows, cols=cols, vals=vals, nnz=nnz,
        shape=(c0.shape[0], c0.shape[1] * len(parts)),
        tile_shape=(tm, wbl), grid_shape=c0.grid_shape, kind="C",
    )


@dataclasses.dataclass
class BatchedResult:
    plan: BatchPlan
    num_retries: int
    consumed: list  # consumer outputs per batch
    binned: bool = False  # did the sparse local multiply run k-binned?
    binned_caps: Optional[BinnedCaps] = None  # the static BinnedCaps used
    local_path: str = "esc"  # local multiply actually executed
    hash_caps: Optional[HashCaps] = None  # the static HashCaps used (hash)
    report: RunReport = dataclasses.field(default_factory=RunReport)

    def floors(self) -> PlanFloors:
        """The capacities this run actually used, as a `PlanFloors` an
        iterated caller merges into its next plan — ONE field replaces the
        old caps/sel/nb/kbin/hash attribute quintet (pow2 quantization on,
        since that is the whole point of pinning)."""
        return PlanFloors(
            caps=self.plan.caps,
            sel_cap=self.plan.sel_cap,
            num_batches=self.plan.num_batches,
            kbin_caps=self.binned_caps,
            hash_caps=self.hash_caps,
            caps_pow2=True,
        )


def batched_summa3d(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    consumer: Callable[[int, object, np.ndarray], object],
    path: str = "sparse",
    semiring: sr.Semiring = sr.PLUS_TIMES,
    spec: Optional[PlanSpec] = None,
    floors: Optional[PlanFloors] = None,
    exec_spec: Optional[ExecSpec] = None,
    postprocess: Optional[Callable[[int, object], object]] = None,
    **legacy,
) -> BatchedResult:
    """Multiply A·B in batches; the consumer sees each batch then it's freed.

    The knob surface is three frozen specs: ``spec`` (`PlanSpec` — mask,
    local path, slack, reserved bytes, k-bin candidates), ``floors``
    (`PlanFloors` — cross-iteration capacity pins, fold a previous run's
    ``BatchedResult.floors()`` in via ``merged()``), and ``exec_spec``
    (`ExecSpec` — pipelined schedule, lookahead, retry budget, degradation).
    The old keyword surface (``slack=``, ``lookahead=``, ``caps_floor=``, …)
    is accepted for one release and mapped onto the specs with a
    ``DeprecationWarning``.

    consumer(batch_idx, c_batch, global_col_map) -> anything; c_batch is a
    DistSparse (path="sparse") or stacked dense tiles (path="dense").
    ``exec_spec.sorted_merge`` selects the segmented (merge-not-sort)
    Merge-Fiber in the per-batch sparse step.

    ``spec.mask`` runs the masked/filtered SpGEMM (§V-B): a C-layout
    ``DistSparse`` whose structure gates the output — consumers receive
    C ⊙ M (or C ⊙ ¬M under ``mask_complement=True``). The mask stays
    device-resident: the plan budgets only surviving entries (strict mode),
    and each batch's mask slice is selected + fiber-gathered inside the
    fused step. ``floors`` quantizes (``caps_pow2``) and floors the planned
    capacities (see ``plan_batches``) so iterated callers reuse one
    fused-step executable across iterations.

    ``postprocess(batch_idx, c_batch) -> c_batch'`` is the DEVICE-side
    per-batch hook (HipMCL integration, §V-C): a jitted transform applied to
    the raw batch product immediately after the fused SPMD step and BEFORE
    the host consumer — under the pipelined schedule it is dispatched
    together with the batch, so e.g. inflation+normalize+prune run on-grid
    while later batches are still multiplying, and only the postprocessed
    batch is ever offered to the host. The consumer then receives the hook's
    return value (which may be any pytree, e.g. ``(pruned, stats)``) in place
    of the raw batch. On an overflow retry the hook re-runs on the retried
    product. ``spec.reserved_bytes`` flows into ``plan_batches``:
    per-process memory already committed to the consumed outputs.

    ``exec_spec.pipelined=True`` (default) runs the Alg. 4 loop as a
    lookahead window: batch i+1..i+lookahead are dispatched before batch i's
    device-resident overflow flags are read, so selection/gather of the next
    batch overlaps the previous multiply and the consumer's host work
    overlaps device compute. A nonzero flag drops that batch to the
    synchronous retry loop (capacities ×2 per attempt — selection first,
    multiply second). ``pipelined=False`` is the serial schedule: one host
    sync per batch.

    ``exec_spec.binned`` switches the sparse local multiply to the k-binned
    paired kernel: "auto" uses it when the symbolic bin plan strictly
    reduces pairing work (and the semiring is plus_times); True forces it;
    False pins ESC. Consumers are always invoked in batch order.

    ``spec.local_path`` is the plan-driven 3-way dispatch over ESC /
    k-binned / hash-accumulator local multiplies: "auto" (default) lets the
    plan pick — hash when the compression factor clears
    ``HASH_CF_THRESHOLD`` (any semiring; the plan then budgets
    O(nnz_out·load_factor) resident bytes, so high-cf multiplies batch
    less), else the existing binned-vs-ESC choice; "hash"/"binned"/"esc"
    force a path. An explicit ``binned`` override (True/False) pins the
    classic two-way dispatch — back-compat for callers that predate the
    hash path. One ``local_path`` decision is made per plan (not per batch)
    so iterated runs keep ONE executable per path; ``floors.hash_caps``
    keeps its static caps monotone across iterations.

    ``exec_spec.degrade`` (default on) bounds the retry ladder at a
    per-process memory ceiling: when doubling the multiply caps would exceed
    ``max(per_process_memory, footprint(planned caps))`` — the planned-caps
    arm keeps legitimately-over-budget plans (slack, uncharged scratch)
    runnable while refusing runaway growth beyond them — the failing batch
    is REPLANNED at finer batching (its columns run as ``d`` sub-batches
    under a ``nb·d`` plan, then column-concat back to the original batch
    extent) instead of OOMing. Every retry/replan lands in the structured
    ``BatchedResult.report`` (`RunReport`). ``degrade=False`` restores the
    unbounded ladder.
    """
    spec, floors, ex = resolve_specs(
        spec, floors, exec_spec, legacy, default_local_path="auto",
        where="batched_summa3d",
    )
    placement = spec.placement
    if placement is not None and not isinstance(placement, Placement):
        raise ValueError(
            f"spec.placement must be a core.placement.Placement whose "
            f"permutations the operands ALREADY carry, got {placement!r} — "
            f"use placement.multiply_placed (or compute_placement + "
            f"apply_a/apply_b) to permute host operands before scattering"
        )
    if spec.distribution is not None and (
        getattr(spec.distribution, "name", None) != BLOCK_CYCLIC.name
    ):
        raise ValueError(
            f"the fused device step implements only the block-cyclic "
            f"distribution; got {spec.distribution!r}. Custom Distribution "
            f"objects are planner-side — price them via plan_from_symbolic."
        )
    r_bytes, slack = spec.r_bytes, spec.slack
    reserved_bytes = spec.reserved_bytes
    mask, mask_complement = spec.mask, spec.mask_complement
    local_path = spec.local_path
    pipelined = ex.pipelined
    max_retries, degrade = ex.max_retries, ex.degrade
    sorted_merge, binned = ex.sorted_merge, ex.binned
    kbin_caps_floor, caps_pow2 = floors.kbin_caps, floors.caps_pow2
    assert local_path in ("auto", "esc", "binned", "hash"), local_path
    # the plan only budgets the hash path when the driver could dispatch it:
    # an explicit binned override pins the classic O(flops) budget.
    plan_local_path = local_path
    if local_path == "auto" and (binned != "auto" or path != "sparse"):
        plan_local_path = "esc"
    plan = plan_batches(
        a, b, grid, per_process_memory,
        spec=spec.replace(local_path=plan_local_path), floors=floors,
    )
    nb = plan.num_batches
    n_cols = b.shape[1]

    use_hash = path == "sparse" and plan.local_path == "hash"
    if use_hash:
        use_binned = False
    elif local_path == "binned":
        use_binned = path == "sparse"
    elif local_path == "esc":
        use_binned = False
    elif binned == "auto":
        use_binned = (
            path == "sparse"
            and semiring.name == "plus_times"
            and plan.binned_profitable
        )
    else:
        use_binned = bool(binned) and path == "sparse"
    if use_binned and semiring.name != "plus_times":
        raise ValueError(
            f"k-binned local multiply requires plus_times, got {semiring.name}"
        )
    kb = (
        BinnedCaps(plan.kbin.num_bins, plan.kbin.bin_cap_a, plan.kbin.bin_cap_b)
        if use_binned else None
    )
    if kb is not None and caps_pow2:
        # same quantization as BatchCaps, for the same jit-cache reason
        kb = BinnedCaps(
            kb.num_bins, _rup_pow2(kb.bin_cap_a), _rup_pow2(kb.bin_cap_b)
        )
    if kb is not None and kbin_caps_floor is not None:
        assert kb.num_bins == kbin_caps_floor.num_bins, (
            "kbin_caps_floor requires a pinned bin count (kbin_candidates)"
        )
        kb = BinnedCaps(
            kb.num_bins,
            max(kb.bin_cap_a, kbin_caps_floor.bin_cap_a),
            max(kb.bin_cap_b, kbin_caps_floor.bin_cap_b),
        )
    bin_of_k = jnp.asarray(plan.kbin.bin_of_k) if use_binned else None
    hc = plan.hash_caps if use_hash else None
    if use_hash:
        assert hc is not None, "hash dispatch requires planned HashCaps"

    caps, sel_cap, mask_cap = plan.caps, plan.sel_cap, plan.mask_sel_cap
    retries = 0
    rep = {"sel_retries": 0, "replans": 0, "ladder_blocked": 0,
           "degraded": []}

    # --- bounded retry ladder (graceful degradation) -----------------------
    # The ceiling takes a max with the PLANNED caps' footprint
    # (`plan_footprint`): a plan is allowed to exceed the strict budget
    # (slack and uncharged scratch make that routine at tight budgets), but
    # the ladder may never grow beyond whichever is larger.
    max_nnz_a = int(np.asarray(a.nnz).max())
    max_nnz_b = int(np.asarray(b.nnz).max())

    def _footprint(caps_: BatchCaps, sel_cap_: int, hc_) -> int:
        return plan_footprint(
            caps_, sel_cap_, hc_, r_bytes=r_bytes, max_nnz_a=max_nnz_a,
            max_nnz_b=max_nnz_b, reserved_bytes=reserved_bytes,
        )

    ladder_ceiling = max(per_process_memory, _footprint(caps, sel_cap, hc))

    def dispatch(
        bi: int, caps_: BatchCaps, sel_cap_: int, kb_, hc_, mask_cap_: int
    ):
        """Async-dispatch one fused batch step; nothing blocks here."""
        return _fused_jit(
            a, b, jnp.int32(bi), bin_of_k, mask, grid=grid, num_batches=nb,
            sel_cap=sel_cap_, caps=caps_, semiring=semiring,
            sorted_merge=sorted_merge, path=path, kbin=kb_, hashc=hc_,
            mask_cap=mask_cap_, mask_complement=mask_complement,
        )

    # capacities actually used, including retry growth — reported on the
    # returned plan so iterated callers (MCL) floor their NEXT plan on
    # reality instead of replaying a known-too-small estimate every
    # iteration. Dispatch defaults stay at the planned values within this
    # run: the pipelined and serial schedules must remain batch-identical
    # (each batch's retry ladder grows from the same base).
    used = {"caps": caps, "sel": sel_cap, "kb": kb, "hashc": hc,
            "mask": mask_cap}

    def grow(
        o: np.ndarray, caps_: BatchCaps, sel_cap_: int, kb_, hc_,
        mask_cap_: int, record: bool = True,
    ):
        """Next capacity plan after an overflow: selection first (a truncated
        selection makes the multiply flags unreliable), multiply second.
        The mask-slice capacity is exact, but it is doubled alongside the
        multiply caps anyway so the retry ladder stays monotone.

        With ``degrade`` on, a multiply-cap doubling that would exceed the
        memory ceiling raises `_LadderBlocked` instead — the caller replans
        at finer batching. ``record=False`` (degraded sub-batches) skips the
        ``used``-floor bookkeeping: sub-plan caps live in a different static
        signature space than the reported plan."""
        if o[0] > 0:
            sel_cap_ = min(_rup8(max(sel_cap_ * 2, 8)), b.cap)
            rep["sel_retries"] += 1
        elif o[1] > 0:
            cand_caps = caps_.doubled()
            cand_hc = hc_.doubled() if hc_ is not None else None
            if degrade and _footprint(cand_caps, sel_cap_, cand_hc) > ladder_ceiling:
                rep["ladder_blocked"] += 1
                raise _LadderBlocked(
                    f"cap doubling to {cand_caps} exceeds the "
                    f"{ladder_ceiling}-byte ceiling"
                )
            caps_, hc_ = cand_caps, cand_hc
            kb_ = kb_.doubled() if kb_ is not None else None
            if mask is not None:
                mask_cap_ = min(mask_cap_ * 2, mask.cap)
        if not record:
            return caps_, sel_cap_, kb_, hc_, mask_cap_
        used["sel"] = max(used["sel"], sel_cap_)
        used["mask"] = max(used["mask"], mask_cap_)
        used["caps"] = BatchCaps(*(
            max(x, y) for x, y in zip(
                dataclasses.astuple(used["caps"]), dataclasses.astuple(caps_)
            )
        ))
        if kb_ is not None:
            used["kb"] = BinnedCaps(
                kb_.num_bins,
                max(used["kb"].bin_cap_a, kb_.bin_cap_a),
                max(used["kb"].bin_cap_b, kb_.bin_cap_b),
            )
        if hc_ is not None:
            used["hashc"] = HashCaps(
                table_cap=max(used["hashc"].table_cap, hc_.table_cap),
                chunk_cap=max(used["hashc"].chunk_cap, hc_.chunk_cap),
                num_chunks=max(used["hashc"].num_chunks, hc_.num_chunks),
                max_probes=max(used["hashc"].max_probes, hc_.max_probes),
            )
        return caps_, sel_cap_, kb_, hc_, mask_cap_

    def run_batch_sync(
        bi: int, caps_: BatchCaps, sel_cap_: int, kb_, hc_, mask_cap_: int,
        dispatch_fn=None, record: bool = True,
    ):
        """The kept, tested synchronous retry loop (§IV-A robustness)."""
        nonlocal retries
        dispatch_fn = dispatch_fn or dispatch
        for _ in range(max_retries + 1):
            c_batch, ovf = dispatch_fn(bi, caps_, sel_cap_, kb_, hc_, mask_cap_)
            o = np.asarray(ovf)
            if not o.any():
                return c_batch
            retries += 1
            caps_, sel_cap_, kb_, hc_, mask_cap_ = grow(
                o, caps_, sel_cap_, kb_, hc_, mask_cap_, record=record
            )
        raise RuntimeError(
            f"batch {bi}: capacity overflow persisted after {max_retries} retries"
        )

    def run_batch_degraded(bi: int):
        """Graceful degradation: batch ``bi``'s columns rerun as ``d``
        sub-batches under a finer ``nb·d`` plan (whose caps fit the budget by
        construction), then merge back to the original batch extent. Split
        factor doubles while a sub-batch still hits the ceiling; a split
        finer than the column structure allows surfaces as RuntimeError."""
        forced = "hash" if use_hash else ("binned" if use_binned else "esc")
        d = 2
        while True:
            try:
                # a fresh sub-plan: caller floors and bin pins do not apply
                # (sub-batch caps live in their own static-signature space)
                sub = plan_batches(
                    a, b, grid, per_process_memory,
                    spec=spec.replace(
                        local_path=forced, force_num_batches=nb * d,
                        kbin_candidates=None,
                    ),
                )
            except MemoryError as e:
                raise RuntimeError(
                    f"batch {bi}: memory ceiling hit and no finer batching "
                    f"fits (split {d}x): {e}"
                ) from e
            nb_f = sub.num_batches
            if nb_f % nb != 0:
                # divisibility rounding broke sub-batch alignment — go finer
                d = nb_f // nb + 1
                continue
            d_eff = nb_f // nb
            sub_kb = (
                BinnedCaps(sub.kbin.num_bins, sub.kbin.bin_cap_a,
                           sub.kbin.bin_cap_b)
                if use_binned else None
            )
            sub_bin = jnp.asarray(sub.kbin.bin_of_k) if use_binned else None
            sub_hc = sub.hash_caps if use_hash else None

            def sub_dispatch(sj, caps_, sel_cap_, kb_, hc_, mask_cap_):
                return _fused_jit(
                    a, b, jnp.int32(sj), sub_bin, mask, grid=grid,
                    num_batches=nb_f, sel_cap=sel_cap_, caps=caps_,
                    semiring=semiring, sorted_merge=sorted_merge, path=path,
                    kbin=kb_, hashc=hc_, mask_cap=mask_cap_,
                    mask_complement=mask_complement,
                )

            try:
                parts = [
                    run_batch_sync(
                        d_eff * bi + q, sub.caps, sub.sel_cap, sub_kb, sub_hc,
                        sub.mask_sel_cap, dispatch_fn=sub_dispatch,
                        record=False,
                    )
                    for q in range(d_eff)
                ]
            except _LadderBlocked:
                d = d_eff * 2  # a sub-batch still over budget: split finer
                continue
            rep["replans"] += 1
            rep["degraded"].append((bi, d_eff))
            if path == "dense":
                return jnp.concatenate(parts, axis=-1)
            return _merge_split_batches(tuple(parts), grid)

    def run_batch_guarded(
        bi: int, caps_: BatchCaps, sel_cap_: int, kb_, hc_, mask_cap_: int
    ):
        try:
            return run_batch_sync(bi, caps_, sel_cap_, kb_, hc_, mask_cap_)
        except _LadderBlocked:
            return run_batch_degraded(bi)

    consumed = []

    def post(bi: int, c_batch):
        """Apply the device-side hook (async — nothing blocks here)."""
        return postprocess(bi, c_batch) if postprocess is not None else c_batch

    def finish(bi: int, c_post, ovf) -> None:
        """Sync point: read batch bi's flags, retry if beaten, consume."""
        nonlocal retries
        o = np.asarray(ovf)
        if o.any():
            retries += 1
            # the speculatively postprocessed batch was built from a garbage
            # product — recompute synchronously and re-run the hook on it
            try:
                c_batch = run_batch_sync(
                    bi, *grow(o, caps, sel_cap, kb, hc, mask_cap)
                )
            except _LadderBlocked:
                c_batch = run_batch_degraded(bi)
            c_post = post(bi, c_batch)
        col_map = _col_map(bi)
        consumed.append(consumer(bi, c_post, col_map))

    def _col_map(bi: int) -> np.ndarray:
        col_map = batch_column_map(n_cols, grid, nb, bi)
        if placement is not None:
            # operands are permuted: hand consumers ORIGINAL column ids so
            # downstream reassembly never sees placement space (rows stay
            # permuted — multiply_placed inverts them after collection)
            col_map = placement.original_cols(col_map)
        return col_map

    if not pipelined:
        for bi in range(nb):
            c_batch = post(
                bi, run_batch_guarded(bi, caps, sel_cap, kb, hc, mask_cap)
            )
            consumed.append(consumer(bi, c_batch, _col_map(bi)))
    else:
        # deferred import: runtime.resilient imports this module (RunReport)
        from ..runtime.driver import LookaheadWindow

        window = LookaheadWindow.from_exec(ex, finish)
        for bi in range(nb):
            c_batch, ovf = dispatch(bi, caps, sel_cap, kb, hc, mask_cap)
            window.push(bi, post(bi, c_batch), ovf)
        window.drain()
    # report the capacities actually used (incl. any retry growth) so
    # iterated callers floor their next plan on reality, not the estimate
    plan = dataclasses.replace(
        plan, caps=used["caps"], sel_cap=used["sel"],
        mask_sel_cap=used["mask"], hash_caps=used["hashc"],
    )
    executed = "hash" if use_hash else ("binned" if use_binned else "esc")
    report = RunReport(
        retries=retries, sel_retries=rep["sel_retries"],
        replans=rep["replans"], ladder_blocked=rep["ladder_blocked"],
        degraded_batches=tuple(rep["degraded"]),
    )
    return BatchedResult(
        plan=plan, num_retries=retries, consumed=consumed, binned=use_binned,
        binned_caps=used["kb"], local_path=executed, hash_caps=used["hashc"],
        report=report,
    )
