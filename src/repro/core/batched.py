"""BatchedSUMMA3D (paper Alg. 4) + the distributed symbolic step (Alg. 3).

The driver mirrors the paper's phase structure, pipelined so the host stays
out of the per-batch loop (§IV-A: numeric batches stream through the
communicators once symbolic planning is done):

  1. SYMBOLIC3D: one communication-avoiding pass that computes per-process
     flops upper bounds. Instead of broadcasting tiles, it reduces A's
     per-column counts along grid rows (psum) and gathers them along grid
     columns — the paper's observation that the symbolic step has the same
     communicator structure but a far lighter payload (§IV-A, Fig. 8). The
     same pass also emits B's per-column entry counts (exact per-batch
     selection capacities — no heuristic, no spurious selection retries) and
     the per-k count vectors of the *gathered* operands, from which the
     k-bin plan for the paired local multiply is derived.
  2. Host-side batch planning: b from Alg. 3 line 12 (+ Eq. 2 lower-bound
     check), rounded up for block-cyclic divisibility; static capacities for
     the numeric pass derived from the symbolic per-column vectors; a
     ``KBinPlan`` sizing the k-binned local multiply. This is the paper's
     symbolic→numeric split — in JAX it also fixes the static shapes the
     compiler needs.
  3. Pipelined per-batch schedule: selection + multiply are FUSED into one
     jitted SPMD step (``summa3d.summa3d_fused_step``) whose batch index is
     a traced scalar — one executable for all batches. The driver dispatches
     batch i+1 (and up to ``lookahead`` more) before reading batch i's
     overflow flags, which stay device-resident; under async dispatch the
     next batch's selection and gathers overlap the previous multiply, and
     the consumer's host-side work overlaps device compute.
  4. A device-side ``postprocess`` hook transforms each batch product
     IMMEDIATELY after the fused step, before any host involvement — the
     HipMCL integration (§V-C): MCL fuses inflation + distributed column
     normalization + top-k pruning here, so the raw product never reaches
     the host. The host ``consumer`` then sees the hook's output (or the raw
     batch when no hook is set) and may store/discard it — C is never
     materialized whole unless asked. ``plan_batches(reserved_bytes=...)``
     lets such consumers charge their kept outputs against the per-process
     budget (memory-constrained consumption).

Overflow robustness: if a static capacity is exceeded (sparsity estimate
beaten by correlation structure), the flags come back nonzero and the driver
falls back to the synchronous retry loop for that batch — selection capacity
grows first, then the multiply capacities (2× per attempt) — bounded, logged,
and tested. ``pipelined=False`` keeps the fully synchronous schedule (one
host round-trip per batch), which doubles as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import semiring as sr
from ..compat import shard_map
from .distsparse import DistSparse, dist_spec
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .summa3d import (
    BatchCaps,
    BinnedCaps,
    _squeeze_tile,
    summa3d_dense_step,
    summa3d_fused_step,
    summa3d_sparse_step,
)
from .symbolic import (
    KBinPlan,
    batch_count,
    batch_count_lower_bound,
    batching_plan_columns,
    fold_block_cyclic,
    plan_k_bins,
    rup8 as _rup8,
)

# cached compiles: one per (grid, caps, semiring, tile-shape) combination —
# the batch index is a traced scalar so all batches share one executable.
_dense_jit = jax.jit(summa3d_dense_step, static_argnames=("grid", "semiring"))
_sparse_jit = jax.jit(
    summa3d_sparse_step,
    static_argnames=("grid", "caps", "semiring", "sorted_merge", "kbin"),
)
_fused_jit = jax.jit(
    summa3d_fused_step,
    static_argnames=(
        "grid", "num_batches", "sel_cap", "caps", "semiring", "sorted_merge",
        "path", "kbin",
    ),
)

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Distributed symbolic step (Alg. 3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SymbolicCounts:
    """Host-side output of the distributed symbolic pass (all numpy).

    Only count *vectors* ever travel (§IV-A, Fig. 8) — the same payload now
    also carries what the numeric pass needs to size selection buffers and
    the k-bin plan, so no extra communication round is spent on either.
    """

    percol: np.ndarray  # (pr, pc, l, tn_b) flops per local output column
    b_colcounts: np.ndarray  # (pr, pc, l, tn_b) B entries per local column
    a_kcounts: np.ndarray  # (pr, l, k_tot) per-k counts of gathered A
    b_kcounts: np.ndarray  # (pc, l, k_tot) per-k counts of gathered B


@partial(jax.jit, static_argnames=("grid",))
def _symbolic3d_jit(a: DistSparse, b: DistSparse, grid: Grid):
    """One jitted executable per (grid, operand-structure) — the shard_map is
    built inside the traced function, so re-running the planner hits the jit
    cache instead of rebuilding (and re-lowering) the SPMD program."""
    _, tn_b = b.tile_shape
    _, wl_a = a.tile_shape

    def step(a_t: DistSparse, b_t: DistSparse):
        a_loc = _squeeze_tile(a_t)
        b_loc = _squeeze_tile(b_t)
        # A col counts restricted to OUR row block, over the per-layer
        # contraction range, ordered by stage (matches _gather_A indexing)
        cc_local = a_loc.col_counts()  # (wl_a,)
        cc_full = lax.all_gather(cc_local, COL_AX).reshape(-1)  # (k_tot,)
        # every row block's count vector (needed because our B entries
        # contribute to every process in our grid column's row group)
        cc_all = lax.all_gather(cc_full, ROW_AX)  # (pr, k_tot)
        k_tot = cc_full.shape[0]
        cc_all_pad = jnp.concatenate(
            [cc_all, jnp.zeros((cc_all.shape[0], 1), jnp.int32)], axis=1
        )
        # B entries in OUR tile: contraction index = i_own*wl + local row
        # (matches _gather_B indexing)
        i_own = lax.axis_index(ROW_AX)
        valid = b_loc.valid_mask()
        k_idx = jnp.where(valid, b_loc.rows + i_own * wl_a, k_tot)
        contrib = cc_all_pad[:, k_idx]  # (pr, capB): per target row block
        contrib = jnp.where(valid[None, :], contrib, 0)
        segids = jnp.where(valid, b_loc.cols, tn_b)
        percol_all = jax.ops.segment_sum(
            contrib.T, segids, num_segments=tn_b + 1
        )[:tn_b].T  # (pr, tn_b): row i = our entries' contribution to block-row i
        # sum over the row group -> each process reads its own row
        percol_all = lax.psum(percol_all, ROW_AX)
        percol = percol_all[i_own]
        # extras for the numeric pass, free on the same communicators:
        # B per-column entry counts (exact selection capacities) and the
        # per-k counts of the gathered operands (k-bin plan input).
        bcc = b_loc.col_counts()  # (tn_b,)
        rc_local = b_loc.row_counts()  # (wl,)
        rc_full = lax.all_gather(rc_local, ROW_AX).reshape(-1)  # (k_tot,)
        return (
            percol[None, None, None],
            bcc[None, None, None],
            cc_full[None, None, None],
            rc_full[None, None, None],
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    in_specs = tuple(dist_spec(d, spec3) for d in (a, b))
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=in_specs,
        out_specs=(spec3, spec3, spec3, spec3),
        check_vma=False,
    )
    return fn(a, b)


def symbolic3d_counts(a: DistSparse, b: DistSparse, grid: Grid) -> SymbolicCounts:
    """Run the distributed symbolic step; see ``SymbolicCounts``."""
    percol, bcc, cc_full, rc_full = _symbolic3d_jit(a, b, grid)
    # cc_full is a function of (row block, layer) only; rc_full of
    # (col block, layer) only — slice the redundant grid axes away.
    return SymbolicCounts(
        percol=np.asarray(percol),
        b_colcounts=np.asarray(bcc),
        a_kcounts=np.asarray(cc_full)[:, 0],  # (pr, l, k_tot)
        b_kcounts=np.asarray(rc_full)[0],  # (pc, l, k_tot)
    )


def symbolic3d(a: DistSparse, b: DistSparse, grid: Grid) -> np.ndarray:
    """Per-(process, local column of B) flops upper bound.

    Returns host array of shape (pr, pc, l, tn_b):
      flops[i,j,k,c] = Σ_{t ∈ B(:, block j, layer k), col(t)=c}
                           nnz(A^(k)(row-block i, k_idx(t)))

    which is exactly the number of partial products process (i,j,k) forms for
    output column c in the numeric step (A gathered over the grid row, B over
    the grid column group). ``symbolic3d_counts`` exposes the fuller payload.
    """
    return symbolic3d_counts(a, b, grid).percol


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Host-side plan produced by the symbolic step."""

    num_batches: int
    lower_bound: int  # Eq. (2)
    caps: BatchCaps
    total_flops: int  # Σ multiply ops (global)
    max_unmerged_nnz: int  # max over processes, b=1
    per_batch_flops: np.ndarray  # (num_batches,) global flops per batch
    sel_cap: int = 0  # exact per-batch selection capacity (B entries)
    kbin: Optional[KBinPlan] = None  # k-bin plan for the paired local multiply

    @property
    def binned_profitable(self) -> bool:
        """Plan-driven switch: does k-binning strictly cut pairing work?

        Requires real bin structure (num_bins > 1): with a single bin the
        capacity-product baseline still shrinks (compaction drops padding),
        but there is no structural reduction to pay the binning pass for.
        """
        return (
            self.kbin is not None
            and self.kbin.num_bins > 1
            and self.kbin.pairings < self.kbin.pairings_unbinned
        )


def plan_batches(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    r_bytes: int = 12,
    slack: float = 1.3,
    force_num_batches: Optional[int] = None,
    reserved_bytes: int = 0,
) -> BatchPlan:
    """Run the symbolic step and derive b + static capacities (host math).

    ``reserved_bytes`` is subtracted from the per-process budget before the
    Alg. 3 batch count: memory the caller has already committed per process
    to the CONSUMED outputs (e.g. the pruned batches a memory-constrained MCL
    iteration keeps on-device for the next iterate, §V-C) — so the budget
    honors what actually lives alongside the unmerged batch results.
    """
    if reserved_bytes >= per_process_memory:
        raise MemoryError(
            f"reserved output bytes ({reserved_bytes}) exceed per-process "
            f"memory ({per_process_memory})"
        )
    per_process_memory = per_process_memory - reserved_bytes
    counts = symbolic3d_counts(a, b, grid)
    percol = counts.percol  # (pr, pc, l, tn_b)
    pr, pc, l, tn_b = percol.shape
    per_process_flops = percol.sum(axis=-1)  # (pr, pc, l)
    max_unmerged = int(per_process_flops.max())
    total_flops = int(per_process_flops.sum())
    max_nnz_a = int(np.asarray(a.nnz).max())
    max_nnz_b = int(np.asarray(b.nnz).max())

    if force_num_batches is not None:
        nb = force_num_batches
    else:
        nb = batch_count(
            max_unmerged, max_nnz_a, max_nnz_b, per_process_memory, r=r_bytes
        )
    nb = batching_plan_columns(tn_b, nb, l)

    # per-(process, batch, piece) flops via the block-cyclic fold
    flops_pbp = fold_block_cyclic(percol, nb, l)  # (pr,pc,l,nb,l)
    per_batch_proc = flops_pbp.sum(axis=-1)  # (pr,pc,l,nb)
    max_batch_flops = int(per_batch_proc.max())
    max_piece_flops = int(flops_pbp.max())
    # merged C piece bound: sum over source layers of that piece's flops
    merged_piece = flops_pbp.sum(axis=2).max()  # max over (pr,pc,batch,piece)

    tm_a = a.tile_shape[0]
    wb = tn_b // nb
    flops_cap = _rup8(max(int(max_batch_flops * slack), 64))
    d_cap = _rup8(min(flops_cap, tm_a * wb))
    piece_cap = _rup8(min(max(int(max_piece_flops * slack), 64), tm_a * (wb // l)))
    c_cap = _rup8(min(max(int(merged_piece * slack), 64), tm_a * (wb // l)))
    caps = BatchCaps(flops_cap=flops_cap, d_cap=d_cap, piece_cap=piece_cap, c_cap=c_cap)

    # exact per-batch selection capacity: max over (process, batch) of the
    # number of B entries the block-cyclic selection keeps — from the
    # symbolic B-column counts, so the first batch can never trigger a
    # spurious selection retry on skewed inputs.
    sel_per_batch = fold_block_cyclic(counts.b_colcounts, nb, l).sum(axis=-1)
    sel_cap = min(_rup8(max(int(sel_per_batch.max()), 8)), b.cap)

    # k-bin plan for the gathered pairing: per-k count vectors bounded
    # element-wise over (block, layer) so the static caps hold on every
    # process; gathered capacities are pc·capA / pr·sel_cap slots.
    kbin = plan_k_bins(
        counts.a_kcounts.max(axis=(0, 1)),
        counts.b_kcounts.max(axis=(0, 1)),
        pc * a.cap,
        pr * sel_cap,
    )

    # Eq. (2) lower bound (global memory form) for reporting/validation
    nnz_a = int(np.asarray(a.nnz).sum())
    nnz_b = int(np.asarray(b.nnz).sum())
    mem_c = r_bytes * int(per_process_flops.sum())
    try:
        lb = batch_count_lower_bound(
            mem_c, per_process_memory * grid.p, nnz_a, nnz_b, r=r_bytes
        )
    except MemoryError:
        lb = -1

    per_batch_flops = per_batch_proc.sum(axis=(0, 1, 2))  # (nb,)
    return BatchPlan(
        num_batches=nb,
        lower_bound=lb,
        caps=caps,
        total_flops=total_flops,
        max_unmerged_nnz=max_unmerged,
        per_batch_flops=per_batch_flops,
        sel_cap=sel_cap,
        kbin=kbin,
    )


def batch_column_map(n: int, grid: Grid, num_batches: int, batch: int) -> np.ndarray:
    """Global columns covered by ``batch``, in C-tile order.

    Returns g[j, k, c] of shape (pc, l, wb/l): the global column of local
    column c in C tile (:, j, k) for this batch. Inverse of the block-cyclic
    selection + fiber split.
    """
    pc, l = grid.pc, grid.l
    w = n // pc
    wb = w // num_batches
    wbl = w // (num_batches * l)
    out = np.zeros((pc, l, wb // l), np.int64)
    for j in range(pc):
        for k in range(l):
            for c in range(wb // l):
                # C tile layer k holds fiber piece k = D cols [k*wb/l,(k+1)*wb/l)
                d_col = k * (wb // l) + c
                # D batch cols remap: block t = d_col // wbl (t-th block of the
                # batch), within = d_col % wbl; original local block index =
                # t * num_batches + batch
                t = d_col // wbl
                within = d_col % wbl
                orig_local = (t * num_batches + batch) * wbl + within
                out[j, k, c] = j * w + orig_local
    return out


# ---------------------------------------------------------------------------
# The batched driver (Alg. 4) — pipelined scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchedResult:
    plan: BatchPlan
    num_retries: int
    consumed: list  # consumer outputs per batch
    binned: bool = False  # did the sparse local multiply run k-binned?


def batched_summa3d(
    a: DistSparse,
    b: DistSparse,
    grid: Grid,
    per_process_memory: int,
    consumer: Callable[[int, object, np.ndarray], object],
    path: str = "sparse",
    semiring: sr.Semiring = sr.PLUS_TIMES,
    r_bytes: int = 12,
    slack: float = 1.3,
    max_retries: int = 4,
    force_num_batches: Optional[int] = None,
    sorted_merge: bool = True,
    pipelined: bool = True,
    lookahead: int = 2,
    binned: object = "auto",
    postprocess: Optional[Callable[[int, object], object]] = None,
    reserved_bytes: int = 0,
) -> BatchedResult:
    """Multiply A·B in batches; the consumer sees each batch then it's freed.

    consumer(batch_idx, c_batch, global_col_map) -> anything; c_batch is a
    DistSparse (path="sparse") or stacked dense tiles (path="dense").
    ``sorted_merge`` selects the segmented (merge-not-sort) Merge-Fiber in
    the per-batch sparse step.

    ``postprocess(batch_idx, c_batch) -> c_batch'`` is the DEVICE-side
    per-batch hook (HipMCL integration, §V-C): a jitted transform applied to
    the raw batch product immediately after the fused SPMD step and BEFORE
    the host consumer — under the pipelined schedule it is dispatched
    together with the batch, so e.g. inflation+normalize+prune run on-grid
    while later batches are still multiplying, and only the postprocessed
    batch is ever offered to the host. The consumer then receives the hook's
    return value (which may be any pytree, e.g. ``(pruned, stats)``) in place
    of the raw batch. On an overflow retry the hook re-runs on the retried
    product. ``reserved_bytes`` flows into ``plan_batches``: per-process
    memory already committed to the consumed outputs.

    ``pipelined=True`` (default) runs the Alg. 4 loop as a lookahead window:
    batch i+1..i+lookahead are dispatched before batch i's device-resident
    overflow flags are read, so selection/gather of the next batch overlaps
    the previous multiply and the consumer's host work overlaps device
    compute. A nonzero flag drops that batch to the synchronous retry loop
    (capacities ×2 per attempt — selection first, multiply second).
    ``pipelined=False`` is the serial schedule: one host sync per batch.

    ``binned`` switches the sparse local multiply to the k-binned paired
    kernel: "auto" uses it when the symbolic bin plan strictly reduces
    pairing work (and the semiring is plus_times); True forces it; False
    pins ESC. Consumers are always invoked in batch order.
    """
    plan = plan_batches(
        a, b, grid, per_process_memory, r_bytes=r_bytes, slack=slack,
        force_num_batches=force_num_batches, reserved_bytes=reserved_bytes,
    )
    nb = plan.num_batches
    n_cols = b.shape[1]

    if binned == "auto":
        use_binned = (
            path == "sparse"
            and semiring.name == "plus_times"
            and plan.binned_profitable
        )
    else:
        use_binned = bool(binned) and path == "sparse"
    if use_binned and semiring.name != "plus_times":
        raise ValueError(
            f"k-binned local multiply requires plus_times, got {semiring.name}"
        )
    kb = (
        BinnedCaps(plan.kbin.num_bins, plan.kbin.bin_cap_a, plan.kbin.bin_cap_b)
        if use_binned else None
    )
    bin_of_k = jnp.asarray(plan.kbin.bin_of_k) if use_binned else None

    caps, sel_cap = plan.caps, plan.sel_cap
    retries = 0

    def dispatch(bi: int, caps_: BatchCaps, sel_cap_: int, kb_):
        """Async-dispatch one fused batch step; nothing blocks here."""
        return _fused_jit(
            a, b, jnp.int32(bi), bin_of_k, grid=grid, num_batches=nb,
            sel_cap=sel_cap_, caps=caps_, semiring=semiring,
            sorted_merge=sorted_merge, path=path, kbin=kb_,
        )

    def grow(o: np.ndarray, caps_: BatchCaps, sel_cap_: int, kb_):
        """Next capacity plan after an overflow: selection first (a truncated
        selection makes the multiply flags unreliable), multiply second."""
        if o[0] > 0:
            sel_cap_ = min(_rup8(max(sel_cap_ * 2, 8)), b.cap)
        elif o[1] > 0:
            caps_ = caps_.doubled()
            kb_ = kb_.doubled() if kb_ is not None else None
        return caps_, sel_cap_, kb_

    def run_batch_sync(bi: int, caps_: BatchCaps, sel_cap_: int, kb_):
        """The kept, tested synchronous retry loop (§IV-A robustness)."""
        nonlocal retries
        for _ in range(max_retries + 1):
            c_batch, ovf = dispatch(bi, caps_, sel_cap_, kb_)
            o = np.asarray(ovf)
            if not o.any():
                return c_batch
            retries += 1
            caps_, sel_cap_, kb_ = grow(o, caps_, sel_cap_, kb_)
        raise RuntimeError(
            f"batch {bi}: capacity overflow persisted after {max_retries} retries"
        )

    consumed = []

    def post(bi: int, c_batch):
        """Apply the device-side hook (async — nothing blocks here)."""
        return postprocess(bi, c_batch) if postprocess is not None else c_batch

    def finish(bi: int, c_post, ovf) -> None:
        """Sync point: read batch bi's flags, retry if beaten, consume."""
        nonlocal retries
        o = np.asarray(ovf)
        if o.any():
            retries += 1
            # the speculatively postprocessed batch was built from a garbage
            # product — recompute synchronously and re-run the hook on it
            c_post = post(bi, run_batch_sync(bi, *grow(o, caps, sel_cap, kb)))
        col_map = batch_column_map(n_cols, grid, nb, bi)
        consumed.append(consumer(bi, c_post, col_map))

    if not pipelined:
        for bi in range(nb):
            c_batch = post(bi, run_batch_sync(bi, caps, sel_cap, kb))
            col_map = batch_column_map(n_cols, grid, nb, bi)
            consumed.append(consumer(bi, c_batch, col_map))
    else:
        inflight = deque()
        for bi in range(nb):
            c_batch, ovf = dispatch(bi, caps, sel_cap, kb)
            inflight.append((bi, post(bi, c_batch), ovf))
            if len(inflight) > lookahead:
                finish(*inflight.popleft())
        while inflight:
            finish(*inflight.popleft())
    return BatchedResult(
        plan=plan, num_retries=retries, consumed=consumed, binned=use_binned
    )
