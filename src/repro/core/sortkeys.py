"""Packed-key sort/compress engine — the local SpGEMM hot path (paper §IV-D).

Every ESC compress, duplicate-coordinate merge, and symbolic nnz count in this
repo reduces to one primitive: *group entries by (row, col) and reduce their
values*. The seed implementation ran a full two-key ``jnp.lexsort`` for each of
those. This module packs the coordinate pair into a single monotonic i32 key

    key(row, col) = row * (n + 1) + col          (row-major; sentinel-aware)

so the grouping can run through one of three engines, picked per shape at
trace time:

  * ``"bucket"``  — sort-free occupancy scan: scatter a presence bit per key,
    prefix-sum the bucket table to rank the distinct keys, segment-reduce the
    values. O(cap + key_space) work, no sort at all. This is the TPU rendering
    of Nagasaka-style binned/hashed accumulation (arXiv:1804.01698): the packed
    key is a perfect hash and the bucket table is the accumulator. Used when
    the key space (m+1)(n+1) fits the table budget — exactly the narrow-tile
    regime the paper's batching (Alg. 4) creates.
  * ``"packed"``  — one single-key ``lax.sort`` carrying the values, then a
    linear boundary scan. O(cap log cap) but with a one-word comparator and no
    permutation gathers; the fallback when the key space is too large to scan.
  * ``"lexsort"`` — the seed's two-key lexsort path, kept verbatim as the
    reference for parity tests and for shapes whose packed key would overflow
    i32 (x64 is disabled under jax defaults).

``choose_engine`` implements the auto policy; all entry points accept an
``engine=`` override so benchmarks and tests can pin a path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

INT32_MAX = (1 << 31) - 1

#: Max bucket-table cells for the sort-free scan (i32 table; 4 MB at 1<<20).
BUCKET_SCAN_MAX = 1 << 22

#: Don't bother scanning a table more than this many times larger than cap.
BUCKET_SCAN_WASTE = 64


# ---------------------------------------------------------------------------
# key packing
# ---------------------------------------------------------------------------
def key_space(m: int, n: int) -> int:
    """Number of distinct packed keys incl. the (m, n) sentinel."""
    return (m + 1) * (n + 1)


def fits_i32(m: int, n: int) -> bool:
    return key_space(m, n) <= INT32_MAX


def pack_rowmajor(rows: Array, cols: Array, n: int) -> Array:
    """(row, col) -> row * (n+1) + col. Sentinel (m, n) maps to the max key."""
    return rows * jnp.int32(n + 1) + cols


def unpack_rowmajor(key: Array, n: int) -> Tuple[Array, Array]:
    return key // (n + 1), key % (n + 1)


def pack_colmajor(rows: Array, cols: Array, m: int) -> Array:
    """(row, col) -> col * (m+1) + row (CSC ordering)."""
    return cols * jnp.int32(m + 1) + rows


def unpack_colmajor(key: Array, m: int) -> Tuple[Array, Array]:
    return key % (m + 1), key // (m + 1)


def choose_engine(m: int, n: int, cap: int, engine: str = "auto") -> str:
    """Static (trace-time) engine policy. See module docstring."""
    if engine != "auto":
        assert engine in ("bucket", "packed", "lexsort"), engine
        return engine
    if not fits_i32(m, n):
        return "lexsort"
    ks = key_space(m, n)
    if ks <= BUCKET_SCAN_MAX and ks <= BUCKET_SCAN_WASTE * max(cap, 1):
        return "bucket"
    return "packed"


# ---------------------------------------------------------------------------
# value reduction into output slots (shared by all engines)
# ---------------------------------------------------------------------------
def _reduce_to_slots(vals: Array, seg: Array, new_cap: int, add_kind: str) -> Array:
    """Reduce vals by slot id ``seg``; slot new_cap is the discard bucket."""
    if add_kind == "sum":
        buf = jnp.zeros((new_cap + 1,), vals.dtype).at[seg].add(
            jnp.where(seg < new_cap, vals, 0)
        )
    elif add_kind == "min":
        buf = jnp.full((new_cap + 1,), jnp.inf, vals.dtype).at[seg].min(vals)
    elif add_kind == "max":
        buf = jnp.full((new_cap + 1,), -jnp.inf, vals.dtype).at[seg].max(vals)
    else:
        raise ValueError(f"unknown add_kind {add_kind}")
    return buf[:new_cap]


def _finalize(out_key, out_vals, total, new_cap, sent, dtype):
    nnz = jnp.minimum(total, new_cap).astype(jnp.int32)
    pad = jnp.arange(new_cap) >= nnz
    out_key = jnp.where(pad, sent, out_key)
    out_vals = jnp.where(pad, 0, out_vals).astype(dtype)
    overflow = (total - nnz).astype(jnp.int32)
    return out_key, out_vals, nnz, overflow


# ---------------------------------------------------------------------------
# engine bodies
# ---------------------------------------------------------------------------
def compress_sorted_keys(
    keys: Array, vals: Array, sent, new_cap: int, add_kind: str = "sum"
):
    """Compress an ascending-sorted key array (duplicates adjacent, sentinels
    last) into unique slots. Returns (out_keys, out_vals, nnz, overflow).

    This is the shared tail of the packed-sort engine and the segmented merge
    (whose inputs arrive already sorted — merge, don't re-sort).
    """
    cap = keys.shape[0]
    vmask = keys < sent
    new_key = jnp.ones((cap,), dtype=bool)
    if cap > 1:
        new_key = new_key.at[1:].set(keys[1:] != keys[:-1])
    new_key = new_key & vmask
    seg = jnp.cumsum(new_key.astype(jnp.int32)) - 1
    total = jnp.maximum(seg[-1] + 1, 0)
    seg = jnp.where(vmask & (seg < new_cap), seg, new_cap)
    out_key = jnp.full((new_cap + 1,), sent, jnp.int32).at[seg].min(keys)[:new_cap]
    out_vals = _reduce_to_slots(vals, seg, new_cap, add_kind)
    return _finalize(out_key, out_vals, total, new_cap, sent, vals.dtype)


def _coalesce_packed(key, vals, sent, new_cap, add_kind):
    key, vals = jax.lax.sort((key, vals), num_keys=1)
    return compress_sorted_keys(key, vals, sent, new_cap, add_kind)


def _coalesce_bucket(key, valid, vals, nbuckets, sent, new_cap, add_kind):
    """Sort-free: presence scatter + bucket-table prefix sum ranks the keys."""
    keyc = jnp.where(valid, key, nbuckets)  # discard bucket
    occ = jnp.zeros((nbuckets + 1,), jnp.int32).at[keyc].max(1)[:nbuckets]
    slot_of_bucket = jnp.cumsum(occ) - 1  # rank among occupied, sorted order
    total = jnp.maximum(slot_of_bucket[-1] + 1, 0)
    slot = slot_of_bucket[jnp.clip(keyc, 0, nbuckets - 1)]
    seg = jnp.where(valid & (slot < new_cap), slot, new_cap)
    out_vals = _reduce_to_slots(vals, seg, new_cap, add_kind)
    bdest = jnp.where((occ > 0) & (slot_of_bucket < new_cap), slot_of_bucket, new_cap)
    out_key = jnp.full((new_cap + 1,), sent, jnp.int32).at[bdest].min(
        jnp.arange(nbuckets, dtype=jnp.int32)
    )[:new_cap]
    return _finalize(out_key, out_vals, total, new_cap, sent, vals.dtype)


def _coalesce_lexsort(rows, cols, vals, valid, m, n, new_cap, add_kind):
    """The seed's two-key path, preserved as the parity reference."""
    cap = rows.shape[0]
    rows = jnp.where(valid, rows, m)
    cols = jnp.where(valid, cols, n)
    order = jnp.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = vals[order]
    vmask = rows < m
    new_key = jnp.ones((cap,), dtype=bool)
    if cap > 1:
        same = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        new_key = new_key.at[1:].set(~same)
    new_key = new_key & vmask
    seg = jnp.cumsum(new_key.astype(jnp.int32)) - 1
    total = jnp.maximum(seg[-1] + 1, 0)
    seg = jnp.where(vmask & (seg < new_cap), seg, new_cap)
    out_rows = jnp.full((new_cap + 1,), m, jnp.int32).at[seg].min(rows)[:new_cap]
    out_cols = jnp.full((new_cap + 1,), n, jnp.int32).at[seg].min(cols)[:new_cap]
    out_vals = _reduce_to_slots(vals, seg, new_cap, add_kind)
    nnz = jnp.minimum(total, new_cap).astype(jnp.int32)
    pad = jnp.arange(new_cap) >= nnz
    out_rows = jnp.where(pad, m, out_rows)
    out_cols = jnp.where(pad, n, out_cols)
    out_vals = jnp.where(pad, 0, out_vals).astype(vals.dtype)
    overflow = (total - nnz).astype(jnp.int32)
    return out_rows, out_cols, out_vals, nnz, overflow


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def coalesce_entries(
    rows: Array,
    cols: Array,
    vals: Array,
    valid: Array,
    shape: Tuple[int, int],
    new_cap: int,
    add_kind: str = "sum",
    engine: str = "auto",
):
    """Group duplicate (row, col) coords among ``valid`` entries, reduce values
    by ``add_kind``, and emit row-major sorted entries with (m, n)-sentinel
    padding. Returns (rows, cols, vals, nnz, overflow)."""
    m, n = shape
    eng = choose_engine(m, n, rows.shape[0], engine)
    if eng == "lexsort":
        return _coalesce_lexsort(rows, cols, vals, valid, m, n, new_cap, add_kind)
    sent = jnp.int32(key_space(m, n) - 1)  # == pack(m, n)
    key = jnp.where(valid, pack_rowmajor(rows, cols, n), sent)
    if eng == "bucket":
        okey, ovals, nnz, ovf = _coalesce_bucket(
            key, valid, vals, key_space(m, n), sent, new_cap, add_kind
        )
    else:
        okey, ovals, nnz, ovf = _coalesce_packed(key, vals, sent, new_cap, add_kind)
    out_rows, out_cols = unpack_rowmajor(okey, n)
    return out_rows, out_cols, ovals, nnz, ovf


def count_unique(
    rows: Array, cols: Array, valid: Array, shape: Tuple[int, int],
    engine: str = "auto",
) -> Array:
    """Number of distinct valid (row, col) coords — the symbolic exact-nnz
    count, without forming values. Bucket engine needs no sort at all; packed
    engine sorts a single key array (no payload)."""
    m, n = shape
    eng = choose_engine(m, n, rows.shape[0], engine)
    if eng == "lexsort":
        r = jnp.where(valid, rows, m)
        c = jnp.where(valid, cols, n)
        order = jnp.lexsort((c, r))
        r, c = r[order], c[order]
        vmask = r < m
        cap = r.shape[0]
        new_key = jnp.ones((cap,), dtype=bool)
        if cap > 1:
            same = (r[1:] == r[:-1]) & (c[1:] == c[:-1])
            new_key = new_key.at[1:].set(~same)
        return jnp.sum(new_key & vmask).astype(jnp.int32)
    nb = key_space(m, n)
    sent = jnp.int32(nb - 1)
    key = jnp.where(valid, pack_rowmajor(rows, cols, n), sent)
    if eng == "bucket":
        keyc = jnp.where(valid, key, nb)
        occ = jnp.zeros((nb + 1,), jnp.int32).at[keyc].max(1)[:nb]
        return jnp.sum(occ).astype(jnp.int32)
    (skey,) = jax.lax.sort((key,), num_keys=1)
    cap = skey.shape[0]
    new_key = jnp.ones((cap,), dtype=bool)
    if cap > 1:
        new_key = new_key.at[1:].set(skey[1:] != skey[:-1])
    return jnp.sum(new_key & (skey < sent)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# membership against a sorted key set (masked SpGEMM, paper §V-B semantics)
# ---------------------------------------------------------------------------
def keys_in_sorted(keys: Array, sorted_keys: Array) -> Array:
    """bool[cap]: is ``keys[e]`` present in the ascending ``sorted_keys``?

    One ``searchsorted`` + gather — the packed-key rendering of a masked
    (filtered-semiring) SpGEMM: C's candidate coordinates are intersected
    against the mask's key set BEFORE the compress, so non-mask partial
    products never occupy output capacity. Sentinel padding in
    ``sorted_keys`` (max key) can only match a sentinel query, which callers
    already exclude via their ``valid`` mask.
    """
    cap = sorted_keys.shape[0]
    pos = jnp.searchsorted(sorted_keys, keys, side="left").astype(jnp.int32)
    return sorted_keys[jnp.clip(pos, 0, cap - 1)] == keys


def sorted_mask_keys(rows: Array, cols: Array, valid: Array, shape) -> Array:
    """Pack a mask's (row, col) coordinates and sort them ascending — the
    one-time (per batch) preparation for ``keys_in_sorted`` lookups. Padding
    maps to the max (sentinel) key and sorts to the tail."""
    m, n = shape
    assert fits_i32(m, n), (
        f"masked SpGEMM needs an i32-packable key space, got {m}x{n} "
        f"(x64 packed keys are a roadmap follow-up)"
    )
    sent = jnp.int32(key_space(m, n) - 1)
    key = jnp.where(valid, pack_rowmajor(rows, cols, n), sent)
    (skey,) = jax.lax.sort((key,), num_keys=1)
    return skey


# ---------------------------------------------------------------------------
# segmented merge of already-sorted runs (Merge-Fiber fast path)
# ---------------------------------------------------------------------------
def merge_two_sorted(
    keys_a: Array, vals_a: Array, keys_b: Array, vals_b: Array
) -> Tuple[Array, Array]:
    """Merge two ascending key runs (merge-path via ranks): each element's
    output position is its own index plus its rank in the other run. Stable
    across runs (ties: run A first); O((|a|+|b|) log) with no full sort."""
    pa, pb = keys_a.shape[0], keys_b.shape[0]
    pos_a = jnp.arange(pa, dtype=jnp.int32) + jnp.searchsorted(
        keys_b, keys_a, side="left"
    ).astype(jnp.int32)
    pos_b = jnp.arange(pb, dtype=jnp.int32) + jnp.searchsorted(
        keys_a, keys_b, side="right"
    ).astype(jnp.int32)
    out_k = (
        jnp.zeros((pa + pb,), keys_a.dtype).at[pos_a].set(keys_a).at[pos_b].set(keys_b)
    )
    out_v = (
        jnp.zeros((pa + pb,), vals_a.dtype).at[pos_a].set(vals_a).at[pos_b].set(vals_b)
    )
    return out_k, out_v


def merge_sorted_runs(keys_list, vals_list) -> Tuple[Array, Array]:
    """k-way merge of sorted runs by pairwise tree reduction (ceil(log2 k)
    rounds). Sentinel keys (max) stay at the tail throughout."""
    runs = list(zip(keys_list, vals_list))
    assert runs, "need at least one run"
    while len(runs) > 1:
        nxt = []
        for i in range(0, len(runs) - 1, 2):
            (ka, va), (kb, vb) = runs[i], runs[i + 1]
            nxt.append(merge_two_sorted(ka, va, kb, vb))
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]
