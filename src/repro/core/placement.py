"""Structure-aware placement: pluggable distributions + permutation passes.

The paper's block-cyclic distribution (Fig. 1(i)) treats every nonzero
alike; hypergraph-partitioning work (Ballard et al., PAPERS.md) shows the
communication volume drops when the data distribution follows the sparsity
structure. This module adds both halves of that direction:

  * ``Distribution`` — the tile→batch distribution as a pluggable object.
    The planner (``batched.plan_from_symbolic``) routes every fold/round
    call through ``PlanSpec.distribution`` (default: the ``BLOCK_CYCLIC``
    singleton, bit-for-bit the old ``symbolic.fold_block_cyclic`` math), so
    hypergraph-quality distributions can slot in later without touching the
    planner. Only block-cyclic is device-executable today — the fused step's
    ``SparseCOO.select_cols_blockcyclic`` hardcodes it — so
    ``batched_summa3d`` rejects other distributions at the door.

  * ``Placement`` — a (row, contraction, column) permutation computed from
    the same per-row/column counts the symbolic pass already extracts
    (degree-spread and reverse-Cuthill–McKee orderings first, pluggable
    like the distributions). Operands are permuted BEFORE ``plan_batches``
    — so every aligned block-cyclic block sees a uniform degree mixture and
    the capacity-padded transfers (selection gather at ``sel_cap``, fiber
    all_to_all at ``piece_cap``) shrink on skewed inputs — and the output
    is mapped back through the inverse permutations, so the result is
    identical to the unpermuted run (property-tested across semirings,
    masks, and all three local paths).

Degree-SPREAD, not degree-sort: sorting by degree concentrates the R-MAT
hubs into one aligned block (strictly worse maxima). The heavy columns are
instead dealt onto bit-reversed positions (power-of-two sizes) or
golden-ratio low-discrepancy positions, so consecutive hubs land in
different blocks of every (batch, layer) split the planner might choose.

``multiply_placed`` is the end-to-end entry: permute → scatter →
``batched_summa3d`` → invert, returning global host triplets.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Tuple

import numpy as np

from .sparse import from_numpy_coo
from .symbolic import batching_plan_columns, fold_block_cyclic


def _host_triplets(a):
    """(rows, cols, vals) of the live entries of a host COO (duck-typed)."""
    nnz = int(a.nnz)
    return (
        np.asarray(a.rows[:nnz]).astype(np.int64),
        np.asarray(a.cols[:nnz]).astype(np.int64),
        np.asarray(a.vals[:nnz]),
    )


# ---------------------------------------------------------------------------
# Pluggable tile→batch distributions
# ---------------------------------------------------------------------------
class Distribution:
    """Contract for a tile→batch column distribution (planner-side math).

    A distribution decides how the ``n`` local B/C columns split into
    ``num_batches × num_layers`` pieces — every capacity the planner derives
    is a fold of per-column count vectors through this object, and every
    consumer-facing column map is its inverse. Implementations must keep
    ``fold``/``batch_column_map`` consistent: ``fold`` sums exactly the
    columns ``batch_column_map`` reports for each (batch, piece).
    """

    name: str = "abstract"

    def round_batches(self, n: int, num_batches: int, num_layers: int) -> int:
        """Smallest feasible batch count >= ``num_batches`` for n columns."""
        raise NotImplementedError

    def fold(
        self, percol: np.ndarray, num_batches: int, num_layers: int
    ) -> np.ndarray:
        """Fold (..., n) per-column vectors into (..., batch, piece) sums."""
        raise NotImplementedError

    def fold_batch_slices(
        self, colcounts: np.ndarray, num_batches: int
    ) -> np.ndarray:
        """Fold (..., wl) C-layout per-column counts into (..., batch) sums
        — the mask-slice selection each batch performs on C-layout tiles."""
        raise NotImplementedError

    def batch_column_map(
        self, n: int, pc: int, num_layers: int, num_batches: int, batch: int
    ) -> np.ndarray:
        """(pc, l, wb/l) global column of each C-tile local column."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class BlockCyclicDistribution(Distribution):
    """The paper's Fig. 1(i) block-cyclic split — the device-executable
    default (``SparseCOO.select_cols_blockcyclic`` implements the same
    mapping in the fused step). Block ``t`` of width ``n/(b·l)`` belongs to
    batch ``t % b`` and fiber piece ``t // b``; the planner-side folds
    delegate to ``symbolic.fold_block_cyclic`` / ``batching_plan_columns``
    so the pluggable default stays bit-identical to the historical math
    (contract-tested)."""

    name: str = "block_cyclic"

    def round_batches(self, n: int, num_batches: int, num_layers: int) -> int:
        return batching_plan_columns(n, num_batches, num_layers)

    def fold(
        self, percol: np.ndarray, num_batches: int, num_layers: int
    ) -> np.ndarray:
        return fold_block_cyclic(percol, num_batches, num_layers)

    def fold_batch_slices(
        self, colcounts: np.ndarray, num_batches: int
    ) -> np.ndarray:
        *lead, wl = colcounts.shape
        wbl = wl // num_batches
        assert wbl * num_batches == wl, (wl, num_batches)
        return colcounts.reshape(*lead, num_batches, wbl).sum(axis=-1)

    def batch_column_map(
        self, n: int, pc: int, num_layers: int, num_batches: int, batch: int
    ) -> np.ndarray:
        l = num_layers
        w = n // pc
        wb = w // num_batches
        wbl = w // (num_batches * l)
        # C tile layer k holds fiber piece k = D cols [k·wb/l, (k+1)·wb/l);
        # D batch col d_col sits in block t = d_col // wbl at offset
        # d_col % wbl, and block t is the (t·b + batch)-th original block.
        k = np.arange(l, dtype=np.int64)[:, None]
        c = np.arange(wb // l, dtype=np.int64)[None, :]
        d_col = k * (wb // l) + c
        orig_local = (d_col // wbl * num_batches + batch) * wbl + d_col % wbl
        j = np.arange(pc, dtype=np.int64)[:, None, None]
        return j * w + orig_local[None]


#: planner default — `PlanSpec.distribution=None` resolves to this singleton
BLOCK_CYCLIC = BlockCyclicDistribution()


# ---------------------------------------------------------------------------
# Permutation passes
# ---------------------------------------------------------------------------
def _invert(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0], dtype=perm.dtype)
    return inv


def _spread_positions(n: int) -> np.ndarray:
    """A low-discrepancy permutation of ``range(n)``: consecutive ranks land
    far apart, so dealing a degree-sorted order onto these positions gives
    every aligned block (any width dividing n) a uniform degree mixture.
    Power-of-two sizes use bit reversal; others the golden-ratio sequence."""
    if n > 0 and n & (n - 1) == 0:
        bits = n.bit_length() - 1
        pos = np.arange(n, dtype=np.int64)
        rev = np.zeros(n, np.int64)
        for i in range(bits):
            rev |= ((pos >> i) & 1) << (bits - 1 - i)
        return rev
    phi = (np.sqrt(5.0) - 1.0) / 2.0
    frac = (np.arange(n, dtype=np.float64) * phi) % 1.0
    rank = np.empty(n, np.int64)
    rank[np.argsort(frac, kind="stable")] = np.arange(n, dtype=np.int64)
    return rank


def _degree_spread_perm(counts: np.ndarray) -> np.ndarray:
    """new_index = perm[old_index]: heaviest indices first, dealt onto
    spread positions (NOT packed together — see module docstring)."""
    n = counts.shape[0]
    order = np.argsort(-np.asarray(counts, np.int64), kind="stable")
    perm = np.empty(n, np.int64)
    perm[order] = _spread_positions(n)
    return perm


def _rcm_order(n: int, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Reverse Cuthill–McKee over the symmetrized pattern: BFS from a
    minimum-degree vertex, neighbors visited in increasing-degree order,
    result reversed — the classic cheap bandwidth-reducing ordering."""
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    keep = r != c
    key = np.unique(r[keep] * n + c[keep])
    r, c = key // n, key % n  # grouped by row, neighbor cols ascending
    deg = np.bincount(r, minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    indptr[1:] = np.cumsum(deg)
    order = np.empty(n, np.int64)
    visited = np.zeros(n, bool)
    pos = 0
    q = deque()
    for s in np.argsort(deg, kind="stable"):
        if visited[s]:
            continue
        visited[s] = True
        q.append(int(s))
        while q:
            v = q.popleft()
            order[pos] = v
            pos += 1
            nbrs = c[indptr[v]:indptr[v + 1]]
            nbrs = nbrs[~visited[nbrs]]
            nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
            visited[nbrs] = True
            q.extend(int(x) for x in nbrs)
    return order[::-1].copy()


@dataclasses.dataclass(eq=False)
class Placement:
    """A (row, contraction, column) permutation triple, ``new = perm[old]``.

    ``apply_a``/``apply_b``/``apply_mask`` permute host COO operands into
    placement space (A: rows by ``row_perm``, cols by ``k_perm``; B: rows by
    ``k_perm``, cols by ``col_perm``; mask: C layout); ``original_rows`` /
    ``original_cols`` map result coordinates back. ``eq=False``: the object
    hashes by identity so it can ride the frozen ``PlanSpec``.
    """

    strategy: str
    row_perm: np.ndarray  # (m,)
    k_perm: np.ndarray  # (k,)
    col_perm: np.ndarray  # (n,)

    def __post_init__(self):
        self.row_inv = _invert(np.asarray(self.row_perm, np.int64))
        self.k_inv = _invert(np.asarray(self.k_perm, np.int64))
        self.col_inv = _invert(np.asarray(self.col_perm, np.int64))

    @classmethod
    def identity(cls, m: int, k: int, n: int) -> "Placement":
        ar = np.arange
        return cls("identity", ar(m, dtype=np.int64), ar(k, dtype=np.int64),
                   ar(n, dtype=np.int64))

    @property
    def is_identity(self) -> bool:
        return all(
            np.array_equal(p, np.arange(p.shape[0]))
            for p in (self.row_perm, self.k_perm, self.col_perm)
        )

    def apply_a(self, a):
        rows, cols, vals = _host_triplets(a)
        return from_numpy_coo(
            self.row_perm[rows], self.k_perm[cols], vals, a.shape, cap=a.cap
        )

    def apply_b(self, b):
        rows, cols, vals = _host_triplets(b)
        return from_numpy_coo(
            self.k_perm[rows], self.col_perm[cols], vals, b.shape, cap=b.cap
        )

    def apply_mask(self, mask):
        rows, cols, vals = _host_triplets(mask)
        return from_numpy_coo(
            self.row_perm[rows], self.col_perm[cols], vals, mask.shape,
            cap=mask.cap,
        )

    def original_rows(self, rows) -> np.ndarray:
        """Map permuted global row coordinates back to the original ones."""
        return self.row_inv[np.asarray(rows)]

    def original_cols(self, cols) -> np.ndarray:
        return self.col_inv[np.asarray(cols)]


def compute_placement(a, b, strategy: str = "degree", mask=None) -> Placement:
    """Compute a :class:`Placement` for ``a @ b`` from structure alone.

    Strategies (pluggable — hypergraph-quality orderings slot in as new
    names): ``"identity"`` (no-op), ``"degree"`` (degree-spread each of the
    three index spaces independently from exact per-row/column counts — the
    same count vectors the symbolic pass extracts), ``"rcm"`` (reverse
    Cuthill–McKee over A's symmetrized pattern, square operands only, one
    shared ordering for rows/contraction/columns). ``mask`` counts are
    folded into the column degrees when given, so a masked multiply spreads
    the surviving structure, not the raw product's.
    """
    m, k = a.shape
    k_b, n = b.shape
    assert k == k_b, (a.shape, b.shape)
    if strategy == "identity":
        return Placement.identity(m, k, n)
    ar, ac, _ = _host_triplets(a)
    br, bc, _ = _host_triplets(b)
    if strategy == "degree":
        col_deg = np.bincount(bc, minlength=n)
        if mask is not None:
            mr, mc, _ = _host_triplets(mask)
            col_deg = col_deg + np.bincount(mc, minlength=n)
        return Placement(
            strategy="degree",
            row_perm=_degree_spread_perm(np.bincount(ar, minlength=m)),
            k_perm=_degree_spread_perm(
                np.bincount(ac, minlength=k) + np.bincount(br, minlength=k)
            ),
            col_perm=_degree_spread_perm(col_deg),
        )
    if strategy == "rcm":
        if not (m == k == n):
            raise ValueError(
                f"rcm placement needs square aligned operands, got "
                f"{a.shape} x {b.shape}"
            )
        order = _rcm_order(n, ar, ac)
        perm = np.empty(n, np.int64)
        perm[order] = np.arange(n, dtype=np.int64)
        return Placement(
            strategy="rcm", row_perm=perm, k_perm=perm.copy(),
            col_perm=perm.copy(),
        )
    raise ValueError(
        f"unknown placement strategy {strategy!r} "
        f"(known: identity, degree, rcm)"
    )


# ---------------------------------------------------------------------------
# End-to-end placed multiply
# ---------------------------------------------------------------------------
def _batch_to_global(c, col_map) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side reassembly of one sparse C batch into global coordinates
    (local twin of the mcl helper — core must not import sparse_apps)."""
    pr, pc, l = c.grid_shape
    tm, _ = c.tile_shape
    R = np.asarray(c.rows)
    C = np.asarray(c.cols)
    V = np.asarray(c.vals)
    N = np.asarray(c.nnz)
    cap = R.shape[-1]
    valid = np.arange(cap)[None, None, None, :] < N[..., None]
    i, j, k, s = np.nonzero(valid)
    return i * tm + R[i, j, k, s], col_map[j, k, C[i, j, k, s]], V[i, j, k, s]


@dataclasses.dataclass
class PlacedResult:
    """Global host COO triplets of a placed multiply, row-major sorted, in
    ORIGINAL (unpermuted) coordinates. Entry coordinates are unique (the
    driver merges within batches; batches and tiles cover disjoint output
    regions), so ``to_dense`` assigns rather than accumulates — exact for
    every semiring."""

    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    shape: Tuple[int, int]
    placement: Placement
    result: object  # the BatchedResult of the underlying driver run

    def to_dense(self, fill: float = 0.0) -> np.ndarray:
        out = np.full(self.shape, fill, dtype=self.vals.dtype)
        out[self.rows, self.cols] = self.vals
        return out


def multiply_placed(
    a,
    b,
    grid,
    per_process_memory: int,
    *,
    strategy: str = "degree",
    placement: Optional[Placement] = None,
    mask=None,
    semiring=None,
    spec=None,
    floors=None,
    exec_spec=None,
) -> PlacedResult:
    """Permute → scatter → ``batched_summa3d`` → invert, in one call.

    ``a``/``b`` (and optional ``mask``) are HOST matrices; ``placement``
    overrides the computed ordering (pass ``Placement.identity(...)`` for
    the baseline run of an A/B comparison). The driver sees the permuted
    operands with ``spec.placement`` set, so the per-batch column maps it
    hands the consumer are already in original column space; this wrapper
    additionally inverts the row coordinates and returns row-major-sorted
    global triplets — identical to an unpermuted multiply's.
    """
    from . import semiring as sr  # deferred: keep import-light module top
    from .batched import batched_summa3d
    from .distsparse import scatter_to_grid
    from .specs import PlanSpec

    semiring = semiring if semiring is not None else sr.PLUS_TIMES
    if placement is None:
        placement = compute_placement(a, b, strategy=strategy, mask=mask)
    A = scatter_to_grid(placement.apply_a(a), grid, "A")
    B = scatter_to_grid(placement.apply_b(b), grid, "B")
    M = (
        scatter_to_grid(placement.apply_mask(mask), grid, "C")
        if mask is not None else None
    )
    spec = (spec if spec is not None else PlanSpec()).replace(
        mask=M, placement=placement
    )

    pieces = []

    def consumer(bi, c_batch, col_map):
        pieces.append(_batch_to_global(c_batch, col_map))
        return None

    res = batched_summa3d(
        A, B, grid, per_process_memory, consumer, path="sparse",
        semiring=semiring, spec=spec, floors=floors, exec_spec=exec_spec,
    )
    rows = placement.original_rows(np.concatenate([p[0] for p in pieces]))
    cols = np.concatenate([p[1] for p in pieces])  # driver already inverted
    vals = np.concatenate([p[2] for p in pieces])
    order = np.lexsort((cols, rows))
    return PlacedResult(
        rows=rows[order], cols=cols[order], vals=vals[order],
        shape=(a.shape[0], b.shape[1]), placement=placement, result=res,
    )
