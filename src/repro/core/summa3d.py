"""SUMMA2D/3D sparse multiply on the grid mesh (paper Alg. 1 + Alg. 2).

One shard_map'd step computes a full 3D multiply for one batch:

  1. A-Broadcast / B-Broadcast (Alg. 1 lines 5-6): realized as
     ``lax.all_gather`` along the grid row/column axes — the bulk equivalent
     of the per-stage broadcasts (same α-β bandwidth: every tile traverses
     its communicator once; see benchmarks/bench_comm_model.py for the
     Table II reconciliation). Because the contraction ranges of the
     gathered stage tiles are disjoint, all `pc` stages fuse into ONE local
     multiply over the concatenated entry lists (contraction index =
     stage * (w/l) + local index) — Local-Multiply and Merge-Layer collapse
     into the same sort-free accumulation, which is the TPU rendering of the
     paper's "merge once after all stages" observation (§III-A).
  2. Local-Multiply (Alg. 1 line 7): dense-accumulator path (spmm into a
     dense D tile — identity-hash accumulator) or sparse path with a
     plan-driven switch between ESC (expand-sort-compress, any semiring) and
     the k-binned paired kernel (``local_spgemm.spgemm_kbinned``: pair only
     matching contraction bins — O(Σ_g capA_g×capB_g) pairings instead of
     O(capA×capB); the symbolic step emits the bin plan from the count
     vectors it already moves).
  3. AllToAll-Fiber + Merge-Fiber (Alg. 2 lines 4-6): dense path lowers the
     pair to ONE ``lax.psum_scatter`` over the layer axis (all-to-all + local
     add is exactly reduce-scatter); sparse path runs ColSplit as a single
     partitioned, order-preserving split into all l pieces, then the literal
     ``lax.all_to_all`` followed by a sort-free (segmented, merge-not-sort)
     merge.

``summa3d_fused_step`` additionally fuses the batch's block-cyclic column
selection into the same SPMD program with the batch index as a traced scalar:
one executable serves every batch, and the pipelined driver
(``batched.batched_summa3d``) dispatches batch i+1 while batch i computes,
reading the device-resident overflow flags only when it drains its window.

``reassemble_operands`` closes the loop for iterated multiplies (MCL-style
A ← f(A·A), §V-C): the batched C outputs are redistributed into fresh A-kind
and B-kind operands entirely on the grid — the A route is a pure local
column remap (C is distributed like A, layer-aligned), the B route one
partitioned layer split + ``all_to_all`` — so an application iterate never
round-trips through ``gather_to_global``/``scatter_to_grid``.

Sentinel discipline: before gathering, every device rewrites its padding
entries to the *global* contraction sentinel (k_tot) so offset arithmetic
cannot alias padding onto real coordinates; values are zero as a second
guarantee.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import semiring as sr
from . import sortkeys
from ..compat import axis_size, shard_map
from .distsparse import DistSparse, dist_spec
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .local_spgemm import (
    mask_indicator,
    merge_sparse,
    spgemm_esc,
    spgemm_hash,
    spgemm_kbinned,
    spmm,
)
from .sparse import SparseCOO, concat as sparse_concat

Array = jnp.ndarray

#: Trace-time counters (a trace == a compile for the module-level jits).
#: ``summa3d_fused_step`` bumps its entry every time jit re-traces it, so
#: tests can assert the batched driver hits the jit cache across MCL
#: iterations instead of recompiling per capacity plan.
TRACE_COUNTS = {"fused_step": 0}


@dataclasses.dataclass(frozen=True)
class BatchCaps:
    """Static capacities for one batch of the multiply (symbolic-step output)."""

    flops_cap: int  # ESC expansion slots per process
    d_cap: int  # unmerged D tile entries per process (sparse path)
    piece_cap: int  # per-fiber-piece entries (sparse path)
    c_cap: int  # merged C tile entries per process (sparse path)

    def doubled(self) -> "BatchCaps":
        """Next capacity plan for the overflow-retry loop (§IV-A)."""
        return BatchCaps(
            flops_cap=self.flops_cap * 2, d_cap=self.d_cap * 2,
            piece_cap=self.piece_cap * 2, c_cap=self.c_cap * 2,
        )


@dataclasses.dataclass(frozen=True)
class BinnedCaps:
    """Static parameters of the k-binned local multiply (hashable — jit-static).

    The dynamic part of the bin plan (the monotone ``bin_of_k`` map over the
    per-layer contraction space) travels as a replicated traced array so one
    executable serves any bin boundary choice.
    """

    num_bins: int
    bin_cap_a: int  # gathered-A entries per bin, per process
    bin_cap_b: int  # gathered-B entries per bin, per process

    def doubled(self) -> "BinnedCaps":
        return BinnedCaps(
            num_bins=self.num_bins,
            bin_cap_a=self.bin_cap_a * 2,
            bin_cap_b=self.bin_cap_b * 2,
        )


@dataclasses.dataclass(frozen=True)
class HashCaps:
    """Static parameters of the hash-accumulator local multiply (jit-static).

    ``table_cap`` (power of two) sizes the open-addressing table — the
    O(nnz_out·load_factor) resident scratch the plan budgets instead of
    O(flops). ``chunk_cap`` partial products are enumerated per chunk into a
    single reused buffer; ``num_chunks`` chunks cover the planned flops
    bound. ``max_probes`` linear-probe rounds before an insert is dropped
    and counted (overflow → driver retry).
    """

    table_cap: int
    chunk_cap: int
    num_chunks: int
    max_probes: int = 32

    def doubled(self) -> "HashCaps":
        # chunk_cap is a bandwidth knob, not a soundness cap — growing the
        # chunk *count* (and the table + probe bound) is what clears drops
        return HashCaps(
            table_cap=self.table_cap * 2,
            chunk_cap=self.chunk_cap,
            num_chunks=self.num_chunks * 2,
            max_probes=min(self.max_probes * 2, 256),
        )


def _squeeze_tile(d: DistSparse) -> SparseCOO:
    """Inside shard_map: (1,1,1,cap) blocks -> local SparseCOO tile."""
    return SparseCOO(
        d.rows.reshape(-1),
        d.cols.reshape(-1),
        d.vals.reshape(-1),
        d.nnz.reshape(()),
        d.tile_shape,
    )


def _gather_A(a: SparseCOO) -> SparseCOO:
    """A-Broadcast: gather stage tiles along the grid row; re-index columns
    to the per-layer contraction space (stage s occupies [s*wl, (s+1)*wl))."""
    tm, wl = a.shape
    s = lax.axis_index(COL_AX)
    pc = axis_size(COL_AX)
    k_tot = pc * wl
    valid = a.valid_mask()
    rows = jnp.where(valid, a.rows, tm)
    cols = jnp.where(valid, a.cols + s * wl, k_tot)
    vals = jnp.where(valid, a.vals, 0)
    g_rows = lax.all_gather(rows, COL_AX).reshape(-1)
    g_cols = lax.all_gather(cols, COL_AX).reshape(-1)
    g_vals = lax.all_gather(vals, COL_AX).reshape(-1)
    cap = g_rows.shape[0]
    # padding is self-masking (zero vals + sentinels); declare all slots live
    return SparseCOO(g_rows, g_cols, g_vals, jnp.int32(cap), (tm, k_tot))


def _gather_B(b: SparseCOO) -> SparseCOO:
    """B-Broadcast: gather stage tiles along the grid column; re-index rows
    to the per-layer contraction space (stage i occupies [i*wl, (i+1)*wl))."""
    wl, tn = b.shape
    i = lax.axis_index(ROW_AX)
    pr = axis_size(ROW_AX)
    k_tot = pr * wl
    valid = b.valid_mask()
    rows = jnp.where(valid, b.rows + i * wl, k_tot)
    cols = jnp.where(valid, b.cols, tn)
    vals = jnp.where(valid, b.vals, 0)
    g_rows = lax.all_gather(rows, ROW_AX).reshape(-1)
    g_cols = lax.all_gather(cols, ROW_AX).reshape(-1)
    g_vals = lax.all_gather(vals, ROW_AX).reshape(-1)
    cap = g_rows.shape[0]
    return SparseCOO(g_rows, g_cols, g_vals, jnp.int32(cap), (k_tot, tn))


# ---------------------------------------------------------------------------
# Dense-accumulator path — two broadcast schedules
# ---------------------------------------------------------------------------
#  "allgather": bulk realization — both operands gathered once (same α-β
#      bandwidth as √(p/l) broadcasts, √(p/l)× the tile memory). Fast and
#      simple; the default.
#  "ring": Cannon-style memory-constrained realization — initial skew
#      (A[i,j] ← A[i,(j+i) mod pc], B[i,j] ← B[(i+j) mod pr, j]) followed by
#      per-stage multiply + unit ppermute shifts. O(1) extra tiles: the
#      schedule the paper's memory-constrained regime actually wants (§IV-A
#      counts unmerged results against the same budget the gathered copies
#      would eat). The skew runs as a tile-index gather OUTSIDE shard_map
#      (XLA partitions it into collective-permutes).
def _skew(d: DistSparse, kind: str, grid: Grid) -> DistSparse:
    pr, pc = grid.pr, grid.pc
    i = jnp.arange(pr)[:, None]
    j = jnp.arange(pc)[None, :]
    if kind == "A":  # shift row i left by i: new[i,j] = old[i, (j+i) % pc]
        src = (j + i) % pc
        gather = lambda x: jnp.take_along_axis(
            x, src[:, :, None, None].astype(jnp.int32), axis=1
        ) if x.ndim == 4 else jnp.take_along_axis(
            x, src[:, :, None].astype(jnp.int32), axis=1
        )
    else:  # B: shift col j up by j: new[i,j] = old[(i+j) % pr, j]
        src = (i + j) % pr
        gather = lambda x: jnp.take_along_axis(
            x, src[:, :, None, None].astype(jnp.int32), axis=0
        ) if x.ndim == 4 else jnp.take_along_axis(
            x, src[:, :, None].astype(jnp.int32), axis=0
        )
    return DistSparse(
        rows=gather(d.rows), cols=gather(d.cols), vals=gather(d.vals),
        nnz=gather(d.nnz), shape=d.shape, tile_shape=d.tile_shape,
        grid_shape=d.grid_shape, kind=d.kind,
    )


def summa3d_dense_step(
    a: DistSparse, b_batch: DistSparse, grid: Grid,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    schedule: str = "allgather",
) -> Array:
    """One batched-SUMMA3D step, dense-accumulator path.

    ``b_batch`` is the batch's column block of B (still kind="B" layout,
    tn = w/b). Returns the C batch as stacked dense tiles
    (pr, pc, l, tm, tn/l) — fiber merge included (psum_scatter).
    """
    assert semiring.add_kind == "sum", "dense path requires a sum monoid"
    tm_a, wl_a = a.tile_shape
    _, tn_b = b_batch.tile_shape
    l = grid.l
    assert tn_b % l == 0

    if schedule == "ring":
        assert grid.pr == grid.pc, "Cannon ring needs a square layer grid"
        a = _skew(a, "A", grid)
        b_batch = _skew(b_batch, "B", grid)

        def step(a_t: DistSparse, b_t: DistSparse) -> Array:
            a_loc = _squeeze_tile(a_t)
            b_loc = _squeeze_tile(b_t)
            pc = grid.pc
            ring_a = [(s, (s - 1) % pc) for s in range(pc)]  # shift left
            ring_b = [(s, (s - 1) % pc) for s in range(pc)]  # shift up

            def stage(t, carry):
                ar, ac, av, br, bc, bv, acc = carry
                # local multiply of the aligned stage tiles; local indices
                # already pair up (both tiles come from the same k-block)
                a_cur = SparseCOO(ar, ac, jnp.where(ar < tm_a, av, 0),
                                  jnp.int32(ar.shape[0]), (tm_a, wl_a))
                b_dense = SparseCOO(br, bc, jnp.where(bc < tn_b, bv, 0),
                                    jnp.int32(br.shape[0]),
                                    (wl_a, tn_b)).to_dense()
                acc = acc + spmm(a_cur, b_dense, semiring)
                ar = lax.ppermute(ar, COL_AX, ring_a)
                ac = lax.ppermute(ac, COL_AX, ring_a)
                av = lax.ppermute(av, COL_AX, ring_a)
                br = lax.ppermute(br, ROW_AX, ring_b)
                bc = lax.ppermute(bc, ROW_AX, ring_b)
                bv = lax.ppermute(bv, ROW_AX, ring_b)
                return ar, ac, av, br, bc, bv, acc

            init = (
                a_loc.rows, a_loc.cols, a_loc.vals,
                b_loc.rows, b_loc.cols, b_loc.vals,
                jnp.zeros((tm_a, tn_b), jnp.float32),
            )
            *_, d_tile = lax.fori_loop(0, grid.pc, stage, init)
            c_tile = lax.psum_scatter(
                d_tile, LAYER_AX, scatter_dimension=1, tiled=True
            )
            return c_tile[None, None, None]
    else:
        def step(a_t: DistSparse, b_t: DistSparse) -> Array:
            a_loc = _squeeze_tile(a_t)
            b_loc = _squeeze_tile(b_t)
            a_cat = _gather_A(a_loc)
            b_cat = _gather_B(b_loc)
            b_dense = b_cat.to_dense()  # (k_tot, tn_b) — narrow by batching
            d_tile = spmm(a_cat, b_dense, semiring)  # (tm, tn_b) accumulator
            # AllToAll-Fiber + Merge-Fiber == reduce-scatter along the fiber
            c_tile = lax.psum_scatter(
                d_tile, LAYER_AX, scatter_dimension=1, tiled=True
            )  # (tm, tn_b/l)
            return c_tile[None, None, None]

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    in_specs = (
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=a.shape, tile_shape=a.tile_shape,
                   grid_shape=a.grid_shape, kind=a.kind),
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=b_batch.shape, tile_shape=b_batch.tile_shape,
                   grid_shape=b_batch.grid_shape, kind=b_batch.kind),
    )
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=in_specs, out_specs=spec3,
        check_vma=False,
    )
    return fn(a, b_batch)


# ---------------------------------------------------------------------------
# Sparse (ESC / k-binned) path
# ---------------------------------------------------------------------------
def _pmax_grid(x: Array) -> Array:
    return lax.pmax(lax.pmax(lax.pmax(x, ROW_AX), COL_AX), LAYER_AX)


def _psum_grid(x: Array) -> Array:
    return lax.psum(lax.psum(lax.psum(x, ROW_AX), COL_AX), LAYER_AX)


def _sparse_tile_body(
    a_loc: SparseCOO, b_loc: SparseCOO, l: int, caps: BatchCaps,
    semiring: sr.Semiring, sorted_merge: bool,
    kbin: "BinnedCaps" = None, bin_of_k: Array = None,
    mask: SparseCOO = None, mask_complement: bool = False,
    hashc: "HashCaps" = None,
) -> Tuple[SparseCOO, Array]:
    """Per-device sparse pipeline (inside shard_map): gather → local multiply
    → partitioned ColSplit → AllToAll-Fiber → Merge-Fiber.

    ``kbin``/``hashc`` select the local multiply: None/None runs ESC (any
    semiring); a ``BinnedCaps`` runs the k-binned paired kernel (plus_times
    only), pairing O(Σ_g capA_g×capB_g) instead of O(capA×capB); a
    ``HashCaps`` runs the hash-accumulator multiply (any semiring),
    O(table + chunk) scratch instead of O(flops) — the plan-driven 3-way
    switch the symbolic step emits. All produce a row-major-sorted D tile,
    so the downstream split/merge invariants are identical.

    ``mask`` (a SparseCOO over the D tile's (tm, tn_b) output space) runs the
    masked/filtered formulation: ESC intersects the expanded products'
    packed keys against the mask's sorted keys before the compress, the
    binned path filters its dense accumulator — either way only surviving
    coordinates consume ``caps.d_cap`` and everything downstream
    (ColSplit pieces, the fiber exchange, Merge-Fiber) carries survivors
    only, which is where the masked memory/traffic win lives.
    """
    assert kbin is None or hashc is None, "kbin and hashc are exclusive"
    tm_a, _ = a_loc.shape
    _, tn_b = b_loc.shape
    piece_w = tn_b // l
    a_cat = _gather_A(a_loc)
    b_cat = _gather_B(b_loc)
    if kbin is None:
        mkeys = None
        if mask is not None:
            mkeys = sortkeys.sorted_mask_keys(
                mask.rows, mask.cols, mask.valid_mask(), (tm_a, tn_b)
            )
        if hashc is not None:
            d_tile, ovf_mul = spgemm_hash(
                a_cat, b_cat, out_cap=caps.d_cap,
                table_cap=hashc.table_cap, chunk_cap=hashc.chunk_cap,
                num_chunks=hashc.num_chunks, semiring=semiring,
                mask_keys=mkeys, mask_complement=mask_complement,
                max_probes=hashc.max_probes,
            )  # (tm, tn_b) sparse, row-major sorted
        else:
            d_tile, ovf_mul = spgemm_esc(
                a_cat, b_cat, out_cap=caps.d_cap, flops_cap=caps.flops_cap,
                semiring=semiring, mask_keys=mkeys,
                mask_complement=mask_complement,
            )  # (tm, tn_b) sparse, row-major sorted
    else:
        d_tile, ovf_mul = spgemm_kbinned(
            a_cat, b_cat, caps.d_cap, kbin.num_bins, kbin.bin_cap_a,
            kbin.bin_cap_b, bin_of_k=bin_of_k, semiring=semiring,
            mask=mask, mask_complement=mask_complement,
        )
    # ColSplit (Alg. 2 line 4): one partitioned split into all l pieces,
    # order-preserving (pieces stay row-major sorted), sized by piece_cap
    pr_, pc_, pv_, pn_, ovf_split = d_tile.split_col_blocks(l, caps.piece_cap)
    # AllToAll-Fiber (Alg. 2 line 5)
    pr_ = lax.all_to_all(pr_, LAYER_AX, split_axis=0, concat_axis=0)
    pc_ = lax.all_to_all(pc_, LAYER_AX, split_axis=0, concat_axis=0)
    pv_ = lax.all_to_all(pv_, LAYER_AX, split_axis=0, concat_axis=0)
    pn_ = lax.all_to_all(pn_[:, None], LAYER_AX, split_axis=0, concat_axis=0)[:, 0]
    # Merge-Fiber (Alg. 2 line 6): sort-free merge of l received pieces
    parts = [
        SparseCOO(pr_[k], pc_[k], pv_[k], pn_[k], (tm_a, piece_w))
        for k in range(l)
    ]
    c_tile, ovf_merge = merge_sparse(
        parts, caps.c_cap, semiring, assume_sorted=sorted_merge
    )
    return c_tile, ovf_mul + ovf_split + ovf_merge


def summa3d_sparse_step(
    a: DistSparse, b_batch: DistSparse, grid: Grid, caps: BatchCaps,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    sorted_merge: bool = True,
    kbin: BinnedCaps = None,
    bin_of_k: Array = None,
    hashc: HashCaps = None,
) -> Tuple[DistSparse, Array]:
    """One batched-SUMMA3D step, sparse path. Returns (C tiles, overflow).

    C tiles come back as a DistSparse with tile shape (tm, tn_b/l); the
    global column mapping is block-cyclic (see batched.batch_column_map).
    overflow > 0 means a static capacity was exceeded — the driver retries
    with the next larger capacity plan (paper robustness, §IV-A).

    ``sorted_merge=True`` runs Merge-Fiber as a segmented k-way merge: the l
    received pieces are column splits of row-major-sorted local-multiply
    outputs, so they arrive sorted and only need merging, never re-sorting
    (§IV-D). ``kbin``/``bin_of_k`` (from the symbolic bin plan) switch the
    local multiply to the k-binned paired kernel.
    """
    tm_a, _ = a.tile_shape
    _, tn_b = b_batch.tile_shape
    l = grid.l
    assert tn_b % l == 0
    piece_w = tn_b // l

    def step(a_t: DistSparse, b_t: DistSparse, *rest):
        bok = rest[0] if rest else None
        c_tile, ovf = _sparse_tile_body(
            _squeeze_tile(a_t), _squeeze_tile(b_t), l, caps, semiring,
            sorted_merge, kbin=kbin, bin_of_k=bok, hashc=hashc,
        )
        return (
            c_tile.rows[None, None, None],
            c_tile.cols[None, None, None],
            c_tile.vals[None, None, None],
            c_tile.nnz[None, None, None],
            _pmax_grid(ovf),
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    in_specs = [dist_spec(a, spec3), dist_spec(b_batch, spec3)]
    args = [a, b_batch]
    if kbin is not None:
        in_specs.append(spec0)  # bin map: replicated
        args.append(bin_of_k)
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=tuple(in_specs),
        out_specs=(spec3, spec3, spec3, spec3, spec0),
        check_vma=False,
    )
    rows, cols, vals, nnz, ovf = fn(*args)
    m, n = a.shape
    c = DistSparse(
        rows=rows, cols=cols, vals=vals, nnz=nnz,
        shape=(m, b_batch.shape[1]),
        tile_shape=(tm_a, piece_w),
        grid_shape=a.grid_shape,
        kind="C",
    )
    return c, ovf


# ---------------------------------------------------------------------------
# Fused per-batch step (selection + multiply in ONE shard_map)
# ---------------------------------------------------------------------------
def summa3d_fused_step(
    a: DistSparse,
    b_full: DistSparse,
    batch,
    bin_of_k: Array = None,
    mask: DistSparse = None,
    *,
    grid: Grid,
    num_batches: int,
    sel_cap: int,
    caps: BatchCaps = None,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    sorted_merge: bool = True,
    path: str = "sparse",
    kbin: BinnedCaps = None,
    hashc: HashCaps = None,
    mask_cap: int = 0,
    mask_complement: bool = False,
):
    """Batch-select + SUMMA3D multiply fused into one SPMD step (Alg. 4
    line 5-6 without the host in the loop).

    ``batch`` stays a traced scalar, so ONE executable serves every batch —
    the driver can dispatch batch i+1 while batch i is still computing
    (async dispatch), and the selected B block never round-trips through a
    separate jit boundary. Returns ``(c_batch, ovf)`` where ``ovf`` is an
    i32[2] device array ``[selection_overflow, multiply_overflow]`` — the
    driver keeps it device-resident and only syncs when it drains its
    pipeline window.

    ``mask`` is an optional C-layout ``DistSparse`` over the full output
    space (the §V-B masked-SpGEMM operand). It is layer-aligned with C:
    batch ``bi``'s piece on layer k is exactly local columns
    [bi·wbl, (bi+1)·wbl) of mask tile (i, j, k), so building the D-tile mask
    is one batch-slice selection (``mask_cap`` entries, exact from the
    symbolic mask counts) plus one ``all_gather`` along the fiber — the mask
    never leaves the grid and one executable still serves every batch. The
    local multiply then filters partial products before its compress
    (``mask_complement`` flips strict ⊙M into ⊙¬M).
    """
    TRACE_COUNTS["fused_step"] += 1
    tm_a, _ = a.tile_shape
    tn_full = b_full.tile_shape[1]
    assert tn_full % num_batches == 0, (tn_full, num_batches)
    wb = tn_full // num_batches
    l = grid.l
    assert wb % l == 0
    piece_w = wb // l
    if path == "dense":
        assert semiring.add_kind == "sum", "dense path requires a sum monoid"
    if mask is not None:
        assert mask.kind in ("A", "C"), mask.kind
        # C layout: tile (m/pr, n/pc/l); each batch is a wbl-wide slice of it
        assert mask.tile_shape == (tm_a, tn_full // l), (
            mask.tile_shape, (tm_a, tn_full // l)
        )
        wbl = mask.tile_shape[1] // num_batches
        assert wbl * num_batches == mask.tile_shape[1], (
            mask.tile_shape, num_batches
        )

    def step(a_t: DistSparse, b_t: DistSparse, batch_, *rest):
        rest = list(rest)
        bok = rest.pop(0) if kbin is not None else None
        mask_t = rest.pop(0) if mask is not None else None
        a_loc = _squeeze_tile(a_t)
        b_loc = _squeeze_tile(b_t)
        # Batch-Select (Alg. 4 line 5): block-cyclic column selection
        sel, ovf_sel = b_loc.select_cols_blockcyclic(
            batch_, num_batches, l, new_cap=sel_cap
        )
        ovf_sel = _pmax_grid(ovf_sel)
        mask_cat, ovf_mask = None, jnp.int32(0)
        if mask_t is not None:
            # Mask-Select: slice this batch's columns out of the local mask
            # tile, then gather the l layer pieces along the fiber — layer t
            # owns D columns [t*wbl, (t+1)*wbl) of the selected batch.
            m_loc = _squeeze_tile(mask_t)
            msel, ovf_mask = m_loc.select_col_block(
                batch_ * wbl, wbl, new_cap=mask_cap
            )
            ovf_mask = _pmax_grid(ovf_mask)
            k_ax = lax.axis_index(LAYER_AX)
            mv = msel.valid_mask()
            mrows = jnp.where(mv, msel.rows, tm_a)
            mcols = jnp.where(mv, k_ax * wbl + msel.cols, wb)
            g_mr = lax.all_gather(mrows, LAYER_AX).reshape(-1)
            g_mc = lax.all_gather(mcols, LAYER_AX).reshape(-1)
            gcap = g_mr.shape[0]
            # all slots declared live; padding is sentinel-coded (tm, wb)
            mask_cat = SparseCOO(
                g_mr, g_mc, jnp.ones((gcap,), jnp.float32),
                jnp.int32(gcap), (tm_a, wb),
            )
        if path == "dense":
            a_cat = _gather_A(a_loc)
            b_cat = _gather_B(sel)
            d_tile = spmm(a_cat, b_cat.to_dense(), semiring)
            if mask_cat is not None:
                d_tile = jnp.where(
                    mask_indicator(mask_cat, mask_complement), d_tile, 0.0
                )
            c_tile = lax.psum_scatter(
                d_tile, LAYER_AX, scatter_dimension=1, tiled=True
            )
            return c_tile[None, None, None], jnp.stack([ovf_sel, ovf_mask])
        c_tile, ovf_mul = _sparse_tile_body(
            a_loc, sel, l, caps, semiring, sorted_merge,
            kbin=kbin, bin_of_k=bok, hashc=hashc,
            mask=mask_cat, mask_complement=mask_complement,
        )
        return (
            c_tile.rows[None, None, None],
            c_tile.cols[None, None, None],
            c_tile.vals[None, None, None],
            c_tile.nnz[None, None, None],
            jnp.stack([ovf_sel, _pmax_grid(ovf_mul) + ovf_mask]),
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    in_specs = [dist_spec(a, spec3), dist_spec(b_full, spec3), spec0]
    args = [a, b_full, jnp.int32(batch)]
    if kbin is not None:
        in_specs.append(spec0)
        args.append(bin_of_k)
    if mask is not None:
        in_specs.append(dist_spec(mask, spec3))
        args.append(mask)
    if path == "dense":
        out_specs = (spec3, spec0)
    else:
        out_specs = (spec3, spec3, spec3, spec3, spec0)
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=tuple(in_specs), out_specs=out_specs,
        check_vma=False,
    )
    if path == "dense":
        c_tiles, ovf = fn(*args)
        return c_tiles, ovf
    rows, cols, vals, nnz, ovf = fn(*args)
    m, _ = a.shape
    c = DistSparse(
        rows=rows, cols=cols, vals=vals, nnz=nnz,
        shape=(m, b_full.shape[1] // num_batches),
        tile_shape=(tm_a, piece_w),
        grid_shape=a.grid_shape,
        kind="C",
    )
    return c, ovf


# ---------------------------------------------------------------------------
# On-grid operand reassembly (device-resident iteration, paper §V-C)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("grid", "cap_a", "cap_b"))
def reassemble_operands(
    c_batches, grid: Grid, cap_a: int, cap_b: int
) -> Tuple[DistSparse, DistSparse, Array]:
    """Turn the batched C outputs of one multiply into the next iteration's
    A-kind and B-kind operands WITHOUT leaving the device grid.

    ``c_batches`` is the (tuple of) per-batch C ``DistSparse`` results of
    ``batched_summa3d`` (kind "C", tile (tm, wb/l)) — e.g. the pruned batches
    of an MCL expansion. Batch bi's local column c of tile (i, j, k) is
    global column j·w + (k·nb + bi)·wbl + c (``batch_column_map``), which
    lands in row block i / column block j of BOTH target distributions — so
    reassembly is fiber-local: a partitioned layer split (reusing
    ``SparseCOO.split_col_blocks``) + one ``all_to_all`` over the layer axis
    per operand, plus local index remapping. One jitted SPMD step, no
    ``gather_to_global``/``scatter_to_grid`` round-trip.

    Requires the square layout the paper (and MCL) uses: m == n, pr == pc.
    Returns ``(a_next, b_next, overflow)`` where overflow counts entries
    dropped because ``cap_a``/``cap_b`` (static per-tile capacities) were
    exceeded — with capacities at the post-prune hard bound it is always 0.
    """
    c_batches = tuple(c_batches)
    nb = len(c_batches)
    c0 = c_batches[0]
    pr, pc, l = c0.grid_shape
    tm, wbl = c0.tile_shape
    m = c0.shape[0]
    w = wbl * l * nb  # full column-block width = n/pc
    n = w * pc
    assert m == n and pr == pc, (
        f"on-grid reassembly requires the square layout, got m={m} n={n} "
        f"grid {pr}x{pc}x{l}"
    )
    wl = w // l  # per-layer slice width (A cols / B rows)

    def step(*c_ts):
        k_ax = lax.axis_index(LAYER_AX)
        tiles = [_squeeze_tile(t) for t in c_ts]
        # concatenate the nb batch tiles into one entry list over the FULL
        # local column block [0, w): batch bi local col c -> (k·nb + bi)·wbl + c.
        # Padding is rewritten to explicit sentinels so every slot can be
        # declared live for the split below.
        rows_l, offs_l, vals_l = [], [], []
        for bi, t in enumerate(tiles):
            valid = t.valid_mask()
            rows_l.append(jnp.where(valid, t.rows, tm))
            offs_l.append(
                jnp.where(valid, (k_ax * nb + bi) * wbl + t.cols, w)
            )
            vals_l.append(jnp.where(valid, t.vals, 0))
        rows = jnp.concatenate(rows_l)
        offs = jnp.concatenate(offs_l)
        vals = jnp.concatenate(vals_l)
        cap_tot = rows.shape[0]

        # ---- A-kind route: layer k's batch offsets span exactly
        # [k·wl, (k+1)·wl) (the batch_column_map algebra), so every entry's
        # destination layer EQUALS its source layer — no fiber exchange at
        # all, just the local per-batch column remap (off - k·wl = bi·wbl+c)
        # and one nb-way concat/compact.
        a_parts = [
            SparseCOO(t.rows, bi * wbl + t.cols, t.vals, t.nnz, (tm, wl))
            for bi, t in enumerate(tiles)
        ]
        a_tile, ovf_a2 = sparse_concat(a_parts, cap_a)

        # ---- B-kind route: destination layer = row // wl (split on rows by
        # transposing the roles: split_col_blocks keys on .cols)
        ent_b = SparseCOO(offs, rows, vals, jnp.int32(cap_tot), (w, tm))
        br, bc, bv, bn, ovf_b = ent_b.split_col_blocks(l, cap_b)
        br = lax.all_to_all(br, LAYER_AX, split_axis=0, concat_axis=0)
        bc = lax.all_to_all(bc, LAYER_AX, split_axis=0, concat_axis=0)
        bv = lax.all_to_all(bv, LAYER_AX, split_axis=0, concat_axis=0)
        bn = lax.all_to_all(bn[:, None], LAYER_AX, split_axis=0, concat_axis=0)[:, 0]
        # received pieces carry (rows=global-block col offset, cols=local B row)
        b_parts = [SparseCOO(bc[k], br[k], bv[k], bn[k], (wl, w)) for k in range(l)]
        b_tile, ovf_b2 = sparse_concat(b_parts, cap_b)

        ovf = _pmax_grid(ovf_a2 + ovf_b + ovf_b2)
        return (
            a_tile.rows[None, None, None], a_tile.cols[None, None, None],
            a_tile.vals[None, None, None], a_tile.nnz[None, None, None],
            b_tile.rows[None, None, None], b_tile.cols[None, None, None],
            b_tile.vals[None, None, None], b_tile.nnz[None, None, None],
            ovf,
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    fn = shard_map(
        step, mesh=grid.mesh,
        in_specs=tuple(dist_spec(c, spec3) for c in c_batches),
        out_specs=(spec3,) * 8 + (spec0,),
        check_vma=False,
    )
    ar, ac, av, an, br, bc, bv, bn, ovf = fn(*c_batches)
    a_next = DistSparse(rows=ar, cols=ac, vals=av, nnz=an, shape=(m, n),
                        tile_shape=(tm, wl), grid_shape=(pr, pc, l), kind="A")
    b_next = DistSparse(rows=br, cols=bc, vals=bv, nnz=bn, shape=(m, n),
                        tile_shape=(wl, w), grid_shape=(pr, pc, l), kind="B")
    return a_next, b_next, ovf
