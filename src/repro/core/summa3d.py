"""SUMMA2D/3D sparse multiply on the grid mesh (paper Alg. 1 + Alg. 2).

One shard_map'd step computes a full 3D multiply for one batch:

  1. A-Broadcast / B-Broadcast (Alg. 1 lines 5-6): realized as
     ``lax.all_gather`` along the grid row/column axes — the bulk equivalent
     of the per-stage broadcasts (same α-β bandwidth: every tile traverses
     its communicator once; see benchmarks/bench_comm_model.py for the
     Table II reconciliation). Because the contraction ranges of the
     gathered stage tiles are disjoint, all `pc` stages fuse into ONE local
     multiply over the concatenated entry lists (contraction index =
     stage * (w/l) + local index) — Local-Multiply and Merge-Layer collapse
     into the same sort-free accumulation, which is the TPU rendering of the
     paper's "merge once after all stages" observation (§III-A).
  2. Local-Multiply (Alg. 1 line 7): dense-accumulator path (spmm into a
     dense D tile — identity-hash accumulator) or sparse ESC path
     (expand-sort-compress with static capacities from the symbolic step).
  3. AllToAll-Fiber + Merge-Fiber (Alg. 2 lines 4-6): dense path lowers the
     pair to ONE ``lax.psum_scatter`` over the layer axis (all-to-all + local
     add is exactly reduce-scatter); sparse path does the literal
     ``lax.all_to_all`` of column pieces followed by a sort-free merge.

Sentinel discipline: before gathering, every device rewrites its padding
entries to the *global* contraction sentinel (k_tot) so offset arithmetic
cannot alias padding onto real coordinates; values are zero as a second
guarantee.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import semiring as sr
from ..compat import axis_size, shard_map
from .distsparse import DistSparse
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .local_spgemm import spgemm_esc, spmm, merge_sparse
from .sparse import SparseCOO

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class BatchCaps:
    """Static capacities for one batch of the multiply (symbolic-step output)."""

    flops_cap: int  # ESC expansion slots per process
    d_cap: int  # unmerged D tile entries per process (sparse path)
    piece_cap: int  # per-fiber-piece entries (sparse path)
    c_cap: int  # merged C tile entries per process (sparse path)


def _squeeze_tile(d: DistSparse) -> SparseCOO:
    """Inside shard_map: (1,1,1,cap) blocks -> local SparseCOO tile."""
    return SparseCOO(
        d.rows.reshape(-1),
        d.cols.reshape(-1),
        d.vals.reshape(-1),
        d.nnz.reshape(()),
        d.tile_shape,
    )


def _gather_A(a: SparseCOO) -> SparseCOO:
    """A-Broadcast: gather stage tiles along the grid row; re-index columns
    to the per-layer contraction space (stage s occupies [s*wl, (s+1)*wl))."""
    tm, wl = a.shape
    s = lax.axis_index(COL_AX)
    pc = axis_size(COL_AX)
    k_tot = pc * wl
    valid = a.valid_mask()
    rows = jnp.where(valid, a.rows, tm)
    cols = jnp.where(valid, a.cols + s * wl, k_tot)
    vals = jnp.where(valid, a.vals, 0)
    g_rows = lax.all_gather(rows, COL_AX).reshape(-1)
    g_cols = lax.all_gather(cols, COL_AX).reshape(-1)
    g_vals = lax.all_gather(vals, COL_AX).reshape(-1)
    cap = g_rows.shape[0]
    # padding is self-masking (zero vals + sentinels); declare all slots live
    return SparseCOO(g_rows, g_cols, g_vals, jnp.int32(cap), (tm, k_tot))


def _gather_B(b: SparseCOO) -> SparseCOO:
    """B-Broadcast: gather stage tiles along the grid column; re-index rows
    to the per-layer contraction space (stage i occupies [i*wl, (i+1)*wl))."""
    wl, tn = b.shape
    i = lax.axis_index(ROW_AX)
    pr = axis_size(ROW_AX)
    k_tot = pr * wl
    valid = b.valid_mask()
    rows = jnp.where(valid, b.rows + i * wl, k_tot)
    cols = jnp.where(valid, b.cols, tn)
    vals = jnp.where(valid, b.vals, 0)
    g_rows = lax.all_gather(rows, ROW_AX).reshape(-1)
    g_cols = lax.all_gather(cols, ROW_AX).reshape(-1)
    g_vals = lax.all_gather(vals, ROW_AX).reshape(-1)
    cap = g_rows.shape[0]
    return SparseCOO(g_rows, g_cols, g_vals, jnp.int32(cap), (k_tot, tn))


# ---------------------------------------------------------------------------
# Dense-accumulator path — two broadcast schedules
# ---------------------------------------------------------------------------
#  "allgather": bulk realization — both operands gathered once (same α-β
#      bandwidth as √(p/l) broadcasts, √(p/l)× the tile memory). Fast and
#      simple; the default.
#  "ring": Cannon-style memory-constrained realization — initial skew
#      (A[i,j] ← A[i,(j+i) mod pc], B[i,j] ← B[(i+j) mod pr, j]) followed by
#      per-stage multiply + unit ppermute shifts. O(1) extra tiles: the
#      schedule the paper's memory-constrained regime actually wants (§IV-A
#      counts unmerged results against the same budget the gathered copies
#      would eat). The skew runs as a tile-index gather OUTSIDE shard_map
#      (XLA partitions it into collective-permutes).
def _skew(d: DistSparse, kind: str, grid: Grid) -> DistSparse:
    pr, pc = grid.pr, grid.pc
    i = jnp.arange(pr)[:, None]
    j = jnp.arange(pc)[None, :]
    if kind == "A":  # shift row i left by i: new[i,j] = old[i, (j+i) % pc]
        src = (j + i) % pc
        gather = lambda x: jnp.take_along_axis(
            x, src[:, :, None, None].astype(jnp.int32), axis=1
        ) if x.ndim == 4 else jnp.take_along_axis(
            x, src[:, :, None].astype(jnp.int32), axis=1
        )
    else:  # B: shift col j up by j: new[i,j] = old[(i+j) % pr, j]
        src = (i + j) % pr
        gather = lambda x: jnp.take_along_axis(
            x, src[:, :, None, None].astype(jnp.int32), axis=0
        ) if x.ndim == 4 else jnp.take_along_axis(
            x, src[:, :, None].astype(jnp.int32), axis=0
        )
    return DistSparse(
        rows=gather(d.rows), cols=gather(d.cols), vals=gather(d.vals),
        nnz=gather(d.nnz), shape=d.shape, tile_shape=d.tile_shape,
        grid_shape=d.grid_shape, kind=d.kind,
    )


def summa3d_dense_step(
    a: DistSparse, b_batch: DistSparse, grid: Grid,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    schedule: str = "allgather",
) -> Array:
    """One batched-SUMMA3D step, dense-accumulator path.

    ``b_batch`` is the batch's column block of B (still kind="B" layout,
    tn = w/b). Returns the C batch as stacked dense tiles
    (pr, pc, l, tm, tn/l) — fiber merge included (psum_scatter).
    """
    assert semiring.add_kind == "sum", "dense path requires a sum monoid"
    tm_a, wl_a = a.tile_shape
    _, tn_b = b_batch.tile_shape
    l = grid.l
    assert tn_b % l == 0

    if schedule == "ring":
        assert grid.pr == grid.pc, "Cannon ring needs a square layer grid"
        a = _skew(a, "A", grid)
        b_batch = _skew(b_batch, "B", grid)

        def step(a_t: DistSparse, b_t: DistSparse) -> Array:
            a_loc = _squeeze_tile(a_t)
            b_loc = _squeeze_tile(b_t)
            pc = grid.pc
            ring_a = [(s, (s - 1) % pc) for s in range(pc)]  # shift left
            ring_b = [(s, (s - 1) % pc) for s in range(pc)]  # shift up

            def stage(t, carry):
                ar, ac, av, br, bc, bv, acc = carry
                # local multiply of the aligned stage tiles; local indices
                # already pair up (both tiles come from the same k-block)
                a_cur = SparseCOO(ar, ac, jnp.where(ar < tm_a, av, 0),
                                  jnp.int32(ar.shape[0]), (tm_a, wl_a))
                b_dense = SparseCOO(br, bc, jnp.where(bc < tn_b, bv, 0),
                                    jnp.int32(br.shape[0]),
                                    (wl_a, tn_b)).to_dense()
                acc = acc + spmm(a_cur, b_dense, semiring)
                ar = lax.ppermute(ar, COL_AX, ring_a)
                ac = lax.ppermute(ac, COL_AX, ring_a)
                av = lax.ppermute(av, COL_AX, ring_a)
                br = lax.ppermute(br, ROW_AX, ring_b)
                bc = lax.ppermute(bc, ROW_AX, ring_b)
                bv = lax.ppermute(bv, ROW_AX, ring_b)
                return ar, ac, av, br, bc, bv, acc

            init = (
                a_loc.rows, a_loc.cols, a_loc.vals,
                b_loc.rows, b_loc.cols, b_loc.vals,
                jnp.zeros((tm_a, tn_b), jnp.float32),
            )
            *_, d_tile = lax.fori_loop(0, grid.pc, stage, init)
            c_tile = lax.psum_scatter(
                d_tile, LAYER_AX, scatter_dimension=1, tiled=True
            )
            return c_tile[None, None, None]
    else:
        def step(a_t: DistSparse, b_t: DistSparse) -> Array:
            a_loc = _squeeze_tile(a_t)
            b_loc = _squeeze_tile(b_t)
            a_cat = _gather_A(a_loc)
            b_cat = _gather_B(b_loc)
            b_dense = b_cat.to_dense()  # (k_tot, tn_b) — narrow by batching
            d_tile = spmm(a_cat, b_dense, semiring)  # (tm, tn_b) accumulator
            # AllToAll-Fiber + Merge-Fiber == reduce-scatter along the fiber
            c_tile = lax.psum_scatter(
                d_tile, LAYER_AX, scatter_dimension=1, tiled=True
            )  # (tm, tn_b/l)
            return c_tile[None, None, None]

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    in_specs = (
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=a.shape, tile_shape=a.tile_shape,
                   grid_shape=a.grid_shape, kind=a.kind),
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=b_batch.shape, tile_shape=b_batch.tile_shape,
                   grid_shape=b_batch.grid_shape, kind=b_batch.kind),
    )
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=in_specs, out_specs=spec3,
        check_vma=False,
    )
    return fn(a, b_batch)


# ---------------------------------------------------------------------------
# Sparse (ESC) path
# ---------------------------------------------------------------------------
def summa3d_sparse_step(
    a: DistSparse, b_batch: DistSparse, grid: Grid, caps: BatchCaps,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    sorted_merge: bool = True,
) -> Tuple[DistSparse, Array]:
    """One batched-SUMMA3D step, sparse path. Returns (C tiles, overflow).

    C tiles come back as a DistSparse with tile shape (tm, tn_b/l); the
    global column mapping is block-cyclic (see batched.batch_column_map).
    overflow > 0 means a static capacity was exceeded — the driver retries
    with the next larger capacity plan (paper robustness, §IV-A).

    ``sorted_merge=True`` runs Merge-Fiber as a segmented k-way merge: the l
    received pieces are column splits of row-major-sorted ESC outputs, so
    they arrive sorted and only need merging, never re-sorting (§IV-D).
    """
    tm_a, _ = a.tile_shape
    _, tn_b = b_batch.tile_shape
    l = grid.l
    assert tn_b % l == 0
    piece_w = tn_b // l

    def step(a_t: DistSparse, b_t: DistSparse):
        a_loc = _squeeze_tile(a_t)
        b_loc = _squeeze_tile(b_t)
        a_cat = _gather_A(a_loc)
        b_cat = _gather_B(b_loc)
        d_tile, ovf_mul = spgemm_esc(
            a_cat, b_cat, out_cap=caps.d_cap, flops_cap=caps.flops_cap,
            semiring=semiring,
        )  # (tm, tn_b) sparse, row-major sorted
        # ColSplit (Alg. 2 line 4): l column pieces, remapped to [0, piece_w)
        pieces_r, pieces_c, pieces_v, pieces_n = [], [], [], []
        ovf_split = jnp.int32(0)
        for k in range(l):
            piece, ovf = d_tile.select_col_block(k * piece_w, piece_w, caps.piece_cap)
            ovf_split = ovf_split + ovf
            pieces_r.append(piece.rows)
            pieces_c.append(piece.cols)
            pieces_v.append(piece.vals)
            pieces_n.append(piece.nnz)
        pr_ = jnp.stack(pieces_r)  # (l, piece_cap)
        pc_ = jnp.stack(pieces_c)
        pv_ = jnp.stack(pieces_v)
        pn_ = jnp.stack(pieces_n)
        # AllToAll-Fiber (Alg. 2 line 5)
        pr_ = lax.all_to_all(pr_, LAYER_AX, split_axis=0, concat_axis=0)
        pc_ = lax.all_to_all(pc_, LAYER_AX, split_axis=0, concat_axis=0)
        pv_ = lax.all_to_all(pv_, LAYER_AX, split_axis=0, concat_axis=0)
        pn_ = lax.all_to_all(pn_[:, None], LAYER_AX, split_axis=0, concat_axis=0)[:, 0]
        # Merge-Fiber (Alg. 2 line 6): sort-free merge of l received pieces
        parts = [
            SparseCOO(pr_[k], pc_[k], pv_[k], pn_[k], (tm_a, piece_w))
            for k in range(l)
        ]
        c_tile, ovf_merge = merge_sparse(
            parts, caps.c_cap, semiring, assume_sorted=sorted_merge
        )
        ovf = ovf_mul + ovf_split + ovf_merge
        ovf_global = lax.pmax(lax.pmax(lax.pmax(ovf, ROW_AX), COL_AX), LAYER_AX)
        return (
            c_tile.rows[None, None, None],
            c_tile.cols[None, None, None],
            c_tile.vals[None, None, None],
            c_tile.nnz[None, None, None],
            ovf_global,
        )

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    spec0 = jax.sharding.PartitionSpec()
    in_specs = (
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=a.shape, tile_shape=a.tile_shape,
                   grid_shape=a.grid_shape, kind=a.kind),
        DistSparse(rows=spec3, cols=spec3, vals=spec3, nnz=spec3,
                   shape=b_batch.shape, tile_shape=b_batch.tile_shape,
                   grid_shape=b_batch.grid_shape, kind=b_batch.kind),
    )
    fn = shard_map(
        step, mesh=grid.mesh, in_specs=in_specs,
        out_specs=(spec3, spec3, spec3, spec3, spec0),
        check_vma=False,
    )
    rows, cols, vals, nnz, ovf = fn(a, b_batch)
    m, n = a.shape
    c = DistSparse(
        rows=rows, cols=cols, vals=vals, nnz=nnz,
        shape=(m, b_batch.shape[1]),
        tile_shape=(tm_a, piece_w),
        grid_shape=a.grid_shape,
        kind="C",
    )
    return c, ovf
