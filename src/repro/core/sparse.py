"""Fixed-capacity sparse matrix formats for JAX.

JAX requires static shapes, so a sparse matrix is stored as padded COO with a
static *capacity* and a dynamic valid count ``nnz``:

    rows : i32[cap]   row index of each entry; padding entries hold ``m`` (sentinel)
    cols : i32[cap]   col index;              padding entries hold ``n``
    vals : f32[cap]   value;                  padding entries hold 0

Invariants (checked by ``tests/test_sparse.py`` property tests):
  * entries [0, nnz) are valid, entries [nnz, cap) are padding
  * sentinel indices are exactly (m, n) so scatter-based ops can route padding
    into a discard bucket and sorts push padding to the end.

This is the JAX analogue of the paper's per-process CSC tiles: capacity plays
the role of the allocation the symbolic step (Alg. 3) sizes. Ops that can
overflow capacity return an ``overflow`` count so callers (the batched driver)
can re-run the symbolic step with a bigger ``b`` — mirroring the paper's
robustness argument (§IV-A).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import sortkeys

Array = jnp.ndarray


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("rows", "cols", "vals", "nnz"),
    meta_fields=("shape",),
)
@dataclasses.dataclass(frozen=True)
class SparseCOO:
    rows: Array  # i32[cap]
    cols: Array  # i32[cap]
    vals: Array  # dtype[cap]
    nnz: Array  # i32 scalar — number of valid entries
    shape: Tuple[int, int]  # static (m, n)

    # ------------------------------------------------------------------ basics
    @property
    def cap(self) -> int:
        return self.rows.shape[0]

    @property
    def dtype(self):
        return self.vals.dtype

    def valid_mask(self) -> Array:
        return jnp.arange(self.cap, dtype=jnp.int32) < self.nnz

    def to_dense(self) -> Array:
        m, n = self.shape
        out = jnp.zeros((m + 1, n + 1), dtype=self.vals.dtype)
        out = out.at[self.rows, self.cols].add(self.vals)
        return out[:m, :n]

    def transpose(self) -> "SparseCOO":
        m, n = self.shape
        return SparseCOO(self.cols, self.rows, self.vals, self.nnz, (n, m))

    # ------------------------------------------------------------- reordering
    def sort_rowmajor(self, engine: str = "auto") -> "SparseCOO":
        """Sort entries by (row, col). Padding (sentinels) sorts to the end.

        ``engine="auto"`` packs (row, col) into one monotonic i32 key and runs
        a single-key ``lax.sort`` (stable — bit-identical to the lexsort path);
        ``"lexsort"`` forces the seed's two-key path (parity reference, and
        the fallback when the packed key would overflow i32).
        """
        m, n = self.shape
        if engine != "lexsort" and sortkeys.fits_i32(m, n):
            key = sortkeys.pack_rowmajor(self.rows, self.cols, n)
            key, vals = jax.lax.sort((key, self.vals), num_keys=1)
            rows, cols = sortkeys.unpack_rowmajor(key, n)
            return SparseCOO(rows, cols, vals, self.nnz, self.shape)
        order = jnp.lexsort((self.cols, self.rows))
        return SparseCOO(
            self.rows[order], self.cols[order], self.vals[order], self.nnz, self.shape
        )

    def sort_colmajor(self, engine: str = "auto") -> "SparseCOO":
        """Sort entries by (col, row) — CSC-like ordering used by local SpGEMM."""
        m, n = self.shape
        if engine != "lexsort" and sortkeys.fits_i32(m, n):
            key = sortkeys.pack_colmajor(self.rows, self.cols, m)
            key, vals = jax.lax.sort((key, self.vals), num_keys=1)
            rows, cols = sortkeys.unpack_colmajor(key, m)
            return SparseCOO(rows, cols, vals, self.nnz, self.shape)
        order = jnp.lexsort((self.rows, self.cols))
        return SparseCOO(
            self.rows[order], self.cols[order], self.vals[order], self.nnz, self.shape
        )

    # ------------------------------------------------------------- reshaping
    def with_capacity(self, new_cap: int) -> "SparseCOO":
        """Grow (pad) or shrink (must have nnz <= new_cap) the capacity."""
        m, n = self.shape
        if new_cap >= self.cap:
            pad = new_cap - self.cap
            rows = jnp.concatenate([self.rows, jnp.full((pad,), m, jnp.int32)])
            cols = jnp.concatenate([self.cols, jnp.full((pad,), n, jnp.int32)])
            vals = jnp.concatenate([self.vals, jnp.zeros((pad,), self.vals.dtype)])
            return SparseCOO(rows, cols, vals, self.nnz, self.shape)
        # Shrink: keep the first new_cap entries (caller guarantees nnz<=new_cap;
        # entries beyond nnz are padding so this is lossless under the invariant).
        return SparseCOO(
            self.rows[:new_cap],
            self.cols[:new_cap],
            self.vals[:new_cap],
            jnp.minimum(self.nnz, new_cap),
            self.shape,
        )

    def compact(self, keep: Array, new_cap: int) -> Tuple["SparseCOO", Array]:
        """Keep entries where ``keep`` (bool[cap]) is set, repacked densely.

        Returns (matrix with capacity ``new_cap``, overflow count). Entries that
        do not fit in ``new_cap`` are dropped and counted in overflow.
        """
        m, n = self.shape
        keep = keep & self.valid_mask()
        pos = jnp.cumsum(keep.astype(jnp.int32)) - 1  # destination slot
        total = jnp.maximum(pos[-1] + 1, 0) if self.cap > 0 else jnp.int32(0)
        write = keep & (pos < new_cap)
        dest = jnp.where(write, pos, new_cap)  # discard bucket at new_cap
        rows = jnp.full((new_cap + 1,), m, jnp.int32).at[dest].set(
            jnp.where(write, self.rows, m)
        )[:new_cap]
        cols = jnp.full((new_cap + 1,), n, jnp.int32).at[dest].set(
            jnp.where(write, self.cols, n)
        )[:new_cap]
        vals = jnp.zeros((new_cap + 1,), self.vals.dtype).at[dest].set(
            jnp.where(write, self.vals, 0)
        )[:new_cap]
        new_nnz = jnp.minimum(total, new_cap).astype(jnp.int32)
        overflow = (total - new_nnz).astype(jnp.int32)
        return SparseCOO(rows, cols, vals, new_nnz, (m, n)), overflow

    # ----------------------------------------------------------- column slicing
    def select_col_block(self, lo, width: int, new_cap: int):
        """Entries with lo <= col < lo+width, columns remapped to [0, width)."""
        m, n = self.shape
        keep = (self.cols >= lo) & (self.cols < lo + width)
        shifted = SparseCOO(
            self.rows,
            jnp.where(keep, self.cols - lo, width),
            self.vals,
            self.nnz,
            (m, width),
        )
        return shifted.compact(keep, new_cap)

    def split_col_blocks(self, num_pieces: int, piece_cap: int):
        """Partitioned ColSplit (Alg. 2 line 4): all ``num_pieces`` column
        pieces in ONE pass instead of ``num_pieces`` sequential
        ``select_col_block`` scans.

        Entry e goes to piece ``col // (n/num_pieces)``; its slot within the
        piece is its rank among same-piece entries (a cumulative one-hot
        count), so the original entry order is preserved per piece — a
        row-major-sorted input yields row-major-sorted pieces, exactly the
        invariant the segmented Merge-Fiber relies on. Columns are remapped
        to [0, n/num_pieces).

        Returns ``(rows, cols, vals, nnz, overflow)`` where the first three
        are (num_pieces, piece_cap) sentinel-padded arrays, ``nnz`` is
        i32[num_pieces], and ``overflow`` counts entries dropped because a
        piece exceeded ``piece_cap``.
        """
        m, n = self.shape
        assert n % num_pieces == 0, (n, num_pieces)
        piece_w = n // num_pieces
        valid = self.valid_mask()
        piece = jnp.where(valid, self.cols // piece_w, num_pieces)
        onehot = (
            piece[:, None] == jnp.arange(num_pieces, dtype=jnp.int32)[None, :]
        ).astype(jnp.int32)  # (cap, num_pieces)
        rank_excl = jnp.cumsum(onehot, axis=0) - onehot  # rank within piece
        rank = jnp.take_along_axis(
            rank_excl, jnp.clip(piece, 0, num_pieces - 1)[:, None], axis=1
        )[:, 0]
        counts = jnp.sum(onehot, axis=0)  # (num_pieces,)
        ok = valid & (piece < num_pieces) & (rank < piece_cap)
        flat = num_pieces * piece_cap
        dest = jnp.where(ok, piece * piece_cap + rank, flat)  # discard bucket
        rows = jnp.full((flat + 1,), m, jnp.int32).at[dest].set(
            jnp.where(ok, self.rows, m)
        )[:flat]
        cols = jnp.full((flat + 1,), piece_w, jnp.int32).at[dest].set(
            jnp.where(ok, self.cols - piece * piece_w, piece_w)
        )[:flat]
        vals = jnp.zeros((flat + 1,), self.vals.dtype).at[dest].set(
            jnp.where(ok, self.vals, 0)
        )[:flat]
        nnz = jnp.minimum(counts, piece_cap)
        overflow = jnp.sum(jnp.maximum(counts - piece_cap, 0)).astype(jnp.int32)
        shape2 = (num_pieces, piece_cap)
        return (
            rows.reshape(shape2), cols.reshape(shape2), vals.reshape(shape2),
            nnz, overflow,
        )

    def select_cols_blockcyclic(
        self, batch, num_batches: int, num_layers: int, new_cap: int
    ):
        """Paper Fig. 1(i): block-cyclic column selection for batch ``batch``.

        The local column range is divided into ``num_batches * num_layers``
        blocks of width w; batch i owns blocks {i, i+b, i+2b, ...} (l of them),
        remapped contiguously. This balances Merge-Fiber load (§IV-B).
        """
        m, n = self.shape
        nblocks = num_batches * num_layers
        assert n % nblocks == 0, f"ncols {n} must divide into {nblocks} blocks"
        w = n // nblocks
        blk = self.cols // w
        keep = (blk % num_batches) == batch
        new_col = (blk // num_batches) * w + self.cols % w
        width = n // num_batches
        shifted = SparseCOO(
            self.rows,
            jnp.where(keep & self.valid_mask(), new_col, width),
            self.vals,
            self.nnz,
            (m, width),
        )
        return shifted.compact(keep, new_cap)

    # ------------------------------------------------------------- statistics
    def col_counts(self) -> Array:
        """nnz per column — i32[n]. Used by the symbolic step (Alg. 3)."""
        m, n = self.shape
        ones = self.valid_mask().astype(jnp.int32)
        return jax.ops.segment_sum(ones, self.cols, num_segments=n + 1)[:n]

    def row_counts(self) -> Array:
        m, n = self.shape
        ones = self.valid_mask().astype(jnp.int32)
        return jax.ops.segment_sum(ones, self.rows, num_segments=m + 1)[:m]

    # -------------------------------------------------------------- pruning
    def prune_threshold(self, thresh, new_cap: int):
        """Drop entries with |val| < thresh (MCL-style pruning)."""
        return self.compact(jnp.abs(self.vals) >= thresh, new_cap)

    def scale_cols(self, scale: Array) -> "SparseCOO":
        """Multiply each column j by scale[j] (MCL column normalization)."""
        m, n = self.shape
        s = jnp.concatenate([scale, jnp.ones((1,), scale.dtype)])
        return SparseCOO(
            self.rows, self.cols, self.vals * s[self.cols], self.nnz, self.shape
        )


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------
def empty(shape: Tuple[int, int], cap: int, dtype=jnp.float32) -> SparseCOO:
    m, n = shape
    return SparseCOO(
        jnp.full((cap,), m, jnp.int32),
        jnp.full((cap,), n, jnp.int32),
        jnp.zeros((cap,), dtype),
        jnp.int32(0),
        shape,
    )


def from_dense(x: Array, cap: int) -> SparseCOO:
    """Jit-compatible dense→COO; entries beyond ``cap`` are dropped."""
    m, n = x.shape
    rows, cols = jnp.nonzero(x, size=cap, fill_value=(m, n))
    nnz = jnp.minimum(jnp.sum(x != 0), cap).astype(jnp.int32)
    vals = jnp.where(jnp.arange(cap) < nnz, x[rows, cols], 0).astype(x.dtype)
    return SparseCOO(rows.astype(jnp.int32), cols.astype(jnp.int32), vals, nnz, (m, n))


def from_dense_overflow(x: Array, cap: int) -> Tuple[SparseCOO, Array]:
    """Jit-compatible dense→COO that also reports how many nonzeros did not
    fit in ``cap`` — the sparsify step of dense-accumulator local multiplies,
    which must follow the same §IV-A overflow-retry discipline as ESC."""
    s = from_dense(x, cap)
    total = jnp.sum(x != 0).astype(jnp.int32)
    return s, jnp.maximum(total - cap, 0)


def from_numpy_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, shape, cap: int = None
) -> SparseCOO:
    """Host-side constructor (dedups duplicate coordinates by summing)."""
    m, n = shape
    key = rows.astype(np.int64) * n + cols.astype(np.int64)
    uniq, inv = np.unique(key, return_inverse=True)
    acc = np.zeros(len(uniq), dtype=vals.dtype)
    np.add.at(acc, inv, vals)
    r, c = (uniq // n).astype(np.int32), (uniq % n).astype(np.int32)
    nnz = len(uniq)
    cap = cap or nnz
    assert cap >= nnz, f"capacity {cap} < nnz {nnz}"
    pr = np.full(cap, m, np.int32)
    pc = np.full(cap, n, np.int32)
    pv = np.zeros(cap, vals.dtype)
    pr[:nnz], pc[:nnz], pv[:nnz] = r, c, acc
    return SparseCOO(jnp.asarray(pr), jnp.asarray(pc), jnp.asarray(pv), jnp.int32(nnz), (m, n))


def coalesce(a: SparseCOO, new_cap: int, engine: str = "auto"):
    """Merge duplicate (row, col) entries by summation; output row-major sorted.

    This is the 'compress' of ESC and the core of the paper's Merge steps for
    the sparse path. Returns (merged, overflow count). ``engine`` selects the
    packed-key sort/compress path (see ``repro.core.sortkeys``): "auto" uses
    the sort-free bucket scan for small key spaces, a single-key packed sort
    otherwise, and "lexsort" pins the seed's two-key reference path.
    """
    m, n = a.shape
    rows, cols, vals, nnz, overflow = sortkeys.coalesce_entries(
        a.rows, a.cols, a.vals, a.valid_mask(), (m, n), new_cap,
        add_kind="sum", engine=engine,
    )
    return SparseCOO(rows, cols, vals, nnz, (m, n)), overflow


def concat(mats, new_cap: int):
    """Stack entry lists of same-shape matrices (no dedup — follow with coalesce)."""
    shape = mats[0].shape
    for x in mats:
        assert x.shape == shape
    rows = jnp.concatenate([x.rows for x in mats])
    cols = jnp.concatenate([x.cols for x in mats])
    vals = jnp.concatenate([x.vals for x in mats])
    # compact valid entries to the front (the stacked entry list interleaves
    # padding, so treat every slot as candidate and mask with `keep`).
    keep = jnp.concatenate([x.valid_mask() for x in mats])
    stacked = SparseCOO(rows, cols, vals, jnp.int32(rows.shape[0]), shape)
    return stacked.compact(keep, new_cap)


def hstack_remap(mats, widths, new_cap: int):
    """Concatenate matrices side by side: block j's columns shift by sum(widths[:j]).

    Used by the batched driver's ColConcat (Alg. 4 line 7) and Merge-Fiber
    column reassembly.
    """
    m = mats[0].shape[0]
    offs = np.concatenate([[0], np.cumsum(widths)]).astype(np.int32)
    total_n = int(offs[-1])
    rows, cols, vals, masks = [], [], [], []
    for x, off, w in zip(mats, offs[:-1], widths):
        assert x.shape[0] == m
        rows.append(x.rows)
        cols.append(jnp.where(x.valid_mask(), x.cols + off, total_n))
        vals.append(x.vals)
        masks.append(x.valid_mask())
    stacked = SparseCOO(
        jnp.concatenate(rows),
        jnp.concatenate(cols),
        jnp.concatenate(vals),
        jnp.int32(sum(x.cap for x in mats)),
        (m, total_n),
    )
    return stacked.compact(jnp.concatenate(masks), new_cap)
