"""Distributed sparse matrices on the 3D grid (paper Fig. 1 distributions).

A ``DistSparse`` stores one padded-COO tile per grid point, stacked into
arrays of shape (pr, pc, l, cap) and sharded with spec P("gr","gc","gl") —
inside ``shard_map`` each device sees its (1,1,1,cap) tile. Indices are
LOCAL tile coordinates; the global↔local maps below implement the paper's
three distributions exactly:

  kind="A": 2D blocks (w × w), each process-column block split column-wise
            into l layer slices → tile (w × w/l).       [Fig. 1(c,d,e)]
  kind="B": 2D blocks (w × w), each process-row block split row-wise into
            l layer slices → tile (w/l × w).            [Fig. 1(f,g,h)]
  kind="C": distributed like A (paper §III-B chooses this).

where w = n_rows/pr (= n_cols/pc; square layer grids). Contraction alignment
(verified by tests): A tile (i,s,k) covers global columns
s·w + k·(w/l) + [0,w/l), and B tile (s,j,k) covers the same global rows —
so per-layer 2D SUMMA contracts stage-s tiles directly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .grid import Grid
from .sparse import SparseCOO, from_numpy_coo

Array = jnp.ndarray


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("rows", "cols", "vals", "nnz"),
    meta_fields=("shape", "tile_shape", "grid_shape", "kind"),
)
@dataclasses.dataclass(frozen=True)
class DistSparse:
    rows: Array  # i32[pr, pc, l, cap] — local tile row indices
    cols: Array  # i32[pr, pc, l, cap]
    vals: Array  # f32[pr, pc, l, cap]
    nnz: Array  # i32[pr, pc, l]
    shape: Tuple[int, int]  # global (m, n)
    tile_shape: Tuple[int, int]  # local (tm, tn)
    grid_shape: Tuple[int, int, int]  # (pr, pc, l)
    kind: str  # "A" | "B" | "C"

    @property
    def cap(self) -> int:
        return self.rows.shape[-1]

    def local(self, i: int, j: int, k: int) -> SparseCOO:
        """Host-side view of one tile (for tests / reassembly)."""
        return SparseCOO(
            self.rows[i, j, k],
            self.cols[i, j, k],
            self.vals[i, j, k],
            self.nnz[i, j, k],
            self.tile_shape,
        )


def tile_shape_for(kind: str, shape: Tuple[int, int], grid: Grid) -> Tuple[int, int]:
    m, n = shape
    if kind in ("A", "C"):
        return (m // grid.pr, n // grid.pc // grid.l)
    if kind == "B":
        return (m // grid.pr // grid.l, n // grid.pc)
    raise ValueError(kind)


def scatter_to_grid(
    a: SparseCOO, grid: Grid, kind: str, cap_slack: float = 1.3, min_cap: int = 8
) -> DistSparse:
    """Host-side: partition a global SparseCOO into grid tiles (paper Fig. 1).

    Capacity = max tile nnz × slack, uniform across tiles (SPMD requires a
    static shape; the slack absorbs mild imbalance, and the symbolic step is
    the principled sizing mechanism for the multiply outputs).
    """
    m, n = a.shape
    pr, pc, l = grid.pr, grid.pc, grid.l
    if kind in ("A", "C"):
        assert m % pr == 0 and n % (pc * l) == 0, (a.shape, (pr, pc, l))
    else:
        assert m % (pr * l) == 0 and n % pc == 0, (a.shape, (pr, pc, l))
    nnz = int(a.nnz)
    g_rows = np.asarray(a.rows[:nnz])
    g_cols = np.asarray(a.cols[:nnz])
    vals = np.asarray(a.vals[:nnz])

    if kind in ("A", "C"):
        w, wl = n // pc, n // pc // l
        ti = g_rows // (m // pr)
        lr = g_rows % (m // pr)
        tj = g_cols // w
        off = g_cols % w
        tk = off // wl
        lc = off % wl
        tm, tn = m // pr, wl
    else:
        w, wl = m // pr, m // pr // l
        ti = g_rows // w
        off = g_rows % w
        tk = off // wl
        lr = off % wl
        tj = g_cols // (n // pc)
        lc = g_cols % (n // pc)
        tm, tn = wl, n // pc

    tile_id = (ti * pc + tj) * l + tk
    counts = np.bincount(tile_id, minlength=pr * pc * l)
    cap = max(int(np.ceil(counts.max() * cap_slack)), min_cap)

    rows_t = np.full((pr * pc * l, cap), tm, np.int32)
    cols_t = np.full((pr * pc * l, cap), tn, np.int32)
    vals_t = np.zeros((pr * pc * l, cap), vals.dtype)
    order = np.argsort(tile_id, kind="stable")
    slot = np.arange(nnz) - np.concatenate([[0], np.cumsum(counts)])[tile_id[order]]
    rows_t[tile_id[order], slot] = lr[order]
    cols_t[tile_id[order], slot] = lc[order]
    vals_t[tile_id[order], slot] = vals[order]

    shard = grid.tile_sharding()
    nnz_shard = jax.sharding.NamedSharding(grid.mesh, jax.sharding.PartitionSpec(*grid.axis_names))
    return DistSparse(
        rows=jax.device_put(rows_t.reshape(pr, pc, l, cap), shard),
        cols=jax.device_put(cols_t.reshape(pr, pc, l, cap), shard),
        vals=jax.device_put(vals_t.reshape(pr, pc, l, cap), shard),
        nnz=jax.device_put(counts.reshape(pr, pc, l).astype(np.int32), nnz_shard),
        shape=(m, n),
        tile_shape=(tm, tn),
        grid_shape=(pr, pc, l),
        kind=kind,
    )


def gather_to_global(d: DistSparse) -> SparseCOO:
    """Host-side inverse of scatter_to_grid (tests / small outputs only)."""
    m, n = d.shape
    pr, pc, l = d.grid_shape
    tm, tn = d.tile_shape
    rows_l, cols_l, vals_l = [], [], []
    R = np.asarray(d.rows)
    C = np.asarray(d.cols)
    V = np.asarray(d.vals)
    N = np.asarray(d.nnz)
    for i in range(pr):
        for j in range(pc):
            for k in range(l):
                cnt = int(N[i, j, k])
                lr, lc = R[i, j, k, :cnt], C[i, j, k, :cnt]
                v = V[i, j, k, :cnt]
                if d.kind in ("A", "C"):
                    w = n // pc
                    wl = w // l
                    gr = i * tm + lr
                    gc = j * w + k * wl + lc
                else:
                    w = m // pr
                    wl = w // l
                    gr = i * w + k * wl + lr
                    gc = j * tn + lc
                rows_l.append(gr)
                cols_l.append(gc)
                vals_l.append(v)
    rows = np.concatenate(rows_l) if rows_l else np.zeros(0, np.int32)
    cols = np.concatenate(cols_l) if cols_l else np.zeros(0, np.int32)
    vals = np.concatenate(vals_l) if vals_l else np.zeros(0, np.float32)
    if len(rows) == 0:
        from .sparse import empty

        return empty((m, n), cap=8)
    return from_numpy_coo(rows, cols, vals, (m, n))
