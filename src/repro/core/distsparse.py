"""Distributed sparse matrices on the 3D grid (paper Fig. 1 distributions).

A ``DistSparse`` stores one padded-COO tile per grid point, stacked into
arrays of shape (pr, pc, l, cap) and sharded with spec P("gr","gc","gl") —
inside ``shard_map`` each device sees its (1,1,1,cap) tile. Indices are
LOCAL tile coordinates; the global↔local maps below implement the paper's
three distributions exactly:

  kind="A": 2D blocks (w × w), each process-column block split column-wise
            into l layer slices → tile (w × w/l).       [Fig. 1(c,d,e)]
  kind="B": 2D blocks (w × w), each process-row block split row-wise into
            l layer slices → tile (w/l × w).            [Fig. 1(f,g,h)]
  kind="C": distributed like A (paper §III-B chooses this).

where w = n_rows/pr (= n_cols/pc; square layer grids). Contraction alignment
(verified by tests): A tile (i,s,k) covers global columns
s·w + k·(w/l) + [0,w/l), and B tile (s,j,k) covers the same global rows —
so per-layer 2D SUMMA contracts stage-s tiles directly.

Column-reduction helpers (device-resident MCL, paper §V-C): a global column
of an A/C-kind matrix lives in the pr tiles of one (j, k) grid column, and a
B-kind column spans the pr×l tiles of one grid column — so per-column
sums/maxima are one local segment reduction plus a ``psum``/``pmax`` over
the owning mesh axes, never a host gather. ``local_col_reduce`` is the
inside-``shard_map`` building block (used by the fused MCL postprocess);
``dist_col_reduce`` is the standalone jitted wrapper over a ``DistSparse``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import shard_map
from .grid import COL_AX, LAYER_AX, ROW_AX, Grid
from .sparse import SparseCOO, from_numpy_coo

Array = jnp.ndarray


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("rows", "cols", "vals", "nnz"),
    meta_fields=("shape", "tile_shape", "grid_shape", "kind"),
)
@dataclasses.dataclass(frozen=True)
class DistSparse:
    rows: Array  # i32[pr, pc, l, cap] — local tile row indices
    cols: Array  # i32[pr, pc, l, cap]
    vals: Array  # f32[pr, pc, l, cap]
    nnz: Array  # i32[pr, pc, l]
    shape: Tuple[int, int]  # global (m, n)
    tile_shape: Tuple[int, int]  # local (tm, tn)
    grid_shape: Tuple[int, int, int]  # (pr, pc, l)
    kind: str  # "A" | "B" | "C"

    @property
    def cap(self) -> int:
        return self.rows.shape[-1]

    def local(self, i: int, j: int, k: int) -> SparseCOO:
        """Host-side view of one tile (for tests / reassembly)."""
        return SparseCOO(
            self.rows[i, j, k],
            self.cols[i, j, k],
            self.vals[i, j, k],
            self.nnz[i, j, k],
            self.tile_shape,
        )


def dist_spec(d: DistSparse, spec) -> DistSparse:
    """The ``shard_map`` in_specs/out_specs pytree for one ``DistSparse``:
    every data field carries ``spec``, the meta fields are copied. Single
    construction site — used by summa3d, the symbolic step, and the MCL
    postprocess, so a new data field only has to be threaded here."""
    return DistSparse(rows=spec, cols=spec, vals=spec, nnz=spec,
                      shape=d.shape, tile_shape=d.tile_shape,
                      grid_shape=d.grid_shape, kind=d.kind)


def tile_shape_for(kind: str, shape: Tuple[int, int], grid: Grid) -> Tuple[int, int]:
    m, n = shape
    if kind in ("A", "C"):
        return (m // grid.pr, n // grid.pc // grid.l)
    if kind == "B":
        return (m // grid.pr // grid.l, n // grid.pc)
    raise ValueError(kind)


def _tile_layout(a: SparseCOO, grid: Grid, kind: str):
    """Tile-index math shared by scatter/count: returns
    ``(tile_id, lr, lc, vals, tm, tn, counts)`` for the block layout of
    ``kind`` on ``grid`` (tile_id row-major over (pr, pc, l))."""
    m, n = a.shape
    pr, pc, l = grid.pr, grid.pc, grid.l
    if kind in ("A", "C"):
        assert m % pr == 0 and n % (pc * l) == 0, (a.shape, (pr, pc, l))
    else:
        assert m % (pr * l) == 0 and n % pc == 0, (a.shape, (pr, pc, l))
    nnz = int(a.nnz)
    g_rows = np.asarray(a.rows[:nnz])
    g_cols = np.asarray(a.cols[:nnz])
    vals = np.asarray(a.vals[:nnz])

    if kind in ("A", "C"):
        w, wl = n // pc, n // pc // l
        ti = g_rows // (m // pr)
        lr = g_rows % (m // pr)
        tj = g_cols // w
        off = g_cols % w
        tk = off // wl
        lc = off % wl
        tm, tn = m // pr, wl
    else:
        w, wl = m // pr, m // pr // l
        ti = g_rows // w
        off = g_rows % w
        tk = off // wl
        lr = off % wl
        tj = g_cols // (n // pc)
        lc = g_cols % (n // pc)
        tm, tn = wl, n // pc

    tile_id = (ti * pc + tj) * l + tk
    counts = np.bincount(tile_id, minlength=pr * pc * l)
    return tile_id, lr, lc, vals, tm, tn, counts


def tile_nnz_counts(a: SparseCOO, grid: Grid, kind: str) -> np.ndarray:
    """Per-tile nnz of ``a`` scattered as ``kind`` on ``grid`` (flat,
    row-major over (pr, pc, l)) WITHOUT moving any data — the input to
    capacity quantization (the serving engine's plan-cache key uses the
    pow2-rounded max so repeat traffic shares one scatter capacity)."""
    *_, counts = _tile_layout(a, grid, kind)
    return counts


def scatter_to_grid(
    a: SparseCOO, grid: Grid, kind: str, cap_slack: float = 1.3,
    min_cap: int = 8, cap: Optional[int] = None,
) -> DistSparse:
    """Host-side: partition a global SparseCOO into grid tiles (paper Fig. 1).

    Capacity = max tile nnz × slack, uniform across tiles (SPMD requires a
    static shape; the slack absorbs mild imbalance, and the symbolic step is
    the principled sizing mechanism for the multiply outputs). An explicit
    ``cap`` overrides the data-derived capacity (it must hold the fullest
    tile) — the serving engine passes a pow2-quantized cap so equally-sized
    inputs land in one static signature.
    """
    m, n = a.shape
    pr, pc, l = grid.pr, grid.pc, grid.l
    tile_id, lr, lc, vals, tm, tn, counts = _tile_layout(a, grid, kind)
    nnz = int(a.nnz)
    if cap is None:
        cap = max(int(np.ceil(counts.max() * cap_slack)), min_cap)
    else:
        assert cap >= counts.max(), (cap, int(counts.max()))

    rows_t = np.full((pr * pc * l, cap), tm, np.int32)
    cols_t = np.full((pr * pc * l, cap), tn, np.int32)
    vals_t = np.zeros((pr * pc * l, cap), vals.dtype)
    order = np.argsort(tile_id, kind="stable")
    slot = np.arange(nnz) - np.concatenate([[0], np.cumsum(counts)])[tile_id[order]]
    rows_t[tile_id[order], slot] = lr[order]
    cols_t[tile_id[order], slot] = lc[order]
    vals_t[tile_id[order], slot] = vals[order]

    shard = grid.tile_sharding()
    nnz_shard = jax.sharding.NamedSharding(grid.mesh, jax.sharding.PartitionSpec(*grid.axis_names))
    return DistSparse(
        rows=jax.device_put(rows_t.reshape(pr, pc, l, cap), shard),
        cols=jax.device_put(cols_t.reshape(pr, pc, l, cap), shard),
        vals=jax.device_put(vals_t.reshape(pr, pc, l, cap), shard),
        nnz=jax.device_put(counts.reshape(pr, pc, l).astype(np.int32), nnz_shard),
        shape=(m, n),
        tile_shape=(tm, tn),
        grid_shape=(pr, pc, l),
        kind=kind,
    )


def gather_to_global(d: DistSparse) -> SparseCOO:
    """Host-side inverse of scatter_to_grid (tests / small outputs only)."""
    m, n = d.shape
    pr, pc, l = d.grid_shape
    tm, tn = d.tile_shape
    rows_l, cols_l, vals_l = [], [], []
    R = np.asarray(d.rows)
    C = np.asarray(d.cols)
    V = np.asarray(d.vals)
    N = np.asarray(d.nnz)
    for i in range(pr):
        for j in range(pc):
            for k in range(l):
                cnt = int(N[i, j, k])
                lr, lc = R[i, j, k, :cnt], C[i, j, k, :cnt]
                v = V[i, j, k, :cnt]
                if d.kind in ("A", "C"):
                    w = n // pc
                    wl = w // l
                    gr = i * tm + lr
                    gc = j * w + k * wl + lc
                else:
                    w = m // pr
                    wl = w // l
                    gr = i * w + k * wl + lr
                    gc = j * tn + lc
                rows_l.append(gr)
                cols_l.append(gc)
                vals_l.append(v)
    rows = np.concatenate(rows_l) if rows_l else np.zeros(0, np.int32)
    cols = np.concatenate(cols_l) if cols_l else np.zeros(0, np.int32)
    vals = np.concatenate(vals_l) if vals_l else np.zeros(0, np.float32)
    if len(rows) == 0:
        from .sparse import empty

        return empty((m, n), cap=8)
    return from_numpy_coo(rows, cols, vals, (m, n))


# ---------------------------------------------------------------------------
# Distributed column reductions (device-resident MCL building blocks)
# ---------------------------------------------------------------------------
def col_reduce_axes(kind: str) -> Tuple[str, ...]:
    """Mesh axes a per-LOCAL-column reduction must be combined over so every
    process reads the reduction of the full GLOBAL column it holds a piece of.

    A/C-kind: a global column is owned by one (grid column, layer) pair and
    split across the pr row blocks → reduce over the row axis. B-kind: a
    global column spans the whole pr×l fiber plane of its grid column.
    """
    if kind in ("A", "C"):
        return (ROW_AX,)
    if kind == "B":
        return (ROW_AX, LAYER_AX)
    raise ValueError(kind)


def local_col_reduce(
    vals: Array, cols: Array, valid: Array, tn: int, op: str = "sum",
    axes: Tuple[str, ...] = (ROW_AX,),
) -> Array:
    """Inside-``shard_map`` per-column reduction: segment-reduce ``vals`` by
    local column, then combine over ``axes`` (``psum`` for sum, ``pmax`` for
    max). Returns f32[tn], replicated along the reduced axes. ``op="max"``
    treats empty columns as 0 (MCL values are nonnegative)."""
    segids = jnp.where(valid, cols, tn)
    v = jnp.where(valid, vals, 0.0)
    if op == "sum":
        out = jax.ops.segment_sum(v, segids, num_segments=tn + 1)[:tn]
        for ax in axes:
            out = lax.psum(out, ax)
    elif op == "max":
        out = jax.ops.segment_max(v, segids, num_segments=tn + 1)[:tn]
        out = jnp.where(jnp.isfinite(out), out, 0.0)  # empty segments -> -inf
        for ax in axes:
            out = lax.pmax(out, ax)
    else:
        raise ValueError(op)
    return out


@partial(jax.jit, static_argnames=("grid", "op"))
def dist_col_reduce(d: DistSparse, grid: Grid, op: str = "sum") -> Array:
    """Per-GLOBAL-column reduction of a ``DistSparse``, computed on-grid.

    Returns a (pr, pc, l, tn) stacked array: entry [i, j, k, c] is the
    reduction (sum or max of values) over the full global column that local
    column ``c`` of tile (i, j, k) belongs to — replicated along the mesh
    axes the reduction ran over (``col_reduce_axes``). No host transfer.

    This is the STANDALONE wrapper (one shard_map per call) for callers and
    tests that need a column reduction outside an existing SPMD step; the
    MCL batch postprocess inlines ``local_col_reduce`` inside its own
    shard_map instead, so normalization fuses with the prune.
    """
    _, tn = d.tile_shape
    axes = col_reduce_axes(d.kind)

    def step(d_t: DistSparse) -> Array:
        t = SparseCOO(
            d_t.rows.reshape(-1), d_t.cols.reshape(-1), d_t.vals.reshape(-1),
            d_t.nnz.reshape(()), d.tile_shape,
        )
        out = local_col_reduce(
            t.vals.astype(jnp.float32), t.cols, t.valid_mask(), tn, op, axes
        )
        return out[None, None, None]

    spec3 = jax.sharding.PartitionSpec(ROW_AX, COL_AX, LAYER_AX)
    fn = shard_map(step, mesh=grid.mesh, in_specs=(dist_spec(d, spec3),),
                   out_specs=spec3, check_vma=False)
    return fn(d)


def dist_col_sums(d: DistSparse, grid: Grid) -> Array:
    """Distributed column sums — see ``dist_col_reduce``."""
    return dist_col_reduce(d, grid, op="sum")
