"""Semiring algebra for SpGEMM.

The paper (§II-A) notes the algorithm applies over an arbitrary semiring S
instead of (R, +, *) since no Strassen-like identities are used. We expose the
semirings needed by the paper's applications:

  plus_times  — numeric SpGEMM (HipMCL / protein similarity, Fig. 3/6/7)
  or_and      — boolean / symbolic multiply (Alg. 3 LocalSymbolic exact-nnz mode)
  min_plus    — shortest-path / tropical
  max_times   — max-reliability (used by MCL-style pruning analyses)
  plus_pair   — pair counting: mul(a,b)=1 — triangle counting (§V-B app (b))

A semiring is (add, mul, zero, add_kind). ``add_kind`` names the monoid so the
compress step of ESC SpGEMM can pick the matching ``jax.ops.segment_*``
reduction (TPU-friendly: segment reductions lower to sorted scatter-adds /
maxes instead of generic loops).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    add_kind: str  # one of: "sum", "min", "max" — selects segment reduction
    mul: Callable[[Array, Array], Array]
    zero: float  # additive identity (also the padding value)

    def segment_reduce(self, vals: Array, segids: Array, num_segments: int) -> Array:
        import jax

        if self.add_kind == "sum":
            return jax.ops.segment_sum(vals, segids, num_segments=num_segments)
        if self.add_kind == "min":
            return jax.ops.segment_min(vals, segids, num_segments=num_segments)
        if self.add_kind == "max":
            return jax.ops.segment_max(vals, segids, num_segments=num_segments)
        raise ValueError(f"unknown add_kind {self.add_kind}")

    def add(self, a: Array, b: Array) -> Array:
        if self.add_kind == "sum":
            return a + b
        if self.add_kind == "min":
            return jnp.minimum(a, b)
        if self.add_kind == "max":
            return jnp.maximum(a, b)
        raise ValueError(self.add_kind)


PLUS_TIMES = Semiring("plus_times", "sum", lambda a, b: a * b, 0.0)
OR_AND = Semiring("or_and", "max", lambda a, b: jnp.minimum(a, b), 0.0)  # on {0,1}
MIN_PLUS = Semiring("min_plus", "min", lambda a, b: a + b, jnp.inf)
MAX_TIMES = Semiring("max_times", "max", lambda a, b: a * b, 0.0)  # nonneg values
PLUS_PAIR = Semiring("plus_pair", "sum", lambda a, b: jnp.ones_like(a), 0.0)

REGISTRY = {s.name: s for s in [PLUS_TIMES, OR_AND, MIN_PLUS, MAX_TIMES, PLUS_PAIR]}


def get(name: str) -> Semiring:
    return REGISTRY[name]
