"""Frozen planning/execution specs — the unified knob surface of the driver.

``plan_batches`` grew 15 keyword knobs and ``batched_summa3d`` 22 as the
paper's features landed (masked planning, k-binning, the hash path, the
retry ladder, iterated-multiply cap pinning). This module collapses them
into three frozen dataclasses so every caller — MCL, APSP, the serving
engine, the autotuner — passes the SAME objects instead of hand-threading
floor kwargs:

  * ``PlanSpec``   — WHAT to plan: mask, local path, slack, reserved bytes,
    k-bin candidates. Pure policy; two calls with the same spec and operands
    produce the same ``BatchPlan``.
  * ``PlanFloors`` — capacity floors carried ACROSS plans: the five
    ``*_floor`` knobs plus ``caps_pow2``, with a monotonic ``merged()``
    (elementwise max, like ``RunReport.merged``) so iterated callers pin the
    fused step's static signature by folding each run's used capacities back
    in. JSON round-trips via ``to_meta``/``from_meta`` so a floors value
    survives a checkpoint (MCL / APSP resilient loops).
  * ``ExecSpec``   — HOW to run: pipelined schedule, lookahead depth, retry
    budget, graceful degradation.

``TunedConfig`` (``repro.tune``) is exactly one of each plus a grid shape,
which is what lets the autotuner emit a config the driver and the serve
admission path consume directly.

Backwards compat: the old keyword surface is still accepted for one release.
``resolve_specs`` maps legacy kwargs onto the spec objects (overriding any
field also set on a passed spec) and emits a single ``DeprecationWarning``
listing them.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

from .summa3d import BatchCaps, BinnedCaps, HashCaps


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """Planning policy for one multiply (see ``plan_batches``).

    ``local_path`` defaults to "auto" — the plan-driven 3-way dispatch.
    Bare ``plan_batches()`` calls (no spec) keep their historical "esc"
    default; a caller who passes a spec opts into the driver's semantics.
    """

    mask: Optional[object] = None  # C-layout DistSparse (§V-B masked plans)
    mask_complement: bool = False
    local_path: str = "auto"  # "auto" | "esc" | "binned" | "hash"
    slack: float = 1.3
    r_bytes: int = 12
    reserved_bytes: int = 0
    force_num_batches: Optional[int] = None
    kbin_candidates: Optional[Tuple[int, ...]] = None
    # Structure-aware placement (core.placement). ``placement`` means "the
    # operands are ALREADY permuted by this Placement": the driver remaps
    # every consumer-facing column map back to original column space (use
    # ``placement.multiply_placed`` for the end-to-end permute/invert).
    # ``distribution`` swaps the planner's tile→batch fold (None resolves
    # to placement.BLOCK_CYCLIC — the only device-executable choice today).
    placement: Optional[object] = None  # core.placement.Placement
    distribution: Optional[object] = None  # core.placement.Distribution

    def replace(self, **kw) -> "PlanSpec":
        return dataclasses.replace(self, **kw)


def _emax(x, y, cls):
    """None-aware elementwise max of two caps dataclasses."""
    if x is None:
        return y
    if y is None:
        return x
    return cls(*(
        max(p, q)
        for p, q in zip(dataclasses.astuple(x), dataclasses.astuple(y))
    ))


@dataclasses.dataclass(frozen=True)
class PlanFloors:
    """Capacity floors carried across plans (iterated-multiply pinning).

    Every field is a FLOOR: the planner takes an elementwise max with its
    own derived value, so floors can only grow capacities, never shrink
    them — which is exactly what keeps the fused step's static signature
    stable (jit-cache hits) as nnz drifts across iterations.

    ``kbin_caps`` doubles as the bin-count pin: when set and the spec
    leaves ``kbin_candidates`` unset, the planner pins the candidate list
    to ``(kbin_caps.num_bins,)`` — one field replaces the old
    ``kbin_candidates`` + ``kbin_caps_floor`` pair every iterated caller
    hand-threaded.
    """

    caps: Optional[BatchCaps] = None
    sel_cap: int = 0
    num_batches: int = 0
    kbin_caps: Optional[BinnedCaps] = None
    hash_caps: Optional[HashCaps] = None
    caps_pow2: bool = False

    def merged(self, other: "PlanFloors") -> "PlanFloors":
        """Monotonic fold (like ``RunReport.merged``): elementwise max, so
        ``a.merged(b)`` dominates both a and b. Mixing floors with different
        pinned bin counts is a caller bug (two incompatible static
        signatures) and raises."""
        if (
            self.kbin_caps is not None
            and other.kbin_caps is not None
            and self.kbin_caps.num_bins != other.kbin_caps.num_bins
        ):
            raise ValueError(
                f"cannot merge floors with different pinned bin counts "
                f"({self.kbin_caps.num_bins} vs {other.kbin_caps.num_bins})"
            )
        return PlanFloors(
            caps=_emax(self.caps, other.caps, BatchCaps),
            sel_cap=max(self.sel_cap, other.sel_cap),
            num_batches=max(self.num_batches, other.num_batches),
            kbin_caps=_emax(self.kbin_caps, other.kbin_caps, BinnedCaps),
            hash_caps=_emax(self.hash_caps, other.hash_caps, HashCaps),
            caps_pow2=self.caps_pow2 or other.caps_pow2,
        )

    def replace(self, **kw) -> "PlanFloors":
        return dataclasses.replace(self, **kw)

    def to_meta(self) -> dict:
        """JSON-safe encoding (checkpoint sidecars, serve snapshots)."""
        enc = lambda x: None if x is None else [
            int(v) for v in dataclasses.astuple(x)
        ]
        return {
            "caps": enc(self.caps),
            "sel_cap": int(self.sel_cap),
            "num_batches": int(self.num_batches),
            "kbin_caps": enc(self.kbin_caps),
            "hash_caps": enc(self.hash_caps),
            "caps_pow2": bool(self.caps_pow2),
        }

    @classmethod
    def from_meta(cls, d: Optional[dict]) -> "PlanFloors":
        if not d:
            return cls()
        dec = lambda v, c: None if v is None else c(*(int(x) for x in v))
        return cls(
            caps=dec(d.get("caps"), BatchCaps),
            sel_cap=int(d.get("sel_cap", 0)),
            num_batches=int(d.get("num_batches", 0)),
            kbin_caps=dec(d.get("kbin_caps"), BinnedCaps),
            hash_caps=dec(d.get("hash_caps"), HashCaps),
            caps_pow2=bool(d.get("caps_pow2", False)),
        )


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """Execution policy for the batched driver (schedule + robustness)."""

    pipelined: bool = True
    lookahead: int = 2
    max_retries: int = 4
    degrade: bool = True
    sorted_merge: bool = True
    binned: object = "auto"  # legacy 2-way override; prefer PlanSpec.local_path

    def replace(self, **kw) -> "ExecSpec":
        return dataclasses.replace(self, **kw)


# legacy keyword -> spec field, one map per spec object
_PLAN_KEYS = {
    "mask": "mask",
    "mask_complement": "mask_complement",
    "local_path": "local_path",
    "slack": "slack",
    "r_bytes": "r_bytes",
    "reserved_bytes": "reserved_bytes",
    "force_num_batches": "force_num_batches",
    "kbin_candidates": "kbin_candidates",
}
_FLOOR_KEYS = {
    "caps_floor": "caps",
    "sel_cap_floor": "sel_cap",
    "num_batches_floor": "num_batches",
    "kbin_caps_floor": "kbin_caps",
    "hash_caps_floor": "hash_caps",
    "caps_pow2": "caps_pow2",
}
_EXEC_KEYS = {
    "pipelined": "pipelined",
    "lookahead": "lookahead",
    "max_retries": "max_retries",
    "degrade": "degrade",
    "sorted_merge": "sorted_merge",
    "binned": "binned",
}


def resolve_specs(
    spec: Optional[PlanSpec],
    floors: Optional[PlanFloors],
    exec_spec: Optional[ExecSpec],
    legacy: dict,
    *,
    default_local_path: str = "auto",
    where: str = "batched_summa3d",
    allow_exec: bool = True,
) -> Tuple[PlanSpec, PlanFloors, ExecSpec]:
    """Normalize (spec, floors, exec_spec, **legacy) to the three specs.

    Legacy kwargs are accepted for one release: each is mapped onto its spec
    field (overriding the passed spec) under a single ``DeprecationWarning``.
    Unknown kwargs raise ``TypeError`` exactly like a real signature.
    """
    if spec is not None and not isinstance(spec, PlanSpec):
        raise TypeError(
            f"{where}: spec must be a PlanSpec, got {type(spec).__name__} "
            f"(old positional keyword arguments must be passed by name)"
        )
    if floors is not None and not isinstance(floors, PlanFloors):
        raise TypeError(
            f"{where}: floors must be a PlanFloors, got {type(floors).__name__}"
        )
    if spec is None:
        spec = PlanSpec(local_path=default_local_path)
    floors = floors if floors is not None else PlanFloors()
    ex = exec_spec if exec_spec is not None else ExecSpec()
    if legacy:
        known = set(_PLAN_KEYS) | set(_FLOOR_KEYS)
        if allow_exec:
            known |= set(_EXEC_KEYS)
        unknown = set(legacy) - known
        if unknown:
            raise TypeError(
                f"{where}() got unexpected keyword argument(s) "
                f"{sorted(unknown)}"
            )
        warnings.warn(
            f"{where}: keyword argument(s) {sorted(legacy)} are deprecated; "
            f"pass PlanSpec / PlanFloors / ExecSpec instead",
            DeprecationWarning,
            stacklevel=3,
        )
        spec = spec.replace(**{
            _PLAN_KEYS[k]: v for k, v in legacy.items() if k in _PLAN_KEYS
        })
        floors = floors.replace(**{
            _FLOOR_KEYS[k]: v for k, v in legacy.items() if k in _FLOOR_KEYS
        })
        if allow_exec:
            ex = ex.replace(**{
                _EXEC_KEYS[k]: v for k, v in legacy.items() if k in _EXEC_KEYS
            })
    return spec, floors, ex
