"""3D process grid for SUMMA (paper §III-B).

A grid is `pr × pc × l` with mesh axes ("gr", "gc", "gl"): process rows,
process columns, layers. `P(:,:,k)` is layer k (a 2D SUMMA grid), and
`P(i,j,:)` is a *fiber* (AllToAll-Fiber runs along it).

The paper uses square per-layer grids (pr == pc == sqrt(p/l)); we enforce the
same. The production mapping folds the training mesh axes onto the grid:
("data" → gr, "model" → gc, "pod" → gl).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import AxisType, make_mesh as _make_mesh

ROW_AX, COL_AX, LAYER_AX = "gr", "gc", "gl"


@dataclasses.dataclass(frozen=True)
class Grid:
    mesh: jax.sharding.Mesh
    pr: int
    pc: int
    l: int

    @property
    def p(self) -> int:
        return self.pr * self.pc * self.l

    @property
    def axis_names(self) -> Tuple[str, str, str]:
        return (ROW_AX, COL_AX, LAYER_AX)

    def tile_sharding(self) -> NamedSharding:
        """Sharding for (pr, pc, l, ...) stacked per-tile arrays."""
        return NamedSharding(self.mesh, P(ROW_AX, COL_AX, LAYER_AX))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def make_grid(pr: int, pc: int, l: int, devices: Optional[Sequence] = None) -> Grid:
    """Build a pr×pc×l grid mesh. Layers must be square (pr == pc) or the
    grid single-layer (l == 1): rectangular per-layer grids only align the
    contraction slices when there is one layer (see host_symbolic_counts)."""
    assert pr == pc or l == 1, \
        f"need square per-layer grids or l == 1, got {pr}x{pc}x{l}"
    ndev = pr * pc * l
    if devices is None:
        devices = jax.devices()[:ndev]
    assert len(devices) >= ndev, f"need {ndev} devices, have {len(devices)}"
    import numpy as np

    dev_array = np.asarray(devices[:ndev]).reshape(pr, pc, l)
    mesh = _make_mesh(
        dev_array,
        (ROW_AX, COL_AX, LAYER_AX),
        axis_types=(AxisType.Auto,) * 3,
    )
    return Grid(mesh, pr, pc, l)


def grid_from_mesh(
    mesh: jax.sharding.Mesh,
    row_axis: str = "data",
    col_axis: str = "model",
    layer_axis: Optional[str] = "pod",
) -> Grid:
    """Reinterpret a training mesh as a SUMMA grid (production path).

    A single-pod ("data", "model") mesh becomes an l=1 grid; a multi-pod
    ("pod", "data", "model") mesh maps pods to layers — the communication-
    avoiding dimension spans the slowest links, which is exactly where the
    paper's analysis says replication pays off (broadcasts shrink by sqrt(l)
    within pods; only the fiber all-to-all crosses pods).
    """
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    pr, pc = sizes[row_axis], sizes[col_axis]
    l = sizes.get(layer_axis, 1) if layer_axis else 1
    assert pr == pc, f"square per-layer grid required, got {pr}x{pc}"
    # reorder devices to (gr, gc, gl)
    perm = [names.index(row_axis), names.index(col_axis)]
    if layer_axis and layer_axis in names:
        perm.append(names.index(layer_axis))
        dev = mesh.devices.transpose(perm)
    else:
        dev = mesh.devices.transpose(perm)[..., None]
    new_mesh = _make_mesh(
        dev, (ROW_AX, COL_AX, LAYER_AX), axis_types=(AxisType.Auto,) * 3
    )
    return Grid(new_mesh, pr, pc, l)


def square_grid_for(p: int, l: int) -> Tuple[int, int, int]:
    """Paper's grid shape: sqrt(p/l) × sqrt(p/l) × l."""
    side = math.isqrt(p // l)
    assert side * side * l == p, f"p={p} not expressible as s*s*{l}"
    return side, side, l
