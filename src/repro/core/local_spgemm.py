"""Local (per-process) SpGEMM algorithms — the paper's §IV-D layer, TPU-adapted.

The paper replaces sorted heap accumulation with *sort-free hash* SpGEMM/merge
on CPUs. On TPU there is no efficient per-lane random scatter, so we adapt the
insight (unsorted accumulation into a direct-addressed structure) as:

  * ``spgemm_dense_acc`` — scatter/accumulate into a **dense accumulator**
    (a perfect hash table with the identity hash). Batching (Alg. 4) makes the
    output column block narrow, so the accumulator fits on-chip; this is the
    default local multiply of the batched distributed algorithm and is backed
    by a Pallas VMEM kernel (``repro.kernels.spgemm_acc``).
  * ``spgemm_esc`` — expand–sort–compress, keeping *inputs unsorted* and only
    producing sorted output at the final compress, mirroring the paper's
    sortedness observation. Sorting maps to TPU-friendly sorting networks.
  * ``spgemm_kbinned`` — k-binned paired multiply (``kernels/spgemm_binned``):
    counting-sort both operands by contraction range, pair only matching bins,
    accumulate dense, sparsify. Same (C, overflow) contract as ``spgemm_esc``;
    the batch plan picks between them per workload.
  * ``spmm`` — sparse × dense (used by MoE dispatch and the dense-acc path).
  * ``local_symbolic`` — Alg. 3's LocalSymbolic: flops (upper bound) and exact
    output nnz of a local product, without forming values.

All functions are jit-compatible with static capacities.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import semiring as sr
from . import sortkeys
from . import sparse as sparse_mod
from .sparse import SparseCOO

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# SpMM: sparse A (m×k) times dense B (k×n) -> dense (m×n)
# ---------------------------------------------------------------------------
def spmm(a: SparseCOO, b_dense: Array, semiring: sr.Semiring = sr.PLUS_TIMES) -> Array:
    """Gather rows of B by A's column index, scale, segment-reduce by A's row.

    O(cap_A × n) work, fully vectorized; the Pallas kernel in
    ``repro.kernels.spmm`` implements the same contraction with VMEM tiling.
    """
    m, k = a.shape
    assert b_dense.shape[0] == k, (a.shape, b_dense.shape)
    n = b_dense.shape[1]
    # pad B with a zero row for sentinel column indices
    b_pad = jnp.concatenate([b_dense, jnp.zeros((1, n), b_dense.dtype)], axis=0)
    gathered = b_pad[a.cols]  # (cap, n)
    prods = semiring.mul(a.vals[:, None], gathered)
    prods = jnp.where(a.valid_mask()[:, None], prods, semiring.zero)
    out = semiring.segment_reduce(prods, a.rows, num_segments=m + 1)[:m]
    if semiring.add_kind != "sum":
        out = jnp.where(jnp.isfinite(out), out, semiring.zero)  # empty segments
    return out


# ---------------------------------------------------------------------------
# Dense-accumulator SpGEMM: sparse × sparse -> dense block
# ---------------------------------------------------------------------------
def spgemm_dense_acc(
    a: SparseCOO,
    b: SparseCOO,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    *,
    out_cap: int = None,
    flops_cap: int = None,
    return_overflow: bool = False,
) -> Array:
    """C = A·B with a dense (m × n_b) accumulator.

    TPU-native local multiply for the batched algorithm: ``b`` is a narrow
    column block (n_b = n/(b·grid)), so the dense accumulator is small. B is
    scattered to dense once (its nnz is small per batch), then a single SpMM
    streams A's nonzeros through the accumulator.

    min/max semirings can't use a 0-initialized dense B (structural zeros
    would participate), so they route through ``spgemm_esc`` and densify the
    sparse result onto a ``semiring.zero`` background. ``out_cap``/``flops_cap``
    bound that fallback's static capacities. The defaults (m*n_b and
    cap_A*cap_B) are hard upper bounds — overflow is impossible with them.
    Callers passing *tighter* symbolic-step caps must set
    ``return_overflow=True`` (returns ``(dense, overflow)``) and check it,
    as a beaten estimate silently drops contributions otherwise (§IV-A
    retry discipline). For sum semirings overflow is always 0 (the dense
    accumulator cannot overflow).
    """
    m, k = a.shape
    k2, nb = b.shape
    assert k == k2, (a.shape, b.shape)
    if semiring.add_kind == "sum":
        b_dense = b.to_dense()
        out = spmm(a, b_dense, semiring)
        return (out, jnp.int32(0)) if return_overflow else out
    out_cap = out_cap if out_cap is not None else m * nb
    flops_cap = flops_cap if flops_cap is not None else max(a.cap * b.cap, 1)
    c, overflow = spgemm_esc(
        a, b, out_cap=out_cap, flops_cap=flops_cap, semiring=semiring
    )
    dense = jnp.full((m + 1, nb + 1), semiring.zero, c.vals.dtype)
    safe_vals = jnp.where(c.valid_mask(), c.vals, semiring.zero)
    if semiring.add_kind == "min":
        dense = dense.at[c.rows, c.cols].min(safe_vals)
    else:
        dense = dense.at[c.rows, c.cols].max(safe_vals)
    out = dense[:m, :nb]
    return (out, overflow) if return_overflow else out


# ---------------------------------------------------------------------------
# ESC SpGEMM: expand - sort - compress (sparse × sparse -> sparse)
# ---------------------------------------------------------------------------
def _expand(a_csc: SparseCOO, b: SparseCOO, flops_cap: int, semiring: sr.Semiring):
    """Enumerate all partial products of A·B.

    ``a_csc`` must be column-major sorted. For each valid B entry t=(k,j,vB),
    the products are A's column-k entries scaled by vB. Expansion uses the
    standard offsets+cumsum trick with a static bound ``flops_cap``.

    Returns (rows, cols, vals, valid, total_flops) each of length flops_cap.
    """
    m, k_dim = a_csc.shape
    _, n = b.shape
    # column pointer of A: start of each column in the sorted entry list
    colcount = a_csc.col_counts()  # i32[k]
    colptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(colcount).astype(jnp.int32)]
    )  # i32[k+1]
    ccount_pad = jnp.concatenate([colcount, jnp.zeros((1,), jnp.int32)])
    colptr_pad = jnp.concatenate([colptr, jnp.zeros((1,), jnp.int32)])

    bm = b.valid_mask()
    cnt = jnp.where(bm, ccount_pad[b.cols], 0)  # products per B entry (cap_b,)
    starts = jnp.cumsum(cnt) - cnt  # segment starts (exclusive cumsum)
    total = starts[-1] + cnt[-1] if b.cap > 0 else jnp.int32(0)

    # B-entry index per expanded slot e: scatter t at each (non-empty) segment
    # start, then running max. Segments tile [starts[t], starts[t]+cnt[t])
    # contiguously, so the largest start <= e identifies e's segment.
    e = jnp.arange(flops_cap, dtype=jnp.int32)
    starts_clip = jnp.where((cnt > 0) & (starts < flops_cap), starts, flops_cap)
    tvals = jnp.arange(b.cap, dtype=jnp.int32)
    buf = jnp.zeros((flops_cap + 1,), jnp.int32).at[starts_clip].max(tvals)
    t_of_e = jax.lax.cummax(buf[:flops_cap])
    t_of_e = jnp.clip(t_of_e, 0, b.cap - 1)
    within = e - starts[t_of_e]  # offset within A's column
    valid = (e < jnp.minimum(total, flops_cap)) & (within >= 0)

    bk = b.cols[t_of_e]  # contraction index k
    ai = colptr_pad[bk] + within  # index into sorted A entries
    ai = jnp.clip(ai, 0, a_csc.cap - 1)
    out_rows = jnp.where(valid, a_csc.rows[ai], m)
    out_cols = jnp.where(valid, b.rows[t_of_e], n)  # note: B entry (k, j) -> col j
    vals = semiring.mul(a_csc.vals[ai], b.vals[t_of_e])
    vals = jnp.where(valid, vals, semiring.zero)
    return out_rows, out_cols, vals, valid, total


def spgemm_esc(
    a: SparseCOO,
    b: SparseCOO,
    out_cap: int,
    flops_cap: int,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    a_is_colsorted: bool = False,
    engine: str = "auto",
    mask_keys: Array = None,
    mask_complement: bool = False,
) -> Tuple[SparseCOO, Array]:
    """Sparse × sparse → sparse via expand–sort–compress.

    Inputs need not be sorted (paper §IV-D: sort-free inputs); only the final
    output is row-major sorted. Returns (C, overflow-count) where overflow > 0
    means out_cap or flops_cap was too small (caller increases b / capacity).

    ``mask_keys`` (ascending packed row-major (row, col) keys of the output
    space, from ``sortkeys.sorted_mask_keys``) switches on the masked
    (filtered-semiring) formulation: expanded partial products are
    intersected against the mask BEFORE the compress, so only surviving
    coordinates consume ``out_cap`` — C = (A·B) ⊙ M for
    ``mask_complement=False``, C = (A·B) ⊙ ¬M for ``mask_complement=True``.
    Coordinate filtering commutes with the coordinate-wise merge, so this is
    exact for every semiring.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    a_csc = a if a_is_colsorted else a.sort_colmajor()
    # B entries as (k, j): transpose so cols hold k, rows hold j
    bt = b.transpose()  # shape (n, k); entries (j, k) with rows=j? No: see below
    # SparseCOO(b).transpose() swaps arrays: rows=old cols (j->k?), careful:
    # b entry is (row=k, col=j). After transpose: row=j, col=k, shape (n, k).
    rows, cols, vals, valid, total = _expand(a_csc, bt, flops_cap, semiring)
    flop_overflow = jnp.maximum(total - flops_cap, 0)
    if mask_keys is not None:
        key = sortkeys.pack_rowmajor(rows, cols, n)
        hit = sortkeys.keys_in_sorted(key, mask_keys)
        valid = valid & (~hit if mask_complement else hit)

    expanded = SparseCOO(rows, cols, vals, jnp.int32(flops_cap), (m, n))
    # compress: packed-key engine (bucket scan / single-key sort — the one
    # ordering step of the whole pipeline; see repro.core.sortkeys)
    merged, overflow = _coalesce_semiring(expanded, valid, out_cap, semiring, engine)
    return merged, overflow + flop_overflow


def _coalesce_semiring(
    x: SparseCOO, valid: Array, new_cap: int, semiring: sr.Semiring,
    engine: str = "auto",
):
    """coalesce() generalized over semirings; `valid` marks live entries.

    Dispatches to the packed-key engine (``repro.core.sortkeys``): sort-free
    bucket scan for small key spaces, single-key packed sort otherwise,
    ``engine="lexsort"`` for the seed's two-key reference path.
    """
    m, n = x.shape
    rows, cols, vals, nnz, overflow = sortkeys.coalesce_entries(
        x.rows, x.cols, x.vals, valid, (m, n), new_cap,
        add_kind=semiring.add_kind, engine=engine,
    )
    return SparseCOO(rows, cols, vals, nnz, (m, n)), overflow


def spgemm_hash(
    a: SparseCOO,
    b: SparseCOO,
    out_cap: int,
    table_cap: int,
    chunk_cap: int,
    num_chunks: int,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    a_is_colsorted: bool = False,
    mask_keys: Array = None,
    mask_complement: bool = False,
    max_probes: int = 32,
    use_pallas: bool = None,
    interpret: bool = None,
) -> Tuple[SparseCOO, Array]:
    """Sparse × sparse → sparse via a hash accumulator — O(output) scratch.

    The paper's memory-constrained claim wants partial products consumed on
    the fly, not materialized: unlike ``spgemm_esc`` (whole O(flops_cap)
    expansion, then sort+compress), this path enumerates the expansion in
    ``num_chunks`` reused chunks of ``chunk_cap`` partial products and inserts
    each chunk into an open-addressing table of ``table_cap`` slots
    (``kernels.spgemm_hash``), semiring-accumulating on probe hits. Resident
    scratch is O(table_cap + chunk_cap) = O(nnz(C)·load_factor + const)
    instead of O(flops) — the win the plan budgets when the compression
    factor flops/nnz(C) is high.

    Masked entries are rejected *at insert* (membership probe of the packed
    key against ``mask_keys``, same strict/complement semantics as
    ``spgemm_esc``), so the table only ever holds survivors.

    Output contract matches ``spgemm_esc`` exactly: (row-major-sorted C,
    overflow) where overflow counts dropped inserts (table full /
    ``max_probes`` beaten), enumeration beyond ``num_chunks·chunk_cap``
    flops, and ``out_cap`` violations — one device-resident flag the batched
    driver's retry ladder handles unchanged.
    """
    from ..kernels import spgemm_hash as hashkern

    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert table_cap >= 8 and table_cap & (table_cap - 1) == 0, table_cap
    assert sortkeys.fits_i32(m, n), (m, n)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    a_csc = a if a_is_colsorted else a.sort_colmajor()
    bt = b.transpose()  # entries (j, k): rows=j, cols=k
    colcount = a_csc.col_counts()
    colptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(colcount).astype(jnp.int32)]
    )
    ccount_pad = jnp.concatenate([colcount, jnp.zeros((1,), jnp.int32)])
    colptr_pad = jnp.concatenate([colptr, jnp.zeros((1,), jnp.int32)])
    bm = bt.valid_mask()
    cnt = jnp.where(bm, ccount_pad[bt.cols], 0)  # products per B entry
    cum = jnp.cumsum(cnt).astype(jnp.int32)  # inclusive prefix
    total = cum[-1] if bt.cap > 0 else jnp.int32(0)

    add_kind = semiring.add_kind
    table_key0 = jnp.full((table_cap,), hashkern.EMPTY, jnp.int32)
    table_val0 = jnp.full(
        (table_cap,), hashkern.table_init_val(add_kind), a.vals.dtype
    )

    def chunk_body(c, carry):
        tk, tv, dropped = carry
        # enumerate expansion slots [c·chunk_cap, (c+1)·chunk_cap): the B
        # entry of slot e is the first t with cum[t] > e (rank in the
        # inclusive prefix — empty segments are skipped by construction)
        e = c * chunk_cap + jnp.arange(chunk_cap, dtype=jnp.int32)
        t = jnp.searchsorted(cum, e, side="right").astype(jnp.int32)
        t = jnp.clip(t, 0, bt.cap - 1)
        within = e - (cum[t] - cnt[t])
        valid = e < total
        bk = bt.cols[t]  # contraction index k
        ai = jnp.clip(colptr_pad[bk] + within, 0, a_csc.cap - 1)
        rows = a_csc.rows[ai]
        cols = bt.rows[t]  # B entry (k, j) -> output col j
        vals = semiring.mul(a_csc.vals[ai], bt.vals[t])
        key = sortkeys.pack_rowmajor(rows, cols, n)
        if mask_keys is not None:
            hit = sortkeys.keys_in_sorted(key, mask_keys)
            valid = valid & (~hit if mask_complement else hit)
        tk, tv, drop = hashkern.hash_insert(
            tk, tv, key, vals, valid, add_kind=add_kind,
            max_probes=max_probes, use_pallas=use_pallas, interpret=interpret,
        )
        return tk, tv, dropped + drop

    table_key, table_val, dropped = jax.lax.fori_loop(
        0, num_chunks, chunk_body, (table_key0, table_val0, jnp.int32(0))
    )
    flop_overflow = jnp.maximum(total - num_chunks * chunk_cap, 0)

    # table → sorted COO: EMPTY (INT32_MAX) sorts after every real key and
    # the row-major sentinel, so one sort + sentinel compress finalizes
    skey, svals = jax.lax.sort((table_key, table_val), num_keys=1)
    sent = jnp.int32(sortkeys.key_space(m, n) - 1)
    okey, ovals, nnz, ovf_out = sortkeys.compress_sorted_keys(
        skey, svals, sent, out_cap, add_kind=add_kind
    )
    orows, ocols = sortkeys.unpack_rowmajor(okey, n)
    c_out = SparseCOO(orows, ocols, ovals, nnz, (m, n))
    return c_out, ovf_out + flop_overflow + dropped


def spgemm_kbinned(
    a: SparseCOO,
    b: SparseCOO,
    out_cap: int,
    num_bins: int,
    bin_cap_a: int,
    bin_cap_b: int,
    bin_of_k: Array = None,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    mask: SparseCOO = None,
    mask_complement: bool = False,
) -> Tuple[SparseCOO, Array]:
    """Sparse × sparse → sparse via the k-binned paired kernel.

    Both operands are counting-sorted into ``num_bins`` contraction ranges
    (``bin_of_k`` — a monotone map from ``symbolic.plan_k_bins`` — absorbs
    skewed-k distributions) and only matching bins are paired:
    O(Σ_g capA_g×capB_g) pairings instead of O(capA×capB). The paired
    accumulation lands in a dense (m, n) block (narrow under batching), which
    is then sparsified to ``out_cap`` entries, row-major sorted — the same
    output contract as ``spgemm_esc``, so the two are interchangeable behind
    the batch plan's switch.

    Requires the plus_times semiring (the pairing kernel accumulates with
    + and ×). Returns (C, overflow) where overflow counts both bin-capacity
    and ``out_cap`` violations (§IV-A retry discipline).

    ``mask`` (a SparseCOO over the output space) applies the masked-SpGEMM
    filter on the dense accumulator before sparsification — the dense-path
    twin of ``spgemm_esc``'s packed-key intersect, with the same
    strict/complement semantics — so ``out_cap`` only pays for survivors.
    """
    from ..kernels.spgemm_binned import spgemm_binned_dense

    assert semiring.name == "plus_times", (
        f"k-binned paired multiply requires plus_times, got {semiring.name}"
    )
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    # gathered operands declare every slot live and rely on sentinel-k
    # padding — mask on the contraction index, not just nnz
    a_valid = a.valid_mask() & (a.cols < k)
    b_valid = b.valid_mask() & (b.rows < k)
    av = jnp.where(a_valid, a.vals, 0)
    bv = jnp.where(b_valid, b.vals, 0)
    on_tpu = jax.default_backend() == "tpu"
    dense, ovf_bin = spgemm_binned_dense(
        a.rows, a.cols, av, a_valid, b.rows, b.cols, bv, b_valid,
        m, n, k, num_bins, bin_cap_a, bin_cap_b, bin_map=bin_of_k,
        use_pallas=on_tpu, interpret=not on_tpu,
    )
    if mask is not None:
        dense = jnp.where(mask_indicator(mask, mask_complement), dense, 0.0)
    # the pairing kernel accumulates f32; restore the input dtype so the
    # binned and ESC paths stay interchangeable behind the plan switch
    c, ovf_out = sparse_mod.from_dense_overflow(dense.astype(a.dtype), out_cap)
    return c, ovf_bin + ovf_out


def mask_indicator(mask: SparseCOO, complement: bool = False) -> Array:
    """bool (m, n): mask membership as a dense indicator (sentinel-safe).

    The dense-accumulator counterpart of the packed-key mask intersect:
    scatter a presence bit per mask entry, flip for the complement mode.
    Used by the k-binned local multiply and the dense SUMMA path, where the
    product already lives in a dense block.
    """
    m, n = mask.shape
    ind = (
        jnp.zeros((m + 1, n + 1), jnp.int32)
        .at[mask.rows, mask.cols]
        .max(mask.valid_mask().astype(jnp.int32))
    )[:m, :n] > 0
    return ~ind if complement else ind


def merge_sparse(
    parts,
    out_cap: int,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    assume_sorted: bool = False,
    engine: str = "auto",
):
    """Merge-Layer / Merge-Fiber for the sparse path: sum duplicate coords.

    Paper §IV-D hash-merge, TPU-adapted. Two regimes:

      * ``assume_sorted=False`` — inputs unsorted; one packed-key coalesce
        over the concatenated entry lists (bucket scan or single-key sort).
      * ``assume_sorted=True`` — every part is already row-major sorted (true
        for ESC outputs and their column-split pieces, i.e. exactly what
        Merge-Fiber receives), so the parts are *merged*, not re-sorted: a
        segmented k-way merge-path over packed keys (ceil(log2 l) rank/scatter
        rounds), then a linear compress. No sort anywhere.

    Returns (merged, overflow).
    """
    shape = parts[0].shape
    for x in parts:
        assert x.shape == shape
    m, n = shape
    if assume_sorted and engine != "lexsort" and sortkeys.fits_i32(m, n):
        # padding carries (m, n) sentinels == max key, so each part's packed
        # key array is ascending end-to-end and merges keep sentinels last
        keys = [sortkeys.pack_rowmajor(x.rows, x.cols, n) for x in parts]
        vals = [x.vals for x in parts]
        mkey, mvals = sortkeys.merge_sorted_runs(keys, vals)
        sent = jnp.int32(sortkeys.key_space(m, n) - 1)
        okey, ovals, nnz, overflow = sortkeys.compress_sorted_keys(
            mkey, mvals, sent, out_cap, add_kind=semiring.add_kind
        )
        orows, ocols = sortkeys.unpack_rowmajor(okey, n)
        return SparseCOO(orows, ocols, ovals, nnz, (m, n)), overflow
    rows = jnp.concatenate([x.rows for x in parts])
    cols = jnp.concatenate([x.cols for x in parts])
    vals = jnp.concatenate([x.vals for x in parts])
    valid = jnp.concatenate([x.valid_mask() for x in parts])
    stacked = SparseCOO(rows, cols, vals, jnp.int32(rows.shape[0]), shape)
    return _coalesce_semiring(stacked, valid, out_cap, semiring, engine)


# ---------------------------------------------------------------------------
# Symbolic local multiply (Alg. 3 LocalSymbolic)
# ---------------------------------------------------------------------------
def local_symbolic_flops(a: SparseCOO, b: SparseCOO) -> Array:
    """Number of partial products (flops/2) of A·B = Σ_t nnz(A(:, B.row_t)).

    Upper bound on nnz of the *unmerged* local product — exactly what Alg. 3
    accumulates per stage (the per-process unmerged D bound).
    """
    colcount = a.col_counts()
    ccount_pad = jnp.concatenate([colcount, jnp.zeros((1,), jnp.int32)])
    return jnp.sum(jnp.where(b.valid_mask(), ccount_pad[b.rows], 0))


def local_symbolic_exact(
    a: SparseCOO, b: SparseCOO, flops_cap: int, engine: str = "auto"
) -> Array:
    """Exact nnz(A·B) via a boolean ESC without forming values (structure only).

    The distinct-coordinate count runs on the packed-key engine: the bucket
    scan needs no sort at all, the packed fallback sorts one bare key array
    (no payload) — either way, never a two-key lexsort.
    """
    m, _ = a.shape
    _, n = b.shape
    a_csc = a.sort_colmajor()
    bt = b.transpose()
    rows, cols, _, valid, total = _expand(a_csc, bt, flops_cap, sr.PLUS_TIMES)
    return sortkeys.count_unique(rows, cols, valid, (m, n), engine=engine)


def nnz_per_col_upper(a_colcounts: Array, b: SparseCOO) -> Array:
    """Per-output-column flops upper bound: ub[j] = Σ_{k in B(:,j)} nnz(A(:,k)).

    Vector form of LocalSymbolic used by the distributed symbolic step to pick
    per-batch capacities (col counts of A travel instead of tiles — the
    lightweight payload that makes Alg. 3 cheap).
    """
    _, n = b.shape
    cc = jnp.concatenate([a_colcounts, jnp.zeros((1,), a_colcounts.dtype)])
    contrib = jnp.where(b.valid_mask(), cc[b.rows], 0)
    return jax.ops.segment_sum(contrib, b.cols, num_segments=n + 1)[:n]
