"""Local (per-process) SpGEMM algorithms — the paper's §IV-D layer, TPU-adapted.

The paper replaces sorted heap accumulation with *sort-free hash* SpGEMM/merge
on CPUs. On TPU there is no efficient per-lane random scatter, so we adapt the
insight (unsorted accumulation into a direct-addressed structure) as:

  * ``spgemm_dense_acc`` — scatter/accumulate into a **dense accumulator**
    (a perfect hash table with the identity hash). Batching (Alg. 4) makes the
    output column block narrow, so the accumulator fits on-chip; this is the
    default local multiply of the batched distributed algorithm and is backed
    by a Pallas VMEM kernel (``repro.kernels.spgemm_acc``).
  * ``spgemm_esc`` — expand–sort–compress, keeping *inputs unsorted* and only
    producing sorted output at the final compress, mirroring the paper's
    sortedness observation. Sorting maps to TPU-friendly sorting networks.
  * ``spmm`` — sparse × dense (used by MoE dispatch and the dense-acc path).
  * ``local_symbolic`` — Alg. 3's LocalSymbolic: flops (upper bound) and exact
    output nnz of a local product, without forming values.

All functions are jit-compatible with static capacities.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import semiring as sr
from .sparse import SparseCOO, empty

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# SpMM: sparse A (m×k) times dense B (k×n) -> dense (m×n)
# ---------------------------------------------------------------------------
def spmm(a: SparseCOO, b_dense: Array, semiring: sr.Semiring = sr.PLUS_TIMES) -> Array:
    """Gather rows of B by A's column index, scale, segment-reduce by A's row.

    O(cap_A × n) work, fully vectorized; the Pallas kernel in
    ``repro.kernels.spmm`` implements the same contraction with VMEM tiling.
    """
    m, k = a.shape
    assert b_dense.shape[0] == k, (a.shape, b_dense.shape)
    n = b_dense.shape[1]
    # pad B with a zero row for sentinel column indices
    b_pad = jnp.concatenate([b_dense, jnp.zeros((1, n), b_dense.dtype)], axis=0)
    gathered = b_pad[a.cols]  # (cap, n)
    prods = semiring.mul(a.vals[:, None], gathered)
    prods = jnp.where(a.valid_mask()[:, None], prods, semiring.zero)
    out = semiring.segment_reduce(prods, a.rows, num_segments=m + 1)[:m]
    if semiring.add_kind != "sum":
        out = jnp.where(jnp.isfinite(out), out, semiring.zero)  # empty segments
    return out


# ---------------------------------------------------------------------------
# Dense-accumulator SpGEMM: sparse × sparse -> dense block
# ---------------------------------------------------------------------------
def spgemm_dense_acc(
    a: SparseCOO, b: SparseCOO, semiring: sr.Semiring = sr.PLUS_TIMES
) -> Array:
    """C = A·B with a dense (m × n_b) accumulator.

    TPU-native local multiply for the batched algorithm: ``b`` is a narrow
    column block (n_b = n/(b·grid)), so the dense accumulator is small. B is
    scattered to dense once (its nnz is small per batch), then a single SpMM
    streams A's nonzeros through the accumulator.
    """
    m, k = a.shape
    k2, nb = b.shape
    assert k == k2, (a.shape, b.shape)
    if semiring.add_kind == "sum":
        b_dense = b.to_dense()
        return spmm(a, b_dense, semiring)
    # min/max semirings can't use a 0-initialized dense B (0 entries would
    # participate); fall back to ESC for those.
    raise ValueError(
        f"dense-accumulator path requires sum-monoid semiring, got {semiring.name}"
    )


# ---------------------------------------------------------------------------
# ESC SpGEMM: expand - sort - compress (sparse × sparse -> sparse)
# ---------------------------------------------------------------------------
def _expand(a_csc: SparseCOO, b: SparseCOO, flops_cap: int, semiring: sr.Semiring):
    """Enumerate all partial products of A·B.

    ``a_csc`` must be column-major sorted. For each valid B entry t=(k,j,vB),
    the products are A's column-k entries scaled by vB. Expansion uses the
    standard offsets+cumsum trick with a static bound ``flops_cap``.

    Returns (rows, cols, vals, valid, total_flops) each of length flops_cap.
    """
    m, k_dim = a_csc.shape
    _, n = b.shape
    # column pointer of A: start of each column in the sorted entry list
    colcount = a_csc.col_counts()  # i32[k]
    colptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(colcount).astype(jnp.int32)]
    )  # i32[k+1]
    ccount_pad = jnp.concatenate([colcount, jnp.zeros((1,), jnp.int32)])
    colptr_pad = jnp.concatenate([colptr, jnp.zeros((1,), jnp.int32)])

    bm = b.valid_mask()
    cnt = jnp.where(bm, ccount_pad[b.cols], 0)  # products per B entry (cap_b,)
    starts = jnp.cumsum(cnt) - cnt  # segment starts (exclusive cumsum)
    total = starts[-1] + cnt[-1] if b.cap > 0 else jnp.int32(0)

    # B-entry index per expanded slot e: scatter t at each (non-empty) segment
    # start, then running max. Segments tile [starts[t], starts[t]+cnt[t])
    # contiguously, so the largest start <= e identifies e's segment.
    e = jnp.arange(flops_cap, dtype=jnp.int32)
    starts_clip = jnp.where((cnt > 0) & (starts < flops_cap), starts, flops_cap)
    tvals = jnp.arange(b.cap, dtype=jnp.int32)
    buf = jnp.zeros((flops_cap + 1,), jnp.int32).at[starts_clip].max(tvals)
    t_of_e = jax.lax.cummax(buf[:flops_cap])
    t_of_e = jnp.clip(t_of_e, 0, b.cap - 1)
    within = e - starts[t_of_e]  # offset within A's column
    valid = (e < jnp.minimum(total, flops_cap)) & (within >= 0)

    bk = b.cols[t_of_e]  # contraction index k
    ai = colptr_pad[bk] + within  # index into sorted A entries
    ai = jnp.clip(ai, 0, a_csc.cap - 1)
    out_rows = jnp.where(valid, a_csc.rows[ai], m)
    out_cols = jnp.where(valid, b.rows[t_of_e], n)  # note: B entry (k, j) -> col j
    vals = semiring.mul(a_csc.vals[ai], b.vals[t_of_e])
    vals = jnp.where(valid, vals, semiring.zero)
    return out_rows, out_cols, vals, valid, total


def spgemm_esc(
    a: SparseCOO,
    b: SparseCOO,
    out_cap: int,
    flops_cap: int,
    semiring: sr.Semiring = sr.PLUS_TIMES,
    a_is_colsorted: bool = False,
) -> Tuple[SparseCOO, Array]:
    """Sparse × sparse → sparse via expand–sort–compress.

    Inputs need not be sorted (paper §IV-D: sort-free inputs); only the final
    output is row-major sorted. Returns (C, overflow-count) where overflow > 0
    means out_cap or flops_cap was too small (caller increases b / capacity).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    a_csc = a if a_is_colsorted else a.sort_colmajor()
    # B entries as (k, j): transpose so cols hold k, rows hold j
    bt = b.transpose()  # shape (n, k); entries (j, k) with rows=j? No: see below
    # SparseCOO(b).transpose() swaps arrays: rows=old cols (j->k?), careful:
    # b entry is (row=k, col=j). After transpose: row=j, col=k, shape (n, k).
    rows, cols, vals, valid, total = _expand(a_csc, bt, flops_cap, semiring)
    flop_overflow = jnp.maximum(total - flops_cap, 0)

    expanded = SparseCOO(rows, cols, vals, jnp.int32(flops_cap), (m, n))
    # coalesce = sort + segment-reduce (the single sort of the whole pipeline)
    merged, overflow = _coalesce_semiring(expanded, valid, out_cap, semiring)
    return merged, overflow + flop_overflow


def _coalesce_semiring(
    x: SparseCOO, valid: Array, new_cap: int, semiring: sr.Semiring
):
    """coalesce() generalized over semirings; `valid` marks live entries."""
    m, n = x.shape
    # push invalid entries to the end by sentinel keys, then sort row-major
    rows = jnp.where(valid, x.rows, m)
    cols = jnp.where(valid, x.cols, n)
    order = jnp.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = x.vals[order]
    vmask = rows < m
    new_key = jnp.ones((x.cap,), dtype=bool)
    if x.cap > 1:
        same = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        new_key = new_key.at[1:].set(~same)
    new_key = new_key & vmask
    seg = jnp.cumsum(new_key.astype(jnp.int32)) - 1
    total = jnp.maximum(seg[-1] + 1, 0)
    seg = jnp.where(vmask & (seg < new_cap), seg, new_cap)
    out_rows = jnp.full((new_cap + 1,), m, jnp.int32).at[seg].min(rows)[:new_cap]
    out_cols = jnp.full((new_cap + 1,), n, jnp.int32).at[seg].min(cols)[:new_cap]
    if semiring.add_kind == "sum":
        buf = jnp.zeros((new_cap + 1,), vals.dtype).at[seg].add(vals)
    elif semiring.add_kind == "min":
        buf = jnp.full((new_cap + 1,), jnp.inf, vals.dtype).at[seg].min(vals)
    else:  # max
        buf = jnp.full((new_cap + 1,), -jnp.inf, vals.dtype).at[seg].max(vals)
    out_vals = buf[:new_cap]
    nnz = jnp.minimum(total, new_cap).astype(jnp.int32)
    pad = jnp.arange(new_cap) >= nnz
    out_rows = jnp.where(pad, m, out_rows)
    out_cols = jnp.where(pad, n, out_cols)
    out_vals = jnp.where(pad, 0, out_vals).astype(x.vals.dtype)
    overflow = (total - nnz).astype(jnp.int32)
    return SparseCOO(out_rows, out_cols, out_vals, nnz, (m, n)), overflow


def merge_sparse(parts, out_cap: int, semiring: sr.Semiring = sr.PLUS_TIMES):
    """Merge-Layer / Merge-Fiber for the sparse path: sum duplicate coords.

    Paper §IV-D hash-merge, TPU-adapted as one sort + segment-reduce over the
    concatenated (unsorted!) entry lists — inputs stay unsorted, only the
    merged result is sorted.
    """
    shape = parts[0].shape
    for x in parts:
        assert x.shape == shape
    rows = jnp.concatenate([x.rows for x in parts])
    cols = jnp.concatenate([x.cols for x in parts])
    vals = jnp.concatenate([x.vals for x in parts])
    valid = jnp.concatenate([x.valid_mask() for x in parts])
    stacked = SparseCOO(rows, cols, vals, jnp.int32(rows.shape[0]), shape)
    return _coalesce_semiring(stacked, valid, out_cap, semiring)


# ---------------------------------------------------------------------------
# Symbolic local multiply (Alg. 3 LocalSymbolic)
# ---------------------------------------------------------------------------
def local_symbolic_flops(a: SparseCOO, b: SparseCOO) -> Array:
    """Number of partial products (flops/2) of A·B = Σ_t nnz(A(:, B.row_t)).

    Upper bound on nnz of the *unmerged* local product — exactly what Alg. 3
    accumulates per stage (the per-process unmerged D bound).
    """
    colcount = a.col_counts()
    ccount_pad = jnp.concatenate([colcount, jnp.zeros((1,), jnp.int32)])
    return jnp.sum(jnp.where(b.valid_mask(), ccount_pad[b.rows], 0))


def local_symbolic_exact(a: SparseCOO, b: SparseCOO, flops_cap: int) -> Array:
    """Exact nnz(A·B) via a boolean ESC without forming values (structure only)."""
    m, _ = a.shape
    _, n = b.shape
    a_csc = a.sort_colmajor()
    bt = b.transpose()
    rows, cols, _, valid, total = _expand(a_csc, bt, flops_cap, sr.PLUS_TIMES)
    rows = jnp.where(valid, rows, m)
    cols = jnp.where(valid, cols, n)
    order = jnp.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vmask = rows < m
    new_key = jnp.ones((flops_cap,), dtype=bool)
    if flops_cap > 1:
        same = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        new_key = new_key.at[1:].set(~same)
    return jnp.sum(new_key & vmask).astype(jnp.int32)


def nnz_per_col_upper(a_colcounts: Array, b: SparseCOO) -> Array:
    """Per-output-column flops upper bound: ub[j] = Σ_{k in B(:,j)} nnz(A(:,k)).

    Vector form of LocalSymbolic used by the distributed symbolic step to pick
    per-batch capacities (col counts of A travel instead of tiles — the
    lightweight payload that makes Alg. 3 cheap).
    """
    _, n = b.shape
    cc = jnp.concatenate([a_colcounts, jnp.zeros((1,), a_colcounts.dtype)])
    contrib = jnp.where(b.valid_mask(), cc[b.rows], 0)
    return jax.ops.segment_sum(contrib, b.cols, num_segments=n + 1)[:n]
