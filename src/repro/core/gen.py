"""Synthetic sparse matrix generators matching the paper's workload regimes.

The paper's matrices (protein-similarity networks, Friendster, k-mer matrices;
Table V) are not shippable in this container, so benchmarks use generators
with matched *statistics*: nnz/row, skew (R-MAT power law vs uniform
Erdős–Rényi), and compression factor cf = flops / nnz(C).

All generators are host-side (numpy) — data loading is outside the jit
boundary, as a real data pipeline would be.
"""
from __future__ import annotations

import numpy as np

from .sparse import SparseCOO, from_numpy_coo


def erdos_renyi(
    n: int,
    avg_nnz_per_row: float,
    seed: int = 0,
    square: bool = True,
    ncols: int = None,
    dtype=np.float32,
    cap: int = None,
) -> SparseCOO:
    """Uniform random sparse matrix (the paper's ER comparison regime)."""
    rng = np.random.default_rng(seed)
    ncols = n if square else (ncols or n)
    nnz_target = int(n * avg_nnz_per_row)
    rows = rng.integers(0, n, nnz_target)
    cols = rng.integers(0, ncols, nnz_target)
    vals = rng.uniform(0.5, 1.0, nnz_target).astype(dtype)
    return from_numpy_coo(rows, cols, vals, (n, ncols), cap=cap)


def rmat(
    scale: int,
    edge_factor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    dtype=np.float32,
    cap: int = None,
) -> SparseCOO:
    """R-MAT power-law graph (Friendster/protein-network-like skew).

    n = 2**scale vertices, ~edge_factor*n edges, Graph500 (a,b,c,d) params.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    nedges = edge_factor * n
    rows = np.zeros(nedges, np.int64)
    cols = np.zeros(nedges, np.int64)
    ab, abc = a + b, a + b + c
    for lvl in range(scale):
        r = rng.random(nedges)
        go_right = ((r >= a) & (r < ab)) | (r >= abc)
        go_down = r >= ab
        rows |= go_down.astype(np.int64) << lvl
        cols |= go_right.astype(np.int64) << lvl
    vals = rng.uniform(0.5, 1.0, nedges).astype(dtype)
    return from_numpy_coo(rows, cols, vals, (n, n), cap=cap)


def protein_similarity_like(
    n: int, blocks: int, intra_p: float, seed: int = 0, dtype=np.float32, cap: int = None
) -> SparseCOO:
    """Stochastic block structure mimicking protein-similarity networks
    (dense-ish clusters, sparse background) — the HipMCL input regime where
    nnz(A^2) >> nnz(A)."""
    rng = np.random.default_rng(seed)
    bs = n // blocks
    rows_l, cols_l = [], []
    for bi in range(blocks):
        size = bs if bi < blocks - 1 else n - bs * (blocks - 1)
        cnt = rng.binomial(size * size, intra_p)
        rows_l.append(rng.integers(0, size, cnt) + bi * bs)
        cols_l.append(rng.integers(0, size, cnt) + bi * bs)
    # sparse background
    bg = max(n // 2, 1)
    rows_l.append(rng.integers(0, n, bg))
    cols_l.append(rng.integers(0, n, bg))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    # symmetrize + self loops (MCL requires them)
    rows, cols = np.concatenate([rows, cols, np.arange(n)]), np.concatenate(
        [cols, rows, np.arange(n)]
    )
    vals = np.random.default_rng(seed + 1).uniform(0.3, 1.0, len(rows)).astype(dtype)
    return from_numpy_coo(rows, cols, vals, (n, n), cap=cap)


def symmetrized(a: SparseCOO) -> SparseCOO:
    """Undirected unit-weight graph from any square pattern: symmetrize and
    drop self loops (the triangle-counting input shape, §V-B)."""
    n = a.shape[0]
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    keep = r2 != c2
    return from_numpy_coo(
        r2[keep], c2[keep], np.ones(int(keep.sum()), np.float32), (n, n)
    )


def kmer_like(
    nseqs: int, nkmers: int, kmers_per_seq: int, seed: int = 0, dtype=np.float32,
    cap: int = None,
) -> SparseCOO:
    """Rice-kmers-like rectangular matrix (rows=sequences, cols=k-mers, ~2
    nnz per column) for the AA^T overlap benchmark (§V-G)."""
    rng = np.random.default_rng(seed)
    nnz = nseqs * kmers_per_seq
    rows = np.repeat(np.arange(nseqs), kmers_per_seq)
    cols = rng.integers(0, nkmers, nnz)
    vals = np.ones(nnz, dtype)
    return from_numpy_coo(rows, cols, vals, (nseqs, nkmers), cap=cap)
