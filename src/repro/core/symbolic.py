"""Symbolic analysis: how many batches b does the multiply need? (Paper §IV-A)

Three estimators, all exposed so tests can verify the paper's ordering
``lower_bound <= b_exact <= b_flops``:

  * ``batch_count_lower_bound`` — Eq. (2): information-theoretic floor from
    mem(C) and aggregate memory M.
  * ``batch_count`` — Alg. 3 line 12: b from the *max per-process* unmerged
    nnz (robust to load imbalance; may exceed the lower bound).
  * per-column upper bounds (``nnz_per_col_upper``) used to size static
    capacities for each batch (JAX needs static shapes — the symbolic step is
    exactly the paper's "symbolic-then-numeric" split, it just also fixes
    buffer capacities here).

The distributed version (communication pattern of Alg. 3) lives in
``repro.core.batched``; this module holds the math.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

#: bytes per nonzero. Paper uses r=24 (two i64 indices + f64 value); our
#: TPU-native default is r=12 (two i32 local indices + f32 value). The
#: constant is a parameter everywhere it matters.
R_BYTES_PAPER = 24
R_BYTES_DEFAULT = 12


@dataclasses.dataclass(frozen=True)
class SymbolicCounts:
    """Host-side output of the symbolic pass (all numpy).

    Only count *vectors* ever travel (§IV-A, Fig. 8) — the same payload now
    also carries what the numeric pass needs to size selection buffers and
    the k-bin plan, so no extra communication round is spent on either.
    ``mask_colcounts`` (masked multiplies only) holds the mask's exact
    per-(tile, local column) entry counts — the §V-B observation that a
    strict mask bounds C's structure, so the batch plan can budget survivors
    instead of the full product.

    Two producers, one consumer (``batched.plan_from_symbolic``): the
    distributed pass (``batched.symbolic3d_counts``, counts computed ON the
    grid the operands live on) and the no-device oracle below
    (``host_symbolic_counts``, counts computed from host COO for ANY
    candidate grid shape — the autotuner's way of pricing grids the
    operands were never scattered to).
    """

    percol: np.ndarray  # (pr, pc, l, tn_b) flops per local output column
    b_colcounts: np.ndarray  # (pr, pc, l, tn_b) B entries per local column
    a_kcounts: np.ndarray  # (pr, l, k_tot) per-k counts of gathered A
    b_kcounts: np.ndarray  # (pc, l, k_tot) per-k counts of gathered B
    mask_colcounts: np.ndarray = None  # (pr, pc, l, wl) mask nnz, or None


def _host_triplets(a):
    """(rows, cols) of the live entries of a host COO (duck-typed: anything
    with ``rows``/``cols``/``nnz`` numpy-convertible attributes works)."""
    nnz = int(a.nnz)
    return (
        np.asarray(a.rows[:nnz]).astype(np.int64),
        np.asarray(a.cols[:nnz]).astype(np.int64),
    )


def host_tile_counts(a, grid_shape, kind: str) -> np.ndarray:
    """Per-tile nnz of ``a`` laid out as ``kind`` on a CANDIDATE grid shape
    — pure host math (mirrors ``distsparse._tile_layout``'s indexing without
    building tiles or touching a device). Returns (pr, pc, l)."""
    pr, pc, l = grid_shape
    m, n = a.shape
    rows, cols = _host_triplets(a)
    if kind in ("A", "C"):
        assert m % pr == 0 and n % (pc * l) == 0, (a.shape, grid_shape)
        w, wl = n // pc, n // pc // l
        ti = rows // (m // pr)
        tj = cols // w
        tk = (cols % w) // wl
    else:
        assert m % (pr * l) == 0 and n % pc == 0, (a.shape, grid_shape)
        w, wl = m // pr, m // pr // l
        ti = rows // w
        tk = (rows % w) // wl
        tj = cols // (n // pc)
    tile_id = (ti * pc + tj) * l + tk
    return np.bincount(tile_id, minlength=pr * pc * l).reshape(pr, pc, l)


def host_symbolic_counts(a, b, grid_shape, mask=None) -> SymbolicCounts:
    """The symbolic pass as a host ORACLE: exact per-column flops / count
    vectors for ``a``·``b`` distributed on a candidate ``grid_shape`` —
    without scattering anything or touching a device.

    Reproduces ``batched._symbolic3d_jit`` bit-for-bit (asserted by tests):
    A's per-(row block, layer, stage-k) counts contracted against B's
    entries through the stage coordinate k_idx = s·wl + local row. Layer
    grids must be square (pr == pc) OR single-layer (l == 1): with one
    layer the stage coordinate equals the global contraction index on both
    sides, so rectangular pr×pc×1 grids align; with l > 1 the per-layer
    slicing only lines up when pr == pc. This is what lets the autotuner
    enumerate (pr, pc, l) candidates from one pass over the host COO per
    candidate, no trial multiplies.
    """
    pr, pc, l = grid_shape
    assert pr == pc or l == 1, \
        f"square layer grids or l == 1 only, got {grid_shape}"
    m_a, k_dim = a.shape
    k_dim_b, n_b = b.shape
    assert k_dim == k_dim_b, (a.shape, b.shape)
    w_a, wl_a = k_dim // pc, k_dim // pc // l
    assert m_a % pr == 0 and k_dim % (pc * l) == 0, (a.shape, grid_shape)
    assert k_dim % (pr * l) == 0 and n_b % pc == 0, (b.shape, grid_shape)
    tn_b = n_b // pc
    k_tot = pc * wl_a

    # A: per-(row block, layer, stage coordinate) column counts — the host
    # image of cc_full = all_gather(col_counts, COL_AX) per (i, k)
    ar, ac = _host_triplets(a)
    a_i = ar // (m_a // pr)
    a_k = (ac % w_a) // wl_a
    a_q = (ac // w_a) * wl_a + (ac % wl_a)
    acc = np.zeros((pr, l, k_tot), np.int64)
    np.add.at(acc, (a_i, a_k, a_q), 1)

    # B: tile coordinates + stage coordinate k_idx = s*wl + local row
    br, bc = _host_triplets(b)
    w_b, wl_b = k_dim // pr, k_dim // pr // l
    b_s = br // w_b
    b_k = (br % w_b) // wl_b
    b_lr = br % wl_b
    b_j = bc // tn_b
    b_lc = bc % tn_b
    b_q = b_s * wl_b + b_lr

    bcc = np.zeros((pr, pc, l, tn_b), np.int64)
    np.add.at(bcc, (b_s, b_j, b_k, b_lc), 1)
    bkc = np.zeros((pc, l, k_tot), np.int64)
    np.add.at(bkc, (b_j, b_k, b_q), 1)

    # percol[i, j, k, c] = Σ over B entries of (grid col j, layer k, local
    # col c): A's stage-k_idx count in row block i — vectorized as one
    # weighted bincount per row block over the (j, k, c) key
    key = (b_j * l + b_k) * tn_b + b_lc
    percol = np.zeros((pr, pc * l * tn_b), np.int64)
    for i in range(pr):
        percol[i] = np.round(np.bincount(
            key, weights=acc[i, b_k, b_q], minlength=pc * l * tn_b
        )).astype(np.int64)
    percol = percol.reshape(pr, pc, l, tn_b)

    mcc = None
    if mask is not None:
        assert mask.shape == (m_a, n_b), (mask.shape, a.shape, b.shape)
        w_c, wl_c = n_b // pc, n_b // pc // l
        mr, mc_ = _host_triplets(mask)
        mcc = np.zeros((pr, pc, l, wl_c), np.int64)
        np.add.at(mcc, (
            mr // (m_a // pr), mc_ // w_c, (mc_ % w_c) // wl_c, mc_ % wl_c,
        ), 1)

    return SymbolicCounts(
        percol=percol, b_colcounts=bcc, a_kcounts=acc, b_kcounts=bkc,
        mask_colcounts=mcc,
    )


@dataclasses.dataclass(frozen=True)
class SymbolicResult:
    """Host-side outcome of the symbolic step (all python ints)."""

    num_batches: int
    max_unmerged_nnz: int  # max over processes of unmerged output nnz (b=1)
    max_nnz_a: int
    max_nnz_b: int
    flops: int  # total multiply count (2*flops = FLOPs)
    lower_bound: int  # Eq. (2)

    def per_batch_capacity(self, slack: float = 1.25) -> int:
        """Static per-process unmerged capacity to allocate for one batch."""
        cap = int(math.ceil(self.max_unmerged_nnz / max(self.num_batches, 1) * slack))
        return max(cap, 8)


def batch_count_lower_bound(
    mem_c_bytes: int, total_memory: int, nnz_a: int, nnz_b: int, r: int = R_BYTES_DEFAULT
) -> int:
    """Paper Eq. (2): b >= ceil(mem(C) / (M - r(nnz(A)+nnz(B))))."""
    denom = total_memory - r * (nnz_a + nnz_b)
    if denom <= 0:
        raise MemoryError(
            f"inputs alone ({r * (nnz_a + nnz_b)}B) exceed aggregate memory "
            f"({total_memory}B) — paper precondition M > r(nnz(A)+nnz(B)) violated"
        )
    return max(1, math.ceil(mem_c_bytes / denom))


def batch_count(
    max_unmerged_nnz: int,
    max_nnz_a: int,
    max_nnz_b: int,
    per_process_memory: int,
    r: int = R_BYTES_DEFAULT,
) -> int:
    """Paper Alg. 3 line 12: b = ceil(r*maxnnzC / (M/p - r(maxnnzA+maxnnzB))).

    Uses per-process *maxima* so no process exhausts memory under load
    imbalance (§IV-A: "robust to different sparsity patterns").
    """
    denom = per_process_memory - r * (max_nnz_a + max_nnz_b)
    if denom <= 0:
        raise MemoryError(
            f"per-process inputs ({r * (max_nnz_a + max_nnz_b)}B) exceed "
            f"per-process memory ({per_process_memory}B)"
        )
    return max(1, math.ceil(r * max_unmerged_nnz / denom))


def batching_plan_columns(n: int, num_batches: int, num_layers: int) -> int:
    """Round b up so the block-cyclic split divides the column dimension.

    Returns the adjusted batch count. Paper Fig. 1(i): each batch is l blocks
    of width n/(b*l); we need (b*l) | n.
    """
    b = num_batches
    b_max = n // num_layers  # finest split: one block-cyclic block per batch
    if b > b_max:
        raise MemoryError(
            f"need {num_batches} batches but only {b_max} column batches exist "
            f"({n} cols / {num_layers} layers) — aggregate memory insufficient "
            f"even at the finest batching granularity (paper precondition)"
        )
    while n % (b * num_layers) != 0:
        b += 1
        if b > b_max:
            raise MemoryError(
                f"cannot split {n} columns into >= {num_batches} batches with "
                f"{num_layers} layers"
            )
    return b


def fold_block_cyclic(
    percol: np.ndarray, num_batches: int, num_layers: int
) -> np.ndarray:
    """Fold per-local-column vectors (..., n) into per-(batch, piece) sums.

    The block-cyclic split (paper Fig. 1(i)) divides n local columns into
    ``num_batches * num_layers`` blocks of width w = n/(b·l); block t belongs
    to batch ``t % b`` and fiber piece ``t // b``. Returns an array of shape
    (..., num_batches, num_layers) — the host-side math behind both the
    per-batch flops capacities and the exact per-batch selection counts.
    """
    *lead, n = percol.shape
    w = n // (num_batches * num_layers)
    assert w * num_batches * num_layers == n, (n, num_batches, num_layers)
    blocks = percol.reshape(*lead, num_layers, num_batches, w).sum(axis=-1)
    return np.swapaxes(blocks, -1, -2)  # (..., batch, piece)


@dataclasses.dataclass(frozen=True)
class KBinPlan:
    """Host-side plan for the k-binned paired kernel (all python ints).

    Sizes the static per-bin capacities of ``repro.kernels.spgemm_binned``
    from the *exact* per-k entry counts (``SparseCOO.col_counts`` of A /
    ``row_counts`` of B) — the same lightweight count vectors the distributed
    symbolic step already moves (§IV-A), reused here to bound pairing work.
    """

    num_bins: int
    bin_cap_a: int
    bin_cap_b: int
    pairings: int  # num_bins * bin_cap_a * bin_cap_b (block-rounded upstream)
    pairings_unbinned: int  # cap_a * cap_b
    bin_of_k: np.ndarray  # monotone i32[k_dim] map k -> bin


def plan_k_bins(
    a_col_counts: np.ndarray,
    b_row_counts: np.ndarray,
    cap_a: int,
    cap_b: int,
    candidates=(1, 2, 4, 8, 16, 32, 64),
    slack: float = 1.0,
) -> KBinPlan:
    """Pick bin boundaries + count minimizing Σ_g capA_g × capB_g (host math).

    For each candidate G two boundary families are scored and the cheaper
    wins: equal-width k-ranges (bin(k) = k*G // k_dim) and quantile-balanced
    ranges that cut the *combined* count mass (a+b) into equal slices — the
    latter is what absorbs skewed-k (R-MAT-like) distributions where a few k
    values carry most entries. Capacities are maxima over bins of the exact
    counts (so ``slack=1.0`` cannot overflow). On a distribution concentrated
    in a single k no boundary helps and the planner falls back to G=1 —
    binning never hurts correctness, only the pairing bound.
    """
    a_cnt = np.asarray(a_col_counts, dtype=np.int64)
    b_cnt = np.asarray(b_row_counts, dtype=np.int64)
    k_dim = a_cnt.shape[0]
    assert b_cnt.shape[0] == k_dim, (a_cnt.shape, b_cnt.shape)

    def score(bin_of_k, g):
        binned_a = np.zeros(g, np.int64)
        binned_b = np.zeros(g, np.int64)
        np.add.at(binned_a, bin_of_k, a_cnt)
        np.add.at(binned_b, bin_of_k, b_cnt)
        ca = rup8(max(int(binned_a.max() * slack), 8))
        cb = rup8(max(int(binned_b.max() * slack), 8))
        return g * ca * cb, ca, cb

    weight = a_cnt + b_cnt
    cumw = np.cumsum(weight)
    total = max(int(cumw[-1]), 1)
    best = None
    for g in candidates:
        if g > k_dim:
            break
        equal = (np.arange(k_dim, dtype=np.int64) * g) // k_dim
        # balanced: cut the cumulative (a+b) mass into g equal slices; the
        # inclusive prefix keeps the map monotone and in [0, g)
        balanced = np.minimum((cumw - weight) * g // total, g - 1)
        for bin_of_k in (equal, balanced):
            cost, ca, cb = score(bin_of_k, g)
            if best is None or cost < best[0]:
                best = (cost, g, ca, cb, bin_of_k.astype(np.int32))
    cost, g, ca, cb, bin_of_k = best
    return KBinPlan(
        num_bins=g,
        bin_cap_a=ca,
        bin_cap_b=cb,
        pairings=cost,
        pairings_unbinned=cap_a * cap_b,
        bin_of_k=bin_of_k,
    )


def rup8(x: int) -> int:
    """Round up to a multiple of 8 (static-capacity alignment)."""
    return ((x + 7) // 8) * 8


def rup_pow2(x: int) -> int:
    """Round up to the next power of two.

    Capacity quantization for iterated multiplies (MCL, §V-C): per-iteration
    nnz drift would otherwise produce a fresh ``BatchCaps`` — and a fresh
    compile of the fused SPMD step — every iteration. Pow2 buckets collapse
    nearby capacity plans onto one static signature so the jit cache hits.
    """
    return 1 << max(int(x) - 1, 0).bit_length()


# Open-addressing slot of the hash-accumulator multiply: i32 key + f32 value.
HASH_SLOT_BYTES = 8

# Default table occupancy target (slots per merged output entry). 1/1.75 ≈
# 0.57 occupancy keeps expected linear-probe chains short while the table
# stays within ~2 slots of footprint per survivor.
HASH_LOAD_FACTOR = 1.75


def estimate_mem_c_bytes(
    flops: int, compression_factor: float, r: int,
    local_path: str = "esc", load_factor: float = None,
) -> int:
    """mem(C) of one multiply's resident intermediate.

    ESC path: r * Σ_k nnz(D^k) — bounded by r*flops (no merging, worst case)
    and approximated by r*flops/cf_layer when layer-level merging is counted.

    Hash path (``local_path="hash"``): the resident structure is the
    open-addressing table over the *merged* output, so the footprint is
    slot_bytes · load_factor · (flops/cf) — the measured load factor scales
    the table, not the COO entry size, which is why high-cf multiplies fit
    where the ESC expansion doesn't.
    """
    nnz = flops / max(compression_factor, 1.0)
    if local_path == "hash":
        lf = HASH_LOAD_FACTOR if load_factor is None else load_factor
        return int(math.ceil(nnz * lf * HASH_SLOT_BYTES))
    return int(r * nnz)
