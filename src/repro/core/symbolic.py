"""Symbolic analysis: how many batches b does the multiply need? (Paper §IV-A)

Three estimators, all exposed so tests can verify the paper's ordering
``lower_bound <= b_exact <= b_flops``:

  * ``batch_count_lower_bound`` — Eq. (2): information-theoretic floor from
    mem(C) and aggregate memory M.
  * ``batch_count`` — Alg. 3 line 12: b from the *max per-process* unmerged
    nnz (robust to load imbalance; may exceed the lower bound).
  * per-column upper bounds (``nnz_per_col_upper``) used to size static
    capacities for each batch (JAX needs static shapes — the symbolic step is
    exactly the paper's "symbolic-then-numeric" split, it just also fixes
    buffer capacities here).

The distributed version (communication pattern of Alg. 3) lives in
``repro.core.batched``; this module holds the math.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

#: bytes per nonzero. Paper uses r=24 (two i64 indices + f64 value); our
#: TPU-native default is r=12 (two i32 local indices + f32 value). The
#: constant is a parameter everywhere it matters.
R_BYTES_PAPER = 24
R_BYTES_DEFAULT = 12


@dataclasses.dataclass(frozen=True)
class SymbolicResult:
    """Host-side outcome of the symbolic step (all python ints)."""

    num_batches: int
    max_unmerged_nnz: int  # max over processes of unmerged output nnz (b=1)
    max_nnz_a: int
    max_nnz_b: int
    flops: int  # total multiply count (2*flops = FLOPs)
    lower_bound: int  # Eq. (2)

    def per_batch_capacity(self, slack: float = 1.25) -> int:
        """Static per-process unmerged capacity to allocate for one batch."""
        cap = int(math.ceil(self.max_unmerged_nnz / max(self.num_batches, 1) * slack))
        return max(cap, 8)


def batch_count_lower_bound(
    mem_c_bytes: int, total_memory: int, nnz_a: int, nnz_b: int, r: int = R_BYTES_DEFAULT
) -> int:
    """Paper Eq. (2): b >= ceil(mem(C) / (M - r(nnz(A)+nnz(B))))."""
    denom = total_memory - r * (nnz_a + nnz_b)
    if denom <= 0:
        raise MemoryError(
            f"inputs alone ({r * (nnz_a + nnz_b)}B) exceed aggregate memory "
            f"({total_memory}B) — paper precondition M > r(nnz(A)+nnz(B)) violated"
        )
    return max(1, math.ceil(mem_c_bytes / denom))


def batch_count(
    max_unmerged_nnz: int,
    max_nnz_a: int,
    max_nnz_b: int,
    per_process_memory: int,
    r: int = R_BYTES_DEFAULT,
) -> int:
    """Paper Alg. 3 line 12: b = ceil(r*maxnnzC / (M/p - r(maxnnzA+maxnnzB))).

    Uses per-process *maxima* so no process exhausts memory under load
    imbalance (§IV-A: "robust to different sparsity patterns").
    """
    denom = per_process_memory - r * (max_nnz_a + max_nnz_b)
    if denom <= 0:
        raise MemoryError(
            f"per-process inputs ({r * (max_nnz_a + max_nnz_b)}B) exceed "
            f"per-process memory ({per_process_memory}B)"
        )
    return max(1, math.ceil(r * max_unmerged_nnz / denom))


def batching_plan_columns(n: int, num_batches: int, num_layers: int) -> int:
    """Round b up so the block-cyclic split divides the column dimension.

    Returns the adjusted batch count. Paper Fig. 1(i): each batch is l blocks
    of width n/(b*l); we need (b*l) | n.
    """
    b = num_batches
    b_max = n // num_layers  # finest split: one block-cyclic block per batch
    if b > b_max:
        raise MemoryError(
            f"need {num_batches} batches but only {b_max} column batches exist "
            f"({n} cols / {num_layers} layers) — aggregate memory insufficient "
            f"even at the finest batching granularity (paper precondition)"
        )
    while n % (b * num_layers) != 0:
        b += 1
        if b > b_max:
            raise MemoryError(
                f"cannot split {n} columns into >= {num_batches} batches with "
                f"{num_layers} layers"
            )
    return b


def estimate_mem_c_bytes(flops: int, compression_factor: float, r: int) -> int:
    """mem(C) = r * Σ_k nnz(D^k); bounded by r*flops (no merging, worst case)
    and approximated by r*flops/cf_layer when layer-level merging is counted."""
    return int(r * flops / max(compression_factor, 1.0))
