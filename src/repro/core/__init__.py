"""Core library: the paper's contribution — communication-avoiding,
memory-constrained SpGEMM (BatchedSUMMA3D) — as composable JAX modules.

Layering (bottom-up):
  semiring      algebra the multiply runs over (paper §II-A)
  sparse        fixed-capacity padded COO + structural ops
  local_spgemm  per-process multiply/merge kernels (paper §IV-D, TPU-adapted)
  symbolic      batch-count math (paper Alg. 3 line 12 + Eq. 2)
  gen           synthetic workload generators (paper Table V regimes)
  summa2d       2D sparse SUMMA on a (rows × cols) mesh (paper Alg. 1)
  summa3d       3D sparse SUMMA: layers + fiber all-to-all/merge (paper Alg. 2)
  batched       BatchedSUMMA3D + distributed symbolic step (paper Alg. 3/4)
"""
from . import gen, local_spgemm, semiring, sparse, symbolic  # noqa: F401
from .sparse import SparseCOO, coalesce, empty, from_dense, from_numpy_coo  # noqa: F401
from .semiring import PLUS_TIMES, OR_AND, MIN_PLUS, MAX_TIMES, PLUS_PAIR  # noqa: F401
