"""AdamW with global-norm clipping and ZeRO-1 optimizer-state sharding.

Raw-pytree implementation (no optax dependency): state = (mu, nu, count).
``opt_state_specs`` shards mu/nu over the data axes on top of the param's TP
spec (ZeRO-1) — at 512 devices this cuts optimizer memory 32×, which is what
lets the 20B arch fit the v5e HBM budget in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.common import batch_axes, zero1_shard_spec

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    zero1: bool = True
    # master-in-opt (ZeRO-style mixed precision): model params live in bf16
    # (replicated), the f32 master copy lives in the ZeRO-sharded optimizer
    # state — gradient all-reduce and param all-gather run at bf16 width.
    master_in_opt: bool = False


def init_opt_state(params, master_in_opt: bool = False) -> Dict[str, Any]:
    state = {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if master_in_opt:
        def cast(p):
            if isinstance(p, jax.ShapeDtypeStruct):  # AOT shape-only path
                return jax.ShapeDtypeStruct(p.shape, jnp.float32)
            return p.astype(jnp.float32)

        state["master"] = jax.tree.map(cast, params)
    return state


def opt_state_specs(param_specs_tree, params_shapes, mesh, cfg: AdamWConfig):
    """PartitionSpec tree for the optimizer state (ZeRO-1 over data axes)."""
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    return opt_state_specs_axes(param_specs_tree, params_shapes, dp, dp_size, cfg)


def opt_state_specs_axes(param_specs_tree, params_shapes, dp_axes, dp_size: int,
                         cfg: AdamWConfig):
    """ZeRO-1 sharding over an explicit axis set (the "dp" strategy passes
    (data, model) so optimizer state shards 256-way)."""

    def one(spec, shaped):
        if not cfg.zero1 or dp_size == 1:
            return spec
        return zero1_shard_spec(spec, shaped.shape, tuple(dp_axes), dp_size)

    mu_specs = jax.tree.map(
        one, param_specs_tree, params_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
    specs = {"mu": mu_specs, "nu": mu_specs, "count": P()}
    if cfg.master_in_opt:
        specs["master"] = mu_specs
    return specs


def _schedule(cfg: AdamWConfig, count: Array) -> Array:
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = _schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    if "master" in state:
        # update the f32 master (ZeRO-sharded), emit bf16 model weights
        flat_master = jax.tree.leaves(state["master"])
        outs = [upd(mp, g, m, v)
                for mp, g, m, v in zip(flat_master, flat_g, flat_m, flat_v)]
        new_master = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), new_master, params
        )
        new_mu = jax.tree.unflatten(tdef, [o[1] for o in outs])
        new_nu = jax.tree.unflatten(tdef, [o[2] for o in outs])
        return new_params, {"mu": new_mu, "nu": new_nu, "count": count,
                            "master": new_master}, {
            "grad_norm": gnorm, "lr": lr,
        }
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, {
        "grad_norm": gnorm,
        "lr": lr,
    }
