from . import adamw, compress  # noqa: F401
