"""Top-k sparsified gradient exchange with error feedback.

Distributed-optimization trick for slow inter-pod links: before the data-
parallel all-reduce, keep only the top-k magnitude entries of each gradient
tensor (per device), accumulate the residual locally (error feedback, à la
Deep Gradient Compression), and exchange the sparse entries. The sparse
format is the core ``SparseCOO`` — the paper's memory-constrained machinery
reused as a communication compressor (DESIGN.md §4).

Exchange realization: within a jit step the compressed gradient is
materialized as (values, flat indices) and the all-reduce runs over the
densified-but-tiny buffer via scatter → psum → gather; on slow "pod" links
this trades flops for an α–β win when density << link_bw/HBM_bw.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    density: float = 0.01  # fraction of entries kept
    min_size: int = 4096  # tensors smaller than this are sent dense


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grad(g: Array, err: Array, cfg: CompressConfig) -> Tuple[Array, Array, Array]:
    """Returns (values (k,), flat indices (k,), new error residual)."""
    flat = g.astype(jnp.float32).reshape(-1) + err.reshape(-1)
    k = max(int(flat.shape[0] * cfg.density), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    resid = flat.at[idx].set(0.0)
    return sel, idx, resid.reshape(g.shape)


def decompress(vals: Array, idx: Array, shape) -> Array:
    size = 1
    for s in shape:
        size *= s
    out = jnp.zeros((size,), jnp.float32).at[idx].add(vals)
    return out.reshape(shape)


def compress_tree(grads, err_state, cfg: CompressConfig):
    """Apply EF-top-k to every large tensor; returns (sparse reps, new err)."""
    flat, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state)
    reps, new_errs = [], []
    for g, e in zip(flat, errs):
        if g.size < cfg.min_size:
            reps.append(("dense", g))
            new_errs.append(e)
        else:
            v, i, r = compress_grad(g, e, cfg)
            reps.append(("topk", (v, i, g.shape)))
            new_errs.append(r)
    return (tdef, reps), jax.tree.unflatten(tdef, new_errs)


def decompress_tree(compressed):
    tdef, reps = compressed
    outs = []
    for kind, payload in reps:
        if kind == "dense":
            outs.append(payload)
        else:
            v, i, shape = payload
            outs.append(decompress(v, i, shape))
    return jax.tree.unflatten(tdef, outs)


def compression_ratio(grads, cfg: CompressConfig) -> float:
    """Bytes after / bytes before (for the comm-model benchmark)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    kept = 0
    for g in jax.tree.leaves(grads):
        if g.size < cfg.min_size:
            kept += g.size
        else:
            kept += 2 * max(int(g.size * cfg.density), 1)  # vals + idx
    return kept / total
