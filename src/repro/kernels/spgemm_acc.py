"""Pallas TPU kernel: paired sort-free SpGEMM — COO A (m×k) × COO B (k×n) → dense C.

This is the TPU-native rendering of the paper's unsorted-hash local SpGEMM
(§IV-D): instead of hashing partial products, every (A-entry, B-entry) block
pair is matched on the contraction index with an equality **match matrix**
evaluated on the MXU, and accumulated straight into a dense VMEM tile of C
(identity-hash accumulator). No input ordering is required — exactly the
paper's sort-free property — and no intermediate partial-product list is ever
materialized in HBM (the paper's memory-constrained motivation).

Per (m-tile i, n-tile j) output block, reducing over A blocks s and B blocks t:

    match  = (a_cols[:, None] == b_rows[None, :])       # (nbA, nbB)  VPU
    w      = a_vals ⊗ b_vals ⊙ match                    # (nbA, nbB)  VPU
    rowsel = one_hot(a_rows - m_off)                    # (m_blk, nbA)
    colsel = one_hot(b_cols - n_off)                    # (nbB, n_blk)
    C_tile += rowsel @ w @ colsel                       # two MXU matmuls

Work is O(capA × capB) pairings per output tile — the narrow output blocks
produced by batching (Alg. 4) keep capB small, which is what makes this
profitable; the ESC path covers the wide/unbatched regime. When entries
spread over the contraction index, ``spgemm_binned.py`` cuts the pairing
work to O(Σ_k capA_k × capB_k) by bucketing both operands by k-range first
and pairing only matching bins — use ``repro.core.symbolic.plan_k_bins`` to
size the bins and prefer the binned kernel whenever its planned pairing
count is lower.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = dict(m_blk=128, n_blk=128, a_blk=256, b_blk=256)


def _paired_kernel(
    ar_ref, ac_ref, av_ref, br_ref, bc_ref, bv_ref, out_ref, *, m_blk, n_blk
):
    s = pl.program_id(2)
    t = pl.program_id(3)

    @pl.when((s == 0) & (t == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ar, ac, av = ar_ref[...], ac_ref[...], av_ref[...].astype(jnp.float32)
    br, bc, bv = br_ref[...], bc_ref[...], bv_ref[...].astype(jnp.float32)
    nbA, nbB = ar.shape[0], br.shape[0]
    m_off = pl.program_id(0) * m_blk
    n_off = pl.program_id(1) * n_blk

    match = (ac[:, None] == br[None, :]).astype(jnp.float32)
    w = av[:, None] * bv[None, :] * match  # (nbA, nbB)
    rowsel = (ar[None, :] - m_off == jax.lax.broadcasted_iota(
        jnp.int32, (m_blk, nbA), 0
    )).astype(jnp.float32)
    colsel = (bc[:, None] - n_off == jax.lax.broadcasted_iota(
        jnp.int32, (nbB, n_blk), 1
    )).astype(jnp.float32)
    acc = jnp.dot(rowsel, w, preferred_element_type=jnp.float32)  # (m_blk, nbB)
    out_ref[...] += jnp.dot(acc, colsel, preferred_element_type=jnp.float32)


def spgemm_paired_pallas(
    a_rows, a_cols, a_vals, b_rows, b_cols, b_vals, m: int, n: int,
    *, m_blk=None, n_blk=None, a_blk=None, b_blk=None, interpret: bool = True,
) -> jnp.ndarray:
    """Dense C (m×n, f32) from two padded COO entry lists (zero-valued padding)."""
    capA, capB = a_rows.shape[0], b_rows.shape[0]
    m_blk = min(m_blk or DEFAULT_BLOCKS["m_blk"], _rup(m, 8))
    n_blk = min(n_blk or DEFAULT_BLOCKS["n_blk"], _rup(n, 128))
    a_blk = min(a_blk or DEFAULT_BLOCKS["a_blk"], _rup(capA, 8))
    b_blk = min(b_blk or DEFAULT_BLOCKS["b_blk"], _rup(capB, 8))

    m_pad, n_pad = _rup(m, m_blk), _rup(n, n_blk)
    capA_pad, capB_pad = _rup(capA, a_blk), _rup(capB, b_blk)
    # pad entry lists; use distinct sentinels for the contraction index so
    # padded A entries never match padded B entries (values are 0 anyway,
    # but keeping the match matrix sparse helps nothing — this is belt and
    # braces for the zero-value guarantee).
    a_rows = _pad1(a_rows, capA_pad, m_pad)
    a_cols = _pad1(a_cols, capA_pad, -1)
    a_vals = _pad1(a_vals, capA_pad, 0)
    b_rows = _pad1(b_rows, capB_pad, -2)
    b_cols = _pad1(b_cols, capB_pad, n_pad)
    b_vals = _pad1(b_vals, capB_pad, 0)

    grid = (m_pad // m_blk, n_pad // n_blk, capA_pad // a_blk, capB_pad // b_blk)
    out = pl.pallas_call(
        functools.partial(_paired_kernel, m_blk=m_blk, n_blk=n_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((a_blk,), lambda i, j, s, t: (s,)),
            pl.BlockSpec((a_blk,), lambda i, j, s, t: (s,)),
            pl.BlockSpec((a_blk,), lambda i, j, s, t: (s,)),
            pl.BlockSpec((b_blk,), lambda i, j, s, t: (t,)),
            pl.BlockSpec((b_blk,), lambda i, j, s, t: (t,)),
            pl.BlockSpec((b_blk,), lambda i, j, s, t: (t,)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, s, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(a_rows, a_cols, a_vals, b_rows, b_cols, b_vals)
    return out[:m, :n]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad1(x, new_len, fill):
    return jnp.pad(x, (0, new_len - x.shape[0]), constant_values=fill)
