"""Pallas TPU kernel: per-column threshold for top-k pruning (MCL hot path).

HipMCL consumes every SpGEMM batch with column-wise selection (paper §V-C:
"keeps top-k entries in each column"). The TPU-native realization avoids
per-column sorting: an iterative per-column threshold refinement (bisection
on value) runs entirely in VMEM on a dense batch block and emits, per
column, the largest threshold t such that |{i : x[i,c] >= t}| <= k. The
caller then keeps entries >= t — a masked select, no sort.

Grid: (n_tiles,) over column tiles; each program bisects THRESH_ITERS times
on its (m × n_blk) block (VPU reductions only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

THRESH_ITERS = 24  # bisection steps — resolves ~1e-7 of the value range


def _col_prune_kernel(x_ref, k_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)  # (m, n_blk)
    k = k_ref[0]
    lo = jnp.zeros((x.shape[1],), jnp.float32)
    hi = jnp.max(jnp.abs(x), axis=0) + 1e-6

    def body(i, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((jnp.abs(x) >= mid[None, :]).astype(jnp.int32), axis=0)
        # too many survivors -> raise threshold (move lo up), else lower hi
        take_hi = cnt > k
        lo = jnp.where(take_hi, mid, lo)
        hi = jnp.where(take_hi, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, THRESH_ITERS, body, (lo, hi))
    out_ref[...] = hi  # smallest threshold with count <= k


def col_topk_threshold_pallas(
    x: jnp.ndarray, k: int, *, n_blk: int = 128, interpret: bool = True
) -> jnp.ndarray:
    """Per-column |value| threshold keeping at most k entries. x: (m, n)."""
    m, n = x.shape
    n_blk = min(n_blk, _rup(n, 128))
    n_pad = _rup(n, n_blk)
    xp = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    karr = jnp.full((1,), k, jnp.int32)
    out = pl.pallas_call(
        _col_prune_kernel,
        grid=(n_pad // n_blk,),
        in_specs=[
            pl.BlockSpec((m, n_blk), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((n_blk,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(xp, karr)
    return out[:n]


def col_topk_threshold_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Oracle: exact k-th largest |value| per column (sorted)."""
    m, n = x.shape
    a = jnp.abs(x.astype(jnp.float32))
    svals = jnp.sort(a, axis=0)[::-1]  # descending per column
    kth = svals[jnp.minimum(k - 1, m - 1)] if k <= m else jnp.zeros((n,))
    counts = jnp.sum(a >= kth[None, :], axis=0)
    return jnp.where(counts <= k, kth, kth + 0.0)


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
