"""Pallas TPU kernel: per-column threshold for top-k pruning (MCL hot path).

HipMCL consumes every SpGEMM batch with column-wise selection (paper §V-C:
"keeps top-k entries in each column"). The TPU-native realization avoids
per-column sorting: an iterative per-column threshold refinement (bisection
on value) runs entirely in VMEM on a dense batch block and emits, per
column, the bisection bracket (lo, hi): hi is the smallest tested threshold
with |{i : x[i,c] >= hi}| <= k, lo the largest with count > k. The caller
keeps entries >= hi — a masked select, no sort — and breaks k-boundary TIES
from the [lo, hi) band by rank (``sparse_apps.mcl``), since a value repeated
across the boundary would otherwise be pruned entirely.

Grid: (n_tiles,) over column tiles; each program bisects THRESH_ITERS times
on its (m × n_blk) block (VPU reductions only).

Wired into the MCL pipeline (``sparse_apps.mcl``): the dense-path batch
postprocess row-gathers each column block and runs this kernel for the
per-column thresholds; the sparse path runs the same bisection distributed
(per-column counts ``psum``-reduced over the grid row axis) as a masked
select on the COO entries. TPU follow-ups: compile/validate outside
interpret mode (the fast lane runs ``interpret=True`` on CPU, including
inside ``shard_map``), and fuse the threshold + masked-select into one
kernel so the survivors never re-visit HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

THRESH_ITERS = 24  # bisection steps — resolves ~1e-7 of the value range


def _col_prune_kernel(x_ref, k_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)  # (m, n_blk)
    k = k_ref[0]
    lo = jnp.zeros((x.shape[1],), jnp.float32)
    hi = jnp.max(jnp.abs(x), axis=0) + 1e-6

    def body(i, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((jnp.abs(x) >= mid[None, :]).astype(jnp.int32), axis=0)
        # too many survivors -> raise threshold (move lo up), else lower hi
        take_hi = cnt > k
        lo = jnp.where(take_hi, mid, lo)
        hi = jnp.where(take_hi, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, THRESH_ITERS, body, (lo, hi))
    out_ref[...] = jnp.stack([lo, hi])  # bracket: count(>=hi) <= k < count(>=lo)


def col_topk_bounds_pallas(
    x: jnp.ndarray, k: int, *, n_blk: int = 128, interpret: bool = True
):
    """Per-column bisection bracket ``(lo, hi)`` for top-k |value| selection.

    ``hi`` keeps at most k entries (``|x| >= hi``); values in ``[lo, hi)``
    are the k-boundary tie band (empty when no tie straddles k). x: (m, n).
    """
    m, n = x.shape
    n_blk = min(n_blk, _rup(n, 128))
    n_pad = _rup(n, n_blk)
    xp = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    karr = jnp.full((1,), k, jnp.int32)
    out = pl.pallas_call(
        _col_prune_kernel,
        grid=(n_pad // n_blk,),
        in_specs=[
            pl.BlockSpec((m, n_blk), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((2, n_blk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((2, n_pad), jnp.float32),
        interpret=interpret,
    )(xp, karr)
    return out[0, :n], out[1, :n]


def col_topk_threshold_pallas(
    x: jnp.ndarray, k: int, *, n_blk: int = 128, interpret: bool = True
) -> jnp.ndarray:
    """Per-column |value| threshold keeping at most k entries. x: (m, n)."""
    return col_topk_bounds_pallas(x, k, n_blk=n_blk, interpret=interpret)[1]


def col_topk_threshold_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Oracle: exact k-th largest |value| per column (sorted)."""
    m, n = x.shape
    a = jnp.abs(x.astype(jnp.float32))
    svals = jnp.sort(a, axis=0)[::-1]  # descending per column
    kth = svals[jnp.minimum(k - 1, m - 1)] if k <= m else jnp.zeros((n,))
    counts = jnp.sum(a >= kth[None, :], axis=0)
    return jnp.where(counts <= k, kth, kth + 0.0)


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
