"""Pure-jnp oracles for the Pallas kernels.

Each function mirrors the exact padded-COO semantics of its kernel (sentinel
indices + zero values in padding) so tests can ``assert_allclose`` kernel
output against these under shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def spmm_ref(rows: Array, cols: Array, vals: Array, b: Array, m: int) -> Array:
    """C[m, n] = Σ_t vals[t] * B[cols[t], :] scattered to row rows[t].

    Padding entries carry vals == 0 (their indices may be sentinels >= dims).
    """
    k, n = b.shape
    b_pad = jnp.concatenate([b, jnp.zeros((1, n), b.dtype)], axis=0)
    gathered = b_pad[jnp.clip(cols, 0, k)]  # (cap, n)
    prods = vals[:, None].astype(jnp.float32) * gathered.astype(jnp.float32)
    out = jax.ops.segment_sum(prods, jnp.clip(rows, 0, m), num_segments=m + 1)[:m]
    return out.astype(b.dtype)


def densify_ref(rows: Array, cols: Array, vals: Array, m: int, n: int) -> Array:
    """Scatter-add a padded COO entry list into a dense (m, n) matrix."""
    out = jnp.zeros((m + 1, n + 1), jnp.float32)
    out = out.at[jnp.clip(rows, 0, m), jnp.clip(cols, 0, n)].add(
        vals.astype(jnp.float32)
    )
    return out[:m, :n].astype(vals.dtype)


def spgemm_paired_binned_ref(
    a_rows: Array,
    a_k: Array,
    a_vals: Array,
    b_k: Array,
    b_cols: Array,
    b_vals: Array,
    m: int,
    n: int,
) -> Array:
    """k-binned paired SpGEMM oracle: inputs are (num_bins, bin_cap*) arrays
    from ``spgemm_binned.bin_entries_by_k``; only same-bin entries are paired,
    so the work is Σ_g binA×binB — the same pairing set the binned Pallas
    grid evaluates (cross-bin pairs are structurally impossible matches)."""
    num_bins = a_rows.shape[0]
    out = jnp.zeros((m, n), jnp.float32)
    for g in range(num_bins):
        out = out + spgemm_paired_ref(
            a_rows[g], a_k[g], a_vals[g], b_k[g], b_cols[g], b_vals[g], m, n
        ).astype(jnp.float32)
    return out


def spgemm_paired_ref(
    a_rows: Array,
    a_cols: Array,
    a_vals: Array,
    b_rows: Array,
    b_cols: Array,
    b_vals: Array,
    m: int,
    n: int,
) -> Array:
    """C[m, n] = Σ over entry pairs (s, t) with a_cols[s] == b_rows[t] of
    a_vals[s] * b_vals[t] at (a_rows[s], b_cols[t]).

    The match-matrix formulation the Pallas kernel evaluates on the MXU.
    Padding entries have zero values so sentinel-sentinel matches contribute 0.
    """
    match = (a_cols[:, None] == b_rows[None, :]).astype(jnp.float32)
    w = a_vals[:, None].astype(jnp.float32) * b_vals[None, :].astype(jnp.float32) * match
    # scatter pair weights: first along output columns, then output rows
    colsum = jax.ops.segment_sum(
        w.T, jnp.clip(b_cols, 0, n), num_segments=n + 1
    )  # (n+1, capA)
    rowsum = jax.ops.segment_sum(
        colsum.T, jnp.clip(a_rows, 0, m), num_segments=m + 1
    )  # (m+1, n+1)
    return rowsum[:m, :n].astype(a_vals.dtype)
