"""Pallas TPU kernel: in-VMEM bitonic sort of (key, value) pairs.

The packed-key engine (``repro.core.sortkeys``) turns every ESC compress and
merge into *one* single-key sort plus linear scans. For tile sizes that fit
VMEM this kernel keeps that sort entirely on-chip as a bitonic network — the
TPU-friendly sorting-network rendering the paper's §IV-D observation asks for
(sorting maps to compare-exchange stages, not data-dependent branches).

The network runs log²(N) compare-exchange stages. Each stage pairs element i
with i^j; because j is a power of two the pairing is a regular interleave, so
it is expressed as a reshape to (N/2j, 2, j) and a swap along the middle axis
— reshapes and selects only, no gathers (TPU has no efficient per-lane random
access, which is why the seed's ``lexsort`` was the bottleneck this engine
replaces).

Above ``MAX_BITONIC_ELEMS`` (or on non-TPU backends) callers should use the
XLA path (``jax.lax.sort``) via ``sort_pairs`` below — same contract.

Contract: keys ascending; vals carried along. The network is NOT stable —
equal keys may permute their values. All repo call sites reduce values per
key afterwards, so this is observable only through bitwise float-sum order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Largest pair count sorted on-chip: 2 i32/f32 arrays × a few network copies
#: must fit in ~16 MB VMEM with headroom.
MAX_BITONIC_ELEMS = 1 << 14


def _compare_exchange(keys, vals, jj: int, kk: int, length: int):
    """One bitonic stage: element i vs i^jj, ascending iff (i & kk) == 0."""
    rrows = length // (2 * jj)
    k3 = keys.reshape(rrows, 2, jj)
    v3 = vals.reshape(rrows, 2, jj)
    # direction is constant per (2*jj)-row: bit log2(kk) of i comes from r
    r = jax.lax.broadcasted_iota(jnp.int32, (rrows, 1), 0)
    asc = ((r * (2 * jj)) & kk) == 0
    a_k, b_k = k3[:, 0, :], k3[:, 1, :]
    a_v, b_v = v3[:, 0, :], v3[:, 1, :]
    in_order = a_k <= b_k
    swap = jnp.where(asc, ~in_order, in_order)
    new_a_k = jnp.where(swap, b_k, a_k)
    new_b_k = jnp.where(swap, a_k, b_k)
    new_a_v = jnp.where(swap, b_v, a_v)
    new_b_v = jnp.where(swap, a_v, b_v)
    keys = jnp.stack([new_a_k, new_b_k], axis=1).reshape(length)
    vals = jnp.stack([new_a_v, new_b_v], axis=1).reshape(length)
    return keys, vals


def _bitonic_kernel(k_ref, v_ref, ko_ref, vo_ref, *, length: int):
    keys = k_ref[...]
    vals = v_ref[...]
    nstages = length.bit_length() - 1
    for kk_exp in range(1, nstages + 1):
        kk = 1 << kk_exp
        for jj_exp in range(kk_exp - 1, -1, -1):
            keys, vals = _compare_exchange(keys, vals, 1 << jj_exp, kk, length)
    ko_ref[...] = keys
    vo_ref[...] = vals


def bitonic_sort_pairs_pallas(keys, vals, *, interpret: bool = True):
    """Sort ``keys`` ascending carrying ``vals``; length must be a power of 2."""
    (length,) = keys.shape
    assert length & (length - 1) == 0, f"length {length} not a power of two"
    assert vals.shape == (length,)
    if length <= 1:
        return keys, vals
    return pl.pallas_call(
        functools.partial(_bitonic_kernel, length=length),
        out_shape=(
            jax.ShapeDtypeStruct((length,), keys.dtype),
            jax.ShapeDtypeStruct((length,), vals.dtype),
        ),
        interpret=interpret,
    )(keys, vals)


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 1).bit_length() if x > 1 else 1


def sort_pairs(
    keys,
    vals,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    max_bitonic: int = MAX_BITONIC_ELEMS,
):
    """Single-key sort of (keys, vals): bitonic Pallas network for VMEM-resident
    sizes, XLA ``lax.sort`` otherwise. Pads to the next power of two with the
    dtype max (sentinels sort last) and slices back.

    Contract for the Pallas path on non-power-of-two lengths: keys must be
    strictly below the key dtype's max. A real max-valued key would tie with
    the padding sentinels and — the network being unstable — its value could
    be dropped in favor of a padding zero. Packed (row, col) keys satisfy
    this by construction (key < key_space <= INT32_MAX); arbitrary callers
    that can't guarantee it should use the XLA path (``use_pallas=False``).
    """
    (length,) = keys.shape
    if not use_pallas or length > max_bitonic:
        return jax.lax.sort((keys, vals), num_keys=1)
    padded = _next_pow2(length)
    if padded != length:
        fill = jnp.iinfo(keys.dtype).max
        keys = jnp.pad(keys, (0, padded - length), constant_values=fill)
        vals = jnp.pad(vals, (0, padded - length))
    ks, vs = bitonic_sort_pairs_pallas(keys, vals, interpret=interpret)
    return ks[:length], vs[:length]
