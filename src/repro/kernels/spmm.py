"""Pallas TPU kernel: SpMM — padded-COO sparse A (m×k) times dense B (k×n).

TPU adaptation of the paper's sort-free local accumulation (§IV-D): the
gather (B rows by A's column index) and the scatter (into C rows by A's row
index) are both expressed as one-hot matmuls so they run on the MXU, and the
output tile is a **dense VMEM accumulator** — a perfect hash table with the
identity hash, which is what "unsorted hash accumulation" becomes when the
output block is narrow enough to sit on-chip (the batched algorithm
guarantees that).

Grid: (m_tiles, n_tiles, k_tiles, nnz_blocks); the last two are reduction
axes — the output BlockSpec ignores them so the C tile stays resident in VMEM
across the whole reduction (Pallas revisiting-accumulator pattern).

Per block:
    ksel   = one_hot(a_cols - k_off)          # (nnz_blk, k_blk)
    gath   = ksel @ B_tile                    # (nnz_blk, n_blk)   MXU
    prods  = a_vals[:, None] * gath           # VPU
    rowsel = one_hot(a_rows - m_off).T        # (m_blk, nnz_blk)
    C_tile += rowsel @ prods                  # MXU

Padding entries carry zero values, so sentinel indices contribute nothing
even when they alias a real coordinate after tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = dict(m_blk=128, n_blk=128, k_blk=512, nnz_blk=512)


def _spmm_kernel(rows_ref, cols_ref, vals_ref, b_ref, out_ref, *, m_blk, k_blk):
    kk = pl.program_id(2)
    s = pl.program_id(3)

    @pl.when((kk == 0) & (s == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)  # (k_blk, n_blk)

    k_off = kk * k_blk
    m_off = pl.program_id(0) * m_blk
    nnz_blk = rows.shape[0]

    ksel = (cols[:, None] - k_off == jax.lax.broadcasted_iota(
        jnp.int32, (nnz_blk, k_blk), 1
    )).astype(jnp.float32)
    gath = jnp.dot(ksel, b, preferred_element_type=jnp.float32)  # (nnz, n_blk)
    prods = vals[:, None] * gath
    rowsel = (rows[None, :] - m_off == jax.lax.broadcasted_iota(
        jnp.int32, (m_blk, nnz_blk), 0
    )).astype(jnp.float32)
    out_ref[...] += jnp.dot(rowsel, prods, preferred_element_type=jnp.float32)


def spmm_pallas(
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: jnp.ndarray,
    b: jnp.ndarray,
    m: int,
    *,
    m_blk: int = None,
    n_blk: int = None,
    k_blk: int = None,
    nnz_blk: int = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """C (m×n, f32) = scatter-accumulate of A's padded COO against dense B.

    Dimensions are padded up to block multiples here; callers pass natural
    shapes. ``interpret=True`` executes on CPU for validation; on TPU pass
    ``interpret=False``.
    """
    cap = rows.shape[0]
    k, n = b.shape
    m_blk = min(m_blk or DEFAULT_BLOCKS["m_blk"], _rup(m, 8))
    n_blk = min(n_blk or DEFAULT_BLOCKS["n_blk"], _rup(n, 128))
    k_blk = min(k_blk or DEFAULT_BLOCKS["k_blk"], _rup(k, 8))
    nnz_blk = min(nnz_blk or DEFAULT_BLOCKS["nnz_blk"], _rup(cap, 8))

    m_pad, n_pad, k_pad, cap_pad = (
        _rup(m, m_blk),
        _rup(n, n_blk),
        _rup(k, k_blk),
        _rup(cap, nnz_blk),
    )
    rows = _pad1(rows, cap_pad, m_pad)  # sentinel beyond any row tile? zero-val guard
    cols = _pad1(cols, cap_pad, k_pad)
    vals = _pad1(vals, cap_pad, 0)
    b = jnp.pad(b, ((0, k_pad - k), (0, n_pad - n)))

    grid = (m_pad // m_blk, n_pad // n_blk, k_pad // k_blk, cap_pad // nnz_blk)
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, m_blk=m_blk, k_blk=k_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnz_blk,), lambda i, j, kk, s: (s,)),
            pl.BlockSpec((nnz_blk,), lambda i, j, kk, s: (s,)),
            pl.BlockSpec((nnz_blk,), lambda i, j, kk, s: (s,)),
            pl.BlockSpec((k_blk, n_blk), lambda i, j, kk, s: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, kk, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(rows, cols, vals, b)
    return out[:m, :n]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad1(x, new_len, fill):
    return jnp.pad(x, (0, new_len - x.shape[0]), constant_values=fill)
