"""Jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the execution path:
  * False (default on CPU): pure-jnp oracle path (``ref.py`` semantics) — this
    is what the dry-run lowers, since Mosaic kernels don't lower to the CPU
    backend.
  * True: pl.pallas_call. On this container that means ``interpret=True``
    (validation); on a real TPU pod the same call sites run compiled
    (``interpret=False``).

These wrappers accept the core ``SparseCOO`` type so the rest of the stack
never touches raw entry lists.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.sparse import SparseCOO
from . import ref
from .densify import densify_pallas
from .sort_engine import sort_pairs as _sort_pairs
from .spgemm_acc import spgemm_paired_pallas
from .spgemm_binned import spgemm_binned_dense
from .spmm import spmm_pallas

_ON_TPU = jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def spmm(a: SparseCOO, b_dense: jnp.ndarray, use_pallas: bool = False,
         interpret: bool = not _ON_TPU) -> jnp.ndarray:
    """Sparse (m×k) × dense (k×n) → dense (m×n) f32."""
    m, _ = a.shape
    vals = jnp.where(a.valid_mask(), a.vals, 0)
    if use_pallas:
        return spmm_pallas(a.rows, a.cols, vals, b_dense, m, interpret=interpret)
    return ref.spmm_ref(a.rows, a.cols, vals, b_dense, m)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def spgemm_paired(a: SparseCOO, b: SparseCOO, use_pallas: bool = False,
                  interpret: bool = not _ON_TPU) -> jnp.ndarray:
    """Sparse (m×k) × sparse (k×n) → dense (m×n) f32 — sort-free paired kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    av = jnp.where(a.valid_mask(), a.vals, 0)
    bv = jnp.where(b.valid_mask(), b.vals, 0)
    if use_pallas:
        return spgemm_paired_pallas(
            a.rows, a.cols, av, b.rows, b.cols, bv, m, n, interpret=interpret
        )
    return ref.spgemm_paired_ref(a.rows, a.cols, av, b.rows, b.cols, bv, m, n)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def densify(a: SparseCOO, use_pallas: bool = False,
            interpret: bool = not _ON_TPU) -> jnp.ndarray:
    """Padded COO → dense (m×n) f32."""
    m, n = a.shape
    vals = jnp.where(a.valid_mask(), a.vals, 0)
    if use_pallas:
        return densify_pallas(a.rows, a.cols, vals, m, n, interpret=interpret)
    return ref.densify_ref(a.rows, a.cols, vals, m, n)


@partial(
    jax.jit,
    static_argnames=("num_bins", "bin_cap_a", "bin_cap_b", "use_pallas", "interpret"),
)
def spgemm_paired_binned(
    a: SparseCOO,
    b: SparseCOO,
    num_bins: int,
    bin_cap_a: int,
    bin_cap_b: int,
    bin_map: jnp.ndarray = None,
    use_pallas: bool = False,
    interpret: bool = not _ON_TPU,
):
    """k-binned paired SpGEMM: bucket both operands by contraction range, pair
    only matching k-bins — O(Σ_g capA_g×capB_g) instead of O(capA×capB).

    Static bin parameters (and the monotone ``bin_map`` absorbing skewed-k
    distributions) come from ``repro.core.symbolic.plan_k_bins``. Returns
    (C dense f32, overflow) — overflow > 0 means a bin capacity was exceeded
    and entries were dropped (caller re-plans with bigger caps).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    av = jnp.where(a.valid_mask(), a.vals, 0)
    bv = jnp.where(b.valid_mask(), b.vals, 0)
    return spgemm_binned_dense(
        a.rows, a.cols, av, a.valid_mask(), b.rows, b.cols, bv, b.valid_mask(),
        m, n, k, num_bins, bin_cap_a, bin_cap_b, bin_map=bin_map,
        use_pallas=use_pallas, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def sort_pairs(keys: jnp.ndarray, vals: jnp.ndarray, use_pallas: bool = False,
               interpret: bool = not _ON_TPU):
    """Single-key sort carrying one payload — the packed-key engine's sort
    primitive (bitonic VMEM network under Pallas, ``lax.sort`` otherwise)."""
    return _sort_pairs(keys, vals, use_pallas=use_pallas, interpret=interpret)
