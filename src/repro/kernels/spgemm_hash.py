"""Pallas TPU kernel: hash-accumulator insert for SpGEMM — O(output) scratch.

The ESC local multiply materializes the *whole* expansion (O(flops) entries)
before sorting and compressing it.  Following Nagasaka et al.'s hash SpGEMM
(arXiv:1804.01698), this kernel instead consumes partial products on the fly:
each (packed row-major key, value) pair is inserted into a VMEM-resident
open-addressing table and semiring-accumulated in place on a probe hit, so
the resident structure is O(nnz(C) · load_factor) — the table — plus one
bounded, reused chunk buffer.  High compression-factor batches (flops ≫
nnz(C)) are exactly where this wins the memory budget.

Insertion is formulated as vectorized probe *rounds* so it maps onto the VPU
(no per-entry serial loop):

  round p: every still-unplaced entry probes slot (h0 + p) & (T - 1)
           — a hit on its own key accumulates next reduction;
           — an EMPTY slot is claimed by scatter-min of the key (ties between
             equal keys are harmless: both land on the same slot);
           — losers retry in round p + 1.

Because every entry with the same key follows the *same* probe sequence and
table slots only ever transition EMPTY → key (never mutate), all equal keys
placed in any round resolve to one slot: linear probing's invariant survives
the data-parallel formulation.  Entries unplaced after ``max_probes`` rounds
are *dropped and counted* — the device-resident overflow flag the batched
driver's retry ladder already understands (paper §IV-A: count, don't crash).

Keys are ``sortkeys.pack_rowmajor`` i32 keys; ``EMPTY`` is INT32_MAX, which
also sorts after every real key *and* every sentinel, so the final table →
sorted-COO compaction is one ``lax.sort`` + ``compress_sorted_keys``.

The scatter-claim (`.at[dest].min`) lowers in interpret mode and on the CPU
oracle path; on Mosaic the same rounds run with the table in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.sortkeys import INT32_MAX

# Open-addressing slot: i32 packed key + f32 accumulator.
SLOT_BYTES = 8

# Fibonacci multiplicative hashing: the golden-ratio constant scrambles the
# packed keys' low-entropy structure (row*(n+1)+col clusters by row) before
# the top-bits cut selects a slot. Python ints (not jnp constants) so the
# Pallas kernel doesn't close over traced arrays.
_FIB = 2654435769

EMPTY = INT32_MAX


def fib_hash(keys: jnp.ndarray, lg_table: int) -> jnp.ndarray:
    """Map i32 keys to [0, 2**lg_table) via Fibonacci hashing (top bits)."""
    assert 1 <= lg_table <= 31, lg_table
    h = jax.lax.shift_right_logical(
        keys.astype(jnp.uint32) * jnp.uint32(_FIB), jnp.uint32(32 - lg_table)
    )
    return h.astype(jnp.int32)


def _insert_rounds(table_key, keys, valid, max_probes: int):
    """Run the vectorized probe rounds; returns (table_key, placed, slot_of)."""
    table_cap = table_key.shape[0]
    assert table_cap & (table_cap - 1) == 0, table_cap
    lg = table_cap.bit_length() - 1
    h0 = fib_hash(keys, lg)

    def body(p, carry):
        tk, placed, slot_of = carry
        slot = (h0 + p) & (table_cap - 1)
        cur = tk[slot]
        live = valid & ~placed
        match = live & (cur == keys)
        empty = live & (cur == EMPTY)
        # claim EMPTY slots by scatter-min of the key; index table_cap is the
        # discard slot of the padded table, so occupied slots are untouched
        dest = jnp.where(empty, slot, table_cap)
        tk = jnp.concatenate([tk, jnp.full((1,), EMPTY, jnp.int32)])
        tk = tk.at[dest].min(jnp.where(empty, keys, EMPTY))[:table_cap]
        won = empty & (tk[slot] == keys)
        placed_now = match | won
        slot_of = jnp.where(placed_now, slot, slot_of)
        return tk, placed | placed_now, slot_of

    placed0 = jnp.zeros(keys.shape, bool)
    slot0 = jnp.zeros(keys.shape, jnp.int32)
    return jax.lax.fori_loop(
        0, max_probes, body, (table_key, placed0, slot0)
    )


def _accumulate(table_val, vals, placed, slot_of, add_kind: str):
    """Semiring-reduce placed values into their slots (one scatter)."""
    table_cap = table_val.shape[0]
    seg = jnp.where(placed, slot_of, table_cap)  # discard slot for unplaced
    if add_kind == "sum":
        pad = jnp.zeros((1,), table_val.dtype)
        contrib = jnp.where(placed, vals, 0).astype(table_val.dtype)
        return jnp.concatenate([table_val, pad]).at[seg].add(contrib)[:table_cap]
    if add_kind == "min":
        pad = jnp.full((1,), jnp.inf, table_val.dtype)
        contrib = jnp.where(placed, vals, jnp.inf).astype(table_val.dtype)
        return jnp.concatenate([table_val, pad]).at[seg].min(contrib)[:table_cap]
    assert add_kind == "max", add_kind
    pad = jnp.full((1,), -jnp.inf, table_val.dtype)
    contrib = jnp.where(placed, vals, -jnp.inf).astype(table_val.dtype)
    return jnp.concatenate([table_val, pad]).at[seg].max(contrib)[:table_cap]


def table_init_val(add_kind: str) -> float:
    """Identity of the additive reduce — what EMPTY slots carry until claimed
    (``compress_sorted_keys`` discards them, so the identity never leaks)."""
    return {"sum": 0.0, "min": float("inf"), "max": float("-inf")}[add_kind]


def hash_insert_ref(
    table_key, table_val, keys, vals, valid, *, add_kind: str, max_probes: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pure-jnp oracle: insert one chunk of (key, val) partial products.

    Returns (table_key, table_val, dropped): the updated table and the count
    of valid entries that found neither their key nor an EMPTY slot within
    ``max_probes`` rounds (table-full overflow — caller retries with doubled
    caps, exactly like an ESC ``out_cap`` overflow).
    """
    table_key, placed, slot_of = _insert_rounds(
        table_key, keys, valid, max_probes
    )
    table_val = _accumulate(table_val, vals, placed, slot_of, add_kind)
    dropped = jnp.sum((valid & ~placed).astype(jnp.int32))
    return table_key, table_val, dropped


# ---------------------------------------------------------------------------
# Pallas kernel: same rounds, table resident in VMEM
# ---------------------------------------------------------------------------
def _hash_insert_kernel(
    keys_ref, vals_ref, valid_ref, tk_in_ref, tv_in_ref,
    tk_ref, tv_ref, drop_ref, *, add_kind: str, max_probes: int,
):
    keys = keys_ref[0, :]
    vals = vals_ref[0, :]
    valid = valid_ref[0, :] != 0
    tk, placed, slot_of = _insert_rounds(
        tk_in_ref[0, :], keys, valid, max_probes
    )
    tv = _accumulate(tv_in_ref[0, :], vals, placed, slot_of, add_kind)
    tk_ref[0, :] = tk
    tv_ref[0, :] = tv
    drop_ref[0, 0] = jnp.sum((valid & ~placed).astype(jnp.int32))


def hash_insert_pallas(
    table_key, table_val, keys, vals, valid,
    *, add_kind: str, max_probes: int, interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One pallas_call per chunk: whole table + chunk as single VMEM blocks
    (table_cap and chunk_cap are planner-bounded VMEM-resident sizes)."""
    table_cap = table_key.shape[0]
    chunk_cap = keys.shape[0]
    tk, tv, drop = pl.pallas_call(
        functools.partial(
            _hash_insert_kernel, add_kind=add_kind, max_probes=max_probes
        ),
        in_specs=[
            pl.BlockSpec((1, chunk_cap), lambda: (0, 0)),
            pl.BlockSpec((1, chunk_cap), lambda: (0, 0)),
            pl.BlockSpec((1, chunk_cap), lambda: (0, 0)),
            pl.BlockSpec((1, table_cap), lambda: (0, 0)),
            pl.BlockSpec((1, table_cap), lambda: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, table_cap), lambda: (0, 0)),
            pl.BlockSpec((1, table_cap), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, table_cap), jnp.int32),
            jax.ShapeDtypeStruct((1, table_cap), table_val.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        interpret=interpret,
    )(
        keys.reshape(1, -1),
        vals.reshape(1, -1),
        valid.astype(jnp.int32).reshape(1, -1),
        table_key.reshape(1, -1),
        table_val.reshape(1, -1),
    )
    return tk[0], tv[0], drop[0, 0]


def hash_insert(
    table_key, table_val, keys, vals, valid,
    *, add_kind: str, max_probes: int,
    use_pallas: bool = False, interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Dispatch one chunk insert to the Pallas kernel or the jnp oracle."""
    if use_pallas:
        return hash_insert_pallas(
            table_key, table_val, keys, vals, valid,
            add_kind=add_kind, max_probes=max_probes, interpret=interpret,
        )
    return hash_insert_ref(
        table_key, table_val, keys, vals, valid,
        add_kind=add_kind, max_probes=max_probes,
    )
