"""Pallas TPU kernel: k-binned paired SpGEMM — COO × COO → dense C.

The base paired kernel (``spgemm_acc.py``) forms the match matrix for *every*
(A-entry, B-entry) block pair: O(capA × capB) MXU pairings regardless of how
entries distribute over the contraction index k. Following Nagasaka et al.'s
binning insight (arXiv:1804.01698: bucket work by contraction structure before
accumulating), this kernel first distributes both operands into ``num_bins``
equal-width k-ranges with an XLA-side counting sort, then pairs **only
matching k-bins**:

    pairings drop from  capA × capB  to  Σ_g capA_g × capB_g
                                        (≤ num_bins × binA_cap × binB_cap)

Entries in different bins can never satisfy ``a_k == b_k``, so the skipped
pairings are exactly the structurally-impossible ones. Bin capacities are
static (JAX shapes): the host planner ``repro.core.symbolic.plan_k_bins``
sizes them from the exact per-k counts (``col_counts``) the symbolic step
already computes, and ``bin_entries_by_k`` reports an overflow count if the
caller's caps were beaten (paper §IV-A robustness discipline).

Padding sentinels match ``spgemm_acc.py``: A pads k with -1, B with -2 (never
equal), values with 0.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = dict(m_blk=128, n_blk=128, a_blk=256, b_blk=256)


# ---------------------------------------------------------------------------
# XLA-side binning (counting sort by k-range)
# ---------------------------------------------------------------------------
def bin_entries_by_k(
    k_idx, other, vals, valid, k_dim: int, num_bins: int, bin_cap: int,
    *, fill_k: int, fill_other: int, bin_map=None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Distribute COO entries into ``num_bins`` k-ranges.

    ``bin_map`` is a monotone i32[k_dim] map k → bin (quantile-balanced
    boundaries from ``plan_k_bins`` absorb skewed-k distributions); when None,
    equal-width ranges ``k * num_bins // k_dim`` are used. Returns
    (k_binned, other_binned, vals_binned, overflow), the first three of shape
    (num_bins, bin_cap) with sentinel-filled padding. Entries beyond a bin's
    capacity are dropped and counted in ``overflow`` (caller re-plans).
    """
    cap = k_idx.shape[0]
    if bin_map is None:
        bucket = jnp.where(valid, k_idx * num_bins // k_dim, num_bins)
    else:
        bin_map_pad = jnp.concatenate(
            [bin_map.astype(jnp.int32), jnp.full((1,), num_bins, jnp.int32)]
        )
        bucket = jnp.where(
            valid, bin_map_pad[jnp.clip(k_idx, 0, k_dim)], num_bins
        )
    # stable counting sort: order by bucket, carrying the entry payloads
    bucket_s, k_s, o_s, v_s = jax.lax.sort(
        (bucket.astype(jnp.int32), k_idx, other, vals), num_keys=1
    )
    counts = jnp.zeros((num_bins + 1,), jnp.int32).at[bucket].add(1)[:num_bins]
    starts = jnp.cumsum(counts) - counts  # exclusive prefix per bin
    bclip = jnp.clip(bucket_s, 0, num_bins - 1)
    within = jnp.arange(cap, dtype=jnp.int32) - starts[bclip]
    ok = (bucket_s < num_bins) & (within < bin_cap)
    dest = jnp.where(ok, bclip * bin_cap + within, num_bins * bin_cap)
    flat = num_bins * bin_cap
    kb = jnp.full((flat + 1,), fill_k, jnp.int32).at[dest].set(
        jnp.where(ok, k_s, fill_k)
    )[:flat]
    ob = jnp.full((flat + 1,), fill_other, jnp.int32).at[dest].set(
        jnp.where(ok, o_s, fill_other)
    )[:flat]
    vb = jnp.zeros((flat + 1,), vals.dtype).at[dest].set(
        jnp.where(ok, v_s, 0)
    )[:flat]
    overflow = jnp.sum(jnp.maximum(counts - bin_cap, 0)).astype(jnp.int32)
    shape2 = (num_bins, bin_cap)
    return kb.reshape(shape2), ob.reshape(shape2), vb.reshape(shape2), overflow


# ---------------------------------------------------------------------------
# Pallas kernel: pair only same-bin blocks
# ---------------------------------------------------------------------------
def _binned_kernel(
    ar_ref, ak_ref, av_ref, bk_ref, bc_ref, bv_ref, out_ref, *, m_blk, n_blk
):
    g = pl.program_id(2)
    s = pl.program_id(3)
    t = pl.program_id(4)

    @pl.when((g == 0) & (s == 0) & (t == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ar, ak, av = ar_ref[0, :], ak_ref[0, :], av_ref[0, :].astype(jnp.float32)
    bk, bc, bv = bk_ref[0, :], bc_ref[0, :], bv_ref[0, :].astype(jnp.float32)
    nbA, nbB = ar.shape[0], bk.shape[0]
    m_off = pl.program_id(0) * m_blk
    n_off = pl.program_id(1) * n_blk

    match = (ak[:, None] == bk[None, :]).astype(jnp.float32)
    w = av[:, None] * bv[None, :] * match  # (nbA, nbB)
    rowsel = (ar[None, :] - m_off == jax.lax.broadcasted_iota(
        jnp.int32, (m_blk, nbA), 0
    )).astype(jnp.float32)
    colsel = (bc[:, None] - n_off == jax.lax.broadcasted_iota(
        jnp.int32, (nbB, n_blk), 1
    )).astype(jnp.float32)
    acc = jnp.dot(rowsel, w, preferred_element_type=jnp.float32)
    out_ref[...] += jnp.dot(acc, colsel, preferred_element_type=jnp.float32)


def spgemm_paired_binned_pallas(
    a_rows, a_k, a_vals, b_k, b_cols, b_vals, m: int, n: int,
    *, m_blk=None, n_blk=None, a_blk=None, b_blk=None, interpret: bool = True,
) -> jnp.ndarray:
    """Dense C (m×n, f32) from k-binned COO entry lists of shape
    (num_bins, bin_cap*) — outputs of ``bin_entries_by_k``."""
    G, binA = a_rows.shape
    G2, binB = b_k.shape
    assert G == G2, (a_rows.shape, b_k.shape)
    m_blk = min(m_blk or DEFAULT_BLOCKS["m_blk"], _rup(m, 8))
    n_blk = min(n_blk or DEFAULT_BLOCKS["n_blk"], _rup(n, 128))
    a_blk = min(a_blk or DEFAULT_BLOCKS["a_blk"], _rup(binA, 8))
    b_blk = min(b_blk or DEFAULT_BLOCKS["b_blk"], _rup(binB, 8))

    m_pad, n_pad = _rup(m, m_blk), _rup(n, n_blk)
    binA_pad, binB_pad = _rup(binA, a_blk), _rup(binB, b_blk)
    a_rows = _pad2(a_rows, binA_pad, m_pad)
    a_k = _pad2(a_k, binA_pad, -1)
    a_vals = _pad2(a_vals, binA_pad, 0)
    b_k = _pad2(b_k, binB_pad, -2)
    b_cols = _pad2(b_cols, binB_pad, n_pad)
    b_vals = _pad2(b_vals, binB_pad, 0)

    grid = (
        m_pad // m_blk, n_pad // n_blk, G, binA_pad // a_blk, binB_pad // b_blk
    )
    out = pl.pallas_call(
        functools.partial(_binned_kernel, m_blk=m_blk, n_blk=n_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, a_blk), lambda i, j, g, s, t: (g, s)),
            pl.BlockSpec((1, a_blk), lambda i, j, g, s, t: (g, s)),
            pl.BlockSpec((1, a_blk), lambda i, j, g, s, t: (g, s)),
            pl.BlockSpec((1, b_blk), lambda i, j, g, s, t: (g, t)),
            pl.BlockSpec((1, b_blk), lambda i, j, g, s, t: (g, t)),
            pl.BlockSpec((1, b_blk), lambda i, j, g, s, t: (g, t)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, g, s, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(a_rows, a_k, a_vals, b_k, b_cols, b_vals)
    return out[:m, :n]


def spgemm_binned_dense(
    a_rows, a_cols, a_vals, valid_a, b_rows, b_cols, b_vals, valid_b,
    m: int, n: int, k_dim: int, num_bins: int, bin_cap_a: int, bin_cap_b: int,
    bin_map=None, use_pallas: bool = False, interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bin both COO operands by contraction index and evaluate the paired
    kernel on matching bins only: the one bin→pair→accumulate sequence shared
    by the jitted wrapper (``kernels.ops.spgemm_paired_binned``) and the
    distributed local multiply (``core.local_spgemm.spgemm_kbinned``).

    Returns (dense C (m, n) f32, bin-capacity overflow count). A's entries
    arrive as (row, k=col, val), B's as (k=row, col, val); callers supply the
    validity masks (gathered operands carry sentinel-k padding beyond nnz).
    """
    ak_b, ar_b, av_b, ovf_a = bin_entries_by_k(
        a_cols, a_rows, a_vals, valid_a, k_dim, num_bins, bin_cap_a,
        fill_k=-1, fill_other=m, bin_map=bin_map,
    )
    bk_b, bc_b, bv_b, ovf_b = bin_entries_by_k(
        b_rows, b_cols, b_vals, valid_b, k_dim, num_bins, bin_cap_b,
        fill_k=-2, fill_other=n, bin_map=bin_map,
    )
    if use_pallas:
        out = spgemm_paired_binned_pallas(
            ar_b, ak_b, av_b, bk_b, bc_b, bv_b, m, n, interpret=interpret
        )
    else:
        from . import ref

        out = ref.spgemm_paired_binned_ref(
            ar_b, ak_b, av_b, bk_b, bc_b, bv_b, m, n
        )
    return out, ovf_a + ovf_b


def pairing_counts(
    cap_a: int, cap_b: int, num_bins: int, bin_cap_a: int, bin_cap_b: int
) -> dict:
    """Static pairing-work comparison: unbinned O(capA×capB) grid vs the
    binned Σ_g capA_g×capB_g grid (both rounded to kernel block multiples)."""
    a_blk = min(DEFAULT_BLOCKS["a_blk"], _rup(cap_a, 8))
    b_blk = min(DEFAULT_BLOCKS["b_blk"], _rup(cap_b, 8))
    full = _rup(cap_a, a_blk) * _rup(cap_b, b_blk)
    a_blk_g = min(DEFAULT_BLOCKS["a_blk"], _rup(bin_cap_a, 8))
    b_blk_g = min(DEFAULT_BLOCKS["b_blk"], _rup(bin_cap_b, 8))
    binned = num_bins * _rup(bin_cap_a, a_blk_g) * _rup(bin_cap_b, b_blk_g)
    return {
        "pairings_unbinned": full,
        "pairings_binned": binned,
        "reduction": full / max(binned, 1),
    }


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad2(x, new_cols, fill):
    return jnp.pad(
        x, ((0, 0), (0, new_cols - x.shape[1])), constant_values=fill
    )
