"""Pallas TPU kernel: densify — scatter a padded COO entry list to a dense tile.

Utility kernel for the dense-accumulator SpGEMM path: the narrow per-batch
column block of B (Alg. 4) is scattered to dense once, then SpMM streams A
through it. Scatter = one-hot matmul (MXU), same idiom as the other kernels.

    colsel = one_hot(cols - n_off)          # (nnz_blk, n_blk)
    rowsel = one_hot(rows - m_off)          # (m_blk, nnz_blk)
    C_tile += rowsel @ (vals[:, None] * colsel)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = dict(m_blk=128, n_blk=128, nnz_blk=512)


def _densify_kernel(rows_ref, cols_ref, vals_ref, out_ref, *, m_blk, n_blk):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows, cols = rows_ref[...], cols_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    nb = rows.shape[0]
    m_off = pl.program_id(0) * m_blk
    n_off = pl.program_id(1) * n_blk

    colsel = (cols[:, None] - n_off == jax.lax.broadcasted_iota(
        jnp.int32, (nb, n_blk), 1
    )).astype(jnp.float32)
    rowsel = (rows[None, :] - m_off == jax.lax.broadcasted_iota(
        jnp.int32, (m_blk, nb), 0
    )).astype(jnp.float32)
    out_ref[...] += jnp.dot(
        rowsel, vals[:, None] * colsel, preferred_element_type=jnp.float32
    )


def densify_pallas(
    rows, cols, vals, m: int, n: int,
    *, m_blk=None, n_blk=None, nnz_blk=None, interpret: bool = True,
) -> jnp.ndarray:
    cap = rows.shape[0]
    m_blk = min(m_blk or DEFAULT_BLOCKS["m_blk"], _rup(m, 8))
    n_blk = min(n_blk or DEFAULT_BLOCKS["n_blk"], _rup(n, 128))
    nnz_blk = min(nnz_blk or DEFAULT_BLOCKS["nnz_blk"], _rup(cap, 8))
    m_pad, n_pad, cap_pad = _rup(m, m_blk), _rup(n, n_blk), _rup(cap, nnz_blk)
    rows = jnp.pad(rows, (0, cap_pad - cap), constant_values=m_pad)
    cols = jnp.pad(cols, (0, cap_pad - cap), constant_values=n_pad)
    vals = jnp.pad(vals, (0, cap_pad - cap), constant_values=0)

    grid = (m_pad // m_blk, n_pad // n_blk, cap_pad // nnz_blk)
    out = pl.pallas_call(
        functools.partial(_densify_kernel, m_blk=m_blk, n_blk=n_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnz_blk,), lambda i, j, s: (s,)),
            pl.BlockSpec((nnz_blk,), lambda i, j, s: (s,)),
            pl.BlockSpec((nnz_blk,), lambda i, j, s: (s,)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(rows, cols, vals)
    return out[:m, :n]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
