"""Pallas TPU kernels for the paper's compute hot-spots (§IV-D), with
pure-jnp oracles (ref.py) and jit'd wrappers (ops.py).

  spmm.py          sparse × dense   (MoE dispatch; dense-accumulator SpGEMM)
  spgemm_acc.py    COO × COO → dense tile (sort-free paired SpGEMM, the
                   paper's hash-SpGEMM adapted to the MXU/VMEM)
  spgemm_binned.py k-binned paired SpGEMM: counting-sort both operands by
                   contraction range, pair only matching k-bins —
                   O(Σ_g capA_g×capB_g) pairings instead of O(capA×capB)
                   (Nagasaka-style binning, arXiv:1804.01698)
  sort_engine.py   in-VMEM bitonic sort of packed (row,col)-key/value pairs —
                   the on-chip sort primitive behind the packed-key
                   sort/compress engine in ``repro.core.sortkeys``
  densify.py       COO → dense tile scatter

See DESIGN.md §3 for the CPU-hash → TPU-dense-accumulator adaptation story;
``repro.core.sortkeys`` documents the packed-key encoding and engine policy.
"""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    densify,
    sort_pairs,
    spgemm_paired,
    spgemm_paired_binned,
    spmm,
)
