"""Pallas TPU kernels for the paper's compute hot-spots (§IV-D), with
pure-jnp oracles (ref.py) and jit'd wrappers (ops.py).

  spmm.py        sparse × dense   (MoE dispatch; dense-accumulator SpGEMM)
  spgemm_acc.py  COO × COO → dense tile (sort-free paired SpGEMM, the paper's
                 hash-SpGEMM adapted to the MXU/VMEM)
  densify.py     COO → dense tile scatter

See DESIGN.md §3 for the CPU-hash → TPU-dense-accumulator adaptation story.
"""
from . import ops, ref  # noqa: F401
from .ops import densify, spgemm_paired, spmm  # noqa: F401
