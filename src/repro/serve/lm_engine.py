"""Batched serving engine: continuous-batching-lite over prefill/decode.

Requests enter a queue; the engine packs up to `max_batch` active sequences,
prefills new arrivals into free cache slots, and decodes all active slots in
lock-step (one jitted decode per tick). Finished sequences free their slot
immediately — the slot is refilled on the next tick (continuous batching).

On a pod, prefill and decode would run on disjoint cores (disaggregated
serving); here they interleave on the same mesh — the scheduling logic and
cache-slot machinery are the deliverable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tfm

Array = jnp.ndarray


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) tokens or (S, D) embeds
    max_new_tokens: int
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    s_max: int = 256
    greedy: bool = True
    eos_id: int = -1  # -1: never stop early


class ServeEngine:
    def __init__(self, cfg: tfm.ModelConfig, params, mesh, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.ecfg = ecfg
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}  # slot -> request
        self.slot_pos = np.zeros(ecfg.max_batch, np.int32)  # tokens in slot
        self.cache = tfm.init_cache(cfg, ecfg.max_batch, ecfg.s_max)
        self.done: List[Request] = []

        def _decode(params, cache, toks, index_vec):
            # per-slot positions: run decode with per-sequence cache_index by
            # using the max index and masking — single-program batching.
            # (per-slot masks are applied host-side on logits for simplicity)
            return tfm.decode_step(cfg, params, cache, toks, index_vec, mesh)

        self._decode = jax.jit(_decode, donate_argnums=(1,))

    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.ecfg.max_batch) if i not in self.active]

    def _prefill_into_slot(self, slot: int, req: Request):
        prompt = jnp.asarray(req.prompt)[None]  # (1, S) / (1, S, D)
        S = prompt.shape[1]
        logits, pcache = tfm.prefill(
            self.cfg, self.params, prompt, s_max=self.ecfg.s_max, mesh=self.mesh
        )
        # splice the single-sequence cache into the batched cache at `slot`
        def splice(batched, single):
            return batched.at[:, slot : slot + 1].set(single.astype(batched.dtype))

        self.cache = jax.tree.map(splice, self.cache, pcache)
        self.slot_pos[slot] = S
        tok = int(jnp.argmax(logits[0]))
        req.out_tokens.append(tok)
        self.active[slot] = req

    def step(self) -> int:
        """One engine tick. Returns number of active sequences."""
        # admit new requests into free slots (continuous batching)
        for slot in self._free_slots():
            if not self.queue:
                break
            self._prefill_into_slot(slot, self.queue.popleft())
        if not self.active:
            return 0
        # build the decode batch: last emitted token per active slot
        toks = np.zeros((self.ecfg.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out_tokens[-1]
        # lock-step decode at the max position; per-slot RoPE positions differ
        # by design tradeoff — serve engines pad to aligned positions.
        index = jnp.int32(int(self.slot_pos.max()))
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), index
        )
        logits = np.asarray(logits)
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(np.argmax(logits[slot]))
            req.out_tokens.append(tok)
            self.slot_pos[slot] += 1
            if (
                len(req.out_tokens) >= req.max_new_tokens
                or tok == self.ecfg.eos_id
                or self.slot_pos[slot] >= self.ecfg.s_max - 1
            ):
                finished.append(slot)
        for slot in finished:
            self.done.append(self.active.pop(slot))
            self.slot_pos[slot] = 0
        return len(self.active)

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
