from .engine import EngineConfig, Request, ServeEngine  # noqa: F401
