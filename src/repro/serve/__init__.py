from .engine import (  # noqa: F401
    MultiplyRequest,
    MultiplyResult,
    PlanCacheEntry,
    ServeConfig,
    SpgemmEngine,
    matrix_signature,
)
from .lm_engine import EngineConfig, Request, ServeEngine  # noqa: F401
