"""Multiply-as-a-service: a plan-cached SpGEMM serving engine.

Requests (pairs of host COO operands + semiring, optionally masked) enter a
FIFO queue. Admission control prices each request with the SAME memory model
the batched driver enforces (``batched.plan_footprint`` over the Alg. 3
plan): a request whose planned footprint does not fit alongside the
in-flight work is DEFERRED (FIFO, no overtaking); one that cannot fit the
``per_process_memory`` budget even alone is re-planned at finer batching
(``force_num_batches`` doublings, up to ``max_splits``) and REFUSED only
when no split fits.

The plan cache is keyed by the matrix signature — global shape, pow2 nnz
profile, pow2 scatter capacities, pow2 k-bin profile (max per-column
counts), semiring, local-path policy, mask id — and stores the pow2/floor
capacities of the last plan with that signature as one ``PlanFloors``.
Repeat traffic re-plans through ``plan_batches(spec=..., floors=...)`` with
the cached floors, landing on the IDENTICAL fused-step static signature: the
dispatch
goes through the driver's shared ``batched._fused_jit``, so a cache hit
costs zero retraces (asserted via ``summa3d.TRACE_COUNTS`` in the tests).

Concurrent in-flight requests interleave round-robin, one batch per engine
tick, through a shared ``runtime.driver.LookaheadWindow`` — batch overflow
flags are read ``lookahead`` dispatches late, so one request's host-side
assembly overlaps another's device compute. Per-request accounting lands in
a ``RunReport`` (retries / selection retries), plus latency and the price
the admission controller charged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import semiring as sr
from ..core.batched import (
    BatchPlan,
    RunReport,
    _fused_jit,
    batch_column_map,
    plan_batches,
    plan_footprint,
)
from ..core.distsparse import DistSparse, scatter_to_grid, tile_nnz_counts
from ..core.grid import Grid
from ..core.sparse import SparseCOO, from_numpy_coo
from ..core.specs import PlanFloors, PlanSpec
from ..core.summa3d import BatchCaps, BinnedCaps, HashCaps
from ..core.symbolic import rup8 as _rup8, rup_pow2 as _rup_pow2
from ..runtime.driver import LookaheadWindow


@dataclasses.dataclass
class MultiplyRequest:
    """One SpGEMM to serve: C = A·B under ``semiring`` (optionally ⊙ mask).

    ``mask`` (C-layout structure gating the output, §V-B) requires a caller
    ``mask_id``: mask VALUES never matter, so the id stands in for the mask's
    structure in the plan-cache key.
    """

    rid: int
    a: SparseCOO
    b: SparseCOO
    semiring: sr.Semiring = sr.PLUS_TIMES
    mask: Optional[SparseCOO] = None
    mask_id: Optional[str] = None


@dataclasses.dataclass
class ServeConfig:
    per_process_memory: int = 1 << 26
    r_bytes: int = 12
    slack: float = 1.3
    lookahead: int = 2  # in-flight window depth (shared across requests)
    max_retries: int = 4  # per-batch overflow retry bound
    max_splits: int = 3  # admission force_num_batches doublings before refusal
    local_path: str = "auto"  # 3-way local-multiply policy (part of the key)
    # base capacity floors applied to every FIRST plan of a signature (an
    # autotuner warm-start: repeat traffic still folds its own floors on top)
    seed_floors: Optional[PlanFloors] = None

    @classmethod
    def from_tuned(cls, tuned, **overrides) -> "ServeConfig":
        """Admission config from an autotuner ``TunedConfig`` (duck-typed:
        anything with per_process_memory / spec / floors / exec_spec) — the
        tuned local path, slack, lookahead, and batch-count floor flow
        straight into the pricing path, no kwarg threading."""
        kw = dict(
            per_process_memory=tuned.per_process_memory,
            r_bytes=tuned.spec.r_bytes,
            slack=tuned.spec.slack,
            lookahead=tuned.exec_spec.lookahead,
            max_retries=tuned.exec_spec.max_retries,
            local_path=tuned.spec.local_path,
            seed_floors=tuned.floors,
        )
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass
class MultiplyResult:
    rid: int
    status: str  # "ok" | "refused"
    c: Optional[SparseCOO]
    report: RunReport
    plan_cached: bool = False
    was_deferred: bool = False
    splits: int = 0
    latency_ms: float = 0.0
    price_bytes: int = 0
    num_batches: int = 0
    reason: str = ""


@dataclasses.dataclass
class PlanCacheEntry:
    """Floors for replanning repeat traffic onto one executable: ONE
    `PlanFloors` holding the pow2 capacities a previous same-signature
    request actually USED (monotone — retry growth folds back via
    ``merged()``), plus the decided local path and admission price."""

    floors: PlanFloors
    local_path: str
    price_bytes: int
    splits: int
    hits: int = 0


@dataclasses.dataclass
class _Active:
    """In-flight request state: scattered operands + the static dispatch
    capacities (grown in place by the per-batch retry ladder)."""

    req: MultiplyRequest
    key: tuple
    plan: BatchPlan
    A: DistSparse
    B: DistSparse
    M: Optional[DistSparse]
    nb: int
    caps: BatchCaps
    sel_cap: int
    kb: Optional[BinnedCaps]
    bin_of_k: Optional[jnp.ndarray]
    hc: Optional[HashCaps]
    mask_cap: int
    price: int
    splits: int
    plan_cached: bool
    was_deferred: bool
    t_submit: float
    bi: int = 0  # next batch to dispatch
    done_batches: int = 0
    retries: int = 0
    sel_retries: int = 0
    pieces: List[tuple] = dataclasses.field(default_factory=list)


def matrix_signature(req: MultiplyRequest, grid: Grid, cfg: ServeConfig) -> tuple:
    """Pow2-quantized request signature = the plan-cache key.

    Everything that feeds the fused step's STATIC signature is quantized to
    a power of two here (nnz profile, scatter capacities, max per-column
    counts), so near-identical repeat traffic maps to one key — and the
    scatter capacities are taken FROM the signature, which is what makes two
    same-key requests produce identical operand array shapes.
    """

    def prof(x: SparseCOO, kind: str):
        nnz = int(x.nnz)
        cols = np.asarray(x.cols)[:nnz]
        maxcol = int(np.bincount(cols).max()) if nnz else 0
        counts = tile_nnz_counts(x, grid, kind)
        cap = _rup_pow2(max(int(counts.max() * cfg.slack), 8))
        return (_rup_pow2(max(nnz, 1)), _rup_pow2(max(maxcol, 1)), cap)

    return (
        req.a.shape, req.b.shape,
        prof(req.a, "A"), prof(req.b, "B"),
        req.semiring.name, cfg.local_path, req.mask_id,
    )


def _batch_triplets(c: DistSparse, col_map: np.ndarray):
    """Host triplets of one sparse C batch in global coordinates."""
    pr, pc, l = c.grid_shape
    tm = c.tile_shape[0]
    R, C, V, N = (np.asarray(x) for x in (c.rows, c.cols, c.vals, c.nnz))
    valid = np.arange(R.shape[-1])[None, None, None, :] < N[..., None]
    i, j, kk, s = np.nonzero(valid)
    return i * tm + R[i, j, kk, s], col_map[j, kk, C[i, j, kk, s]], V[i, j, kk, s]


class SpgemmEngine:
    """Plan-cached SpGEMM serving engine on one device grid.

    ``submit`` enqueues; ``step`` runs one tick (admit → dispatch one batch
    per active request → reap); ``run_to_completion`` drains everything and
    returns the results in completion order.
    """

    def __init__(self, grid: Grid, cfg: Optional[ServeConfig] = None):
        self.grid = grid
        self.cfg = cfg or ServeConfig()
        self.queue: Deque[MultiplyRequest] = deque()
        self.active: List[_Active] = []
        self.done: List[MultiplyResult] = []
        self.plan_cache: Dict[tuple, PlanCacheEntry] = {}
        self.in_use = 0  # admitted bytes currently in flight
        self.stats = {"hits": 0, "misses": 0, "deferred": 0, "refused": 0,
                      "splits": 0, "served": 0}
        self._t_submit: Dict[int, float] = {}
        self._deferred_rids: set = set()
        self._head: Optional[_Active] = None  # priced-but-not-admitted head
        self._window = LookaheadWindow(self.cfg.lookahead, self._finish)

    # -- admission ---------------------------------------------------------
    def submit(self, req: MultiplyRequest) -> None:
        if req.mask is not None:
            assert req.mask_id is not None, "masked requests need a mask_id"
        self._t_submit[req.rid] = time.perf_counter()
        self.queue.append(req)

    def _price(self, req: MultiplyRequest) -> Tuple[Optional[_Active], str]:
        """Scatter + plan + price one request (the head of the queue).

        Splits the plan (force_num_batches doublings) while its footprint
        exceeds the budget; returns ``(None, reason)`` when no allowed split
        fits — the request is refused without dispatching anything.
        """
        cfg = self.cfg
        key = matrix_signature(req, self.grid, cfg)
        entry = self.plan_cache.get(key)
        cap_a, cap_b = key[2][2], key[3][2]  # pow2 scatter caps from the key
        A = scatter_to_grid(req.a, self.grid, "A", cap=cap_a)
        B = scatter_to_grid(req.b, self.grid, "B", cap=cap_b)
        M = (scatter_to_grid(req.mask, self.grid, "A")
             if req.mask is not None else None)
        if entry is not None:
            floors = entry.floors
        else:
            floors = cfg.seed_floors or PlanFloors()
        floors = floors.replace(caps_pow2=True)
        local_path = entry.local_path if entry is not None else cfg.local_path
        max_nnz_a = int(np.asarray(A.nnz).max())
        max_nnz_b = int(np.asarray(B.nnz).max())
        splits = entry.splits if entry is not None else 0
        force = None
        while True:
            try:
                plan = plan_batches(
                    A, B, self.grid, per_process_memory=cfg.per_process_memory,
                    spec=PlanSpec(
                        mask=M, local_path=local_path, slack=cfg.slack,
                        r_bytes=cfg.r_bytes, force_num_batches=force,
                    ),
                    floors=floors,
                )
            except MemoryError as e:
                return None, str(e)
            price = plan_footprint(
                plan.caps, plan.sel_cap, plan.hash_caps,
                r_bytes=cfg.r_bytes, max_nnz_a=max_nnz_a, max_nnz_b=max_nnz_b,
            )
            if price <= cfg.per_process_memory or splits >= cfg.max_splits:
                break
            splits += 1
            self.stats["splits"] += 1
            force = plan.num_batches * 2
        if price > cfg.per_process_memory:
            return None, (
                f"footprint {price} exceeds budget {cfg.per_process_memory} "
                f"after {splits} splits"
            )
        use_hash = plan.local_path == "hash"
        use_binned = (
            not use_hash and plan.local_path == "binned"
            and req.semiring.name == "plus_times"
        )
        kb = None
        if use_binned:
            kb = BinnedCaps(
                plan.kbin.num_bins, _rup_pow2(plan.kbin.bin_cap_a),
                _rup_pow2(plan.kbin.bin_cap_b),
            )
            prior_kb = entry.floors.kbin_caps if entry is not None else None
            if prior_kb is not None:
                kb = BinnedCaps(
                    kb.num_bins,
                    max(kb.bin_cap_a, prior_kb.bin_cap_a),
                    max(kb.bin_cap_b, prior_kb.bin_cap_b),
                )
        # the cache entry is written at PLAN time (not completion) so repeat
        # traffic hits even while the first request with this signature is
        # still in flight; completion folds any retry growth back in.
        if entry is not None:
            entry.hits += 1
            self.stats["hits"] += 1
        else:
            self.stats["misses"] += 1
            self.plan_cache[key] = PlanCacheEntry(
                floors=PlanFloors(
                    caps=plan.caps, sel_cap=plan.sel_cap,
                    num_batches=plan.num_batches,
                    kbin_caps=kb,
                    hash_caps=(plan.hash_caps if use_hash else None),
                    caps_pow2=True,
                ),
                local_path=plan.local_path,
                price_bytes=price, splits=splits,
            )
        return _Active(
            req=req, key=key, plan=plan, A=A, B=B, M=M,
            nb=plan.num_batches, caps=plan.caps, sel_cap=plan.sel_cap,
            kb=kb, bin_of_k=(jnp.asarray(plan.kbin.bin_of_k) if use_binned
                             else None),
            hc=(plan.hash_caps if use_hash else None),
            mask_cap=plan.mask_sel_cap, price=price, splits=splits,
            plan_cached=entry is not None,
            was_deferred=req.rid in self._deferred_rids,
            t_submit=self._t_submit.pop(req.rid, time.perf_counter()),
        ), ""

    def _admit(self) -> None:
        """FIFO admission: price the head, defer it while the in-flight work
        leaves no room (never overtaken), refuse what no split can fit."""
        while self.queue:
            req = self.queue[0]
            if self._head is None or self._head.req.rid != req.rid:
                act, reason = self._price(req)
                if act is None:
                    self.queue.popleft()
                    self.stats["refused"] += 1
                    self.done.append(MultiplyResult(
                        rid=req.rid, status="refused", c=None,
                        report=RunReport(), reason=reason,
                        was_deferred=req.rid in self._deferred_rids,
                    ))
                    self._deferred_rids.discard(req.rid)
                    continue
                self._head = act
            act = self._head
            if self.in_use > 0 and (
                self.in_use + act.price > self.cfg.per_process_memory
            ):
                if req.rid not in self._deferred_rids:
                    self._deferred_rids.add(req.rid)
                    self.stats["deferred"] += 1
                    act.was_deferred = True
                return  # FIFO: nothing behind the head may overtake it
            self.queue.popleft()
            self._deferred_rids.discard(req.rid)
            self._head = None
            self.in_use += act.price
            self.active.append(act)

    # -- dispatch / finish -------------------------------------------------
    def _dispatch(self, act: _Active, bi: int):
        return _fused_jit(
            act.A, act.B, jnp.int32(bi), act.bin_of_k, act.M,
            grid=self.grid, num_batches=act.nb, sel_cap=act.sel_cap,
            caps=act.caps, semiring=act.req.semiring, sorted_merge=True,
            path="sparse", kbin=act.kb, hashc=act.hc, mask_cap=act.mask_cap,
            mask_complement=False,
        )

    def _finish(self, act: _Active, bi: int, c_batch, ovf) -> None:
        """Window sync point: read batch bi's flags, retry if beaten, then
        assemble the batch's triplets on the host."""
        o = np.asarray(ovf)
        for _ in range(self.cfg.max_retries):
            if not o.any():
                break
            act.retries += 1
            if o[0] > 0:
                act.sel_retries += 1
                act.sel_cap = min(
                    _rup8(max(act.sel_cap * 2, 8)), act.B.cap
                )
            elif o[1] > 0:
                act.caps = act.caps.doubled()
                act.hc = act.hc.doubled() if act.hc is not None else None
                act.kb = act.kb.doubled() if act.kb is not None else None
                if act.M is not None:
                    act.mask_cap = min(act.mask_cap * 2, act.M.cap)
            c_batch, ovf = self._dispatch(act, bi)
            o = np.asarray(ovf)
        assert not o.any(), (
            f"rid {act.req.rid} batch {bi}: overflow persisted after "
            f"{self.cfg.max_retries} retries"
        )
        col_map = batch_column_map(
            act.B.shape[1], self.grid, act.nb, bi
        )
        act.pieces.append(_batch_triplets(c_batch, col_map))
        act.done_batches += 1

    def _reap(self) -> None:
        for act in [a for a in self.active if a.done_batches == a.nb]:
            self.active.remove(act)
            self.in_use -= act.price
            rows = np.concatenate([p[0] for p in act.pieces])
            cols = np.concatenate([p[1] for p in act.pieces])
            vals = np.concatenate([p[2] for p in act.pieces])
            shape = (act.A.shape[0], act.B.shape[1])
            c = from_numpy_coo(rows, cols, vals, shape, cap=max(len(rows), 8))
            # fold retry growth back into the entry (monotone floors)
            entry = self.plan_cache[act.key]
            entry.floors = entry.floors.merged(PlanFloors(
                caps=act.caps, sel_cap=act.sel_cap, num_batches=act.nb,
                kbin_caps=act.kb, hash_caps=act.hc, caps_pow2=True,
            ))
            entry.price_bytes = max(entry.price_bytes, act.price)
            self.stats["served"] += 1
            self.done.append(MultiplyResult(
                rid=act.req.rid, status="ok", c=c,
                report=RunReport(retries=act.retries,
                                 sel_retries=act.sel_retries),
                plan_cached=act.plan_cached, was_deferred=act.was_deferred,
                splits=act.splits,
                latency_ms=(time.perf_counter() - act.t_submit) * 1e3,
                price_bytes=act.price, num_batches=act.nb,
            ))

    # -- scheduling --------------------------------------------------------
    def step(self) -> int:
        """One engine tick. Returns the number of requests still in the
        system (queued + in flight)."""
        self._admit()
        progressed = False
        for act in list(self.active):
            if act.bi < act.nb:
                c_batch, ovf = self._dispatch(act, act.bi)
                self._window.push(act, act.bi, c_batch, ovf)
                act.bi += 1
                progressed = True
        if not progressed:
            self._window.drain()
        self._reap()
        return len(self.active) + len(self.queue)

    def run_to_completion(self, max_ticks: int = 100_000) -> List[MultiplyResult]:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        assert not (self.queue or self.active), "engine did not drain"
        return self.done

    def cache_hit_rate(self) -> float:
        total = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / total if total else 0.0
