"""Model zoo: one decoder-LM implementation covering all assigned families."""
from .transformer import (  # noqa: F401
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_loss,
    param_specs,
    prefill,
)
