"""Decoder LM covering all assigned architecture families.

One ``ModelConfig`` describes dense (llama/starcoder/granite/minitron),
gemma2 (alternating local/global + soft-caps + post-norms), MoE
(deepseek-moe/olmoe with SpGEMM dispatch), SSM (mamba2), hybrid (zamba2:
mamba backbone + a weight-shared attention block every k layers), and
embeds-input stubs (pixtral vision / musicgen audio frontends).

Params are nested dicts; layers are stacked along a leading L axis and
iterated with ``lax.scan`` (compile time ~ one layer). ``param_specs``
returns the parallel PartitionSpec tree (TP over "model", see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import MODEL_AX, dense_init, embed_init, rms_norm, soft_cap

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    act: str = "swiglu"
    rope_theta: float = 10_000.0
    family: str = "attn"  # "attn" | "ssm" | "hybrid"
    # gemma2-style features
    local_global_alt: bool = False
    window: Optional[int] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    use_post_norms: bool = False
    embed_scale: bool = False
    # MoE / SSM / hybrid
    moe: Optional[moe_mod.MoEConfig] = None
    ssm: Optional[ssm_mod.SSMConfig] = None
    hybrid_every: int = 6
    # IO
    input_mode: str = "tokens"  # "tokens" | "embeds" (modality-frontend stub)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # which serve shapes apply (encoder-only would disable decode; all ours decode)
    supports_long_context: bool = False  # sub-quadratic decode state
    # Megatron-style vocab padding: keeps the unembed shardable over "model"
    # for any tokenizer size (e.g. mamba2's 50280). Padded logits are masked
    # to -inf, so loss/sampling are exact.
    vocab_pad_multiple: int = 128
    # --- beyond-baseline sharding knobs (EXPERIMENTS.md section Perf) ---
    # pad attention heads per GQA group with zero heads so the head dim
    # divides the model axis (e.g. starcoder2 36 -> 48); function-exact.
    pad_heads_to: int = 0
    # activation sharding constraint between layers: None = GSPMD choice,
    # "seq" = sequence parallelism (residual sharded over "model" on S).
    act_sharding: Optional[str] = None

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    @property
    def eff_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def param_dtype(self):
        return jnp.float32  # master weights; compute casts per-step

    @property
    def compute_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def active_param_count(self) -> int:
        """Approximate activated params per token (for 6·N·D MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        if self.family == "ssm":
            cfg = self.ssm
            di = cfg.d_inner(D)
            per_layer = D * di * 2 + D * (2 * cfg.n_groups * cfg.d_state) + di * D
        elif self.family == "hybrid":
            cfg = self.ssm
            di = cfg.d_inner(D)
            per_layer = D * di * 2 + D * (2 * cfg.n_groups * cfg.d_state) + di * D
            # shared attention block amortized over hybrid_every layers
            shared = (
                D * self.n_heads * self.hdim * 2
                + D * self.kv_heads * self.hdim * 2
                + 3 * D * F
            )
            per_layer += shared // self.hybrid_every
        else:
            attn = D * self.n_heads * self.hdim * 2 + D * self.kv_heads * self.hdim * 2
            if self.moe:
                m = self.moe
                ffn = m.top_k * 3 * D * m.d_expert + m.n_shared * 3 * D * m.d_expert
            else:
                ffn = (3 if self.act == "swiglu" else 2) * D * F
            per_layer = attn + ffn
        return L * per_layer + V * D  # + unembed


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _stack_init(key, n: int, fn):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = cfg.param_dtype
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.padded_vocab), dtype=dtype
        )
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)

    if cfg.family == "attn":
        def layer_init(k):
            k1, k2 = jax.random.split(k)
            lp = {
                "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": attn_mod.init_attention(
                    k1, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hdim, dtype,
                    pad_heads_to=cfg.pad_heads_to,
                ),
            }
            if cfg.use_post_norms:
                lp["ln1_post"] = jnp.zeros((cfg.d_model,), jnp.float32)
                lp["ln2_post"] = jnp.zeros((cfg.d_model,), jnp.float32)
            if cfg.moe:
                lp["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe, dtype)
            else:
                lp["mlp"] = mlp_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)
            return lp

        params["layers"] = _stack_init(keys[2], cfg.n_layers, layer_init)
    elif cfg.family == "ssm":
        def layer_init(k):
            return {
                "ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "mamba": ssm_mod.init_mamba2(k, cfg.d_model, cfg.ssm, dtype),
            }

        params["layers"] = _stack_init(keys[2], cfg.n_layers, layer_init)
    elif cfg.family == "hybrid":
        def layer_init(k):
            return {
                "ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "mamba": ssm_mod.init_mamba2(k, cfg.d_model, cfg.ssm, dtype),
            }

        params["layers"] = _stack_init(keys[2], cfg.n_layers, layer_init)
        k1, k2 = jax.random.split(keys[3])
        params["shared_block"] = {
            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": attn_mod.init_attention(
                k1, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hdim, dtype
            ),
            "mlp": mlp_mod.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }
    else:
        raise ValueError(cfg.family)
    return params


def param_specs(cfg: ModelConfig, tp: int = 1) -> Dict[str, Any]:
    """PartitionSpec tree; tp = size of the "model" axis for divisibility
    fallbacks (dims that don't divide tp are replicated)."""
    d_ax = MODEL_AX if tp > 1 and cfg.d_model % tp == 0 else None
    v_ax = MODEL_AX if tp > 1 and cfg.padded_vocab % tp == 0 else None
    specs: Dict[str, Any] = {"final_norm": P(None)}
    if cfg.input_mode == "tokens":
        # untied: shard the hidden dim (lookup needs no collective).
        # tied: shard the VOCAB dim so the unembed contraction keeps logits
        # vocab-sharded (otherwise (B,S,V) materializes replicated — 13 GB/dev
        # for mamba2 train_4k); the lookup costs one table all-gather, orders
        # of magnitude smaller.
        specs["embed"] = P(v_ax, None) if cfg.tie_embeddings else P(None, d_ax)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, v_ax)

    def _stack(d):  # prepend the layer axis (unsharded)
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))), d,
                            is_leaf=lambda x: isinstance(x, P))

    if cfg.family == "attn":
        lp = {"ln1": P(None), "ln2": P(None),
              "attn": attn_mod.attention_specs(cfg.eff_heads, cfg.kv_heads, tp)}
        if cfg.use_post_norms:
            lp["ln1_post"] = P(None)
            lp["ln2_post"] = P(None)
        if cfg.moe:
            lp["moe"] = moe_mod.moe_specs(cfg.moe, tp)
        else:
            lp["mlp"] = mlp_mod.mlp_specs(cfg.act, cfg.d_ff, tp)
        specs["layers"] = _stack(lp)
    else:
        lp = {"ln": P(None),
              "mamba": ssm_mod.mamba2_specs(cfg.ssm, cfg.d_model, tp)}
        specs["layers"] = _stack(lp)
        if cfg.family == "hybrid":
            specs["shared_block"] = {
                "ln1": P(None),
                "ln2": P(None),
                "attn": attn_mod.attention_specs(cfg.n_heads, cfg.kv_heads, tp),
                "mlp": mlp_mod.mlp_specs(cfg.act, cfg.d_ff, tp),
            }
    return specs


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def _act_constraint(cfg: ModelConfig, mesh, h: Array) -> Array:
    """Sequence-parallel residual constraint (cfg.act_sharding == "seq"):
    between layers the residual stream is sharded over "model" along S —
    GSPMD converts the per-layer collectives to the AG/RS pattern of
    Megatron-SP and divides residual memory by tp."""
    if mesh is None or cfg.act_sharding != "seq":
        return h
    if h.shape[1] % mesh.shape.get(MODEL_AX, 1) != 0:
        return h
    from .common import batch_axes

    dp = batch_axes(mesh)
    dspec = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(
        h, jax.sharding.NamedSharding(mesh, P(dspec, MODEL_AX, None))
    )


def _layer_windows(cfg: ModelConfig, s_ref: int) -> Array:
    """Per-layer attention window as a traced scan input (gemma2 alternation:
    even layers local, odd global). A huge window == unconstrained."""
    big = jnp.int32(2**30)
    if cfg.local_global_alt:
        loc = jnp.int32(cfg.window)
        return jnp.where(jnp.arange(cfg.n_layers) % 2 == 0, loc, big)
    if cfg.window is not None:
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    return jnp.full((cfg.n_layers,), big, jnp.int32)


def _gather_for_attn(cfg: ModelConfig, mesh, x: Array) -> Array:
    """Megatron-SP: one explicit sequence all-gather of the normed residual
    before the qkv projections (instead of GSPMD gathering each of q/k/v
    post-projection — 2-3× the volume)."""
    if mesh is None or cfg.act_sharding != "seq":
        return x
    from .common import batch_axes

    dp = batch_axes(mesh)
    dspec = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(dspec, None, None))
    )


def _attn_layer_fwd(cfg: ModelConfig, mesh, lp, h, positions, window,
                    kv_cache=None, cache_index=None, moe_mode="a2a"):
    att, new_cache = attn_mod.attend(
        lp["attn"], _gather_for_attn(cfg, mesh, rms_norm(h, lp["ln1"])),
        positions,
        rope_theta=cfg.rope_theta, window=window,
        attn_softcap=cfg.attn_softcap, query_scale=cfg.query_scale,
        kv_cache=kv_cache, cache_index=cache_index,
    )
    if cfg.use_post_norms:
        att = rms_norm(att, lp["ln1_post"])
    h = h + att
    ff_in = rms_norm(h, lp["ln2"])
    if cfg.moe:
        ff, aux = moe_mod.moe_layer(lp["moe"], ff_in, cfg.moe, mesh, mode=moe_mode)
    else:
        ff, aux = mlp_mod.mlp(lp["mlp"], ff_in, cfg.act), jnp.float32(0)
    if cfg.use_post_norms:
        ff = rms_norm(ff, lp["ln2_post"])
    return h + ff, aux, new_cache


def _shared_block_fwd(cfg: ModelConfig, sp, h, positions):
    att, _ = attn_mod.attend(
        sp["attn"], rms_norm(h, sp["ln1"]), positions,
        rope_theta=cfg.rope_theta, query_scale=cfg.query_scale,
    )
    h = h + att
    h = h + mlp_mod.mlp(sp["mlp"], rms_norm(h, sp["ln2"]), cfg.act)
    return h


def forward(
    cfg: ModelConfig,
    params: Dict[str, Any],
    inputs: Array,  # (B,S) tokens or (B,S,D) embeds
    mesh=None,
) -> Tuple[Array, Array]:
    """Returns (logits (B,S,V), aux loss scalar)."""
    cd = cfg.compute_dtype
    if cfg.input_mode == "tokens":
        h = params["embed"].astype(cd)[inputs]
    else:
        h = inputs.astype(cd)
    if cfg.embed_scale:
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cd)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    aux_total = jnp.float32(0)
    if cfg.family == "attn":
        windows = _layer_windows(cfg, S)

        def body(h, xs):
            lp, window = xs
            lp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                              and p.ndim > 1 else p, lp)
            h2, aux, _ = _attn_layer_fwd(cfg, mesh, lp, h, positions, window)
            return _act_constraint(cfg, mesh, h2), aux

        if cfg.remat:
            body = jax.checkpoint(body)
        h, auxs = jax.lax.scan(body, h, (params["layers"], windows))
        aux_total = jnp.sum(auxs)
    elif cfg.family == "ssm":
        def body(h, lp):
            lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                and p.ndim > 1 else p, lp)
            h = h + ssm_mod.mamba2_block(
                lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm
            )
            return _act_constraint(cfg, mesh, h), jnp.float32(0)

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["layers"])
    else:  # hybrid
        k = cfg.hybrid_every
        ngroups = cfg.n_layers // k
        stacked = jax.tree.map(
            lambda p: p.reshape((ngroups, k) + p.shape[1:]), params["layers"]
        )
        sp = params["shared_block"]
        sp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                          and p.ndim > 1 else p, sp)

        def group_body(h, lp_group):
            def inner(h, lp):
                lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                    and p.ndim > 1 else p, lp)
                h = h + ssm_mod.mamba2_block(
                    lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm
                )
                return h, None

            h, _ = jax.lax.scan(inner, h, lp_group)
            h = _shared_block_fwd(cfg, sp, h, positions)
            return h, None

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        h, _ = jax.lax.scan(group_body, h, stacked)

    h = rms_norm(h, params["final_norm"])
    w_out = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cd)
    logits = jnp.einsum("bsd,dv->bsv", h, w_out)
    logits = soft_cap(logits.astype(jnp.float32), cfg.final_softcap)
    return _mask_pad_vocab(cfg, logits), aux_total


def _mask_pad_vocab(cfg: ModelConfig, logits: Array) -> Array:
    """Padded vocab entries get -inf so softmax/argmax are exact."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    live = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(live, logits, -1e30)


@jax.custom_vjp
def _vp_xent_local(lg: Array, tg: Array) -> Array:
    nll, _ = _vp_xent_fwd(lg, tg)
    return nll


def _vp_xent_fwd(lg, tg):
    """Runs inside shard_map over the model axis. lg (b,s,v_loc) f32."""
    v_loc = lg.shape[-1]
    off = jax.lax.axis_index(MODEL_AX) * v_loc
    m = jax.lax.pmax(jnp.max(lg, axis=-1), MODEL_AX)  # (b,s)
    s = jax.lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1), MODEL_AX)
    lse = m + jnp.log(s)
    t_loc = tg - off
    in_range = (t_loc >= 0) & (t_loc < v_loc)
    tl = jnp.take_along_axis(
        lg, jnp.clip(t_loc, 0, v_loc - 1)[..., None], axis=-1
    )[..., 0]
    tl = jax.lax.psum(jnp.where(in_range, tl, 0.0), MODEL_AX)
    nll = lse - tl
    return nll, (lg, lse, t_loc, in_range)


def _vp_xent_bwd(res, g):
    """d nll / d lg = softmax(lg) - onehot(target) — purely local."""
    lg, lse, t_loc, in_range = res
    v_loc = lg.shape[-1]
    softmax = jnp.exp(lg - lse[..., None])
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, len(lg.shape) - 1)
    onehot = (iota == t_loc[..., None]) & in_range[..., None]
    dlg = (softmax - onehot.astype(jnp.float32)) * g[..., None]
    return dlg, None


_vp_xent_local.defvjp(_vp_xent_fwd, _vp_xent_bwd)


def _sharded_xent(cfg: ModelConfig, mesh, logits: Array, targets: Array) -> Array:
    """Megatron-style vocab-parallel cross entropy: each model shard extracts
    its local target logits (masked gather) and computes a partial logsumexp;
    both reduce with one tiny psum. Never materializes a replicated (B,S,V)
    or a one-hot tensor. custom_vjp because pmax has no autodiff rule."""
    from .common import batch_axes

    dp = batch_axes(mesh)
    dspec = dp if len(dp) > 1 else dp[0]
    lspec = P(dspec, None, MODEL_AX)
    tspec = P(dspec, None)

    def local(lg, tg):
        return _vp_xent_local(lg.astype(jnp.float32), tg)

    from ..compat import shard_map as _shard_map

    nll = _shard_map(
        local, mesh=mesh, in_specs=(lspec, tspec), out_specs=tspec,
        check_vma=False,
    )(logits, targets)
    return nll.mean()


def lm_loss(cfg: ModelConfig, params, inputs, targets, mesh=None,
            aux_weight: float = 0.01):
    logits, aux = forward(cfg, params, inputs, mesh)
    if mesh is not None and "model" in mesh.axis_names:
        nll_mean = _sharded_xent(cfg, mesh, logits, targets)
        return nll_mean + aux_weight * aux
    # single-device fallback (smoke tests)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B,S)
    onehot = jax.nn.one_hot(targets, cfg.padded_vocab, dtype=jnp.float32)
    target_logit = jnp.sum(logits * onehot, axis=-1)  # (B,S)
    nll = lse - target_logit
    return nll.mean() + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: KV / SSM state caches + single-token decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> Dict[str, Any]:
    """Decode-state pytree. Attention: (L,B,S_max,kvH,hd) k/v. SSM: conv +
    state per layer. Hybrid: SSM states + per-application shared-block KV."""
    cd = cfg.compute_dtype
    cache: Dict[str, Any] = {}
    if cfg.family == "attn":
        shape = (cfg.n_layers, batch, s_max, cfg.kv_heads, cfg.hdim)
        cache["k"] = jnp.zeros(shape, cd)
        cache["v"] = jnp.zeros(shape, cd)
    elif cfg.family == "ssm":
        conv, st = ssm_mod.init_mamba2_state(cfg.ssm, cfg.d_model, batch, cd)
        cache["conv"] = jnp.broadcast_to(conv, (cfg.n_layers,) + conv.shape)
        cache["ssm"] = jnp.broadcast_to(st, (cfg.n_layers,) + st.shape)
    else:  # hybrid
        conv, st = ssm_mod.init_mamba2_state(cfg.ssm, cfg.d_model, batch, cd)
        cache["conv"] = jnp.broadcast_to(conv, (cfg.n_layers,) + conv.shape)
        cache["ssm"] = jnp.broadcast_to(st, (cfg.n_layers,) + st.shape)
        napps = cfg.n_layers // cfg.hybrid_every
        shape = (napps, batch, s_max, cfg.kv_heads, cfg.hdim)
        cache["k"] = jnp.zeros(shape, cd)
        cache["v"] = jnp.zeros(shape, cd)
    return cache


def cache_specs(cfg: ModelConfig, mesh, batch: Optional[int] = None,
                s_max: Optional[int] = None) -> Dict[str, Any]:
    """Sharding for the cache: batch over data axes (when divisible), heads
    over "model"; when kv heads can't shard (MQA / kv < tp), the SEQUENCE dim
    shards over "model" instead (flash-decoding style: attention reductions
    over the sharded context psum under GSPMD) — without this, gemma2-class
    decode replicates a 273 GB/device cache (EXPERIMENTS.md §Perf). batch=1
    (long_500k) keeps batch unsharded — the state is small by construction."""
    from .common import batch_axes

    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if (batch is None or batch % dp_size == 0) else None
    tp = mesh.shape[MODEL_AX]
    head_ax = MODEL_AX if cfg.kv_heads % tp == 0 and cfg.kv_heads >= tp else None
    seq_ax = None
    if head_ax is None and tp > 1 and (s_max is None or s_max % tp == 0):
        seq_ax = MODEL_AX
    specs: Dict[str, Any] = {}
    if cfg.family in ("attn", "hybrid"):
        specs["k"] = P(None, bspec, seq_ax, head_ax, None)
        specs["v"] = P(None, bspec, seq_ax, head_ax, None)
    if cfg.family in ("ssm", "hybrid"):
        nh = cfg.ssm.n_heads(cfg.d_model)
        ssm_head_ax = MODEL_AX if nh % tp == 0 else None
        specs["conv"] = P(None, bspec, None, None)
        specs["ssm"] = P(None, bspec, ssm_head_ax, None, None)
    return specs


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    cache: Dict[str, Any],
    inputs: Array,  # (B,1) tokens or (B,1,D) embeds
    cache_index: Array,  # scalar i32 — number of tokens already in cache
    mesh=None,
) -> Tuple[Array, Dict[str, Any]]:
    """One new token for every sequence in the batch. Returns (logits (B,V),
    updated cache). The ``decode_*``/``long_*`` dry-run shapes lower this."""
    cd = cfg.compute_dtype
    if cfg.input_mode == "tokens":
        h = params["embed"].astype(cd)[inputs]
    else:
        h = inputs.astype(cd)
    if cfg.embed_scale:
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cd)
    B = h.shape[0]
    positions = jnp.broadcast_to(cache_index, (B, 1))

    if cfg.family == "attn":
        windows = _layer_windows(cfg, 1)

        def body(h, xs):
            lp, window, ck, cv = xs
            lp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                              and p.ndim > 1 else p, lp)
            h2, _, new_kv = _attn_layer_fwd(
                cfg, mesh, lp, h, positions, window,
                kv_cache=(ck, cv), cache_index=cache_index, moe_mode="dense_ep",
            )
            return h2, new_kv

        h, new_kv = jax.lax.scan(
            body, h, (params["layers"], windows, cache["k"], cache["v"])
        )
        new_cache = {"k": new_kv[0], "v": new_kv[1]}
    elif cfg.family == "ssm":
        def body(h, xs):
            lp, conv, st = xs
            lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                and p.ndim > 1 else p, lp)
            out, (nconv, nst) = ssm_mod.mamba2_decode_step(
                lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm, (conv, st)
            )
            return h + out, (nconv, nst)

        h, (nconv, nst) = jax.lax.scan(
            body, h, (params["layers"], cache["conv"], cache["ssm"])
        )
        new_cache = {"conv": nconv, "ssm": nst}
    else:  # hybrid
        k = cfg.hybrid_every
        ngroups = cfg.n_layers // k
        stacked = jax.tree.map(
            lambda p: p.reshape((ngroups, k) + p.shape[1:]), params["layers"]
        )
        conv_g = cache["conv"].reshape((ngroups, k) + cache["conv"].shape[1:])
        ssm_g = cache["ssm"].reshape((ngroups, k) + cache["ssm"].shape[1:])
        sp = params["shared_block"]
        sp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                          and p.ndim > 1 else p, sp)

        def group_body(h, xs):
            lp_group, conv_l, ssm_l, ck, cv = xs

            def inner(h, ys):
                lp, conv, st = ys
                lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                    and p.ndim > 1 else p, lp)
                out, (nconv, nst) = ssm_mod.mamba2_decode_step(
                    lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm, (conv, st)
                )
                return h + out, (nconv, nst)

            h, (nconv, nst) = jax.lax.scan(inner, h, (lp_group, conv_l, ssm_l))
            att, (nk, nv) = attn_mod.attend(
                sp["attn"], rms_norm(h, sp["ln1"]), positions,
                rope_theta=cfg.rope_theta, query_scale=cfg.query_scale,
                kv_cache=(ck, cv), cache_index=cache_index,
            )
            h = h + att
            h = h + mlp_mod.mlp(sp["mlp"], rms_norm(h, sp["ln2"]), cfg.act)
            return h, (nconv, nst, nk, nv)

        h, (nconv, nst, nk, nv) = jax.lax.scan(
            group_body, h, (stacked, conv_g, ssm_g, cache["k"], cache["v"])
        )
        new_cache = {
            "conv": nconv.reshape(cache["conv"].shape),
            "ssm": nst.reshape(cache["ssm"].shape),
            "k": nk,
            "v": nv,
        }

    h = rms_norm(h, params["final_norm"])
    w_out = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cd)
    logits = jnp.einsum("bsd,dv->bsv", h, w_out)[:, 0]
    logits = soft_cap(logits.astype(jnp.float32), cfg.final_softcap)
    return _mask_pad_vocab(cfg, logits)[:, : cfg.vocab], new_cache


def prefill(
    cfg: ModelConfig,
    params: Dict[str, Any],
    inputs: Array,  # (B,S) or (B,S,D)
    s_max: int,
    mesh=None,
) -> Tuple[Array, Dict[str, Any]]:
    """Forward over the prompt, building the decode cache. Returns
    (last-position logits (B,V), cache filled to S)."""
    cd = cfg.compute_dtype
    B = inputs.shape[0]
    S = inputs.shape[1]
    cache = init_cache(cfg, B, s_max)
    if cfg.input_mode == "tokens":
        h = params["embed"].astype(cd)[inputs]
    else:
        h = inputs.astype(cd)
    if cfg.embed_scale:
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cd)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    zero = jnp.int32(0)

    if cfg.family == "attn":
        windows = _layer_windows(cfg, S)

        def body(h, xs):
            lp, window, ck, cv = xs
            lp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                              and p.ndim > 1 else p, lp)
            h2, _, new_kv = _attn_layer_fwd(
                cfg, mesh, lp, h, positions, window,
                kv_cache=(ck, cv), cache_index=zero,
            )
            return h2, new_kv

        if cfg.remat:
            body = jax.checkpoint(body)
        h, new_kv = jax.lax.scan(
            body, h, (params["layers"], windows, cache["k"], cache["v"])
        )
        cache = {"k": new_kv[0], "v": new_kv[1]}
    elif cfg.family == "ssm":
        def body(h, xs):
            lp, conv, st = xs
            lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                and p.ndim > 1 else p, lp)
            out, (nconv, nst) = ssm_mod.mamba2_block(
                lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm,
                state=(conv, st), return_state=True,
            )
            return h + out, (nconv, nst)

        if cfg.remat:
            body = jax.checkpoint(body)
        h, (nconv, nst) = jax.lax.scan(
            body, h, (params["layers"], cache["conv"], cache["ssm"])
        )
        cache = {"conv": nconv, "ssm": nst.astype(jnp.float32)}
    else:  # hybrid
        k = cfg.hybrid_every
        ngroups = cfg.n_layers // k
        stacked = jax.tree.map(
            lambda p: p.reshape((ngroups, k) + p.shape[1:]), params["layers"]
        )
        conv_g = cache["conv"].reshape((ngroups, k) + cache["conv"].shape[1:])
        ssm_g = cache["ssm"].reshape((ngroups, k) + cache["ssm"].shape[1:])
        sp = params["shared_block"]
        sp = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                          and p.ndim > 1 else p, sp)

        def group_body(h, xs):
            lp_group, conv_l, ssm_l, ck, cv = xs

            def inner(h, ys):
                lp, conv, st = ys
                lp_c = jax.tree.map(lambda p: p.astype(cd) if p.dtype == jnp.float32
                                    and p.ndim > 1 else p, lp)
                out, (nconv, nst) = ssm_mod.mamba2_block(
                    lp_c["mamba"], rms_norm(h, lp["ln"]), cfg.ssm,
                    state=(conv, st), return_state=True,
                )
                return h + out, (nconv, nst)

            h, (nconv, nst) = jax.lax.scan(inner, h, (lp_group, conv_l, ssm_l))
            att, (nk, nv) = attn_mod.attend(
                sp["attn"], rms_norm(h, sp["ln1"]), positions,
                rope_theta=cfg.rope_theta, query_scale=cfg.query_scale,
                kv_cache=(ck, cv), cache_index=zero,
            )
            h = h + att
            h = h + mlp_mod.mlp(sp["mlp"], rms_norm(h, sp["ln2"]), cfg.act)
            return h, (nconv, nst, nk, nv)

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        h, (nconv, nst, nk, nv) = jax.lax.scan(
            group_body, h, (stacked, conv_g, ssm_g, cache["k"], cache["v"])
        )
        cache = {
            "conv": nconv.reshape(cache["conv"].shape),
            "ssm": nst.reshape(cache["ssm"].shape).astype(jnp.float32),
            "k": nk,
            "v": nv,
        }

    h = rms_norm(h, params["final_norm"])
    w_out = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cd)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], w_out)
    logits = soft_cap(logits.astype(jnp.float32), cfg.final_softcap)
    return _mask_pad_vocab(cfg, logits)[:, : cfg.vocab], cache
