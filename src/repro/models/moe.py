"""Mixture-of-Experts layer with SpGEMM-formulated dispatch — the paper's
technique as a first-class feature of the LM stack (DESIGN.md §4).

The token→expert dispatch is literally a sparse matrix S (slots × tokens):
dispatch = S @ X and combine = Sᵀ_weighted @ Y are SpMM calls into
``repro.core.local_spgemm.spmm`` (the same gather/segment-accumulate the
distributed SpGEMM uses, with the Pallas kernel on TPU). The capacity-bucket
structure mirrors the paper's column batching: each expert's slot block is a
narrow output column block sized by a symbolic count (the router histogram).

Two expert-parallel modes:
  * "a2a"      — training/prefill: tokens are split over the "model" axis
                 (sequence dimension), routed locally, exchanged with one
                 all_to_all, expert-processed (experts sharded over "model"),
                 and exchanged back. The EP analogue of AllToAll-Fiber.
  * "dense_ep" — decode (S==1): every device routes all its dp-local tokens,
                 processes only its expert shard and psum-combines over
                 "model" — trading compute replication for latency, the right
                 call at decode batch sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..compat import shard_map as _shard_map
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.local_spgemm import spmm
from ..core.sparse import SparseCOO
from .common import MODEL_AX, dense_init

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    normalize_topk: bool = True
    dispatch_mode: str = "spgemm"  # "spgemm" | "scatter" (equivalent; tested)


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Dict[str, Array]:
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    E, F = cfg.n_experts, cfg.d_expert
    params = {
        "router": dense_init(k1, (d_model, E), dtype=jnp.float32),  # fp32 router
        "w_in": dense_init(k2, (E, d_model, F), in_axis=1, dtype=dtype),
        "w_gate": dense_init(k3, (E, d_model, F), in_axis=1, dtype=dtype),
        "w_out": dense_init(k4, (E, F, d_model), in_axis=1, dtype=dtype),
    }
    if cfg.n_shared:
        Fs = cfg.n_shared * F
        params["shared"] = {
            "w_in": dense_init(k5, (d_model, Fs), dtype=dtype),
            "w_gate": dense_init(k6, (d_model, Fs), dtype=dtype),
            "w_out": dense_init(k7, (Fs, d_model), dtype=dtype),
        }
    return params


def moe_specs(cfg: MoEConfig, tp: int = 1) -> Dict:
    e_ax = MODEL_AX if tp > 1 and cfg.n_experts % tp == 0 else None
    specs = {
        "router": P(None, None),
        "w_in": P(e_ax, None, None),
        "w_gate": P(e_ax, None, None),
        "w_out": P(e_ax, None, None),
    }
    if cfg.n_shared:
        fs_ax = MODEL_AX if tp > 1 and (cfg.n_shared * cfg.d_expert) % tp == 0 else None
        specs["shared"] = {
            "w_in": P(None, fs_ax),
            "w_gate": P(None, fs_ax),
            "w_out": P(fs_ax, None),
        }
    return specs


# ---------------------------------------------------------------------------
# local routing + dispatch (runs per device inside shard_map)
# ---------------------------------------------------------------------------
def _route(x_flat: Array, router_w: Array, cfg: MoEConfig):
    logits = x_flat.astype(jnp.float32) @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, cfg.top_k)  # (T, k)
    if cfg.normalize_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * Σ_e f_e * P_e
    E = router_w.shape[1]
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1), axis=0
    )  # fraction routed per expert (×k)
    aux = E * jnp.sum(f / cfg.top_k * jnp.mean(probs, axis=0))
    return top_p.astype(x_flat.dtype), top_e, aux


def _capacity(T: int, cfg: MoEConfig) -> int:
    c = int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return ((c + 7) // 8) * 8


def _dispatch_indices(top_e: Array, cfg: MoEConfig, cap: int):
    """slot position of each (token, k) assignment within its expert bucket."""
    T, k = top_e.shape
    eid = top_e.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(eid, cfg.n_experts, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot  # exclusive count per expert
    slot = jnp.take_along_axis(rank, eid[:, None], axis=1)[:, 0]  # (T*k,)
    keep = slot < cap
    return eid, slot, keep


def _dispatch(x_flat: Array, eid, slot, keep, cfg: MoEConfig, cap: int) -> Array:
    """Build (E, cap, D) expert input buffers. SpGEMM formulation: the
    dispatch matrix S is (E*cap × T) sparse with one 1 per kept assignment;
    buffers = S @ X via the core SpMM."""
    T = x_flat.shape[0]
    Tk = eid.shape[0]
    k = Tk // T
    token_of = jnp.repeat(jnp.arange(T), k)  # (T*k,) token index per assignment
    E, D = cfg.n_experts, x_flat.shape[1]
    if cfg.dispatch_mode == "spgemm":
        dest = eid * cap + slot  # row index in the (E*cap × T) dispatch matrix
        s = SparseCOO(
            rows=jnp.where(keep, dest, E * cap).astype(jnp.int32),
            cols=jnp.where(keep, token_of, T).astype(jnp.int32),
            vals=jnp.where(keep, 1.0, 0.0).astype(x_flat.dtype),
            nnz=jnp.int32(Tk),
            shape=(E * cap, T),
        )
        buf = spmm(s, x_flat)  # (E*cap, D)
        return buf.reshape(E, cap, D).astype(x_flat.dtype)
    # direct scatter (reference)
    buf = jnp.zeros((E, cap, D), x_flat.dtype)
    e_idx = jnp.where(keep, eid, E)
    s_idx = jnp.where(keep, slot, cap)
    return buf.at[e_idx, s_idx].add(x_flat[token_of], mode="drop")


def _combine(y_buf: Array, top_p, eid, slot, keep, T: int, cfg: MoEConfig,
             cap: int) -> Array:
    """Weighted gather back: X_out = Sᵀ_weighted @ Y (SpMM again)."""
    E, _, D = y_buf.shape
    Tk = eid.shape[0]
    k = Tk // T
    token_of = jnp.repeat(jnp.arange(T), k)
    w = top_p.reshape(-1)  # (T*k,)
    if cfg.dispatch_mode == "spgemm":
        s = SparseCOO(
            rows=jnp.where(keep, token_of, T).astype(jnp.int32),
            cols=jnp.where(keep, eid * cap + slot, E * cap).astype(jnp.int32),
            vals=jnp.where(keep, w, 0.0).astype(y_buf.dtype),
            nnz=jnp.int32(Tk),
            shape=(T, E * cap),
        )
        return spmm(s, y_buf.reshape(E * cap, D))
    src = y_buf[jnp.where(keep, eid, 0), jnp.where(keep, slot, 0)]  # (T*k, D)
    src = jnp.where(keep[:, None], src * w[:, None], 0)
    return jax.ops.segment_sum(src, token_of, num_segments=T)


def _expert_ffn(buf: Array, w_in: Array, w_gate: Array, w_out: Array) -> Array:
    """buf: (E_loc, C', D); expert weights (E_loc, D, F) / (E_loc, F, D)."""
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _shared_ffn(params: Dict[str, Array], x: Array, sharded: bool = False) -> Array:
    """Shared-expert FFN. When ``sharded``, weights arrive as model-axis
    shards of the F dimension (w_in (D, F/tp), w_out (F/tp, D)) and the
    output is psum'd — avoids all-gathering the shared weights every layer."""
    h = x @ params["w_in"]
    g = x @ params["w_gate"]
    out = (jax.nn.silu(g) * h) @ params["w_out"]
    if sharded:
        out = lax.psum(out, MODEL_AX)
    return out


# ---------------------------------------------------------------------------
# expert-parallel layer
# ---------------------------------------------------------------------------
def moe_layer(
    params: Dict[str, Array],
    x: Array,  # (B, S, D) — global
    cfg: MoEConfig,
    mesh,
    mode: str = "a2a",
) -> Tuple[Array, Array]:
    """Returns (output (B,S,D), aux loss scalar)."""
    from .common import batch_axes

    dp = batch_axes(mesh)
    tp = mesh.shape[MODEL_AX]
    B, S, D = x.shape

    if mode == "a2a" and S % tp == 0:
        x_spec = P(dp, MODEL_AX, None)

        def local(x_loc, router_w, w_in, w_gate, w_out, shared):
            b_l, s_l, _ = x_loc.shape
            T = b_l * s_l
            xf = x_loc.reshape(T, D)
            top_p, top_e, aux = _route(xf, router_w, cfg)
            cap = _capacity(T, cfg)
            eid, slot, keep = _dispatch_indices(top_e, cfg, cap)
            buf = _dispatch(xf, eid, slot, keep, cfg, cap)  # (E, cap, D)
            E_loc = cfg.n_experts // tp
            buf = buf.reshape(tp, E_loc, cap, D)
            buf = lax.all_to_all(buf, MODEL_AX, split_axis=0, concat_axis=0)
            buf = buf.reshape(tp, E_loc, cap, D).transpose(1, 0, 2, 3).reshape(
                E_loc, tp * cap, D
            )
            y = _expert_ffn(buf, w_in, w_gate, w_out)  # (E_loc, tp*cap, D)
            y = y.reshape(E_loc, tp, cap, D).transpose(1, 0, 2, 3)
            y = lax.all_to_all(y, MODEL_AX, split_axis=0, concat_axis=0)
            y = y.reshape(cfg.n_experts, cap, D)
            out = _combine(y, top_p, eid, slot, keep, T, cfg, cap)
            if shared is not None:
                out = out + _shared_ffn(shared, xf, sharded=shared_is_sharded)
            aux = lax.pmean(aux, MODEL_AX)
            for ax in dp:
                aux = lax.pmean(aux, ax)
            return out.reshape(b_l, s_l, D), aux

        shared = params.get("shared")
        fs = cfg.n_shared * cfg.d_expert
        shared_is_sharded = shared is not None and fs % tp == 0
        fs_ax = MODEL_AX if shared_is_sharded else None
        shared_spec = (
            {"w_in": P(None, fs_ax), "w_gate": P(None, fs_ax),
             "w_out": P(fs_ax, None)}
            if shared is not None
            else None
        )
        out, aux = _shard_map(
            local,
            mesh=mesh,
            in_specs=(
                x_spec,
                P(None, None),
                P(MODEL_AX, None, None),
                P(MODEL_AX, None, None),
                P(MODEL_AX, None, None),
                shared_spec,
            ),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(x, params["router"], params["w_in"], params["w_gate"], params["w_out"],
          shared)
        return out, aux

    # dense_ep (decode / S not divisible): route everywhere, compute local
    # expert shard over all dp-local tokens, psum over "model"
    x_spec = P(dp, None, None)

    def local_dense(x_loc, router_w, w_in, w_gate, w_out, shared):
        b_l, s_l, _ = x_loc.shape
        T = b_l * s_l
        xf = x_loc.reshape(T, D)
        top_p, top_e, aux = _route(xf, router_w, cfg)
        cap = _capacity(T, cfg)
        eid, slot, keep = _dispatch_indices(top_e, cfg, cap)
        buf = _dispatch(xf, eid, slot, keep, cfg, cap)  # (E, cap, D)
        E_loc = cfg.n_experts // tp
        r = lax.axis_index(MODEL_AX)
        buf_loc = lax.dynamic_slice_in_dim(buf, r * E_loc, E_loc, axis=0)
        y_loc = _expert_ffn(buf_loc, w_in, w_gate, w_out)
        y = jnp.zeros((cfg.n_experts, cap, D), y_loc.dtype)
        y = lax.dynamic_update_slice_in_dim(y, y_loc, r * E_loc, axis=0)
        y = lax.psum(y, MODEL_AX)
        out = _combine(y, top_p, eid, slot, keep, T, cfg, cap)
        if shared is not None:
            out = out + _shared_ffn(shared, xf, sharded=shared_is_sharded)
        aux = lax.pmean(aux, MODEL_AX)
        for ax in dp:
            aux = lax.pmean(aux, ax)
        return out.reshape(b_l, s_l, D), aux

    shared = params.get("shared")
    fs = cfg.n_shared * cfg.d_expert
    shared_is_sharded = shared is not None and fs % tp == 0
    fs_ax = MODEL_AX if shared_is_sharded else None
    shared_spec = (
        {"w_in": P(None, fs_ax), "w_gate": P(None, fs_ax), "w_out": P(fs_ax, None)}
        if shared is not None
        else None
    )
    out, aux = _shard_map(
        local_dense,
        mesh=mesh,
        in_specs=(
            x_spec,
            P(None, None),
            P(MODEL_AX, None, None),
            P(MODEL_AX, None, None),
            P(MODEL_AX, None, None),
            shared_spec,
        ),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_in"], params["w_gate"], params["w_out"], shared)
    return out, aux
