"""Shared model-building blocks: norms, RoPE, init, sharding-spec helpers.

Everything is raw-JAX (params are nested dicts of arrays) — no framework
dependency. Sharding is expressed as a parallel pytree of PartitionSpec
produced by each module's ``*_specs`` function; ``repro.train.step`` turns
those into NamedShardings for pjit.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jnp.ndarray
Params = Dict[str, Any]

# Mesh axis conventions (see launch/mesh.py):
#   "pod"  — slow inter-pod links; data parallel
#   "data" — intra-pod data parallel
#   "model"— tensor/expert parallel
MODEL_AX = "model"


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def soft_cap(x: Array, cap: Optional[float]) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10_000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> Array:
    """Scaled truncated-normal (fan-in)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, shape, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def tree_specs_like(params: Params, spec_fn) -> Params:
    """Map leaf -> PartitionSpec via spec_fn(path, leaf)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_fn(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_shard_spec(spec: P, shape: Tuple[int, ...], dp_axes: Tuple[str, ...],
                     dp_size: int) -> P:
    """ZeRO-1: additionally shard an optimizer-state tensor over the data
    axes on its first axis that is divisible and not already sharded."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and dim % dp_size == 0 and dim > 0:
            entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(*entries)
    return spec  # nothing divisible — keep as-is
