"""Mamba2 — state-space duality (SSD) blocks [arXiv:2405.21060].

Implements the chunked SSD algorithm (intra-chunk quadratic + inter-chunk
state recurrence) for training/prefill, and the O(1)-state recurrent step for
decode — this is what makes the ``long_500k`` shape runnable (no KV cache;
state is (B, H, P, N) regardless of context length).

Tensor parallelism: heads (d_inner) are sharded over "model"; B/C projections
are grouped (n_groups=1) and replicated — the TP analogue used by Mamba2's
own Megatron integration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init, rms_norm

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64  # P
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


def init_mamba2(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict[str, Array]:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    conv_dim = di + 2 * gn
    ks = jax.random.split(key, 8)
    return {
        "w_in_z": dense_init(ks[0], (d_model, di), dtype=dtype),
        "w_in_x": dense_init(ks[1], (d_model, di), dtype=dtype),
        "w_bc": dense_init(ks[2], (d_model, 2 * gn), dtype=dtype),
        "w_dt": dense_init(ks[3], (d_model, nh), dtype=dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(A_log)
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[4], (cfg.d_conv, conv_dim), in_axis=0, dtype=dtype),
        "norm": jnp.zeros((di,), jnp.float32),
        "w_out": dense_init(ks[5], (di, d_model), dtype=dtype),
    }


def mamba2_specs(cfg: SSMConfig, d_model: int = 0, tp: int = 1) -> Dict[str, P]:
    di = cfg.d_inner(d_model) if d_model else 0
    nh = cfg.n_heads(d_model) if d_model else 0
    di_ax = "model" if tp > 1 and di % tp == 0 and di > 0 else None
    h_ax = "model" if tp > 1 and nh % tp == 0 and nh > 0 else None
    return {
        "w_in_z": P(None, di_ax),
        "w_in_x": P(None, di_ax),
        "w_bc": P(None, None),  # grouped B/C replicated (n_groups=1)
        "w_dt": P(None, h_ax),
        "dt_bias": P(h_ax),
        "A_log": P(h_ax),
        "D_skip": P(h_ax),
        "conv_w": P(None, None),  # mixed x|B|C dims — keep replicated
        "norm": P(di_ax),
        "w_out": P(di_ax, None),
    }


def _segsum(x: Array) -> Array:
    """(..., T) -> (..., T, T) cumulative segment sums; upper triangle -inf."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, S, H, P) — already dt-scaled inputs
    a_dt: Array,  # (B, S, H) — dt * A (negative)
    b: Array,  # (B, S, G, N)
    c: Array,  # (B, S, G, N)
    chunk: int,
    h0: Optional[Array] = None,  # (B, H, P, N)
) -> Tuple[Array, Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final state (B,H,P,N)).

    Streams chunk-by-chunk through the inter-chunk recurrence: the quadratic
    intra-chunk decay matrix L (chunk × chunk) only ever exists for ONE chunk
    — peak temp memory is O(B·H·chunk²) instead of O(B·H·S·chunk), which is
    what keeps the train_4k activations inside the v5e HBM budget (the
    all-chunks-at-once einsum form needs ~50 GB/device at B_loc=16, S=4k).
    """
    B, S, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    assert G == 1, "n_groups=1 supported (mamba2 default); see DESIGN.md"
    assert S % chunk == 0
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, Pd).transpose(1, 0, 2, 3, 4)  # (nc,B,l,H,P)
    bc_ = b.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)  # (nc,B,l,N)
    cc_ = c.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    ac_ = a_dt.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)  # (nc,B,H,l)

    init = (
        h0.astype(jnp.float32) if h0 is not None
        else jnp.zeros((B, H, Pd, N), jnp.float32)
    )

    def body(h, inp):
        xk, bk, ck, ak = inp  # (B,l,H,P) (B,l,N) (B,l,N) (B,H,l)
        a_cum = jnp.cumsum(ak, axis=-1)  # (B,H,l)
        L = jnp.exp(_segsum(ak))  # (B,H,l,l) — one chunk only
        y_diag = jnp.einsum("bln,bsn,bhls,bshp->blhp", ck, bk, L, xk)
        # contribution of this chunk's inputs to the carried state
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,l)
        contrib = jnp.einsum("bln,bhl,blhp->bhpn", bk, decay_states, xk)
        # contribution of the carried state to this chunk's outputs
        state_decay = jnp.exp(a_cum)  # (B,H,l)
        y_off = jnp.einsum("bln,bhpn,bhl->blhp", ck, h, state_decay)
        h_new = h * jnp.exp(a_cum[..., -1])[..., None, None] + contrib.astype(
            jnp.float32
        )
        return h_new, (y_diag + y_off).astype(x.dtype)

    final, ys = jax.lax.scan(body, init, (xc, bc_, cc_, ac_))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Pd)
    return y, final


def _split_proj(params, x):
    """x: (B,S,D) -> z, xbc_conv_input, dt (pre-activation)."""
    z = jnp.einsum("bsd,de->bse", x, params["w_in_z"])
    xi = jnp.einsum("bsd,de->bse", x, params["w_in_x"])
    bc = jnp.einsum("bsd,de->bse", x, params["w_bc"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])
    return z, jnp.concatenate([xi, bc], axis=-1), dt


def mamba2_block(
    params: Dict[str, Array],
    x: Array,  # (B, S, D)
    cfg: SSMConfig,
    state: Optional[Tuple[Array, Array]] = None,  # (conv_state, ssm_state)
    return_state: bool = False,
):
    """Prefill/training forward. state/return_state used by serving."""
    B, S, D = x.shape
    di = cfg.d_inner(D)
    nh = cfg.n_heads(D)
    gn = cfg.n_groups * cfg.d_state

    z, xbc, dt = _split_proj(params, x)
    # causal depthwise conv (kernel d_conv) over sequence
    conv_in = xbc
    if state is not None:
        conv_in = jnp.concatenate([state[0].astype(xbc.dtype), xbc], axis=1)
        pad = 0
    else:
        pad = cfg.d_conv - 1
    conv_in = jnp.pad(conv_in, ((0, 0), (pad, 0), (0, 0)))
    windows = jnp.stack(
        [conv_in[:, i : i + S, :] for i in range(cfg.d_conv)], axis=-1
    )  # (B,S,conv_dim,d_conv)
    xbc = jax.nn.silu(jnp.einsum("bsck,kc->bsc", windows, params["conv_w"]))
    new_conv_state = conv_in[:, -(cfg.d_conv - 1) :, :] if return_state else None

    xi = xbc[..., :di].reshape(B, S, nh, cfg.head_dim)
    bmat = xbc[..., di : di + gn].reshape(B, S, cfg.n_groups, cfg.d_state)
    cmat = xbc[..., di + gn :].reshape(B, S, cfg.n_groups, cfg.d_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)
    a_dt = dt * A  # (B,S,H)
    x_scaled = (xi.astype(jnp.float32) * dt[..., None]).astype(xi.dtype)

    # pad S up to a chunk multiple; padding carries decay=1 (a_dt=0) and
    # zero inputs so outputs/state are exact
    chunk = min(cfg.chunk, S)
    s_pad = (S + chunk - 1) // chunk * chunk
    if s_pad != S:
        pad = s_pad - S
        x_scaled = jnp.pad(x_scaled, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    h0 = state[1] if state is not None else None
    y, h_final = ssd_chunked(x_scaled, a_dt, bmat, cmat, chunk, h0=h0)
    y = y[:, :S]
    y = y + xi * params["D_skip"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"]).astype(x.dtype)
    if return_state:
        return out, (new_conv_state, h_final)
    return out


def mamba2_decode_step(
    params: Dict[str, Array],
    x: Array,  # (B, 1, D)
    cfg: SSMConfig,
    state: Tuple[Array, Array],  # conv_state (B, d_conv-1, conv_dim), ssm (B,H,P,N)
):
    """Single-token recurrent step: h' = h·exp(dtA) + dt·x ⊗ B ; y = C·h."""
    B, _, D = x.shape
    di = cfg.d_inner(D)
    nh = cfg.n_heads(D)
    gn = cfg.n_groups * cfg.d_state
    conv_state, h = state

    z, xbc, dt = _split_proj(params, x)  # (B,1,*)
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)  # (B,d_conv,cd)
    xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, params["conv_w"]))
    new_conv = window[:, 1:, :]

    xi = xbc_t[:, :di].reshape(B, nh, cfg.head_dim)
    bvec = xbc_t[:, di : di + gn].reshape(B, cfg.n_groups, cfg.d_state)
    cvec = xbc_t[:, di + gn :].reshape(B, cfg.n_groups, cfg.d_state)
    rep = nh // cfg.n_groups
    bvec = jnp.repeat(bvec, rep, axis=1)  # (B,H,N)
    cvec = jnp.repeat(cvec, rep, axis=1)

    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt_t * A)  # (B,H)
    x_dt = xi.astype(jnp.float32) * dt_t[..., None]  # (B,H,P)
    h = h * decay[..., None, None] + jnp.einsum("bhp,bhn->bhpn", x_dt, bvec.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, cvec.astype(jnp.float32))
    y = y + xi.astype(jnp.float32) * params["D_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"]).astype(x.dtype)
    return out, (new_conv, h)


def init_mamba2_state(cfg: SSMConfig, d_model: int, batch: int, dtype=jnp.float32):
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    conv_dim = di + 2 * gn
    return (
        jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
    )
