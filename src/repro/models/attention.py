"""Grouped-query attention with RoPE, optional sliding window and soft-cap.

Covers the assigned archs' attention variants:
  * GQA with arbitrary kv_heads (MQA kv=1 for granite-20b, MHA kv=32 for
    musicgen/zamba2)
  * gemma2-9b: alternating local (sliding-window) / global layers + attn
    logit soft-capping
  * prefill (causal over S) and single-token decode against a KV cache

Tensor parallelism: q/k/v/o projections shard heads over the "model" axis
via the specs in ``attention_specs`` — activations stay replicated over
"model" inside the block (Megatron-style), with XLA inserting the two
all-reduces per block.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import apply_rope, dense_init, soft_cap

Array = jnp.ndarray


def init_attention(key, d_model: int, n_heads: int, kv_heads: int, head_dim: int,
                   dtype=jnp.float32, pad_heads_to: int = 0) -> Dict[str, Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": dense_init(k1, (d_model, n_heads, head_dim), dtype=dtype),
        "wk": dense_init(k2, (d_model, kv_heads, head_dim), dtype=dtype),
        "wv": dense_init(k3, (d_model, kv_heads, head_dim), dtype=dtype),
        "wo": dense_init(k4, (n_heads, head_dim, d_model), in_axis=0, dtype=dtype),
    }
    if pad_heads_to and pad_heads_to > n_heads:
        # Mathematically-exact head padding (EXPERIMENTS.md §Perf): each GQA
        # group is padded equally with zero heads (zero wq rows -> the pad
        # heads compute garbage attention; zero wo rows -> it never reaches
        # the output), so a 36-head model becomes a shardable 48-head model
        # with identical function. Real head (g, j) lands at g*per_new + j,
        # preserving the query->kv-group mapping under _repeat_kv.
        assert pad_heads_to % kv_heads == 0, (pad_heads_to, kv_heads)
        per_old = n_heads // kv_heads
        per_new = pad_heads_to // kv_heads
        wq = jnp.zeros((d_model, pad_heads_to, head_dim), dtype)
        wo = jnp.zeros((pad_heads_to, head_dim, d_model), dtype)
        for g in range(kv_heads):
            wq = wq.at[:, g * per_new : g * per_new + per_old].set(
                params["wq"][:, g * per_old : (g + 1) * per_old]
            )
            wo = wo.at[g * per_new : g * per_new + per_old].set(
                params["wo"][g * per_old : (g + 1) * per_old]
            )
        params["wq"], params["wo"] = wq, wo
    return params


def attention_specs(n_heads: int = 0, kv_heads: int = 0, tp: int = 1) -> Dict[str, P]:
    """TP specs with divisibility fallbacks: a head dim that doesn't divide
    the model axis is replicated (e.g. MQA kv=1, starcoder2's 36 heads at
    tp=16 — see EXPERIMENTS.md §Perf for the padded-heads optimization)."""
    q_ax = "model" if tp > 1 and n_heads % tp == 0 else None
    kv_ax = "model" if tp > 1 and kv_heads % tp == 0 else None
    return {
        "wq": P(None, q_ax, None),
        "wk": P(None, kv_ax, None),
        "wv": P(None, kv_ax, None),
        "wo": P(q_ax, None, None),
    }


def _repeat_kv(x: Array, n_rep: int) -> Array:
    """(B, S, kvH, hd) -> (B, S, kvH*n_rep, hd)"""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _causal_mask(s_q: int, s_k: int, q_offset, window) -> Array:
    """``window`` may be None, a python int, or a traced scalar (per-layer
    alternation à la gemma2 passes it through lax.scan)."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_k)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask  # (s_q, s_k)


def attend(
    params: Dict[str, Array],
    x: Array,  # (B, S, D)
    positions: Array,  # (B, S)
    *,
    rope_theta: float = 10_000.0,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    kv_cache: Optional[Tuple[Array, Array]] = None,  # (B, S_max, kvH, hd) x2
    cache_index: Optional[Array] = None,  # scalar: current fill level
    query_scale: Optional[float] = None,
) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Returns (output (B,S,D), updated kv cache or None).

    Prefill: kv_cache=None and S>=1 — causal over the block.
    Decode:  kv_cache given, S==1 — attends over cache[:cache_index+1].
    """
    B, S, D = x.shape
    n_heads = params["wq"].shape[1]
    kv_heads = params["wk"].shape[1]
    hd = params["wq"].shape[2]
    n_rep = n_heads // kv_heads
    scale = query_scale if query_scale is not None else hd ** -0.5

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if kv_cache is None:
        kf = _repeat_kv(k, n_rep)
        vf = _repeat_kv(v, n_rep)
        logits = jnp.einsum("bqhk,bshk->bhqs", q, kf) * scale
        logits = soft_cap(logits, attn_softcap)
        mask = _causal_mask(S, S, jnp.int32(0), window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, vf)
        new_cache = None
    else:
        ck, cv = kv_cache  # (B, S_max, kvH, hd)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_index, axis=1)
        s_max = ck.shape[1]
        kf = _repeat_kv(ck, n_rep)
        vf = _repeat_kv(cv, n_rep)
        logits = jnp.einsum("bqhk,bshk->bhqs", q, kf.astype(q.dtype)) * scale
        logits = soft_cap(logits, attn_softcap)
        kpos = jnp.arange(s_max)[None, :]
        qpos = cache_index + jnp.arange(S)[:, None]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, vf.astype(probs.dtype))
        new_cache = (ck, cv)

    y = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
    return y, new_cache
