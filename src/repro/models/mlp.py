"""Feed-forward blocks: SwiGLU (llama-family) and GELU (starcoder-family)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init

Array = jnp.ndarray


GATED = ("swiglu", "geglu")


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> Dict[str, Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_in": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(k2, (d_ff, d_model), dtype=dtype),
    }
    if act in GATED:
        params["w_gate"] = dense_init(k3, (d_model, d_ff), dtype=dtype)
    return params


def mlp_specs(act: str, d_ff: int = 0, tp: int = 1) -> Dict[str, P]:
    ax = "model" if tp > 1 and d_ff % tp == 0 else None
    specs = {"w_in": P(None, ax), "w_out": P(ax, None)}
    if act in GATED:
        specs["w_gate"] = P(None, ax)
    return specs


def mlp(params: Dict[str, Array], x: Array, act: str) -> Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    elif act == "geglu":  # gemma2 gated-GELU
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.gelu(g, approximate=True) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "gelu_tanh":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(act)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])
