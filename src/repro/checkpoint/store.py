"""Sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.msgpack   — step, tree structure, shapes, dtypes, hashes
           arrays.npz         — one entry per leaf (host-gathered)

Design points for 1000+-node deployments (scaled-down here, same contract):
  * each leaf records a content hash — restore verifies integrity and
    refuses silently-truncated files (a real failure mode at scale);
  * restore is **elastic**: arrays are re-device_put with the *target* mesh's
    shardings, so a 512-chip checkpoint restores onto 256 chips (or a
    different DP/TP split) without conversion tooling;
  * writes go to a temp dir + atomic rename, so a node failure mid-write
    never corrupts the latest-complete checkpoint;
  * `async_save` runs the host-gather + write on a worker thread, overlapping
    the next training steps (checkpoint stalls are a top straggler source).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

try:
    import msgpack
except ImportError:  # pragma: no cover
    msgpack = None


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): leaf for p, leaf in flat}, treedef


def save(path: str, step: int, state: Dict[str, Any]) -> str:
    """Synchronous checkpoint write. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "leaves": {
            k: {
                "shape": list(a.shape),
                "dtype": str(a.dtype),
                "hash": hashlib.sha256(a.tobytes()).hexdigest()[:16],
            }
            for k, a in arrays.items()
        },
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **{
        k.replace("/", "\x00"): a for k, a in arrays.items()
    })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    path: str, step: int, like: Dict[str, Any], shardings=None
) -> Dict[str, Any]:
    """Restore into the structure of `like`, resharding onto `shardings`
    (elastic: the saved mesh layout is irrelevant — only shapes must match)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k.replace("\x00", "/"): z[k] for k in z.files}
    for k, meta in manifest["leaves"].items():
        a = arrays[k]
        h = hashlib.sha256(a.tobytes()).hexdigest()[:16]
        if h != meta["hash"]:
            raise IOError(f"checkpoint corruption: {k} hash mismatch")
    flat_like, treedef = _flatten(like)
    if set(flat_like) != set(arrays):
        missing = set(flat_like) ^ set(arrays)
        raise KeyError(f"checkpoint tree mismatch: {sorted(missing)[:5]} ...")
    sh_flat = None
    if shardings is not None:
        sh_flat, _ = _flatten(shardings)
    out = {}
    for k, template in flat_like.items():
        a = arrays[k]
        assert tuple(a.shape) == tuple(template.shape), (k, a.shape, template.shape)
        if sh_flat is not None and k in sh_flat:
            out[k] = jax.device_put(a, sh_flat[k])
        else:
            out[k] = jax.device_put(a)
    leaves = [out[jax.tree_util.keystr(p)] for p, _ in
              jax.tree_util.tree_flatten_with_path(like)[0]]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Threaded save: snapshot to host, write off-thread, never block > one
    outstanding checkpoint (back-pressure instead of unbounded queue)."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, state) -> None:
        self.wait()  # back-pressure: at most one in flight
        host_state = jax.tree.map(np.asarray, state)  # snapshot now

        def work():
            save(self.path, step, host_state)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"))
