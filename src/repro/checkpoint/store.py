"""Sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json      — step, user meta, tree structure, shapes,
                                dtypes, content hashes
           arrays.npz         — one entry per leaf (host-gathered)

Design points for 1000+-node deployments (scaled-down here, same contract):
  * each leaf records a content hash — restore verifies integrity and
    refuses silently-truncated files (a real failure mode at scale);
  * restore is **elastic**: arrays are re-device_put with the *target* mesh's
    shardings, so a 512-chip checkpoint restores onto 256 chips (or a
    different DP/TP split) without conversion tooling;
  * writes go to a temp dir + atomic rename, so a node failure mid-write
    never corrupts the latest-complete checkpoint; stale ``step_*.tmp``
    leftovers from a mid-write kill are swept on the next `latest_step`;
  * a free-form ``meta`` dict rides in the manifest — the SpGEMM loops use
    it to snapshot the **plan signature** (pow2/floor caps, pinned k-bin
    signature, hash caps, local path, batch-count floor) next to the iterate
    so a restored run rebuilds the identical fused-step executable with zero
    extra retraces (see `runtime/resilient.py`);
  * `AsyncCheckpointer` runs the host-gather + write on a worker thread,
    overlapping the next multiply (checkpoint stalls are a top straggler
    source); it records stall time and bytes written for the `RunReport`.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import msgpack
except ImportError:  # pragma: no cover
    msgpack = None

_STEP_RE = re.compile(r"^step_(\d+)$")


def _step_of(name: str) -> Optional[int]:
    """Step number of a checkpoint dir entry, or None for foreign entries.

    Defensive by design: a checkpoint dir accumulates junk over long runs
    (``step_00000003.bak`` from operators, editor droppings, ``.tmp`` from a
    mid-write kill) and a naive ``int(d.split("_")[1])`` turns any of it
    into a crash at restore time.
    """
    m = _STEP_RE.match(name)
    return int(m.group(1)) if m else None


def sweep_stale_tmp(path: str) -> int:
    """Remove ``step_*.tmp`` leftovers from a mid-write kill.

    Safe against a concurrent in-flight writer only in the sense the store
    already requires: one writer per directory (the AsyncCheckpointer
    enforces a single outstanding save). Returns the number swept.
    """
    if not os.path.isdir(path):
        return 0
    swept = 0
    for d in os.listdir(path):
        if d.endswith(".tmp") and _step_of(d[: -len(".tmp")]) is not None:
            try:
                shutil.rmtree(os.path.join(path, d))
                swept += 1
            except FileNotFoundError:
                pass  # vanished between list and rmtree — already gone
    return swept


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): leaf for p, leaf in flat}, treedef


def save(
    path: str, step: int, state: Dict[str, Any],
    meta: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous checkpoint write. Returns the final directory.

    ``meta`` is any JSON-serializable dict, stored in the manifest and read
    back via `load_meta` — the plan-signature side channel for the SpGEMM
    loops (it never touches the array payload, so the content-hash contract
    is unchanged).
    """
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "meta": meta or {},
        "leaves": {
            k: {
                "shape": list(a.shape),
                "dtype": str(a.dtype),
                "hash": hashlib.sha256(a.tobytes()).hexdigest()[:16],
            }
            for k, a in arrays.items()
        },
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **{
        k.replace("/", "\x00"): a for k, a in arrays.items()
    })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def dir_nbytes(d: str) -> int:
    """Total bytes of one checkpoint directory (manifest + arrays)."""
    try:
        return sum(
            os.path.getsize(os.path.join(d, f)) for f in os.listdir(d)
        )
    except OSError:
        return 0


def steps_available(path: str) -> List[int]:
    """Sorted complete checkpoint steps (foreign entries and .tmp ignored)."""
    if not os.path.isdir(path):
        return []
    steps = [s for d in os.listdir(path) if (s := _step_of(d)) is not None]
    return sorted(steps)


def latest_step(path: str) -> Optional[int]:
    sweep_stale_tmp(path)
    steps = steps_available(path)
    return steps[-1] if steps else None


def _read_verified(d: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Load one checkpoint dir, verifying every leaf hash.

    Any corruption — unreadable/truncated npz, missing leaves, or a content
    hash mismatch — surfaces as IOError so callers have one refusal channel.
    """
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            arrays = {k.replace("\x00", "/"): z[k] for k in z.files}
    except IOError:
        raise
    except Exception as e:  # truncated zip, bad JSON, missing member ...
        raise IOError(f"checkpoint unreadable: {d}: {e}") from e
    for k, meta in manifest["leaves"].items():
        if k not in arrays:
            raise IOError(f"checkpoint corruption: {k} missing from arrays")
        a = arrays[k]
        h = hashlib.sha256(a.tobytes()).hexdigest()[:16]
        if h != meta["hash"]:
            raise IOError(f"checkpoint corruption: {k} hash mismatch")
    return arrays, manifest


def load_meta(path: str, step: int) -> Dict[str, Any]:
    """The ``meta`` dict stored with `save` (plan signature et al.)."""
    d = os.path.join(path, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f).get("meta", {})
    except IOError:
        raise
    except Exception as e:
        raise IOError(f"checkpoint manifest unreadable: {d}: {e}") from e


def restore_arrays(path: str, step: int) -> Dict[str, np.ndarray]:
    """Hash-verified flat leaf dict, no template tree needed.

    The template-free twin of `restore`: callers that rebuild typed state
    themselves (the resilient SpGEMM loops) get the raw host arrays keyed by
    `jax.tree_util.keystr` paths and decide placement/sharding on their own.
    """
    d = os.path.join(path, f"step_{step:08d}")
    arrays, _ = _read_verified(d)
    return arrays


def restore(
    path: str, step: int, like: Dict[str, Any], shardings=None
) -> Dict[str, Any]:
    """Restore into the structure of `like`, resharding onto `shardings`
    (elastic: the saved mesh layout is irrelevant — only shapes must match)."""
    d = os.path.join(path, f"step_{step:08d}")
    arrays, _ = _read_verified(d)
    flat_like, treedef = _flatten(like)
    if set(flat_like) != set(arrays):
        missing = set(flat_like) ^ set(arrays)
        raise KeyError(f"checkpoint tree mismatch: {sorted(missing)[:5]} ...")
    sh_flat = None
    if shardings is not None:
        sh_flat, _ = _flatten(shardings)
    out = {}
    for k, template in flat_like.items():
        a = arrays[k]
        assert tuple(a.shape) == tuple(template.shape), (k, a.shape, template.shape)
        if sh_flat is not None and k in sh_flat:
            out[k] = jax.device_put(a, sh_flat[k])
        else:
            out[k] = jax.device_put(a)
    leaves = [out[jax.tree_util.keystr(p)] for p, _ in
              jax.tree_util.tree_flatten_with_path(like)[0]]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Threaded save: snapshot to host, write off-thread, never block > one
    outstanding checkpoint (back-pressure instead of unbounded queue).

    Accounting for the durability `RunReport`: `stalls`/`stall_s` measure
    time spent blocked on a previous in-flight write (a save issued while
    the prior one is still writing), `bytes_written` totals finished
    checkpoint sizes. A failed background write surfaces on the next
    `save`/`wait` instead of dying silently on the worker thread.
    """

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_saved: Optional[int] = None
        self.stalls = 0
        self.stall_s = 0.0
        self.bytes_written = 0
        sweep_stale_tmp(path)

    def save(self, step: int, state, meta: Optional[Dict[str, Any]] = None):
        # back-pressure: at most one in flight
        if self._thread is not None and self._thread.is_alive():
            self.stalls += 1
        t0 = time.perf_counter()
        self.wait()
        self.stall_s += time.perf_counter() - t0
        host_state = jax.tree.map(np.asarray, state)  # snapshot now

        def work():
            try:
                final = save(self.path, step, host_state, meta=meta)
                self.bytes_written += dir_nbytes(final)
                self.last_saved = step
                self._gc()
            except BaseException as e:  # surface on next save/wait
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, state, meta: Optional[Dict[str, Any]] = None):
        """Blocking save through the same accounting/GC as the async path."""
        self.wait()
        final = save(self.path, step, state, meta=meta)
        self.bytes_written += dir_nbytes(final)
        self.last_saved = step
        self._gc()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        try:
            entries = os.listdir(self.path)
        except FileNotFoundError:
            return  # whole dir vanished (external cleanup) — nothing to gc
        steps = sorted(s for d in entries if (s := _step_of(d)) is not None)
        for s in steps[: -self.keep] if self.keep > 0 else steps:
            try:
                shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"))
            except FileNotFoundError:
                pass  # vanished between list and rmtree — already gone
