"""Assigned input shapes (one set, shared by all LM archs).

  train_4k     seq 4,096  × global batch 256   -> train_step
  prefill_32k  seq 32,768 × global batch 32    -> prefill (serve)
  decode_32k   KV 32,768  × global batch 128   -> decode_step (serve)
  long_500k    KV 524,288 × global batch 1     -> decode_step (serve);
               requires sub-quadratic state — SSM/hybrid only (DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(arch_family: str, supports_long: bool, shape: str) -> bool:
    """Skip rules: long_500k only for sub-quadratic decode state."""
    if shape == "long_500k":
        return supports_long
    return True
