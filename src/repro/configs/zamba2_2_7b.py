"""zamba2-2.7b [hybrid] — 54L mamba2 backbone d_model=2560 + one weight-
shared attention block (32H MHA + d_ff=10240 MLP) applied every 6 layers,
vocab=32000, ssm_state=64 [arXiv:2411.15242; hf].

Hybrid decode state: per-layer SSM states + per-application KV cache for the
shared block — sub-quadratic, so long_500k runs.
"""
from ..models.ssm import SSMConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    act="gelu",
    family="hybrid",
    hybrid_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=128),
    supports_long_context=True,
)

SMOKE = ModelConfig(
    arch_id="zamba2-2.7b-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="gelu",
    family="hybrid",
    hybrid_every=2,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=8),
    supports_long_context=True,
    dtype="float32",
)
