"""pixtral-12b [vlm] — Pixtral-ViT frontend (STUB) + Mistral-Nemo-style
backbone. 40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336
vocab=131072. [hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings (input_mode="embeds"); the backbone (the part
that matters for distribution/roofline) is exact.
"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    act="swiglu",
    rope_theta=1e6,
    family="attn",
    input_mode="embeds",
)

SMOKE = ModelConfig(
    arch_id="pixtral-12b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="swiglu",
    family="attn",
    input_mode="embeds",
    dtype="float32",
)
