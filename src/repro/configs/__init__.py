"""Architecture configs (--arch <id>) + input shapes + SpGEMM workloads."""
from .registry import ARCHS, SHAPES, WORKLOADS, applicable, get_config, input_specs  # noqa: F401
