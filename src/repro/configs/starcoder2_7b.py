"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4, head_dim=128)
d_ff=18432 vocab=49152, RoPE [arXiv:2402.19173; hf]."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-7b",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    act="gelu",
    family="attn",
)

SMOKE = ModelConfig(
    arch_id="starcoder2-7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="gelu",
    family="attn",
    dtype="float32",
)
