"""The paper's own workload as first-class configs (Table V regimes).

Each entry describes a distributed SpGEMM whose dry-run lowers the
batched-SUMMA3D step on the production mesh. Sizes are chosen so the
per-device tiles at 256/512 chips match the paper's per-core working sets
(Metaclust/Isolates are ~10^2 nnz/process-row at 262k cores); the synthetic
generators (core.gen) reproduce the sparsity regimes.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpGEMMWorkload:
    name: str
    n: int  # square matrix dimension (divisible by grid cols × layers × 16)
    avg_nnz_per_row: float
    kind: str  # "er" | "rmat" | "protein"
    cap_per_tile: int  # input tile capacity (static)
    flops_cap: int  # ESC expansion capacity per process per batch
    d_cap: int
    piece_cap: int
    c_cap: int
    num_batches: int
    semiring: str = "plus_times"


# Scaled to compile-time-tractable capacities; nnz/row and cf regimes match
# the paper's matrices (Eukarya ~120 nnz/row, Friendster ~55, Metaclust ~130).
WORKLOADS = {
    # Eukarya-like: moderate density, cf ~ 2.4
    "spgemm_eukarya_like": SpGEMMWorkload(
        name="spgemm_eukarya_like", n=1 << 20, avg_nnz_per_row=16,
        kind="protein", cap_per_tile=1 << 14, flops_cap=1 << 18,
        d_cap=1 << 17, piece_cap=1 << 16, c_cap=1 << 16, num_batches=4,
    ),
    # Friendster-like: power-law, high cf
    "spgemm_friendster_like": SpGEMMWorkload(
        name="spgemm_friendster_like", n=1 << 22, avg_nnz_per_row=8,
        kind="rmat", cap_per_tile=1 << 14, flops_cap=1 << 18,
        d_cap=1 << 17, piece_cap=1 << 16, c_cap=1 << 16, num_batches=16,
    ),
    # Metaclust-like: the memory-constrained flagship (b large)
    "spgemm_metaclust_like": SpGEMMWorkload(
        name="spgemm_metaclust_like", n=1 << 24, avg_nnz_per_row=4,
        kind="er", cap_per_tile=1 << 13, flops_cap=1 << 17,
        d_cap=1 << 16, piece_cap=1 << 15, c_cap=1 << 15, num_batches=64,
    ),
}
