"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8, head_dim=128)
d_ff=16384 vocab=256000, pruned nemotron [arXiv:2407.14679; hf]."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    act="swiglu",
    family="attn",
)

SMOKE = ModelConfig(
    arch_id="minitron-8b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="swiglu",
    family="attn",
    dtype="float32",
)
