"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE: 64 routed experts top-6 + 2 shared, fine-grained
[arXiv:2401.06066; hf].

Exercises the paper's technique end-to-end: SpGEMM-formulated dispatch
(DESIGN.md §4) with expert parallelism over the "model" axis.
"""
from ..models.moe import MoEConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    act="swiglu",
    family="attn",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)

SMOKE = ModelConfig(
    arch_id="deepseek-moe-16b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab=256,
    act="swiglu",
    family="attn",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1),
    dtype="float32",
)
