"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: the paper's SpGEMM technique is inapplicable (DESIGN.md
§Arch-applicability); long_500k decode runs with O(1) recurrent state.
"""
from ..models.ssm import SSMConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    n_layers=48,
    d_model=1024,
    vocab=50280,
    family="ssm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
    tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = ModelConfig(
    arch_id="mamba2-370m-smoke",
    n_layers=2,
    d_model=64,
    vocab=256,
    family="ssm",
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=8),
    tie_embeddings=True,
    supports_long_context=True,
    dtype="float32",
)
