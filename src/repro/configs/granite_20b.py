"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1, head_dim=128)
d_ff=24576 vocab=49152, llama-arch code model [arXiv:2405.04324; hf].
MQA means the KV cache is head-replicated under TP (cache_specs handles it).
"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    act="gelu",
    family="attn",
)

SMOKE = ModelConfig(
    arch_id="granite-20b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="gelu",
    family="attn",
    dtype="float32",
)
