"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8, head_dim=256)
d_ff=14336 vocab=256000; alternating local(4096)/global attention, attn
soft-cap 50, final logit soft-cap 30, GeGLU, post-norms, scaled embeddings
[arXiv:2408.00118; hf]."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-9b",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    act="geglu",
    family="attn",
    local_global_alt=True,
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=256 ** -0.5,
    use_post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="gemma2-9b-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    act="geglu",
    family="attn",
    local_global_alt=True,
    window=8,
    attn_softcap=50.0,
    final_softcap=30.0,
    use_post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    dtype="float32",
)
