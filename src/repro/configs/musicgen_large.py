"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32, head_dim=64)
d_ff=8192 vocab=2048; decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (the sum of per-codebook embeddings), so
input_mode="embeds"; the output head predicts one codebook stream
(vocab 2048). The backbone transformer is exact.
"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    family="attn",
    input_mode="embeds",
)

SMOKE = ModelConfig(
    arch_id="musicgen-large-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=64,
    act="gelu",
    family="attn",
    input_mode="embeds",
    dtype="float32",
)
