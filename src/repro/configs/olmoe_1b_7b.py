"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE: 64 experts top-8 [arXiv:2409.02060; hf]."""
from ..models.moe import MoEConfig
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    act="swiglu",
    family="attn",
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024, n_shared=0,
                  normalize_topk=False),
)

SMOKE = ModelConfig(
    arch_id="olmoe-1b-7b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab=256,
    act="swiglu",
    family="attn",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=0,
                  normalize_topk=False),
    dtype="float32",
)
