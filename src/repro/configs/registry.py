"""--arch <id> registry: full configs, smoke configs, shapes, input specs."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..models.transformer import ModelConfig
from . import (
    deepseek_moe_16b,
    gemma2_9b,
    granite_20b,
    mamba2_370m,
    minitron_8b,
    musicgen_large,
    olmoe_1b_7b,
    pixtral_12b,
    starcoder2_7b,
    zamba2_2_7b,
)
from .shapes import SHAPES, ShapeSpec, applicable  # noqa: F401
from .spgemm_workloads import WORKLOADS  # noqa: F401

_MODULES = {
    "pixtral-12b": pixtral_12b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "gemma2-9b": gemma2_9b,
    "granite-20b": granite_20b,
    "starcoder2-7b": starcoder2_7b,
    "minitron-8b": minitron_8b,
    "musicgen-large": musicgen_large,
    "mamba2-370m": mamba2_370m,
    "zamba2-2.7b": zamba2_2_7b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.CONFIG


def input_specs(
    cfg: ModelConfig, shape: ShapeSpec
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell —
    no allocation; the dry-run lowers against these. Modality frontends are
    stubs: `embeds` replaces token ids for [vlm]/[audio] archs."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "tokens":
            return {
                "inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return {
            "inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if shape.kind == "prefill":
        if cfg.input_mode == "tokens":
            return {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
    # decode: one token per sequence, KV cache of size S
    if cfg.input_mode == "tokens":
        return {"inputs": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    return {"inputs": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
