"""Autotuner: enumerate (grid, path, batches, bins, lookahead) candidates
from symbolic counts alone and price them with the cost model.

One ``host_symbolic_counts`` pass per candidate grid (host math over the
COO — no scatter, no devices, no trial multiplies), then
``plan_from_symbolic`` turns each (local path, forced batch count, k-bin
pin) combination into a concrete ``BatchPlan`` that ``predict_cost``
prices. The default configuration — the grid ``square_grid_for`` would
pick with ``PlanSpec()``/``ExecSpec()`` defaults — is ALWAYS in the
candidate set, so the argmin is never priced worse than the defaults by
construction (an acceptance criterion, asserted in tests).

The winner is returned as a ``TunedConfig``: exactly the frozen
``PlanSpec`` + ``PlanFloors`` + ``ExecSpec`` + grid shape that
``batched_summa3d`` and ``ServeConfig.from_tuned`` consume directly —
tuning output IS the spec API, no translation layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from ..core.batched import PlanInputs, plan_from_symbolic
from ..core.placement import compute_placement
from ..core.specs import ExecSpec, PlanFloors, PlanSpec
from ..core.symbolic import host_symbolic_counts
from .cost_model import (
    CostBreakdown,
    CostCoefficients,
    padded_comm_volume,
    predict_cost,
)

#: local-multiply paths the tuner prices explicitly ("auto" lets the plan
#: decide — the fixed-heuristic default the tuned pick must not lose to)
PATHS = ("auto", "esc", "binned", "hash")

#: placement strategies the tuner prices. ``None`` (no permutation) comes
#: first and wins ties: a placement is only picked on a STRICT improvement
#: of (predicted ms, padded transfer bytes) — the Table II volumes are
#: permutation-invariant, so the tiebreaker is the capacity-padded volume
#: (``padded_comm_volume``), the quantity a degree spread actually lowers.
PLACEMENTS = (None, "degree")


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """Autotuner output: a priced configuration in spec-API terms.

    ``spec``/``floors``/``exec_spec`` feed ``batched_summa3d`` (and
    ``ServeConfig.from_tuned``) verbatim; ``spec.mask`` is left ``None`` —
    the caller passes its scattered mask at multiply time. ``floors`` pin
    the priced plan's capacities so the first real multiply compiles the
    signature the model priced.
    """

    grid_shape: Tuple[int, int, int]
    per_process_memory: int
    spec: PlanSpec
    floors: PlanFloors
    exec_spec: ExecSpec
    num_batches: int
    predicted: CostBreakdown
    baseline_grid_shape: Tuple[int, int, int]
    baseline_num_batches: int
    baseline_predicted: CostBreakdown
    # winning placement STRATEGY name (None = unpermuted). Kept off
    # ``spec.placement`` on purpose: the spec field carries a concrete
    # Placement object for already-permuted operands, while the tuned
    # recommendation is "run this multiply through
    # ``placement.multiply_placed(..., strategy=...)``".
    placement: Optional[str] = None

    def to_meta(self) -> dict:
        """JSON-serializable summary (bench rows, serve admission logs)."""
        return {
            "grid_shape": list(self.grid_shape),
            "per_process_memory": self.per_process_memory,
            "local_path": self.spec.local_path,
            "lookahead": self.exec_spec.lookahead,
            "num_batches": self.num_batches,
            "placement": self.placement,
            "floors": self.floors.to_meta(),
            "predicted": self.predicted.to_meta(),
            "baseline_grid_shape": list(self.baseline_grid_shape),
            "baseline_num_batches": self.baseline_num_batches,
            "baseline_predicted": self.baseline_predicted.to_meta(),
        }


def candidate_grids(
    a_shape: Tuple[int, int],
    b_shape: Tuple[int, int],
    num_devices: int,
    mask: bool = False,
) -> Tuple[Tuple[int, int, int], ...]:
    """All (s, s, l) layer grids with s²·l ≤ ``num_devices`` — plus every
    RECTANGULAR single-layer (pr, pc, 1) with pr·pc ≤ ``num_devices`` —
    whose tile math divides the operand shapes (the
    ``host_symbolic_counts`` / ``make_grid`` preconditions): m(A) % pr,
    k % (pr·l) and k % (pc·l), n(B) % pc — plus n(B) % (pc·l) when a mask
    will be scattered (C-layout tiles). Rectangular layer grids only align
    the contraction slices at l == 1, hence the single-layer restriction."""
    m_a, k_dim = a_shape
    k_dim_b, n_b = b_shape
    assert k_dim == k_dim_b, (a_shape, b_shape)
    out = []
    s = 1
    while s * s <= num_devices:
        if m_a % s == 0 and n_b % s == 0:
            l = 1
            while s * s * l <= num_devices:
                ok = k_dim % (s * l) == 0
                if mask:
                    ok = ok and n_b % (s * l) == 0
                if ok:
                    out.append((s, s, l))
                l += 1
        s += 1
    for pr in range(1, num_devices + 1):
        if m_a % pr or k_dim % pr:
            continue
        for pc in range(1, num_devices // pr + 1):
            if pc == pr:
                continue  # squares enumerated above (with their layers)
            if n_b % pc or k_dim % pc:
                continue
            out.append((pr, pc, 1))
    return tuple(out)


def _default_grid(
    grids: Sequence[Tuple[int, int, int]],
) -> Tuple[int, int, int]:
    """The grid the fixed heuristics would pick: among the SQUARE layer
    grids (``square_grid_for`` never proposes a rectangle), use all the
    devices you can, prefer the squarest layout among equal process counts,
    then the fewest layers."""
    squares = [g for g in grids if g[0] == g[1]]
    return max(squares, key=lambda g: (g[0] * g[1] * g[2], g[0], -g[2]))


def autotune(
    a,
    b,
    per_process_memory: int,
    *,
    num_devices: Optional[int] = None,
    mask=None,
    coeffs: Optional[CostCoefficients] = None,
    lookaheads: Sequence[int] = (1, 2, 4),
    r_bytes: int = 12,
    max_retries: int = 4,
) -> TunedConfig:
    """Pick the cheapest (grid, path, batches, bins, lookahead) for
    ``a @ b`` under ``per_process_memory`` — by symbolic pricing only.

    ``a``/``b`` (and the optional ``mask``) are HOST matrices (anything
    with ``shape``/``nnz``/COO triplets, e.g. ``scipy.sparse`` or
    ``gen.*`` output) — nothing is scattered. Candidates that cannot fit
    the memory budget (``plan_from_symbolic`` raises ``MemoryError``) are
    skipped; if even the default grid cannot fit, the error propagates so
    the caller learns the budget is infeasible, same as ``plan_batches``.
    """
    if num_devices is None:
        import jax

        num_devices = len(jax.devices())
    grids = candidate_grids(a.shape, b.shape, num_devices,
                            mask=mask is not None)
    if not grids:
        raise ValueError(
            f"no layer grid with ≤{num_devices} devices divides shapes "
            f"{a.shape} × {b.shape}"
        )
    base_grid = _default_grid(grids)

    best = None  # TunedConfig-args tuple for the winning candidate
    best_key = None  # (total_ms, padded transfer bytes) — strict-< compare
    baseline = None  # (grid, plan, CostBreakdown) for the default config

    for strategy in PLACEMENTS:
        if strategy is None:
            pa, pb, pmask = a, b, mask
        else:
            placement = compute_placement(a, b, strategy=strategy, mask=mask)
            pa, pb = placement.apply_a(a), placement.apply_b(b)
            pmask = placement.apply_mask(mask) if mask is not None else None
        for grid in grids:
            counts = host_symbolic_counts(pa, pb, grid, mask=pmask)
            inputs = PlanInputs.from_host(pa, pb, grid, mask=pmask)
            for path in PATHS:
                for kbin_pin in (None, (1,)):
                    spec = PlanSpec(local_path=path, r_bytes=r_bytes,
                                    kbin_candidates=kbin_pin)
                    try:
                        plan = plan_from_symbolic(
                            counts, inputs, per_process_memory, spec,
                            PlanFloors(),
                        )
                    except MemoryError:
                        if strategy is None and grid == base_grid \
                                and path == "auto" and kbin_pin is None:
                            raise  # the default config itself is infeasible
                        continue
                    nb_forced = (None, plan.num_batches * 2)
                    for force in nb_forced:
                        if force is not None:
                            try:
                                plan_f = plan_from_symbolic(
                                    counts, inputs, per_process_memory,
                                    dataclasses.replace(
                                        spec, force_num_batches=force),
                                    PlanFloors(),
                                )
                            except MemoryError:
                                continue
                        else:
                            plan_f = plan
                        for la in lookaheads:
                            cost = predict_cost(
                                plan_f, grid, inputs.nnz_a, inputs.nnz_b,
                                coeffs=coeffs, r_bytes=r_bytes,
                                pipelined=True, lookahead=la,
                            )
                            padded = padded_comm_volume(
                                plan_f, grid, r_bytes=r_bytes
                            )
                            is_default = (
                                strategy is None and grid == base_grid
                                and path == "auto" and kbin_pin is None
                                and force is None
                                and la == ExecSpec().lookahead
                            )
                            if is_default:
                                baseline = (grid, plan_f, cost)
                            cand = (grid, plan_f, cost, path, kbin_pin,
                                    force, la, strategy)
                            # lexicographic, strict: placements iterate
                            # after None, so a permutation only wins when
                            # it strictly lowers the (ms, padded-bytes) key
                            key = (cost.total_ms, padded.total_bytes)
                            if best is None or key < best_key:
                                best, best_key = cand, key

    assert best is not None  # default grid either planned or raised
    if baseline is None:
        # default lookahead absent from `lookaheads`: reprice the default
        # plan at ExecSpec()'s lookahead so the comparison is still the
        # untouched-defaults configuration
        counts = host_symbolic_counts(a, b, base_grid, mask=mask)
        inputs = PlanInputs.from_host(a, b, base_grid, mask=mask)
        plan0 = plan_from_symbolic(
            counts, inputs, per_process_memory,
            PlanSpec(r_bytes=r_bytes), PlanFloors(),
        )
        baseline = (
            base_grid, plan0,
            predict_cost(plan0, base_grid, inputs.nnz_a, inputs.nnz_b,
                         coeffs=coeffs, r_bytes=r_bytes,
                         lookahead=ExecSpec().lookahead),
        )

    grid, plan, cost, path, kbin_pin, force, la, strategy = best
    decided = plan.local_path
    pin = kbin_pin
    if pin is None and decided == "binned" and plan.kbin is not None:
        pin = (plan.kbin.num_bins,)  # reproduce the priced bin structure
    tuned_spec = PlanSpec(
        local_path=decided,
        r_bytes=r_bytes,
        force_num_batches=force,
        kbin_candidates=pin,
    )
    tuned_floors = PlanFloors(
        caps=plan.caps,
        sel_cap=plan.sel_cap,
        num_batches=plan.num_batches,
        hash_caps=plan.hash_caps,
        caps_pow2=True,
    )
    return TunedConfig(
        grid_shape=grid,
        per_process_memory=per_process_memory,
        spec=tuned_spec,
        floors=tuned_floors,
        exec_spec=ExecSpec(lookahead=la, max_retries=max_retries),
        num_batches=plan.num_batches,
        predicted=cost,
        baseline_grid_shape=baseline[0],
        baseline_num_batches=baseline[1].num_batches,
        baseline_predicted=baseline[2],
        placement=strategy,
    )
