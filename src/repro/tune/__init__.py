"""Analytical cost model + autotuner (paper Table II / §IV cost analysis).

``cost_model`` prices a batch plan — per-batch and end-to-end — from the
Table II α–β communication terms and per-path γ compute terms over the
symbolic counts; ``autotune`` enumerates candidate (grid, local path, batch
count, k-bin pinning, lookahead) configurations from ONE symbolic pass per
candidate grid (host math, no devices, no trial multiplies) and returns a
``TunedConfig`` — exactly a ``PlanSpec`` + ``PlanFloors`` + ``ExecSpec`` +
grid shape, which ``batched_summa3d`` and the serving engine's admission
path (``ServeConfig.from_tuned``) consume directly. Placement candidates
(``core.placement`` permutations) are priced with ``padded_comm_volume``
— the capacity-padded transfer bytes the permutation-invariant Table II
terms cannot see.
"""
from .cost_model import (  # noqa: F401
    ACCEPT_BAND,
    CostBreakdown,
    CostCoefficients,
    comm_volume,
    fit_overhead,
    padded_comm_volume,
    predict_cost,
)
from .autotune import TunedConfig, autotune, candidate_grids  # noqa: F401
