"""Symbolic α–β–γ cost model of the batched SUMMA3D multiply (Table II, §IV).

One multiply at grid (pr, pc, l) with b batches is priced as

  predicted_ms = overhead · (dispatch + sync + comm + compute)

  dispatch = dispatch_ms · b                    per-batch fused-step launch
  sync     = sync_ms · b / lookahead            host flag reads, amortized by
                                                the pipelined window (serial
                                                schedule: lookahead = 1)
  comm     = beta_ms_per_byte · per-process Table II bytes
  compute  = γ_path · per-path compute units

Table II bandwidth terms (per process, r bytes per stored nonzero, totals
over the whole run — the model the comm bench reconciles against measured
HLO collectives):

  A-Gather        b · r · nnz(A)/p · (pc − 1)   A is re-gathered every batch
  B-Gather        r · nnz(B)/p · (pr − 1)       each batch gathers 1/b of B
  AllToAll-Fiber  r · flops/p · (l − 1)/l       every partial product crosses
                                                the fiber at most once

Compute units per local-multiply path: ESC and hash pay γ per flop (the hash
γ also covers its serialized per-chunk insert passes, which is why it is
~100× the ESC γ per flop on this backend); the k-binned path pays the ESC
merge cost plus γ_binned per PAIRING — ``b · KBinPlan.pairings``, the exact
quantity the symbolic k-bin plan minimizes, so a pinned bin count reprices
the candidate without re-running anything.

Coefficient defaults are priors fitted once against the checked-in
``BENCH_local_kernels.json`` / ``BENCH_summa3d.json`` rows (CPU backend);
``fit_overhead`` refits the single multiplicative ``overhead`` as the
geometric mean of measured/raw over whatever measured rows are at hand —
that one scalar is the hardware calibration (the WSE/TPU recipe: keep the
model, refit overhead), and ``ACCEPT_BAND`` is the fixed predicted/measured
acceptance band ``bench_tune`` records per row.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

#: fixed acceptance band for predicted/measured ratios after the overhead
#: fit (recorded in BENCH_tune.json; asserted by check_bench_json and tests)
ACCEPT_BAND = (0.25, 4.0)


@dataclasses.dataclass(frozen=True)
class CostCoefficients:
    """α–β–γ coefficients (ms). Defaults are CPU-backend priors fitted from
    the checked-in bench artifacts; ``overhead`` is the refittable scalar."""

    dispatch_ms: float = 9.6  # per-batch fused-step launch (α · phases)
    sync_ms: float = 0.2  # per-batch host flag read (amortized by lookahead)
    beta_ms_per_byte: float = 1e-6  # inverse bandwidth (β)
    gamma_esc_ms: float = 8.109 / 61581  # per flop (local_kernels esc row)
    gamma_hash_ms: float = 2.73e-2  # per flop (fused-step hash, per-chunk)
    gamma_binned_ms: float = 4.6e-5  # per pairing (k-binned extra pass)
    overhead: float = 1.0  # fitted measured/raw factor

    def replace(self, **kw) -> "CostCoefficients":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class CommVolume:
    """Per-process Table II bytes for one whole multiply (all batches)."""

    a_gather_bytes: int
    b_gather_bytes: int
    fiber_bytes: int

    @property
    def per_process_bytes(self) -> int:
        return self.a_gather_bytes + self.b_gather_bytes + self.fiber_bytes


def comm_volume(
    grid_shape: Tuple[int, int, int],
    num_batches: int,
    nnz_a: int,
    nnz_b: int,
    total_flops: int,
    r_bytes: int = 12,
) -> CommVolume:
    """Table II α–β volumes (see module docstring) — pure host math."""
    pr, pc, l = grid_shape
    p = pr * pc * l
    a_gather = num_batches * r_bytes * (nnz_a / p) * (pc - 1)
    b_gather = r_bytes * (nnz_b / p) * (pr - 1)
    fiber = r_bytes * (total_flops / p) * (l - 1) / l
    return CommVolume(
        a_gather_bytes=int(math.ceil(a_gather)),
        b_gather_bytes=int(math.ceil(b_gather)),
        fiber_bytes=int(math.ceil(fiber)),
    )


@dataclasses.dataclass(frozen=True)
class PaddedCommVolume:
    """CAPACITY-padded per-process transfer bytes of one planned multiply.

    The Table II ``comm_volume`` terms count exact nonzeros, which are
    permutation-INVARIANT — they cannot see what a placement buys. What the
    fused step actually moves is padded to the plan's static capacities:
    the block-cyclic B selection gathers a ``sel_cap``-sized buffer along
    the grid row every batch, and the fiber all_to_all exchanges
    ``piece_cap``-sized pieces across the layers. Those caps are MAXIMA of
    the distribution's fold — exactly what a degree-spread placement lowers
    on skewed inputs — so this is the volume the autotuner prices a
    placement candidate with (and the quantity the graph bench's placement
    summary row asserts shrinks on R-MAT skew).
    """

    all_to_all_bytes: int  # fiber exchange at piece_cap padding, all batches
    gather_bytes: int  # B-selection gather at sel_cap padding, all batches

    @property
    def total_bytes(self) -> int:
        return self.all_to_all_bytes + self.gather_bytes


def padded_comm_volume(
    plan, grid_shape: Tuple[int, int, int], r_bytes: int = 12
) -> PaddedCommVolume:
    """Padded per-process transfer bytes of ``plan`` on ``grid_shape``.

    Per batch the fused step sends its sel_cap-padded B selection to the
    ``pr − 1`` other processes of its grid row and its piece_cap-padded
    D pieces to the ``l − 1`` other layers; both are static shapes, so the
    bytes follow the caps, not the nnz."""
    pr, pc, l = grid_shape
    nb = plan.num_batches
    return PaddedCommVolume(
        all_to_all_bytes=int(nb * r_bytes * plan.caps.piece_cap * (l - 1)),
        gather_bytes=int(nb * r_bytes * plan.sel_cap * (pr - 1)),
    )


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Priced cost of one candidate configuration (end-to-end multiply)."""

    total_ms: float
    dispatch_ms: float
    sync_ms: float
    comm_ms: float
    compute_ms: float
    comm_bytes: int  # per-process Table II bytes (sum of the three terms)
    a_gather_bytes: int
    b_gather_bytes: int
    fiber_bytes: int
    num_batches: int
    path: str

    def to_meta(self) -> dict:
        return {k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()}


def compute_units(plan, path: str) -> Tuple[float, float]:
    """(flop-priced units, pairing-priced units) of one whole multiply.

    ESC/hash: every path pays the merge/compress over ``total_flops``
    partial products. Binned additionally pays the per-batch pairing grid
    the k-bin plan bounds (``pairings`` is a per-batch capacity product).
    """
    pairings = 0.0
    if path == "binned" and plan.kbin is not None:
        pairings = float(plan.kbin.pairings) * plan.num_batches
    return float(plan.total_flops), pairings


def predict_cost(
    plan,
    grid_shape: Tuple[int, int, int],
    nnz_a: int,
    nnz_b: int,
    coeffs: Optional[CostCoefficients] = None,
    r_bytes: int = 12,
    pipelined: bool = True,
    lookahead: int = 2,
    path: Optional[str] = None,
) -> CostBreakdown:
    """Price one ``BatchPlan`` on ``grid_shape`` — per-batch terms × b plus
    the Table II volumes. ``path`` overrides the plan's decided local path
    (the autotuner prices explicit path candidates through here)."""
    c = coeffs or CostCoefficients()
    if path is None or path == "auto":
        path = plan.local_path
    nb = plan.num_batches
    vol = comm_volume(grid_shape, nb, nnz_a, nnz_b, plan.total_flops, r_bytes)
    flop_units, pairing_units = compute_units(plan, path)
    gamma = {
        "esc": c.gamma_esc_ms,
        "binned": c.gamma_esc_ms,  # binned keeps the ESC merge pipeline
        "hash": c.gamma_hash_ms,
    }[path]
    compute_ms = gamma * flop_units + c.gamma_binned_ms * pairing_units
    dispatch_ms = c.dispatch_ms * nb
    window = max(int(lookahead), 1) if pipelined else 1
    sync_ms = c.sync_ms * nb / window
    comm_ms = c.beta_ms_per_byte * vol.per_process_bytes
    total = c.overhead * (dispatch_ms + sync_ms + comm_ms + compute_ms)
    return CostBreakdown(
        total_ms=total,
        dispatch_ms=dispatch_ms,
        sync_ms=sync_ms,
        comm_ms=comm_ms,
        compute_ms=compute_ms,
        comm_bytes=vol.per_process_bytes,
        a_gather_bytes=vol.a_gather_bytes,
        b_gather_bytes=vol.b_gather_bytes,
        fiber_bytes=vol.fiber_bytes,
        num_batches=nb,
        path=path,
    )


def fit_overhead(
    pairs: Sequence[Tuple[float, float]],
    coeffs: Optional[CostCoefficients] = None,
) -> CostCoefficients:
    """Refit the single ``overhead`` scalar from (raw_predicted_ms,
    measured_ms) pairs — geometric mean of measured/raw, the hardware
    calibration step (everything else in the model is symbolic)."""
    c = coeffs or CostCoefficients()
    ratios = [m / max(r, 1e-9) for r, m in pairs if m > 0]
    if not ratios:
        return c
    log_mean = sum(math.log(x) for x in ratios) / len(ratios)
    return c.replace(overhead=math.exp(log_mean))
