"""Paper Table II — α–β communication model vs measured HLO collectives.

For a host grid we compile one SUMMA3D step, parse the collective traffic
from the HLO (the same machinery as the dry-run), and compare against the
paper's Table II bandwidth terms:

  A-Broadcast    β · nnz(A)/p · sqrt(p/l)   per process (total over stages)
  B-Broadcast    β · nnz(B)/(b·p) · sqrt(p/l)
  AllToAll-Fiber β · flops/(b·p)            (loose; see §IV-C)

The derived column reports predicted/measured byte ratios — the
reconciliation of the analytic model with the compiled program.
"""
import numpy as np

import jax

from repro.core import gen
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.summa3d import BatchCaps, summa3d_sparse_step
from repro.launch import hlo_analysis

from .common import emit


def run(n: int = 64, nnz_per_row: int = 6) -> None:
    if len(jax.devices()) < 8:
        emit("tableII/skipped", 0, "needs 8 host devices")
        return
    grid = make_grid(2, 2, 2)
    a = gen.erdos_renyi(n, nnz_per_row, seed=3)
    b = gen.erdos_renyi(n, nnz_per_row, seed=4)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    caps = BatchCaps(flops_cap=8192, d_cap=4096, piece_cap=2048, c_cap=2048)
    lowered = jax.jit(
        summa3d_sparse_step, static_argnames=("grid", "caps", "semiring")
    ).lower(A, B, grid=grid, caps=caps)
    compiled = lowered.compile()
    coll = hlo_analysis.parse_collectives(compiled.as_text(), grid.p)

    # analytic Table II per-process bytes (r = 12 bytes/nonzero)
    r = 12
    p, l = grid.p, grid.l
    nnz_a, nnz_b = int(np.asarray(A.nnz).sum()), int(np.asarray(B.nnz).sum())
    pred_abcast = r * (nnz_a / p) * (grid.pc - 1)  # gather of pc-1 remote tiles
    pred_bbcast = r * (nnz_b / p) * (grid.pr - 1)
    # measured: all-gather wire bytes (A and B gathers dominate)
    meas_gather = coll.wire_bytes.get("all-gather", 0.0)
    meas_a2a = coll.wire_bytes.get("all-to-all", 0.0)
    emit("tableII/predicted_bcast_bytes", pred_abcast + pred_bbcast, "alpha-beta model")
    emit("tableII/measured_gather_bytes", meas_gather,
         f"ratio={(pred_abcast + pred_bbcast) / max(meas_gather, 1):.2f}")
    emit("tableII/measured_a2a_bytes", meas_a2a,
         f"counts={coll.counts}")
