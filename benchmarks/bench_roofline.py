"""§Roofline summary — reads dryrun_results.json and emits the three terms
per (arch × shape × mesh) as benchmark rows (derived = dominant term +
useful-flops fraction). Run the dry-run sweep first:
    python -m repro.launch.dryrun --all --mesh both
"""
import json
import os

from .common import emit

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline/skipped", 0, f"no {RESULTS}; run the dry-run sweep")
        return
    with open(RESULTS) as f:
        rows = json.load(f)
    for r in sorted(rows, key=lambda x: (x["arch"], x.get("shape", ""), x["mesh"])):
        name = f"roofline/{r['arch']}/{r.get('shape','')}/{r['mesh']}"
        if r.get("kind") == "skip":
            emit(name, 0, "SKIP " + r.get("skip_reason", "")[:60])
            continue
        if r.get("kind") == "error":
            emit(name, 0, "ERROR")
            continue
        roof = r["roofline"]
        emit(
            name,
            roof["bound_s"] * 1e6,
            f"dom={roof['dominant']} c={roof['compute_s']:.4f} "
            f"m={roof['memory_s']:.4f} n={roof['collective_s']:.4f} "
            f"useful={r.get('useful_flops_fraction', 0):.3f}",
        )
