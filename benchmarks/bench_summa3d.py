"""End-to-end batched-SUMMA3D driver benchmark (paper Fig. 4/5 regime).

Measures the pipelined scheduler against the serial one on a multi-batch
R-MAT workload — the paper's claim that streaming numeric batches through the
communicators without the host in the loop is what keeps the per-batch
pipeline busy (§IV-A, Alg. 4):

  * serial: one fused step per batch, host-syncs the overflow flags before
    dispatching the next batch (the pre-pipelining schedule).
  * pipelined: batches i+1..i+lookahead dispatched before batch i's flags
    are read; consumer host work overlaps device compute.
  * binned vs ESC vs hash-accumulator local multiply on the same plan, with
    the pairing-work counts the symbolic k-bin plan bounds.

The suite also emits the hash path's MEMORY claim as a plan row: at a fixed
``per_process_memory`` (the probe budget that forces the ESC plan to batch),
the hash memory model — table slots over the merged output instead of the
full expansion — plans strictly fewer batches.

CPU wall times are NOT TPU predictions; the reproduced claim is the shape of
the comparison (host-sync per batch vs windowed async dispatch, full pairing
grid vs k-binned). ``run_summa3d_suite`` emits JSON rows for
BENCH_summa3d.json: per-batch wall-ms, end-to-end wall-ms per driver, the
pairing counts, and an acceptance summary row.
"""
import time

import numpy as np

from repro.core import gen
from repro.core.batched import (
    batched_summa3d,
    plan_batches,
    probe_memory_budget,
)
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.specs import ExecSpec, PlanSpec

from .common import emit


def _consumer_factory(n, grid):
    """HipMCL-style consumer: pull the batch to host and store it into the
    global output structure (the prune/store step of §V-C) — real host work
    that the pipelined schedule overlaps with device compute while the next
    batch's fused step is already in flight."""
    acc = np.zeros((n, n), np.float32)
    state = dict(nnz=0, t_last=0.0, batch_ms=[], acc=acc)
    pr, pc, l = grid.pr, grid.pc, grid.l

    def consumer(bi, c_batch, col_map):
        rows = np.asarray(c_batch.rows)
        cols = np.asarray(c_batch.cols)
        vals = np.asarray(c_batch.vals)
        nnzs = np.asarray(c_batch.nnz)
        tm = c_batch.tile_shape[0]
        for i in range(pr):
            for j in range(pc):
                for k in range(l):
                    cnt = int(nnzs[i, j, k])
                    gr = i * tm + rows[i, j, k, :cnt]
                    gc = col_map[j, k][cols[i, j, k, :cnt]]
                    np.add.at(acc, (gr, gc), vals[i, j, k, :cnt])
        state["nnz"] += int(nnzs.sum())
        now = time.perf_counter()
        state["batch_ms"].append((now - state["t_last"]) * 1e3)
        state["t_last"] = now
        return int(nnzs.sum())

    return state, consumer


def _run_once(A, B, grid, nb, pipelined, binned, local_path="auto"):
    """One timed end-to-end driver run; returns (wall_ms, batch_ms, result)."""
    n = A.shape[0]
    state, consumer = _consumer_factory(n, grid)
    t0 = time.perf_counter()
    state["t_last"] = t0
    res = batched_summa3d(
        A, B, grid, per_process_memory=1 << 30, consumer=consumer,
        path="sparse",
        spec=PlanSpec(local_path=local_path, force_num_batches=nb),
        exec_spec=ExecSpec(pipelined=pipelined, binned=binned),
    )
    dt = (time.perf_counter() - t0) * 1e3
    return dt, state["batch_ms"], res


def _time_drivers(A, B, grid, nb, configs, iters=5):
    """Per-config wall-ms over ``iters`` INTERLEAVED rounds (variant A, B,
    ... then again): adjacent runs share machine conditions, so per-round
    ratios cancel noise drift that best-of-N over separate blocks cannot.
    Round 0 warms the jit cache and is discarded. Returns (per-config list of
    round times, serial per-batch ms from the fastest serial round, results).
    """
    times = {name: [] for name in configs}
    batch_ms = {name: None for name in configs}
    results = {}
    for it in range(iters + 1):
        for name, (pipelined, binned, local_path) in configs.items():
            dt, bms, res = _run_once(A, B, grid, nb, pipelined, binned,
                                     local_path)
            results[name] = res
            if it == 0:
                continue
            if not times[name] or dt < min(times[name]):
                batch_ms[name] = bms
            times[name].append(dt)
    return times, batch_ms, results


def run_summa3d_suite(scale=8, edge_factor=8, nb=32, iters=5) -> list:
    """The ``--suite summa3d`` entry: returns JSON-ready rows."""
    grid = make_grid(2, 2, 2)
    n = 1 << scale
    a = gen.rmat(scale=scale, edge_factor=edge_factor, seed=3)
    b = gen.rmat(scale=scale, edge_factor=edge_factor, seed=4)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    rows = []

    plan = plan_batches(A, B, grid, per_process_memory=1 << 30,
                        spec=PlanSpec(force_num_batches=nb, local_path="esc"))
    reduction = plan.kbin.pairings_unbinned / max(plan.kbin.pairings, 1)
    rows.append(dict(
        op="plan", variant="kbin", wall_ms=0.0, n=n,
        num_batches=plan.num_batches, num_bins=plan.kbin.num_bins,
        pairings_binned=plan.kbin.pairings,
        pairings_unbinned=plan.kbin.pairings_unbinned,
        pairing_reduction=reduction,
    ))
    emit("fig4/summa3d_plan", 0.0,
         f"b={plan.num_batches} pairings={plan.kbin.pairings}"
         f"({reduction:.1f}x fewer)")

    # --- the hash path's memory claim: at the SAME fixed per-process budget
    # (probed so the ESC plan must batch), the hash plan needs fewer
    # batches. Measured on the compressing regime the hash table targets —
    # A·Aᵀ of a denser R-MAT (2× edge factor), the overlap/MCL-like shape
    # where flops ≫ nnz(C).
    ah = gen.rmat(scale=scale, edge_factor=2 * edge_factor, seed=3)
    Ah = scatter_to_grid(ah, grid, "A")
    Bh = scatter_to_grid(ah.transpose().sort_rowmajor(), grid, "B")
    ppm = probe_memory_budget(Ah, Bh, grid)
    p_esc = plan_batches(Ah, Bh, grid, per_process_memory=ppm,
                         spec=PlanSpec(local_path="esc"))
    p_hash = plan_batches(Ah, Bh, grid, per_process_memory=ppm,
                          spec=PlanSpec(local_path="hash"))
    rows.append(dict(
        op="plan", variant="fixed_mem_batches", wall_ms=0.0, n=n,
        edge_factor=2 * edge_factor,
        per_process_memory=ppm,
        num_batches_esc=p_esc.num_batches,
        num_batches_hash=p_hash.num_batches,
        compression_factor=p_hash.compression_est,
        hash_table_cap=(p_hash.hash_caps.table_cap
                        if p_hash.hash_caps else 0),
    ))
    emit("fig4/summa3d_fixed_mem_batches", 0.0,
         f"b_esc={p_esc.num_batches} b_hash={p_hash.num_batches} "
         f"cf={p_hash.compression_est:.2f}")

    configs = {
        "serial": (False, "auto", "auto"),
        "pipelined": (True, "auto", "auto"),
        "pipelined_esc": (True, False, "esc"),
        "pipelined_binned": (True, True, "binned"),
        "pipelined_hash": (True, "auto", "hash"),
    }
    times, batch_ms, results = _time_drivers(A, B, grid, nb, configs,
                                             iters=iters)
    for bi, ms in enumerate(batch_ms["serial"]):
        rows.append(dict(op="driver_batch", variant=f"serial_batch{bi}",
                         wall_ms=ms))
    for variant, ts in times.items():
        ms = float(np.median(ts))
        rows.append(dict(op="driver_e2e", variant=variant, wall_ms=ms,
                         wall_ms_min=min(ts), num_batches=nb))
        emit(f"fig4/summa3d_{variant}", ms * 1e3, f"b={nb}")
    res = results["pipelined"]

    # per-round ratio median: serial and pipelined runs of the same round are
    # adjacent in time, so shared machine noise cancels
    speedup = float(np.median(
        [s / max(p, 1e-9)
         for s, p in zip(times["serial"], times["pipelined"])]
    ))
    rows.append(dict(
        op="summary", variant="acceptance", wall_ms=0.0,
        speedup_pipelined_vs_serial=speedup,
        pairing_reduction=reduction,
        pairings_binned=plan.kbin.pairings,
        pairings_unbinned=plan.kbin.pairings_unbinned,
        binned_local_multiply_used=bool(res.binned),
        local_path_used=res.local_path,
        num_batches_esc=p_esc.num_batches,
        num_batches_hash=p_hash.num_batches,
        hash_batches_fewer=bool(p_hash.num_batches < p_esc.num_batches),
    ))
    emit("fig4/summa3d_speedup", 0.0, f"{speedup:.2f}x pipelined vs serial")
    return rows


def run() -> None:
    run_summa3d_suite(iters=2)
