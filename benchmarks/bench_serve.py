"""Serving-engine suite — plan-cached multiply-as-a-service (``--suite serve``).

Drives an open-loop mixed repeat/novel request stream through the
``SpgemmEngine`` (admission control priced by the batched-plan footprint
model, plan cache keyed on the pow2-quantized matrix signature, pipelined
lookahead dispatch) and reports:

  * per-request latency percentiles (p50/p99) and multiplies/sec,
  * the plan-cache hit rate over the mixed phase,
  * ``retraces_repeat`` — extra ``fused_step`` traces incurred by a repeat
    request after the warm-up, the zero-retrace acceptance artifact.

``run_serve_suite`` emits JSON rows for BENCH_serve.json. CPU wall times are
NOT TPU predictions; the reproduced claim is the cache/admission shape
(repeat traffic compiles nothing, over-budget traffic is split or deferred,
never OOM-killed).
"""
import time

import numpy as np

from repro.core import summa3d
from repro.core.gen import erdos_renyi
from repro.core.grid import make_grid
from repro.serve import MultiplyRequest, ServeConfig, SpgemmEngine

from .common import emit


def _pct(sorted_ms: list, q: float) -> float:
    if not sorted_ms:
        return 0.0
    return sorted_ms[min(int(q * len(sorted_ms)), len(sorted_ms) - 1)]


def run_serve_suite(n: int = 128, requests: int = 16,
                    repeat_frac: float = 0.5, smoke: bool = False) -> list:
    """The ``--suite serve`` entry: returns JSON-ready rows."""
    if smoke:
        n, requests = 64, 8
    grid = make_grid(2, 2, 2)
    eng = SpgemmEngine(grid, ServeConfig(per_process_memory=1 << 26))
    a0 = erdos_renyi(n, 4.0, seed=7)
    b0 = erdos_renyi(n, 4.0, seed=8)

    # warm-up: one request populates the plan cache and compiles the
    # fused-step executable for the repeat signature (excluded from timing)
    eng.submit(MultiplyRequest(rid=-1, a=a0, b=b0))
    eng.run_to_completion()
    warm_hits, warm_misses = eng.stats["hits"], eng.stats["misses"]
    warm_done = len(eng.done)

    # open-loop mixed phase: all requests queued up front, engine drains
    rng = np.random.default_rng(0)
    for rid in range(requests):
        if rng.random() < repeat_frac:
            eng.submit(MultiplyRequest(rid=rid, a=a0, b=b0))
        else:
            eng.submit(MultiplyRequest(
                rid=rid,
                a=erdos_renyi(n, 4.0, seed=100 + 2 * rid),
                b=erdos_renyi(n, 4.0, seed=101 + 2 * rid),
            ))
    t0 = time.perf_counter()
    results = eng.run_to_completion()
    wall_ms = (time.perf_counter() - t0) * 1e3
    ok = [r for r in results[warm_done:] if r.status == "ok"]
    lat = sorted(r.latency_ms for r in ok)
    p50, p99 = _pct(lat, 0.5), _pct(lat, 0.99)
    mps = len(ok) / (wall_ms / 1e3) if wall_ms > 0 else 0.0
    hits = eng.stats["hits"] - warm_hits
    misses = eng.stats["misses"] - warm_misses
    hit_rate = hits / max(hits + misses, 1)

    # zero-retrace acceptance probe: one more repeat after the mixed phase
    tr0 = summa3d.TRACE_COUNTS["fused_step"]
    eng.submit(MultiplyRequest(rid=requests, a=a0, b=b0))
    eng.run_to_completion()
    retraces_repeat = summa3d.TRACE_COUNTS["fused_step"] - tr0

    emit("serve_e2e/open_loop", wall_ms * 1e3 / max(len(ok), 1),
         f"p50={p50:.1f}ms p99={p99:.1f}ms {mps:.1f}/s")
    emit("plan_cache/hit_rate", 0.0, f"hit_rate={hit_rate:.2f}")
    emit("serve/retraces_repeat", 0.0, f"retraces={retraces_repeat}")
    return [
        dict(op="serve_e2e", variant="open_loop", wall_ms=wall_ms,
             n=n, requests=len(ok), p50_ms=p50, p99_ms=p99,
             multiplies_per_s=mps, deferred=eng.stats["deferred"],
             refused=eng.stats["refused"], splits=eng.stats["splits"]),
        dict(op="plan_cache", variant="hit_rate", wall_ms=0.0,
             hit_rate=hit_rate, hits=hits, misses=misses),
        dict(op="summary", variant="acceptance", wall_ms=0.0,
             plan_cache_hit_rate=hit_rate, retraces_repeat=retraces_repeat,
             p50_ms=p50, p99_ms=p99),
    ]


def run() -> None:
    """CSV mode for ``--suite all``."""
    run_serve_suite(smoke=True)
