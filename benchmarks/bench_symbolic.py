"""Paper Fig. 8 — symbolic step cost vs numeric multiply.

Times the distributed symbolic pass (count vectors only) against the numeric
multiply on the same inputs; the paper's claim is that the symbolic step is
communication-dominated and benefits even more from CA layering because its
local compute is trivial.
"""
import jax

from repro.core import gen
from repro.core.batched import symbolic3d
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.summa3d import BatchCaps, summa3d_sparse_step

from .common import emit, time_jit


def run(n: int = 64, nnz_per_row: int = 6) -> None:
    if len(jax.devices()) < 8:
        emit("fig8/skipped", 0, "needs 8 host devices")
        return
    grid = make_grid(2, 2, 2)
    a = gen.erdos_renyi(n, nnz_per_row, seed=7)
    b = gen.erdos_renyi(n, nnz_per_row, seed=8)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")

    t_sym = time_jit(lambda: symbolic3d(A, B, grid), iters=3, warmup=1)
    emit("fig8/symbolic_step", t_sym, "count-vector payloads")

    caps = BatchCaps(flops_cap=8192, d_cap=4096, piece_cap=2048, c_cap=2048)
    fn = jax.jit(summa3d_sparse_step, static_argnames=("grid", "caps", "semiring"))
    t_num = time_jit(lambda: fn(A, B, grid=grid, caps=caps)[0].vals, iters=3,
                     warmup=1)
    emit("fig8/numeric_multiply", t_num,
         f"symbolic/numeric={t_sym / max(t_num, 1):.3f}")
