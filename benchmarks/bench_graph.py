"""Paper §V-B graph workloads — masked vs unmasked SpGEMM (BENCH_graph.json).

Triangle counting on an R-MAT power-law graph compares the two formulations
the repo keeps:

  * masked_device — ``triangle_count``: the L-mask is a device-resident
    operand; the symbolic step budgets survivors only (smaller capacities,
    fewer batches), the local multiply drops non-mask partial products
    before its compress, and one f32 scalar per batch crosses to the host.
  * host_filter — ``triangle_count_host``: the full (unmasked) L·U product,
    every batch pulled to numpy and masked by a Python set lookup — the
    pre-masked-path baseline.

Overlap detection (AA^T, BELLA filter) compares the on-grid filter
(``overlap_pairs``) against the pull-and-filter host oracle.

``run_graph_suite`` emits JSON rows for BENCH_graph.json: the masked vs
unmasked *plans* (capacities, batch count, k-bin pairings), per-path wall-ms
and host-transfer bytes, and an acceptance summary asserting the §V-B claim
(masked capacities and batch count strictly below unmasked on the R-MAT
case). CPU wall times are NOT TPU predictions; the reproduced claim is the
capacity/batch/transfer shape.
"""
import time

import jax

from repro.core import gen
from repro.core.batched import plan_batches, probe_memory_budget
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.specs import PlanSpec
from repro.sparse_apps import graph_algorithms as ga
from repro.sparse_apps.mcl import reset_transfer_bytes, transfer_bytes

from .common import emit


def _plan_row(variant, plan):
    kb = plan.kbin
    return dict(
        op="plan", variant=variant, wall_ms=0.0,
        batches=plan.num_batches,
        flops_cap=plan.caps.flops_cap, d_cap=plan.caps.d_cap,
        piece_cap=plan.caps.piece_cap, c_cap=plan.caps.c_cap,
        sel_cap=plan.sel_cap, mask_sel_cap=plan.mask_sel_cap,
        max_unmerged_nnz=plan.max_unmerged_nnz,
        pairings=kb.pairings, pairings_unbinned=kb.pairings_unbinned,
    )


def _timed(fn, *args, **kwargs):
    fn(*args, **kwargs)  # warm the jit caches (compile time excluded)
    reset_transfer_bytes()
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, (time.perf_counter() - t0) * 1e3, transfer_bytes()


def run_graph_suite(scale: int = 7, edge_factor: int = 8) -> list:
    """The ``--suite graph`` entry: returns JSON-ready rows."""
    grid = make_grid(2, 2, 2)
    a = gen.symmetrized(gen.rmat(scale, edge_factor=edge_factor, seed=5))
    n = a.shape[0]
    rows = []

    # ---- plans under a budget that forces the unmasked multiply to batch
    # (probe_memory_budget is the same math the slow-lane R-MAT case uses)
    L, U = ga._strict_parts(a)
    A_d = scatter_to_grid(L, grid, "A")
    B_d = scatter_to_grid(U, grid, "B")
    M_d = scatter_to_grid(L, grid, "C")
    ppm = probe_memory_budget(A_d, B_d, grid)
    pu = plan_batches(A_d, B_d, grid, per_process_memory=ppm,
                      spec=PlanSpec(local_path="esc"))
    pm = plan_batches(A_d, B_d, grid, per_process_memory=ppm,
                      spec=PlanSpec(mask=M_d, local_path="esc"))
    rows.append(dict(_plan_row("triangle_unmasked", pu), n=n,
                     per_process_memory=ppm))
    rows.append(dict(_plan_row("triangle_masked", pm), n=n,
                     per_process_memory=ppm))

    # ---- timed triangle counting, device-masked vs host-filter
    tri_m, ms_m, bytes_m = _timed(ga.triangle_count, a, grid,
                                  per_process_memory=ppm)
    tri_h, ms_h, bytes_h = _timed(ga.triangle_count_host, a, grid,
                                  per_process_memory=ppm)
    assert tri_m == tri_h, (tri_m, tri_h)
    rows.append(dict(op="triangle", variant="masked_device", wall_ms=ms_m,
                     host_bytes=bytes_m, triangles=tri_m, n=n))
    rows.append(dict(op="triangle", variant="host_filter", wall_ms=ms_h,
                     host_bytes=bytes_h, triangles=tri_h, n=n))

    # ---- overlap detection, on-grid filter vs host filter
    kmer = gen.kmer_like(64, 128, 6, seed=17)
    ov_d, ms_d, bytes_d = _timed(ga.overlap_pairs, kmer, grid, min_shared=2)
    ov_h, ms_oh, bytes_oh = _timed(ga.overlap_pairs_host, kmer, grid,
                                   min_shared=2)
    assert ov_d == ov_h, (len(ov_d), len(ov_h))
    rows.append(dict(op="overlap", variant="device_filter", wall_ms=ms_d,
                     host_bytes=bytes_d, pairs=len(ov_d), nseqs=64))
    rows.append(dict(op="overlap", variant="host_filter", wall_ms=ms_oh,
                     host_bytes=bytes_oh, pairs=len(ov_h), nseqs=64))

    # ---- structure-aware placement: degree-spread vs block-cyclic volume
    # (same R-MAT skew, square a·a multiply, same probe-budget math; the
    # Table II volumes are permutation-invariant, so the comparison is on
    # the capacity-PADDED transfer bytes the plan actually moves)
    from repro.core.placement import compute_placement
    from repro.tune import padded_comm_volume

    gs = (grid.pr, grid.pc, grid.l)
    Aq = scatter_to_grid(a, grid, "A")
    Bq = scatter_to_grid(a, grid, "B")
    ppm_q = probe_memory_budget(Aq, Bq, grid)
    p_base = plan_batches(Aq, Bq, grid, per_process_memory=ppm_q,
                          spec=PlanSpec(local_path="esc"))
    placement = compute_placement(a, a, "degree")
    Apl = scatter_to_grid(placement.apply_a(a), grid, "A")
    Bpl = scatter_to_grid(placement.apply_b(a), grid, "B")
    p_deg = plan_batches(Apl, Bpl, grid, per_process_memory=ppm_q,
                         spec=PlanSpec(local_path="esc"))
    v_base = padded_comm_volume(p_base, gs)
    v_deg = padded_comm_volume(p_deg, gs)
    for variant, plan, vol in (("block_cyclic", p_base, v_base),
                               ("degree", p_deg, v_deg)):
        rows.append(dict(
            op="placement", variant=variant, wall_ms=0.0, n=n,
            per_process_memory=ppm_q, batches=plan.num_batches,
            sel_cap=plan.sel_cap, piece_cap=plan.caps.piece_cap,
            all_to_all_bytes=vol.all_to_all_bytes,
            gather_bytes=vol.gather_bytes, padded_bytes=vol.total_bytes,
        ))
    # acceptance: degree-spread plans no more batches and strictly fewer
    # padded transfer bytes (the all_to_all term alone may tie — the
    # layer-split piece cap can absorb the whole reduction into fewer,
    # larger batches)
    placement_ok = (
        p_deg.num_batches <= p_base.num_batches
        and v_deg.all_to_all_bytes <= v_base.all_to_all_bytes
        and v_deg.total_bytes < v_base.total_bytes
    )
    assert placement_ok, (p_deg.num_batches, p_base.num_batches,
                          v_deg, v_base)
    rows.append(dict(
        op="summary", variant="placement_volume", wall_ms=0.0,
        batches_block_cyclic=p_base.num_batches,
        batches_degree=p_deg.num_batches,
        padded_bytes_block_cyclic=v_base.total_bytes,
        padded_bytes_degree=v_deg.total_bytes,
        volume_reduction=v_base.total_bytes / max(v_deg.total_bytes, 1),
        degree_below_block_cyclic=placement_ok,
    ))

    # ---- acceptance: the §V-B masked claim on the R-MAT case
    ok = (
        pm.num_batches < pu.num_batches
        and pm.caps.d_cap < pu.caps.d_cap
        and pm.caps.c_cap < pu.caps.c_cap
    )
    assert ok, (pm, pu)
    rows.append(dict(
        op="summary", variant="masked_vs_unmasked", wall_ms=ms_m,
        batches_masked=pm.num_batches, batches_unmasked=pu.num_batches,
        d_cap_masked=pm.caps.d_cap, d_cap_unmasked=pu.caps.d_cap,
        c_cap_masked=pm.caps.c_cap, c_cap_unmasked=pu.caps.c_cap,
        triangle_transfer_reduction=bytes_h / max(bytes_m, 1),
        masked_below_unmasked=ok,
    ))
    return rows


def run(scale: int = 7) -> None:
    if len(jax.devices()) < 8:
        emit("graph/skipped", 0, "needs 8 host devices")
        return
    for row in run_graph_suite(scale=scale):
        if row["op"] == "plan":
            emit(f"graph/plan_{row['variant']}", 0,
                 f"b={row['batches']} d_cap={row['d_cap']} "
                 f"c_cap={row['c_cap']}")
        elif row["op"] in ("triangle", "overlap"):
            emit(f"graph/{row['op']}_{row['variant']}", row["wall_ms"] * 1e3,
                 f"host_bytes={row['host_bytes']}")
        elif row["op"] == "placement":
            emit(f"graph/placement_{row['variant']}", 0,
                 f"b={row['batches']} padded_bytes={row['padded_bytes']}")
        elif row["variant"] == "placement_volume":
            emit("graph/summary_placement", 0,
                 f"b {row['batches_degree']}<={row['batches_block_cyclic']} "
                 f"volume_red={row['volume_reduction']:.2f}x")
        else:
            emit("graph/summary", row["wall_ms"] * 1e3,
                 f"b {row['batches_masked']}<{row['batches_unmasked']} "
                 f"transfer_red={row['triangle_transfer_reduction']:.0f}x")
