"""Paper Fig. 3 — HipMCL iterations with batched SpGEMM (§V-C end-to-end).

Runs the MCL loop on a protein-similarity-like block matrix with a tight
memory budget (forces b > 1) and compares the two implementations the repo
keeps:

  * device — ``mcl_iterate``: inflation/normalization/top-k pruning fused
    into the batched driver's device-side postprocess hook, pruned batches
    reassembled into the next iterate ON the grid. Host traffic per
    iteration is a handful of stat scalars.
  * host — ``mcl_iterate_host``: the kept host-loop reference; every batch
    is pulled to numpy, pruned there, and the iterate round-trips
    host<->device each iteration.

``run_mcl_suite`` emits JSON rows for BENCH_mcl.json: per-iteration wall-ms
and host-transfer bytes for both paths, plus an acceptance summary row
(speedup + transfer reduction). CPU wall times are NOT TPU predictions; the
reproduced claim is the transfer/schedule shape.
"""
import time

import numpy as np

import jax

from repro.core import gen
from repro.core.grid import make_grid
from repro.sparse_apps.mcl import (
    MCLConfig,
    _col_normalize_np,
    mcl_iterate,
    mcl_iterate_host,
    reset_transfer_bytes,
    transfer_bytes,
)
from repro.core.sparse import from_numpy_coo

from .common import emit


def _block_input(n: int, blocks: int = 4, intra_p: float = 0.5, seed: int = 11):
    a = gen.protein_similarity_like(n, blocks=blocks, intra_p=intra_p, seed=seed)
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    vals = _col_normalize_np(rows, cols,
                             np.asarray(a.vals[:nnz]).astype(np.float64), n)
    return from_numpy_coo(rows, cols, vals.astype(np.float32), (n, n), cap=nnz)


def _tight_budget(a, grid):
    """Pick a per-process budget that actually forces b > 1 (probe plan)."""
    from repro.core.batched import plan_batches
    from repro.core.distsparse import scatter_to_grid

    probe = plan_batches(
        scatter_to_grid(a, grid, "A"), scatter_to_grid(a, grid, "B"), grid,
        per_process_memory=1 << 30,
    )
    # headroom covers the device path's reserved pruned-output capacities
    # (MCLConfig defaults: <= 12*(k*w/l + k*w) bytes) on top of the batch math
    return 12 * max(probe.max_unmerged_nnz // 3, 1) + (1 << 15)


def run_mcl_suite(n: int = 64, max_iters: int = 6) -> list:
    """The ``--suite mcl`` entry: returns JSON-ready rows."""
    grid = make_grid(2, 2, 2)
    a = _block_input(n)
    tight = _tight_budget(a, grid)
    rows = []
    # memory-driven batch counts under the tight budget (the device path
    # reserves its pruned-output capacities, so it batches finer) — recorded
    # for the planning story; the timed comparison below forces one shared
    # plan (b=4) so per-iteration wall-ms is apples-to-apples.
    _, hist_d1 = mcl_iterate(
        a, grid, MCLConfig(max_iters=1, per_process_memory=tight))
    _, hist_h1 = mcl_iterate_host(
        a, grid, MCLConfig(max_iters=1, per_process_memory=tight))
    rows.append(dict(
        op="plan", variant="memory_driven", wall_ms=0.0, n=n,
        per_process_memory=tight,
        batches_device=hist_d1[0]["batches"],
        batches_host=hist_h1[0]["batches"],
    ))
    e2e = {}
    bytes_total = {}
    iter_bytes = {}
    nb = 4
    for variant, fn in (("device", mcl_iterate), ("host", mcl_iterate_host)):
        cfg = MCLConfig(max_iters=max_iters, per_process_memory=tight,
                        force_num_batches=nb)
        fn(a, grid, cfg)  # warm the jit caches (compile time excluded)
        reset_transfer_bytes()
        t0 = time.perf_counter()
        _, hist = fn(a, grid, cfg)
        e2e[variant] = (time.perf_counter() - t0) * 1e3
        bytes_total[variant] = transfer_bytes()
        iter_bytes[variant] = float(
            np.mean([h["host_bytes"] for h in hist])
        )
        for h in hist:
            rows.append(dict(
                op="mcl_iter", variant=f"{variant}_iter{h['iter']}",
                wall_ms=h["wall_ms"], host_bytes=h["host_bytes"],
                nnz=h["nnz"], chaos=h["chaos"], batches=h["batches"],
            ))
        rows.append(dict(
            op="mcl_e2e", variant=variant, wall_ms=e2e[variant], n=n,
            iters=len(hist), host_bytes=bytes_total[variant],
            batches=hist[0]["batches"],
        ))
    rows.append(dict(
        op="summary", variant="device_vs_host", wall_ms=e2e["device"],
        speedup_device_vs_host=e2e["host"] / max(e2e["device"], 1e-9),
        host_transfer_reduction=(
            bytes_total["host"] / max(bytes_total["device"], 1)
        ),
        iter_transfer_reduction=(
            iter_bytes["host"] / max(iter_bytes["device"], 1.0)
        ),
    ))
    rows.extend(_checkpoint_overhead_rows(
        a, grid, max_iters, tight, nb, e2e["device"]))
    return rows


def _checkpoint_overhead_rows(a, grid, max_iters, tight, nb, base_ms):
    """Per-iteration checkpoint overhead of the resilient loop: the same
    device run under ``mcl_iterate_resilient`` with a checkpoint every
    iteration, async (off-thread write overlapped with the next multiply)
    vs sync (blocking write). Overhead is measured against the plain
    ``mcl_iterate`` end-to-end time; bytes are per completed save."""
    import tempfile

    from repro.runtime.resilient import ResilientConfig
    from repro.sparse_apps.mcl import mcl_iterate_resilient

    rows = []
    cfg = MCLConfig(max_iters=max_iters, per_process_memory=tight,
                    force_num_batches=nb)
    for variant, async_save in (("async", True), ("sync", False)):
        with tempfile.TemporaryDirectory() as d:
            rc = ResilientConfig(ckpt_dir=d, ckpt_every=1,
                                 async_save=async_save, resume=False)
            t0 = time.perf_counter()
            _, hist, rep = mcl_iterate_resilient(a, grid, cfg, rc)
            wall = (time.perf_counter() - t0) * 1e3
        saves = max(len(hist), 1)
        rows.append(dict(
            op="checkpoint", variant=variant, wall_ms=wall,
            overhead_ms_per_iter=max(wall - base_ms, 0.0) / saves,
            bytes_per_save=rep.checkpoint_bytes // saves,
            checkpoint_stalls=rep.checkpoint_stalls,
            checkpoint_stall_ms=rep.checkpoint_stall_s * 1e3,
            iters=len(hist),
        ))
    return rows


def run(n: int = 64) -> None:
    if len(jax.devices()) < 8:
        emit("fig3/skipped", 0, "needs 8 host devices")
        return
    for row in run_mcl_suite(n=n, max_iters=4):
        if row["op"] == "mcl_e2e":
            emit(f"fig3/mcl_{row['variant']}", row["wall_ms"] * 1e3,
                 f"iters={row['iters']} host_bytes={row['host_bytes']}")
        elif row["op"] == "summary":
            emit("fig3/mcl_summary", row["wall_ms"] * 1e3,
                 f"speedup={row['speedup_device_vs_host']:.2f} "
                 f"transfer_red={row['host_transfer_reduction']:.0f}x")
