"""Paper Fig. 3 — HipMCL iterations with batched SpGEMM.

Runs the first MCL iterations on a protein-similarity-like block matrix with
a tight memory budget (forces b > 1) and an unconstrained budget (b = 1),
reporting per-iteration time and the batch counts — the end-to-end
application integration the paper demonstrates on Isolates-small.
"""
import time

import numpy as np

import jax

from repro.core import gen
from repro.core.grid import make_grid
from repro.sparse_apps.mcl import MCLConfig, _col_normalize_np, mcl_iterate
from repro.core.sparse import from_numpy_coo

from .common import emit


def run(n: int = 64) -> None:
    if len(jax.devices()) < 8:
        emit("fig3/skipped", 0, "needs 8 host devices")
        return
    grid = make_grid(2, 2, 2)
    a = gen.protein_similarity_like(n, blocks=4, intra_p=0.5, seed=11)
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    vals = _col_normalize_np(rows, cols,
                             np.asarray(a.vals[:nnz]).astype(np.float64), n)
    a = from_numpy_coo(rows, cols, vals.astype(np.float32), (n, n), cap=nnz)

    # probe the symbolic plan to pick a budget that actually forces b > 1
    from repro.core.batched import plan_batches
    from repro.core.distsparse import scatter_to_grid

    probe = plan_batches(
        scatter_to_grid(a, grid, "A"), scatter_to_grid(a, grid, "B"), grid,
        per_process_memory=1 << 30,
    )
    r = 12
    tight = r * max(probe.max_unmerged_nnz // 3, 1) + (1 << 14)
    for label, mem in (("batched", tight), ("unconstrained", 1 << 30)):
        t0 = time.perf_counter()
        final, hist = mcl_iterate(
            a, grid,
            MCLConfig(max_iters=4, per_process_memory=mem),
        )
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"fig3/mcl_{label}", dt,
             f"iters={len(hist)} b_first={hist[0]['batches']} "
             f"nnz_final={hist[-1]['nnz']}")
