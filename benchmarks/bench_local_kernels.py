"""Paper Table VII / Fig. 15 — local multiply + merge kernel comparison.

The paper compares 'previous' (sorted heap) against 'now' (sort-free hash).
Our TPU adaptation compares, per hot-path op:

  * ESC coalesce — legacy two-key ``lexsort`` vs the packed-key engine
    (single-key sort, and the sort-free bucket scan where the key space
    allows it; see ``repro.core.sortkeys``).
  * Merge-Fiber — unsorted lexsort-merge vs packed engines vs the segmented
    k-way merge that exploits already-sorted fiber pieces (merge, don't
    re-sort).
  * Paired SpGEMM — O(capA×capB) pairing grid vs the k-binned grid
    (``repro.kernels.spgemm_binned``), with the pairing-work counts that the
    symbolic bin plan bounds.

CPU wall times are NOT TPU predictions; the comparison shape (relative cost
of keeping intermediates sorted / pairing everything against everything vs
the binned + packed-key engines) is the reproduced claim. ``run_local_suite``
emits machine-readable rows for BENCH_local_kernels.json (op, variant,
wall_ms, achieved gflops) so the perf trajectory is tracked PR over PR.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import gen
from repro.core import local_spgemm as lsp
from repro.core import semiring as sr
from repro.core import sparse as sp
from repro.core import symbolic as sym
from repro.kernels import ops
from repro.kernels.spgemm_binned import pairing_counts

from .common import emit, time_jit


def _note(rows_out, **row):
    """Collect a JSON row when a collector is supplied (CSV-only runs pass
    ``None`` and keep just the emit() side effects)."""
    if rows_out is not None:
        rows_out.append(row)


def _expanded_workload(n, flops_cap, seed=0, valid_p=0.85):
    """An ESC-expansion-shaped entry list: flops_cap slots, duplicate-heavy
    coordinates over an (n, n) tile, a tail of invalid slots."""
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.integers(0, n, flops_cap).astype(np.int32))
    cols = jnp.asarray(rng.integers(0, n, flops_cap).astype(np.int32))
    valid = jnp.asarray(rng.random(flops_cap) < valid_p)
    vals = jnp.asarray((rng.random(flops_cap) + 0.1).astype(np.float32))
    x = sp.SparseCOO(rows, cols, vals, jnp.int32(flops_cap), (n, n))
    return x, valid


def bench_coalesce(rows_out=None, n=512, flops_cap=1 << 17, out_cap=1 << 16):
    """ESC coalesce micro-benchmark: the acceptance comparison (packed vs
    lexsort) plus the individual engines."""
    x, valid = _expanded_workload(n, flops_cap)
    times = {}
    for eng in ("lexsort", "packed", "bucket", "auto"):
        fn = jax.jit(
            lambda xx, vv, e=eng: lsp._coalesce_semiring(
                xx, vv, out_cap, sr.PLUS_TIMES, engine=e
            )[0].vals
        )
        times[eng] = time_jit(fn, x, valid)
        _note(rows_out, **dict(
            op="esc_coalesce", variant=eng, wall_ms=times[eng] / 1e3,
            gflops=flops_cap / times[eng] / 1e3,  # one reduce op per slot
            entries=flops_cap,
        ))
        emit(f"tableVII/esc_coalesce_{eng}", times[eng], f"n={n}")
    speed = times["lexsort"] / max(times["auto"], 1)
    _note(rows_out, **dict(
        op="esc_coalesce", variant="speedup_packed_vs_lexsort",
        wall_ms=0.0, gflops=0.0, speedup=speed,
    ))
    emit("tableVII/esc_coalesce_speedup", 0.0, f"{speed:.2f}x")
    return speed


def bench_merge(rows_out=None, n=512, layers=4, part_cap=1 << 14, out_cap=1 << 16):
    """Merge-Fiber micro-benchmark: engines + the segmented sorted merge."""
    parts = [
        gen.erdos_renyi(n, part_cap / n, seed=10 + i, cap=part_cap).sort_rowmajor()
        for i in range(layers)
    ]
    total = layers * part_cap
    times = {}
    cases = {
        "lexsort": dict(engine="lexsort"),
        "packed": dict(engine="packed"),
        "bucket": dict(engine="bucket"),
        "auto": dict(engine="auto"),
        "segmented_sorted": dict(assume_sorted=True),
    }
    for name, kwargs in cases.items():
        fn = jax.jit(
            lambda *ps, kw=kwargs: lsp.merge_sparse(
                list(ps), out_cap, sr.PLUS_TIMES, **kw
            )[0].vals
        )
        times[name] = time_jit(fn, *parts)
        _note(rows_out, **dict(
            op="merge_fiber", variant=name, wall_ms=times[name] / 1e3,
            gflops=total / times[name] / 1e3, entries=total, layers=layers,
        ))
        emit(f"tableVII/merge_fiber_{name}", times[name], f"l={layers}")
    speed = times["lexsort"] / max(times["auto"], 1)
    _note(rows_out, **dict(
        op="merge_fiber", variant="speedup_packed_vs_lexsort",
        wall_ms=0.0, gflops=0.0, speedup=speed,
    ))
    emit("tableVII/merge_fiber_speedup", 0.0, f"{speed:.2f}x")
    return speed


def bench_hash_vs_esc(rows_out=None, n=256, nnz_per_row=16):
    """Local multiply: ESC expansion vs hash accumulator on a dense-ish
    (high compression factor) workload — the regime where the table's
    O(nnz(C)·load-factor) scratch beats the O(flops) expansion."""
    a = gen.erdos_renyi(n, nnz_per_row, seed=5)
    b = gen.erdos_renyi(n, nnz_per_row, seed=6)
    flops = int(np.asarray(a.col_counts(), np.int64)
                @ np.asarray(b.row_counts(), np.int64))
    flops_cap = sym.rup8(flops)
    out_cap = 1 << 16
    c_probe, ovf = jax.jit(
        lambda x, y: lsp.spgemm_esc(x, y, out_cap, flops_cap)
    )(a, b)
    nnz_out = int(c_probe.nnz)
    assert int(ovf) == 0, int(ovf)
    cf = flops / max(nnz_out, 1)
    table_cap = sym.rup_pow2(max(int(nnz_out * sym.HASH_LOAD_FACTOR), 64))
    chunk_cap = 4096
    num_chunks = -(-flops_cap // chunk_cap)

    t_esc = time_jit(
        jax.jit(lambda x, y: lsp.spgemm_esc(x, y, out_cap, flops_cap)[0].vals),
        a, b,
    )
    t_hash = time_jit(
        jax.jit(lambda x, y: lsp.spgemm_hash(
            x, y, out_cap, table_cap, chunk_cap, num_chunks)[0].vals),
        a, b,
    )
    # resident scratch: the expansion's 3 arrays vs the table's 2
    scratch_esc = flops_cap * 12
    scratch_hash = table_cap * sym.HASH_SLOT_BYTES
    _note(rows_out, **dict(
        op="local_multiply", variant="esc", wall_ms=t_esc / 1e3,
        gflops=2 * flops / t_esc / 1e3, flops=flops, nnz_out=nnz_out,
        compression_factor=cf, scratch_bytes=scratch_esc,
    ))
    _note(rows_out, **dict(
        op="local_multiply", variant="hash", wall_ms=t_hash / 1e3,
        gflops=2 * flops / t_hash / 1e3, flops=flops, nnz_out=nnz_out,
        compression_factor=cf, scratch_bytes=scratch_hash,
        table_cap=table_cap,
    ))
    emit("tableVII/local_multiply_esc", t_esc, f"cf={cf:.2f}")
    emit("tableVII/local_multiply_hash", t_hash,
         f"cf={cf:.2f} scratch {scratch_hash}/{scratch_esc}B")
    return scratch_esc / max(scratch_hash, 1)


def bench_binned_pairing(rows_out=None, scale=7, edge_factor=8):
    """Paired SpGEMM: unbinned O(capA×capB) vs the k-binned plan on a
    skewed-k (R-MAT) workload — the regime binning targets."""
    a = gen.rmat(scale=scale, edge_factor=edge_factor, seed=3)
    b = gen.rmat(scale=scale, edge_factor=edge_factor, seed=4)
    plan = sym.plan_k_bins(
        np.asarray(a.col_counts()), np.asarray(b.row_counts()), a.cap, b.cap
    )
    pc = pairing_counts(a.cap, b.cap, plan.num_bins, plan.bin_cap_a,
                        plan.bin_cap_b)
    t_full = time_jit(lambda x, y: ops.spgemm_paired(x, y), a, b)
    bm = jnp.asarray(plan.bin_of_k)
    t_bin = time_jit(
        lambda x, y, z: ops.spgemm_paired_binned(
            x, y, plan.num_bins, plan.bin_cap_a, plan.bin_cap_b, bin_map=z
        )[0],
        a, b, bm,
    )
    _note(rows_out, **dict(
        op="paired_spgemm", variant="unbinned", wall_ms=t_full / 1e3,
        gflops=2 * pc["pairings_unbinned"] / t_full / 1e3,
        pairings=pc["pairings_unbinned"],
    ))
    _note(rows_out, **dict(
        op="paired_spgemm", variant="binned", wall_ms=t_bin / 1e3,
        gflops=2 * pc["pairings_binned"] / t_bin / 1e3,
        pairings=pc["pairings_binned"], num_bins=plan.num_bins,
        pairing_reduction=pc["reduction"],
    ))
    emit("tableVII/paired_unbinned", t_full,
         f"pairings={pc['pairings_unbinned']}")
    emit("tableVII/paired_binned", t_bin,
         f"pairings={pc['pairings_binned']} ({pc['reduction']:.1f}x fewer)")
    return pc["reduction"]


def run(n: int = 256, nnz_per_row: int = 8, layers: int = 4) -> None:
    """CSV suite (paper Table VII shape) — kept for ``benchmarks.run`` all."""
    a = gen.erdos_renyi(n, nnz_per_row, seed=1)
    b = gen.erdos_renyi(n, nnz_per_row, seed=2)
    flops_cap = 1 << 17
    out_cap = 1 << 16

    # --- local multiply: ESC (sort-free) vs dense-accumulator
    esc = jax.jit(lambda x, y: lsp.spgemm_esc(x, y, out_cap, flops_cap)[0].vals)
    t_esc = time_jit(esc, a, b)
    emit("tableVII/local_multiply_esc_sortfree", t_esc, f"n={n}")

    acc = jax.jit(lambda x, y: lsp.spgemm_dense_acc(x, y))
    t_acc = time_jit(acc, a, b)
    emit("tableVII/local_multiply_dense_acc", t_acc, f"n={n}")

    # --- merge: sorted-maintained baseline vs sort-free hash-merge
    parts = [gen.erdos_renyi(n, nnz_per_row, seed=10 + i) for i in range(layers)]

    def merge_sorted_baseline(ps):
        # 'heap-like': sort every input first, then pairwise coalesce —
        # sortedness maintained at every step (the paper's 'previous')
        cur = ps[0].sort_rowmajor()
        for nxt in ps[1:]:
            stacked, _ = sp.concat([cur, nxt.sort_rowmajor()], new_cap=out_cap)
            cur, _ = sp.coalesce(stacked, new_cap=out_cap)
        return cur.vals

    def merge_sortfree(ps):
        m, _ = lsp.merge_sparse(ps, out_cap)
        return m.vals

    t_sorted = time_jit(jax.jit(merge_sorted_baseline), parts)
    t_free = time_jit(jax.jit(merge_sortfree), parts)
    emit("tableVII/merge_sorted_baseline", t_sorted, f"l={layers}")
    emit("tableVII/merge_sortfree", t_free,
         f"l={layers} speedup={t_sorted / max(t_free, 1):.2f}x")

    bench_coalesce()
    bench_merge()
    bench_binned_pairing()
    bench_hash_vs_esc()


def run_local_suite() -> list:
    """The ``--suite local`` entry: returns JSON-ready rows (op, variant,
    wall_ms, gflops, extras)."""
    rows = []
    coal = bench_coalesce(rows)
    merg = bench_merge(rows)
    red = bench_binned_pairing(rows)
    scratch = bench_hash_vs_esc(rows)
    rows.append(dict(
        op="summary", variant="acceptance",
        wall_ms=0.0, gflops=0.0,
        coalesce_speedup=coal, merge_speedup=merg, pairing_reduction=red,
        hash_scratch_reduction=scratch,
    ))
    return rows
