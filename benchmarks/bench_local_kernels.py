"""Paper Table VII / Fig. 15 — local multiply + merge kernel comparison.

The paper compares 'previous' (sorted heap) against 'now' (sort-free hash).
Our TPU adaptation compares:
  * sorted-merge baseline (coalesce on row-major-sorted inputs — plays the
    'heap/sorted' role: sortedness maintained throughout)
  * sort-free ESC (inputs unsorted; one sort at compress — the paper's
    observation, §IV-D)
  * dense-accumulator SpMM path (identity-hash accumulation — the paper's
    hash table, TPU-native)
CPU wall times are NOT TPU predictions; the comparison shape (relative cost
of keeping intermediates sorted vs sort-free) is the reproduced claim.
"""
import numpy as np

import jax.numpy as jnp

from repro.core import gen
from repro.core import local_spgemm as lsp
from repro.core import sparse as sp

from .common import emit, time_jit


def run(n: int = 256, nnz_per_row: int = 8, layers: int = 4) -> None:
    a = gen.erdos_renyi(n, nnz_per_row, seed=1)
    b = gen.erdos_renyi(n, nnz_per_row, seed=2)
    flops_cap = 1 << 17
    out_cap = 1 << 16

    import jax

    # --- local multiply: ESC (sort-free) vs dense-accumulator
    esc = jax.jit(lambda x, y: lsp.spgemm_esc(x, y, out_cap, flops_cap)[0].vals)
    t_esc = time_jit(esc, a, b)
    emit("tableVII/local_multiply_esc_sortfree", t_esc, f"n={n}")

    acc = jax.jit(lambda x, y: lsp.spgemm_dense_acc(x, y))
    t_acc = time_jit(acc, a, b)
    emit("tableVII/local_multiply_dense_acc", t_acc, f"n={n}")

    # --- merge: sorted-maintained baseline vs sort-free hash-merge
    parts = [gen.erdos_renyi(n, nnz_per_row, seed=10 + i) for i in range(layers)]

    def merge_sorted_baseline(ps):
        # 'heap-like': sort every input first, then pairwise coalesce —
        # sortedness maintained at every step (the paper's 'previous')
        cur = ps[0].sort_rowmajor()
        for nxt in ps[1:]:
            stacked, _ = sp.concat([cur, nxt.sort_rowmajor()], new_cap=out_cap)
            cur, _ = sp.coalesce(stacked, new_cap=out_cap)
        return cur.vals

    def merge_sortfree(ps):
        m, _ = lsp.merge_sparse(ps, out_cap)
        return m.vals

    t_sorted = time_jit(jax.jit(merge_sorted_baseline), parts)
    t_free = time_jit(jax.jit(merge_sortfree), parts)
    emit("tableVII/merge_sorted_baseline", t_sorted, f"l={layers}")
    emit("tableVII/merge_sortfree", t_free,
         f"l={layers} speedup={t_sorted / max(t_free, 1):.2f}x")
