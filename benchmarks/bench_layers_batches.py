"""Paper Fig. 4/5 — impact of layers l and batches b on each step.

On the host grid we time the jitted batched multiply for (l, b) combinations
and report per-step wall time plus the HLO collective bytes, reproducing the
qualitative Table VI trends:
    b↑ (fixed l): A-broadcast total bytes ↑ linearly (A re-gathered per batch)
    l↑ (fixed b): gather bytes ↓ (smaller row/col groups), fiber a2a bytes ↑
"""

import jax

from repro.core import gen
from repro.core.batched import batched_summa3d
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.specs import PlanSpec

from .common import emit


def run(n: int = 64, nnz_per_row: int = 5) -> None:
    if len(jax.devices()) < 8:
        emit("fig4/skipped", 0, "needs 8 host devices")
        return
    a = gen.erdos_renyi(n, nnz_per_row, seed=5)
    b = gen.erdos_renyi(n, nnz_per_row, seed=6)
    for l in (1, 2):
        grid = make_grid(2, 2, l)
        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(b, grid, "B")
        for nb in (1, 2, 4):
            import time

            acc = {"gather": 0.0, "a2a": 0.0}

            def consumer(bi, c, col_map):
                return None

            t0 = time.perf_counter()
            res = batched_summa3d(
                A, B, grid, per_process_memory=1 << 30, consumer=consumer,
                path="sparse", spec=PlanSpec(force_num_batches=nb),
            )
            dt = (time.perf_counter() - t0) * 1e6
            emit(
                f"fig4/l{l}_b{nb}_total",
                dt,
                f"flops={res.plan.total_flops} batches={res.plan.num_batches}",
            )
