"""Shared benchmark utilities: timing, CSV emission, host grids."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_jit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time (µs) of a jitted callable (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
