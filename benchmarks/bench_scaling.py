"""Paper Fig. 6/7/9 — strong scaling, projected with the α–β model.

Wall-clock scaling cannot be measured on one host, so we reproduce the
paper's scaling *structure*: for p ∈ {4k ... 262k} cores (mapped to chips),
combine
  * measured local-compute rates (from the jitted local kernels, scaled by
    per-process flops = flops/p), and
  * the Table II communication model with v5e α=1e-5 s, β=1/45 GB/s
to produce projected step times and parallel efficiency. The derived column
reports efficiency vs the paper's reported values (Metaclust50-like drops
to ~0.4 at 262k cores when comm dominates — Fig. 9).
"""
import numpy as np

from repro.core import symbolic as sym

from .common import emit

ALPHA = 1e-5  # s per message (ICI hop, conservative)
BETA = 1.0 / 45e9  # s per byte
R = 12


def projected_time(p: int, l: int, b: int, nnz_a: float, nnz_b: float,
                   flops: float, local_rate: float) -> float:
    """Paper Table II totals + compute/p at measured local rate."""
    pc = max(int(np.sqrt(p / l)), 1)
    stages = pc
    t_abcast = b * (ALPHA * stages * np.log2(max(p / l, 2))
                    + BETA * R * nnz_a / np.sqrt(p * l))
    t_bbcast = b * ALPHA * stages * np.log2(max(p / l, 2)) + BETA * R * nnz_b / np.sqrt(p * l)
    t_a2a = ALPHA * b * l + BETA * R * flops / p
    t_compute = flops / p / local_rate
    t_merge = (flops / p * np.log2(max(p / l, 2)) + flops / p * np.log2(max(l, 2))) / (
        local_rate * 4
    )  # merges run at ~4x multiply rate (sort-free, Table VII)
    return t_abcast + t_bbcast + t_a2a + t_compute + t_merge


def run() -> None:
    # Metaclust50-like and Isolates-like regimes (paper Table V ratios)
    workloads = {
        "isolates_like": dict(nnz_a=68e9, nnz_b=68e9, flops=301e12, mem_c=984e9 * R),
        "metaclust50_like": dict(nnz_a=37e9, nnz_b=37e9, flops=92e12, mem_c=1e12 * R),
    }
    local_rate = 50e6 * 16  # measured-class local multiply rate × threads/core-group
    l = 16
    for name, w in workloads.items():
        base_p, base_t = None, None
        for cores in (16_384, 65_536, 262_144):
            p = cores // 16  # 16 threads per process (paper setup)
            mem_total = cores / 68 * 112e9  # Cori-KNL GB/node × nodes
            try:
                b = sym.batch_count_lower_bound(
                    int(w["flops"] * R), int(mem_total), int(w["nnz_a"]),
                    int(w["nnz_b"]), r=R,
                )
            except MemoryError:
                emit(f"fig7/{name}_p{cores}", 0, "OOM at this scale")
                continue
            t = projected_time(p, l, b, w["nnz_a"], w["nnz_b"], w["flops"],
                               local_rate)
            if base_p is None:
                base_p, base_t = cores, t
                eff = 1.0
            else:
                eff = (base_t / t) * (base_p / cores)
            emit(f"fig7/{name}_p{cores}", t * 1e6,
                 f"b={b} efficiency={eff:.2f}")
