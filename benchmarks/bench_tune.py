"""Cost-model calibration + autotuner acceptance suite (BENCH_tune.json).

Two claims, both host math (no devices, no timed multiplies):

  * model rows — for every PIPELINED driver row of the checked-in
    ``BENCH_summa3d.json``, the analytical cost model's prediction for the
    exact same workload (R-MAT seeds, grid, forced batch count — replanned
    through the host symbolic oracle) divided by the measured wall-ms lands
    inside the fixed ``ACCEPT_BAND`` after the single-scalar overhead fit.
    The ratio per row is the artifact later PRs assert against: the model
    stays calibrated as the kernels evolve or it fails the schema check.
  * autotune rows — across memory budgets, the tuner's pick is NEVER priced
    worse than the untouched defaults (the default config is in its
    candidate set), and on the constrained R-MAT skew budget it picks a
    config with strictly fewer transfer bytes or batches than the fixed
    heuristics (it drops the fiber exchange by choosing fewer layers).

``--smoke`` shrinks the budget sweep, same rows/schema.
"""
import json
import pathlib
import time

from repro.tune import (
    ACCEPT_BAND,
    autotune,
    fit_overhead,
    predict_cost,
)

from .common import emit

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

# the exact workload run_summa3d_suite times, replanned via the host oracle
BENCH_SCALE, BENCH_EF, BENCH_NB = 8, 8, 32
BENCH_GRID = (2, 2, 2)
BENCH_PPM = 1 << 30
PIPELINED_VARIANTS = {
    "pipelined": "auto",
    "pipelined_esc": "esc",
    "pipelined_binned": "binned",
    "pipelined_hash": "hash",
}

SKEW_BUDGET = 80_000  # forces batching; layer choice moves real bytes


def _bench_pair():
    from repro.core import gen

    return (gen.rmat(scale=BENCH_SCALE, edge_factor=BENCH_EF, seed=3),
            gen.rmat(scale=BENCH_SCALE, edge_factor=BENCH_EF, seed=4))


def _model_rows(a, b) -> list:
    """Predicted-vs-measured ratio per checked-in pipelined driver row."""
    from repro.core.batched import PlanInputs, plan_from_symbolic
    from repro.core.specs import PlanFloors, PlanSpec
    from repro.core.symbolic import host_symbolic_counts

    artifact = REPO_ROOT / "BENCH_summa3d.json"
    if not artifact.exists():
        raise FileNotFoundError(
            f"{artifact} not found — run `benchmarks.run --suite summa3d` "
            f"first (the tune suite calibrates against its driver rows)"
        )
    measured = {
        r["variant"]: r["wall_ms"]
        for r in json.loads(artifact.read_text())["rows"]
        if r.get("op") == "driver_e2e" and r["variant"] in PIPELINED_VARIANTS
    }
    counts = host_symbolic_counts(a, b, BENCH_GRID)
    inputs = PlanInputs.from_host(a, b, BENCH_GRID)
    raw = {}
    for variant, path in PIPELINED_VARIANTS.items():
        plan = plan_from_symbolic(
            counts, inputs, BENCH_PPM,
            PlanSpec(local_path=path, force_num_batches=BENCH_NB),
            PlanFloors(),
        )
        raw[variant] = predict_cost(plan, BENCH_GRID, inputs.nnz_a,
                                    inputs.nnz_b)
    coeffs = fit_overhead(
        [(raw[v].total_ms, measured[v]) for v in measured]
    )
    lo, hi = ACCEPT_BAND
    rows = []
    all_ok = True
    for variant in PIPELINED_VARIANTS:
        pred = coeffs.overhead * raw[variant].total_ms
        ratio = pred / measured[variant]
        ok = lo <= ratio <= hi
        all_ok = all_ok and ok
        rows.append(dict(
            op="model", variant=variant, wall_ms=measured[variant],
            raw_predicted_ms=raw[variant].total_ms, predicted_ms=pred,
            ratio=ratio, band_lo=lo, band_hi=hi, within_band=ok,
            num_batches=raw[variant].num_batches, path=raw[variant].path,
        ))
        emit(f"tune/model_{variant}", 0.0, f"ratio={ratio:.2f}")
    rows.append(dict(
        op="summary", variant="model_acceptance", wall_ms=0.0,
        overhead=coeffs.overhead, all_within_band=all_ok,
        band_lo=lo, band_hi=hi,
    ))
    emit("tune/model_acceptance", 0.0,
         f"overhead={coeffs.overhead:.2f} all_within_band={all_ok}")
    return rows


def _autotune_row(a, b, budget, variant) -> dict:
    t0 = time.perf_counter()
    t = autotune(a, b, budget, num_devices=8)
    wall = (time.perf_counter() - t0) * 1e3
    never_worse = t.predicted.total_ms <= t.baseline_predicted.total_ms
    row = dict(
        op="autotune", variant=variant, wall_ms=wall, budget=budget,
        tuned_grid=list(t.grid_shape), tuned_path=t.spec.local_path,
        tuned_batches=t.num_batches,
        tuned_pred_ms=round(t.predicted.total_ms, 3),
        tuned_comm_bytes=t.predicted.comm_bytes,
        base_grid=list(t.baseline_grid_shape),
        base_batches=t.baseline_num_batches,
        base_pred_ms=round(t.baseline_predicted.total_ms, 3),
        base_comm_bytes=t.baseline_predicted.comm_bytes,
        never_worse=never_worse,
    )
    emit(f"tune/{variant}", wall * 1e3,
         f"grid={t.grid_shape} path={t.spec.local_path} "
         f"b={t.num_batches} vs default b={t.baseline_num_batches}")
    return row


def run_tune_suite(smoke: bool = False) -> list:
    """The ``--suite tune`` entry: returns JSON-ready rows."""
    a, b = _bench_pair()
    rows = _model_rows(a, b)

    budgets = ((200_000, 40_000) if smoke
               else (1 << 30, 200_000, 120_000, 80_000, 40_000))
    never_worse_all = True
    for budget in budgets:
        row = _autotune_row(a, b, budget, f"budget_{budget}")
        never_worse_all = never_worse_all and row["never_worse"]
        rows.append(row)

    # the R-MAT skew acceptance row: constrained budget, tuned must beat the
    # fixed heuristics on a MEASURABLE axis (bytes or batches), not just ms
    skew = _autotune_row(a, b, SKEW_BUDGET, "skew")
    skew["cheaper_comm_bytes"] = (
        skew["tuned_comm_bytes"] < skew["base_comm_bytes"])
    skew["cheaper_batches"] = skew["tuned_batches"] < skew["base_batches"]
    skew_cheaper = skew["cheaper_comm_bytes"] or skew["cheaper_batches"]
    never_worse_all = never_worse_all and skew["never_worse"]
    rows.append(skew)

    rows.append(dict(
        op="summary", variant="autotune_acceptance", wall_ms=0.0,
        never_worse_all=never_worse_all, skew_cheaper=skew_cheaper,
        skew_budget=SKEW_BUDGET,
    ))
    emit("tune/autotune_acceptance", 0.0,
         f"never_worse_all={never_worse_all} skew_cheaper={skew_cheaper}")
    return rows


def run() -> None:
    run_tune_suite(smoke=True)
