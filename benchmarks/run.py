import os

# The paper's figures measure COLLECTIVES (broadcast/fiber-a2a volumes), so
# this entrypoint provisions 8 host devices for itself — deliberately scoped
# here, not in conftest/pyproject (tests must keep seeing 1 device).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.
"""
import sys


def main() -> None:
    from . import (
        bench_comm_model,
        bench_layers_batches,
        bench_local_kernels,
        bench_mcl,
        bench_roofline,
        bench_scaling,
        bench_symbolic,
    )

    print("name,us_per_call,derived")
    bench_local_kernels.run()   # Table VII / Fig. 15
    bench_comm_model.run()      # Table II
    bench_layers_batches.run()  # Fig. 4/5 (+ Table VI trends)
    bench_symbolic.run()        # Fig. 8
    bench_scaling.run()         # Fig. 6/7/9 (alpha-beta projection)
    bench_mcl.run()             # Fig. 3 (HipMCL end-to-end)
    bench_roofline.run()        # EXPERIMENTS.md section Roofline feed


if __name__ == "__main__":
    main()
