import os

# The paper's figures measure COLLECTIVES (broadcast/fiber-a2a volumes), so
# this entrypoint provisions 8 host devices for itself — deliberately scoped
# here, not in conftest/pyproject (tests must keep seeing 1 device).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one module per paper table/figure.

``--suite all`` (default) prints ``name,us_per_call,derived`` CSV across every
table/figure module. ``--suite local`` runs the local-kernel hot-path suite
(packed-key sort engine + k-binned pairing) and writes
``BENCH_local_kernels.json`` at the repo root — op, variant, wall-ms, achieved
GFLOP/s per row — so the perf trajectory is tracked from PR to PR.
``--suite summa3d`` runs the end-to-end batched driver suite (pipelined vs
serial schedule, binned vs ESC vs hash-accumulator local multiply, plus the
fixed-memory hash-vs-ESC batch-count row) and writes ``BENCH_summa3d.json``,
refreshing ``BENCH_local_kernels.json`` in the same run so both perf files
stay in lockstep; ``--smoke`` shrinks it to CI-sized shapes with the same
row schema. ``--suite mcl`` runs the
device-resident vs host-loop MCL comparison (per-iteration wall-ms and
host-transfer bytes) and writes ``BENCH_mcl.json``. ``--suite graph`` runs
the §V-B masked-SpGEMM workloads (masked vs unmasked triangle counting on
R-MAT, on-grid vs host-filtered overlap detection) and writes
``BENCH_graph.json``. ``--suite serve`` runs the plan-cached serving-engine
suite (open-loop mixed repeat/novel traffic: p50/p99 latency,
multiplies/sec, plan-cache hit rate, zero-retrace repeat probe) and writes
``BENCH_serve.json``; ``--smoke`` shrinks it to CI size. ``--suite tune``
runs the cost-model calibration + autotuner acceptance suite (predicted /
measured ratio per checked-in summa3d pipelined row, never-worse-than-default
and R-MAT-skew autotuner rows — pure host math) and writes
``BENCH_tune.json``. Every BENCH_*.json artifact validates against the
shared row schema via ``python -m benchmarks.check_bench_json`` (enforced
in CI).
"""
import argparse
import json
import pathlib
import platform
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_all() -> None:
    from . import (
        bench_comm_model,
        bench_graph,
        bench_layers_batches,
        bench_local_kernels,
        bench_mcl,
        bench_roofline,
        bench_scaling,
        bench_serve,
        bench_summa3d,
        bench_symbolic,
    )

    print("name,us_per_call,derived")
    bench_local_kernels.run()   # Table VII / Fig. 15
    bench_comm_model.run()      # Table II
    bench_layers_batches.run()  # Fig. 4/5 (+ Table VI trends)
    bench_summa3d.run()         # Alg. 4 pipelined driver
    bench_symbolic.run()        # Fig. 8
    bench_scaling.run()         # Fig. 6/7/9 (alpha-beta projection)
    bench_mcl.run()             # Fig. 3 (HipMCL end-to-end)
    bench_graph.run()           # §V-B masked graph workloads
    bench_serve.run()           # plan-cached serving engine
    bench_roofline.run()        # EXPERIMENTS.md section Roofline feed


def _write_suite(suite: str, rows_fn, json_path: pathlib.Path) -> None:
    """Shared single-suite runner: one payload schema for every artifact
    (``check_bench_json`` validates exactly this envelope)."""
    import jax

    print("name,us_per_call,derived")
    payload = {
        "suite": suite,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "rows": rows_fn(),
    }
    json_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {json_path}", file=sys.stderr)


def run_local(json_path: pathlib.Path) -> None:
    from . import bench_local_kernels

    _write_suite("local_kernels", bench_local_kernels.run_local_suite, json_path)


def run_summa3d(json_path: pathlib.Path, smoke: bool = False) -> None:
    from . import bench_summa3d

    if smoke:
        # CI-sized shapes: same rows/schema (check_bench_json validates the
        # full summa3d row set), minutes -> seconds
        _write_suite(
            "summa3d_driver",
            lambda: bench_summa3d.run_summa3d_suite(
                scale=6, edge_factor=6, nb=4, iters=1
            ),
            json_path,
        )
        return
    _write_suite("summa3d_driver", bench_summa3d.run_summa3d_suite, json_path)
    # keep the local-kernel numbers in lockstep with the driver numbers
    run_local(REPO_ROOT / "BENCH_local_kernels.json")


def run_mcl(json_path: pathlib.Path) -> None:
    from . import bench_mcl

    _write_suite("mcl_pipeline", bench_mcl.run_mcl_suite, json_path)


def run_graph(json_path: pathlib.Path) -> None:
    from . import bench_graph

    _write_suite("graph_masked", bench_graph.run_graph_suite, json_path)


def run_tune(json_path: pathlib.Path, smoke: bool = False) -> None:
    from . import bench_tune

    _write_suite(
        "tune",
        lambda: bench_tune.run_tune_suite(smoke=smoke),
        json_path,
    )


def run_serve(json_path: pathlib.Path, smoke: bool = False) -> None:
    from . import bench_serve

    _write_suite(
        "serve_engine",
        lambda: bench_serve.run_serve_suite(smoke=smoke),
        json_path,
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--suite",
        choices=("all", "local", "summa3d", "mcl", "graph", "serve", "tune"),
        default="all",
    )
    ap.add_argument(
        "--json-out",
        default=None,
        help="output path for the single-suite modes",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized shapes (summa3d/serve suites): same rows, tiny scale",
    )
    args = ap.parse_args()
    if args.suite == "local":
        run_local(pathlib.Path(
            args.json_out or REPO_ROOT / "BENCH_local_kernels.json"
        ))
    elif args.suite == "summa3d":
        run_summa3d(pathlib.Path(
            args.json_out or REPO_ROOT / "BENCH_summa3d.json"
        ), smoke=args.smoke)
    elif args.suite == "mcl":
        run_mcl(pathlib.Path(args.json_out or REPO_ROOT / "BENCH_mcl.json"))
    elif args.suite == "graph":
        run_graph(pathlib.Path(
            args.json_out or REPO_ROOT / "BENCH_graph.json"
        ))
    elif args.suite == "serve":
        run_serve(pathlib.Path(
            args.json_out or REPO_ROOT / "BENCH_serve.json"
        ), smoke=args.smoke)
    elif args.suite == "tune":
        run_tune(pathlib.Path(
            args.json_out or REPO_ROOT / "BENCH_tune.json"
        ), smoke=args.smoke)
    else:
        run_all()


if __name__ == "__main__":
    main()
