"""Validate the checked-in BENCH_*.json artifacts against the shared schema.

Every perf suite (``benchmarks.run --suite local|summa3d|mcl``) writes a JSON
payload with the same envelope, so stale or hand-edited artifacts are caught
mechanically (a CI step runs this after the bench smoke):

    top level: {"suite": str, "backend": str, "platform": str, "rows": [...]}
    every row: {"op": str, "variant": str, "wall_ms": int|float, ...}

Usage::

    python -m benchmarks.check_bench_json [paths...]

With no arguments, validates every BENCH_*.json at the repo root. Exits
nonzero (listing every violation) if any artifact is malformed.
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

TOP_KEYS = ("suite", "backend", "platform", "rows")
ROW_KEYS = ("op", "variant", "wall_ms")

# Per-suite required (op, variant) -> extra row keys. Suites grow rows over
# time; the pairs here are the acceptance artifacts later PRs assert against,
# so dropping one is a schema error, not a silent regression.
SUITE_ROWS = {
    "summa3d_driver": {
        ("plan", "fixed_mem_batches"): (
            "num_batches_esc", "num_batches_hash", "per_process_memory",
            "compression_factor",
        ),
        ("driver_e2e", "pipelined_hash"): (),
        ("summary", "acceptance"): (
            "num_batches_esc", "num_batches_hash", "hash_batches_fewer",
            "local_path_used",
        ),
    },
    "local_kernels": {
        ("local_multiply", "esc"): ("compression_factor", "scratch_bytes"),
        ("local_multiply", "hash"): ("compression_factor", "scratch_bytes"),
        ("summary", "acceptance"): ("hash_scratch_reduction",),
    },
    "mcl_pipeline": {
        ("mcl_e2e", "device"): ("iters", "host_bytes"),
        ("mcl_e2e", "host"): ("iters", "host_bytes"),
        ("summary", "device_vs_host"): (
            "speedup_device_vs_host", "host_transfer_reduction",
        ),
        # durability lane: per-iteration checkpoint overhead, async vs sync
        ("checkpoint", "async"): (
            "overhead_ms_per_iter", "bytes_per_save", "checkpoint_stalls",
        ),
        ("checkpoint", "sync"): (
            "overhead_ms_per_iter", "bytes_per_save", "checkpoint_stalls",
        ),
    },
    "tune": {
        # one calibration row per pipelined summa3d variant; the summaries
        # carry the two autotuner acceptance criteria
        ("model", "pipelined"): ("ratio", "within_band"),
        ("model", "pipelined_esc"): ("ratio", "within_band"),
        ("model", "pipelined_binned"): ("ratio", "within_band"),
        ("model", "pipelined_hash"): ("ratio", "within_band"),
        ("summary", "model_acceptance"): ("overhead", "all_within_band"),
        ("autotune", "skew"): (
            "never_worse", "cheaper_comm_bytes", "cheaper_batches",
        ),
        ("summary", "autotune_acceptance"): (
            "never_worse_all", "skew_cheaper",
        ),
    },
    "graph_masked": {
        ("summary", "masked_vs_unmasked"): (
            "batches_masked", "batches_unmasked", "d_cap_masked",
            "d_cap_unmasked", "masked_below_unmasked",
        ),
        # structure-aware placement acceptance: degree-spread must plan
        # strictly fewer capacity-padded transfer bytes than block-cyclic
        ("placement", "block_cyclic"): (
            "batches", "sel_cap", "piece_cap", "all_to_all_bytes",
            "gather_bytes", "padded_bytes",
        ),
        ("placement", "degree"): (
            "batches", "sel_cap", "piece_cap", "all_to_all_bytes",
            "gather_bytes", "padded_bytes",
        ),
        ("summary", "placement_volume"): (
            "batches_block_cyclic", "batches_degree",
            "padded_bytes_block_cyclic", "padded_bytes_degree",
            "volume_reduction", "degree_below_block_cyclic",
        ),
    },
    "serve_engine": {
        ("serve_e2e", "open_loop"): (
            "p50_ms", "p99_ms", "multiplies_per_s", "requests",
        ),
        ("plan_cache", "hit_rate"): ("hit_rate", "hits", "misses"),
        ("summary", "acceptance"): (
            "plan_cache_hit_rate", "retraces_repeat", "p50_ms", "p99_ms",
        ),
    },
}


def check_payload(payload: object, name: str = "<payload>") -> list:
    """Schema errors for one parsed artifact (empty list = valid)."""
    errors = []
    if not isinstance(payload, dict):
        return [f"{name}: top level must be an object, got {type(payload).__name__}"]
    for key in TOP_KEYS:
        if key not in payload:
            errors.append(f"{name}: missing top-level key '{key}'")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{name}: 'rows' must be a non-empty list")
        return errors
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{name}: rows[{i}] is not an object")
            continue
        for key in ROW_KEYS:
            if key not in row:
                errors.append(f"{name}: rows[{i}] missing '{key}' (op={row.get('op')!r})")
        wall = row.get("wall_ms")
        if wall is not None and not isinstance(wall, (int, float)):
            errors.append(f"{name}: rows[{i}].wall_ms not a number: {wall!r}")
        elif isinstance(wall, (int, float)) and wall < 0:
            errors.append(f"{name}: rows[{i}].wall_ms negative: {wall!r}")
    by_key = {
        (row.get("op"), row.get("variant")): row
        for row in rows if isinstance(row, dict)
    }
    for (op, variant), extras in SUITE_ROWS.get(payload.get("suite"), {}).items():
        row = by_key.get((op, variant))
        if row is None:
            errors.append(f"{name}: missing required row op={op!r} variant={variant!r}")
            continue
        for key in extras:
            if key not in row:
                errors.append(
                    f"{name}: row op={op!r} variant={variant!r} missing '{key}'"
                )
    return errors


def check_file(path: pathlib.Path) -> list:
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable/unparsable ({e})"]
    return check_payload(payload, path.name)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = [pathlib.Path(p) for p in argv] or sorted(
        REPO_ROOT.glob("BENCH_*.json")
    )
    if not paths:
        print("no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    errors = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"ok: {len(paths)} artifact(s) valid "
              f"({', '.join(p.name for p in paths)})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
