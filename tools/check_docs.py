"""Dependency-free markdown lint + link check for the repo docs.

Covers ``README.md``, ``ROADMAP.md``, ``CHANGES.md``, ``PAPER.md`` and
everything under ``docs/``. Checks, per file:

  * relative markdown links/images resolve to an existing file or directory
    (external http(s)/mailto links are NOT fetched — no network in CI);
  * intra-document anchors (``[x](#section)`` and ``[x](file.md#section)``)
    match a heading in the target file (GitHub slug rules: lowercase,
    punctuation stripped, spaces -> dashes);
  * fenced code blocks are balanced (every ``` opener has a closer);
  * no literal tab characters (the repo is space-indented, and tabs render
    inconsistently in markdown code spans).

Usage::

    python tools/check_docs.py [files...]

With no arguments, checks the default doc set. Exits nonzero listing every
violation — the CI docs lane runs exactly this.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

DEFAULT_DOCS = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md")

# [text](target) and ![alt](target); target may carry a #anchor
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip punctuation, lowercase, spaces->dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.lower().replace(" ", "-")


def _strip_fences(lines):
    """Lines outside fenced code blocks (links inside fences aren't links)."""
    out, in_fence, fence_tok = [], False, None
    for ln in lines:
        m = _FENCE_RE.match(ln.strip())
        if m:
            tok = m.group(1)
            if not in_fence:
                in_fence, fence_tok = True, tok
            elif tok == fence_tok:
                in_fence, fence_tok = False, None
            continue
        if not in_fence:
            out.append(ln)
    return out


def _headings(path: pathlib.Path):
    try:
        lines = path.read_text().splitlines()
    except (OSError, UnicodeDecodeError):
        return set()
    return {
        github_slug(m.group(2))
        for ln in _strip_fences(lines)
        if (m := _HEADING_RE.match(ln))
    }


def _display_path(path: pathlib.Path) -> str:
    """Repo-relative when inside the checkout, absolute otherwise (the CLI
    accepts arbitrary file arguments)."""
    try:
        return str(path.relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def check_file(path: pathlib.Path) -> list:
    errors = []
    rel = _display_path(path)
    try:
        text = path.read_text()
    except (OSError, UnicodeDecodeError) as e:
        return [f"{rel}: unreadable ({e})"]
    lines = text.splitlines()

    # fence balance: per-token open/close state (a ``` block may contain a
    # literal ~~~ line and vice versa — same walk as _strip_fences)
    open_tok = None
    for ln in lines:
        m = _FENCE_RE.match(ln.strip())
        if not m:
            continue
        tok = m.group(1)
        if open_tok is None:
            open_tok = tok
        elif tok == open_tok:
            open_tok = None
    if open_tok is not None:
        errors.append(
            f"{rel}: unbalanced fenced code block ({open_tok} left open)"
        )

    for i, ln in enumerate(lines, 1):
        if "\t" in ln:
            errors.append(f"{rel}:{i}: literal tab character")

    for m in _LINK_RE.finditer("\n".join(_strip_fences(lines))):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            dest = (path.parent / target).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {m.group(1)}")
                continue
        else:
            dest = path
        if frag is not None and dest.suffix == ".md":
            if github_slug(frag) not in _headings(dest):
                errors.append(f"{rel}: broken anchor -> {m.group(1)}")
    return errors


def default_paths():
    paths = [REPO_ROOT / name for name in DEFAULT_DOCS
             if (REPO_ROOT / name).exists()]
    docs_dir = REPO_ROOT / "docs"
    if docs_dir.is_dir():
        paths.extend(sorted(docs_dir.rglob("*.md")))
    return paths


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = [pathlib.Path(p).resolve() for p in argv] or default_paths()
    if not paths:
        print("no markdown docs found", file=sys.stderr)
        return 1
    errors = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print(f"DOCS ERROR: {e}", file=sys.stderr)
    if not errors:
        rels = ", ".join(_display_path(p) for p in paths)
        print(f"ok: {len(paths)} doc(s) clean ({rels})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
