"""End-to-end LM training driver (deliverable (b)): trains a ~100M-class
model for a few hundred steps with the full substrate — sharded train step,
AdamW+ZeRO, synthetic pipeline, async checkpointing, fault-tolerant loop.

Default runs a reduced-width model sized for this CPU container; pass
--full-100m for the 100M-parameter configuration (same code path, slower).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b --smoke
"""
import argparse
import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default=None, help="train a smoke config of an arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    import jax
    from repro.compat import AxisType, make_mesh, set_mesh

    from repro.configs import get_config
    from repro.data import DataConfig, synthetic_batch
    from repro.models import transformer as tfm
    from repro.models.transformer import ModelConfig
    from repro.optim import adamw
    from repro.runtime import RuntimeConfig, run_training
    from repro.train import TrainConfig, build_train_step

    ndev = len(jax.devices())
    mesh = make_mesh((max(ndev // 2, 1), min(2, ndev)), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

    if args.arch:
        cfg = get_config(args.arch, smoke=True)
    elif args.full_100m:
        cfg = ModelConfig(
            arch_id="lm-100m", n_layers=12, d_model=768, n_heads=12,
            kv_heads=12, head_dim=64, d_ff=3072, vocab=32000, act="swiglu",
            family="attn", dtype="float32",
        )
    else:  # 100M-class structure, reduced width for CPU throughput
        cfg = ModelConfig(
            arch_id="lm-mini", n_layers=4, d_model=256, n_heads=8,
            kv_heads=4, head_dim=32, d_ff=1024, vocab=4096, act="swiglu",
            family="attn", dtype="float32",
        )
    n_params = None

    tc = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=20))
    step_fn, shardings, _ = build_train_step(cfg, mesh, tc)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
                      input_mode=cfg.input_mode, d_model=cfg.d_model)

    def make_state():
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        nonlocal n_params
        from repro.models.common import param_count
        n_params = param_count(params)
        return {"params": params, "opt": adamw.init_opt_state(params)}

    def wrapped_step(state, batch):
        with set_mesh(mesh):
            p, o, m = step_fn(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="lm_ckpt_")
    rc = RuntimeConfig(ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 4, 10))
    res = run_training(
        steps=args.steps, make_state=make_state, step_fn=wrapped_step,
        batch_fn=lambda s: synthetic_batch(dcfg, s), rc=rc,
    )
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M steps={res.final_step}")
    k = max(args.steps // 10, 1)
    print(f"loss: first {np.mean(res.losses[:k]):.3f} -> last "
          f"{np.mean(res.losses[-k:]):.3f}")
    assert np.mean(res.losses[-k:]) < np.mean(res.losses[:k]), "loss must drop"
    print(f"checkpoints in {ckpt_dir}; OK")


if __name__ == "__main__":
    main()
