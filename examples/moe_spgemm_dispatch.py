"""The paper's technique inside the LM stack: MoE dispatch as SpGEMM.

Shows the token→expert dispatch matrix built as a core SparseCOO and pushed
through the SpMM kernel (the same gather/segment machinery the distributed
SpGEMM uses), compares against the direct scatter, and prints the routing
histogram — DESIGN.md §4's integration story, runnable.

Run:  PYTHONPATH=src python examples/moe_spgemm_dispatch.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")


def main() -> None:
    import dataclasses

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.compat import AxisType, make_mesh, set_mesh

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.models.moe import (
        MoEConfig,
        _capacity,
        _dispatch,
        _dispatch_indices,
        _route,
    )

    cfg = get_config("deepseek-moe-16b", smoke=True)
    mesh = make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,) * 2)

    # --- the dispatch matrix, explicitly
    mcfg = cfg.moe
    T, D = 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(0), (T, D))
    wg = jax.random.normal(jax.random.PRNGKey(1), (D, mcfg.n_experts)) * 0.1
    top_p, top_e, aux = _route(x, wg, mcfg)
    cap = _capacity(T, mcfg)
    eid, slot, keep = _dispatch_indices(top_e, mcfg, cap)
    print(f"{T} tokens -> {mcfg.n_experts} experts (top-{mcfg.top_k}), "
          f"capacity {cap}/expert")
    hist = np.bincount(np.asarray(eid), minlength=mcfg.n_experts)
    print(f"routing histogram: {hist.tolist()}")
    print(f"aux (load-balance) loss: {float(aux):.4f}")

    buf_spgemm = _dispatch(x, eid, slot, keep, mcfg, cap)
    mcfg_scatter = dataclasses.replace(mcfg, dispatch_mode="scatter")
    buf_scatter = _dispatch(x, eid, slot, keep, mcfg_scatter, cap)
    np.testing.assert_allclose(np.asarray(buf_spgemm), np.asarray(buf_scatter),
                               rtol=1e-5, atol=1e-5)
    print("SpGEMM dispatch == direct scatter ✓ "
          f"(buffers {buf_spgemm.shape}, dispatch matrix {mcfg.n_experts * cap}×{T})")

    # --- full model forward with EP over the "model" axis
    params = tfm.init_params(cfg, jax.random.PRNGKey(2))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (4, 16), 0, cfg.vocab)
    with set_mesh(mesh):
        logits, aux = tfm.forward(cfg, params, tokens, mesh)
    print(f"full MoE model forward on 2×2 mesh: logits {logits.shape}, "
          f"aux={float(aux):.4f} — OK")


if __name__ == "__main__":
    main()
