"""HipMCL-style protein clustering (paper §V-C, Fig. 3) — end-to-end.

Builds a synthetic protein-similarity network with planted families, runs
Markov clustering where every expansion A·A goes through BatchedSUMMA3D
under a tight memory budget (each batch pruned immediately), and reports the
recovered families.

Run:  PYTHONPATH=src python examples/protein_clustering.py [--n 96 --families 6]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--families", type=int, default=4)
    ap.add_argument("--memory", type=int, default=1 << 22,
                    help="per-process bytes (tight -> batching kicks in)")
    args = ap.parse_args()

    from repro.core import gen
    from repro.core.grid import make_grid
    from repro.core.sparse import from_numpy_coo
    from repro.sparse_apps.mcl import (
        MCLConfig,
        _col_normalize_np,
        clusters_from_matrix,
        mcl_iterate,
    )

    grid = make_grid(2, 2, 2)
    a = gen.protein_similarity_like(args.n, blocks=args.families, intra_p=0.6,
                                    seed=7)
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    vals = _col_normalize_np(
        rows, cols, np.asarray(a.vals[:nnz]).astype(np.float64), args.n
    )
    a = from_numpy_coo(rows, cols, vals.astype(np.float32), (args.n, args.n),
                       cap=nnz)
    print(f"input: {args.n} proteins, {nnz} similarities, "
          f"{args.families} planted families")

    final, hist = mcl_iterate(
        a, grid,
        MCLConfig(max_iters=15, per_process_memory=args.memory),
        verbose=True,
    )
    nnz = int(final.nnz)
    labels = clusters_from_matrix(
        np.asarray(final.rows[:nnz]), np.asarray(final.cols[:nnz]), args.n
    )
    found = len(set(labels.tolist()))
    print(f"converged in {len(hist)} iterations; clusters found: {found} "
          f"(planted: {args.families})")
    sizes = sorted(np.bincount(np.unique(labels, return_inverse=True)[1]).tolist(),
                   reverse=True)
    print(f"cluster sizes: {sizes[:10]}")


if __name__ == "__main__":
    main()
