"""Quickstart: memory-constrained distributed SpGEMM in ~40 lines.

Multiplies two random sparse matrices on a 2×2×2 grid (8 host devices),
letting the symbolic step pick the number of batches for a tight memory
budget, and verifies the batched result against the dense product.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import gen
from repro.core.batched import batched_summa3d, plan_batches
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.sparse_apps.mcl import _sparse_batch_to_global


def main() -> None:
    n = 64
    grid = make_grid(2, 2, 2)  # sqrt(p/l) × sqrt(p/l) × l, paper §III-B
    a = gen.erdos_renyi(n, avg_nnz_per_row=6, seed=1)
    b = gen.erdos_renyi(n, avg_nnz_per_row=6, seed=2)

    A = scatter_to_grid(a, grid, "A")  # Fig. 1 layer-split distributions
    B = scatter_to_grid(b, grid, "B")

    # symbolic step (Alg. 3): how many batches for this budget?
    budget = 3_000  # bytes per process — deliberately tight
    plan = plan_batches(A, B, grid, per_process_memory=budget)
    print(f"symbolic: flops={plan.total_flops} max_unmerged={plan.max_unmerged_nnz} "
          f"-> b={plan.num_batches} (Eq.2 lower bound {plan.lower_bound})")

    acc = np.zeros((n, n), np.float32)

    def consumer(bi, c_batch, col_map):
        rows, cols, vals = _sparse_batch_to_global(c_batch, col_map)
        print(f"  batch {bi}: {len(vals)} nonzeros produced, consumed, freed")
        np.add.at(acc, (rows, cols), vals)

    batched_summa3d(
        A, B, grid, per_process_memory=budget, consumer=consumer, path="sparse"
    )

    expect = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
    np.testing.assert_allclose(acc, expect, rtol=1e-4, atol=1e-5)
    print("OK — batched product matches the dense reference")


if __name__ == "__main__":
    main()
