"""BELLA-style sequence overlap via AA^T (paper §V-G, Fig. 10/11).

A (sequences × k-mers) indicator matrix is multiplied by its transpose in
batches; pairs sharing >= min_shared k-mers are candidate overlaps, emitted
per batch and discarded — the memory-constrained pattern the paper built for.

Run:  PYTHONPATH=src python examples/overlap_detection.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    import numpy as np

    from repro.core import gen
    from repro.core.grid import make_grid
    from repro.sparse_apps.graph_algorithms import (
        overlap_pairs,
        overlap_pairs_reference,
    )

    grid = make_grid(2, 2, 2)
    nseqs, nkmers = 64, 128
    a = gen.kmer_like(nseqs, nkmers, kmers_per_seq=6, seed=23)
    print(f"{nseqs} sequences × {nkmers} k-mers, nnz={int(a.nnz)}")

    pairs = overlap_pairs(a, grid, min_shared=2)
    ref = overlap_pairs_reference(a, min_shared=2)
    assert pairs == ref, "batched AA^T disagrees with the dense reference"
    print(f"candidate overlap pairs (>=2 shared k-mers): {len(pairs)}")
    for i, j, s in pairs[:8]:
        print(f"  seq{i:3d} ~ seq{j:3d}  shared={s}")
    print("OK — matches dense reference")


if __name__ == "__main__":
    main()
