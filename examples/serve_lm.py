"""Batched serving with continuous batching (deliverable (b), serving kind).

Spins up the ServeEngine on a smoke-size model, submits a burst of requests
with varying prompt lengths, and drives prefill + lock-step decode to
completion.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch granite-20b
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    import numpy as np

    import jax
    from repro.compat import AxisType, make_mesh, set_mesh

    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.serve import EngineConfig, Request, ServeEngine

    cfg = get_config(args.arch, smoke=True)
    ndev = len(jax.devices())
    mesh = make_mesh((1, min(2, ndev)), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    with set_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.PRNGKey(3))
        eng = ServeEngine(cfg, params, mesh, EngineConfig(max_batch=3, s_max=64))
        rng = np.random.default_rng(0)
        for rid in range(args.requests):
            plen = int(rng.integers(4, 12))
            eng.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new_tokens=args.max_new,
            ))
        print(f"submitted {args.requests} requests (max_batch=3 -> continuous "
              f"batching refills slots)")
        done = eng.run_to_completion()
    for req in sorted(done, key=lambda r: r.rid):
        print(f"  req{req.rid}: prompt_len={len(req.prompt)} "
              f"generated={req.out_tokens}")
    assert len(done) == args.requests
    print("OK — all requests completed")


if __name__ == "__main__":
    main()
