"""Hash-accumulator local multiply: kernel, local, plan and driver tests.

The hash path (``kernels/spgemm_hash`` + ``local_spgemm.spgemm_hash``) must
be a drop-in third local multiply: identical (row-major-sorted C, overflow)
contract to ESC across semirings / masks / batch counts, the same
count-and-retry overflow behavior, and — the point of the exercise — a
strictly smaller memory footprint on compressing workloads, surfaced as
fewer planned batches at a fixed ``per_process_memory``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import gen, semiring as sr, sortkeys, sparse as sp
from repro.core.batched import (
    HASH_CF_THRESHOLD,
    batched_summa3d,
    plan_batches,
    probe_memory_budget,
    symbolic3d_counts,
)
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.local_spgemm import spgemm_esc, spgemm_hash
from repro.core.symbolic import (
    HASH_LOAD_FACTOR,
    estimate_mem_c_bytes,
    rup_pow2,
)
from repro.kernels import spgemm_hash as hashkern
from repro.sparse_apps.mcl import _sparse_batch_to_global


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _dense(m, n, density, seed, lo=0.5, hi=1.0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(lo, hi, (m, n)).astype(np.float32)
    return np.where(rng.random((m, n)) < density, x, 0.0).astype(np.float32)


def _pair(seed=0, m=24, k=20, n=22, da=0.35, db=0.35):
    xa = _dense(m, k, da, seed)
    xb = _dense(k, n, db, seed + 1)
    a = sp.from_dense(jnp.asarray(xa), cap=max(int((xa != 0).sum()), 8))
    b = sp.from_dense(jnp.asarray(xb), cap=max(int((xb != 0).sum()), 8))
    return xa, xb, a, b


def _as_sets(c: sp.SparseCOO):
    nnz = int(c.nnz)
    return (
        np.asarray(c.rows[:nnz]),
        np.asarray(c.cols[:nnz]),
        np.asarray(c.vals[:nnz]),
    )


def _assert_same_output(ch, ce, rtol=1e-5):
    rh, colh, vh = _as_sets(ch)
    re_, cole, ve = _as_sets(ce)
    assert len(rh) == len(re_), (len(rh), len(re_))
    np.testing.assert_array_equal(rh, re_)
    np.testing.assert_array_equal(colh, cole)
    np.testing.assert_allclose(vh, ve, rtol=rtol, atol=1e-6)


def _hash_kwargs(a, b, table_slack=4.0):
    """Generous static caps for parity tests (overflow exercised separately)."""
    flops = 4096
    nnz_hint = int(a.shape[0]) * int(b.shape[1])
    return dict(
        out_cap=max(nnz_hint, 8),
        table_cap=rup_pow2(max(int(nnz_hint * table_slack), 64)),
        chunk_cap=256,
        num_chunks=-(-flops // 256),
    )


SEMIRINGS = [sr.PLUS_TIMES, sr.MIN_PLUS, sr.MAX_TIMES]


# ---------------------------------------------------------------------------
# Kernel level: insert rounds
# ---------------------------------------------------------------------------
class TestHashInsert:
    def test_pallas_matches_oracle(self):
        rng = np.random.default_rng(0)
        keys = jnp.asarray(rng.integers(0, 500, 128), jnp.int32)
        vals = jnp.asarray(rng.uniform(0.5, 1, 128), jnp.float32)
        valid = jnp.asarray(rng.random(128) < 0.8)
        T = 256
        tk0 = jnp.full((T,), hashkern.EMPTY, jnp.int32)
        tv0 = jnp.zeros((T,), jnp.float32)
        ref = hashkern.hash_insert_ref(
            tk0, tv0, keys, vals, valid, add_kind="sum", max_probes=T)
        pal = hashkern.hash_insert_pallas(
            tk0, tv0, keys, vals, valid, add_kind="sum", max_probes=T,
            interpret=True)
        for r, p in zip(ref, pal):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    @pytest.mark.parametrize("add_kind", ["sum", "min", "max"])
    def test_duplicate_keys_accumulate_in_one_slot(self, add_kind):
        """Every copy of a key resolves to a single slot regardless of which
        probe round placed it (the linear-probing invariant the vectorized
        rounds must preserve)."""
        rng = np.random.default_rng(1)
        # 64 distinct keys, each repeated 8 times, shuffled
        base = rng.choice(10_000, 64, replace=False).astype(np.int32)
        keys_np = np.repeat(base, 8)
        rng.shuffle(keys_np)
        vals_np = rng.uniform(0.5, 1, keys_np.shape[0]).astype(np.float32)
        T = 128  # load factor 0.5 over distinct keys
        tk = jnp.full((T,), hashkern.EMPTY, jnp.int32)
        tv = jnp.full((T,), hashkern.table_init_val(add_kind), jnp.float32)
        tk, tv, dropped = hashkern.hash_insert_ref(
            tk, tv, jnp.asarray(keys_np), jnp.asarray(vals_np),
            jnp.ones(keys_np.shape[0], bool), add_kind=add_kind,
            max_probes=T)
        assert int(dropped) == 0
        tk_np, tv_np = np.asarray(tk), np.asarray(tv)
        occupied = tk_np != hashkern.EMPTY
        assert occupied.sum() == len(base)  # one slot per distinct key
        reduce = {"sum": np.sum, "min": np.min, "max": np.max}[add_kind]
        for k in base:
            slots = np.nonzero(tk_np == k)[0]
            assert len(slots) == 1, (k, slots)
            np.testing.assert_allclose(
                tv_np[slots[0]], reduce(vals_np[keys_np == k]), rtol=1e-6)

    def test_probe_exhaustion_drops_and_counts(self):
        """A full table (or too few probe rounds) drops entries and REPORTS
        them — the driver's retry signal, never a crash or silent loss."""
        keys = jnp.arange(64, dtype=jnp.int32)
        vals = jnp.ones(64, jnp.float32)
        valid = jnp.ones(64, bool)
        T = 16
        tk = jnp.full((T,), hashkern.EMPTY, jnp.int32)
        tv = jnp.zeros((T,), jnp.float32)
        tk, tv, dropped = hashkern.hash_insert_ref(
            tk, tv, keys, vals, valid, add_kind="sum", max_probes=T)
        assert int(dropped) == 64 - T  # every slot claimed, rest counted
        assert int(np.sum(np.asarray(tk) != hashkern.EMPTY)) == T


# ---------------------------------------------------------------------------
# Local multiply: spgemm_hash vs spgemm_esc
# ---------------------------------------------------------------------------
class TestSpgemmHashParity:
    @pytest.mark.parametrize("semiring", SEMIRINGS, ids=lambda s: s.name)
    @pytest.mark.parametrize("mask_mode", ["none", "strict", "complement"])
    def test_matches_esc(self, semiring, mask_mode):
        xa, xb, a, b = _pair(seed=3)
        m, n = xa.shape[0], xb.shape[1]
        mask_keys = None
        complement = False
        if mask_mode != "none":
            md = np.random.default_rng(5).random((m, n)) < 0.3
            mr, mc = np.nonzero(md)
            mask_keys = sortkeys.sorted_mask_keys(
                jnp.asarray(mr, jnp.int32), jnp.asarray(mc, jnp.int32),
                jnp.ones(len(mr), bool), (m, n))
            complement = mask_mode == "complement"
        ce, ovf_e = spgemm_esc(
            a, b, out_cap=m * n, flops_cap=4096, semiring=semiring,
            mask_keys=mask_keys, mask_complement=complement)
        ch, ovf_h = spgemm_hash(
            a, b, semiring=semiring, mask_keys=mask_keys,
            mask_complement=complement, **_hash_kwargs(a, b))
        assert int(ovf_e) == 0 and int(ovf_h) == 0
        _assert_same_output(ch, ce)

    def test_collision_heavy_table_still_exact(self):
        """Table sized at load factor ~1.0 (every slot needed): parity holds
        with enough probe rounds — correctness never depends on a low load
        factor, only speed does."""
        xa, xb, a, b = _pair(seed=7, m=16, k=16, n=16, da=0.5, db=0.5)
        ce, _ = spgemm_esc(a, b, out_cap=256, flops_cap=4096)
        exact_nnz = int(ce.nnz)
        table_cap = rup_pow2(exact_nnz)
        ch, ovf = spgemm_hash(
            a, b, out_cap=256, table_cap=table_cap, chunk_cap=256,
            num_chunks=16, max_probes=table_cap)
        assert int(ovf) == 0
        _assert_same_output(ch, ce)
        # with one probe round the same table MUST drop entries (and say so)
        _, ovf1 = spgemm_hash(
            a, b, out_cap=256, table_cap=table_cap, chunk_cap=256,
            num_chunks=16, max_probes=1)
        assert int(ovf1) > 0

    def test_table_overflow_reported_then_doubling_clears(self):
        """ESC's overflow contract: a too-small table reports a positive
        count; doubling caps (the driver's retry ladder) converges to the
        exact result."""
        xa, xb, a, b = _pair(seed=9)
        ce, _ = spgemm_esc(a, b, out_cap=2048, flops_cap=4096)
        table_cap, probes = 8, 8
        ovf = 1
        for _ in range(10):
            ch, ovf = spgemm_hash(
                a, b, out_cap=2048, table_cap=table_cap, chunk_cap=256,
                num_chunks=16, max_probes=probes)
            if int(ovf) == 0:
                break
            table_cap *= 2
            probes = min(probes * 2, 256)
        assert int(ovf) == 0
        _assert_same_output(ch, ce)

    def test_flop_overflow_reported(self):
        xa, xb, a, b = _pair(seed=11)
        total_flops = int(
            ((xa != 0).sum(axis=0) * (xb != 0).sum(axis=1)).sum())
        _, ovf = spgemm_hash(
            a, b, out_cap=2048, table_cap=4096, chunk_cap=8, num_chunks=1)
        assert int(ovf) >= total_flops - 8

    def test_pallas_interpret_matches_oracle_path(self):
        xa, xb, a, b = _pair(seed=13)
        kw = _hash_kwargs(a, b)
        c0, o0 = spgemm_hash(a, b, use_pallas=False, **kw)
        c1, o1 = spgemm_hash(a, b, use_pallas=True, interpret=True, **kw)
        assert int(o0) == int(o1) == 0
        _assert_same_output(c1, c0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Plan: hash memory model and 3-way dispatch
# ---------------------------------------------------------------------------
class TestHashPlanning:
    def test_hash_mem_model_beats_esc_on_compression(self):
        """mem(C) under the hash model scales with merged output, so it
        drops below the ESC expansion exactly when cf > lf·slot/r."""
        flops = 1_000_000
        esc = estimate_mem_c_bytes(flops, 1.0, r=12)
        for cf in (2.0, 4.0, 8.0):
            h = estimate_mem_c_bytes(flops, cf, r=12, local_path="hash")
            assert h == int(np.ceil(flops / cf * HASH_LOAD_FACTOR * 8))
            assert h < esc
        # load_factor override is honored
        assert estimate_mem_c_bytes(
            flops, 4.0, r=12, local_path="hash", load_factor=1.0
        ) < estimate_mem_c_bytes(flops, 4.0, r=12, local_path="hash")

    def test_auto_dispatch_uses_compression_threshold(self, grid1):
        xa, xb, a, b = _pair(seed=15, m=32, k=32, n=32)
        A = scatter_to_grid(a, grid1, "A")
        B = scatter_to_grid(b, grid1, "B")
        p = plan_batches(A, B, grid1, per_process_memory=1 << 26,
                         local_path="auto")
        expect = "hash" if p.compression_est >= HASH_CF_THRESHOLD else "esc"
        assert p.local_path == expect
        assert (p.hash_caps is not None) == (p.local_path == "hash")
        # explicit paths are respected verbatim
        for forced in ("esc", "hash", "binned"):
            pf = plan_batches(A, B, grid1, per_process_memory=1 << 26,
                              local_path=forced)
            assert pf.local_path == forced

    def test_fixed_memory_hash_needs_fewer_batches(self, grid1):
        """THE acceptance property: on R-MAT A·Aᵀ (high compression factor)
        at a fixed per-process memory, the hash plan runs in strictly fewer
        batches than the ESC plan — the paper's b = ceil(mem(C)/M) with a
        smaller mem(C)."""
        a = gen.rmat(7, edge_factor=16, seed=3)
        A = scatter_to_grid(a, grid1, "A")
        B = scatter_to_grid(a.transpose().sort_rowmajor(), grid1, "B")
        ppm = probe_memory_budget(A, B, grid1)
        pe = plan_batches(A, B, grid1, per_process_memory=ppm,
                          local_path="esc")
        ph = plan_batches(A, B, grid1, per_process_memory=ppm,
                          local_path="hash")
        pa = plan_batches(A, B, grid1, per_process_memory=ppm,
                          local_path="auto")
        assert pe.num_batches > 1, pe.num_batches
        assert ph.num_batches < pe.num_batches, (
            ph.num_batches, pe.num_batches)
        assert pa.local_path == "hash" and pa.num_batches == ph.num_batches
        assert ph.compression_est >= HASH_CF_THRESHOLD


# ---------------------------------------------------------------------------
# Driver: batched_summa3d with the hash local multiply
# ---------------------------------------------------------------------------
def _multiply(A, B, grid, nb, semiring=sr.PLUS_TIMES, mask=None,
              complement=False, **kw):
    n = B.shape[1]
    got = np.full((A.shape[0], n), np.inf if semiring.add_kind == "min"
                  else (-np.inf if semiring.add_kind == "max" else 0.0),
                  np.float32)

    def consumer(bi, c, cm):
        rr, cc, vv = _sparse_batch_to_global(c, cm)
        if semiring.add_kind == "min":
            np.minimum.at(got, (rr, cc), vv)
        elif semiring.add_kind == "max":
            np.maximum.at(got, (rr, cc), vv)
        else:
            got[rr, cc] += vv
    res = batched_summa3d(
        A, B, grid, per_process_memory=1 << 26, consumer=consumer,
        path="sparse", force_num_batches=nb, semiring=semiring,
        mask=mask, mask_complement=complement, **kw)
    return got, res


def _reference(xa, xb, semiring):
    m, n = xa.shape[0], xb.shape[1]
    if semiring is sr.PLUS_TIMES:
        return xa @ xb, 0.0
    acc = np.full((m, n), np.inf if semiring.add_kind == "min" else -np.inf,
                  np.float32)
    for kk in range(xa.shape[1]):
        av, bv = xa[:, kk], xb[kk, :]
        hit = np.outer(av != 0, bv != 0)
        prod = (np.add if semiring is sr.MIN_PLUS else np.multiply).outer(
            av, bv)
        red = np.minimum if semiring.add_kind == "min" else np.maximum
        acc = np.where(hit, red(acc, prod), acc)
    return acc, (np.inf if semiring.add_kind == "min" else -np.inf)


class TestBatchedHashDriver:
    @pytest.mark.parametrize("semiring", SEMIRINGS, ids=lambda s: s.name)
    @pytest.mark.parametrize("nb", [1, 3])
    def test_forced_hash_matches_reference(self, grid1, semiring, nb):
        xa = _dense(48, 48, 0.25, seed=21)
        xb = _dense(48, 48, 0.25, seed=22)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024),
                            grid1, "B")
        got, res = _multiply(A, B, grid1, nb, semiring=semiring,
                             local_path="hash")
        assert res.local_path == "hash" and res.num_retries == 0
        want, empty = _reference(xa, xb, semiring)
        got = np.where(np.isinf(got), empty, got) if empty else got
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("complement", [False, True])
    @pytest.mark.parametrize("nb", [1, 2])
    def test_masked_hash_matches_dense(self, grid1, complement, nb, n=32):
        xa = _dense(n, n, 0.3, seed=23)
        xb = _dense(n, n, 0.3, seed=24)
        md = np.random.default_rng(25).random((n, n)) < 0.2
        mr, mc = np.nonzero(md)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024),
                            grid1, "B")
        M = scatter_to_grid(
            sp.from_numpy_coo(mr, mc, np.ones(len(mr), np.float32), (n, n)),
            grid1, "C")
        got, res = _multiply(A, B, grid1, nb, mask=M, complement=complement,
                             local_path="hash")
        assert res.local_path == "hash" and res.num_retries == 0
        keep = ~md if complement else md
        np.testing.assert_allclose(got, (xa @ xb) * keep,
                                   rtol=1e-4, atol=1e-5)

    def test_undersized_hash_caps_retry_to_parity(self, grid1):
        """A deliberately starved HashCaps floor trips the device overflow
        flag; the driver's doubling retry ladder converges to the exact
        product (same machinery as ESC cap overflow)."""
        from repro.core.summa3d import HashCaps

        xa = _dense(32, 32, 0.3, seed=27)
        xb = _dense(32, 32, 0.3, seed=28)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024),
                            grid1, "B")
        import repro.core.batched as batched_mod
        real_plan = batched_mod.plan_batches

        def starved_plan(*args, **kwargs):
            p = real_plan(*args, **kwargs)
            if p.hash_caps is None:
                return p
            import dataclasses
            return dataclasses.replace(
                p, hash_caps=HashCaps(table_cap=16, chunk_cap=p.hash_caps.
                                      chunk_cap, num_chunks=p.hash_caps.
                                      num_chunks, max_probes=4))
        batched_mod.plan_batches = starved_plan
        try:
            got, res = _multiply(A, B, grid1, 2, local_path="hash")
        finally:
            batched_mod.plan_batches = real_plan
        assert res.num_retries > 0
        assert res.hash_caps.table_cap > 16  # the grown caps are recorded
        np.testing.assert_allclose(got, xa @ xb, rtol=1e-4, atol=1e-5)

    def test_auto_path_does_not_retrace_across_runs(self, grid1):
        """Repeated auto-dispatch runs (the MCL regime: pinned path + caps
        floor) hit the jit cache — one fused-step trace total."""
        from repro.core import summa3d

        xa = _dense(32, 32, 0.3, seed=29)
        xb = _dense(32, 32, 0.3, seed=30)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024),
                            grid1, "B")
        _, first_res = _multiply(A, B, grid1, 2)  # local_path defaults auto
        first = summa3d.TRACE_COUNTS["fused_step"]
        for _ in range(3):
            _, res = _multiply(
                A, B, grid1, 2, local_path=first_res.local_path,
                hash_caps_floor=first_res.hash_caps)
            assert res.num_retries == 0
        repeat = summa3d.TRACE_COUNTS["fused_step"] - first
        assert repeat == 0, repeat


# ---------------------------------------------------------------------------
# Satellite: device-resident mask counts (planner no longer pulls the mask)
# ---------------------------------------------------------------------------
class TestDeviceMaskCounts:
    def test_device_counts_match_host_oracle(self, grid1, n=32):
        from repro.core.batched import _mask_tile_colcounts

        md = np.random.default_rng(31).random((n, n)) < 0.2
        mr, mc = np.nonzero(md)
        xa = _dense(n, n, 0.3, seed=33)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024),
                            grid1, "B")
        M = scatter_to_grid(
            sp.from_numpy_coo(mr, mc, np.ones(len(mr), np.float32), (n, n)),
            grid1, "C")
        counts = symbolic3d_counts(A, B, grid1, mask=M)
        np.testing.assert_array_equal(
            counts.mask_colcounts, _mask_tile_colcounts(M))
