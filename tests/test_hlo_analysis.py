"""Validate the loop-aware HLO cost model against hand-counted programs."""
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as ha


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestDotFlops:
    def test_single_matmul(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        txt = _compile_text(lambda x, y: x @ y, a, b)
        cost = ha.analyze_module(txt, world=1)
        # 2*M*N*K = 2*64*32*128 = 524288
        assert cost.flops == pytest.approx(524288, rel=0.01)

    def test_scan_multiplies_by_trips(self):
        L = 7
        w = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

        def fn(ws, x0):
            def body(h, wi):
                return h @ wi, None

            h, _ = jax.lax.scan(body, x0, ws)
            return h

        txt = _compile_text(fn, w, x)
        cost = ha.analyze_module(txt, world=1)
        expect = L * 2 * 8 * 32 * 32
        assert cost.flops == pytest.approx(expect, rel=0.05), (
            cost.flops, expect, cost.loop_trips
        )

    def test_nested_scan(self):
        Lo, Li = 3, 5
        w = jax.ShapeDtypeStruct((Lo, Li, 16, 16), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 16), jnp.float32)

        def fn(ws, x0):
            def outer(h, wo):
                def inner(h2, wi):
                    return h2 @ wi, None

                h2, _ = jax.lax.scan(inner, h, wo)
                return h2, None

            h, _ = jax.lax.scan(outer, x0, ws)
            return h

        txt = _compile_text(fn, w, x)
        cost = ha.analyze_module(txt, world=1)
        expect = Lo * Li * 2 * 4 * 16 * 16
        assert cost.flops == pytest.approx(expect, rel=0.05), (
            cost.flops, expect, cost.loop_trips
        )


class TestCollectives:
    def test_psum_in_scan_counted_per_trip(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >=2 devices")

    def test_shape_bytes(self):
        assert ha._shape_bytes("bf16[64,512]") == 64 * 512 * 2
        assert ha._shape_bytes("(f32[8], f32[16])") == 4 * 8 + 4 * 16

    def test_group_size_iota(self):
        line = "x = f32[2] all-gather(y), replica_groups=[32,16]<=[512], dimensions={0}"
        assert ha._group_size(line, 512) == 16

    def test_group_size_explicit(self):
        line = "x = f32[2] all-reduce(y), replica_groups={{0,1,2,3},{4,5,6,7}}"
        assert ha._group_size(line, 8) == 4
