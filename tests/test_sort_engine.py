"""Packed-key sort engine + k-binned pairing: parity vs the legacy lexsort
path (randomized, over PLUS_TIMES / MIN_PLUS / MAX_TIMES), merge overflow
reporting, the segmented sorted merge, and the bitonic Pallas kernel."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import gen
from repro.core import local_spgemm as lsp
from repro.core import semiring as sr
from repro.core import sortkeys as sk
from repro.core import sparse as sp
from repro.core import symbolic as sym
from repro.kernels import ops
from repro.kernels import sort_engine as se
from repro.testing import given, settings, strategies as st

SEMIRINGS = [sr.PLUS_TIMES, sr.MIN_PLUS, sr.MAX_TIMES]


def dense_random(rng, m, n, density):
    x = rng.random((m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    return np.where(mask, x + 0.1, 0.0).astype(np.float32)


def random_entries(rng, m, n, cap, valid_p=0.8):
    rows = jnp.asarray(rng.integers(0, m, cap).astype(np.int32))
    cols = jnp.asarray(rng.integers(0, n, cap).astype(np.int32))
    valid = jnp.asarray(rng.random(cap) < valid_p)
    vals = jnp.asarray((rng.random(cap) + 0.1).astype(np.float32))
    return rows, cols, vals, valid


def assert_entries_equal(got, want, context=""):
    for name, x, y in zip(("rows", "cols", "vals", "nnz", "ovf"), got, want):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-5,
            err_msg=f"{context}: {name}",
        )


# ---------------------------------------------------------------------------
# packed-key sort parity vs lexsort
# ---------------------------------------------------------------------------
class TestPackedSortParity:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), density=st.floats(0.05, 0.6))
    def test_sort_rowmajor_bitexact(self, seed, density):
        rng = np.random.default_rng(seed)
        x = dense_random(rng, 13, 11, density)
        a = sp.from_dense(jnp.asarray(x), cap=13 * 11 + 5)
        packed = a.sort_rowmajor(engine="auto")
        legacy = a.sort_rowmajor(engine="lexsort")
        for f in ("rows", "cols", "vals"):
            np.testing.assert_array_equal(
                np.asarray(getattr(packed, f)), np.asarray(getattr(legacy, f)), f
            )

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), density=st.floats(0.05, 0.6))
    def test_sort_colmajor_bitexact(self, seed, density):
        rng = np.random.default_rng(seed)
        x = dense_random(rng, 9, 17, density)
        a = sp.from_dense(jnp.asarray(x), cap=9 * 17 + 3)
        packed = a.sort_colmajor(engine="auto")
        legacy = a.sort_colmajor(engine="lexsort")
        for f in ("rows", "cols", "vals"):
            np.testing.assert_array_equal(
                np.asarray(getattr(packed, f)), np.asarray(getattr(legacy, f)), f
            )


# ---------------------------------------------------------------------------
# coalesce engines parity over semirings
# ---------------------------------------------------------------------------
class TestCoalesceEngines:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        ring=st.sampled_from(["plus_times", "min_plus", "max_times"]),
    )
    def test_engines_match_lexsort(self, seed, ring):
        semiring = sr.get(ring)
        rng = np.random.default_rng(seed)
        m, n, cap, new_cap = 14, 10, 96, 64
        rows, cols, vals, valid = random_entries(rng, m, n, cap)
        ref = sk.coalesce_entries(
            rows, cols, vals, valid, (m, n), new_cap, semiring.add_kind, "lexsort"
        )
        for eng in ("packed", "bucket"):
            got = sk.coalesce_entries(
                rows, cols, vals, valid, (m, n), new_cap, semiring.add_kind, eng
            )
            assert_entries_equal(got, ref, f"{ring}/{eng}")

    def test_auto_picks_bucket_for_small_tiles(self):
        assert sk.choose_engine(100, 100, 1000) == "bucket"

    def test_auto_falls_back_above_table_budget(self):
        big = 1 << 13
        assert sk.choose_engine(big, big, 1000) == "packed"

    def test_lexsort_when_key_overflows_i32(self):
        big = 1 << 17
        assert sk.choose_engine(big, big, 1000) == "lexsort"

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_esc_engine_parity(self, seed):
        rng = np.random.default_rng(seed)
        A = dense_random(rng, 10, 12, 0.3)
        B = dense_random(rng, 12, 9, 0.3)
        a = sp.from_dense(jnp.asarray(A), cap=10 * 12 + 1)
        b = sp.from_dense(jnp.asarray(B), cap=12 * 9 + 1)
        outs = {}
        for eng in ("lexsort", "packed", "bucket"):
            c, ovf = lsp.spgemm_esc(
                a, b, out_cap=10 * 9 + 1, flops_cap=2048, engine=eng
            )
            assert int(ovf) == 0
            outs[eng] = c
        for eng in ("packed", "bucket"):
            for f in ("rows", "cols", "nnz"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(outs[eng], f)),
                    np.asarray(getattr(outs["lexsort"], f)), (eng, f),
                )
            np.testing.assert_allclose(
                np.asarray(outs[eng].vals), np.asarray(outs["lexsort"].vals),
                rtol=1e-5, atol=1e-6,
            )

    def test_symbolic_exact_engine_parity(self):
        rng = np.random.default_rng(3)
        A = dense_random(rng, 11, 13, 0.4)
        B = dense_random(rng, 13, 7, 0.4)
        a = sp.from_dense(jnp.asarray(A), cap=11 * 13 + 1)
        b = sp.from_dense(jnp.asarray(B), cap=13 * 7 + 1)
        expect = int(((A @ B) != 0).sum())
        for eng in ("lexsort", "packed", "bucket"):
            assert int(lsp.local_symbolic_exact(a, b, 4096, engine=eng)) == expect


# ---------------------------------------------------------------------------
# merge_sparse: overflow reporting + segmented sorted merge
# ---------------------------------------------------------------------------
class TestMergeSparse:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        ring=st.sampled_from(["plus_times", "min_plus", "max_times"]),
    )
    def test_sorted_merge_matches_unsorted(self, seed, ring):
        semiring = sr.get(ring)
        rng = np.random.default_rng(seed)
        xs = [dense_random(rng, 12, 8, 0.35) for _ in range(3)]
        # parts are row-major sorted (the Merge-Fiber precondition)
        parts = [
            sp.from_dense(jnp.asarray(x), cap=40).sort_rowmajor() for x in xs
        ]
        m1, o1 = lsp.merge_sparse(parts, 96, semiring, assume_sorted=True)
        m2, o2 = lsp.merge_sparse(parts, 96, semiring, assume_sorted=False,
                                  engine="lexsort")
        assert int(o1) == int(o2) == 0
        for f in ("rows", "cols", "nnz"):
            np.testing.assert_array_equal(
                np.asarray(getattr(m1, f)), np.asarray(getattr(m2, f)), f
            )
        np.testing.assert_allclose(
            np.asarray(m1.vals), np.asarray(m2.vals), rtol=1e-5, atol=1e-6
        )

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), parts=st.integers(1, 5))
    def test_overflow_reported_consistently(self, seed, parts):
        """Overflow = distinct-coordinate count minus out_cap, identically
        across engines and the sorted merge (satellite: overflow reporting)."""
        rng = np.random.default_rng(seed)
        xs = [dense_random(rng, 9, 9, 0.5) for _ in range(parts)]
        mats = [sp.from_dense(jnp.asarray(x), cap=50) for x in xs]
        distinct = int((sum((x != 0).astype(np.int64) for x in xs) != 0).sum())
        out_cap = max(distinct // 2, 1)
        expect_ovf = distinct - out_cap
        for kwargs in (
            dict(engine="lexsort"),
            dict(engine="packed"),
            dict(engine="bucket"),
            dict(assume_sorted=True),
        ):
            ps = (
                [x.sort_rowmajor() for x in mats]
                if kwargs.get("assume_sorted")
                else mats
            )
            merged, ovf = lsp.merge_sparse(ps, out_cap, sr.PLUS_TIMES, **kwargs)
            assert int(ovf) == expect_ovf, kwargs
            assert int(merged.nnz) == out_cap, kwargs
            # surviving prefix is the row-major smallest coordinate set
            keys = (
                np.asarray(merged.rows[: out_cap]) * 10
                + np.asarray(merged.cols[: out_cap])
            )
            assert np.all(np.diff(keys) > 0), kwargs

    def test_merge_empty_parts(self):
        parts = [sp.empty((6, 6), cap=8) for _ in range(3)]
        for kwargs in (dict(engine="bucket"), dict(assume_sorted=True)):
            merged, ovf = lsp.merge_sparse(parts, 10, sr.PLUS_TIMES, **kwargs)
            assert int(ovf) == 0 and int(merged.nnz) == 0


# ---------------------------------------------------------------------------
# bitonic Pallas kernel
# ---------------------------------------------------------------------------
class TestBitonicKernel:
    @pytest.mark.parametrize("n", [8, 128, 500, 2048])
    def test_matches_lax_sort(self, n):
        rng = np.random.default_rng(n)
        keys = jnp.asarray(rng.integers(0, 300, n).astype(np.int32))
        vals = jnp.asarray(rng.random(n).astype(np.float32))
        k1, v1 = se.sort_pairs(keys, vals, use_pallas=True, interpret=True)
        k2, v2 = jax.lax.sort((keys, vals), num_keys=1)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
        # network is unstable: compare per-key value multisets via sums
        s1 = jax.ops.segment_sum(v1, k1, num_segments=301)
        s2 = jax.ops.segment_sum(v2, k2, num_segments=301)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)

    def test_large_sizes_fall_back_to_xla(self):
        n = se.MAX_BITONIC_ELEMS + 8
        rng = np.random.default_rng(0)
        keys = jnp.asarray(rng.integers(0, 99, n).astype(np.int32))
        vals = jnp.asarray(rng.random(n).astype(np.float32))
        k1, _ = se.sort_pairs(keys, vals, use_pallas=True, interpret=True)
        assert np.all(np.diff(np.asarray(k1)) >= 0)


# ---------------------------------------------------------------------------
# k-binned pairing
# ---------------------------------------------------------------------------
class TestBinnedPairing:
    def _check(self, a, b):
        plan = sym.plan_k_bins(
            np.asarray(a.col_counts()), np.asarray(b.row_counts()), a.cap, b.cap
        )
        c_ref = ops.spgemm_paired(a, b)
        c_bin, ovf = ops.spgemm_paired_binned(
            a, b, plan.num_bins, plan.bin_cap_a, plan.bin_cap_b,
            bin_map=jnp.asarray(plan.bin_of_k),
        )
        assert int(ovf) == 0
        np.testing.assert_allclose(
            np.asarray(c_bin), np.asarray(c_ref), rtol=1e-4, atol=1e-4
        )
        return plan

    def test_uniform_workload(self):
        a = gen.erdos_renyi(64, 5, seed=1)
        b = gen.erdos_renyi(64, 5, seed=2)
        plan = self._check(a, b)
        assert plan.pairings < plan.pairings_unbinned

    def test_skewed_workload_reduces_pairings(self):
        """The acceptance shape: on skewed-k inputs the balanced-bin plan
        must still do measurably fewer pairings than O(capA×capB)."""
        a = gen.rmat(scale=6, edge_factor=6, seed=3)
        b = gen.rmat(scale=6, edge_factor=6, seed=4)
        plan = self._check(a, b)
        assert plan.num_bins > 1
        assert plan.pairings * 2 <= plan.pairings_unbinned

    def test_pallas_interpret_matches(self):
        a = gen.erdos_renyi(48, 4, seed=5)
        b = gen.erdos_renyi(48, 4, seed=6)
        plan = sym.plan_k_bins(
            np.asarray(a.col_counts()), np.asarray(b.row_counts()), a.cap, b.cap
        )
        c_ref = ops.spgemm_paired(a, b)
        c_p, ovf = ops.spgemm_paired_binned(
            a, b, plan.num_bins, plan.bin_cap_a, plan.bin_cap_b,
            bin_map=jnp.asarray(plan.bin_of_k), use_pallas=True, interpret=True,
        )
        assert int(ovf) == 0
        np.testing.assert_allclose(
            np.asarray(c_p), np.asarray(c_ref), rtol=1e-4, atol=1e-4
        )

    def test_bin_overflow_reported(self):
        a = gen.erdos_renyi(64, 5, seed=7)
        b = gen.erdos_renyi(64, 5, seed=8)
        _, ovf = ops.spgemm_paired_binned(a, b, num_bins=4, bin_cap_a=8,
                                          bin_cap_b=8)
        assert int(ovf) > 0
