"""Fast-lane tests for structure-aware placement (``core/placement.py``).

Three contracts lock the layer in:

  * Distribution contract — the pluggable ``BLOCK_CYCLIC`` reproduces the
    historical ``fold_block_cyclic`` / ``batching_plan_columns`` math
    bit-for-bit over a (pr, pc, l, nb) sweep, its vectorized column map
    matches the triple-loop reference, and the driver rejects distributions
    the fused step cannot execute.
  * Permutation invariance (property-based, hypothesis with the
    ``repro.testing`` fallback) — permute → multiply → unpermute equals the
    unpermuted run EXACTLY across {plus_times, min_plus, max_times} ×
    {unmasked, strict mask} × {esc, binned, hash} local paths. Values are
    small integers so even plus_times f32 sums are order-exact.
  * Plan ordering on skew (host oracle, no devices) — a degree-spread
    R-MAT plan needs no more batches and no more padded transfer bytes
    than block-cyclic at the same ``per_process_memory``, and strictly
    fewer total padded bytes (the BENCH_graph placement-summary claim).

Plus the rectangular-grid oracle coverage the autotuner's new (pr, pc, 1)
candidates rely on (the 8-device device-parity case lives in
``tests/distributed_cases.py``).
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import gen
from repro.core import semiring as sr
from repro.core import sparse as sp
from repro.core.batched import (
    PlanInputs,
    batch_column_map,
    batched_summa3d,
    plan_from_symbolic,
)
from repro.core.grid import make_grid
from repro.core.placement import (
    BLOCK_CYCLIC,
    Distribution,
    Placement,
    compute_placement,
    multiply_placed,
)
from repro.core.specs import PlanFloors, PlanSpec
from repro.core.symbolic import (
    batching_plan_columns,
    fold_block_cyclic,
    host_symbolic_counts,
)
from repro.testing import given, settings, strategies as st
from repro.tune import padded_comm_volume

_GRID1 = None


def grid1():
    """Module-cached 1×1×1 grid (plain function, not a fixture: the
    hypothesis fallback erases test signatures, so property tests cannot
    take pytest fixtures)."""
    global _GRID1
    if _GRID1 is None:
        _GRID1 = make_grid(1, 1, 1)
    return _GRID1


def _rand_int_sparse(n, density, rng, cap=512):
    """Random COO with small-INTEGER f32 values (1..4): any summation order
    is exact in f32, so permuted plus_times products are bit-comparable."""
    m = rng.random((n, n)) < density
    rr, cc = np.nonzero(m)
    vals = rng.integers(1, 5, size=rr.shape[0]).astype(np.float32)
    return sp.from_numpy_coo(rr, cc, vals, (n, n), cap=cap)


# ---------------------------------------------------------------------------
# Distribution contract
# ---------------------------------------------------------------------------
class TestDistributionContract:
    GRID_SWEEP = [(1, 1, 1), (2, 2, 1), (2, 2, 2), (3, 3, 3),
                  (4, 2, 1), (2, 4, 1), (1, 4, 1)]

    def test_fold_reproduces_fold_block_cyclic_bit_for_bit(self):
        rng = np.random.default_rng(0)
        for pr, pc, l in self.GRID_SWEEP:
            for nb in (1, 2, 3, 4):
                n = nb * l * 3  # any width divisible by nb·l
                x = rng.integers(0, 100, size=(pr, pc, l, n))
                np.testing.assert_array_equal(
                    BLOCK_CYCLIC.fold(x, nb, l), fold_block_cyclic(x, nb, l)
                )

    def test_round_batches_reproduces_batching_plan_columns(self):
        for n in (12, 24, 48, 64, 96):
            for l in (1, 2, 4):
                if n % l:
                    continue
                for nb in (1, 2, 3, 5, 7):
                    if nb > n // l:  # finer than the column structure allows
                        with pytest.raises(MemoryError):
                            BLOCK_CYCLIC.round_batches(n, nb, l)
                        continue
                    assert (
                        BLOCK_CYCLIC.round_batches(n, nb, l)
                        == batching_plan_columns(n, nb, l)
                    )

    def test_fold_batch_slices_reference(self):
        rng = np.random.default_rng(1)
        for pr, pc, l in [(1, 1, 1), (2, 2, 2), (4, 2, 1)]:
            for nb in (1, 2, 4):
                wl = nb * 5
                x = rng.integers(0, 9, size=(pr, pc, l, wl))
                got = BLOCK_CYCLIC.fold_batch_slices(x, nb)
                ref = x.reshape(pr, pc, l, nb, wl // nb).sum(axis=-1)
                np.testing.assert_array_equal(got, ref)

    def test_batch_column_map_matches_triple_loop_reference(self):
        def ref(n, pc, l, nb, batch):
            w = n // pc
            wb = w // nb
            wbl = w // (nb * l)
            out = np.zeros((pc, l, wb // l), np.int64)
            for j in range(pc):
                for k in range(l):
                    for c in range(wb // l):
                        d_col = k * (wb // l) + c
                        t, within = d_col // wbl, d_col % wbl
                        out[j, k, c] = j * w + (t * nb + batch) * wbl + within
            return out

        for n, pc, l, nb in [(64, 2, 2, 2), (48, 2, 1, 4), (96, 4, 1, 2),
                             (32, 1, 1, 4), (64, 1, 2, 2)]:
            grid = SimpleNamespace(pc=pc, l=l)
            for batch in range(nb):
                np.testing.assert_array_equal(
                    batch_column_map(n, grid, nb, batch),
                    ref(n, pc, l, nb, batch),
                )
                # every batch covers each of its columns exactly once
                cols = batch_column_map(n, grid, nb, batch).ravel()
                assert len(set(cols.tolist())) == cols.size

    def test_explicit_block_cyclic_spec_plans_identically(self):
        a = gen.erdos_renyi(64, 4.0, seed=2)
        b = gen.erdos_renyi(64, 4.0, seed=3)
        counts = host_symbolic_counts(a, b, (2, 2, 2))
        inputs = PlanInputs.from_host(a, b, (2, 2, 2))
        p0 = plan_from_symbolic(
            counts, inputs, 1 << 30, PlanSpec(local_path="esc"), PlanFloors()
        )
        p1 = plan_from_symbolic(
            counts, inputs, 1 << 30,
            PlanSpec(local_path="esc", distribution=BLOCK_CYCLIC),
            PlanFloors(),
        )
        assert (p0.num_batches, p0.caps, p0.sel_cap, p0.mask_sel_cap) == (
            p1.num_batches, p1.caps, p1.sel_cap, p1.mask_sel_cap
        )
        assert (p0.local_path, p0.total_flops, p0.max_unmerged_nnz) == (
            p1.local_path, p1.total_flops, p1.max_unmerged_nnz
        )
        np.testing.assert_array_equal(p0.per_batch_flops, p1.per_batch_flops)

    def test_driver_rejects_non_block_cyclic_distribution(self):
        class RowwiseDistribution(Distribution):
            name = "rowwise"

        grid = grid1()
        rng = np.random.default_rng(4)
        a = _rand_int_sparse(16, 0.2, rng)
        from repro.core.distsparse import scatter_to_grid

        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(a, grid, "B")
        with pytest.raises(ValueError, match="block-cyclic"):
            batched_summa3d(
                A, B, grid, 1 << 22, lambda bi, c, cm: None,
                spec=PlanSpec(distribution=RowwiseDistribution()),
            )

    def test_driver_rejects_strategy_string_placement(self):
        grid = grid1()
        rng = np.random.default_rng(5)
        a = _rand_int_sparse(16, 0.2, rng)
        from repro.core.distsparse import scatter_to_grid

        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(a, grid, "B")
        with pytest.raises(ValueError, match="multiply_placed"):
            batched_summa3d(
                A, B, grid, 1 << 22, lambda bi, c, cm: None,
                spec=PlanSpec(placement="degree"),
            )


# ---------------------------------------------------------------------------
# Placement permutations
# ---------------------------------------------------------------------------
class TestPlacementPermutations:
    def test_identity_placement_is_identity(self):
        p = Placement.identity(8, 12, 16)
        assert p.is_identity
        np.testing.assert_array_equal(
            p.original_cols(np.arange(16)), np.arange(16)
        )

    def test_strategies_produce_bijections_with_exact_inverses(self):
        a = gen.symmetrized(gen.rmat(5, edge_factor=4, seed=1))
        for strategy in ("degree", "rcm"):
            p = compute_placement(a, a, strategy)
            for perm, inv in [(p.row_perm, p.row_inv), (p.k_perm, p.k_inv),
                              (p.col_perm, p.col_inv)]:
                n = perm.shape[0]
                assert sorted(perm.tolist()) == list(range(n))
                np.testing.assert_array_equal(inv[perm], np.arange(n))

    def test_apply_then_invert_roundtrips_structure(self):
        rng = np.random.default_rng(6)
        a = _rand_int_sparse(32, 0.2, rng)
        b = _rand_int_sparse(32, 0.2, rng)
        p = compute_placement(a, b, "degree")
        ap = p.apply_a(a)
        nnz = int(ap.nnz)
        rows = p.original_rows(np.asarray(ap.rows[:nnz]))
        cols = p.k_inv[np.asarray(ap.cols[:nnz])]
        got = np.zeros((32, 32), np.float32)
        got[rows, cols] = np.asarray(ap.vals[:nnz])
        want = np.zeros((32, 32), np.float32)
        want[np.asarray(a.rows[: a.nnz]), np.asarray(a.cols[: a.nnz])] = (
            np.asarray(a.vals[: a.nnz])
        )
        np.testing.assert_array_equal(got, want)

    def test_degree_spreads_hubs_across_aligned_blocks(self):
        """R-MAT hubs concentrate at low indices; after the degree spread
        every aligned half/quarter holds a near-equal share of the nnz —
        the property that lowers the fold maxima the caps derive from."""
        a = gen.symmetrized(gen.rmat(6, edge_factor=8, seed=5))
        n = a.shape[1]
        colc = np.bincount(np.asarray(a.cols[: a.nnz]), minlength=n)
        p = compute_placement(a, a, "degree")
        placed = np.zeros(n, np.int64)
        placed[p.col_perm] = colc
        for blocks in (2, 4):
            before = colc.reshape(blocks, -1).sum(axis=1)
            after = placed.reshape(blocks, -1).sum(axis=1)
            assert after.max() < before.max()

    def test_rcm_requires_square_operands(self):
        a = gen.erdos_renyi(16, 2.0, seed=0, square=False, ncols=32)
        with pytest.raises(ValueError, match="square"):
            compute_placement(a, gen.erdos_renyi(32, 2.0, seed=1), "rcm")

    def test_unknown_strategy_raises(self):
        a = gen.erdos_renyi(16, 2.0, seed=0)
        with pytest.raises(ValueError, match="unknown placement strategy"):
            compute_placement(a, a, "hypergraph")


# ---------------------------------------------------------------------------
# Property-based permutation invariance (the tentpole guarantee)
# ---------------------------------------------------------------------------
_SEMIRINGS = {
    "plus_times": sr.PLUS_TIMES,
    "min_plus": sr.MIN_PLUS,
    "max_times": sr.MAX_TIMES,
}


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    semiring=st.sampled_from(sorted(_SEMIRINGS)),
    masked=st.booleans(),
    path=st.sampled_from(["esc", "binned", "hash"]),
    strategy=st.sampled_from(["degree", "rcm"]),
)
def test_permute_multiply_unpermute_is_exact(
    seed, semiring, masked, path, strategy
):
    if path == "binned" and semiring != "plus_times":
        path = "esc"  # the k-binned local multiply is plus_times-only
    grid = grid1()
    rng = np.random.default_rng(seed)
    n = 16
    a = _rand_int_sparse(n, 0.25, rng)
    b = _rand_int_sparse(n, 0.25, rng)
    mask = _rand_int_sparse(n, 0.3, rng) if masked else None
    spec = PlanSpec(local_path=path, force_num_batches=2)
    kwargs = dict(semiring=_SEMIRINGS[semiring], spec=spec, mask=mask)
    base = multiply_placed(
        a, b, grid, 1 << 22, placement=Placement.identity(n, n, n), **kwargs
    )
    placed = multiply_placed(a, b, grid, 1 << 22, strategy=strategy, **kwargs)
    assert placed.placement.strategy == strategy
    fill = np.inf if semiring == "min_plus" else 0.0
    np.testing.assert_array_equal(
        placed.to_dense(fill), base.to_dense(fill),
        err_msg=f"{semiring}/{path}/{strategy} masked={masked} seed={seed}",
    )


def test_placed_plus_times_matches_dense_reference():
    """Anchor the invariance suite: the identity-placement run itself is
    the true product (not merely self-consistent)."""
    grid = grid1()
    rng = np.random.default_rng(7)
    n = 16
    a = _rand_int_sparse(n, 0.25, rng)
    b = _rand_int_sparse(n, 0.25, rng)
    placed = multiply_placed(
        a, b, grid, 1 << 22, strategy="degree",
        spec=PlanSpec(local_path="esc", force_num_batches=2),
    )
    xa = np.zeros((n, n), np.float32)
    xa[np.asarray(a.rows[: a.nnz]), np.asarray(a.cols[: a.nnz])] = (
        np.asarray(a.vals[: a.nnz])
    )
    xb = np.zeros((n, n), np.float32)
    xb[np.asarray(b.rows[: b.nnz]), np.asarray(b.cols[: b.nnz])] = (
        np.asarray(b.vals[: b.nnz])
    )
    np.testing.assert_array_equal(placed.to_dense(), xa @ xb)


# ---------------------------------------------------------------------------
# Plan ordering on R-MAT skew (host oracle — no devices)
# ---------------------------------------------------------------------------
class TestPlacementPlanOrdering:
    GRID_SHAPE = (2, 2, 2)
    R_BYTES = 12

    def _plan(self, a, b, ppm):
        counts = host_symbolic_counts(a, b, self.GRID_SHAPE)
        inputs = PlanInputs.from_host(a, b, self.GRID_SHAPE)
        return plan_from_symbolic(
            counts, inputs, ppm, PlanSpec(local_path="esc"), PlanFloors()
        )

    def test_degree_rmat_plan_never_worse_and_strictly_fewer_padded_bytes(
        self,
    ):
        a = gen.symmetrized(gen.rmat(7, edge_factor=8, seed=5))
        # the probe_memory_budget math, host-side: inputs + 1/3 of the
        # probed unmerged output, so the block-cyclic plan must batch
        probe = self._plan(a, a, 1 << 30)
        ppm = self.R_BYTES * 2 * int(a.nnz) + max(
            self.R_BYTES * probe.max_unmerged_nnz // 3, 256
        )
        base = self._plan(a, a, ppm)
        placement = compute_placement(a, a, "degree")
        placed = self._plan(placement.apply_a(a), placement.apply_b(a), ppm)
        v_base = padded_comm_volume(base, self.GRID_SHAPE, self.R_BYTES)
        v_placed = padded_comm_volume(placed, self.GRID_SHAPE, self.R_BYTES)
        assert base.num_batches > 1  # the budget actually forces batching
        assert placed.num_batches <= base.num_batches
        assert v_placed.all_to_all_bytes <= v_base.all_to_all_bytes
        assert v_placed.gather_bytes <= v_base.gather_bytes
        assert v_placed.total_bytes < v_base.total_bytes

    def test_padded_volume_terms(self):
        a = gen.erdos_renyi(64, 4.0, seed=9)
        plan = self._plan(a, a, 1 << 30)
        v = padded_comm_volume(plan, self.GRID_SHAPE, self.R_BYTES)
        pr, _, l = self.GRID_SHAPE
        nb = plan.num_batches
        assert v.all_to_all_bytes == (
            nb * self.R_BYTES * plan.caps.piece_cap * (l - 1)
        )
        assert v.gather_bytes == nb * self.R_BYTES * plan.sel_cap * (pr - 1)
        assert v.total_bytes == v.all_to_all_bytes + v.gather_bytes
        # single-process grids move nothing
        v1 = padded_comm_volume(plan, (1, 1, 1), self.R_BYTES)
        assert v1.total_bytes == 0


# ---------------------------------------------------------------------------
# Rectangular-grid host oracle (autotuner candidate coverage)
# ---------------------------------------------------------------------------
class TestRectangularOracle:
    @pytest.mark.parametrize("grid_shape", [(4, 2, 1), (2, 4, 1), (1, 4, 1)])
    def test_rectangular_percol_matches_dense_reference(self, grid_shape):
        a = gen.erdos_renyi(64, 4.0, seed=11)
        b = gen.erdos_renyi(64, 4.0, seed=12)
        pr, pc, l = grid_shape
        counts = host_symbolic_counts(a, b, grid_shape)
        # per-(row block, output column) flops from the dense patterns
        pa = np.zeros((64, 64), bool)
        pa[np.asarray(a.rows[: a.nnz]), np.asarray(a.cols[: a.nnz])] = True
        pb = np.zeros((64, 64), bool)
        pb[np.asarray(b.rows[: b.nnz]), np.asarray(b.cols[: b.nnz])] = True
        tn = 64 // pc
        for i in range(pr):
            a_colc = pa[i * (64 // pr):(i + 1) * (64 // pr)].sum(axis=0)
            want = a_colc @ pb  # flops per output column for row block i
            got = np.concatenate([counts.percol[i, j, 0] for j in range(pc)])
            np.testing.assert_array_equal(got, want)
        assert counts.percol.shape == (pr, pc, l, tn)

    def test_rectangular_multi_layer_rejected(self):
        a = gen.erdos_renyi(64, 4.0, seed=13)
        with pytest.raises(AssertionError):
            host_symbolic_counts(a, a, (4, 2, 2))
        with pytest.raises(AssertionError):
            make_grid(4, 2, 2)
