"""Fast-lane tests for the masked (filtered-semiring) SpGEMM path (§V-B).

Single-device (1x1x1) coverage of the masked pipeline: the symbolic mask
counts against a dense reference, the masked plan's capacity ordering
(incl. the empty-mask and full-mask edges), the fused multiply under strict
and complement masks across batch counts, and binned/ESC parity behind the
plan switch. The 8-device R-MAT parity cases (triangle counting, overlap
detection) live in ``tests/app_cases.py`` (slow lane).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sparse as sp
from repro.core.batched import batched_summa3d, plan_batches, symbolic3d_counts
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.symbolic import rup_pow2
from repro.sparse_apps.mcl import _sparse_batch_to_global


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _rand_sparse(n, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.5, 1.0, (n, n)).astype(np.float32)
    return np.where(rng.random((n, n)) < density, x, 0.0).astype(np.float32)


def _mask_coo(mask_dense):
    m, n = mask_dense.shape
    mr, mc = np.nonzero(mask_dense)
    return sp.from_numpy_coo(
        mr, mc, np.ones(len(mr), np.float32), (m, n), cap=max(len(mr), 8)
    )


def _operands(grid, n=32, seed=0):
    xa = _rand_sparse(n, 0.3, seed)
    xb = _rand_sparse(n, 0.3, seed + 1)
    A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024), grid, "A")
    B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024), grid, "B")
    return xa, xb, A, B


def _multiply(A, B, grid, nb, mask=None, complement=False, binned="auto"):
    n = B.shape[1]
    got = np.zeros((A.shape[0], n), np.float32)

    def consumer(bi, c, cm):
        rr, cc, vv = _sparse_batch_to_global(c, cm)
        got[rr, cc] += vv

    res = batched_summa3d(
        A, B, grid, per_process_memory=1 << 26, consumer=consumer,
        path="sparse", force_num_batches=nb, mask=mask,
        mask_complement=complement, binned=binned,
    )
    return got, res


class TestMaskedSymbolicCounts:
    def test_mask_colcounts_exact(self, grid1, n=32):
        """The emitted mask counts are EXACT per-(tile, column) nnz — the
        guarantee that sizes the mask-slice selection without overflow."""
        xa, xb, A, B = _operands(grid1, n)
        mask_dense = np.random.default_rng(7).random((n, n)) < 0.2
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        counts = symbolic3d_counts(A, B, grid1, mask=M)
        np.testing.assert_array_equal(
            counts.mask_colcounts[0, 0, 0], mask_dense.sum(axis=0)
        )

    def test_masked_bounds_sound_vs_dense_reference(self, grid1, n=32):
        """The masked plan capacities bound the true masked product: running
        the multiply at plan capacities never overflows (zero retries) and
        the dense-reference masked product is reproduced exactly."""
        xa, xb, A, B = _operands(grid1, n)
        mask_dense = np.random.default_rng(11).random((n, n)) < 0.15
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        for nb in (1, 2, 4):
            got, res = _multiply(A, B, grid1, nb, mask=M)
            assert res.num_retries == 0
            np.testing.assert_allclose(
                got, (xa @ xb) * mask_dense, rtol=1e-4, atol=1e-5
            )

    def test_masked_caps_below_unmasked(self, grid1, n=32):
        """A sparse strict mask must shrink the planned D/C capacities (the
        §V-B memory win the batch plan is supposed to realize)."""
        _, _, A, B = _operands(grid1, n)
        mask_dense = np.random.default_rng(13).random((n, n)) < 0.1
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        pm = plan_batches(A, B, grid1, per_process_memory=1 << 26, mask=M)
        pu = plan_batches(A, B, grid1, per_process_memory=1 << 26)
        assert pm.caps.d_cap < pu.caps.d_cap
        assert pm.caps.c_cap < pu.caps.c_cap
        assert pm.caps.piece_cap <= pu.caps.piece_cap
        assert pm.max_unmerged_nnz < pu.max_unmerged_nnz
        assert pm.mask_sel_cap > 0

    def test_masked_batch_count_below_unmasked(self, grid1, n=32):
        """Under a budget that forces the unmasked multiply to batch, the
        masked plan needs strictly fewer batches (same shared budget math
        the graph bench and R-MAT slow case assert against)."""
        from repro.core.batched import probe_memory_budget

        _, _, A, B = _operands(grid1, n)
        mask_dense = np.random.default_rng(17).random((n, n)) < 0.05
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        ppm = probe_memory_budget(A, B, grid1)
        pu = plan_batches(A, B, grid1, per_process_memory=ppm)
        pm = plan_batches(A, B, grid1, per_process_memory=ppm, mask=M)
        assert pu.num_batches > 1
        assert pm.num_batches < pu.num_batches


class TestMaskedMultiply:
    @pytest.mark.parametrize("complement", [False, True])
    @pytest.mark.parametrize("nb", [1, 2])
    def test_matches_dense_reference(self, grid1, complement, nb, n=32):
        xa, xb, A, B = _operands(grid1, n)
        mask_dense = np.random.default_rng(19).random((n, n)) < 0.2
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        got, res = _multiply(A, B, grid1, nb, mask=M, complement=complement)
        keep = ~mask_dense if complement else mask_dense
        np.testing.assert_allclose(got, (xa @ xb) * keep, rtol=1e-4, atol=1e-5)
        assert res.num_retries == 0

    def test_empty_mask_yields_empty_product(self, grid1, n=32):
        xa, xb, A, B = _operands(grid1, n)
        M = scatter_to_grid(_mask_coo(np.zeros((n, n), bool)), grid1, "C")
        got, res = _multiply(A, B, grid1, 2, mask=M)
        np.testing.assert_array_equal(got, np.zeros((n, n), np.float32))
        assert res.num_retries == 0
        # the plan collapsed to the floor capacities, not the full product
        pu = plan_batches(A, B, grid1, per_process_memory=1 << 26)
        assert res.plan.caps.d_cap < pu.caps.d_cap

    def test_full_mask_equals_unmasked(self, grid1, n=32):
        xa, xb, A, B = _operands(grid1, n)
        M = scatter_to_grid(_mask_coo(np.ones((n, n), bool)), grid1, "C")
        got_m, _ = _multiply(A, B, grid1, 2, mask=M)
        got_u, _ = _multiply(A, B, grid1, 2)
        np.testing.assert_allclose(got_m, got_u, rtol=1e-6)
        np.testing.assert_allclose(got_m, xa @ xb, rtol=1e-4, atol=1e-5)

    def test_empty_complement_mask_equals_unmasked(self, grid1, n=32):
        xa, xb, A, B = _operands(grid1, n)
        M = scatter_to_grid(_mask_coo(np.zeros((n, n), bool)), grid1, "C")
        got, _ = _multiply(A, B, grid1, 2, mask=M, complement=True)
        np.testing.assert_allclose(got, xa @ xb, rtol=1e-4, atol=1e-5)

    def test_binned_matches_esc_under_mask(self, grid1, n=32):
        """The masked filter is applied identically by the ESC packed-key
        intersect and the binned dense-accumulator indicator."""
        xa, xb, A, B = _operands(grid1, n, seed=29)
        mask_dense = np.random.default_rng(23).random((n, n)) < 0.2
        M = scatter_to_grid(_mask_coo(mask_dense), grid1, "C")
        got_esc, _ = _multiply(A, B, grid1, 2, mask=M, binned=False)
        got_bin, res = _multiply(A, B, grid1, 2, mask=M, binned=True)
        assert res.binned
        np.testing.assert_allclose(got_bin, got_esc, rtol=1e-5, atol=1e-6)


class TestPow2Rounding:
    def test_rup_pow2(self):
        assert [rup_pow2(x) for x in (1, 2, 3, 8, 9, 1000)] == [
            1, 2, 4, 8, 16, 1024,
        ]

    def test_caps_pow2_and_floor(self, grid1, n=32):
        from repro.core.summa3d import BatchCaps

        _, _, A, B = _operands(grid1, n)
        p = plan_batches(A, B, grid1, per_process_memory=1 << 26,
                         caps_pow2=True)
        for c in (p.caps.flops_cap, p.caps.d_cap, p.caps.piece_cap,
                  p.caps.c_cap):
            assert c == rup_pow2(c)  # powers of two
        floor = BatchCaps(1 << 20, 1 << 20, 1 << 20, 1 << 20)
        pf = plan_batches(A, B, grid1, per_process_memory=1 << 26,
                          caps_pow2=True, caps_floor=floor,
                          sel_cap_floor=12345)
        assert pf.caps == floor
        assert pf.sel_cap >= 12345
