"""Fast-lane tests for the unified PlanSpec / PlanFloors / ExecSpec API.

Single-device grid: the legacy-kwarg shim produces the IDENTICAL ``BatchPlan``
and fused-step static signature (zero extra traces via
``summa3d.TRACE_COUNTS``) as the spec path, under exactly one
``DeprecationWarning``; unknown kwargs still raise ``TypeError``;
``PlanFloors.merged`` is a monotonic fold that JSON round-trips; and
``LookaheadWindow.from_exec`` is the one place exec policy becomes schedule
depth.
"""
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sparse as sp
from repro.core import summa3d
from repro.core.batched import batched_summa3d, plan_batches
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.specs import (
    ExecSpec,
    PlanFloors,
    PlanSpec,
    resolve_specs,
)
from repro.core.summa3d import BatchCaps, BinnedCaps, HashCaps
from repro.runtime.driver import LookaheadWindow


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _rand_sparse(n, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.5, 1.0, (n, n)).astype(np.float32)
    return np.where(rng.random((n, n)) < density, x, 0.0).astype(np.float32)


def _operands(grid, n=32, seed=0):
    xa = _rand_sparse(n, 0.3, seed)
    xb = _rand_sparse(n, 0.3, seed + 1)
    A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=1024), grid, "A")
    B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=1024), grid, "B")
    return xa, xb, A, B


class TestKwargShim:
    def test_plan_batches_legacy_equals_spec(self, grid1):
        """Old kwargs and the spec objects produce the IDENTICAL plan."""
        _, _, A, B = _operands(grid1, seed=1)
        with pytest.warns(DeprecationWarning, match="plan_batches"):
            legacy = plan_batches(
                A, B, grid1, per_process_memory=1 << 24,
                force_num_batches=2, local_path="esc", slack=1.5,
            )
        new = plan_batches(
            A, B, grid1, per_process_memory=1 << 24,
            spec=PlanSpec(force_num_batches=2, local_path="esc", slack=1.5),
        )
        assert legacy.num_batches == new.num_batches
        assert legacy.caps == new.caps
        assert legacy.sel_cap == new.sel_cap
        assert legacy.local_path == new.local_path
        np.testing.assert_array_equal(legacy.per_batch_flops,
                                      new.per_batch_flops)

    def test_bare_plan_keeps_esc_default(self, grid1):
        """No spec, no kwargs → historical local_path="esc" default; a
        passed spec opts into the "auto" plan-driven dispatch."""
        _, _, A, B = _operands(grid1, seed=2)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # bare call must NOT warn
            bare = plan_batches(A, B, grid1, per_process_memory=1 << 24)
        assert bare.local_path == "esc"

    def test_batched_legacy_same_signature_zero_retrace(self, grid1):
        """The shim maps onto the same static signature: running the legacy
        spelling after the spec spelling compiles NOTHING new."""
        xa, xb, A, B = _operands(grid1, seed=3)
        kw = dict(per_process_memory=1 << 24, path="sparse",
                  consumer=lambda bi, c, cm: None)
        res_new = batched_summa3d(
            A, B, grid1, spec=PlanSpec(force_num_batches=2, local_path="esc"),
            exec_spec=ExecSpec(lookahead=1), **kw)
        t0 = summa3d.TRACE_COUNTS["fused_step"]
        with pytest.warns(DeprecationWarning, match="batched_summa3d"):
            res_old = batched_summa3d(
                A, B, grid1, force_num_batches=2, local_path="esc",
                lookahead=1, **kw)
        assert summa3d.TRACE_COUNTS["fused_step"] - t0 == 0
        assert res_old.plan.caps == res_new.plan.caps
        assert res_old.plan.num_batches == res_new.plan.num_batches
        assert res_old.plan.sel_cap == res_new.plan.sel_cap
        assert res_old.local_path == res_new.local_path

    def test_legacy_floor_kwargs_map_to_floors(self, grid1):
        _, _, A, B = _operands(grid1, seed=4)
        caps = BatchCaps(4096, 4096, 4096, 4096)
        with pytest.warns(DeprecationWarning):
            legacy = plan_batches(
                A, B, grid1, per_process_memory=1 << 24,
                caps_floor=caps, sel_cap_floor=512, num_batches_floor=4,
            )
        new = plan_batches(
            A, B, grid1, per_process_memory=1 << 24,
            floors=PlanFloors(caps=caps, sel_cap=512, num_batches=4),
        )
        assert legacy.caps == new.caps
        assert legacy.sel_cap == new.sel_cap == 512
        assert legacy.num_batches == new.num_batches == 4

    def test_unknown_kwarg_raises_typeerror(self, grid1):
        _, _, A, B = _operands(grid1, seed=5)
        with pytest.raises(TypeError, match="nonsense"):
            plan_batches(A, B, grid1, per_process_memory=1 << 24,
                         nonsense=1)
        # exec-only kwargs are not part of plan_batches' surface
        with pytest.raises(TypeError, match="lookahead"):
            plan_batches(A, B, grid1, per_process_memory=1 << 24,
                         lookahead=3)

    def test_single_warning_lists_all_legacy_keys(self):
        with pytest.warns(DeprecationWarning) as rec:
            resolve_specs(None, None, None,
                          {"slack": 1.1, "lookahead": 3},
                          where="batched_summa3d")
        assert len(rec) == 1
        msg = str(rec[0].message)
        assert "slack" in msg and "lookahead" in msg


class TestPlanFloors:
    def test_merged_monotone(self):
        a = PlanFloors(caps=BatchCaps(8, 16, 32, 64), sel_cap=10,
                       num_batches=2,
                       hash_caps=HashCaps(128, 64, 8), caps_pow2=False)
        b = PlanFloors(caps=BatchCaps(16, 8, 64, 32), sel_cap=5,
                       num_batches=4,
                       hash_caps=HashCaps(64, 128, 16), caps_pow2=True)
        m = a.merged(b)
        assert m.caps == BatchCaps(16, 16, 64, 64)
        assert m.sel_cap == 10 and m.num_batches == 4
        assert m.hash_caps == HashCaps(128, 128, 16)
        assert m.caps_pow2 is True
        # dominance: merging the fold back in is a no-op (idempotent max)
        assert m.merged(a) == m and m.merged(b) == m
        # commutative
        assert b.merged(a) == m

    def test_merged_none_fields(self):
        a = PlanFloors(sel_cap=3)
        b = PlanFloors(caps=BatchCaps(1, 2, 3, 4))
        m = a.merged(b)
        assert m.caps == BatchCaps(1, 2, 3, 4) and m.sel_cap == 3
        assert m.kbin_caps is None and m.hash_caps is None

    def test_merged_bin_count_mismatch_raises(self):
        a = PlanFloors(kbin_caps=BinnedCaps(4, 64, 64))
        b = PlanFloors(kbin_caps=BinnedCaps(8, 64, 64))
        with pytest.raises(ValueError, match="bin counts"):
            a.merged(b)

    def test_meta_round_trip(self):
        f = PlanFloors(caps=BatchCaps(8, 16, 32, 64), sel_cap=7,
                       num_batches=3, kbin_caps=BinnedCaps(4, 8, 8),
                       hash_caps=HashCaps(32, 16, 4), caps_pow2=True)
        assert PlanFloors.from_meta(f.to_meta()) == f
        assert PlanFloors.from_meta(None) == PlanFloors()
        assert PlanFloors.from_meta({}) == PlanFloors()


class TestExecWindow:
    def test_from_exec_depth(self):
        done = []
        w = LookaheadWindow.from_exec(ExecSpec(lookahead=3), done.append)
        assert w.depth == 3
        w = LookaheadWindow.from_exec(
            ExecSpec(pipelined=False, lookahead=3), done.append)
        assert w.depth == 0
        w.push(1)
        assert done == [1]  # synchronous: completes on push
