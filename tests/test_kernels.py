"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracles, with
shape/dtype sweeps and block-shape sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sparse as sp
from repro.kernels import ops, ref
from repro.kernels.densify import densify_pallas
from repro.kernels.spgemm_acc import spgemm_paired_pallas
from repro.kernels.spmm import spmm_pallas


def dense_random(rng, m, n, density, dtype=np.float32):
    x = rng.standard_normal((m, n)).astype(dtype)
    mask = rng.random((m, n)) < density
    return np.where(mask, x, 0.0).astype(dtype)


SHAPES = [(8, 8, 8), (16, 24, 8), (33, 17, 9), (64, 40, 128), (128, 128, 130)]
DTYPES = [np.float32, jnp.bfloat16]


class TestSpMMKernel:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_sweep_vs_ref(self, m, k, n, dtype):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        A = dense_random(rng, m, k, 0.3)
        B = dense_random(rng, k, n, 0.8).astype(dtype)
        a = sp.from_dense(jnp.asarray(A), cap=m * k // 2 + m)
        vals = jnp.where(a.valid_mask(), a.vals, 0).astype(dtype)
        got = spmm_pallas(a.rows, a.cols, vals, jnp.asarray(B), m,
                          m_blk=16, n_blk=128, k_blk=16, nnz_blk=32)
        want = ref.spmm_ref(a.rows, a.cols, vals, jnp.asarray(B), m)
        tol = 1e-5 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_block_shape_sweep(self):
        rng = np.random.default_rng(7)
        m, k, n = 40, 24, 48
        A = dense_random(rng, m, k, 0.4)
        B = dense_random(rng, k, n, 0.9)
        a = sp.from_dense(jnp.asarray(A), cap=600)
        vals = jnp.where(a.valid_mask(), a.vals, 0)
        want = A @ B
        for m_blk, n_blk, k_blk, nnz_blk in [(8, 128, 8, 8), (40, 128, 24, 600),
                                             (16, 128, 16, 64)]:
            got = spmm_pallas(a.rows, a.cols, vals, jnp.asarray(B), m,
                              m_blk=m_blk, n_blk=n_blk, k_blk=k_blk, nnz_blk=nnz_blk)
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    def test_ops_wrapper_pallas_matches_jnp(self):
        rng = np.random.default_rng(3)
        A = dense_random(rng, 20, 30, 0.3)
        B = dense_random(rng, 30, 16, 0.9)
        a = sp.from_dense(jnp.asarray(A), cap=250)
        got_p = ops.spmm(a, jnp.asarray(B), use_pallas=True)
        got_j = ops.spmm(a, jnp.asarray(B), use_pallas=False)
        np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_j),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_j), A @ B, rtol=1e-4, atol=1e-5)


class TestPairedSpGEMMKernel:
    @pytest.mark.parametrize("m,k,n", SHAPES[:4])
    def test_sweep_vs_ref(self, m, k, n):
        rng = np.random.default_rng(m + k + n)
        A = dense_random(rng, m, k, 0.3)
        B = dense_random(rng, k, n, 0.3)
        a = sp.from_dense(jnp.asarray(A), cap=m * k // 2 + m)
        b = sp.from_dense(jnp.asarray(B), cap=k * n // 2 + n)
        av = jnp.where(a.valid_mask(), a.vals, 0)
        bv = jnp.where(b.valid_mask(), b.vals, 0)
        got = spgemm_paired_pallas(a.rows, a.cols, av, b.rows, b.cols, bv, m, n,
                                   m_blk=16, n_blk=128, a_blk=32, b_blk=32)
        np.testing.assert_allclose(np.asarray(got), A @ B, rtol=1e-4, atol=1e-4)

    def test_unsorted_entries(self):
        """Sort-free: arbitrary entry order must give identical results."""
        rng = np.random.default_rng(11)
        m, k, n = 24, 16, 24
        A = dense_random(rng, m, k, 0.4)
        B = dense_random(rng, k, n, 0.4)
        a = sp.from_dense(jnp.asarray(A), cap=200)
        b = sp.from_dense(jnp.asarray(B), cap=200)
        av = jnp.where(a.valid_mask(), a.vals, 0)
        bv = jnp.where(b.valid_mask(), b.vals, 0)
        perm = rng.permutation(200)
        got = spgemm_paired_pallas(
            a.rows[perm], a.cols[perm], av[perm], b.rows, b.cols, bv, m, n,
            m_blk=8, n_blk=128, a_blk=40, b_blk=40,
        )
        np.testing.assert_allclose(np.asarray(got), A @ B, rtol=1e-4, atol=1e-4)

    def test_ops_wrapper(self):
        rng = np.random.default_rng(5)
        A = dense_random(rng, 16, 12, 0.4)
        B = dense_random(rng, 12, 8, 0.4)
        a = sp.from_dense(jnp.asarray(A), cap=100)
        b = sp.from_dense(jnp.asarray(B), cap=60)
        got_p = ops.spgemm_paired(a, b, use_pallas=True)
        got_j = ops.spgemm_paired(a, b, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_j),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_j), A @ B, rtol=1e-4, atol=1e-5)


class TestDensifyKernel:
    @pytest.mark.parametrize("m,n", [(8, 8), (17, 33), (64, 128), (130, 60)])
    def test_sweep_vs_ref(self, m, n):
        rng = np.random.default_rng(m * n)
        X = dense_random(rng, m, n, 0.3)
        a = sp.from_dense(jnp.asarray(X), cap=m * n // 2 + m)
        vals = jnp.where(a.valid_mask(), a.vals, 0)
        got = densify_pallas(a.rows, a.cols, vals, m, n,
                             m_blk=16, n_blk=128, nnz_blk=64)
        np.testing.assert_allclose(np.asarray(got), X, rtol=1e-6)

    def test_duplicate_coords_accumulate(self):
        rows = jnp.array([1, 1, 2, 1], jnp.int32)
        cols = jnp.array([3, 3, 0, 3], jnp.int32)
        vals = jnp.array([1.0, 2.0, 5.0, 3.0], jnp.float32)
        got = densify_pallas(rows, cols, vals, 4, 4, m_blk=8, n_blk=128, nnz_blk=8)
        assert got[1, 3] == 6.0 and got[2, 0] == 5.0

    def test_ops_wrapper(self):
        rng = np.random.default_rng(9)
        X = dense_random(rng, 12, 20, 0.4)
        a = sp.from_dense(jnp.asarray(X), cap=120)
        np.testing.assert_allclose(
            np.asarray(ops.densify(a, use_pallas=True)), X, rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(ops.densify(a, use_pallas=False)), X, rtol=1e-6
        )


class TestKernelIntegration:
    def test_dense_acc_spgemm_via_kernels(self):
        """densify(B batch block) + spmm == paired kernel == dense oracle —
        the two kernel realizations of the batched local multiply agree."""
        rng = np.random.default_rng(21)
        m, k, n = 32, 24, 16
        A = dense_random(rng, m, k, 0.3)
        B = dense_random(rng, k, n, 0.3)
        a = sp.from_dense(jnp.asarray(A), cap=300)
        b = sp.from_dense(jnp.asarray(B), cap=200)
        bd = ops.densify(b, use_pallas=True)
        c1 = ops.spmm(a, bd, use_pallas=True)
        c2 = ops.spgemm_paired(a, b, use_pallas=True)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(c1), A @ B, rtol=1e-4, atol=1e-4)


class TestColPruneKernel:
    """Per-column top-k threshold (MCL batch consumption, paper §V-C)."""

    @pytest.mark.parametrize("m,n,k", [(32, 16, 4), (64, 128, 8), (17, 33, 3)])
    def test_threshold_keeps_at_most_k(self, m, n, k):
        from repro.kernels.col_prune import col_topk_threshold_pallas

        rng = np.random.default_rng(m * n + k)
        x = rng.standard_normal((m, n)).astype(np.float32)
        t = np.asarray(col_topk_threshold_pallas(jnp.asarray(x), k))
        counts = (np.abs(x) >= t[None, :]).sum(0)
        assert (counts <= k).all(), counts.max()
        # threshold must not over-prune: at least k kept unless impossible
        # (bisection resolves ties to <= k; with distinct values == k)
        assert (counts >= min(k, m) - 1).all(), counts.min()

    def test_matches_sorted_oracle_distinct_values(self):
        from repro.kernels.col_prune import (
            col_topk_threshold_pallas,
            col_topk_threshold_ref,
        )

        rng = np.random.default_rng(5)
        m, n, k = 48, 24, 6
        x = rng.permutation(m * n).reshape(m, n).astype(np.float32) + 1.0
        t_k = np.asarray(col_topk_threshold_pallas(jnp.asarray(x), k))
        t_r = np.asarray(col_topk_threshold_ref(jnp.asarray(x), k))
        kept_k = (np.abs(x) >= t_k[None, :])
        kept_r = (np.abs(x) >= t_r[None, :])
        np.testing.assert_array_equal(kept_k, kept_r)

    @pytest.mark.parametrize("m,n,k", [(32, 8, 4), (40, 16, 5)])
    def test_parity_with_numpy_topk_including_ties(self, m, n, k):
        """Threshold selection vs exact numpy top-k with REPEATED values.

        The kernel keeps the largest set with |{x >= t}| <= k. When ties
        straddle the k-th position that set is exactly the entries STRICTLY
        greater than the k-th value (numpy's top-k keeps an arbitrary tie
        subset); without a straddling tie it equals numpy's top-k set."""
        from repro.kernels.col_prune import col_topk_threshold_pallas

        rng = np.random.default_rng(m * n * k)
        # quantized values -> many exact ties, including at the k boundary
        x = (rng.integers(0, 6, (m, n)) * 0.125).astype(np.float32)
        t = np.asarray(col_topk_threshold_pallas(jnp.asarray(x), k))
        for j in range(n):
            col = x[:, j]
            kept = col >= t[j]
            kth = np.sort(col)[::-1][k - 1]  # numpy's exact k-th largest
            if (col >= kth).sum() > k:  # tie straddles the boundary
                np.testing.assert_array_equal(kept, col > kth)
            else:
                np.testing.assert_array_equal(kept, col >= kth)
            assert kept.sum() <= k
