"""Correctness of the §Perf sharding variants: they must be function-exact
(padding) or training-equivalent (strategies) vs the baseline."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.compat import AxisType, make_mesh, set_mesh

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models.attention import attend, init_attention
from repro.optim import adamw
from repro.train import TrainConfig, build_train_step

# compiles model variants — excluded from the CI fast lane (-m 'not slow')
pytestmark = pytest.mark.slow


def tiny_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return make_mesh(dev, ("data", "model"), axis_types=(AxisType.Auto,) * 2)


class TestHeadPadding:
    def test_padded_attention_exact(self):
        """48-head padded attention == 36-head original, bit-for-bit structure:
        zero wq rows -> garbage in pad heads, zero wo rows -> never surfaces,
        per-group layout preserves the q->kv mapping."""
        key = jax.random.PRNGKey(0)
        D, H, KV, hd = 64, 6, 2, 16
        base = init_attention(key, D, H, KV, hd)
        padded = init_attention(key, D, H, KV, hd, pad_heads_to=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        y0, _ = attend(base, x, pos)
        y1, _ = attend(padded, x, pos)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-5)

    def test_padded_model_forward_exact(self):
        cfg = get_config("starcoder2-7b", smoke=True)  # 4 heads kv 2
        cfg_pad = dataclasses.replace(cfg, pad_heads_to=8)
        params = tfm.init_params(cfg, jax.random.PRNGKey(3))
        params_pad = tfm.init_params(cfg_pad, jax.random.PRNGKey(3))
        tok = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, cfg.vocab)
        mesh = tiny_mesh()
        with set_mesh(mesh):
            l0, _ = tfm.forward(cfg, params, tok, mesh)
            l1, _ = tfm.forward(cfg_pad, params_pad, tok, mesh)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=1e-4, atol=1e-4)

    def test_pad_specs_shardable(self):
        cfg = get_config("starcoder2-7b")
        cfg_pad = dataclasses.replace(cfg, pad_heads_to=48)
        specs = tfm.param_specs(cfg_pad, tp=16)
        assert specs["layers"]["attn"]["wq"][2] == "model"
        # unpadded 36 heads cannot shard over 16
        specs0 = tfm.param_specs(cfg, tp=16)
        assert specs0["layers"]["attn"]["wq"][2] is None


class TestStrategies:
    @pytest.mark.parametrize("strategy,master", [("tp", False), ("dp", True)])
    def test_one_step_finite(self, strategy, master):
        cfg = get_config("minitron-8b", smoke=True)
        mesh = tiny_mesh()
        tc = TrainConfig(
            optimizer=adamw.AdamWConfig(lr=1e-3, master_in_opt=master),
            strategy=strategy,
        )
        from repro.data import DataConfig, synthetic_batch

        with set_mesh(mesh):
            step_fn, _, _ = build_train_step(cfg, mesh, tc, global_batch=2)
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            if master:
                params = jax.tree.map(
                    lambda p: p.astype(jnp.bfloat16)
                    if p.dtype == jnp.float32 else p, params
                )
            opt = adamw.init_opt_state(params, master_in_opt=master)
            batch = synthetic_batch(DataConfig(seq_len=8, global_batch=2,
                                               vocab=cfg.vocab), 0)
            p, o, m = step_fn(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        if master:
            assert "master" in o
            # master stays f32, params stay bf16
            assert jax.tree.leaves(o["master"])[0].dtype == jnp.float32

    def test_dp_tp_losses_match(self):
        """Strategy changes sharding, never math: first-step loss identical."""
        cfg = get_config("musicgen-large", smoke=True)
        mesh = tiny_mesh()
        from repro.data import DataConfig, synthetic_batch

        dcfg = DataConfig(seq_len=8, global_batch=2, vocab=cfg.vocab,
                          input_mode=cfg.input_mode, d_model=cfg.d_model)
        batch = synthetic_batch(dcfg, 0)
        losses = {}
        with set_mesh(mesh):
            for strat in ("tp", "dp"):
                step_fn, _, _ = build_train_step(
                    cfg, mesh, TrainConfig(strategy=strat), global_batch=2
                )
                params = tfm.init_params(cfg, jax.random.PRNGKey(0))
                opt = adamw.init_opt_state(params)
                _, _, m = step_fn(params, opt, batch)
                losses[strat] = float(m["loss"])
        np.testing.assert_allclose(losses["tp"], losses["dp"], rtol=1e-4)
