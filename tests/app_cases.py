"""SpGEMM application correctness cases (subprocess, 8 host devices)."""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import gen
from repro.core.grid import make_grid
from repro.core.specs import ExecSpec, PlanSpec
from repro.sparse_apps.graph_algorithms import (
    overlap_pairs,
    overlap_pairs_host,
    overlap_pairs_reference,
    triangle_count,
    triangle_count_host,
    triangle_count_reference,
)
from repro.sparse_apps.mcl import (
    MCLConfig,
    clusters_from_matrix,
    mcl_iterate,
    mcl_iterate_host,
)


def _stochastic_blocks(n, blocks, intra_p, seed):
    """Column-normalized planted-cluster input (MCL operates on a
    column-stochastic matrix)."""
    from repro.core.sparse import from_numpy_coo
    from repro.sparse_apps.mcl import _col_normalize_np

    a = gen.protein_similarity_like(n, blocks=blocks, intra_p=intra_p, seed=seed)
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    vals = np.asarray(a.vals[:nnz]).astype(np.float64)
    vals = _col_normalize_np(rows, cols, vals, n).astype(np.float32)
    return from_numpy_coo(rows, cols, vals, (n, n), cap=nnz)


def _labels(final, n):
    nnz = int(final.nnz)
    return clusters_from_matrix(
        np.asarray(final.rows[:nnz]), np.asarray(final.cols[:nnz]), n
    )


def case_mcl_clusters_blocks():
    """MCL on a 4-block stochastic block matrix must recover ~4 clusters."""
    grid = make_grid(2, 2, 2)
    n = 64
    a = _stochastic_blocks(n, blocks=4, intra_p=0.6, seed=3)
    final, hist = mcl_iterate(
        a, grid, MCLConfig(max_iters=12, per_process_memory=1 << 24), verbose=True
    )
    labels = _labels(final, n)
    ncl = len(set(labels.tolist()))
    assert 2 <= ncl <= 10, f"expected block-ish clustering, got {ncl} clusters"
    # chaos decreased
    assert hist[-1]["chaos"] < hist[0]["chaos"]
    print(f"OK mcl_clusters_blocks (clusters={ncl}, iters={len(hist)})")


def case_mcl_device_matches_host():
    """Device-resident MCL == host-loop reference on a planted two-cluster
    graph: identical per-iteration nnz trajectory, matching final cluster
    partition, chaos converged and decreasing into convergence — including
    under a FORCED multi-batch plan (per-batch pruning exercised) and under
    a tight ``max_per_col`` so the distributed top-k selection actually
    binds (values are distinct, so threshold selection == exact top-k)."""
    grid = make_grid(2, 2, 2)
    n = 64
    a = _stochastic_blocks(n, blocks=2, intra_p=0.6, seed=3)
    for nb, k in ((None, 64), (4, 64), (4, 4)):
        cfg = MCLConfig(max_iters=12, per_process_memory=1 << 24,
                        force_num_batches=nb, max_per_col=k)
        fin_d, hist_d = mcl_iterate(a, grid, cfg)
        fin_h, hist_h = mcl_iterate_host(a, grid, cfg)
        lab_d, lab_h = _labels(fin_d, n), _labels(fin_h, n)
        if k == 64:
            assert len(set(lab_d.tolist())) == 2, set(lab_d.tolist())
        else:  # aggressive top-k may over-fragment; parity is the claim
            assert len(set(lab_d.tolist())) >= 2, set(lab_d.tolist())
        # same partition (labels are representatives, compare co-membership)
        for i in range(n):
            np.testing.assert_array_equal(lab_d == lab_d[i], lab_h == lab_h[i])
        assert [h["nnz"] for h in hist_d] == [h["nnz"] for h in hist_h], (
            hist_d, hist_h)
        if k < 64:  # top-k must have actually pruned below the k=64 runs
            assert hist_d[0]["nnz"] <= n * k, hist_d[0]
        chaos = [h["chaos"] for h in hist_d]
        assert chaos[-1] < cfg.converge_tol, chaos
        assert chaos[-1] < chaos[0] and chaos[-1] < chaos[-2] < chaos[-3], chaos
        # device path moves only stat scalars per iteration; host loop moves
        # the matrix every batch
        assert max(h["host_bytes"] for h in hist_d) < 1024, hist_d
        assert min(h["host_bytes"] for h in hist_h) > 10240, hist_h
    print("OK mcl_device_matches_host")


def case_mcl_dense_path():
    """Dense-path device pipeline (col_prune Pallas postprocess + vectorized
    extraction) matches the sparse device path and the host reference."""
    grid = make_grid(2, 2, 2)
    n = 64
    a = _stochastic_blocks(n, blocks=2, intra_p=0.6, seed=3)
    cfg_d = MCLConfig(max_iters=8, per_process_memory=1 << 24, path="dense",
                      force_num_batches=2, max_per_col=8)
    fin_dense, hist_dense = mcl_iterate(a, grid, cfg_d)
    cfg_s = MCLConfig(max_iters=8, per_process_memory=1 << 24, path="sparse",
                      force_num_batches=2, max_per_col=8)
    _, hist_sparse = mcl_iterate(a, grid, cfg_s)
    cfg_h = MCLConfig(max_iters=8, per_process_memory=1 << 24, path="dense",
                      force_num_batches=2, max_per_col=8)
    fin_host, hist_host = mcl_iterate_host(a, grid, cfg_h)
    assert [h["nnz"] for h in hist_dense] == [h["nnz"] for h in hist_sparse]
    assert [h["nnz"] for h in hist_dense] == [h["nnz"] for h in hist_host]
    lab_d, lab_h = _labels(fin_dense, n), _labels(fin_host, n)
    for i in range(n):
        np.testing.assert_array_equal(lab_d == lab_d[i], lab_h == lab_h[i])
    print("OK mcl_dense_path")


def case_mcl_tied_topk_distributed():
    """k-boundary ties split across GRID ROW BLOCKS: a uniform-degree graph
    (every column = equal values, degree > k) must keep exactly k entries
    per column on both device paths — the distributed rank fill must
    allocate the tie quota consistently across the pr row blocks."""
    import jax
    import jax.numpy as jnp

    from repro.core.distsparse import scatter_to_grid
    from repro.core.sparse import from_dense
    from repro.sparse_apps.mcl import _mcl_prune_dense, _mcl_prune_sparse

    grid = make_grid(2, 2, 2)
    n, deg, k = 64, 12, 5  # deg rows per column straddle both row blocks
    x = np.zeros((n, n), np.float32)
    for j in range(n):
        x[(j + np.arange(0, deg * 5, 5)) % n, j] = 1.0  # spread across blocks
    d = scatter_to_grid(from_dense(jnp.asarray(x), cap=2048), grid, "C")
    pruned, stats = _mcl_prune_sparse(
        d, grid=grid, inflation=2.0, thresh=1e-4, k=k, new_cap=2048,
    )
    assert int(np.asarray(stats["nnz"])) == n * k, int(np.asarray(stats["nnz"]))
    # per-column counts == k exactly, assembled across all tiles
    R = np.asarray(pruned.rows); C = np.asarray(pruned.cols)
    N = np.asarray(pruned.nnz)
    tm, wbl = pruned.tile_shape
    counts = np.zeros(n, np.int64)
    pr, pc, l = pruned.grid_shape
    w = n // pc
    for i in range(pr):
        for j in range(pc):
            for kk in range(l):
                cnt = int(N[i, j, kk])
                np.add.at(counts, j * w + kk * wbl + C[i, j, kk, :cnt], 1)
    np.testing.assert_array_equal(counts, np.full(n, k))
    # dense path: same tie semantics through the col_prune kernel
    tiles = np.zeros((pr, pc, l, tm, wbl), np.float32)
    for i in range(pr):
        for j in range(pc):
            for kk in range(l):
                tiles[i, j, kk] = x[i * tm:(i + 1) * tm,
                                    j * w + kk * wbl:j * w + (kk + 1) * wbl]
    dev = jax.device_put(jnp.asarray(tiles), grid.tile_sharding())
    out, stats_d = _mcl_prune_dense(
        dev, grid=grid, inflation=2.0, thresh=1e-4, k=k,
    )
    assert int(np.asarray(stats_d["nnz"])) == n * k
    print("OK mcl_tied_topk_distributed")


def case_mcl_no_host_roundtrip():
    """The sparse device-resident loop performs ZERO gather_to_global /
    scatter_to_grid calls inside the iteration loop: exactly two scatters
    (initial operands) and one gather (final matrix) over a whole run."""
    from repro.core import distsparse

    calls = {"scatter": 0, "gather": 0}
    real_scatter, real_gather = distsparse.scatter_to_grid, distsparse.gather_to_global

    def counting_scatter(*args, **kwargs):
        calls["scatter"] += 1
        return real_scatter(*args, **kwargs)

    def counting_gather(*args, **kwargs):
        calls["gather"] += 1
        return real_gather(*args, **kwargs)

    distsparse.scatter_to_grid = counting_scatter
    distsparse.gather_to_global = counting_gather
    try:
        grid = make_grid(2, 2, 2)
        n = 64
        a = _stochastic_blocks(n, blocks=2, intra_p=0.6, seed=5)
        _, hist = mcl_iterate(
            a, grid,
            MCLConfig(max_iters=6, per_process_memory=1 << 24,
                      force_num_batches=2),
        )
    finally:
        distsparse.scatter_to_grid = real_scatter
        distsparse.gather_to_global = real_gather
    assert len(hist) >= 3, "need a multi-iteration run to prove residency"
    assert calls["scatter"] == 2, calls  # initial A and B only
    assert calls["gather"] == 1, calls  # final matrix only
    print(f"OK mcl_no_host_roundtrip (iters={len(hist)}, calls={calls})")


def case_triangle_count_exact():
    grid = make_grid(2, 2, 2)
    a = gen.symmetrized(gen.erdos_renyi(48, 6.0, seed=9))
    got = triangle_count(a, grid)
    want = triangle_count_reference(a)
    assert got == want, (got, want)
    print(f"OK triangle_count_exact (triangles={got})")


def case_triangle_masked_rmat():
    """Masked triangle counting on R-MAT skew at 8 devices: the on-device
    masked path matches both the host-filter oracle and the dense reference,
    the masked plan needs strictly fewer batches and strictly smaller
    capacities than the unmasked plan under the same memory budget, and the
    device path performs ZERO host-side per-entry filtering (call-counted
    like ``mcl_no_host_roundtrip``)."""
    from repro.core.batched import plan_batches, probe_memory_budget
    from repro.core.distsparse import scatter_to_grid
    from repro.sparse_apps import graph_algorithms as ga
    from repro.sparse_apps.mcl import reset_transfer_bytes, transfer_bytes

    grid = make_grid(2, 2, 2)
    a = gen.symmetrized(gen.rmat(6, edge_factor=8, seed=5))  # n=64, power-law
    want = triangle_count_reference(a)

    # --- plan comparison under a budget that forces the unmasked run to batch
    L, U = ga._strict_parts(a)
    A_d = scatter_to_grid(L, grid, "A")
    B_d = scatter_to_grid(U, grid, "B")
    M_d = scatter_to_grid(L, grid, "C")
    ppm = probe_memory_budget(A_d, B_d, grid)  # unmasked b ~ 3-4
    pu = plan_batches(A_d, B_d, grid, per_process_memory=ppm,
                      spec=PlanSpec(local_path="esc"))
    pm = plan_batches(A_d, B_d, grid, per_process_memory=ppm,
                      spec=PlanSpec(mask=M_d, local_path="esc"))
    assert pu.num_batches > 1, pu.num_batches
    assert pm.num_batches < pu.num_batches, (pm.num_batches, pu.num_batches)
    assert pm.caps.d_cap < pu.caps.d_cap, (pm.caps, pu.caps)
    assert pm.caps.c_cap < pu.caps.c_cap, (pm.caps, pu.caps)

    # --- device path: no host-side per-entry filtering, scalars-only traffic
    calls = {"mask_filter": 0, "to_global": 0}
    real_filter = ga._host_mask_filter
    real_to_global = ga._sparse_batch_to_global

    def counting_filter(*args, **kwargs):
        calls["mask_filter"] += 1
        return real_filter(*args, **kwargs)

    def counting_to_global(*args, **kwargs):
        calls["to_global"] += 1
        return real_to_global(*args, **kwargs)

    ga._host_mask_filter = counting_filter
    ga._sparse_batch_to_global = counting_to_global
    try:
        reset_transfer_bytes()
        got = triangle_count(a, grid, per_process_memory=ppm)
        device_bytes = transfer_bytes()
        assert calls == {"mask_filter": 0, "to_global": 0}, calls
        reset_transfer_bytes()
        got_host = triangle_count_host(a, grid, per_process_memory=ppm)
        host_bytes = transfer_bytes()
        assert calls["mask_filter"] > 0 and calls["to_global"] > 0, calls
    finally:
        ga._host_mask_filter = real_filter
        ga._sparse_batch_to_global = real_to_global
    assert got == want == got_host, (got, want, got_host)
    # device path: one scalar per batch + the one-time mask count-vector
    # pull the planner makes (counts are computed on-grid now, so only the
    # (pr, pc, l, w_l) i32 array crosses); host oracle moves every full batch
    pr_, pc_, l_ = M_d.grid_shape
    mask_pull = pr_ * pc_ * l_ * M_d.tile_shape[1] * 4
    assert device_bytes <= mask_pull + 64, (device_bytes, mask_pull)
    assert host_bytes > 10 * device_bytes, (host_bytes, device_bytes)
    print(f"OK triangle_masked_rmat (triangles={got}, "
          f"batches {pm.num_batches}<{pu.num_batches}, "
          f"bytes {device_bytes}<<{host_bytes})")


def case_masked_multibatch_grid():
    """The masked fused step's mask-slice ↔ block-cyclic-batch alignment is
    only nontrivial when num_batches > 1 AND layers > 1 (the batch slice is
    fiber-gathered with per-layer column offsets): exact parity with the
    dense reference at nb ∈ {2, 4} × {strict, complement} on the 2x2x2
    grid, including the k-binned local multiply."""
    import jax.numpy as jnp

    from repro.core.batched import batched_summa3d
    from repro.core.distsparse import scatter_to_grid
    from repro.core.sparse import from_dense, from_numpy_coo
    from repro.sparse_apps.mcl import _sparse_batch_to_global

    grid = make_grid(2, 2, 2)
    n = 64
    rng = np.random.default_rng(41)
    xa = np.where(rng.random((n, n)) < 0.2,
                  rng.uniform(0.5, 1, (n, n)), 0).astype(np.float32)
    xb = np.where(rng.random((n, n)) < 0.2,
                  rng.uniform(0.5, 1, (n, n)), 0).astype(np.float32)
    mask_dense = rng.random((n, n)) < 0.15
    mr, mc = np.nonzero(mask_dense)
    A = scatter_to_grid(from_dense(jnp.asarray(xa), cap=1024), grid, "A")
    B = scatter_to_grid(from_dense(jnp.asarray(xb), cap=1024), grid, "B")
    M = scatter_to_grid(
        from_numpy_coo(mr, mc, np.ones(len(mr), np.float32), (n, n)),
        grid, "C",
    )
    for complement in (False, True):
        for nb in (2, 4):
            for binned in ("auto", True, False):
                got = np.zeros((n, n), np.float32)

                def consumer(bi, c, cm):
                    rr, cc, vv = _sparse_batch_to_global(c, cm)
                    got[rr, cc] += vv

                res = batched_summa3d(
                    A, B, grid, per_process_memory=1 << 26,
                    consumer=consumer, path="sparse",
                    spec=PlanSpec(force_num_batches=nb, mask=M,
                                  mask_complement=complement),
                    exec_spec=ExecSpec(binned=binned),
                )
                keep = ~mask_dense if complement else mask_dense
                np.testing.assert_allclose(
                    got, (xa @ xb) * keep, rtol=1e-4, atol=1e-4,
                )
                assert res.num_retries == 0, (complement, nb, binned)
    print("OK masked_multibatch_grid")


def case_overlap_pairs_exact():
    grid = make_grid(2, 2, 2)
    a = gen.kmer_like(32, 64, 5, seed=17)
    got = overlap_pairs(a, grid, min_shared=2)
    want = overlap_pairs_reference(a, min_shared=2)
    assert got == want, (len(got), len(want))
    print(f"OK overlap_pairs_exact (pairs={len(got)})")


def case_overlap_device_filter():
    """Overlap detection with the BELLA filter applied ON the grid: parity
    with the host-filter oracle and the dense reference, zero host-side
    per-entry filtering on the device path (call-counted), and the optional
    candidate-pair mask (PASTIS regime) gating the multiply itself."""
    from repro.core.sparse import from_numpy_coo
    from repro.sparse_apps import graph_algorithms as ga

    grid = make_grid(2, 2, 2)
    a = gen.kmer_like(32, 64, 5, seed=31)
    want = overlap_pairs_reference(a, min_shared=2)

    calls = {"pair_filter": 0}
    real_filter = ga._host_pair_filter

    def counting_filter(*args, **kwargs):
        calls["pair_filter"] += 1
        return real_filter(*args, **kwargs)

    ga._host_pair_filter = counting_filter
    try:
        got = overlap_pairs(a, grid, min_shared=2)
        assert calls["pair_filter"] == 0, calls
        got_host = overlap_pairs_host(a, grid, min_shared=2)
        assert calls["pair_filter"] > 0, calls
    finally:
        ga._host_pair_filter = real_filter
    assert got == want == got_host, (len(got), len(want), len(got_host))

    # candidate mask (PASTIS): candidates ⊇ true pairs reproduces the full
    # result; candidates ⊂ true pairs restricts the output to the mask.
    nseqs = a.shape[0]
    rng = np.random.default_rng(3)
    extra_r = rng.integers(0, nseqs, 40)
    extra_c = rng.integers(0, nseqs, 40)
    cr = np.concatenate([[p[0] for p in want], extra_r])
    cc = np.concatenate([[p[1] for p in want], extra_c])
    cands = from_numpy_coo(cr, cc, np.ones(len(cr), np.float32),
                           (nseqs, nseqs))
    got_c = overlap_pairs(a, grid, min_shared=2, candidates=cands)
    assert got_c == want, (len(got_c), len(want))
    half = want[: len(want) // 2]
    cands_half = from_numpy_coo(
        np.array([p[0] for p in half]), np.array([p[1] for p in half]),
        np.ones(len(half), np.float32), (nseqs, nseqs),
    )
    got_h = overlap_pairs(a, grid, min_shared=2, candidates=cands_half)
    assert got_h == half, (len(got_h), len(half))

    # survivor-sized transfer: the device→host pull is sliced down to the
    # max per-tile survivor count before any array moves. With an impossible
    # threshold every batch shrinks to the floor capacity of 8.
    seen_caps = []
    real_to_global2 = ga._sparse_batch_to_global

    def spying_to_global(c, col_map):
        seen_caps.append(int(c.rows.shape[-1]))
        return real_to_global2(c, col_map)

    ga._sparse_batch_to_global = spying_to_global
    try:
        none = overlap_pairs(a, grid, min_shared=10 ** 6)
        exact_again = overlap_pairs(a, grid, min_shared=2)
    finally:
        ga._sparse_batch_to_global = real_to_global2
    assert none == []
    assert exact_again == want, (len(exact_again), len(want))
    nb_seen = len(seen_caps)
    assert seen_caps and min(seen_caps) == 8, seen_caps
    print(f"OK overlap_device_filter (pairs={len(got)}, "
          f"candidates {len(got_c)}/{len(got_h)}, "
          f"shrunk caps {seen_caps[:nb_seen]})")


def case_mcl_kill_and_resume():
    """Durability at 8 devices: a run preempted mid-flight and resumed from
    its checkpoint reproduces the uninterrupted run bitwise — identical
    nnz/chaos trajectory, identical final cluster partition — and replans to
    the identical fused-step static signature (zero extra retraces)."""
    import tempfile

    from repro.core import summa3d
    from repro.runtime.resilient import ResilientConfig, SpgemmFailureInjector
    from repro.sparse_apps.mcl import mcl_iterate_resilient

    grid = make_grid(2, 2, 2)
    n = 64
    a = _stochastic_blocks(n, blocks=2, intra_p=0.6, seed=3)
    cfg = MCLConfig(max_iters=8, per_process_memory=1 << 24, max_per_col=8)
    final0, hist0 = mcl_iterate(a, grid, cfg)

    with tempfile.TemporaryDirectory() as d:
        rc = ResilientConfig(ckpt_dir=d, ckpt_every=1)
        inj = SpgemmFailureInjector(preempt_iters=(3,))
        tc0 = summa3d.TRACE_COUNTS["fused_step"]
        final1, hist1, rep = mcl_iterate_resilient(a, grid, cfg, rc,
                                                   injector=inj)
        tc1 = summa3d.TRACE_COUNTS["fused_step"]

    assert rep.restarts == 1, rep
    assert tc1 - tc0 == 0, (tc0, tc1)
    assert [(h["nnz"], h["chaos"]) for h in hist1] == \
           [(h["nnz"], h["chaos"]) for h in hist0]
    lab0, lab1 = _labels(final0, n), _labels(final1, n)
    for i in range(n):
        np.testing.assert_array_equal(lab1 == lab1[i], lab0 == lab0[i])
    nnz0, nnz1 = int(final0.nnz), int(final1.nnz)
    assert nnz0 == nnz1
    np.testing.assert_array_equal(np.asarray(final1.rows[:nnz1]),
                                  np.asarray(final0.rows[:nnz0]))
    np.testing.assert_array_equal(np.asarray(final1.cols[:nnz1]),
                                  np.asarray(final0.cols[:nnz0]))
    np.testing.assert_array_equal(np.asarray(final1.vals[:nnz1]),
                                  np.asarray(final0.vals[:nnz0]))
    assert rep.checkpoint_bytes > 0, rep
    print(f"OK mcl_kill_and_resume (iters={len(hist1)}, "
          f"ckpt_bytes={rep.checkpoint_bytes}, restarts={rep.restarts})")


def case_apsp_min_plus():
    """APSP iterated squaring over MIN_PLUS at 8 devices == numpy
    Floyd-Warshall, including unreachable pairs (implicit +inf)."""
    from repro.sparse_apps.graph_algorithms import (
        APSPConfig,
        apsp_iterate,
        apsp_reference,
    )

    grid = make_grid(2, 2, 2)
    n = 64
    rng = np.random.default_rng(11)
    from repro.core.sparse import from_numpy_coo
    w = rng.random((n, n)).astype(np.float32) * 9 + 1
    mask = rng.random((n, n)) < 0.06
    np.fill_diagonal(mask, False)
    r, c = np.nonzero(mask)
    a = from_numpy_coo(r.astype(np.int32), c.astype(np.int32), w[r, c], (n, n))
    D, hist = apsp_iterate(a, grid, APSPConfig(per_process_memory=1 << 24))
    ref = apsp_reference(a)
    got = np.full((n, n), np.inf, np.float64)
    k = int(D.nnz)
    got[np.asarray(D.rows[:k]), np.asarray(D.cols[:k])] = np.asarray(D.vals[:k])
    assert (np.isfinite(got) == np.isfinite(ref)).all()
    fin = np.isfinite(ref)
    np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)
    print(f"OK apsp_min_plus (iters={len(hist)}, reachable={int(fin.sum())})")


def _counting_roundtrip(body):
    """Run ``body`` with counting wrappers over scatter/gather; returns the
    call counts — the shared harness of the no-host-roundtrip cases."""
    from repro.core import distsparse

    calls = {"scatter": 0, "gather": 0}
    real_scatter = distsparse.scatter_to_grid
    real_gather = distsparse.gather_to_global

    def counting_scatter(*args, **kwargs):
        calls["scatter"] += 1
        return real_scatter(*args, **kwargs)

    def counting_gather(*args, **kwargs):
        calls["gather"] += 1
        return real_gather(*args, **kwargs)

    distsparse.scatter_to_grid = counting_scatter
    distsparse.gather_to_global = counting_gather
    try:
        body()
    finally:
        distsparse.scatter_to_grid = real_scatter
        distsparse.gather_to_global = real_gather
    return calls


def case_apsp_no_host_roundtrip():
    """The APSP iterate is device-resident like MCL's: two scatters (initial
    D as A- and B-kind) and one gather (the converged distance matrix) over
    the whole iterated-squaring run — zero round-trips inside the loop."""
    from repro.sparse_apps.graph_algorithms import APSPConfig, apsp_iterate

    out = {}

    def body():
        grid = make_grid(2, 2, 2)
        n = 64
        rng = np.random.default_rng(11)
        from repro.core.sparse import from_numpy_coo
        w = rng.random((n, n)).astype(np.float32) * 9 + 1
        mask = rng.random((n, n)) < 0.06
        np.fill_diagonal(mask, False)
        r, c = np.nonzero(mask)
        a = from_numpy_coo(r.astype(np.int32), c.astype(np.int32),
                           w[r, c], (n, n))
        _, out["hist"] = apsp_iterate(
            a, grid, APSPConfig(per_process_memory=1 << 24)
        )

    calls = _counting_roundtrip(body)
    assert len(out["hist"]) >= 3, "need a multi-iteration run"
    assert calls["scatter"] == 2, calls  # initial A and B only
    assert calls["gather"] == 1, calls  # final distance matrix only
    print(f"OK apsp_no_host_roundtrip (iters={len(out['hist'])}, "
          f"calls={calls})")


def case_mcl_dense_no_host_roundtrip():
    """The MCL dense path now matches the sparse path's residency contract:
    scatter twice before the loop, gather once after convergence — the
    pruned dense batches are sparsified on-device and reassembled on-grid."""
    out = {}

    def body():
        grid = make_grid(2, 2, 2)
        n = 64
        a = _stochastic_blocks(n, blocks=2, intra_p=0.6, seed=5)
        _, out["hist"] = mcl_iterate(
            a, grid,
            MCLConfig(max_iters=6, per_process_memory=1 << 24,
                      force_num_batches=2, path="dense", max_per_col=8),
        )

    calls = _counting_roundtrip(body)
    assert len(out["hist"]) >= 3, "need a multi-iteration run"
    assert calls["scatter"] == 2, calls  # initial A and B only
    assert calls["gather"] == 1, calls  # final matrix only
    print(f"OK mcl_dense_no_host_roundtrip (iters={len(out['hist'])}, "
          f"calls={calls})")


def case_serve_mixed_traffic():
    """The serving engine at 8 devices under mixed repeat/novel traffic:
    every request matches the dense oracle, repeat signatures hit the plan
    cache, and the repeats cost ZERO extra fused-step retraces."""
    from repro.core import summa3d
    from repro.serve import MultiplyRequest, ServeConfig, SpgemmEngine

    grid = make_grid(2, 2, 2)
    n = 64
    a0 = gen.erdos_renyi(n, 4.0, seed=40)
    b0 = gen.erdos_renyi(n, 4.0, seed=41)
    eng = SpgemmEngine(grid, ServeConfig(per_process_memory=1 << 24))

    def dense(s):
        m = np.zeros(s.shape, np.float64)
        k = int(s.nnz)
        m[np.asarray(s.rows)[:k], np.asarray(s.cols)[:k]] = (
            np.asarray(s.vals)[:k]
        )
        return m

    # warm the cache with the repeat signature, then measure the repeats
    eng.submit(MultiplyRequest(rid=0, a=a0, b=b0))
    eng.run_to_completion()
    pairs = {0: (a0, b0)}
    t0 = summa3d.TRACE_COUNTS["fused_step"]
    for rid in (1, 2, 3, 4):
        eng.submit(MultiplyRequest(rid=rid, a=a0, b=b0))
        pairs[rid] = (a0, b0)
    eng.run_to_completion()
    repeat_traces = summa3d.TRACE_COUNTS["fused_step"] - t0
    # the acceptance criterion: identical signature → zero extra retraces
    assert repeat_traces == 0, repeat_traces
    # interleave novel signatures (these may legitimately retrace)
    for i, rid in enumerate((5, 6, 7, 8)):
        an = gen.erdos_renyi(n, 4.0, seed=500 + 2 * i)
        bn = gen.erdos_renyi(n, 4.0, seed=501 + 2 * i)
        eng.submit(MultiplyRequest(rid=rid, a=an, b=bn))
        pairs[rid] = (an, bn)
    # done accumulates across run_to_completion calls: all nine requests
    results = eng.run_to_completion()
    assert len(results) == 9 and all(r.status == "ok" for r in results)
    for r in results:
        ra, rb = pairs[r.rid]
        np.testing.assert_allclose(
            dense(r.c), dense(ra) @ dense(rb), rtol=1e-5, atol=1e-6
        )
    assert eng.stats["hits"] >= 4, eng.stats  # the a0·b0 repeats all hit
    print(f"OK serve_mixed_traffic (requests={len(results)}, "
          f"stats={eng.stats}, extra_traces={repeat_traces})")


def case_placement_rmat_volume():
    """Structure-aware placement on a REAL 2×2×2 mesh: the degree-spread
    permutation (a) plans no more batches and strictly fewer capacity-padded
    transfer bytes than block-cyclic at the same constrained budget, and
    (b) the end-to-end placed multiply (permute → scatter → batched driver
    → invert) reproduces the unpermuted R-MAT product exactly."""
    from repro.core.batched import plan_batches, probe_memory_budget
    from repro.core.distsparse import scatter_to_grid
    from repro.core.placement import Placement, compute_placement, \
        multiply_placed
    from repro.tune import padded_comm_volume

    grid = make_grid(2, 2, 2)
    gs = (2, 2, 2)
    a = gen.symmetrized(gen.rmat(7, edge_factor=8, seed=5))
    n = a.shape[0]
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(a, grid, "B")
    ppm = probe_memory_budget(A, B, grid)
    base_plan = plan_batches(A, B, grid, per_process_memory=ppm,
                             spec=PlanSpec(local_path="esc"))
    placement = compute_placement(a, a, "degree")
    Ap = scatter_to_grid(placement.apply_a(a), grid, "A")
    Bp = scatter_to_grid(placement.apply_b(a), grid, "B")
    placed_plan = plan_batches(Ap, Bp, grid, per_process_memory=ppm,
                               spec=PlanSpec(local_path="esc"))
    v_base = padded_comm_volume(base_plan, gs)
    v_placed = padded_comm_volume(placed_plan, gs)
    assert base_plan.num_batches > 1, base_plan.num_batches
    assert placed_plan.num_batches <= base_plan.num_batches
    assert v_placed.all_to_all_bytes <= v_base.all_to_all_bytes
    assert v_placed.total_bytes < v_base.total_bytes, (
        v_placed.total_bytes, v_base.total_bytes)

    # end-to-end correctness on the mesh: placed == unpermuted, exactly
    spec = PlanSpec(local_path="esc")
    base = multiply_placed(a, a, grid, ppm,
                           placement=Placement.identity(n, n, n), spec=spec)
    placed = multiply_placed(a, a, grid, ppm, placement=placement, spec=spec)
    np.testing.assert_array_equal(placed.to_dense(), base.to_dense())
    print(f"OK placement_rmat_volume (batches {base_plan.num_batches}->"
          f"{placed_plan.num_batches}, padded bytes {v_base.total_bytes}->"
          f"{v_placed.total_bytes})")


CASES = {n[len("case_"):]: f for n, f in list(globals().items())
         if n.startswith("case_")}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else None
    if which:
        CASES[which]()
    else:
        for f in CASES.values():
            f()
