"""SpGEMM application correctness cases (subprocess, 8 host devices)."""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import gen
from repro.core.grid import make_grid
from repro.sparse_apps.graph_algorithms import (
    overlap_pairs,
    overlap_pairs_reference,
    triangle_count,
    triangle_count_reference,
)
from repro.sparse_apps.mcl import MCLConfig, clusters_from_matrix, mcl_iterate


def case_mcl_clusters_blocks():
    """MCL on a 4-block stochastic block matrix must recover ~4 clusters."""
    grid = make_grid(2, 2, 2)
    n, blocks = 64, 4
    a = gen.protein_similarity_like(n, blocks=blocks, intra_p=0.6, seed=3)
    # column-normalize the input (MCL operates on a column-stochastic matrix)
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    vals = np.asarray(a.vals[:nnz]).astype(np.float64)
    from repro.sparse_apps.mcl import _col_normalize_np
    from repro.core.sparse import from_numpy_coo

    vals = _col_normalize_np(rows, cols, vals, n).astype(np.float32)
    a = from_numpy_coo(rows, cols, vals, (n, n), cap=nnz)
    final, hist = mcl_iterate(
        a, grid, MCLConfig(max_iters=12, per_process_memory=1 << 24), verbose=True
    )
    nnz = int(final.nnz)
    labels = clusters_from_matrix(
        np.asarray(final.rows[:nnz]), np.asarray(final.cols[:nnz]), n
    )
    ncl = len(set(labels.tolist()))
    assert 2 <= ncl <= 10, f"expected block-ish clustering, got {ncl} clusters"
    # chaos decreased
    assert hist[-1]["chaos"] < hist[0]["chaos"]
    print(f"OK mcl_clusters_blocks (clusters={ncl}, iters={len(hist)})")


def case_triangle_count_exact():
    grid = make_grid(2, 2, 2)
    a = gen.erdos_renyi(48, 6.0, seed=9)
    # symmetrize
    nnz = int(a.nnz)
    rows = np.asarray(a.rows[:nnz])
    cols = np.asarray(a.cols[:nnz])
    from repro.core.sparse import from_numpy_coo

    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    keep = r2 != c2
    a = from_numpy_coo(r2[keep], c2[keep], np.ones(keep.sum(), np.float32),
                       (48, 48))
    got = triangle_count(a, grid)
    want = triangle_count_reference(a)
    assert got == want, (got, want)
    print(f"OK triangle_count_exact (triangles={got})")


def case_overlap_pairs_exact():
    grid = make_grid(2, 2, 2)
    a = gen.kmer_like(32, 64, 5, seed=17)
    got = overlap_pairs(a, grid, min_shared=2)
    want = overlap_pairs_reference(a, min_shared=2)
    assert got == want, (len(got), len(want))
    print(f"OK overlap_pairs_exact (pairs={len(got)})")


CASES = {n[len("case_"):]: f for n, f in list(globals().items())
         if n.startswith("case_")}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else None
    if which:
        CASES[which]()
    else:
        for f in CASES.values():
            f()
