"""Cross-cluster DP with EF-top-k compressed gradient exchange."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import transformer as tfm
from repro.optim import adamw, compress
from repro.runtime.hierarchical import CrossClusterDP


def _setup(density=0.05):
    cfg = get_config("starcoder2-7b", smoke=True)
    dcfg = DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab)

    def loss_fn(params, batch):
        return tfm.lm_loss(cfg, params, batch["inputs"], batch["targets"], None)

    dp = CrossClusterDP(
        loss_fn,
        adamw.AdamWConfig(lr=2e-3, warmup_steps=5),
        compress.CompressConfig(density=density, min_size=256),
        num_clusters=2,
    )
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return dp, params, dcfg


def test_replicas_stay_bit_identical():
    """Every cluster applies the same summed gradient -> exact sync."""
    dp, params, dcfg = _setup()
    states = dp.init(params)
    for s in range(4):
        batches = [synthetic_batch(dcfg, 2 * s + c) for c in range(2)]
        states, m = dp.step(states, batches)
    a = jax.tree.leaves(states[0].params)
    b = jax.tree.leaves(states[1].params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_compressed_training_converges():
    """EF-top-k at 5% density must still reduce the loss (error feedback
    preserves the descent direction over steps)."""
    dp, params, dcfg = _setup(density=0.05)
    states = dp.init(params)
    losses = []
    for s in range(30):
        batches = [synthetic_batch(dcfg, 2 * s + c) for c in range(2)]
        states, m = dp.step(states, batches)
        losses.append(m["loss"])
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15, (
        losses[:3], losses[-3:]
    )


def test_wire_bytes_reflect_density():
    dp_dense, params, dcfg = _setup(density=1.0)
    dp_sparse, _, _ = _setup(density=0.01)
    s_d = dp_dense.init(params)
    s_s = dp_sparse.init(params)
    batches = [synthetic_batch(dcfg, c) for c in range(2)]
    _, m_d = dp_dense.step(s_d, batches)
    _, m_s = dp_sparse.step(s_s, batches)
    # 1% density with (val+idx) pairs => ~2% of dense f32 traffic (+small
    # uncompressed tensors)
    assert m_s["wire_bytes"] < 0.1 * m_d["wire_bytes"], (
        m_s["wire_bytes"], m_d["wire_bytes"]
    )


def test_error_feedback_residual_nonzero():
    """The EF state must actually accumulate what was not sent."""
    dp, params, dcfg = _setup(density=0.02)
    states = dp.init(params)
    batches = [synthetic_batch(dcfg, c) for c in range(2)]
    states, _ = dp.step(states, batches)
    resid_norm = sum(
        float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(states[0].err)
    )
    assert resid_norm > 0
