"""Tests for the batch-count math (paper Eq. 2 + Alg. 3 line 12)."""
import pytest

from repro.core import symbolic as sym


class TestLowerBound:
    def test_eq2_basic(self):
        # mem(C)=100GB, M=60GB, inputs 10GB -> b >= ceil(100/50) = 2
        b = sym.batch_count_lower_bound(
            mem_c_bytes=100 << 30,
            total_memory=60 << 30,
            nnz_a=5 << 30,
            nnz_b=5 << 30,
            r=1,
        )
        assert b == 2

    def test_fits_in_memory_one_batch(self):
        b = sym.batch_count_lower_bound(1 << 20, 1 << 30, 100, 100, r=12)
        assert b == 1

    def test_inputs_exceed_memory_raises(self):
        with pytest.raises(MemoryError):
            sym.batch_count_lower_bound(1, 100, 10, 10, r=12)


class TestAlg3BatchCount:
    def test_line12(self):
        # M/p = 1000B, r=10, maxA=20, maxB=30 -> denom = 1000-500=500
        # maxC=200 -> b = ceil(2000/500) = 4
        b = sym.batch_count(200, 20, 30, per_process_memory=1000, r=10)
        assert b == 4

    def test_robust_to_imbalance_monotone(self):
        # larger max unmerged nnz (more imbalance) -> never fewer batches
        bs = [
            sym.batch_count(c, 10, 10, per_process_memory=10_000, r=12)
            for c in (100, 500, 2500, 12500)
        ]
        assert bs == sorted(bs)

    def test_alg3_geq_eq2_under_balance(self):
        """With perfectly balanced distribution the Alg-3 count >= Eq-2 bound
        (paper: symbolic estimates MORE batches for imbalanced cases)."""
        p = 16
        nnz_a = nnz_b = 1_000_000
        unmerged_total = 50_000_000
        M = 30_000_000  # bytes, r=1
        r = 1
        eq2 = sym.batch_count_lower_bound(unmerged_total, M, nnz_a, nnz_b, r=r)
        alg3 = sym.batch_count(
            unmerged_total // p, nnz_a // p, nnz_b // p, per_process_memory=M // p, r=r
        )
        assert alg3 >= eq2

    def test_imbalance_increases_b(self):
        p_mem = 10_000
        balanced = sym.batch_count(1000, 10, 10, p_mem, r=4)
        imbalanced = sym.batch_count(3000, 10, 10, p_mem, r=4)  # hot process
        assert imbalanced > balanced


class TestPlanColumns:
    def test_divisible_passthrough(self):
        assert sym.batching_plan_columns(64, 4, 2) == 4

    def test_rounds_up(self):
        assert sym.batching_plan_columns(60, 4, 3) == 4  # 60 % 12 == 0
        assert sym.batching_plan_columns(64, 3, 2) == 4  # 3 -> 4 (64 % 8 == 0)

    def test_symbolic_result_capacity(self):
        res = sym.SymbolicResult(
            num_batches=4,
            max_unmerged_nnz=1000,
            max_nnz_a=10,
            max_nnz_b=10,
            flops=5000,
            lower_bound=2,
        )
        assert res.per_batch_capacity(slack=1.0) == 250
        assert res.per_batch_capacity() >= 250
