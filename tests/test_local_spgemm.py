"""Local SpGEMM kernels vs dense oracles — incl. semiring property tests."""
import numpy as np
from repro.testing import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import local_spgemm as lsp
from repro.core import semiring as sr
from repro.core import sparse as sp


def dense_random(rng, m, n, density):
    x = rng.random((m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    return np.where(mask, x + 0.1, 0.0).astype(np.float32)


def make_pair(seed, m=10, k=12, n=9, da=0.3, db=0.3):
    rng = np.random.default_rng(seed)
    A = dense_random(rng, m, k, da)
    B = dense_random(rng, k, n, db)
    a = sp.from_dense(jnp.asarray(A), cap=m * k + 1)
    b = sp.from_dense(jnp.asarray(B), cap=k * n + 1)
    return A, B, a, b


class TestSpMM:
    def test_matches_dense(self):
        A, B, a, _ = make_pair(0)
        np.testing.assert_allclose(
            np.asarray(lsp.spmm(a, jnp.asarray(B))), A @ B, rtol=1e-5
        )

    def test_min_plus(self):
        # min-plus product on small graphs == shortest one-hop relaxation
        A = np.array([[0.0, 1.0], [4.0, 0.0]], np.float32)
        B = np.array([[2.0, 0.0], [1.0, 3.0]], np.float32)
        a = sp.from_dense(jnp.asarray(A), cap=5)
        out = np.asarray(lsp.spmm(a, jnp.asarray(B), sr.MIN_PLUS))
        # only structural nonzeros of A participate: A[0,1]=1, A[1,0]=4
        expect = np.array(
            [[1 + 1, 1 + 3], [4 + 2, 4 + 0]], np.float32
        )
        np.testing.assert_allclose(out, expect)


class TestDenseAcc:
    def test_matches_dense(self):
        A, B, a, b = make_pair(1)
        np.testing.assert_allclose(
            np.asarray(lsp.spgemm_dense_acc(a, b)), A @ B, rtol=1e-5
        )

    def test_min_plus_falls_back_to_esc(self):
        """min/max semirings route through the ESC fallback (docstring promise):
        result equals the ESC product densified onto a semiring.zero background."""
        _, _, a, b = make_pair(2)
        m, n = a.shape[0], b.shape[1]
        got = np.asarray(lsp.spgemm_dense_acc(a, b, sr.MIN_PLUS))
        c, ovf = lsp.spgemm_esc(
            a, b, out_cap=m * n + 1, flops_cap=8192, semiring=sr.MIN_PLUS
        )
        assert int(ovf) == 0
        expect = np.full((m, n), np.inf, np.float32)
        nnz = int(c.nnz)
        rr = np.asarray(c.rows[:nnz])
        cc = np.asarray(c.cols[:nnz])
        vv = np.asarray(c.vals[:nnz])
        np.minimum.at(expect, (rr, cc), vv)
        np.testing.assert_allclose(got, expect, rtol=1e-5)

    def test_max_times_falls_back_to_esc(self):
        A, B, a, b = make_pair(12, da=0.4, db=0.4)
        got = np.asarray(lsp.spgemm_dense_acc(a, b, sr.MAX_TIMES))
        # dense oracle: max over k of A[i,k]*B[k,j] restricted to structural nnz
        expect = np.zeros((A.shape[0], B.shape[1]), np.float32)
        for i in range(A.shape[0]):
            for j in range(B.shape[1]):
                prods = [
                    A[i, k] * B[k, j]
                    for k in range(A.shape[1])
                    if A[i, k] != 0 and B[k, j] != 0
                ]
                if prods:
                    expect[i, j] = max(prods)
        np.testing.assert_allclose(got, expect, rtol=1e-5)


class TestESC:
    def test_matches_dense(self):
        A, B, a, b = make_pair(3)
        c, ovf = lsp.spgemm_esc(a, b, out_cap=10 * 9 + 1, flops_cap=4000)
        assert int(ovf) == 0
        np.testing.assert_allclose(np.asarray(c.to_dense()), A @ B, rtol=1e-5)

    def test_output_row_sorted(self):
        _, _, a, b = make_pair(4)
        c, _ = lsp.spgemm_esc(a, b, out_cap=200, flops_cap=4000)
        nnz = int(c.nnz)
        keys = np.asarray(c.rows[:nnz]) * c.shape[1] + np.asarray(c.cols[:nnz])
        assert np.all(np.diff(keys) > 0)

    def test_overflow_reported(self):
        A, B, a, b = make_pair(5, da=0.6, db=0.6)
        dense_nnz = int((A @ B != 0).sum())
        c, ovf = lsp.spgemm_esc(a, b, out_cap=dense_nnz // 2, flops_cap=8000)
        assert int(ovf) > 0

    def test_flops_cap_overflow_reported(self):
        _, _, a, b = make_pair(6, da=0.6, db=0.6)
        c, ovf = lsp.spgemm_esc(a, b, out_cap=200, flops_cap=7)
        assert int(ovf) > 0

    def test_unsorted_inputs_ok(self):
        """Paper §IV-D: local multiply must not require sorted inputs."""
        A, B, a, b = make_pair(7)
        rng = np.random.default_rng(0)
        perm = rng.permutation(a.cap)
        # permuting scatters padding among real entries -> declare all slots
        # candidate (nnz=cap), then compact on the sentinel test to restore
        # the valid-prefix invariant
        a_shuf = sp.SparseCOO(
            a.rows[perm], a.cols[perm], a.vals[perm], jnp.int32(a.cap), a.shape
        )
        a_shuf, _ = a_shuf.compact(a_shuf.rows < a.shape[0], new_cap=a.cap)
        c, ovf = lsp.spgemm_esc(a_shuf, b, out_cap=200, flops_cap=4000)
        assert int(ovf) == 0
        np.testing.assert_allclose(np.asarray(c.to_dense()), A @ B, rtol=1e-5)

    def test_min_plus_semiring(self):
        INF = np.float32(1e9)
        A = np.array([[0, 1, 0], [0, 0, 2], [3, 0, 0]], np.float32)
        B = np.array([[0, 5, 0], [4, 0, 0], [0, 0, 6]], np.float32)
        a = sp.from_dense(jnp.asarray(A), cap=10)
        b = sp.from_dense(jnp.asarray(B), cap=10)
        c, _ = lsp.spgemm_esc(a, b, out_cap=20, flops_cap=40, semiring=sr.MIN_PLUS)
        # structural product: C[i,j] = min over k in A(i,:)∩B(:,j) of a+b
        # A(0,1)=1, B(1,0)=4 -> C[0,0] = 5
        d = np.asarray(c.to_dense())
        assert d[0, 0] == 5.0

    def test_plus_pair_counts_paths(self):
        # triangle counting semiring: values are path counts
        A = (np.ones((4, 4)) - np.eye(4)).astype(np.float32)
        a = sp.from_dense(jnp.asarray(A), cap=20)
        c, _ = lsp.spgemm_esc(a, a, out_cap=20, flops_cap=80, semiring=sr.PLUS_PAIR)
        d = np.asarray(c.to_dense())
        # number of 2-paths between distinct i,j in K4 = 2 (through the other 2)
        assert d[0, 1] == 2.0 and d[0, 0] == 3.0


class TestSymbolic:
    def test_flops_exact(self):
        A, B, a, b = make_pair(8)
        expect = int(((A != 0).astype(np.int64).T.sum(1) * (B != 0).sum(1)).sum())
        # flops = sum_k nnz(A(:,k)) * nnz(B(k,:))
        expect = int(((A != 0).sum(0) * (B != 0).sum(1)).sum())
        got = int(lsp.local_symbolic_flops(a, b))
        assert got == expect

    def test_exact_nnz(self):
        A, B, a, b = make_pair(9)
        expect = int(((A @ B) != 0).sum())
        got = int(lsp.local_symbolic_exact(a, b, flops_cap=4000))
        assert got == expect

    def test_ordering_flops_geq_nnz(self):
        _, _, a, b = make_pair(10, da=0.5, db=0.5)
        fl = int(lsp.local_symbolic_flops(a, b))
        ex = int(lsp.local_symbolic_exact(a, b, flops_cap=8000))
        assert fl >= ex  # cf >= 1 (paper §II-A)

    def test_nnz_per_col_upper(self):
        A, B, a, b = make_pair(11)
        cc = a.col_counts()
        ub = np.asarray(lsp.nnz_per_col_upper(cc, b))
        true_cols = ((A @ B) != 0).sum(0)
        assert np.all(ub >= true_cols)
        assert ub.sum() == int(lsp.local_symbolic_flops(a, b))


class TestMerge:
    def test_merge_sparse(self):
        rng = np.random.default_rng(12)
        xs = [dense_random(rng, 8, 8, 0.3) for _ in range(3)]
        parts = [sp.from_dense(jnp.asarray(x), cap=30) for x in xs]
        merged, ovf = lsp.merge_sparse(parts, out_cap=80)
        assert int(ovf) == 0
        np.testing.assert_allclose(
            np.asarray(merged.to_dense()), sum(xs), rtol=1e-5
        )

    def test_merge_max_semiring(self):
        xs = [np.diag(np.array([1, 5, 2], np.float32)),
              np.diag(np.array([4, 2, 3], np.float32))]
        parts = [sp.from_dense(jnp.asarray(x), cap=5) for x in xs]
        merged, _ = lsp.merge_sparse(parts, out_cap=10, semiring=sr.MAX_TIMES)
        np.testing.assert_allclose(
            np.asarray(merged.to_dense()), np.maximum(*xs)
        )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    m=st.integers(2, 10),
    k=st.integers(2, 10),
    n=st.integers(2, 10),
    da=st.floats(0.1, 0.7),
    db=st.floats(0.1, 0.7),
)
def test_property_esc_equals_dense_acc_equals_dense(seed, m, k, n, da, db):
    rng = np.random.default_rng(seed)
    A = dense_random(rng, m, k, da)
    B = dense_random(rng, k, n, db)
    a = sp.from_dense(jnp.asarray(A), cap=m * k + 1)
    b = sp.from_dense(jnp.asarray(B), cap=k * n + 1)
    expect = A @ B
    got_acc = np.asarray(lsp.spgemm_dense_acc(a, b))
    np.testing.assert_allclose(got_acc, expect, rtol=1e-4, atol=1e-5)
    c, ovf = lsp.spgemm_esc(a, b, out_cap=m * n + 1, flops_cap=m * k * n + 1)
    assert int(ovf) == 0
    np.testing.assert_allclose(np.asarray(c.to_dense()), expect, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_distributive_blocked_multiply(seed):
    """C = A·B == Σ_k A[:,k-block]·B[k-block,:] — the layer-splitting identity
    SUMMA3D relies on (paper Fig. 1: per-layer low-rank products merge to C)."""
    rng = np.random.default_rng(seed)
    m, k, n, l = 6, 8, 5, 2
    A = dense_random(rng, m, k, 0.4)
    B = dense_random(rng, k, n, 0.4)
    a = sp.from_dense(jnp.asarray(A), cap=m * k + 1)
    parts = []
    w = k // l
    for layer in range(l):
        Ak = A[:, layer * w : (layer + 1) * w]
        Bk = B[layer * w : (layer + 1) * w, :]
        ak = sp.from_dense(jnp.asarray(Ak), cap=m * w + 1)
        bk = sp.from_dense(jnp.asarray(Bk), cap=w * n + 1)
        ck, ovf = lsp.spgemm_esc(ak, bk, out_cap=m * n + 1, flops_cap=m * w * n + 1)
        assert int(ovf) == 0
        parts.append(ck)
    merged, ovf = lsp.merge_sparse(parts, out_cap=m * n + 1)
    assert int(ovf) == 0
    np.testing.assert_allclose(np.asarray(merged.to_dense()), A @ B, rtol=1e-4, atol=1e-5)
