"""Application-level tests (run distributed in a subprocess — 8 devices)."""
import os
import subprocess
import sys

import pytest

# subprocess-per-case with an 8-device host platform — excluded from the CI fast lane (-m 'not slow')
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASES = [
    "mcl_clusters_blocks",
    "mcl_device_matches_host",
    "mcl_dense_path",
    "mcl_tied_topk_distributed",
    "mcl_no_host_roundtrip",
    "triangle_count_exact",
    "triangle_masked_rmat",
    "masked_multibatch_grid",
    "overlap_pairs_exact",
    "overlap_device_filter",
    "mcl_kill_and_resume",
    "apsp_min_plus",
    "placement_rmat_volume",
]


@pytest.mark.parametrize("case", CASES)
def test_app_case(case):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "app_cases.py"), case],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, f"{case} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    assert f"OK {case}" in r.stdout
