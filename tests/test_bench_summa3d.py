"""Smoke test for ``benchmarks.run --suite summa3d`` — the driver benchmark
must produce the acceptance rows (plan pairings, per-batch and end-to-end
driver timings, summary). Runs in a subprocess with 8 host devices; excluded
from the CI fast lane (-m 'not slow')."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SNIPPET = """
import json, sys
from benchmarks.bench_summa3d import run_summa3d_suite
rows = run_summa3d_suite(scale=6, edge_factor=6, nb=4, iters=1)
json.dump(rows, open(sys.argv[1], "w"))
"""


def test_summa3d_suite_rows(tmp_path):
    out = tmp_path / "rows.json"
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET, str(out)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, f"suite failed:\n{r.stdout}\n{r.stderr}"
    rows = json.loads(out.read_text())
    by_op = {}
    for row in rows:
        by_op.setdefault(row["op"], []).append(row)

    plans = {row["variant"]: row for row in by_op["plan"]}
    assert set(plans) == {"kbin", "fixed_mem_batches"}
    assert plans["kbin"]["pairings_binned"] < plans["kbin"]["pairings_unbinned"]
    # the hash memory model's acceptance row: fewer batches at fixed memory
    fixed = plans["fixed_mem_batches"]
    assert fixed["num_batches_esc"] > 1, fixed
    assert fixed["num_batches_hash"] < fixed["num_batches_esc"], fixed

    e2e = {row["variant"]: row["wall_ms"] for row in by_op["driver_e2e"]}
    assert set(e2e) == {"serial", "pipelined", "pipelined_esc",
                        "pipelined_binned", "pipelined_hash"}
    assert all(ms > 0 for ms in e2e.values()), e2e
    assert len(by_op["driver_batch"]) == 4  # one wall-ms row per batch

    (summary,) = by_op["summary"]
    assert summary["speedup_pipelined_vs_serial"] > 0
    assert summary["pairing_reduction"] > 1.0
    assert summary["hash_batches_fewer"] is True, summary
    assert summary["local_path_used"] in ("esc", "binned", "hash")
