"""Per-architecture smoke tests: reduced config of the same family, one
forward + one grad step + one decode step on CPU; asserts shapes + no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.compat import AxisType, make_mesh, set_mesh

from repro.configs import ARCHS, get_config
from repro.models import transformer as tfm

# compiles every model family — excluded from the CI fast lane (-m 'not slow')
pytestmark = pytest.mark.slow


def tiny_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return make_mesh(dev, ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)


def make_inputs(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    targets = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    return inputs, targets


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(42))
    inputs, _ = make_inputs(cfg)
    mesh = tiny_mesh()
    with set_mesh(mesh):
        logits, aux = tfm.forward(cfg, params, inputs, mesh)
    # forward returns Megatron-padded-vocab logits with the pad masked out
    assert logits.shape == (2, 16, cfg.padded_vocab)
    live = np.asarray(logits[..., : cfg.vocab])
    assert np.all(np.isfinite(live)), arch
    if cfg.padded_vocab > cfg.vocab:
        assert np.all(np.asarray(logits[..., cfg.vocab:]) <= -1e29)
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(7))
    inputs, targets = make_inputs(cfg)
    mesh = tiny_mesh()

    def loss_fn(p):
        return tfm.lm_loss(cfg, p, inputs, targets, mesh)

    with set_mesh(mesh):
        loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch
    # gradients actually flow to the embedding / first projection
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat)
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_matches_cache_semantics(arch):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(9))
    mesh = tiny_mesh()
    B, S_max = 2, 16
    cache = tfm.init_cache(cfg, B, S_max)
    if cfg.input_mode == "tokens":
        tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    else:
        tok = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model))
    with set_mesh(mesh):
        logits, new_cache = tfm.decode_step(
            cfg, params, cache, tok, jnp.int32(0), mesh
        )
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    # cache was updated (attention families write k/v at position 0)
    if cfg.family in ("attn", "hybrid"):
        assert float(jnp.sum(jnp.abs(new_cache["k"][:, :, 0]))) > 0


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-370m", "zamba2-2.7b",
                                  "granite-20b"])
def test_prefill_then_decode_consistent(arch):
    """Decoding token S given a prefilled cache must match the full forward
    at position S (teacher-forcing consistency)."""
    cfg = get_config(arch, smoke=True)
    cfg = tfm.dataclasses.replace(cfg, remat=False)
    params = tfm.init_params(cfg, jax.random.PRNGKey(11))
    mesh = tiny_mesh()
    B, S = 1, 8
    if cfg.input_mode == "tokens":
        seq = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab)
        prompt, nxt = seq[:, :S], seq[:, S:]
    else:
        seq = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, cfg.d_model))
        prompt, nxt = seq[:, :S], seq[:, S:]
    with set_mesh(mesh):
        logits_full, _ = tfm.forward(cfg, params, seq, mesh)
        _, cache = tfm.prefill(cfg, params, prompt, s_max=S + 4, mesh=mesh)
        logits_dec, _ = tfm.decode_step(cfg, params, cache, nxt, jnp.int32(S), mesh)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_param_specs_cover_all_params():
    """Every param leaf must have a PartitionSpec (no silent replication
    surprises in the dry-run)."""
    from jax.sharding import PartitionSpec

    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        specs = tfm.param_specs(cfg)
        pleaves = jax.tree_util.tree_flatten_with_path(params)[0]
        sleaves = {jax.tree_util.keystr(p) for p, _ in
                   jax.tree_util.tree_flatten_with_path(
                       specs, is_leaf=lambda x: isinstance(x, PartitionSpec))[0]}
        for path, leaf in pleaves:
            assert jax.tree_util.keystr(path) in sleaves, (arch, path)


def test_moe_spgemm_dispatch_equals_scatter():
    """The paper-technique dispatch (SpMM) must equal the direct scatter."""
    import dataclasses as dc


    cfg = get_config("deepseek-moe-16b", smoke=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(5))
    inputs, _ = make_inputs(cfg)
    mesh = tiny_mesh()
    cfg_scatter = dc.replace(
        cfg, moe=dc.replace(cfg.moe, dispatch_mode="scatter")
    )
    with set_mesh(mesh):
        l1, _ = tfm.forward(cfg, params, inputs, mesh)
        l2, _ = tfm.forward(cfg_scatter, params, inputs, mesh)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)
