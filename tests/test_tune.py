"""Fast-lane tests for the cost model + autotuner (``repro.tune``).

Host-math heavy, single device: the host symbolic oracle reproduces the
device pass (bit-for-bit counts and an identical plan on the 1×1×1 grid —
the 2×2×2 parity case lives in the 8-device slow lane), the cost model's
predictions for the CHECKED-IN ``BENCH_summa3d.json`` pipelined rows land
inside ``ACCEPT_BAND`` after the one-scalar overhead fit, the autotuner
never returns a config the model prices worse than the untouched defaults,
the R-MAT skew case picks a measurably cheaper config (fewer comm bytes or
batches) than the fixed heuristics, and a ``TunedConfig`` drives the serve
engine's admission path end to end with plan-cache hits on repeat traffic.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import gen
from repro.core import summa3d
from repro.core.batched import (
    PlanInputs,
    plan_batches,
    plan_from_symbolic,
    symbolic3d_counts,
)
from repro.core.distsparse import scatter_to_grid
from repro.core.grid import make_grid
from repro.core.specs import PlanFloors, PlanSpec
from repro.core.symbolic import host_symbolic_counts
from repro.serve import MultiplyRequest, ServeConfig, SpgemmEngine
from repro.tune import (
    ACCEPT_BAND,
    autotune,
    candidate_grids,
    fit_overhead,
    predict_cost,
)

REPO = pathlib.Path(__file__).resolve().parents[1]

# the exact workload run_summa3d_suite times (seeds, grid, forced batches)
BENCH_SCALE, BENCH_EF, BENCH_NB = 8, 8, 32
BENCH_GRID = (2, 2, 2)
BENCH_PPM = 1 << 30
# bench config name -> the PlanSpec.local_path it pins
PIPELINED_VARIANTS = {
    "pipelined": "auto",
    "pipelined_esc": "esc",
    "pipelined_binned": "binned",
    "pipelined_hash": "hash",
}


def _bench_pair():
    return (gen.rmat(scale=BENCH_SCALE, edge_factor=BENCH_EF, seed=3),
            gen.rmat(scale=BENCH_SCALE, edge_factor=BENCH_EF, seed=4))


def _bench_plan(a, b, path):
    counts = host_symbolic_counts(a, b, BENCH_GRID)
    inputs = PlanInputs.from_host(a, b, BENCH_GRID)
    plan = plan_from_symbolic(
        counts, inputs, BENCH_PPM,
        PlanSpec(local_path=path, force_num_batches=BENCH_NB), PlanFloors(),
    )
    return plan, inputs


class TestHostOracle:
    def test_counts_match_device_pass(self):
        grid = make_grid(1, 1, 1)
        a = gen.erdos_renyi(64, 5.0, seed=7)
        b = gen.erdos_renyi(64, 5.0, seed=8)
        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(b, grid, "B")
        dev = symbolic3d_counts(A, B, grid)
        host = host_symbolic_counts(a, b, (1, 1, 1))
        np.testing.assert_array_equal(np.asarray(dev.percol), host.percol)
        np.testing.assert_array_equal(
            np.asarray(dev.b_colcounts), host.b_colcounts)
        np.testing.assert_array_equal(
            np.asarray(dev.a_kcounts), host.a_kcounts)
        np.testing.assert_array_equal(
            np.asarray(dev.b_kcounts), host.b_kcounts)
        assert dev.mask_colcounts is None and host.mask_colcounts is None

    def test_plan_matches_device_plan(self):
        grid = make_grid(1, 1, 1)
        a = gen.erdos_renyi(64, 5.0, seed=9)
        b = gen.erdos_renyi(64, 5.0, seed=10)
        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(b, grid, "B")
        ppm = 1 << 22
        dev = plan_batches(A, B, grid, per_process_memory=ppm,
                           spec=PlanSpec())
        host = plan_from_symbolic(
            host_symbolic_counts(a, b, (1, 1, 1)),
            PlanInputs.from_host(a, b, (1, 1, 1)),
            ppm, PlanSpec(), PlanFloors(),
        )
        assert host.num_batches == dev.num_batches
        assert host.caps == dev.caps
        assert host.sel_cap == dev.sel_cap
        assert host.local_path == dev.local_path
        assert host.total_flops == dev.total_flops
        np.testing.assert_array_equal(host.per_batch_flops,
                                      dev.per_batch_flops)


class TestCostModelBand:
    def test_checked_in_pipelined_rows_within_band(self):
        """Acceptance criterion: for every pipelined BENCH_summa3d.json
        driver row, predicted/measured stays inside the fixed band after
        the single-scalar overhead fit."""
        path = REPO / "BENCH_summa3d.json"
        if not path.exists():
            pytest.skip("no checked-in BENCH_summa3d.json")
        rows = json.loads(path.read_text())["rows"]
        measured = {
            r["variant"]: r["wall_ms"] for r in rows
            if r.get("op") == "driver_e2e" and r["variant"] in
            PIPELINED_VARIANTS
        }
        assert set(measured) == set(PIPELINED_VARIANTS)
        a, b = _bench_pair()
        pairs, raw = [], {}
        for variant, lpath in PIPELINED_VARIANTS.items():
            plan, inputs = _bench_plan(a, b, lpath)
            pred = predict_cost(plan, BENCH_GRID, inputs.nnz_a,
                                inputs.nnz_b)
            raw[variant] = pred.total_ms
            pairs.append((pred.total_ms, measured[variant]))
        coeffs = fit_overhead(pairs)
        lo, hi = ACCEPT_BAND
        for variant in PIPELINED_VARIANTS:
            ratio = coeffs.overhead * raw[variant] / measured[variant]
            assert lo <= ratio <= hi, (variant, ratio)


class TestAutotune:
    def test_candidate_grids_divisibility(self):
        grids = candidate_grids((256, 256), (256, 256), 8)
        assert (2, 2, 2) in grids and (1, 1, 1) in grids
        for pr, pc, l in grids:
            assert pr * pc * l <= 8
            assert l == 1 or pr == pc  # rectangles only as single-layer grids
            assert 256 % pr == 0 and 256 % (pc * l) == 0
        # odd shapes prune non-dividing grids (no l=4 layer split of k=6,
        # no 3×3 side of 8 devices); squares enumerate first, then the
        # single-layer rectangles by ascending pr, pc
        assert candidate_grids((6, 6), (6, 6), 8) == (
            (1, 1, 1), (1, 1, 2), (1, 1, 3), (1, 1, 6), (2, 2, 1),
            (1, 2, 1), (1, 3, 1), (1, 6, 1), (2, 1, 1), (2, 3, 1),
            (3, 1, 1), (3, 2, 1), (6, 1, 1))

    def test_never_worse_than_defaults(self):
        a, b = _bench_pair()
        for budget in (1 << 30, 200_000, 80_000, 40_000):
            t = autotune(a, b, budget, num_devices=8)
            assert t.predicted.total_ms <= t.baseline_predicted.total_ms, (
                budget, t.predicted, t.baseline_predicted)

    def test_rmat_skew_beats_fixed_heuristics(self):
        """Acceptance criterion: on the R-MAT skew case under a constrained
        budget the tuner picks a config that is measurably cheaper than the
        fixed defaults — strictly fewer transfer bytes (it drops the fiber
        exchange by choosing fewer layers) or strictly fewer batches."""
        a, b = _bench_pair()
        t = autotune(a, b, 80_000, num_devices=8)
        assert t.predicted.total_ms <= t.baseline_predicted.total_ms
        assert (t.predicted.comm_bytes < t.baseline_predicted.comm_bytes
                or t.num_batches < t.baseline_num_batches), t.to_meta()
        # deterministic: same inputs, same pick
        t2 = autotune(a, b, 80_000, num_devices=8)
        assert t2.grid_shape == t.grid_shape
        assert t2.spec == t.spec and t2.floors == t.floors

    def test_tuned_config_is_spec_api(self):
        a, b = _bench_pair()
        t = autotune(a, b, 200_000, num_devices=8)
        assert isinstance(t.spec, PlanSpec)
        assert isinstance(t.floors, PlanFloors)
        assert t.floors.num_batches == t.num_batches
        assert t.spec.local_path in ("esc", "binned", "hash")
        meta = json.loads(json.dumps(t.to_meta()))  # JSON-safe
        assert meta["grid_shape"] == list(t.grid_shape)
        assert PlanFloors.from_meta(meta["floors"]) == t.floors

    def test_infeasible_budget_raises(self):
        a, b = _bench_pair()
        with pytest.raises(MemoryError):
            autotune(a, b, 64, num_devices=8)


class TestServeFromTuned:
    def test_tuned_drives_admission_with_cache_hits(self):
        a = gen.erdos_renyi(64, 4.0, seed=30)
        b = gen.erdos_renyi(64, 4.0, seed=31)
        t = autotune(a, b, 1 << 24, num_devices=1)
        assert t.grid_shape == (1, 1, 1)
        cfg = ServeConfig.from_tuned(t)
        assert cfg.local_path == t.spec.local_path
        assert cfg.seed_floors == t.floors
        eng = SpgemmEngine(make_grid(1, 1, 1), cfg)
        eng.submit(MultiplyRequest(rid=0, a=a, b=b))
        eng.run_to_completion()
        t0 = summa3d.TRACE_COUNTS["fused_step"]
        eng.submit(MultiplyRequest(rid=1, a=a, b=b))
        results = eng.run_to_completion()
        repeat = [r for r in results if r.rid == 1][0]
        assert repeat.status == "ok" and repeat.plan_cached
        assert summa3d.TRACE_COUNTS["fused_step"] - t0 == 0
