"""Checkpoint store durability contract: crash consistency (atomic rename +
stale-tmp sweep), corruption refusal (content hashes), defensive directory
parsing, keep-N GC robustness, meta side channel, async accounting."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import store


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.random((8, 4), np.float32)),
        "step": jnp.asarray(seed, jnp.int32),
    }


class TestParsing:
    def test_step_of_foreign_entries(self):
        assert store._step_of("step_00000003") == 3
        assert store._step_of("step_0001.bak") is None
        assert store._step_of("step_") is None
        assert store._step_of("step_12.tmp") is None
        assert store._step_of("notes.txt") is None

    def test_latest_step_ignores_foreign_entries(self, tmp_path):
        store.save(str(tmp_path), 4, _state())
        # operator droppings that int(d.split("_")[1]) would crash on
        os.makedirs(tmp_path / "step_00000004.bak")
        (tmp_path / "step_readme").write_text("junk")
        (tmp_path / "other_7").write_text("junk")
        assert store.latest_step(str(tmp_path)) == 4
        assert store.steps_available(str(tmp_path)) == [4]

    def test_latest_step_missing_dir(self, tmp_path):
        assert store.latest_step(str(tmp_path / "nope")) is None


class TestCrashConsistency:
    def test_sweep_stale_tmp(self, tmp_path):
        store.save(str(tmp_path), 2, _state())
        stale = tmp_path / "step_00000005.tmp"
        os.makedirs(stale)
        (stale / "arrays.npz").write_bytes(b"partial write")
        assert store.sweep_stale_tmp(str(tmp_path)) == 1
        assert not stale.exists()
        assert store.latest_step(str(tmp_path)) == 2

    def test_kill_between_write_and_rename(self, tmp_path, monkeypatch):
        """A crash after the temp-dir write but before the atomic rename must
        leave the previous checkpoint intact and only a .tmp leftover."""
        store.save(str(tmp_path), 1, _state(1))

        def boom(src, dst):
            raise OSError("killed before rename")

        monkeypatch.setattr(os, "rename", boom)
        with pytest.raises(OSError):
            store.save(str(tmp_path), 2, _state(2))
        monkeypatch.undo()
        assert (tmp_path / "step_00000002.tmp").exists()
        # next latest_step sweeps the leftover and still serves step 1
        assert store.latest_step(str(tmp_path)) == 1
        assert not (tmp_path / "step_00000002.tmp").exists()
        back = store.restore(str(tmp_path), 1, _state(1))
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(_state(1)["w"]))


class TestCorruptionRefusal:
    def test_hash_mismatch_refused(self, tmp_path):
        store.save(str(tmp_path), 3, _state())
        d = tmp_path / "step_00000003"
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k].copy() for k in z.files}
        key = [k for k in arrays if "w" in k][0]
        arrays[key][0, 0] += 1.0  # silent bit-flip
        np.savez(d / "arrays.npz", **arrays)
        with pytest.raises(IOError, match="hash mismatch"):
            store.restore_arrays(str(tmp_path), 3)

    def test_truncated_archive_refused(self, tmp_path):
        store.save(str(tmp_path), 3, _state())
        p = tmp_path / "step_00000003" / "arrays.npz"
        size = os.path.getsize(p)
        with open(p, "r+b") as f:
            f.truncate(size // 2)
        with pytest.raises(IOError, match="unreadable"):
            store.restore_arrays(str(tmp_path), 3)

    def test_missing_leaf_refused(self, tmp_path):
        store.save(str(tmp_path), 3, _state())
        d = tmp_path / "step_00000003"
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files if "w" not in k}
        np.savez(d / "arrays.npz", **arrays)
        with pytest.raises(IOError, match="missing"):
            store.restore_arrays(str(tmp_path), 3)

    def test_tree_mismatch_is_keyerror(self, tmp_path):
        store.save(str(tmp_path), 3, _state())
        with pytest.raises(KeyError, match="tree mismatch"):
            store.restore(str(tmp_path), 3, {"w": jnp.zeros((8, 4))})


class TestMetaAndElastic:
    def test_meta_roundtrip(self, tmp_path):
        meta = {"it": 7, "plan_sig": {"caps": [1, 2, 3, 4], "nb": 2}}
        store.save(str(tmp_path), 7, _state(), meta=meta)
        assert store.load_meta(str(tmp_path), 7) == meta
        # meta rides in the manifest only — array payload identical contract
        arrays = store.restore_arrays(str(tmp_path), 7)
        assert len(arrays) == 2

    def test_elastic_restore_new_sharding(self, tmp_path):
        state = _state(4)
        store.save(str(tmp_path), 1, state)
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
        sh = {
            "w": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("x", None)),
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
        }
        back = store.restore(str(tmp_path), 1,
                             jax.tree.map(jnp.zeros_like, state), sh)
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(state["w"]))
        assert back["w"].sharding == sh["w"]


class TestGC:
    def test_keep_n_with_foreign_entries(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        os.makedirs(tmp_path / "step_junk.bak")
        for s in range(1, 5):
            ck.save_sync(s, _state(s))
        assert store.steps_available(str(tmp_path)) == [3, 4]
        assert (tmp_path / "step_junk.bak").exists()  # never GC'd

    def test_gc_survives_vanishing_dir(self, tmp_path, monkeypatch):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=1)
        for s in (1, 2, 3):
            store.save(str(tmp_path), s, _state(s))

        real_rmtree = shutil.rmtree

        def racing_rmtree(path, *a, **k):
            real_rmtree(path, *a, **k)  # external cleaner got there first
            raise FileNotFoundError(path)

        monkeypatch.setattr(shutil, "rmtree", racing_rmtree)
        ck._gc()  # must not raise
        monkeypatch.undo()
        assert store.steps_available(str(tmp_path)) == [3]

    def test_gc_survives_missing_root(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path / "sub"), keep=1)
        shutil.rmtree(tmp_path / "sub", ignore_errors=True)
        ck._gc()  # whole dir vanished — no crash


class TestAsyncCheckpointer:
    def test_accounting(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=3)
        ck.save(1, _state(1), meta={"it": 1})
        ck.wait()
        assert ck.last_saved == 1
        assert ck.bytes_written > 0
        assert store.load_meta(str(tmp_path), 1) == {"it": 1}

    def test_background_error_surfaces_on_wait(self, tmp_path, monkeypatch):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=3)

        def boom(*a, **k):
            raise RuntimeError("disk full")

        monkeypatch.setattr(store, "save", boom)
        ck.save(2, _state(2))
        ck._thread.join()
        with pytest.raises(RuntimeError, match="disk full"):
            ck.wait()

    def test_stall_accounting(self, tmp_path, monkeypatch):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=3)
        real_save = store.save

        def slow_save(*a, **k):
            import time
            time.sleep(0.05)
            return real_save(*a, **k)

        monkeypatch.setattr(store, "save", slow_save)
        ck.save(1, _state(1))
        ck.save(2, _state(2))  # issued while 1 still writing → stall
        ck.wait()
        assert ck.stalls >= 1
        assert ck.stall_s > 0
