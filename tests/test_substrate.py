"""Training/serving substrate tests: optimizer, data, checkpoint (elastic),
fault-tolerant driver, gradient compression, serve engine."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.compat import AxisType, make_mesh, set_mesh

from repro.checkpoint import store
from repro.configs import get_config
from repro.data import DataConfig, Prefetcher, synthetic_batch
from repro.models import transformer as tfm
from repro.optim import adamw, compress
from repro.runtime import FailureInjector, RuntimeConfig, run_training
from repro.serve import EngineConfig, Request, ServeEngine
from repro.train import TrainConfig, build_train_step


def tiny_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return make_mesh(dev, ("data", "model"), axis_types=(AxisType.Auto,) * 2)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init_opt_state(params)
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw.apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.1
        assert float(m["grad_norm"]) < 1.0

    def test_grad_clip(self):
        grads = {"a": jnp.full((10,), 100.0)}
        clipped, gnorm = adamw.clip_by_global_norm(grads, 1.0)
        assert float(gnorm) > 100
        total = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(clipped)))
        np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(seq_len=32, global_batch=4, vocab=100)
        b1 = synthetic_batch(cfg, 7)
        b2 = synthetic_batch(cfg, 7)
        np.testing.assert_array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))
        b3 = synthetic_batch(cfg, 8)
        assert not np.array_equal(np.asarray(b1["inputs"]), np.asarray(b3["inputs"]))

    def test_targets_shifted(self):
        cfg = DataConfig(seq_len=16, global_batch=2, vocab=50)
        b = synthetic_batch(cfg, 0)
        assert b["inputs"].shape == (2, 16) and b["targets"].shape == (2, 16)

    def test_prefetcher_sequence(self):
        cfg = DataConfig(seq_len=8, global_batch=2, vocab=10)
        pf = Prefetcher(cfg, start_step=0)
        batches = [next(pf) for _ in range(3)]
        ref = [synthetic_batch(cfg, s) for s in range(3)]
        for b, r in zip(batches, ref):
            np.testing.assert_array_equal(np.asarray(b["inputs"]), np.asarray(r["inputs"]))


class TestTrainStep:
    def test_loss_decreases_smoke_model(self):
        cfg = get_config("granite-20b", smoke=True)
        mesh = tiny_mesh()
        with set_mesh(mesh):
            step_fn, sh, _ = build_train_step(cfg, mesh, TrainConfig(
                optimizer=adamw.AdamWConfig(lr=3e-3, warmup_steps=5)))
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            opt = adamw.init_opt_state(params)
            dcfg = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab)
            losses = []
            for s in range(30):
                batch = synthetic_batch(dcfg, s)
                params, opt, metrics = step_fn(params, opt, batch)
                losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]

    def test_microbatching_matches_full_batch_loss(self):
        cfg = get_config("starcoder2-7b", smoke=True)
        mesh = tiny_mesh()
        dcfg = DataConfig(seq_len=8, global_batch=4, vocab=cfg.vocab)
        batch = synthetic_batch(dcfg, 0)
        with set_mesh(mesh):
            f1, _, _ = build_train_step(cfg, mesh, TrainConfig(microbatches=1))
            f2, _, _ = build_train_step(cfg, mesh, TrainConfig(microbatches=2))
            # step fns donate their inputs — build fresh states per call
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            _, _, m1 = f1(params, adamw.init_opt_state(params), batch)
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            _, _, m2 = f2(params, adamw.init_opt_state(params), batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = {
            "params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.int32(5),
        }
        store.save(str(tmp_path), 5, state)
        assert store.latest_step(str(tmp_path)) == 5
        like = jax.tree.map(jnp.zeros_like, state)
        back = store.restore(str(tmp_path), 5, like)
        np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                      np.asarray(state["params"]["w"]))

    def test_corruption_detected(self, tmp_path):
        state = {"w": jnp.ones((4,))}
        d = store.save(str(tmp_path), 1, state)
        # tamper with the array file
        path = os.path.join(d, "arrays.npz")
        data = dict(np.load(path))
        key = list(data)[0]
        data[key] = data[key] + 1
        np.savez(path, **data)
        with pytest.raises(IOError):
            store.restore(str(tmp_path), 1, {"w": jnp.zeros((4,))})

    def test_elastic_restore_new_sharding(self, tmp_path):
        mesh = tiny_mesh()
        from jax.sharding import NamedSharding, PartitionSpec as P

        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        store.save(str(tmp_path), 2, state)
        sh = {"w": NamedSharding(mesh, P("data", "model"))}
        back = store.restore(str(tmp_path), 2, jax.tree.map(jnp.zeros_like, state), sh)
        assert back["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(state["w"]))

    def test_gc_keeps_last_k(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"w": jnp.ones(2) * s})
        ck.wait()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [3, 4]


class TestFaultTolerance:
    def _setup(self, tmp_path):
        cfg = get_config("starcoder2-7b", smoke=True)
        mesh = tiny_mesh()
        step_fn, _, _ = build_train_step(cfg, mesh, TrainConfig(
            optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=2)))
        dcfg = DataConfig(seq_len=8, global_batch=2, vocab=cfg.vocab)

        def make_state():
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            return {"params": params, "opt": adamw.init_opt_state(params)}

        def wrapped_step(state, batch):
            with set_mesh(mesh):
                p, o, m = step_fn(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, m

        return make_state, wrapped_step, (lambda s: synthetic_batch(dcfg, s))

    def test_restart_after_injected_failure(self, tmp_path):
        make_state, step_fn, batch_fn = self._setup(tmp_path)
        rc = RuntimeConfig(ckpt_dir=str(tmp_path), ckpt_every=4, max_rollbacks=2)
        res = run_training(
            steps=12, make_state=make_state, step_fn=step_fn, batch_fn=batch_fn,
            rc=rc, injector=FailureInjector(fail_steps=(6,)),
        )
        assert res.final_step == 12
        assert res.restarts == 1
        assert len(res.losses) == 12 - (store.latest_step(str(tmp_path)) or 0) or True

    def test_straggler_detected(self, tmp_path):
        make_state, step_fn, batch_fn = self._setup(tmp_path)
        rc = RuntimeConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                           straggler_factor=2.5)
        res = run_training(
            steps=8, make_state=make_state, step_fn=step_fn, batch_fn=batch_fn,
            rc=rc, injector=FailureInjector(straggle_steps=(5,), straggle_s=1.0),
        )
        assert res.straggler_events >= 1
        assert res.final_step == 8


class TestCompression:
    def test_topk_error_feedback_converges(self):
        # EF-top-k on a quadratic: residual accumulation must preserve
        # convergence despite 90% sparsification
        w = jnp.array(np.random.default_rng(0).normal(size=64).astype(np.float32))
        err = jnp.zeros((64,), jnp.float32)
        ccfg = compress.CompressConfig(density=0.1, min_size=1)
        for _ in range(300):
            g = 2 * w
            vals, idx, err = compress.compress_grad(g, err, ccfg)
            g_hat = compress.decompress(vals, idx, (64,))
            w = w - 0.05 * g_hat
        assert float(jnp.abs(w).max()) < 0.05

    def test_ratio(self):
        grads = {"big": jnp.zeros((100_000,)), "small": jnp.zeros((10,))}
        r = compress.compression_ratio(grads, compress.CompressConfig(density=0.01))
        assert r < 0.05


class TestServeEngine:
    def test_continuous_batching_completes_all(self):
        cfg = get_config("granite-20b", smoke=True)
        mesh = tiny_mesh()
        with set_mesh(mesh):
            params = tfm.init_params(cfg, jax.random.PRNGKey(1))
            eng = ServeEngine(cfg, params, mesh,
                              EngineConfig(max_batch=2, s_max=32))
            rng = np.random.default_rng(0)
            for rid in range(5):
                eng.submit(Request(rid=rid,
                                   prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                                   max_new_tokens=4))
            done = eng.run_to_completion()
        assert len(done) == 5
        for req in done:
            assert len(req.out_tokens) == 4
            assert all(0 <= t < cfg.vocab for t in req.out_tokens)
