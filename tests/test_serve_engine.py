"""Fast-lane tests for the plan-cached SpGEMM serving engine.

Single-device grid: admission control (refusal / deferral under the
``per_process_memory`` budget), plan-cache behavior (repeat traffic reuses
the fused-step executable — zero retraces, asserted via
``summa3d.TRACE_COUNTS``), FIFO ordering, per-request ``RunReport``
accounting, and numeric parity against a dense oracle (plus_times and
min_plus). The 8-device mixed-traffic smoke lives in ``tests/app_cases.py``.
"""
import numpy as np
import pytest

from repro.core import semiring as sr
from repro.core import summa3d
from repro.core.gen import erdos_renyi
from repro.core.grid import make_grid
from repro.serve import (
    MultiplyRequest,
    ServeConfig,
    SpgemmEngine,
    matrix_signature,
)


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _dense(s, fill=0.0):
    m = np.full(s.shape, fill, np.float64)
    nnz = int(s.nnz)
    m[np.asarray(s.rows)[:nnz], np.asarray(s.cols)[:nnz]] = (
        np.asarray(s.vals)[:nnz]
    )
    return m


def _pair(n=64, deg=4.0, seed=0):
    return (erdos_renyi(n, deg, seed=seed),
            erdos_renyi(n, deg, seed=seed + 1))


class TestCorrectness:
    def test_matches_dense_plus_times(self, grid1):
        a, b = _pair(seed=10)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        eng.submit(MultiplyRequest(rid=0, a=a, b=b))
        (res,) = eng.run_to_completion()
        assert res.status == "ok"
        np.testing.assert_allclose(
            _dense(res.c), _dense(a) @ _dense(b), rtol=1e-5, atol=1e-6
        )

    def test_matches_dense_min_plus(self, grid1):
        a, b = _pair(seed=20)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        eng.submit(MultiplyRequest(rid=0, a=a, b=b, semiring=sr.MIN_PLUS))
        (res,) = eng.run_to_completion()
        da, db = _dense(a, np.inf), _dense(b, np.inf)
        want = np.min(da[:, :, None] + db[None, :, :], axis=1)
        got = _dense(res.c, np.inf)
        # structural zeros are +inf in both renderings
        np.testing.assert_allclose(
            np.where(np.isfinite(want), want, 0),
            np.where(np.isfinite(got), got, 0), rtol=1e-5,
        )
        assert (np.isfinite(want) == np.isfinite(got)).all()


class TestPlanCache:
    def test_repeat_request_zero_retrace(self, grid1):
        a, b = _pair(seed=30)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        eng.submit(MultiplyRequest(rid=0, a=a, b=b))
        eng.run_to_completion()
        assert eng.stats == {**eng.stats, "hits": 0, "misses": 1}
        t0 = summa3d.TRACE_COUNTS["fused_step"]
        eng.submit(MultiplyRequest(rid=1, a=a, b=b))
        results = eng.run_to_completion()
        assert summa3d.TRACE_COUNTS["fused_step"] - t0 == 0
        repeat = [r for r in results if r.rid == 1][0]
        assert repeat.plan_cached
        assert eng.stats["hits"] == 1 and eng.cache_hit_rate() == 0.5

    def test_signature_stability(self, grid1):
        cfg = ServeConfig()
        a, b = _pair(seed=40)
        r1 = MultiplyRequest(rid=0, a=a, b=b)
        r2 = MultiplyRequest(rid=1, a=a, b=b)
        assert (matrix_signature(r1, grid1, cfg)
                == matrix_signature(r2, grid1, cfg))
        c, d = _pair(n=32, seed=41)
        r3 = MultiplyRequest(rid=2, a=c, b=d)
        assert (matrix_signature(r1, grid1, cfg)
                != matrix_signature(r3, grid1, cfg))

    def test_concurrent_same_signature_hits(self, grid1):
        # the entry is written at plan time, so the second identical request
        # hits even though the first has not completed yet
        a, b = _pair(seed=50)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        eng.submit(MultiplyRequest(rid=0, a=a, b=b))
        eng.submit(MultiplyRequest(rid=1, a=a, b=b))
        results = eng.run_to_completion()
        assert [r.status for r in results] == ["ok", "ok"]
        assert eng.stats["hits"] == 1 and eng.stats["misses"] == 1


class TestAdmission:
    def test_refusal_at_budget(self, grid1):
        a, b = _pair(seed=60)
        # budget below the operands' own footprint: no split can fit it
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1024))
        eng.submit(MultiplyRequest(rid=0, a=a, b=b))
        (res,) = eng.run_to_completion()
        assert res.status == "refused" and res.c is None
        assert res.reason != ""
        assert eng.stats["refused"] == 1 and eng.stats["served"] == 0

    def test_deferred_fifo_ordering(self, grid1):
        a, b = _pair(seed=70)
        # probe one request's price, then set a budget that fits exactly one
        probe = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        probe.submit(MultiplyRequest(rid=0, a=a, b=b))
        (p,) = probe.run_to_completion()
        budget = int(p.price_bytes * 1.5)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=budget))
        for rid in range(3):
            eng.submit(MultiplyRequest(rid=rid, a=a, b=b))
        results = eng.run_to_completion()
        assert [r.rid for r in results] == [0, 1, 2]  # FIFO, no overtaking
        assert all(r.status == "ok" for r in results)
        assert eng.stats["deferred"] >= 1
        assert results[1].was_deferred

    def test_budget_forces_batching(self, grid1):
        a, b = _pair(n=96, deg=8.0, seed=80)
        roomy = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        roomy.submit(MultiplyRequest(rid=0, a=a, b=b))
        (r0,) = roomy.run_to_completion()
        tight = SpgemmEngine(
            grid1, ServeConfig(per_process_memory=int(r0.price_bytes * 0.7))
        )
        tight.submit(MultiplyRequest(rid=0, a=a, b=b))
        (r1,) = tight.run_to_completion()
        assert r1.status == "ok"
        assert r1.num_batches > r0.num_batches or r1.splits > 0
        np.testing.assert_allclose(
            _dense(r1.c), _dense(r0.c), rtol=1e-5, atol=1e-6
        )


class TestAccounting:
    def test_run_report_and_result_fields(self, grid1):
        a, b = _pair(seed=90)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        eng.submit(MultiplyRequest(rid=7, a=a, b=b))
        (res,) = eng.run_to_completion()
        assert res.rid == 7 and res.status == "ok"
        assert res.price_bytes > 0 and res.num_batches >= 1
        assert res.latency_ms > 0
        assert res.report.retries == 0 and res.report.sel_retries == 0
        assert res.report.to_dict()["retries"] == 0  # JSON round-trip intact
        assert eng.stats["served"] == 1
        assert eng.stats["hits"] + eng.stats["misses"] == 1

    def test_mixed_traffic_stats(self, grid1):
        rng = np.random.default_rng(0)
        eng = SpgemmEngine(grid1, ServeConfig(per_process_memory=1 << 24))
        a0, b0 = _pair(seed=100)
        for rid in range(6):
            if rid % 2 == 0:
                eng.submit(MultiplyRequest(rid=rid, a=a0, b=b0))
            else:
                n = int(rng.integers(32, 48)) * 2
                a, b = _pair(n=n, deg=4.0, seed=200 + rid)
                eng.submit(MultiplyRequest(rid=rid, a=a, b=b))
        results = eng.run_to_completion()
        assert len(results) == 6
        assert eng.stats["served"] == 6
        assert eng.stats["hits"] >= 2  # the three repeats of (a0, b0)
        assert 0.0 < eng.cache_hit_rate() < 1.0
