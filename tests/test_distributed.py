"""Distributed SUMMA tests — each case runs in a subprocess with 8 host
devices (XLA device count is locked at first jax init, so the main pytest
process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

# subprocess-per-case with an 8-device host platform — excluded from the CI fast lane (-m 'not slow')
pytestmark = pytest.mark.slow

CASES = [
    "scatter_gather_roundtrip",
    "dense_path_full_multiply",
    "sparse_path_full_multiply",
    "symbolic_flops_exact",
    "plan_batches_bounds",
    "batched_dense_invariance",
    "batched_sparse_invariance",
    "layer1_grid",
    "symbolic_driven_batching",
    "semiring_or_and",
    "overflow_retry",
    "pipelined_serial_parity",
    "binned_sparse_path",
    "pipelined_overflow_retry",
    "rectangular_aat",
    "ring_schedule_matches",
    "tune_oracle_parity",
    "rect_grid_oracle_parity",
]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("case", CASES)
def test_distributed_case(case):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.path.join(REPO, "src"),
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "distributed_cases.py"), case],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert r.returncode == 0, f"case {case} failed:\n{r.stdout}\n{r.stderr}"
    assert f"OK {case}" in r.stdout
