"""Fast-lane tests for the device-resident MCL building blocks.

These run on a 1x1x1 grid (single device — the fast lane keeps the default
host platform), so the distributed column reductions, the fused per-batch
prune step, and the on-grid operand reassembly are exercised in-process
against numpy oracles; the full 8-device parity cases live in
``tests/app_cases.py`` (slow lane).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import sparse as sp
from repro.core.batched import batched_summa3d, plan_batches
from repro.core.distsparse import (
    dist_col_reduce,
    dist_col_sums,
    gather_to_global,
    scatter_to_grid,
)
from repro.core.grid import make_grid
from repro.core.summa3d import reassemble_operands
from repro.sparse_apps.mcl import (
    MCLConfig,
    _col_normalize_np,
    _mcl_prune_sparse,
    _prune_topk_np,
    mcl_iterate,
    mcl_iterate_host,
)


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _dense_mat(n, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.1, 1.0, (n, n)).astype(np.float32)
    x = np.where(rng.random((n, n)) < density, x, 0.0).astype(np.float32)
    return x


class TestDistColReduce:
    @pytest.mark.parametrize("kind", ["A", "B"])
    @pytest.mark.parametrize("op", ["sum", "max"])
    def test_matches_numpy(self, grid1, kind, op, n=24):
        x = _dense_mat(n, 0.4, seed=n + ord(kind))
        d = scatter_to_grid(sp.from_dense(jnp.asarray(x), cap=400), grid1, kind)
        reduce = (
            (lambda d_, g_: dist_col_sums(d_, g_)) if op == "sum"
            else (lambda d_, g_: dist_col_reduce(d_, g_, op="max"))
        )
        got = np.asarray(reduce(d, grid1))[0, 0, 0]
        want = x.sum(axis=0) if op == "sum" else x.max(axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestMclPruneStep:
    def test_matches_host_prune_math(self, grid1, n=16, k=3):
        """Fused inflate+normalize+top-k == the numpy reference pipeline
        (distinct values, so threshold selection == exact top-k)."""
        x = _dense_mat(n, 0.5, seed=7)
        d = scatter_to_grid(sp.from_dense(jnp.asarray(x), cap=200), grid1, "C")
        cfg = MCLConfig(inflation=2.0, prune_threshold=1e-4, max_per_col=k)
        pruned, stats = _mcl_prune_sparse(
            d, grid=grid1, inflation=cfg.inflation, thresh=cfg.prune_threshold,
            k=k, new_cap=200,
        )
        cnt = int(np.asarray(pruned.nnz)[0, 0, 0])
        got = np.zeros((n, n), np.float32)
        got[np.asarray(pruned.rows)[0, 0, 0, :cnt],
            np.asarray(pruned.cols)[0, 0, 0, :cnt]] = (
            np.asarray(pruned.vals)[0, 0, 0, :cnt])

        rr, cc = np.nonzero(x)
        vv = x[rr, cc].astype(np.float64) ** cfg.inflation
        vv = _col_normalize_np(rr, cc, vv, n)
        rr, cc, vv = _prune_topk_np(rr, cc, vv, n, cfg.prune_threshold, k)
        vv = _col_normalize_np(rr, cc, vv, n)
        want = np.zeros((n, n), np.float32)
        want[rr, cc] = vv
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
        assert int(np.asarray(stats["nnz"])) == len(rr)
        assert int(np.asarray(stats["overflow"])) == 0
        # chaos agrees with the host definition on the same values
        colmax = np.zeros(n); colsq = np.zeros(n)
        np.maximum.at(colmax, cc, vv)
        np.add.at(colsq, cc, vv ** 2)
        np.testing.assert_allclose(
            float(np.asarray(stats["chaos"])), (colmax - colsq).max(),
            rtol=1e-4, atol=1e-5,
        )

    def test_keeps_at_most_k_per_column(self, grid1, n=16, k=2):
        x = _dense_mat(n, 0.8, seed=9)
        d = scatter_to_grid(sp.from_dense(jnp.asarray(x), cap=300), grid1, "C")
        pruned, _ = _mcl_prune_sparse(
            d, grid=grid1, inflation=2.0, thresh=1e-4, k=k, new_cap=300,
        )
        cnt = int(np.asarray(pruned.nnz)[0, 0, 0])
        cols = np.asarray(pruned.cols)[0, 0, 0, :cnt]
        assert np.bincount(cols, minlength=n).max() <= k

    def test_tied_columns_keep_exactly_k(self, grid1, n=16, k=2):
        """Regression: a column of EQUAL values (uniform-weight graph column
        after normalization — every entry ties at the k boundary) must keep
        exactly k entries, not be annihilated by the bisection threshold."""
        x = np.zeros((n, n), np.float32)
        deg = 5  # > k: the tie straddles the top-k boundary in every column
        for j in range(n):
            x[(np.arange(deg) + j) % n, j] = 1.0  # uniform column values
        d = scatter_to_grid(sp.from_dense(jnp.asarray(x), cap=200), grid1, "C")
        pruned, stats = _mcl_prune_sparse(
            d, grid=grid1, inflation=2.0, thresh=1e-4, k=k, new_cap=200,
        )
        cnt = int(np.asarray(pruned.nnz)[0, 0, 0])
        cols = np.asarray(pruned.cols)[0, 0, 0, :cnt]
        counts = np.bincount(cols, minlength=n)
        np.testing.assert_array_equal(counts, np.full(n, k))
        assert int(np.asarray(stats["nnz"])) == n * k
        # survivors are renormalized: each kept entry is 1/k
        vals = np.asarray(pruned.vals)[0, 0, 0, :cnt]
        np.testing.assert_allclose(vals, 1.0 / k, rtol=1e-5)


class TestReassembleOperands:
    @pytest.mark.parametrize("nb", [1, 2, 4])
    def test_roundtrip_from_batches(self, grid1, nb, n=16):
        """Batched C outputs -> next A/B operands on-grid: both gather back
        to the multiply's dense result, with zero overflow at the hard-bound
        capacities."""
        xa = _dense_mat(n, 0.4, seed=11)
        xb = _dense_mat(n, 0.4, seed=13)
        A = scatter_to_grid(sp.from_dense(jnp.asarray(xa), cap=200), grid1, "A")
        B = scatter_to_grid(sp.from_dense(jnp.asarray(xb), cap=200), grid1, "B")
        batches = []
        res = batched_summa3d(
            A, B, grid1, per_process_memory=1 << 30,
            consumer=lambda bi, c, cm: batches.append(c),
            path="sparse", force_num_batches=nb,
        )
        assert res.plan.num_batches == nb
        cap = 1024
        a2, b2, ovf = reassemble_operands(tuple(batches), grid1, cap, cap)
        assert int(ovf) == 0
        want = xa @ xb
        np.testing.assert_allclose(
            np.asarray(gather_to_global(a2).to_dense()), want,
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_allclose(
            np.asarray(gather_to_global(b2).to_dense()), want,
            rtol=1e-4, atol=1e-5,
        )
        assert a2.kind == "A" and b2.kind == "B"


class TestPlanReservedBytes:
    def test_reserved_bytes_tightens_plan(self, grid1, n=32):
        x = _dense_mat(n, 0.5, seed=17)
        a = sp.from_dense(jnp.asarray(x), cap=800)
        A = scatter_to_grid(a, grid1, "A")
        B = scatter_to_grid(a, grid1, "B")
        base = plan_batches(A, B, grid1, per_process_memory=1 << 16)
        tight = plan_batches(
            A, B, grid1, per_process_memory=1 << 16, reserved_bytes=3 << 14
        )
        assert tight.num_batches > base.num_batches
        with pytest.raises(MemoryError):
            plan_batches(
                A, B, grid1, per_process_memory=1 << 16, reserved_bytes=1 << 16
            )


class TestDeviceLoopSingleDevice:
    def test_device_matches_host_on_1x1x1(self, grid1, n=24):
        """Whole device-resident loop == host reference, in-process."""
        x = _dense_mat(n, 0.5, seed=23)
        rr, cc = np.nonzero(x)
        vv = _col_normalize_np(rr, cc, x[rr, cc].astype(np.float64), n)
        a = sp.from_numpy_coo(rr, cc, vv.astype(np.float32), (n, n))
        cfg = MCLConfig(max_iters=4, per_process_memory=1 << 24, max_per_col=8)
        _, hist_d = mcl_iterate(a, grid1, cfg)
        _, hist_h = mcl_iterate_host(a, grid1, cfg)
        assert [h["nnz"] for h in hist_d] == [h["nnz"] for h in hist_h]
        np.testing.assert_allclose(
            [h["chaos"] for h in hist_d], [h["chaos"] for h in hist_h],
            rtol=1e-3, atol=1e-5,
        )


class TestFusedStepCompileCount:
    def test_pow2_caps_hit_jit_cache(self, grid1, n=24):
        """ROADMAP MCL follow-up (b): per-iteration capacity drift must NOT
        recompile the fused step. With pow2-quantized, running-max floored
        capacities (and the k-bin signature pinned after iteration 1) a
        4-iteration MCL run traces the fused step at most twice — iteration
        1's scattered operands vs. the reassembled operands of iterations
        2+ — and a repeat run of the same loop traces NOTHING."""
        from repro.core import summa3d

        x = _dense_mat(n, 0.5, seed=23)
        rr, cc = np.nonzero(x)
        vv = _col_normalize_np(rr, cc, x[rr, cc].astype(np.float64), n)
        a = sp.from_numpy_coo(rr, cc, vv.astype(np.float32), (n, n))
        cfg = MCLConfig(max_iters=4, per_process_memory=1 << 24,
                        max_per_col=8, force_num_batches=2)
        t0 = summa3d.TRACE_COUNTS["fused_step"]
        _, hist = mcl_iterate(a, grid1, cfg)
        assert len(hist) == 4, "need a multi-iteration run to prove caching"
        first = summa3d.TRACE_COUNTS["fused_step"] - t0
        assert first <= 2, f"fused step traced {first}x in one MCL run"
        t1 = summa3d.TRACE_COUNTS["fused_step"]
        _, hist2 = mcl_iterate(a, grid1, cfg)
        assert len(hist2) == 4
        repeat = summa3d.TRACE_COUNTS["fused_step"] - t1
        assert repeat == 0, f"repeat run recompiled the fused step {repeat}x"

    def test_unforced_batch_count_pinned(self, grid1, n=24):
        """With memory-driven planning (force_num_batches=None, the default
        config) the batch count is floored at its running max, so a
        sparsifying iterate cannot shrink nb mid-run and re-trace; the
        repeat-run contract holds for the default config too."""
        from repro.core import summa3d

        x = _dense_mat(n, 0.6, seed=29)
        rr, cc = np.nonzero(x)
        vv = _col_normalize_np(rr, cc, x[rr, cc].astype(np.float64), n)
        a = sp.from_numpy_coo(rr, cc, vv.astype(np.float32), (n, n))
        cfg = MCLConfig(max_iters=4, per_process_memory=1 << 17, max_per_col=4)
        _, hist = mcl_iterate(a, grid1, cfg)
        assert len(hist) >= 3
        nbs = [h["batches"] for h in hist]
        assert nbs == sorted(nbs), f"batch count shrank mid-run: {nbs}"
        t0 = summa3d.TRACE_COUNTS["fused_step"]
        mcl_iterate(a, grid1, cfg)
        repeat = summa3d.TRACE_COUNTS["fused_step"] - t0
        assert repeat == 0, f"repeat run recompiled the fused step {repeat}x"
