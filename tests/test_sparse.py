"""Unit + property tests for the fixed-capacity sparse core."""
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import sparse as sp
from repro.core import gen


def dense_random(rng, m, n, density):
    x = rng.random((m, n)).astype(np.float32)
    mask = rng.random((m, n)) < density
    return np.where(mask, x + 0.1, 0.0).astype(np.float32)


@pytest.fixture
def rng():
    return np.random.default_rng(42)


class TestRoundtrip:
    def test_from_to_dense(self, rng):
        x = dense_random(rng, 13, 17, 0.2)
        a = sp.from_dense(jnp.asarray(x), cap=300)
        np.testing.assert_allclose(np.asarray(a.to_dense()), x, rtol=1e-6)

    def test_from_numpy_coo_dedup(self):
        rows = np.array([0, 0, 1, 2, 2])
        cols = np.array([1, 1, 0, 2, 2])
        vals = np.array([1.0, 2.0, 3.0, 4.0, 5.0], np.float32)
        a = sp.from_numpy_coo(rows, cols, vals, (3, 3))
        d = np.asarray(a.to_dense())
        assert d[0, 1] == 3.0 and d[1, 0] == 3.0 and d[2, 2] == 9.0
        assert int(a.nnz) == 3

    def test_transpose(self, rng):
        x = dense_random(rng, 7, 11, 0.3)
        a = sp.from_dense(jnp.asarray(x), cap=100)
        np.testing.assert_allclose(np.asarray(a.transpose().to_dense()), x.T)

    def test_empty(self):
        e = sp.empty((5, 6), cap=10)
        assert np.asarray(e.to_dense()).sum() == 0
        assert int(e.nnz) == 0


class TestInvariants:
    def test_sort_rowmajor_keeps_padding_last(self, rng):
        x = dense_random(rng, 9, 9, 0.25)
        a = sp.from_dense(jnp.asarray(x), cap=60).sort_rowmajor()
        nnz = int(a.nnz)
        assert np.all(np.asarray(a.rows[nnz:]) == 9)
        r = np.asarray(a.rows[:nnz])
        c = np.asarray(a.cols[:nnz])
        keys = r * 9 + c
        assert np.all(np.diff(keys) > 0)

    def test_with_capacity_grow_shrink(self, rng):
        x = dense_random(rng, 6, 6, 0.2)
        a = sp.from_dense(jnp.asarray(x), cap=40)
        big = a.with_capacity(80)
        np.testing.assert_allclose(np.asarray(big.to_dense()), x)
        small = big.sort_rowmajor().with_capacity(int(a.nnz))
        np.testing.assert_allclose(np.asarray(small.to_dense()), x)

    def test_compact_overflow_counts(self):
        a = sp.from_dense(jnp.asarray(np.eye(8, dtype=np.float32)), cap=16)
        kept, overflow = a.compact(a.rows < 8, new_cap=4)
        assert int(kept.nnz) == 4
        assert int(overflow) == 4


class TestColumnOps:
    def test_select_col_block(self, rng):
        x = dense_random(rng, 10, 12, 0.4)
        a = sp.from_dense(jnp.asarray(x), cap=80)
        blk, ovf = a.select_col_block(4, 4, new_cap=80)
        assert int(ovf) == 0
        np.testing.assert_allclose(np.asarray(blk.to_dense()), x[:, 4:8])

    def test_blockcyclic_partition_covers_all(self, rng):
        # b=2 batches, l=2 layers, 8 columns -> blocks of width 2
        x = dense_random(rng, 6, 8, 0.5)
        a = sp.from_dense(jnp.asarray(x), cap=60)
        b0, _ = a.select_cols_blockcyclic(0, 2, 2, new_cap=60)
        b1, _ = a.select_cols_blockcyclic(1, 2, 2, new_cap=60)
        # batch 0 gets blocks 0,2 -> cols 0,1,4,5 ; batch 1 gets 2,3,6,7
        np.testing.assert_allclose(
            np.asarray(b0.to_dense()), x[:, [0, 1, 4, 5]]
        )
        np.testing.assert_allclose(
            np.asarray(b1.to_dense()), x[:, [2, 3, 6, 7]]
        )

    def test_split_col_blocks_matches_select_loop(self, rng):
        x = dense_random(rng, 10, 12, 0.5)
        a = sp.from_dense(jnp.asarray(x), cap=96).sort_rowmajor()
        for num_pieces, piece_cap in ((3, 32), (4, 32), (12, 8)):
            rows, cols, vals, nnz, ovf = a.split_col_blocks(num_pieces, piece_cap)
            assert int(ovf) == 0
            piece_w = 12 // num_pieces
            for k in range(num_pieces):
                ref, ref_ovf = a.select_col_block(k * piece_w, piece_w, piece_cap)
                assert int(ref_ovf) == 0
                np.testing.assert_array_equal(np.asarray(rows[k]), np.asarray(ref.rows))
                np.testing.assert_array_equal(np.asarray(cols[k]), np.asarray(ref.cols))
                np.testing.assert_array_equal(np.asarray(vals[k]), np.asarray(ref.vals))
                assert int(nnz[k]) == int(ref.nnz)

    def test_split_col_blocks_overflow(self, rng):
        x = dense_random(rng, 8, 8, 0.9)
        a = sp.from_dense(jnp.asarray(x), cap=64)
        total = int(a.nnz)
        rows, cols, vals, nnz, ovf = a.split_col_blocks(2, 4)
        assert int(nnz.sum()) + int(ovf) == total
        assert int(ovf) > 0
        assert int(nnz.max()) <= 4

    def test_counts(self, rng):
        x = dense_random(rng, 15, 9, 0.3)
        a = sp.from_dense(jnp.asarray(x), cap=100)
        np.testing.assert_array_equal(
            np.asarray(a.col_counts()), (x != 0).sum(0).astype(np.int32)
        )
        np.testing.assert_array_equal(
            np.asarray(a.row_counts()), (x != 0).sum(1).astype(np.int32)
        )


class TestCoalesceConcat:
    def test_coalesce_sums_duplicates(self):
        rows = jnp.array([2, 0, 2, 5, 5], jnp.int32)
        cols = jnp.array([3, 1, 3, 5, 5], jnp.int32)
        vals = jnp.array([1.0, 2.0, 3.0, 4.0, -4.0], jnp.float32)
        a = sp.SparseCOO(rows, cols, vals, jnp.int32(5), (6, 6))
        m, ovf = sp.coalesce(a, new_cap=8)
        assert int(ovf) == 0
        d = np.asarray(m.to_dense())
        assert d[2, 3] == 4.0 and d[0, 1] == 2.0
        # duplicates (5,5) with values 4 and -4 merge to an explicit zero entry
        assert int(m.nnz) == 3

    def test_concat_then_dense(self, rng):
        x = dense_random(rng, 8, 8, 0.2)
        y = dense_random(rng, 8, 8, 0.2)
        a = sp.from_dense(jnp.asarray(x), cap=30)
        b = sp.from_dense(jnp.asarray(y), cap=30)
        c, ovf = sp.concat([a, b], new_cap=90)
        assert int(ovf) == 0
        merged, ovf2 = sp.coalesce(c, new_cap=90)
        assert int(ovf2) == 0
        np.testing.assert_allclose(np.asarray(merged.to_dense()), x + y, rtol=1e-6)

    def test_hstack_remap(self, rng):
        x = dense_random(rng, 5, 4, 0.5)
        y = dense_random(rng, 5, 6, 0.5)
        a = sp.from_dense(jnp.asarray(x), cap=30)
        b = sp.from_dense(jnp.asarray(y), cap=40)
        c, ovf = sp.hstack_remap([a, b], [4, 6], new_cap=70)
        assert int(ovf) == 0
        np.testing.assert_allclose(
            np.asarray(c.to_dense()), np.concatenate([x, y], axis=1)
        )


class TestPruneScale:
    def test_prune_threshold(self, rng):
        x = dense_random(rng, 10, 10, 0.5)
        a = sp.from_dense(jnp.asarray(x), cap=80)
        pruned, _ = a.prune_threshold(0.5, new_cap=80)
        expect = np.where(np.abs(x) >= 0.5, x, 0.0)
        np.testing.assert_allclose(np.asarray(pruned.to_dense()), expect)

    def test_scale_cols(self, rng):
        x = dense_random(rng, 6, 4, 0.6)
        s = np.arange(1, 5, dtype=np.float32)
        a = sp.from_dense(jnp.asarray(x), cap=30)
        np.testing.assert_allclose(
            np.asarray(a.scale_cols(jnp.asarray(s)).to_dense()), x * s, rtol=1e-6
        )


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(2, 12),
    n=st.integers(2, 12),
    density=st.floats(0.05, 0.6),
    seed=st.integers(0, 2**16),
)
def test_property_roundtrip_and_sort(m, n, density, seed):
    rng = np.random.default_rng(seed)
    x = dense_random(rng, m, n, density)
    cap = m * n + 3
    a = sp.from_dense(jnp.asarray(x), cap=cap)
    np.testing.assert_allclose(np.asarray(a.to_dense()), x, rtol=1e-6)
    for s in (a.sort_rowmajor(), a.sort_colmajor(), a.transpose().transpose()):
        np.testing.assert_allclose(np.asarray(s.to_dense()), x, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), b=st.integers(1, 4))
def test_property_blockcyclic_reassembles(seed, b):
    """Block-cyclic batches, hstack'd back in order, reproduce the matrix
    column set (possibly permuted) — and every column appears exactly once."""
    rng = np.random.default_rng(seed)
    l = 2
    n = b * l * 3  # divisible width
    x = dense_random(rng, 7, n, 0.4)
    a = sp.from_dense(jnp.asarray(x), cap=7 * n + 1)
    cols_seen = []
    for i in range(b):
        blk, ovf = a.select_cols_blockcyclic(i, b, l, new_cap=7 * n + 1)
        assert int(ovf) == 0
        w = n // (b * l)
        blocks = [j for j in range(b * l) if j % b == i]
        cols_seen += [blk for blkids in [blocks] for blk in blkids]
        expect = np.concatenate([x[:, j * w : (j + 1) * w] for j in blocks], axis=1)
        np.testing.assert_allclose(np.asarray(blk.to_dense()), expect)
    assert sorted(cols_seen) == list(range(b * l))


class TestGenerators:
    def test_erdos_renyi_stats(self):
        a = gen.erdos_renyi(100, 5.0, seed=1)
        assert a.shape == (100, 100)
        assert 350 <= int(a.nnz) <= 500  # dedup removes a few

    def test_rmat_skew(self):
        a = gen.rmat(scale=7, edge_factor=8, seed=1)
        counts = np.asarray(a.row_counts())
        assert counts.max() > 4 * max(counts.mean(), 1)  # power-law skew

    def test_kmer_like_shape(self):
        a = gen.kmer_like(50, 200, 4, seed=0)
        assert a.shape == (50, 200)
