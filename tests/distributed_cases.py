"""Distributed SUMMA correctness cases — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (see test_distributed.py).

Each case asserts against the dense reference. Invoked as:
    python tests/distributed_cases.py <case_name>
"""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import gen
from repro.core import semiring as sr
from repro.core import sparse as sp
from repro.core.batched import (
    batch_column_map,
    batched_summa3d,
    plan_batches,
    symbolic3d,
)
from repro.core.distsparse import DistSparse, gather_to_global, scatter_to_grid
from repro.core.grid import make_grid
from repro.core.summa3d import BatchCaps, summa3d_dense_step, summa3d_sparse_step


def _rand_square(n, density, seed, cap_slack=2.0):
    rng = np.random.default_rng(seed)
    x = rng.random((n, n)).astype(np.float32)
    mask = rng.random((n, n)) < density
    x = np.where(mask, x + 0.1, 0.0).astype(np.float32)
    return x, sp.from_dense(jnp.asarray(x), cap=int(mask.sum() * cap_slack) + 8)


def reconstruct_dense_c(c_tiles: np.ndarray, grid, col_map: np.ndarray, m: int, n: int):
    """Assemble global dense C (m × n) from stacked (pr,pc,l,tm,wbl) tiles."""
    pr, pc, l, tm, wbl = c_tiles.shape
    out = np.zeros((m, n), np.float32)
    for i in range(pr):
        for j in range(pc):
            for k in range(l):
                out[i * tm : (i + 1) * tm, col_map[j, k]] = c_tiles[i, j, k]
    return out


def reconstruct_sparse_c(c: DistSparse, grid, col_map: np.ndarray, m: int, n: int):
    pr, pc, l = c.grid_shape
    tm, wbl = c.tile_shape
    out = np.zeros((m, n), np.float32)
    R, C, V, N = (np.asarray(c.rows), np.asarray(c.cols), np.asarray(c.vals),
                  np.asarray(c.nnz))
    for i in range(pr):
        for j in range(pc):
            for k in range(l):
                cnt = int(N[i, j, k])
                gr = i * tm + R[i, j, k, :cnt]
                gc = col_map[j, k][C[i, j, k, :cnt]]
                np.add.at(out, (gr, gc), V[i, j, k, :cnt])
    return out


def case_scatter_gather_roundtrip():
    grid = make_grid(2, 2, 2)
    for kind in ("A", "B"):
        x, a = _rand_square(32, 0.2, seed=3)
        d = scatter_to_grid(a, grid, kind)
        back = gather_to_global(d)
        np.testing.assert_allclose(np.asarray(back.to_dense()), x, rtol=1e-6)
    print("OK scatter_gather_roundtrip")


def case_dense_path_full_multiply():
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.25, seed=5)
    xb, b = _rand_square(n, 0.25, seed=7)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    c_tiles = np.asarray(summa3d_dense_step(A, B, grid))
    col_map = batch_column_map(n, grid, 1, 0)
    got = reconstruct_dense_c(c_tiles, grid, col_map, n, n)
    np.testing.assert_allclose(got, xa @ xb, rtol=1e-4, atol=1e-5)
    print("OK dense_path_full_multiply")


def case_sparse_path_full_multiply():
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.25, seed=11)
    xb, b = _rand_square(n, 0.25, seed=13)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    caps = BatchCaps(flops_cap=8192, d_cap=4096, piece_cap=2048, c_cap=2048)
    c, ovf = summa3d_sparse_step(A, B, grid, caps)
    assert int(ovf) == 0, f"overflow {int(ovf)}"
    col_map = batch_column_map(n, grid, 1, 0)
    got = reconstruct_sparse_c(c, grid, col_map, n, n)
    np.testing.assert_allclose(got, xa @ xb, rtol=1e-4, atol=1e-5)
    print("OK sparse_path_full_multiply")


def case_symbolic_flops_exact():
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.3, seed=17)
    xb, b = _rand_square(n, 0.3, seed=19)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    percol = symbolic3d(A, B, grid)  # (pr,pc,l,tn_b)
    total = int(percol.sum())
    expect = int(((xa != 0).sum(0) * (xb != 0).sum(1)).sum())
    assert total == expect, (total, expect)
    print("OK symbolic_flops_exact")


def case_plan_batches_bounds():
    grid = make_grid(2, 2, 2)
    n = 32
    _, a = _rand_square(n, 0.3, seed=23)
    _, b = _rand_square(n, 0.3, seed=29)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    # generous memory -> 1 batch
    plan1 = plan_batches(A, B, grid, per_process_memory=1 << 30)
    assert plan1.num_batches == 1, plan1
    # tight memory -> multiple batches, Alg3 count >= Eq2 bound
    r = 12
    need = r * (int(np.asarray(A.nnz).max()) + int(np.asarray(B.nnz).max()))
    budget = need + r * max(plan1.max_unmerged_nnz // 3, 1)  # ~3 batches
    plan2 = plan_batches(A, B, grid, per_process_memory=budget)
    assert plan2.num_batches > 1
    if plan2.lower_bound > 0:
        assert plan2.num_batches >= plan2.lower_bound, plan2
    assert plan2.per_batch_flops.sum() == plan2.total_flops
    print("OK plan_batches_bounds")


def _run_batched(n, density, nb_force, l, path, seed=31):
    grid = make_grid(2, 2, l)
    xa, a = _rand_square(n, density, seed=seed)
    xb, b = _rand_square(n, density, seed=seed + 1)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    acc = np.zeros((n, n), np.float32)

    def consumer(bi, c_batch, col_map):
        if path == "dense":
            acc_part = reconstruct_dense_c(np.asarray(c_batch), grid, col_map, n, n)
        else:
            acc_part = reconstruct_sparse_c(c_batch, grid, col_map, n, n)
        acc[:] += acc_part
        return float(acc_part.sum())

    res = batched_summa3d(
        A, B, grid, per_process_memory=1 << 30, consumer=consumer, path=path,
        force_num_batches=nb_force,
    )
    np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-5)
    return res


def case_batched_dense_invariance():
    for nb in (1, 2, 4):
        _run_batched(32, 0.25, nb, l=2, path="dense")
    print("OK batched_dense_invariance")


def case_batched_sparse_invariance():
    for nb in (1, 2, 4):
        _run_batched(32, 0.25, nb, l=2, path="sparse")
    print("OK batched_sparse_invariance")


def case_layer1_grid():
    # l=1 degenerates to 2D SUMMA (paper Alg. 1); 2x2x1 grid on 4 devices
    for path in ("dense", "sparse"):
        _run_batched(32, 0.3, 2, l=1, path=path, seed=41)
    print("OK layer1_grid")


def case_symbolic_driven_batching():
    """End-to-end: tight memory budget forces b>1 via the symbolic step."""
    grid = make_grid(2, 2, 2)
    n = 64
    xa, a = _rand_square(n, 0.15, seed=43)
    xb, b = _rand_square(n, 0.15, seed=47)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    plan_free = plan_batches(A, B, grid, per_process_memory=1 << 30)
    r = 12
    need = r * (int(np.asarray(A.nnz).max()) + int(np.asarray(B.nnz).max()))
    budget = need + max(r * plan_free.max_unmerged_nnz // 3, 1)
    acc = np.zeros((n, n), np.float32)

    def consumer(bi, c_batch, col_map):
        acc[:] += reconstruct_sparse_c(c_batch, grid, col_map, n, n)

    res = batched_summa3d(
        A, B, grid, per_process_memory=budget, consumer=consumer, path="sparse"
    )
    assert res.plan.num_batches > 1, res.plan
    np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-5)
    print(f"OK symbolic_driven_batching (b={res.plan.num_batches})")


def case_semiring_or_and():
    """Boolean structure product over the or_and semiring (symbolic use)."""
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.2, seed=53)
    xb, b = _rand_square(n, 0.2, seed=59)
    # boolean-ize values
    a = sp.SparseCOO(a.rows, a.cols, jnp.where(a.valid_mask(), 1.0, 0.0), a.nnz, a.shape)
    b = sp.SparseCOO(b.rows, b.cols, jnp.where(b.valid_mask(), 1.0, 0.0), b.nnz, b.shape)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    caps = BatchCaps(flops_cap=8192, d_cap=4096, piece_cap=2048, c_cap=2048)
    c, ovf = summa3d_sparse_step(A, B, grid, caps, semiring=sr.OR_AND)
    assert int(ovf) == 0
    col_map = batch_column_map(n, grid, 1, 0)
    got = reconstruct_sparse_c(c, grid, col_map, n, n)
    expect = (((xa != 0).astype(np.float32) @ (xb != 0)) > 0).astype(np.float32)
    np.testing.assert_allclose(got, expect)
    print("OK semiring_or_and")


def case_overflow_retry():
    """Tiny slack must trigger the retry path yet still converge."""
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.4, seed=61)
    xb, b = _rand_square(n, 0.4, seed=67)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    acc = np.zeros((n, n), np.float32)

    def consumer(bi, c_batch, col_map):
        acc[:] += reconstruct_sparse_c(c_batch, grid, col_map, n, n)

    res = batched_summa3d(
        A, B, grid, per_process_memory=1 << 30, consumer=consumer, path="sparse",
        slack=0.05, force_num_batches=2, max_retries=8,
    )
    np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-5)
    assert res.num_retries > 0
    print(f"OK overflow_retry (retries={res.num_retries})")


def _collect_batches(path):
    """Consumer capturing each batch's raw tiles for exact cross-driver
    comparison (pipelined vs serial must be the same program)."""
    store = {}

    def consumer(bi, c_batch, col_map):
        if path == "dense":
            store[bi] = (np.asarray(c_batch),)
        else:
            store[bi] = (
                np.asarray(c_batch.rows), np.asarray(c_batch.cols),
                np.asarray(c_batch.vals), np.asarray(c_batch.nnz),
            )
        return bi

    return store, consumer


def _run_driver_pair(A, B, grid, path, semiring, nb, **kw):
    """Run pipelined + serial drivers, assert identical per-batch output."""
    stores = {}
    for pipelined in (True, False):
        store, consumer = _collect_batches(path)
        res = batched_summa3d(
            A, B, grid, per_process_memory=1 << 30, consumer=consumer,
            path=path, semiring=semiring, force_num_batches=nb,
            pipelined=pipelined, **kw,
        )
        assert res.consumed == list(range(res.plan.num_batches))
        stores[pipelined] = store
    assert stores[True].keys() == stores[False].keys()
    for bi in stores[True]:
        for x, y in zip(stores[True][bi], stores[False][bi]):
            np.testing.assert_array_equal(x, y)
    return stores[True]


def case_pipelined_serial_parity():
    """Pipelined scheduler == serial scheduler, batch for batch, over
    {PLUS_TIMES, MIN_PLUS} x {sparse, dense path} x {1, multi}-batch plans
    (dense path requires a sum monoid, so MIN_PLUS runs sparse-only)."""
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.25, seed=83)
    xb, b = _rand_square(n, 0.25, seed=89)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    for nb in (1, 4):
        for path in ("sparse", "dense"):
            batches = _run_driver_pair(A, B, grid, path, sr.PLUS_TIMES, nb)
            # PLUS_TIMES also checks against the dense reference
            acc = np.zeros((n, n), np.float32)
            for bi, tiles in batches.items():
                col_map = batch_column_map(n, grid, nb, bi)
                if path == "dense":
                    acc += reconstruct_dense_c(tiles[0], grid, col_map, n, n)
                else:
                    c = DistSparse(
                        rows=tiles[0], cols=tiles[1], vals=tiles[2],
                        nnz=tiles[3], shape=(n, n // nb),
                        tile_shape=(n // 2, n // 2 // nb // 2),
                        grid_shape=(2, 2, 2), kind="C",
                    )
                    acc += reconstruct_sparse_c(c, grid, col_map, n, n)
            np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-5)
        _run_driver_pair(A, B, grid, "sparse", sr.MIN_PLUS, nb)
    # MIN_PLUS correctness vs the tropical dense reference (single batch)
    batches = _run_driver_pair(A, B, grid, "sparse", sr.MIN_PLUS, 1)
    ai = np.where(xa != 0, xa, np.inf)
    bi_ = np.where(xb != 0, xb, np.inf)
    ref = (ai[:, :, None] + bi_[None, :, :]).min(axis=1)
    got = np.full((n, n), np.inf, np.float32)
    col_map = batch_column_map(n, grid, 1, 0)
    rows, cols, vals, nnzs = batches[0]
    tm, wbl = n // 2, n // 4
    for i in range(2):
        for j in range(2):
            for k in range(2):
                cnt = int(nnzs[i, j, k])
                gr = i * tm + rows[i, j, k, :cnt]
                gc = col_map[j, k][cols[i, j, k, :cnt]]
                got[gr, gc] = vals[i, j, k, :cnt]
    finite = np.isfinite(ref)
    np.testing.assert_allclose(got[finite], ref[finite], rtol=1e-5, atol=1e-6)
    assert not np.isfinite(got[~finite]).any()
    print("OK pipelined_serial_parity")


def case_binned_sparse_path():
    """Plan-driven k-binned local multiply == ESC on a skewed (R-MAT)
    workload, with strictly fewer pairings evaluated."""
    grid = make_grid(2, 2, 2)
    n = 64
    a = gen.rmat(scale=6, edge_factor=6, seed=91)
    b = gen.rmat(scale=6, edge_factor=6, seed=97)
    xa = np.asarray(a.to_dense())
    xb = np.asarray(b.to_dense())
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    accs = {}
    for binned in (True, False):
        acc = np.zeros((n, n), np.float32)

        def consumer(bi, c_batch, col_map, acc=acc):
            acc += reconstruct_sparse_c(c_batch, grid, col_map, n, n)

        res = batched_summa3d(
            A, B, grid, per_process_memory=1 << 30, consumer=consumer,
            path="sparse", force_num_batches=2, binned=binned,
        )
        assert res.binned == binned, (res.binned, binned)
        np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-4)
        accs[binned] = acc
    np.testing.assert_allclose(accs[True], accs[False], rtol=1e-5, atol=1e-5)
    plan = res.plan
    assert plan.kbin.pairings < plan.kbin.pairings_unbinned, plan.kbin
    # auto mode must pick the binned path on this plan
    res_auto = batched_summa3d(
        A, B, grid, per_process_memory=1 << 30,
        consumer=lambda bi, c, m: None, path="sparse", force_num_batches=2,
    )
    assert res_auto.binned
    print(
        f"OK binned_sparse_path (pairings {plan.kbin.pairings} < "
        f"{plan.kbin.pairings_unbinned}, bins={plan.kbin.num_bins})"
    )


def case_pipelined_overflow_retry():
    """Beaten capacities in the pipelined schedule must drop to the
    synchronous retry loop and still converge — on both local-multiply
    engines — and stay batch-identical to the serial schedule."""
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.4, seed=101)
    xb, b = _rand_square(n, 0.4, seed=103)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    for binned in (False, True):
        acc = np.zeros((n, n), np.float32)

        def consumer(bi, c_batch, col_map, acc=acc):
            acc += reconstruct_sparse_c(c_batch, grid, col_map, n, n)

        res = batched_summa3d(
            A, B, grid, per_process_memory=1 << 30, consumer=consumer,
            path="sparse", slack=0.05, force_num_batches=2, max_retries=8,
            pipelined=True, binned=binned,
        )
        np.testing.assert_allclose(acc, xa @ xb, rtol=1e-4, atol=1e-4)
        assert res.num_retries > 0, f"binned={binned} hit no retries"
    _run_driver_pair(
        A, B, grid, "sparse", sr.PLUS_TIMES, 2, slack=0.05, max_retries=8
    )
    print(f"OK pipelined_overflow_retry (retries={res.num_retries})")


def case_rectangular_aat():
    """AA^T on a kmer-like rectangular matrix (paper §V-G, BELLA use case)."""
    grid = make_grid(2, 2, 2)
    nseqs, nkmers = 32, 64
    a = gen.kmer_like(nseqs, nkmers, 4, seed=71)
    at = a.transpose().sort_rowmajor()
    xa = np.asarray(a.to_dense())
    A = scatter_to_grid(a, grid, "A")
    Bt = scatter_to_grid(at, grid, "B")
    caps = BatchCaps(flops_cap=8192, d_cap=4096, piece_cap=2048, c_cap=2048)
    c, ovf = summa3d_sparse_step(A, Bt, grid, caps)
    assert int(ovf) == 0
    col_map = batch_column_map(nseqs, grid, 1, 0)
    got = reconstruct_sparse_c(c, grid, col_map, nseqs, nseqs)
    np.testing.assert_allclose(got, xa @ xa.T, rtol=1e-4, atol=1e-5)
    print("OK rectangular_aat")




def case_ring_schedule_matches():
    """Cannon ring schedule == allgather schedule == dense reference
    (paper-faithful memory-constrained broadcast realization)."""
    grid = make_grid(2, 2, 2)
    n = 32
    xa, a = _rand_square(n, 0.3, seed=77)
    xb, b = _rand_square(n, 0.3, seed=79)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    col_map = batch_column_map(n, grid, 1, 0)
    got_ag = reconstruct_dense_c(
        np.asarray(summa3d_dense_step(A, B, grid)), grid, col_map, n, n
    )
    got_ring = reconstruct_dense_c(
        np.asarray(summa3d_dense_step(A, B, grid, schedule="ring")),
        grid, col_map, n, n,
    )
    np.testing.assert_allclose(got_ring, got_ag, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_ring, xa @ xb, rtol=1e-4, atol=1e-5)
    # also on an l=1 grid (pure 2D Cannon)
    grid1 = make_grid(2, 2, 1)
    A1 = scatter_to_grid(a, grid1, "A")
    B1 = scatter_to_grid(b, grid1, "B")
    col1 = batch_column_map(n, grid1, 1, 0)
    got1 = reconstruct_dense_c(
        np.asarray(summa3d_dense_step(A1, B1, grid1, schedule="ring")),
        grid1, col1, n, n,
    )
    np.testing.assert_allclose(got1, xa @ xb, rtol=1e-4, atol=1e-5)
    print("OK ring_schedule_matches")


def case_tune_oracle_parity():
    """The autotuner's host symbolic oracle reproduces the distributed
    symbolic pass BIT-FOR-BIT on a real 2×2×2 grid — counts, the derived
    plan (capacities, batch count, decided local path), and the
    ``PlanInputs.from_host`` capacities vs an actual default scatter — for
    both the unmasked and masked formulations. This is what licenses
    ``repro.tune`` to price candidate grids without scattering anything."""
    from repro.core.batched import PlanInputs, plan_from_symbolic, \
        symbolic3d_counts
    from repro.core.specs import PlanFloors, PlanSpec
    from repro.core.symbolic import host_symbolic_counts

    grid = make_grid(2, 2, 2)
    a = gen.rmat(6, edge_factor=8, seed=5)
    b = gen.rmat(6, edge_factor=8, seed=6)
    mask = gen.erdos_renyi(64, 4.0, seed=7)
    A = scatter_to_grid(a, grid, "A")
    B = scatter_to_grid(b, grid, "B")
    M = scatter_to_grid(mask, grid, "C")

    for m_host, m_dev in ((None, None), (mask, M)):
        dev = symbolic3d_counts(A, B, grid, mask=m_dev)
        host = host_symbolic_counts(a, b, (2, 2, 2), mask=m_host)
        np.testing.assert_array_equal(np.asarray(dev.percol), host.percol)
        np.testing.assert_array_equal(np.asarray(dev.b_colcounts),
                                      host.b_colcounts)
        np.testing.assert_array_equal(np.asarray(dev.a_kcounts),
                                      host.a_kcounts)
        np.testing.assert_array_equal(np.asarray(dev.b_kcounts),
                                      host.b_kcounts)
        if m_host is None:
            assert host.mask_colcounts is None
        else:
            np.testing.assert_array_equal(np.asarray(dev.mask_colcounts),
                                          host.mask_colcounts)

        spec = PlanSpec(mask=m_dev)
        ppm = 1 << 22
        dev_plan = plan_batches(A, B, grid, per_process_memory=ppm,
                                spec=spec)
        inputs = PlanInputs.from_host(a, b, (2, 2, 2), mask=m_host)
        host_plan = plan_from_symbolic(
            host_symbolic_counts(a, b, (2, 2, 2), mask=m_host), inputs,
            ppm, PlanSpec(mask=m_host), PlanFloors(),
        )
        assert host_plan.num_batches == dev_plan.num_batches
        assert host_plan.caps == dev_plan.caps
        assert host_plan.sel_cap == dev_plan.sel_cap
        assert host_plan.mask_sel_cap == dev_plan.mask_sel_cap
        assert host_plan.local_path == dev_plan.local_path
        assert host_plan.total_flops == dev_plan.total_flops

    # default-scatter capacity parity (the from_host sizing rule)
    inputs = PlanInputs.from_host(a, b, (2, 2, 2))
    assert inputs.cap_a == A.cap and inputs.cap_b == B.cap, (
        inputs.cap_a, A.cap, inputs.cap_b, B.cap)
    print("OK tune_oracle_parity")


def case_rect_grid_oracle_parity():
    """Rectangular single-layer grids: the host symbolic oracle matches the
    device pass BIT-FOR-BIT on a real 4×2×1 mesh (the stage stride is B's
    own tile row count — wrong if A's tile width were used, which only a
    pr ≠ pc grid can detect), the derived plans agree, and the batched
    driver's numeric product is correct — what licenses the autotuner's new
    rectangular (pr, pc, 1) candidates."""
    from repro.core.batched import PlanInputs, plan_from_symbolic, \
        symbolic3d_counts
    from repro.core.specs import PlanFloors, PlanSpec
    from repro.core.symbolic import host_symbolic_counts

    n = 64
    a = gen.rmat(6, edge_factor=8, seed=3)
    b = gen.rmat(6, edge_factor=8, seed=4)
    for pr, pc in ((4, 2), (2, 4)):
        grid = make_grid(pr, pc, 1)
        A = scatter_to_grid(a, grid, "A")
        B = scatter_to_grid(b, grid, "B")
        dev = symbolic3d_counts(A, B, grid)
        host = host_symbolic_counts(a, b, (pr, pc, 1))
        np.testing.assert_array_equal(np.asarray(dev.percol), host.percol)
        np.testing.assert_array_equal(np.asarray(dev.b_colcounts),
                                      host.b_colcounts)
        np.testing.assert_array_equal(np.asarray(dev.a_kcounts),
                                      host.a_kcounts)
        np.testing.assert_array_equal(np.asarray(dev.b_kcounts),
                                      host.b_kcounts)

        ppm = 1 << 22
        dev_plan = plan_batches(A, B, grid, per_process_memory=ppm,
                                spec=PlanSpec())
        inputs = PlanInputs.from_host(a, b, (pr, pc, 1))
        host_plan = plan_from_symbolic(
            host, inputs, ppm, PlanSpec(), PlanFloors(),
        )
        assert host_plan.num_batches == dev_plan.num_batches
        assert host_plan.caps == dev_plan.caps
        assert host_plan.sel_cap == dev_plan.sel_cap
        assert host_plan.local_path == dev_plan.local_path
        assert host_plan.total_flops == dev_plan.total_flops

        # numeric correctness of the batched driver on the rectangle
        got = np.zeros((n, n), np.float32)

        def consumer(bi, c, col_map):
            got[:] += reconstruct_sparse_c(c, grid, col_map, n, n)

        batched_summa3d(A, B, grid, 1 << 30, consumer)
        xa = np.asarray(a.to_dense())
        xb = np.asarray(b.to_dense())
        np.testing.assert_allclose(got, xa @ xb, rtol=1e-4, atol=1e-4)
    print("OK rect_grid_oracle_parity")


def _collect_cases():
    return {
        name[len("case_"):]: fn
        for name, fn in list(globals().items())
        if name.startswith("case_")
    }


CASES = _collect_cases()

if __name__ == "__main__":
    CASES = _collect_cases()  # include cases defined after this block
    which = sys.argv[1] if len(sys.argv) > 1 else None
    if which:
        CASES[which]()
    else:
        for name, fn in CASES.items():
            fn()
