"""Fault-tolerant long-run harness tests (fast lane, 1x1x1 grid).

Covers the durability contract end-to-end on single-device grids:
kill-and-resume parity for MCL and APSP (bitwise trajectory + final matrix,
zero extra fused-step retraces after restore), corrupt-checkpoint refusal
with fallback, the bounded retry ladder degrading to finer batches instead
of exceeding ``per_process_memory``, overflow storms through the injector's
slack override, and the warm-up-fixed straggler EWMA. The 8-device
kill-and-resume case lives in ``tests/app_cases.py`` (slow lane).
"""
import os
import pathlib
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core import summa3d
from repro.core.batched import RunReport, batched_summa3d, plan_batches
from repro.core.distsparse import gather_to_global, scatter_to_grid
from repro.core.specs import ExecSpec, PlanSpec
from repro.core.grid import make_grid
from repro.core.sparse import from_numpy_coo
from repro.runtime.driver import StragglerEwma
from repro.runtime.resilient import (
    PreemptionError,
    ResilientConfig,
    SpgemmFailureInjector,
    clear_preemption,
    install_preemption_handler,
    preemption_requested,
    restore_arrays_latest,
    run_iterated,
)
from repro.sparse_apps.graph_algorithms import (
    APSPConfig,
    apsp_iterate,
    apsp_iterate_resilient,
    apsp_reference,
)
from repro.sparse_apps.mcl import MCLConfig, mcl_iterate, mcl_iterate_resilient


@pytest.fixture(scope="module")
def grid1():
    return make_grid(1, 1, 1)


def _stochastic(n, density, seed):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, n)) < density).astype(np.float32)
    dense = dense + dense.T + np.eye(n, dtype=np.float32)
    dense = dense / dense.sum(axis=0, keepdims=True)
    r, c = np.nonzero(dense)
    return from_numpy_coo(r.astype(np.int32), c.astype(np.int32),
                          dense[r, c].astype(np.float32), (n, n))


def _weighted_digraph(n, density, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((n, n)).astype(np.float32) * 9 + 1
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, False)
    r, c = np.nonzero(mask)
    return from_numpy_coo(r.astype(np.int32), c.astype(np.int32),
                          w[r, c], (n, n))


def _triplets(m):
    k = int(m.nnz)
    return (np.asarray(m.rows)[:k].tolist(), np.asarray(m.cols)[:k].tolist(),
            np.asarray(m.vals)[:k].tolist())


def _traj(history):
    return [(h["nnz"], h["chaos"]) for h in history]


MCL_CFG = dict(max_iters=5, per_process_memory=1 << 24, max_per_col=16)


class TestRunIteratedGeneric:
    """The loop itself, on a trivial numeric workload (no SpGEMM)."""

    @staticmethod
    def _harness(tmp_path, injector=None, **rc_kw):
        def step(state, it, inj):
            state = {"x": state["x"] * 2 + it}
            return state, RunReport(retries=1), bool(state["x"][0] > 1000)

        return run_iterated(
            rc=ResilientConfig(ckpt_dir=str(tmp_path), **rc_kw),
            max_iters=6,
            cold_start=lambda: {"x": np.ones(3, np.float64)},
            step_fn=step,
            encode=lambda s: (dict(s), {"v": 1}),
            decode=lambda arrays, meta: dict(arrays),
            injector=injector,
        )

    def test_plain_run_and_report(self, tmp_path):
        res = self._harness(tmp_path)
        assert res.it == 6
        assert res.report.retries == 6  # per-iteration reports merged
        assert res.report.checkpoint_bytes > 0
        # x_{k+1} = 2 x_k + k from x_0 = 1 → 2, 5, 12, 27, 58, 121
        np.testing.assert_array_equal(res.state["x"], np.full(3, 121.0))

    def test_preempt_resumes_from_checkpoint(self, tmp_path):
        ref = self._harness(tmp_path / "ref")
        inj = SpgemmFailureInjector(preempt_iters=(4,))
        res = self._harness(tmp_path / "run", injector=inj)
        assert res.report.restarts == 1
        np.testing.assert_array_equal(res.state["x"], ref.state["x"])

    def test_restart_budget_bounded(self, tmp_path):
        class Always(SpgemmFailureInjector):
            def maybe_preempt(self, it, batch=None):
                if batch is None:
                    raise PreemptionError("flaky node")

        with pytest.raises(PreemptionError):
            self._harness(tmp_path, injector=Always(), max_restarts=2)

    def test_resume_false_is_fresh_initial_start(self, tmp_path):
        self._harness(tmp_path)  # leaves checkpoints behind
        warm = self._harness(tmp_path)
        assert warm.report.retries == 0  # warm-started at it=6, ran nothing
        fresh = self._harness(tmp_path, resume=False)
        assert fresh.report.retries == 6  # re-ran all iterations

    def test_keystr_keys_normalized(self, tmp_path):
        self._harness(tmp_path)
        arrays, meta, step, refused = restore_arrays_latest(str(tmp_path))
        assert list(arrays) == ["x"]  # not "['x']"
        assert meta == {"v": 1}
        assert refused == 0


class TestMclResilient:
    def test_kill_and_resume_bitwise_parity(self, grid1, tmp_path):
        a = _stochastic(48, 0.12, seed=0)
        cfg = MCLConfig(**MCL_CFG)
        final0, hist0 = mcl_iterate(a, grid1, cfg)

        rc = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=1)
        inj = SpgemmFailureInjector(preempt_iters=(3,))
        tc0 = summa3d.TRACE_COUNTS["fused_step"]
        final1, hist1, rep = mcl_iterate_resilient(
            a, grid1, cfg, rc, injector=inj)
        tc1 = summa3d.TRACE_COUNTS["fused_step"]

        assert rep.restarts == 1
        assert _traj(hist1) == _traj(hist0)
        assert _triplets(final1) == _triplets(final0)
        # plan signature restored with the iterate → the resumed fused step
        # replans to the identical static signature: zero extra retraces
        # (the warm run above already compiled the executables)
        assert tc1 - tc0 == 0

    def test_mid_iteration_preemption(self, grid1, tmp_path):
        a = _stochastic(48, 0.12, seed=0)
        cfg = MCLConfig(**MCL_CFG)
        final0, hist0 = mcl_iterate(a, grid1, cfg)
        rc = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=1,
                             async_save=False)
        inj = SpgemmFailureInjector(preempt_iters=(2,), preempt_batch=0)
        final1, hist1, rep = mcl_iterate_resilient(
            a, grid1, cfg, rc, injector=inj)
        assert rep.restarts == 1
        assert _traj(hist1) == _traj(hist0)
        assert _triplets(final1) == _triplets(final0)

    def test_corrupt_checkpoint_refused_with_fallback(self, grid1, tmp_path):
        a = _stochastic(48, 0.12, seed=0)
        cfg = MCLConfig(**MCL_CFG)
        final0, hist0 = mcl_iterate(a, grid1, cfg)
        rc = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=1)
        # truncate the step-3 checkpoint after it lands, then preempt: the
        # restore must refuse step 3 and fall back to step 2
        inj = SpgemmFailureInjector(preempt_iters=(3,), corrupt_steps=(3,))
        final1, hist1, rep = mcl_iterate_resilient(
            a, grid1, cfg, rc, injector=inj)
        assert rep.refused_restores >= 1
        assert rep.restarts == 1
        assert _traj(hist1) == _traj(hist0)
        assert _triplets(final1) == _triplets(final0)

    def test_overflow_storm_parity(self, grid1, tmp_path):
        """Forced capacity under-prediction (slack override) drives the §IV-A
        retry ladder; the result must still match the calm run (allclose:
        different caps can reorder f32 reductions in the prune step)."""
        a = _stochastic(48, 0.12, seed=0)
        cfg = MCLConfig(**MCL_CFG)
        final0, hist0 = mcl_iterate(a, grid1, cfg)
        rc = ResilientConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
        # iteration 0: no floors pinned yet, so the slack override really
        # under-predicts (later iterations are shielded by the running-max
        # caps floors — the storm must hit before they are seeded)
        inj = SpgemmFailureInjector(overflow_iters=(0,), overflow_slack=0.05)
        final1, hist1, rep = mcl_iterate_resilient(
            a, grid1, cfg, rc, injector=inj)
        assert rep.retries + rep.sel_retries + rep.replans > 0
        assert [h["nnz"] for h in hist1] == [h["nnz"] for h in hist0]
        r0, c0, v0 = _triplets(final0)
        r1, c1, v1 = _triplets(final1)
        assert (r1, c1) == (r0, c0)
        np.testing.assert_allclose(v1, v0, rtol=1e-5, atol=1e-7)


class TestGracefulDegradation:
    def test_ladder_degrades_instead_of_exceeding_budget(self, grid1):
        """With a budget a fraction of the true output footprint and a
        slack-starved plan, capacity doubling would blow per_process_memory;
        the driver must replan the failing batch at finer granularity and
        still produce the exact product — reported in RunReport."""
        rng = np.random.default_rng(0)
        n = 64
        dense = (rng.random((n, n)) < 0.3).astype(np.float32) \
            * rng.random((n, n)).astype(np.float32)
        r, c = np.nonzero(dense)
        A = from_numpy_coo(r.astype(np.int32), c.astype(np.int32),
                           dense[r, c], (n, n))
        a = scatter_to_grid(A, grid1, "A")
        b = scatter_to_grid(A, grid1, "B")
        ref_plan = plan_batches(a, b, grid1, per_process_memory=1 << 30,
                                spec=PlanSpec(slack=1.0, local_path="esc"))
        inputs = 12 * (int(np.asarray(a.nnz).max())
                       + int(np.asarray(b.nnz).max()))
        budget = inputs + 12 * ref_plan.caps.flops_cap // 4
        outs = {}
        res = batched_summa3d(
            a, b, grid1, per_process_memory=budget,
            consumer=lambda bi, cb, cm: outs.setdefault(bi, (cb, cm)),
            spec=PlanSpec(slack=0.05), exec_spec=ExecSpec(max_retries=12),
        )
        assert res.report.ladder_blocked > 0
        assert res.report.replans > 0
        assert len(res.report.degraded_batches) == res.report.replans
        ref = dense @ dense
        got = np.zeros_like(ref)
        for bi, (cb, cm) in outs.items():
            gl = gather_to_global(cb)
            nz = int(gl.nnz)
            got[np.asarray(gl.rows)[:nz],
                cm.reshape(-1)[np.asarray(gl.cols)[:nz]]] += (
                np.asarray(gl.vals)[:nz])
        np.testing.assert_allclose(got, ref, atol=1e-4)

    def test_degrade_off_raises(self, grid1):
        """degrade=False keeps the pre-existing unbounded-ladder behavior."""
        rng = np.random.default_rng(0)
        n = 64
        dense = (rng.random((n, n)) < 0.3).astype(np.float32) \
            * rng.random((n, n)).astype(np.float32)
        r, c = np.nonzero(dense)
        A = from_numpy_coo(r.astype(np.int32), c.astype(np.int32),
                           dense[r, c], (n, n))
        a = scatter_to_grid(A, grid1, "A")
        b = scatter_to_grid(A, grid1, "B")
        outs = {}
        res = batched_summa3d(
            a, b, grid1, per_process_memory=1 << 26,
            consumer=lambda bi, cb, cm: outs.setdefault(bi, (cb, cm)),
            spec=PlanSpec(slack=0.05),
            exec_spec=ExecSpec(max_retries=12, degrade=False),
        )
        assert res.report.ladder_blocked == 0
        assert res.report.degraded_batches == ()


class TestApsp:
    def test_matches_floyd_warshall(self, grid1):
        a = _weighted_digraph(40, 0.08, seed=1)
        D, hist = apsp_iterate(a, grid1, APSPConfig(
            per_process_memory=1 << 24))
        n = a.shape[0]
        ref = apsp_reference(a)
        got = np.full((n, n), np.inf, np.float64)
        k = int(D.nnz)
        got[np.asarray(D.rows[:k]), np.asarray(D.cols[:k])] = \
            np.asarray(D.vals[:k])
        assert (np.isfinite(got) == np.isfinite(ref)).all()
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[fin], ref[fin], rtol=1e-5)
        # fixpoint reached before the hop-doubling bound
        assert len(hist) <= int(np.ceil(np.log2(n - 1))) + 1

    def test_resilient_resume_parity(self, grid1, tmp_path):
        a = _weighted_digraph(40, 0.08, seed=1)
        cfg = APSPConfig(per_process_memory=1 << 24)
        D0, hist0 = apsp_iterate(a, grid1, cfg)
        rc = ResilientConfig(ckpt_dir=str(tmp_path))
        inj = SpgemmFailureInjector(preempt_iters=(2,))
        D1, hist1, rep = apsp_iterate_resilient(a, grid1, cfg, rc,
                                                injector=inj)
        assert rep.restarts == 1
        assert [h["nnz"] for h in hist1] == [h["nnz"] for h in hist0]
        assert _triplets(D1) == _triplets(D0)


class TestStragglerEwma:
    def test_warmup_seeds_with_minimum(self):
        ew = StragglerEwma(factor=3.0, alpha=0.2, warmup=2)
        # compile-heavy first steps must not poison the baseline or fire
        assert not ew.observe(5.0)
        assert not ew.observe(4.0)
        assert not ew.observe(0.1)  # arms with min = 0.1
        assert ew.ewma == pytest.approx(0.1)
        assert ew.observe(1.0)  # 1.0 > 3 * 0.1 → straggler
        assert not ew.observe(0.1)

    def test_no_event_during_warmup(self):
        ew = StragglerEwma(factor=3.0, alpha=0.2, warmup=5)
        assert not any(ew.observe(dt) for dt in [0.1, 100.0, 0.1, 50.0])

    def test_loop_counts_straggler_events(self, tmp_path):
        inj = SpgemmFailureInjector(straggle_batches=((3, 0),),
                                    batch_straggle_s=0.25)

        def step(state, it, inj_):
            inj_.maybe_straggle_batch(it, 0)
            return state, None, False

        res = run_iterated(
            rc=ResilientConfig(ckpt_dir=str(tmp_path), ewma_warmup=1),
            max_iters=5,
            cold_start=lambda: {"x": np.zeros(1)},
            step_fn=step,
            encode=lambda s: (dict(s), {}),
            decode=lambda arrays, meta: dict(arrays),
            injector=inj,
        )
        assert res.report.straggler_events >= 1


class TestSigtermTranslation:
    """A real SIGTERM is translated into `PreemptionError` at the iteration
    boundary (`install_preemption_handler` + `check_preemption`), so an
    orchestrator's stop signal takes the tested restore path instead of
    killing the process mid-write."""

    def test_inprocess_sigterm_resumes_and_matches(self, tmp_path):
        install_preemption_handler()
        clear_preemption()

        def mk_step(kill_at):
            def step(state, it, inj):
                if it == kill_at:
                    os.kill(os.getpid(), signal.SIGTERM)  # handled, not fatal
                return {"x": state["x"] * 2 + it}, None, it >= 4
            return step

        def run(path, kill_at):
            return run_iterated(
                rc=ResilientConfig(ckpt_dir=str(path)),
                max_iters=6,
                cold_start=lambda: {"x": np.ones(2, np.float64)},
                step_fn=mk_step(kill_at),
                encode=lambda s: (dict(s), {"v": 1}),
                decode=lambda arrays, meta: dict(arrays),
            )

        ref = run(tmp_path / "ref", kill_at=None)
        res = run(tmp_path / "run", kill_at=2)
        assert ref.report.restarts == 0
        assert res.report.restarts == 1  # the signal became a clean restore
        assert not preemption_requested()  # translated AND cleared
        np.testing.assert_array_equal(res.state["x"], ref.state["x"])

    def test_subprocess_sigterm_drains_cleanly(self, tmp_path):
        src = pathlib.Path(__file__).resolve().parents[1] / "src"
        ckpt = tmp_path / "ckpt"
        script = textwrap.dedent(f"""
            import time
            import numpy as np
            from repro.runtime.resilient import (
                ResilientConfig, install_preemption_handler, run_iterated,
            )
            install_preemption_handler()

            def step(state, it, inj):
                time.sleep(0.3)
                return {{"x": state["x"] * 2 + it}}, None, it >= 5

            res = run_iterated(
                rc=ResilientConfig(ckpt_dir={str(ckpt)!r}, async_save=False),
                max_iters=6,
                cold_start=lambda: {{"x": np.ones(2, np.float64)}},
                step_fn=step,
                encode=lambda s: (dict(s), {{"v": 1}}),
                decode=lambda arrays, meta: dict(arrays),
            )
            print("RESTARTS", res.report.restarts,
                  "X", float(res.state["x"][0]))
        """)
        env = dict(os.environ, PYTHONPATH=str(src), JAX_PLATFORMS="cpu")
        proc = subprocess.Popen(
            [sys.executable, "-c", script], env=env, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        try:
            deadline = time.time() + 120
            while time.time() < deadline:
                if ckpt.exists() and any(ckpt.iterdir()):
                    break
                assert proc.poll() is None, proc.communicate()
                time.sleep(0.05)
            else:
                pytest.fail("child never wrote a checkpoint")
            proc.send_signal(signal.SIGTERM)  # a REAL kill from outside
            out, err = proc.communicate(timeout=180)
        finally:
            if proc.poll() is None:
                proc.kill()
        assert proc.returncode == 0, (out, err)
        line = [ln for ln in out.splitlines() if ln.startswith("RESTARTS")]
        assert line, (out, err)
        _, restarts, _, x = line[0].split()
        assert int(restarts) >= 1  # SIGTERM took the restore path
        # trajectory parity: x_{k+1} = 2 x_k + k from 1 → 121 after 6 iters
        assert float(x) == 121.0
